"""Mid-fit checkpoint/resume tests (DESIGN.md §13).

The contract: a fit killed at any checkpointed cut — iteration boundary
on every path, batch boundary on the sequential minibatch path — resumes
to a final model BIT-EXACT with the uninterrupted run: same share words,
same dealer counters, same online AND offline CommLog tallies. That
holds because the checkpoint pins (a) the secret-shared state, (b) the
cursor, and (c) the per-class consumed-request counts, from which every
dealer's PCG64 streams are re-positioned with one jump per class.
"""
import os
import shutil

import numpy as np
import pytest

from repro.checkpoint.fit import FitCheckpointer, FitState
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.triples import TripleBank

from test_wire import _assert_same_fit, _blobs, _run_two_party, _split


def _resume_from(step: int, src_dir, tmp_path, cfg, a, b, dealer=None):
    """Copy ONE published step into a fresh dir and resume from it (no
    further saves — every=huge)."""
    d2 = tmp_path / f"resume_{step}"
    d2.mkdir()
    shutil.copytree(os.path.join(src_dir, f"step_{step:010d}"),
                    str(d2 / f"step_{step:010d}"))
    ck = FitCheckpointer(str(d2), every=10**9)
    return SecureKMeans(cfg).fit(a, b, checkpoint=ck, resume=True,
                                 dealer=dealer)


def _check_all_steps(cfg, a, b, tmp_path, *, batch_every=None,
                     dealer_factory=None):
    ref = SecureKMeans(cfg).fit(
        a, b, dealer=dealer_factory() if dealer_factory else None)
    d = str(tmp_path / "ck")
    ck = FitCheckpointer(d, every=1, batch_every=batch_every, keep=0)
    full = SecureKMeans(cfg).fit(
        a, b, dealer=dealer_factory() if dealer_factory else None,
        checkpoint=ck)
    # checkpointing itself must not perturb the fit
    _assert_same_fit(ref, full)
    steps = ck.all_steps()
    assert steps, "no checkpoints were published"
    for s in steps:
        res = _resume_from(s, d, tmp_path, cfg, a, b,
                           dealer=dealer_factory() if dealer_factory
                           else None)
        _assert_same_fit(ref, res)
        assert res.log.by_tag("offline") == ref.log.by_tag("offline"), s
    return steps


# ---------------------------------------------------------------------------
# full-batch: every offline mode, both partitions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("offline", ["on_demand", "pooled", "streamed"])
@pytest.mark.parametrize("partition,sparse",
                         [("vertical", False), ("horizontal", True)])
def test_fullbatch_resume_bit_exact(tmp_path, offline, partition, sparse):
    x = _blobs(48, 4, 2, seed=11, sparse_frac=0.5 if sparse else 0.0)
    a, b = _split(x, partition)
    cfg = KMeansConfig(k=2, iters=3, seed=5, partition=partition,
                       sparse=sparse, offline=offline, backend="xla")
    steps = _check_all_steps(cfg, a, b, tmp_path)
    assert steps == [1_000_000, 2_000_000]   # boundaries only, never last


# ---------------------------------------------------------------------------
# minibatch: mid-iteration (sequential) and iteration-boundary (pipelined)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
def test_minibatch_batch_boundary_resume(tmp_path, partition):
    """Sequential executor, checkpoint after EVERY batch: resume from a cut
    in the middle of an iteration (partial accumulators + completed
    batches' assignment shares restored)."""
    x = _blobs(48, 4, 2, seed=11)
    a, b = _split(x, partition)
    cfg = KMeansConfig(k=2, iters=3, seed=5, partition=partition,
                       offline="streamed", batch_size=16, pipeline=False,
                       backend="xla")
    steps = _check_all_steps(cfg, a, b, tmp_path, batch_every=1)
    assert any(s % 1_000_000 for s in steps), "no mid-iteration cuts"


@pytest.mark.parametrize("pipeline", [False, True])
def test_minibatch_iteration_boundary_resume(tmp_path, pipeline):
    x = _blobs(48, 4, 2, seed=11)
    a, b = _split(x, "vertical")
    cfg = KMeansConfig(k=2, iters=3, seed=5, partition="vertical",
                       offline="streamed", batch_size=16,
                       pipeline=pipeline, backend="xla")
    _check_all_steps(cfg, a, b, tmp_path)


def test_batch_checkpoint_on_pipelined_executor_rejected(tmp_path):
    """Mid-iteration cuts are only canonical on the sequential executor;
    the pipelined one merges batch t+1's traffic before batch t's post."""
    x = _blobs(48, 4, 2, seed=11)
    a, b = _split(x, "vertical")
    cfg = KMeansConfig(k=2, iters=2, seed=5, partition="vertical",
                       offline="streamed", batch_size=16, pipeline=True,
                       backend="xla")
    ck = FitCheckpointer(str(tmp_path / "ck"), every=1, batch_every=1)
    with pytest.raises(ValueError, match="pipeline"):
        SecureKMeans(cfg).fit(a, b, checkpoint=ck)


# ---------------------------------------------------------------------------
# bank-backed dealers: FIFO realignment on resume
# ---------------------------------------------------------------------------

def test_bank_fullbatch_resume(tmp_path):
    x = _blobs(48, 4, 2, seed=11)
    a, b = _split(x, "vertical")
    cfg = KMeansConfig(k=2, iters=3, seed=5, partition="vertical",
                       backend="xla")
    km = SecureKMeans(cfg)
    key, plan, _ = km.plan_fit(a.shape, b.shape)

    def dealer_factory():
        bank = TripleBank(seed=cfg.seed)
        bank.provision(key, plan, copies=1)
        return bank.dealer(key)

    _check_all_steps(cfg, a, b, tmp_path, dealer_factory=dealer_factory)


def test_bank_minibatch_resume(tmp_path):
    x = _blobs(48, 4, 2, seed=11)
    a, b = _split(x, "vertical")
    cfg = KMeansConfig(k=2, iters=3, seed=5, partition="vertical",
                       offline="streamed", batch_size=16, pipeline=True,
                       backend="xla")
    km = SecureKMeans(cfg)
    key, plan, _ = km.plan_fit(a.shape, b.shape)

    def dealer_factory():
        bank = TripleBank(seed=cfg.seed)
        bank.provision(key, plan, copies=1)
        return bank.dealer(key)

    _check_all_steps(cfg, a, b, tmp_path, dealer_factory=dealer_factory)


# ---------------------------------------------------------------------------
# guard rails
# ---------------------------------------------------------------------------

def test_resume_without_checkpoint_rejected():
    x = _blobs(48, 4, 2, seed=11)
    a, b = _split(x, "vertical")
    cfg = KMeansConfig(k=2, iters=2, seed=5, backend="xla")
    with pytest.raises(ValueError, match="resume"):
        SecureKMeans(cfg).fit(a, b, resume=True)


def test_fingerprint_mismatch_rejected(tmp_path):
    x = _blobs(48, 4, 2, seed=11)
    a, b = _split(x, "vertical")
    d = str(tmp_path / "ck")
    cfg1 = KMeansConfig(k=2, iters=3, seed=5, backend="xla")
    SecureKMeans(cfg1).fit(a, b, checkpoint=FitCheckpointer(d, every=1))
    cfg2 = KMeansConfig(k=2, iters=3, seed=6, backend="xla")
    with pytest.raises(ValueError, match="fingerprint"):
        SecureKMeans(cfg2).fit(a, b, checkpoint=FitCheckpointer(d),
                               resume=True)


def test_tmp_dirs_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    ck = FitCheckpointer(d, every=1, keep=2)
    for it in (1, 2, 3, 4):
        ck.save(FitState(iteration=it, batch=0,
                         mu0=np.zeros((2, 4), np.uint64),
                         mu1=np.zeros((2, 4), np.uint64),
                         counters={"n_matmul": 0, "n_mul": 0, "n_bin": 0},
                         comm={"bytes": [], "rounds": []}, advance={}))
    # a torn writer's tmp dir must be invisible to discovery
    os.makedirs(os.path.join(d, "step_0000000099.tmp"))
    assert ck.all_steps() == [3_000_000, 4_000_000]   # keep=2 pruned 1, 2
    assert ck.latest().iteration == 4


# ---------------------------------------------------------------------------
# killed mid-fit — in-process and as two real processes over TCP
# ---------------------------------------------------------------------------

class _Die(BaseException):
    """Out-of-band kill signal the fit loop cannot catch as Exception."""


def test_killed_fit_resumes_bit_exact(tmp_path):
    x = _blobs(48, 4, 2, seed=11)
    a, b = _split(x, "vertical")
    cfg = KMeansConfig(k=2, iters=3, seed=5, offline="pooled",
                       backend="xla")
    ref = SecureKMeans(cfg).fit(a, b)

    d = str(tmp_path / "ck")

    def kill_at_1(state, _path):
        if state.iteration == 1:
            raise _Die

    with pytest.raises(_Die):
        SecureKMeans(cfg).fit(
            a, b, checkpoint=FitCheckpointer(d, every=1,
                                             after_save=kill_at_1))
    res = SecureKMeans(cfg).fit(a, b, checkpoint=FitCheckpointer(d),
                                resume=True)
    _assert_same_fit(ref, res)
    assert res.log.by_tag("offline") == ref.log.by_tag("offline")


def test_two_process_kill_and_resume_bit_exact(tmp_path):
    """The full acceptance path: party A dies (os._exit) right after the
    iteration-1 checkpoint publishes, a fresh A+B pair resumes, and the
    final npz equals a clean two-process run's."""
    import json
    ckdir = str(tmp_path / "ck")
    clean = str(tmp_path / "clean.npz")
    resumed = str(tmp_path / "resumed.npz")
    rc, out, _rb, _bo = _run_two_party(
        ["--iters", "3", "--out", clean])
    assert rc == 0, out
    rc, out, _rb, _bo = _run_two_party(
        ["--iters", "3", "--checkpoint-dir", ckdir, "--die-at-iter", "1"])
    assert rc == 17, out                 # scripted crash, post-publish
    assert "DYING" in out
    rc, out, _rb, _bo = _run_two_party(
        ["--iters", "3", "--checkpoint-dir", ckdir, "--resume",
         "--out", resumed])
    assert rc == 0, out
    zc, zr = np.load(clean), np.load(resumed)
    for k in ("mu0", "mu1", "c0", "c1", "p0", "p1"):
        np.testing.assert_array_equal(zc[k], zr[k])
    mc = json.loads(bytes(zc["meta"]))
    mr = json.loads(bytes(zr["meta"]))
    assert mc["counters"] == mr["counters"]
    assert mc["fit_online"] == mr["fit_online"]
    assert mc["predict_online"] == mr["predict_online"]
