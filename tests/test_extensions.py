"""Tests for the beyond-deliverable extensions: serving driver, secure
normalization, and the KS-adder kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol as P
from repro.core import ring
from repro.core.normalize import (normalize_horizontal, normalize_local,
                                  secure_minmax)
from repro.core.sharing import rec_real


# ---------------------------------------------------------------------------
# serving driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["granite-34b", "rwkv6-1.6b",
                                  "deepseek-v2-236b"])
def test_serve_driver(arch):
    from repro.launch.serve import serve
    out = serve(arch, reduced=True, batch=2, prompt_len=8, gen=6,
                verbose=False)
    assert out["finite"]
    assert out["tokens"].shape == (2, 6)
    # greedy decode of a fixed model+prompt is deterministic
    out2 = serve(arch, reduced=True, batch=2, prompt_len=8, gen=6,
                 verbose=False)
    np.testing.assert_array_equal(out["tokens"], out2["tokens"])


# ---------------------------------------------------------------------------
# secure joint normalization
# ---------------------------------------------------------------------------

def test_normalize_local_bounds():
    rng = np.random.default_rng(0)
    x = rng.normal(3, 17, (50, 4))
    z = normalize_local(x)
    assert z.min() >= 0 and z.max() <= 1 + 1e-9


def test_secure_minmax_matches_plain():
    rng = np.random.default_rng(1)
    xa, xb = rng.normal(0, 5, (40, 6)), rng.normal(2, 3, (25, 6))
    ctx = P.make_ctx(0)
    g_min, g_max = secure_minmax(ctx, xa, xb, rng)
    full = np.vstack([xa, xb])
    np.testing.assert_allclose(np.asarray(rec_real(g_min)), full.min(0),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(rec_real(g_max)), full.max(0),
                               atol=1e-4)


def test_normalize_horizontal_end_to_end():
    rng = np.random.default_rng(2)
    xa, xb = rng.normal(0, 5, (30, 3)), rng.normal(1, 9, (20, 3))
    ctx = P.make_ctx(1)
    za, zb = normalize_horizontal(ctx, xa, xb, rng)
    z = np.vstack([za, zb])
    assert z.min() >= -1e-3 and z.max() <= 1 + 1e-3
    ref = normalize_local(np.vstack([xa, xb]))
    np.testing.assert_allclose(z, ref, atol=1e-3)


# ---------------------------------------------------------------------------
# KS-adder kernel == protocol.msb_carry local pieces
# ---------------------------------------------------------------------------

def test_ks_carry_kernel_matches_protocol():
    """Drive the real protocol to capture each level's exchanged masks and
    triples, then verify the fused kernel reproduces both parties' final
    carry shares (and hence the exact MSB)."""
    from repro.core.sharing import BShare, share
    from repro.core.triples import TrustedDealer
    from repro.kernels.ksadder import ks_carry_share, LEVELS

    rng = np.random.default_rng(3)
    n, m = 16, 128
    vals = rng.integers(-(2 ** 40), 2 ** 40, (n, m))
    sh = share(vals.astype(np.int64).astype(np.uint64), rng)

    # reference: run msb_carry while recording the per-level Beaver state
    rec_state = {"e": [], "f": [], "u0": [], "v0": [], "z0": [],
                 "u1": [], "v1": [], "z1": []}

    class RecordingCtx(P.Ctx):
        def send(self, nbytes, rounds=1):
            pass

    dealer = TrustedDealer(seed=9)
    ctx = RecordingCtx(dealer=dealer, log=__import__(
        "repro.core.channel", fromlist=["CommLog"]).CommLog())

    orig_band = P.band

    def band_spy(c, x, y):
        shape = jnp.broadcast_shapes(x.shape, y.shape)
        t = dealer.bin_triple(shape)
        xb = BShare(jnp.broadcast_to(x.b0, shape),
                    jnp.broadcast_to(x.b1, shape))
        yb = BShare(jnp.broadcast_to(y.b0, shape),
                    jnp.broadcast_to(y.b1, shape))
        e = (xb.b0 ^ t.u.b0) ^ (xb.b1 ^ t.u.b1)
        f = (yb.b0 ^ t.v.b0) ^ (yb.b1 ^ t.v.b1)
        rec_state["e"].append(e)
        rec_state["f"].append(f)
        for nm, val in (("u0", t.u.b0), ("v0", t.v.b0), ("z0", t.z.b0),
                        ("u1", t.u.b1), ("v1", t.v.b1), ("z1", t.z.b1)):
            rec_state[nm].append(val)
        z0 = t.z.b0 ^ (t.u.b0 & f) ^ (e & (t.v.b0 ^ f))
        z1 = t.z.b1 ^ (t.u.b1 & f) ^ (e & t.v.b1)
        return BShare(z0, z1)

    P.band = band_spy
    try:
        want_bit = P.msb_carry(ctx, sh)
    finally:
        P.band = orig_band

    # kernel replay: level 0 (initial g) + 6 stacked levels
    def grab(idx):
        return {k: rec_state[k][idx] for k in rec_state}

    lvl = [grab(i) for i in range(7)]
    el = jnp.stack([l["e"] for l in lvl[1:]]).reshape(6, 2, n, m)
    fl = jnp.stack([l["f"] for l in lvl[1:]]).reshape(6, 2, n, m)
    carries = {}
    for party0, (us, vs, zs, xw) in {
            True: ("u0", "v0", "z0", sh.s0),
            False: ("u1", "v1", "z1", sh.s1)}.items():
        ul = jnp.stack([l[us] for l in lvl[1:]]).reshape(6, 2, n, m)
        vl = jnp.stack([l[vs] for l in lvl[1:]]).reshape(6, 2, n, m)
        zl = jnp.stack([l[zs] for l in lvl[1:]]).reshape(6, 2, n, m)
        carries[party0] = ks_carry_share(
            xw ^ jnp.zeros_like(xw), lvl[0]["e"], lvl[0]["f"],
            lvl[0][us], lvl[0][vs], lvl[0][zs], el, fl, ul, vl, zl,
            party0=party0)
    g = np.asarray(carries[True] ^ carries[False], np.uint64)
    # msb = p_orig[63] ^ G[62]  (protocol.msb_carry's final extraction)
    p_orig = np.asarray(sh.s0 ^ sh.s1, np.uint64)
    msb = ((p_orig >> 63) & 1) ^ ((g >> 62) & 1)
    np.testing.assert_array_equal(msb.astype(np.int64),
                                  (vals < 0).astype(np.int64))
    # and it agrees with the protocol's own output
    from repro.core.sharing import rec_b
    np.testing.assert_array_equal(np.asarray(rec_b(want_bit), np.uint64),
                                  msb)
