"""Tests for the beyond-deliverable extensions: serving driver, secure
normalization, and the KS-adder kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol as P
from repro.core import ring
from repro.core.normalize import (normalize_horizontal, normalize_local,
                                  secure_minmax)
from repro.core.sharing import rec_real


# ---------------------------------------------------------------------------
# serving driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["granite-34b", "rwkv6-1.6b",
                                  "deepseek-v2-236b"])
def test_serve_driver(arch):
    from repro.launch.serve import serve
    out = serve(arch, reduced=True, batch=2, prompt_len=8, gen=6,
                verbose=False)
    assert out["finite"]
    assert out["tokens"].shape == (2, 6)
    # greedy decode of a fixed model+prompt is deterministic
    out2 = serve(arch, reduced=True, batch=2, prompt_len=8, gen=6,
                 verbose=False)
    np.testing.assert_array_equal(out["tokens"], out2["tokens"])


# ---------------------------------------------------------------------------
# secure joint normalization
# ---------------------------------------------------------------------------

def test_normalize_local_bounds():
    rng = np.random.default_rng(0)
    x = rng.normal(3, 17, (50, 4))
    z = normalize_local(x)
    assert z.min() >= 0 and z.max() <= 1 + 1e-9


def test_secure_minmax_matches_plain():
    rng = np.random.default_rng(1)
    xa, xb = rng.normal(0, 5, (40, 6)), rng.normal(2, 3, (25, 6))
    ctx = P.make_ctx(0)
    g_min, g_max = secure_minmax(ctx, xa, xb, rng)
    full = np.vstack([xa, xb])
    np.testing.assert_allclose(np.asarray(rec_real(g_min)), full.min(0),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(rec_real(g_max)), full.max(0),
                               atol=1e-4)


def test_normalize_horizontal_end_to_end():
    rng = np.random.default_rng(2)
    xa, xb = rng.normal(0, 5, (30, 3)), rng.normal(1, 9, (20, 3))
    ctx = P.make_ctx(1)
    za, zb = normalize_horizontal(ctx, xa, xb, rng)
    z = np.vstack([za, zb])
    assert z.min() >= -1e-3 and z.max() <= 1 + 1e-3
    ref = normalize_local(np.vstack([xa, xb]))
    np.testing.assert_allclose(z, ref, atol=1e-3)


# ---------------------------------------------------------------------------
# KS-adder kernel == protocol.msb_carry local pieces
# ---------------------------------------------------------------------------

def test_ks_carry_kernel_matches_protocol():
    """Run the sequential band-by-band Kogge-Stone adder (the seed
    formulation of msb_carry) as the oracle, recording each level's
    exchanged masks and triples, then verify (a) the fused kernel reproduces
    both parties' final carry shares, and (b) protocol.msb_carry — which now
    dispatches the same fused recombination through the ring backend —
    extracts the identical MSB from identical dealer randomness."""
    from repro.core.channel import CommLog
    from repro.core.sharing import BShare, rec_b, share
    from repro.core.triples import TrustedDealer
    from repro.kernels.ksadder import ks_carry_share, LEVELS

    rng = np.random.default_rng(3)
    n, m = 16, 128
    vals = rng.integers(-(2 ** 40), 2 ** 40, (n, m))
    sh = share(vals.astype(np.int64).astype(np.uint64), rng)

    dealer = TrustedDealer(seed=9)
    state = []  # one (e, f, triple) per AND level

    def band_ref(x: BShare, y: BShare) -> BShare:
        t = dealer.bin_triple(x.shape)
        e = (x.b0 ^ t.u.b0) ^ (x.b1 ^ t.u.b1)
        f = (y.b0 ^ t.v.b0) ^ (y.b1 ^ t.v.b1)
        state.append((e, f, t))
        z0 = t.z.b0 ^ (t.u.b0 & f) ^ (e & (t.v.b0 ^ f))
        z1 = t.z.b1 ^ (t.u.b1 & f) ^ (e & t.v.b1)
        return BShare(z0, z1)

    x = BShare(sh.s0, jnp.zeros_like(sh.s0))
    y = BShare(jnp.zeros_like(sh.s1), sh.s1)
    g = band_ref(x, y)
    p = BShare(x.b0 ^ y.b0, x.b1 ^ y.b1)
    for s in LEVELS:
        lhs = BShare(jnp.stack([p.b0, p.b0]), jnp.stack([p.b1, p.b1]))
        rhs = BShare(jnp.stack([g.b0 << s, p.b0 << s]),
                     jnp.stack([g.b1 << s, p.b1 << s]))
        both = band_ref(lhs, rhs)
        g = BShare(g.b0 ^ both.b0[0], g.b1 ^ both.b1[0])
        p = BShare(both.b0[1], both.b1[1])

    e0, f0, t0 = state[0]
    el = jnp.stack([lv[0] for lv in state[1:]])
    fl = jnp.stack([lv[1] for lv in state[1:]])
    carries = {}
    for party0 in (True, False):
        ul = jnp.stack([(lv[2].u.b0 if party0 else lv[2].u.b1)
                        for lv in state[1:]])
        vl = jnp.stack([(lv[2].v.b0 if party0 else lv[2].v.b1)
                        for lv in state[1:]])
        zl = jnp.stack([(lv[2].z.b0 if party0 else lv[2].z.b1)
                        for lv in state[1:]])
        carries[party0] = ks_carry_share(
            sh.s0 if party0 else sh.s1, e0, f0,
            t0.u.b0 if party0 else t0.u.b1,
            t0.v.b0 if party0 else t0.v.b1,
            t0.z.b0 if party0 else t0.z.b1,
            el, fl, ul, vl, zl, party0=party0)
    # (a) fused kernel == sequential oracle, per party share
    np.testing.assert_array_equal(np.asarray(carries[True], np.uint64),
                                  np.asarray(g.b0, np.uint64))
    np.testing.assert_array_equal(np.asarray(carries[False], np.uint64),
                                  np.asarray(g.b1, np.uint64))
    gw = np.asarray(carries[True] ^ carries[False], np.uint64)
    # msb = p_orig[63] ^ G[62]  (protocol.msb_carry's final extraction)
    p_orig = np.asarray(sh.s0 ^ sh.s1, np.uint64)
    msb = ((p_orig >> 63) & 1) ^ ((gw >> 62) & 1)
    np.testing.assert_array_equal(msb.astype(np.int64),
                                  (vals < 0).astype(np.int64))
    # (b) protocol.msb_carry consumes the same triples in the same order, so
    # an identically-seeded dealer must yield the identical MSB bits
    ctx = P.Ctx(dealer=TrustedDealer(seed=9), log=CommLog())
    want_bit = P.msb_carry(ctx, sh)
    np.testing.assert_array_equal(np.asarray(rec_b(want_bit), np.uint64), msb)
