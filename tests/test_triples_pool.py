"""Offline planner/pool tests: the PooledDealer must be a bit-exact,
zero-host-work replacement for the on-demand TrustedDealer.

The load-bearing property: bulk per-class generation (one stacked RNG draw
+ one batched ring op per shape-class) serves the SAME uint64 words as the
on-demand dealer under the same seed — at the single-triple level, at the
pjit flat-tensor level, and through a full SecureKMeans.fit for all four
partition x sparsity combinations."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.triples import (PlanningDealer, PlanRequest, PooledDealer,
                                PoolExhaustedError, StreamingPooledDealer,
                                TriplePlan, TrustedDealer)
from repro.launch.kmeans_step import (materialize_offline,
                                      pooled_offline_arrays,
                                      record_offline_shapes)

RNG = np.random.default_rng(77)


def _consume(dealer, requests):
    """Serve a request schedule, returning every share word as numpy."""
    out = []
    for r in requests:
        if r.kind == "matmul":
            t = dealer.matmul_triple(*r.shape, tag=r.tag)
            out += [t.u.s0, t.u.s1, t.v.s0, t.v.s1, t.z.s0, t.z.s1]
        elif r.kind == "mul":
            t = dealer.mul_triple(r.shape, tag=r.tag)
            out += [t.u.s0, t.u.s1, t.v.s0, t.v.s1, t.z.s0, t.z.s1]
        elif r.kind == "bin":
            t = dealer.bin_triple(r.shape, tag=r.tag)
            out += [t.u.b0, t.u.b1, t.v.b0, t.v.b1, t.z.b0, t.z.b1]
        elif r.kind == "rand":
            out.append(dealer.rand(r.shape))
        else:
            out.append(np.uint64(dealer.mask_seed()))
    return [np.asarray(a, np.uint64) for a in out]


@given(st.lists(st.sampled_from(["matmul", "mul", "bin", "rand", "seed"]),
                min_size=1, max_size=24),
       st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=20)
def test_pooled_replays_trusted_dealer_bit_exact(kinds, seed):
    """Random interleaved schedules over a few shape-classes: every served
    word identical between on-demand and bulk generation."""
    shapes = {"matmul": ((5, 3), (3, 2)), "mul": (4, 3), "bin": (2, 7),
              "rand": (6,), "seed": ()}
    requests = [PlanRequest(k, shapes[k], "t") for k in kinds]
    plan = TriplePlan(requests)
    a = _consume(TrustedDealer(seed=seed), requests)
    b = _consume(PooledDealer(plan, seed=seed), requests)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_pooled_mixed_shapes_same_kind():
    """Two shape-classes of the same kind keep separate streams/cursors."""
    requests = [PlanRequest("mul", (3, 3), "a"), PlanRequest("mul", (5,), "b"),
                PlanRequest("mul", (3, 3), "a"), PlanRequest("mul", (3, 3), "c")]
    plan = TriplePlan(requests)
    a = _consume(TrustedDealer(seed=9), requests)
    b = _consume(PooledDealer(plan, seed=9), requests)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# fit-level property (satellite): all four partition x sparsity combos
# ---------------------------------------------------------------------------

def _blobs(n, d, k, seed, sparse_frac=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-4, 4, (k, d))
    lab = rng.integers(0, k, n)
    x = centers[lab] + rng.normal(0, 0.3, (n, d))
    if sparse_frac:
        x = x * (rng.random((n, d)) >= sparse_frac)
    return x


@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
@pytest.mark.parametrize("sparse", [False, True])
def test_fit_pooled_bit_exact_vs_on_demand(partition, sparse):
    """Same seed -> identical share words, dealer counts, and offline
    CommLog tallies, whether triples are synthesized on demand inside the
    loop, planned + bulk-generated + pooled up front, or streamed per-
    iteration tranche. ALL four partition x sparsity combos take the
    compiled S1/S3 split-launch fast path in pooled/streamed mode (the
    sparse ones with Protocol 2 as a host callback between the launches),
    so this is the end-to-end parity guarantee of the split."""
    n, d, k = 48, 4, 2
    x = _blobs(n, d, k, seed=11, sparse_frac=0.5 if sparse else 0.0)
    if partition == "vertical":
        a, b = x[:, :2], x[:, 2:]
    else:
        a, b = x[:24], x[24:]
    res = {}
    for off in ("on_demand", "pooled", "streamed"):
        cfg = KMeansConfig(k=k, iters=2, partition=partition, sparse=sparse,
                           seed=5, backend="xla", offline=off)
        res[off] = SecureKMeans(cfg).fit(a, b)
    r0 = res["on_demand"]
    for r1 in (res["pooled"], res["streamed"]):
        for field in ("centroids", "assignment"):
            np.testing.assert_array_equal(
                np.asarray(getattr(r0, field).s0, np.uint64),
                np.asarray(getattr(r1, field).s0, np.uint64))
            np.testing.assert_array_equal(
                np.asarray(getattr(r0, field).s1, np.uint64),
                np.asarray(getattr(r1, field).s1, np.uint64))
        assert (r0.dealer.n_matmul, r0.dealer.n_mul, r0.dealer.n_bin) == \
               (r1.dealer.n_matmul, r1.dealer.n_mul, r1.dealer.n_bin)
        assert r0.log.by_tag("offline") == r1.log.by_tag("offline")
        assert r0.log.by_tag("online") == r1.log.by_tag("online")
    # the streaming dealer consumed every planned tranche exactly
    assert res["streamed"].dealer.served_iters == 2
    assert all(v == 0 for v in res["streamed"].dealer.remaining().values())


def test_fit_pooled_nondefault_f_falls_back_bit_exact():
    """The compiled fast path hardcodes f = ring.F; a custom precision must
    take the eager pooled loop and still replay bit-exact."""
    x = _blobs(40, 4, 2, seed=3)
    res = {}
    for off in ("on_demand", "pooled"):
        cfg = KMeansConfig(k=2, iters=2, seed=5, f=16, backend="xla",
                           offline=off)
        res[off] = SecureKMeans(cfg).fit(x[:, :2], x[:, 2:])
    np.testing.assert_array_equal(
        np.asarray(res["on_demand"].centroids.s0, np.uint64),
        np.asarray(res["pooled"].centroids.s0, np.uint64))
    np.testing.assert_allclose(res["pooled"].centroids_plain(f=16),
                               res["on_demand"].centroids_plain(f=16))


def test_fit_pooled_with_tol_leaves_surplus():
    """A tol early-stop only leaves pool surplus — never an error."""
    x = _blobs(200, 4, 3, seed=4)
    cfg = KMeansConfig(k=3, iters=50, seed=5, tol=1e-6, backend="xla",
                       offline="pooled")
    res = SecureKMeans(cfg).fit(x[:, :2], x[:, 2:])
    assert res.iters_run < 50
    assert all(v >= 0 for v in res.dealer.remaining().values())
    assert any(v > 0 for v in res.dealer.remaining().values())


# ---------------------------------------------------------------------------
# pool exhaustion / shape-mismatch semantics
# ---------------------------------------------------------------------------

def test_pool_exhaustion_raises():
    plan = TriplePlan([PlanRequest("mul", (2, 2), "t")])
    dealer = PooledDealer(plan, seed=1)
    dealer.mul_triple((2, 2))
    with pytest.raises(PoolExhaustedError, match="exhausted"):
        dealer.mul_triple((2, 2))


def test_pool_unplanned_class_raises():
    plan = TriplePlan([PlanRequest("mul", (2, 2), "t")])
    dealer = PooledDealer(plan, seed=1)
    with pytest.raises(PoolExhaustedError, match="never"):
        dealer.mul_triple((3, 3))
    with pytest.raises(PoolExhaustedError):
        dealer.bin_triple((2, 2))


def test_matmul_triple_shape_mismatch_raises_value_error():
    """Planner bugs must surface under `python -O` too (no bare asserts)."""
    for dealer in (TrustedDealer(seed=0), PlanningDealer(),
                   PooledDealer(TriplePlan([]), seed=0),
                   StreamingPooledDealer(TriplePlan([]), 1, seed=0)):
        with pytest.raises(ValueError, match=r"inner dims disagree.*\(2, 4\)"):
            dealer.matmul_triple((2, 4), (3, 5))


def test_mul_bin_triple_bad_shape_raises_value_error():
    """mul/bin triples take ONE flat tensor shape; a matmul-style nested
    pair, floats, or negative dims are planner bugs -> ValueError (matching
    the matmul inner-dim check)."""
    dealers = (TrustedDealer(seed=0), PlanningDealer(),
               PooledDealer(TriplePlan([]), seed=0),
               StreamingPooledDealer(TriplePlan([]), 1, seed=0))
    for dealer in dealers:
        with pytest.raises(ValueError, match="flat tuple of ints"):
            dealer.mul_triple(((2, 3), (3, 4)))     # nested matmul-style
        with pytest.raises(ValueError, match="flat tuple of ints"):
            dealer.bin_triple((2, 3.5))
        with pytest.raises(ValueError, match="negative"):
            dealer.mul_triple((2, -3))
        with pytest.raises(ValueError, match="iterable"):
            dealer.bin_triple(7)


# ---------------------------------------------------------------------------
# pjit path consumes the pool
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# StreamingPooledDealer — per-iteration tranches, O(1) residency
# ---------------------------------------------------------------------------

_SHAPES = {"matmul": ((5, 3), (3, 2)), "mul": (4, 3), "bin": (2, 7),
           "rand": (6,), "seed": ()}


@given(st.lists(st.sampled_from(["matmul", "mul", "bin", "rand", "seed"]),
                min_size=1, max_size=12),
       st.integers(1, 5), st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=15)
def test_streaming_replays_pooled_bit_exact(kinds, iters, seed):
    """StreamingPooledDealer ≡ PooledDealer(iter_plan.repeat(iters)): every
    served word identical, for random per-iteration schedules — the chunked
    per-class draws concatenate to the single stacked draw."""
    requests = [PlanRequest(k, _SHAPES[k], "t") for k in kinds]
    iter_plan = TriplePlan(requests)
    full = requests * iters
    a = _consume(PooledDealer(iter_plan.repeat(iters), seed=seed), full)
    stream = StreamingPooledDealer(iter_plan, iters, seed=seed)
    b = _consume(stream, full)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert stream.served_iters == iters
    assert all(v == 0 for v in stream.remaining().values())


@given(st.lists(st.sampled_from(["matmul", "mul", "bin", "rand", "seed"]),
                min_size=1, max_size=8),
       st.integers(1, 6), st.integers(1, 7), st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=15)
def test_streaming_grouped_tranches_equal_ungrouped(kinds, iters, group,
                                                    seed):
    """Tranche grouping (several iterations per generation wakeup) serves
    the SAME words as group=1 — the grouped stacked draw is the
    concatenation of the per-iteration draws. Any group size, including
    group > iters and a ragged tail group."""
    requests = [PlanRequest(k, _SHAPES[k], "t") for k in kinds]
    iter_plan = TriplePlan(requests)
    full = requests * iters
    a = _consume(StreamingPooledDealer(iter_plan, iters, seed=seed), full)
    grouped = StreamingPooledDealer(iter_plan, iters, seed=seed, group=group)
    b = _consume(grouped, full)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert grouped.served_iters == iters
    assert all(v == 0 for v in grouped.remaining().values())


def test_streaming_auto_group_sizes_to_tranche_bytes():
    """group="auto" groups tiny per-iteration tranches (amortizing worker
    wakeups) but never more than the fit has iterations; a big tranche
    stays ungrouped."""
    small = TriplePlan([PlanRequest("mul", (2, 2), "t")])
    d = StreamingPooledDealer(small, 5, seed=1, group="auto",
                              async_gen=False)
    assert d.group == 5                      # tiny tranche: one wakeup
    big = TriplePlan([PlanRequest("matmul", ((512, 256), (256, 64)), "t")])
    d2 = StreamingPooledDealer(big, 5, seed=1, group="auto",
                               async_gen=False)
    assert d2.group == 1                     # ~7 MB/iteration: no grouping
    d2.close()
    d.close()


def test_streaming_sync_mode_matches_async():
    """async_gen=False (generation inline at dispatch) serves the same
    words — the worker thread is an overlap optimization, not semantics."""
    requests = [PlanRequest("mul", (3, 3), "a"), PlanRequest("bin", (2,), "b")]
    plan = TriplePlan(requests)
    full = requests * 3
    a = _consume(StreamingPooledDealer(plan, 3, seed=4, async_gen=False), full)
    b = _consume(StreamingPooledDealer(plan, 3, seed=4, async_gen=True), full)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_streaming_peak_residency_independent_of_iters():
    """The headline streaming property: peak device residency is bounded by
    `prefetch` tranches, not by the fit length — while the bulk pool grows
    linearly with iters. async_gen=False makes the observed peak exact (with
    the worker it depends on generate/consume interleaving, so the async
    case asserts the structural prefetch bound instead of equality)."""
    requests = [PlanRequest("mul", (32, 8), "t"), PlanRequest("bin", (16,), "t")]
    plan = TriplePlan(requests)
    tranche_bytes = PooledDealer(plan, seed=1).pool_bytes
    peaks = {}
    for iters in (2, 8):
        s = StreamingPooledDealer(plan, iters, seed=1, async_gen=False)
        _consume(s, requests * iters)
        peaks[iters] = s.pool_bytes
    assert peaks[2] == peaks[8] == 2 * tranche_bytes
    assert peaks[8] < PooledDealer(plan.repeat(8), seed=1).pool_bytes
    s = StreamingPooledDealer(plan, 8, seed=1)          # async worker
    _consume(s, requests * 8)
    assert s.pool_bytes <= 2 * tranche_bytes


def test_streaming_exhaustion_and_unplanned_raise():
    plan = TriplePlan([PlanRequest("mul", (2, 2), "t")])
    dealer = StreamingPooledDealer(plan, 2, seed=1)
    dealer.mul_triple((2, 2))
    dealer.mul_triple((2, 2))
    with pytest.raises(PoolExhaustedError, match="exhausted"):
        dealer.mul_triple((2, 2))
    dealer2 = StreamingPooledDealer(plan, 1, seed=1)
    with pytest.raises(PoolExhaustedError, match="never"):
        dealer2.bin_triple((2, 2))


def test_streaming_early_stop_leaves_surplus_and_closes():
    """Stopping mid-schedule (the tol case) leaves counted surplus; undis-
    patched tranches are never generated. close() is idempotent."""
    requests = [PlanRequest("mul", (2, 2), "t"), PlanRequest("rand", (3,), "t")]
    plan = TriplePlan(requests)
    dealer = StreamingPooledDealer(plan, 10, seed=2)
    _consume(dealer, requests * 2)       # 2 of 10 iterations
    dealer.mul_triple((2, 2))            # half of iteration 3
    rem = dealer.remaining()
    assert rem[("mul", (2, 2))] == 7
    assert rem[("rand", (3,))] == 8
    dealer.close()
    dealer.close()


def test_fit_streamed_with_tol_leaves_surplus():
    """A tol early-stop under the streaming dealer only leaves surplus —
    never an error — and peak residency stays at the prefetch bound."""
    x = _blobs(200, 4, 3, seed=4)
    cfg = KMeansConfig(k=3, iters=50, seed=5, tol=1e-6, backend="xla",
                       offline="streamed")
    res = SecureKMeans(cfg).fit(x[:, :2], x[:, 2:])
    assert res.iters_run < 50
    assert all(v >= 0 for v in res.dealer.remaining().values())
    assert any(v > 0 for v in res.dealer.remaining().values())


# ---------------------------------------------------------------------------
# plan cache: a second identical-shape fit must skip the dry-run trace
# ---------------------------------------------------------------------------

def test_plan_cache_skips_second_trace(monkeypatch):
    import repro.core.kmeans as KM
    KM.clear_plan_cache()
    x = _blobs(40, 4, 2, seed=3)
    cfg = KMeansConfig(k=2, iters=2, seed=5, backend="xla", offline="pooled")
    r1 = SecureKMeans(cfg).fit(x[:, :2], x[:, 2:])
    assert len(KM._PLAN_CACHE) == 1

    def boom(self, sa, sb):
        raise AssertionError("second identical fit re-traced the plan")

    monkeypatch.setattr(SecureKMeans, "_trace_iteration", boom)
    r2 = SecureKMeans(cfg).fit(x[:, :2], x[:, 2:])
    np.testing.assert_array_equal(np.asarray(r1.centroids.s0, np.uint64),
                                  np.asarray(r2.centroids.s0, np.uint64))
    # a DIFFERENT config key must re-trace (and here: blow up)
    cfg3 = KMeansConfig(k=2, iters=2, seed=5, backend="xla",
                        offline="pooled", tol=1e-9)
    with pytest.raises(AssertionError, match="re-traced"):
        SecureKMeans(cfg3).fit(x[:, :2], x[:, 2:])


def test_pooled_offline_arrays_match_trusted_dealer():
    """The launch-path bulk offline arrays equal the on-demand flat list,
    tensor for tensor, across multiple iterations from one pool."""
    n, d, k, d_a = 16, 4, 2, 2
    requests = record_offline_shapes(n, d, k, d_a)
    iters = 2
    flats, dealer = pooled_offline_arrays(requests, seed=23, iters=iters)
    assert len(flats) == iters
    trusted = TrustedDealer(seed=23)
    for flat in flats:
        want = materialize_offline(requests, trusted)
        assert len(flat) == len(want)
        for got, ref in zip(flat, want):
            np.testing.assert_array_equal(np.asarray(got, np.uint64),
                                          np.asarray(ref, np.uint64))
    assert all(v == 0 for v in dealer.remaining().values())
