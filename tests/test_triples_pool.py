"""Offline planner/pool tests: the PooledDealer must be a bit-exact,
zero-host-work replacement for the on-demand TrustedDealer.

The load-bearing property: bulk per-class generation (one stacked RNG draw
+ one batched ring op per shape-class) serves the SAME uint64 words as the
on-demand dealer under the same seed — at the single-triple level, at the
pjit flat-tensor level, and through a full SecureKMeans.fit for all four
partition x sparsity combinations."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.triples import (PlanningDealer, PlanRequest, PooledDealer,
                                PoolExhaustedError, TriplePlan, TrustedDealer)
from repro.launch.kmeans_step import (materialize_offline,
                                      pooled_offline_arrays,
                                      record_offline_shapes)

RNG = np.random.default_rng(77)


def _consume(dealer, requests):
    """Serve a request schedule, returning every share word as numpy."""
    out = []
    for r in requests:
        if r.kind == "matmul":
            t = dealer.matmul_triple(*r.shape, tag=r.tag)
            out += [t.u.s0, t.u.s1, t.v.s0, t.v.s1, t.z.s0, t.z.s1]
        elif r.kind == "mul":
            t = dealer.mul_triple(r.shape, tag=r.tag)
            out += [t.u.s0, t.u.s1, t.v.s0, t.v.s1, t.z.s0, t.z.s1]
        elif r.kind == "bin":
            t = dealer.bin_triple(r.shape, tag=r.tag)
            out += [t.u.b0, t.u.b1, t.v.b0, t.v.b1, t.z.b0, t.z.b1]
        elif r.kind == "rand":
            out.append(dealer.rand(r.shape))
        else:
            out.append(np.uint64(dealer.mask_seed()))
    return [np.asarray(a, np.uint64) for a in out]


@given(st.lists(st.sampled_from(["matmul", "mul", "bin", "rand", "seed"]),
                min_size=1, max_size=24),
       st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=20)
def test_pooled_replays_trusted_dealer_bit_exact(kinds, seed):
    """Random interleaved schedules over a few shape-classes: every served
    word identical between on-demand and bulk generation."""
    shapes = {"matmul": ((5, 3), (3, 2)), "mul": (4, 3), "bin": (2, 7),
              "rand": (6,), "seed": ()}
    requests = [PlanRequest(k, shapes[k], "t") for k in kinds]
    plan = TriplePlan(requests)
    a = _consume(TrustedDealer(seed=seed), requests)
    b = _consume(PooledDealer(plan, seed=seed), requests)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_pooled_mixed_shapes_same_kind():
    """Two shape-classes of the same kind keep separate streams/cursors."""
    requests = [PlanRequest("mul", (3, 3), "a"), PlanRequest("mul", (5,), "b"),
                PlanRequest("mul", (3, 3), "a"), PlanRequest("mul", (3, 3), "c")]
    plan = TriplePlan(requests)
    a = _consume(TrustedDealer(seed=9), requests)
    b = _consume(PooledDealer(plan, seed=9), requests)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# fit-level property (satellite): all four partition x sparsity combos
# ---------------------------------------------------------------------------

def _blobs(n, d, k, seed, sparse_frac=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-4, 4, (k, d))
    lab = rng.integers(0, k, n)
    x = centers[lab] + rng.normal(0, 0.3, (n, d))
    if sparse_frac:
        x = x * (rng.random((n, d)) >= sparse_frac)
    return x


@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
@pytest.mark.parametrize("sparse", [False, True])
def test_fit_pooled_bit_exact_vs_on_demand(partition, sparse):
    """Same seed -> identical share words, dealer counts, and offline
    CommLog tallies, whether triples are synthesized on demand inside the
    loop or planned + bulk-generated + pooled up front. The dense-vertical
    combo additionally exercises the compiled single-launch fast path."""
    n, d, k = 48, 4, 2
    x = _blobs(n, d, k, seed=11, sparse_frac=0.5 if sparse else 0.0)
    if partition == "vertical":
        a, b = x[:, :2], x[:, 2:]
    else:
        a, b = x[:24], x[24:]
    res = {}
    for off in ("on_demand", "pooled"):
        cfg = KMeansConfig(k=k, iters=2, partition=partition, sparse=sparse,
                           seed=5, backend="xla", offline=off)
        res[off] = SecureKMeans(cfg).fit(a, b)
    r0, r1 = res["on_demand"], res["pooled"]
    for field in ("centroids", "assignment"):
        np.testing.assert_array_equal(
            np.asarray(getattr(r0, field).s0, np.uint64),
            np.asarray(getattr(r1, field).s0, np.uint64))
        np.testing.assert_array_equal(
            np.asarray(getattr(r0, field).s1, np.uint64),
            np.asarray(getattr(r1, field).s1, np.uint64))
    assert (r0.dealer.n_matmul, r0.dealer.n_mul, r0.dealer.n_bin) == \
           (r1.dealer.n_matmul, r1.dealer.n_mul, r1.dealer.n_bin)
    assert r0.log.by_tag("offline") == r1.log.by_tag("offline")
    assert r0.log.by_tag("online") == r1.log.by_tag("online")


def test_fit_pooled_nondefault_f_falls_back_bit_exact():
    """The compiled fast path hardcodes f = ring.F; a custom precision must
    take the eager pooled loop and still replay bit-exact."""
    x = _blobs(40, 4, 2, seed=3)
    res = {}
    for off in ("on_demand", "pooled"):
        cfg = KMeansConfig(k=2, iters=2, seed=5, f=16, backend="xla",
                           offline=off)
        res[off] = SecureKMeans(cfg).fit(x[:, :2], x[:, 2:])
    np.testing.assert_array_equal(
        np.asarray(res["on_demand"].centroids.s0, np.uint64),
        np.asarray(res["pooled"].centroids.s0, np.uint64))
    np.testing.assert_allclose(res["pooled"].centroids_plain(f=16),
                               res["on_demand"].centroids_plain(f=16))


def test_fit_pooled_with_tol_leaves_surplus():
    """A tol early-stop only leaves pool surplus — never an error."""
    x = _blobs(200, 4, 3, seed=4)
    cfg = KMeansConfig(k=3, iters=50, seed=5, tol=1e-6, backend="xla",
                       offline="pooled")
    res = SecureKMeans(cfg).fit(x[:, :2], x[:, 2:])
    assert res.iters_run < 50
    assert all(v >= 0 for v in res.dealer.remaining().values())
    assert any(v > 0 for v in res.dealer.remaining().values())


# ---------------------------------------------------------------------------
# pool exhaustion / shape-mismatch semantics
# ---------------------------------------------------------------------------

def test_pool_exhaustion_raises():
    plan = TriplePlan([PlanRequest("mul", (2, 2), "t")])
    dealer = PooledDealer(plan, seed=1)
    dealer.mul_triple((2, 2))
    with pytest.raises(PoolExhaustedError, match="exhausted"):
        dealer.mul_triple((2, 2))


def test_pool_unplanned_class_raises():
    plan = TriplePlan([PlanRequest("mul", (2, 2), "t")])
    dealer = PooledDealer(plan, seed=1)
    with pytest.raises(PoolExhaustedError, match="never"):
        dealer.mul_triple((3, 3))
    with pytest.raises(PoolExhaustedError):
        dealer.bin_triple((2, 2))


def test_matmul_triple_shape_mismatch_raises_value_error():
    """Planner bugs must surface under `python -O` too (no bare asserts)."""
    for dealer in (TrustedDealer(seed=0), PlanningDealer(),
                   PooledDealer(TriplePlan([]), seed=0)):
        with pytest.raises(ValueError, match=r"inner dims disagree.*\(2, 4\)"):
            dealer.matmul_triple((2, 4), (3, 5))


# ---------------------------------------------------------------------------
# pjit path consumes the pool
# ---------------------------------------------------------------------------

def test_pooled_offline_arrays_match_trusted_dealer():
    """The launch-path bulk offline arrays equal the on-demand flat list,
    tensor for tensor, across multiple iterations from one pool."""
    n, d, k, d_a = 16, 4, 2, 2
    requests = record_offline_shapes(n, d, k, d_a)
    iters = 2
    flats, dealer = pooled_offline_arrays(requests, seed=23, iters=iters)
    assert len(flats) == iters
    trusted = TrustedDealer(seed=23)
    for flat in flats:
        want = materialize_offline(requests, trusted)
        assert len(flat) == len(want)
        for got, ref in zip(flat, want):
            np.testing.assert_array_equal(np.asarray(got, np.uint64),
                                          np.asarray(ref, np.uint64))
    assert all(v == 0 for v in dealer.remaining().values())
