"""Backend-parity tests: every ring-compute implementation must be
BIT-EXACT in Z_{2^64} — the dispatch layer may change where the arithmetic
runs, never what it computes. Covers the three primitive ops across all
backend pairs (including wraparound-heavy inputs) and full SecureKMeans.fit
under xla vs pallas for all four partition x sparsity combinations."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol as P
from repro.core import ring
from repro.core.backend import (KS_LEVELS, NumpyBackend, PallasBackend,
                                XlaBackend, get_backend)
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.sharing import rec
from repro.core.sparse import CSRMatrix

RNG = np.random.default_rng(42)
BACKENDS = {"xla": XlaBackend(), "pallas": PallasBackend(interpret=True),
            "numpy": NumpyBackend()}
PAIRS = [("xla", "pallas"), ("xla", "numpy"), ("pallas", "numpy")]


def _wraparound_heavy(shape):
    """Values clustered at the top of the ring so partial products and
    accumulations overflow constantly — the regime where a sloppy
    implementation (float detour, signed overflow) diverges."""
    top = np.uint64(0xFFFFFFFFFFFFFFFF)
    x = RNG.integers(0, 1 << 20, shape, dtype=np.uint64)
    return top - x


# ---------------------------------------------------------------------------
# ring_mm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pair", PAIRS)
@pytest.mark.parametrize("shape", [(64, 32, 16), (100, 37, 9), (1, 5, 1),
                                   (129, 130, 3)])
def test_ring_mm_parity(pair, shape):
    n, d, k = shape
    a = RNG.integers(0, 1 << 64, (n, d), dtype=np.uint64)
    b = RNG.integers(0, 1 << 64, (d, k), dtype=np.uint64)
    b1, b2 = BACKENDS[pair[0]], BACKENDS[pair[1]]
    np.testing.assert_array_equal(np.asarray(b1.ring_mm(a, b), np.uint64),
                                  np.asarray(b2.ring_mm(a, b), np.uint64))


@pytest.mark.parametrize("pair", PAIRS)
def test_ring_mm_parity_wraparound_heavy(pair):
    a = _wraparound_heavy((40, 33))
    b = _wraparound_heavy((33, 7))
    b1, b2 = BACKENDS[pair[0]], BACKENDS[pair[1]]
    got1 = np.asarray(b1.ring_mm(a, b), np.uint64)
    got2 = np.asarray(b2.ring_mm(a, b), np.uint64)
    np.testing.assert_array_equal(got1, got2)
    # sanity vs an exact big-int oracle: every partial product here exceeds
    # 2^64, so a non-wrapping implementation could not land on this value
    i, j = 3, 2
    want = sum(int(a[i, t]) * int(b[t, j]) for t in range(a.shape[1]))
    assert want >= 1 << 64
    assert int(got1[i, j]) == want % (1 << 64)


# ---------------------------------------------------------------------------
# ring_spmm (blocked-ELL and CSR entry points)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pair", PAIRS)
@pytest.mark.parametrize("sparsity", [0.0, 0.7, 0.97])
def test_ring_spmm_parity(pair, sparsity):
    n, d, k = 52, 300, 5
    mask = RNG.random((n, d)) >= sparsity
    x = _wraparound_heavy((n, d)) * mask
    csr = CSRMatrix.from_dense(x.astype(np.uint64))
    y = _wraparound_heavy((d, k))
    b1, b2 = BACKENDS[pair[0]], BACKENDS[pair[1]]
    got1 = np.asarray(b1.ring_spmm_csr(csr, y), np.uint64)
    got2 = np.asarray(b2.ring_spmm_csr(csr, y), np.uint64)
    np.testing.assert_array_equal(got1, got2)
    want = np.einsum("ij,jk->ik", x.astype(np.uint64), y,
                     dtype=np.uint64, casting="unsafe")
    np.testing.assert_array_equal(got1, want)


@pytest.mark.parametrize("pair", PAIRS)
def test_ring_spmm_ell_op_parity(pair):
    """The blocked-ELL op itself (xla/numpy use it as the pallas kernel's
    oracle; ring_spmm_csr on host backends takes the chunked CSR path)."""
    from repro.kernels.spmm import csr_to_ell
    n, d, k = 24, 300, 4
    x = _wraparound_heavy((n, d)) * (RNG.random((n, d)) >= 0.8)
    csr = CSRMatrix.from_dense(x.astype(np.uint64))
    blocks, idx, counts = csr_to_ell(csr.indptr, csr.indices, csr.data,
                                     csr.shape)
    y = np.pad(_wraparound_heavy((d, k)), ((0, (-d) % 128), (0, 0)))
    b1, b2 = BACKENDS[pair[0]], BACKENDS[pair[1]]
    got1 = np.asarray(b1.ring_spmm(blocks, idx, counts, y), np.uint64)[:n]
    got2 = np.asarray(b2.ring_spmm(blocks, idx, counts, y), np.uint64)[:n]
    np.testing.assert_array_equal(got1, got2)
    want = np.einsum("ij,jk->ik", x.astype(np.uint64), y[:d],
                     dtype=np.uint64, casting="unsafe")
    np.testing.assert_array_equal(got1, want)


def test_ring_spmm_empty_matrix():
    csr = CSRMatrix.from_dense(np.zeros((10, 40), np.uint64))
    y = RNG.integers(0, 1 << 64, (40, 3), dtype=np.uint64)
    for bk in BACKENDS.values():
        got = np.asarray(bk.ring_spmm_csr(csr, y), np.uint64)
        np.testing.assert_array_equal(got, np.zeros((10, 3), np.uint64))


# ---------------------------------------------------------------------------
# ks_fused
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pair", PAIRS)
@pytest.mark.parametrize("shape", [(16, 8), (3,), (1, 1), ()])
def test_ks_fused_parity(pair, shape):
    def draw(s):
        return jnp.asarray(RNG.integers(0, 1 << 64, s, dtype=np.uint64))

    flat = [draw(shape) for _ in range(6)]
    lvls = [draw((len(KS_LEVELS), 2) + shape) for _ in range(5)]
    b1, b2 = BACKENDS[pair[0]], BACKENDS[pair[1]]
    for party0 in (True, False):
        got1 = np.asarray(b1.ks_fused(*flat, *lvls, party0=party0), np.uint64)
        got2 = np.asarray(b2.ks_fused(*flat, *lvls, party0=party0), np.uint64)
        np.testing.assert_array_equal(got1, got2)


# ---------------------------------------------------------------------------
# backend resolution
# ---------------------------------------------------------------------------

def test_get_backend_resolution():
    assert get_backend("xla").name == "xla"
    assert get_backend("pallas").name == "pallas"
    assert get_backend("numpy").name == "numpy"
    assert get_backend(None).name in ("xla", "pallas")   # auto
    assert get_backend("auto").name in ("xla", "pallas")
    inst = XlaBackend()
    assert get_backend(inst) is inst
    with pytest.raises(ValueError):
        get_backend("cuda")


def test_ctx_carries_backend():
    ctx = P.make_ctx(0, backend="pallas")
    assert ctx.backend.name == "pallas"
    assert P.make_ctx(0).backend.name in ("xla", "pallas")


# ---------------------------------------------------------------------------
# end-to-end: SecureKMeans.fit bit-exact across backends
# ---------------------------------------------------------------------------

def _blobs(n, d, k, seed, sparse_frac=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-4, 4, (k, d))
    lab = rng.integers(0, k, n)
    x = centers[lab] + rng.normal(0, 0.3, (n, d))
    if sparse_frac:
        x = x * (rng.random((n, d)) >= sparse_frac)
    return x


@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
@pytest.mark.parametrize("sparse", [False, True])
def test_fit_bit_exact_xla_vs_pallas(partition, sparse):
    """The whole secure pipeline — distances, tournament argmin, centroid
    update — must produce IDENTICAL shares under either compute backend:
    same seed means same dealer randomness, and the local ring algebra is
    exact, so even the final uint64 share words must agree bit for bit."""
    n, d, k = 48, 4, 2
    x = _blobs(n, d, k, seed=11, sparse_frac=0.5 if sparse else 0.0)
    if partition == "vertical":
        a, b = x[:, :2], x[:, 2:]
    else:
        a, b = x[:24], x[24:]
    results = {}
    for backend in ("xla", "pallas"):
        cfg = KMeansConfig(k=k, iters=2, partition=partition, sparse=sparse,
                           seed=5, backend=backend)
        results[backend] = SecureKMeans(cfg).fit(a, b)
    rx, rp = results["xla"], results["pallas"]
    np.testing.assert_array_equal(np.asarray(rec(rx.centroids), np.uint64),
                                  np.asarray(rec(rp.centroids), np.uint64))
    np.testing.assert_array_equal(np.asarray(rec(rx.assignment), np.uint64),
                                  np.asarray(rec(rp.assignment), np.uint64))
    np.testing.assert_array_equal(rx.labels_plain(), rp.labels_plain())
    # traffic accounting must be backend-independent
    assert rx.log.total_bytes("online") == rp.log.total_bytes("online")
    assert rx.log.total_rounds("online") == rp.log.total_rounds("online")


# ---------------------------------------------------------------------------
# KMeansConfig validation (regression: iters=0 used to crash fit with an
# UnboundLocalError deep in the loop)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("iters", [0, -3])
def test_config_rejects_nonpositive_iters(iters):
    with pytest.raises(ValueError, match="iters"):
        KMeansConfig(k=3, iters=iters)
