"""Minibatch Lloyd + pipelined executor tests (DESIGN.md §11).

Load-bearing properties:
* minibatch fit at batch_size >= n is BIT-EXACT vs the existing full-batch
  pooled fast path for all four partition x sparsity combos (same share
  words, same dealer counters, same CommLog tallies);
* pipeline=True is stream-identical to pipeline=False (the executor only
  reorders host work into the device window — the SlotDealer pins every
  slot's randomness at generation time, in canonical order);
* batch geometries are reused — a many-batch fit compiles at most a
  handful of program pairs (full shape + remainder), never one per batch;
* SlotDealer serves the words PooledDealer would, for ANY acquisition
  order within the window, streamed or pregenerated, grouped or not.
"""
import numpy as np
import pytest

from repro.core.kmeans import (KMeansConfig, SecureKMeans,
                               _assemble_assignment, _minibatch_bounds)
from repro.core.triples import (PlanRequest, PooledDealer,
                                PoolExhaustedError, SlotDealer, TriplePlan)
from repro.launch import kmeans_step as K
from repro.launch.pipeline import StageTask, run_pipeline


def _blobs(n, d, k, seed, sparse_frac=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-4, 4, (k, d))
    lab = rng.integers(0, k, n)
    x = centers[lab] + rng.normal(0, 0.3, (n, d))
    if sparse_frac:
        x = x * (rng.random((n, d)) >= sparse_frac)
    return x


def _split(x, partition):
    n, d = x.shape
    if partition == "vertical":
        return x[:, :d // 2], x[:, d // 2:]
    return x[:n // 2], x[n // 2:]


def _assert_same_fit(r0, r1):
    for field in ("centroids", "assignment"):
        for s in ("s0", "s1"):
            np.testing.assert_array_equal(
                np.asarray(getattr(getattr(r0, field), s), np.uint64),
                np.asarray(getattr(getattr(r1, field), s), np.uint64))
    assert (r0.dealer.n_matmul, r0.dealer.n_mul, r0.dealer.n_bin) == \
           (r1.dealer.n_matmul, r1.dealer.n_mul, r1.dealer.n_bin)
    assert r0.log.by_tag("online") == r1.log.by_tag("online")


# ---------------------------------------------------------------------------
# minibatch fit vs the full-batch fast path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
@pytest.mark.parametrize("sparse", [False, True])
def test_minibatch_full_batch_bit_exact(partition, sparse):
    """batch_size = n (one batch covering the fit) must replay the existing
    full-batch pooled path word for word: same shares, dealer counters, and
    online/offline CommLog tallies — the accumulator algebra composes to
    exactly the single-launch S3."""
    n, d, k = 48, 4, 2
    x = _blobs(n, d, k, seed=11, sparse_frac=0.5 if sparse else 0.0)
    a, b = _split(x, partition)
    base = dict(k=k, iters=2, partition=partition, sparse=sparse, seed=5,
                backend="xla")
    r_full = SecureKMeans(KMeansConfig(**base, offline="pooled")).fit(a, b)
    r_mb = SecureKMeans(KMeansConfig(**base, offline="pooled",
                                     batch_size=n)).fit(a, b)
    _assert_same_fit(r_full, r_mb)
    assert r_full.log.by_tag("offline") == r_mb.log.by_tag("offline")


@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
@pytest.mark.parametrize("sparse", [False, True])
def test_minibatch_pipeline_on_off_stream_identical(partition, sparse):
    """pipeline=True == pipeline=False, multi-batch, with a remainder
    batch, across pooled and streamed dealers: identical shares, dealer
    counters, CommLog tallies — the overlap cannot change a single word."""
    n, d, k = 48, 4, 2
    x = _blobs(n, d, k, seed=9, sparse_frac=0.5 if sparse else 0.0)
    a, b = _split(x, partition)
    base = dict(k=k, iters=2, partition=partition, sparse=sparse, seed=5,
                backend="xla", batch_size=17)        # 17 -> ragged batches
    res = {}
    for pipe in (True, False):
        for off in ("pooled", "streamed"):
            cfg = KMeansConfig(**base, offline=off, pipeline=pipe)
            res[(pipe, off)] = SecureKMeans(cfg).fit(a, b)
    ref = res[(False, "pooled")]
    for key, r in res.items():
        _assert_same_fit(ref, r)
    # and the minibatch split agrees with the full-batch fit on the data
    # itself (well-separated blobs: truncation LSB noise flips nothing)
    full = SecureKMeans(KMeansConfig(k=k, iters=2, partition=partition,
                                     sparse=sparse, seed=5, backend="xla",
                                     offline="pooled")).fit(a, b)
    assert ref.labels_plain().tolist() == full.labels_plain().tolist()
    np.testing.assert_allclose(ref.centroids_plain(),
                               full.centroids_plain(), atol=1e-3)


def test_minibatch_remainder_geometry_reuse():
    """A many-batch fit compiles ONE program pair per distinct batch
    geometry (full + remainder) plus one finalize — never per batch."""
    K.clear_program_cache()
    n = 80
    x = _blobs(n, 4, 2, seed=3)
    cfg = KMeansConfig(k=2, iters=2, seed=5, backend="xla",
                       offline="pooled", batch_size=16)  # 5 equal batches
    SecureKMeans(cfg).fit(x[:, :2], x[:, 2:])
    assert len(K._BATCH_PROGRAM_CACHE) == 1
    assert len(K._FINALIZE_CACHE) == 1
    cfg2 = KMeansConfig(k=2, iters=2, seed=5, backend="xla",
                        offline="pooled", batch_size=32)  # 32,32,16
    SecureKMeans(cfg2).fit(x[:, :2], x[:, 2:])
    # the 16-row remainder reuses the FIRST fit's 16-row program: only the
    # 32-row geometry is new
    assert len(K._BATCH_PROGRAM_CACHE) == 2
    assert len(K._FINALIZE_CACHE) == 1        # finalize keyed by (k, d, n)


def test_minibatch_requires_planned_offline():
    x = _blobs(24, 4, 2, seed=1)
    with pytest.raises(ValueError, match="pooled"):
        SecureKMeans(KMeansConfig(k=2, iters=1, batch_size=8)) \
            .fit(x[:, :2], x[:, 2:])
    with pytest.raises(ValueError, match="fast path"):
        SecureKMeans(KMeansConfig(k=2, iters=1, batch_size=8,
                                  offline="pooled", backend="numpy")) \
            .fit(x[:, :2], x[:, 2:])
    with pytest.raises(ValueError, match="batch_size"):
        KMeansConfig(k=2, batch_size=0)


def test_minibatch_tol_early_stop_closes_cleanly():
    """A tol early-stop mid-schedule leaves SlotDealer surplus, never an
    error — undispatched slots are dropped by close()."""
    x = _blobs(120, 4, 3, seed=4)
    cfg = KMeansConfig(k=3, iters=40, seed=5, tol=1e-6, backend="xla",
                       offline="streamed", batch_size=48)
    res = SecureKMeans(cfg).fit(x[:, :2], x[:, 2:])
    assert res.iters_run < 40
    assert any(v > 0 for v in res.dealer.remaining().values())
    res.dealer.close()                      # idempotent


# ---------------------------------------------------------------------------
# _minibatch_bounds / assignment reassembly
# ---------------------------------------------------------------------------

def test_minibatch_bounds_vertical():
    assert _minibatch_bounds("vertical", 10, 10, 4) == \
        [((0, 4), (0, 4)), ((4, 8), (4, 8)), ((8, 10), (8, 10))]
    assert _minibatch_bounds("vertical", 10, 10, 100) == [((0, 10), (0, 10))]


def test_minibatch_bounds_horizontal_alignment():
    """Both parties get the same NUMBER of contiguous chunks, sizes within
    one of each other, covering all rows — even for uneven row counts."""
    for na, nb, bs in [(9, 7, 4), (10, 10, 4), (5, 29, 8), (3, 3, 100)]:
        bounds = _minibatch_bounds("horizontal", na, nb, bs)
        a_spans = [b[0] for b in bounds]
        b_spans = [b[1] for b in bounds]
        assert a_spans[0][0] == 0 and a_spans[-1][1] == na
        assert b_spans[0][0] == 0 and b_spans[-1][1] == nb
        for spans in (a_spans, b_spans):
            for (l0, h0), (l1, _h1) in zip(spans, spans[1:]):
                assert h0 == l1
            sizes = [h - l for l, h in spans]
            assert max(sizes) - min(sizes) <= 1
            assert min(sizes) >= 1


# ---------------------------------------------------------------------------
# SlotDealer: the acquisition-order-independence contract
# ---------------------------------------------------------------------------

_SHAPES = {"matmul": ((5, 3), (3, 2)), "mul": (4, 3), "bin": (2, 7),
           "rand": (6,), "seed": ()}


def _slot_plans(seed, n_slots=6, per_slot=3):
    rng = np.random.default_rng(seed)
    kinds = list(_SHAPES)
    return [TriplePlan([PlanRequest(k, _SHAPES[k], "t")
                        for k in rng.choice(kinds, per_slot)])
            for _ in range(n_slots)]


def _serve_slot(view, plan):
    out = []
    for r in plan.requests:
        if r.kind == "matmul":
            t = view.matmul_triple(*r.shape)
            out += [t.u.s0, t.u.s1, t.v.s0, t.v.s1, t.z.s0, t.z.s1]
        elif r.kind == "mul":
            t = view.mul_triple(r.shape)
            out += [t.u.s0, t.u.s1, t.v.s0, t.v.s1, t.z.s0, t.z.s1]
        elif r.kind == "bin":
            t = view.bin_triple(r.shape)
            out += [t.u.b0, t.u.b1, t.v.b0, t.v.b1, t.z.b0, t.z.b1]
        elif r.kind == "rand":
            out.append(view.rand(r.shape))
        else:
            out.append(np.uint64(view.mask_seed()))
    return [np.asarray(a, np.uint64) for a in out]


@pytest.mark.parametrize("stream", [False, True])
@pytest.mark.parametrize("group_bytes", [0, "auto"])
def test_slot_dealer_matches_pooled_any_order(stream, group_bytes):
    """Acquiring slots out of order (the pipelined lead) serves the same
    words as PooledDealer over the concatenated plan — streamed or
    pregenerated, grouped or per-slot generation."""
    plans = _slot_plans(seed=8)
    concat = TriplePlan([r for p in plans for r in p.requests])
    pooled = PooledDealer(concat, seed=13)
    want = {}
    cursor = []
    for i, p in enumerate(plans):
        want[i] = _serve_slot(pooled, p)
        cursor.append(p)
    order = [0, 2, 1, 4, 3, 5]          # the executor's S1-ahead pattern
    dealer = SlotDealer(plans, seed=13, stream=stream, async_gen=False,
                        group_bytes=group_bytes)
    for i in order:
        got = _serve_slot(dealer.acquire(i), plans[i])
        assert len(got) == len(want[i])
        for x, y in zip(got, want[i]):
            np.testing.assert_array_equal(x, y)
    dealer.close()


def test_slot_dealer_async_worker_matches_sync():
    plans = _slot_plans(seed=21, n_slots=8)
    serve = {}
    for async_gen in (False, True):
        dealer = SlotDealer(plans, seed=4, stream=True, async_gen=async_gen,
                            window=4)
        serve[async_gen] = [w for i in range(len(plans))
                            for w in _serve_slot(dealer.acquire(i),
                                                 plans[i])]
        dealer.close()
    for x, y in zip(serve[False], serve[True]):
        np.testing.assert_array_equal(x, y)


def test_slot_dealer_forward_acquire_past_window_no_deadlock():
    """acquire(i) far beyond the backpressure window must generate through
    to slot i (a waiting caller overrides the window) — and the words stay
    canonical."""
    plans = [TriplePlan([PlanRequest("mul", (8, 8), "t")])
             for _ in range(10)]
    dealer = SlotDealer(plans, seed=2, stream=True, window=2, group_bytes=0)
    got = dealer.acquire(7).mul_triple((8, 8))
    concat = TriplePlan([r for p in plans for r in p.requests])
    pooled = PooledDealer(concat, seed=2)
    for _ in range(8):                   # the 8th draw is slot 7's word
        want = pooled.mul_triple((8, 8))
    np.testing.assert_array_equal(np.asarray(got.u.s0, np.uint64),
                                  np.asarray(want.u.s0, np.uint64))
    dealer.close()


def test_slot_dealer_exhaustion_and_reacquire():
    plans = [TriplePlan([PlanRequest("mul", (2, 2), "t")])] * 2
    dealer = SlotDealer(plans, seed=1, stream=False)
    v = dealer.acquire(0)
    v.mul_triple((2, 2))
    with pytest.raises(PoolExhaustedError, match="exhausted"):
        v.mul_triple((2, 2))
    with pytest.raises(PoolExhaustedError, match="never"):
        dealer.acquire(1).bin_triple((2, 2))
    with pytest.raises(PoolExhaustedError, match="already"):
        dealer.acquire(0)
    with pytest.raises(IndexError):
        dealer.acquire(7)


# ---------------------------------------------------------------------------
# the executor itself
# ---------------------------------------------------------------------------

def test_run_pipeline_phase_order_and_results():
    """Pipelined execution returns the same results as sequential; the only
    reordering is pre(t+1) sliding before mid/post(t)."""
    for pipeline in (False, True):
        trace = []

        def mk(t):
            return StageTask(
                pre=lambda t=t: trace.append(("pre", t)) or t * 10,
                launch=lambda p, t=t: trace.append(("launch", t)) or p + 1,
                mid=lambda p, o, t=t: trace.append(("mid", t)) or o + p,
                post=lambda p, o, m, t=t: trace.append(("post", t)) or m)

        out = run_pipeline([mk(t) for t in range(3)], pipeline=pipeline)
        assert out == [1, 21, 41]
        # every phase ran exactly once per task, launch after its pre
        for t in range(3):
            assert trace.index(("pre", t)) < trace.index(("launch", t)) \
                < trace.index(("mid", t)) < trace.index(("post", t))
        if pipeline:
            assert trace.index(("pre", 1)) < trace.index(("mid", 0))
        else:
            assert trace.index(("pre", 1)) > trace.index(("post", 0))


def test_assemble_assignment_horizontal_order():
    """Horizontal reassembly restores [all A rows; all B rows] from per-
    batch [A chunk; B chunk] outputs."""
    import jax.numpy as jnp

    from repro.core.sharing import AShare
    parts = [AShare(jnp.asarray(np.array([[1], [2], [10]], np.uint64)),
                    jnp.asarray(np.array([[0], [0], [0]], np.uint64))),
             AShare(jnp.asarray(np.array([[3], [11]], np.uint64)),
                    jnp.asarray(np.array([[0], [0]], np.uint64)))]
    batches = [{"a_rows": 2}, {"a_rows": 1}]
    c = _assemble_assignment("horizontal", parts, batches)
    np.testing.assert_array_equal(np.asarray(c.s0, np.uint64).ravel(),
                                  [1, 2, 3, 10, 11])
