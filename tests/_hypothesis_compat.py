"""Drop-in subset of `hypothesis` for offline environments.

The container has no network access, so `hypothesis` may not be installed.
When it is, we re-export the real thing; when it isn't, `given` degrades to
a deterministic fixed-example sweep: each strategy draws from a PRNG seeded
by the test name, so runs are reproducible and the property tests still
exercise a spread of inputs (just without shrinking or adaptive search).
"""
from __future__ import annotations

try:  # pragma: no cover - depends on environment
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    import zlib

    _DEFAULT_EXAMPLES = 10
    _MAX_EXAMPLES_CAP = 25  # keep the fallback sweep CI-sized

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=1 << 16):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, allow_nan=False,
                   allow_infinity=False):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(size)]
            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

    strategies = _Strategies()

    def settings(deadline=None, max_examples=_DEFAULT_EXAMPLES, **kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strats, **kw_strats):
        def deco(fn):
            n = min(getattr(fn, "_compat_max_examples", _DEFAULT_EXAMPLES),
                    _MAX_EXAMPLES_CAP)

            # NOT functools.wraps: pytest must see a zero-arg signature, or
            # it would treat the property arguments as missing fixtures
            def wrapper():
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = tuple(s.example(rng) for s in strats)
                    named = {k: s.example(rng) for k, s in kw_strats.items()}
                    fn(*drawn, **named)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
