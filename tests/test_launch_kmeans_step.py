"""Parity tests for the pjit-able online Lloyd iteration in
launch/kmeans_step: one jit'd iteration — offline tensors materialized by a
TrustedDealer and fed through the ListDealer, Protocol-2 HE results entering
as share inputs — must agree with the simulated SecureKMeans iteration built
from the class's own _distances / argmin / _update methods."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol as P
from repro.core import ring
from repro.core.he import SimulatedPHE
from repro.core.kmeans import KMeansConfig, SecureKMeans, _encode_np
from repro.core.sharing import AShare, rec, rec_real, share
from repro.core.sparse import CSRMatrix, secure_sparse_matmul
from repro.core.triples import TrustedDealer
from repro.launch.kmeans_step import (materialize_offline,
                                      online_iteration_fn,
                                      record_offline_shapes)

_materialize_offline = materialize_offline  # promoted into launch/kmeans_step


@pytest.mark.parametrize("sparse", [False, True])
def test_online_iteration_matches_secure_kmeans(sparse):
    n, d, k, d_a = 32, 4, 2, 2
    rng = np.random.default_rng(8)
    centers = rng.uniform(-4, 4, (k, d))
    x = centers[rng.integers(0, k, n)] + rng.normal(0, 0.2, (n, d))
    if sparse:
        x = x * (rng.random((n, d)) >= 0.4)
    x_a, x_b = x[:, :d_a], x[:, d_a:]
    enc_a, enc_b = _encode_np(x_a, ring.F), _encode_np(x_b, ring.F)
    csr_a = CSRMatrix.from_dense(enc_a) if sparse else None
    csr_b = CSRMatrix.from_dense(enc_b) if sparse else None
    mu0 = share(_encode_np(x[rng.choice(n, k, replace=False)], ring.F), rng)

    # ---- reference: one iteration through SecureKMeans's own methods -----
    skm = SecureKMeans(KMeansConfig(k=k, iters=1, sparse=sparse, seed=0))
    ctx = P.make_ctx(17)
    dist = skm._distances(ctx, enc_a, enc_b, csr_a, csr_b, mu0)
    c_ref = P.argmin_onehot(ctx, dist)
    mu_ref = skm._update(ctx, enc_a, enc_b, csr_a, csr_b, c_ref, mu0, n)

    # ---- pjit path: offline tensors in, one jit'd iteration --------------
    fn, _args = online_iteration_fn(n, d, k, d_a, sparse=sparse)
    dealer = TrustedDealer(seed=23)
    flat = _materialize_offline(
        record_offline_shapes(n, d, k, d_a, sparse=sparse), dealer)
    he_flat = []
    if sparse:
        # Protocol-2 joint products (core/kmeans orientation conventions).
        # j1/j2 only need mu0, known upfront. ja/jb need the ASSIGNMENT
        # SHARES the iteration itself produces in S2 — in deployment the
        # HE exchange runs mid-iteration on those shares — so capture them
        # with a first eager pass (zero ja/jb cannot influence S1/S2), then
        # feed the matching products to the jit'd run.
        ctx_he = P.make_ctx(99)
        he = SimulatedPHE()
        mut = AShare(mu0.s0.T, mu0.s1.T)
        j1 = secure_sparse_matmul(ctx_he, csr_a, np.asarray(mut.s1[:d_a]), he)
        z2 = secure_sparse_matmul(ctx_he, csr_b, np.asarray(mut.s0[d_a:]), he)
        j2 = AShare(z2.s1, z2.s0)
        zero_nk = jnp.zeros((k, d_a), ring.DTYPE)
        zero_nk2 = jnp.zeros((k, d - d_a), ring.DTYPE)
        probe = [j1.s0, j1.s1, j2.s0, j2.s1,
                 zero_nk, zero_nk, zero_nk2, zero_nk2]
        captured = {}
        orig_argmin = P.argmin_onehot

        def argmin_spy(ctx_, dist_):
            captured["c"] = c = orig_argmin(ctx_, dist_)
            return c

        P.argmin_onehot = argmin_spy
        try:
            fn(jnp.asarray(enc_a), jnp.asarray(enc_b), mu0.s0, mu0.s1,
               *probe, *flat)
        finally:
            P.argmin_onehot = orig_argmin
        ct = AShare(captured["c"].s0.T, captured["c"].s1.T)
        za = secure_sparse_matmul(ctx_he, CSRMatrix.from_dense(enc_a.T),
                                  np.asarray(ct.s1.T), he)
        ja = AShare(za.s0.T, za.s1.T)
        zb = secure_sparse_matmul(ctx_he, CSRMatrix.from_dense(enc_b.T),
                                  np.asarray(ct.s0.T), he)
        jb = AShare(zb.s1.T, zb.s0.T)
        for h in (j1, j2, ja, jb):
            he_flat += [h.s0, h.s1]
    out0, out1 = jax.jit(fn)(jnp.asarray(enc_a), jnp.asarray(enc_b),
                             mu0.s0, mu0.s1, *he_flat, *flat)
    mu_jit = AShare(out0, out1)

    # Same values flow through both paths; only the share/mask randomness
    # differs, so reconstructions agree up to truncation ulps.
    got = np.asarray(rec_real(mu_jit))
    want = np.asarray(rec_real(mu_ref))
    np.testing.assert_allclose(got, want, atol=1e-2)
    assert np.isfinite(got).all()
    # the reference iteration must itself be sane: one-hot rows summing to 1
    oh = np.asarray(rec(c_ref), np.uint64).astype(np.int64)
    assert (oh.sum(1) == 1).all()


@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
@pytest.mark.parametrize("sparse", [False, True])
def test_fit_programs_two_launches_per_iteration(partition, sparse):
    """The pooled fast path runs EVERY partition x sparsity combo as exactly
    two compiled launches per online iteration (S1: distances+argmin, S3:
    update), with the sparse combos' Protocol-2 exchange as a host callback
    between them — no eager fallback, no two-pass trick."""
    import repro.launch.kmeans_step as K
    from repro.core.kmeans import KMeansConfig, SecureKMeans

    n, d, k, iters = 32, 4, 2, 3
    rng = np.random.default_rng(6)
    x = rng.normal(0, 2, (n, d))
    if sparse:
        x = x * (rng.random((n, d)) >= 0.5)
    if partition == "vertical":
        a, b = x[:, :2], x[:, 2:]
    else:
        a, b = x[:16], x[16:]
    cfg = KMeansConfig(k=k, iters=iters, partition=partition, sparse=sparse,
                       seed=5, backend="xla", offline="pooled")
    skm = SecureKMeans(cfg)
    enc_a, enc_b = _encode_np(np.asarray(a), ring.F), _encode_np(np.asarray(b), ring.F)
    progs = K.fit_programs(partition, sparse, enc_a.shape, enc_b.shape, k,
                           backend="xla")
    # same geometry+backend -> the fit must reuse this cached pair; wrap the
    # compiled callables with counters to count actual launches
    calls = {"s1": 0, "s3": 0}

    def wrap(name, fn):
        def counted(*args):
            calls[name] += 1
            return fn(*args)
        return counted

    key = (progs.geo, "xla")
    K._PROGRAM_CACHE[key] = progs._replace(s1=wrap("s1", progs.s1),
                                           s3=wrap("s3", progs.s3))
    try:
        res = skm.fit(a, b)
    finally:
        K._PROGRAM_CACHE[key] = progs
    assert calls == {"s1": iters, "s3": iters}
    assert res.iters_run == iters
    # S1 outputs valid one-hot assignment shares (the S2 callback contract:
    # the host exchange runs on exactly these)
    oh = np.asarray(rec(res.assignment), np.uint64).astype(np.int64)
    assert (oh.sum(1) == 1).all()
    # the sparse programs declare the Protocol-2 inputs; dense ones don't
    assert bool(progs.geo.he_shapes_s1()) == sparse
    assert bool(progs.geo.he_shapes_s3()) == sparse


def test_fit_geometry_validation():
    from repro.launch.kmeans_step import FitGeometry
    with pytest.raises(ValueError, match="unknown partition"):
        FitGeometry("diagonal", False, (4, 2), (4, 2), 2)
    with pytest.raises(ValueError, match="equal sample counts"):
        FitGeometry("vertical", False, (4, 2), (5, 2), 2)
    with pytest.raises(ValueError, match="equal feature counts"):
        FitGeometry("horizontal", False, (4, 2), (4, 3), 2)


def test_online_iteration_backend_parity():
    """The pjit'd iteration must be bit-exact across ring backends when fed
    the IDENTICAL offline tensors and inputs."""
    n, d, k, d_a = 16, 4, 2, 2
    rng = np.random.default_rng(3)
    x = rng.normal(0, 2, (n, d))
    enc_a, enc_b = _encode_np(x[:, :d_a], ring.F), _encode_np(x[:, d_a:], ring.F)
    mu0 = share(_encode_np(x[:k], ring.F), rng)
    flat = _materialize_offline(record_offline_shapes(n, d, k, d_a),
                                TrustedDealer(seed=5))
    outs = {}
    for backend in ("xla", "pallas"):
        fn, _ = online_iteration_fn(n, d, k, d_a, backend=backend)
        s0, s1 = jax.jit(fn)(jnp.asarray(enc_a), jnp.asarray(enc_b),
                             mu0.s0, mu0.s1, *flat)
        outs[backend] = (np.asarray(s0, np.uint64), np.asarray(s1, np.uint64))
    np.testing.assert_array_equal(outs["xla"][0], outs["pallas"][0])
    np.testing.assert_array_equal(outs["xla"][1], outs["pallas"][1])
