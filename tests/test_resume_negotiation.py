"""Resume-negotiation handshake tests (DESIGN.md §16).

Load-bearing properties:
* `PeerProgress` is a durable, monotone, crash-safe marker: atomic
  publish, never moves backwards, unreadable files degrade to scratch;
* `handle_resume` implements the negotiation contract — hello answers
  the recorded (step, fingerprint) and binds the fingerprint on first
  contact, publish advances the marker, fingerprint disagreement is a
  TYPED error (not a step answer);
* over a real loopback wire, `negotiate_resume` agrees on min(step) and
  `ResumeMismatch` propagates to the engine;
* a restarted engine's incarnation announce resets the responder's
  dedup window, so its fresh seq-0 space is served instead of
  stale-dropped — and same-incarnation duplicates still replay;
* two-process regression: party B dying MID-HANDSHAKE (killed at its
  first served frame — the hello) leaves the surviving engine parked
  and resumable; a respawned B completes the run.
"""
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.core.channel import (LoopbackTransport, PeerProgress,
                                ReliableChannel, ResumeMismatch, WireSession,
                                handle_resume, serve_peer)

# ---------------------------------------------------------------------------
# PeerProgress durability
# ---------------------------------------------------------------------------


def test_peer_progress_inmemory_monotone():
    p = PeerProgress()
    assert p.step == -1 and p.fingerprint is None
    p.update(3, "fp1")
    assert (p.step, p.fingerprint) == (3, "fp1")
    p.update(1, "fp1")                       # never backwards
    assert p.step == 3
    p.update(5, None)                        # step advances, fp sticks
    assert (p.step, p.fingerprint) == (5, "fp1")


def test_peer_progress_durable_roundtrip(tmp_path):
    path = str(tmp_path / "peer_progress.json")
    p = PeerProgress(path)
    p.update(7, "fpX")
    q = PeerProgress(path)                   # a restarted B
    assert (q.step, q.fingerprint) == (7, "fpX")
    assert not os.path.exists(path + ".tmp")


def test_peer_progress_unreadable_marker_degrades_to_scratch(tmp_path):
    path = str(tmp_path / "peer_progress.json")
    with open(path, "w") as f:
        f.write("{torn")
    p = PeerProgress(path)
    assert p.step == -1 and p.fingerprint is None


# ---------------------------------------------------------------------------
# handle_resume contract
# ---------------------------------------------------------------------------


def test_hello_reports_step_and_binds_fingerprint():
    p = PeerProgress()
    out = handle_resume({"op": "hello", "inc": "i0", "step": -1,
                         "fp": "fpA"}, p)
    assert out == {"step": -1, "fp": "fpA"}
    assert p.fingerprint == "fpA"            # bound on first contact


def test_publish_advances_then_hello_answers_it():
    p = PeerProgress()
    assert handle_resume({"op": "publish", "step": 2_000_000,
                          "fp": "fpA"}, p) == {"ok": 1}
    out = handle_resume({"op": "hello", "step": 1_000_000, "fp": "fpA"}, p)
    assert out["step"] == 2_000_000


def test_fingerprint_mismatch_is_typed_error_not_a_step():
    p = PeerProgress()
    p.update(4, "fpA")
    out = handle_resume({"op": "hello", "step": 9, "fp": "fpB"}, p)
    assert out["error"] == "fingerprint-mismatch"
    assert out["ours"] == "fpA" and out["theirs"] == "fpB"
    # the marker did NOT move — a rejected hello has no side effects
    assert (p.step, p.fingerprint) == (4, "fpA")


# ---------------------------------------------------------------------------
# over the wire: loopback engine <-> serve_peer
# ---------------------------------------------------------------------------


def _served_pair(progress):
    ta, tb = LoopbackTransport.pair()
    out = {}

    def run():
        try:
            out["responder"] = serve_peer(tb, idle_timeout_s=30.0,
                                          progress=progress)
        except Exception as e:               # surfaced by the test join
            out["error"] = e

    th = threading.Thread(target=run, daemon=True)
    th.start()
    return ta, th, out


def test_negotiate_resume_agrees_on_min_step():
    prog = PeerProgress()
    prog.update(3_000_000, "fpA")
    ta, th, out = _served_pair(prog)
    ws = WireSession(ReliableChannel(ta, deadline_s=10.0),
                     incarnation="inc-1")
    # engine holds a NEWER published step than B witnessed: rewind to B's
    agreed = ws.negotiate_resume(step=5_000_000, fingerprint="fpA")
    assert agreed == 3_000_000
    # B ahead of the engine (die-before-local-load): engine's step wins
    prog.update(9_000_000, "fpA")
    assert ws.negotiate_resume(step=4_000_000,
                               fingerprint="fpA") == 4_000_000
    ws.notify_publish(6_000_000, "fpA")
    assert prog.step == 9_000_000            # publish never rewinds B
    ws.bye()
    th.join(timeout=10.0)
    assert "error" not in out


def test_mismatch_raises_resume_mismatch_over_wire():
    prog = PeerProgress()
    prog.update(2, "fpA")
    ta, th, out = _served_pair(prog)
    ws = WireSession(ReliableChannel(ta, deadline_s=10.0),
                     incarnation="inc-1")
    with pytest.raises(ResumeMismatch):
        ws.negotiate_resume(step=2, fingerprint="fpB")
    ws.bye()
    th.join(timeout=10.0)


def test_incarnation_announce_resets_dedup_window():
    """A restarted engine restarts its sequence space at 0; without the
    incarnation reset the responder would stale-drop every request. The
    announce must land first and clear the window."""
    prog = PeerProgress()
    ta, th, out = _served_pair(prog)
    ws1 = WireSession(ReliableChannel(ta, deadline_s=10.0),
                      incarnation="inc-1")
    ws1.negotiate_resume(step=-1, fingerprint="fpA")    # seq 0
    ws1.notify_publish(1_000_000, "fpA")                # seq 1
    ws1.exchange(64, 1)                                 # seq 2
    # "crash": a fresh channel on the same transport, fresh seq space
    ws2 = WireSession(ReliableChannel(ta, deadline_s=10.0,
                                      try_timeout_s=0.2, max_retries=3),
                      incarnation="inc-2")
    agreed = ws2.negotiate_resume(step=1_000_000, fingerprint="fpA")
    assert agreed == 1_000_000
    ws2.exchange(64, 1)                                 # fresh seq space OK
    ws2.bye()
    th.join(timeout=10.0)
    r = out["responder"]
    assert r.incarnation_resets == 1
    assert r.stale_drops == 0


def test_same_incarnation_duplicate_hello_replays_from_cache():
    prog = PeerProgress()
    ta, th, out = _served_pair(prog)
    chan = ReliableChannel(ta, deadline_s=10.0)
    ws = WireSession(chan, incarnation="inc-1")
    ws.negotiate_resume(step=-1, fingerprint=None)
    # resend the LAST frame verbatim (same seq, same incarnation):
    # dedup must replay the cached response, not reset the window
    from repro.core.channel import T_RESUME, encode_frame
    body = json.dumps({"op": "hello", "inc": "inc-1", "step": -1,
                       "fp": None}, sort_keys=True).encode()
    ta.send_frame(encode_frame(T_RESUME, chan._seq - 1, body))
    ta.recv_frame(5.0)                       # the replayed response
    ws.bye()
    th.join(timeout=10.0)
    r = out["responder"]
    assert r.dedup_replays == 1 and r.incarnation_resets == 0


# ---------------------------------------------------------------------------
# two-process regression: B dies during the handshake itself
# ---------------------------------------------------------------------------


def _env():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def _spawn(role, port, extra, env):
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.two_party", "--role", role,
         "--port", str(port)] + list(extra),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)


def test_peer_death_mid_handshake_leaves_survivor_resumable(tmp_path):
    """B is killed at its FIRST served frame — A's incarnation hello, the
    resume handshake itself. A (with a park budget) must survive B's
    crash window; a respawned B (durable state dir) completes the fit."""
    env = _env()
    ck = str(tmp_path / "ck")
    state = str(tmp_path / "bstate")
    out_npz = str(tmp_path / "a.npz")
    a = _spawn("A", 0, ["--out", out_npz, "--checkpoint-dir", ck,
                        "--auto-resume", "--peer-wait", "60",
                        "--io-timeout", "60", "--iters", "2"], env)
    line = a.stdout.readline()
    assert line.startswith("LISTENING "), line
    port = int(line.split()[1])
    b_extra = ["--state-dir", state, "--peer-wait", "60",
               "--io-timeout", "60"]
    b1 = _spawn("B", port, b_extra + ["--die-at", "wire.serve:1"], env)
    b1_out = b1.communicate(timeout=120)[0]
    assert b1.returncode == 17, b1_out
    assert "DYING point=wire.serve" in b1_out
    # A is parked mid-handshake; the respawned B answers the resend
    b2 = _spawn("B", port, b_extra, env)
    a_out = a.communicate(timeout=300)[0]
    b2_out = b2.communicate(timeout=60)[0]
    assert a.returncode == 0, a_out
    assert b2.returncode == 0, b2_out
    assert "A: negotiated resume step -1" in a_out
    assert os.path.exists(out_npz)
