"""Offline cold-start subsystem tests (fit-plan bank, batched HE exchange,
parallel provisioning).

Load-bearing properties: (1) a `TripleBank` provisioned under a
`plan_fit` key serves `fit(dealer=...)` bit-exactly vs the pooled and
on-demand dealers — shares, dealer counters, AND online traffic — on all
four partition x sparsity combos, for full-batch and minibatch fits, and
survives an np.savez round-trip; (2) parallel provisioning (any worker
count, any chunk completion order) is word-for-word identical to serial
provisioning, including the master streams' final positions — the
per-class PCG64 `advance` contract; (3) the column-batched HE joint-product
exchange is share-for-share identical to the legacy per-ciphertext loop on
a real Paillier key, and its measured operation counts match the closed
form `he2ss_op_counts` that prices the simulated backend."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import protocol as P
from repro.core import ring
from repro.core.he import KAPPA_STAT, OU_COST_S, Paillier, SimulatedPHE
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.sparse import (CSRMatrix, default_value_bits,
                               he2ss_layout, he2ss_op_counts,
                               secure_sparse_matmul)
from repro.core.triples import (PlanningDealer, TripleBank, _class_rng,
                                _class_words, _gen_class,
                                _gen_provision_item, _provision_items)

COMBOS = [("vertical", False), ("vertical", True),
          ("horizontal", False), ("horizontal", True)]


def _fit_data(partition, seed=0, sparse=False):
    rng = np.random.default_rng(seed)
    def blob(n, d):
        x = rng.uniform(-2, 2, (n, d))
        if sparse:
            x *= rng.random((n, d)) > 0.6
        return x
    if partition == "vertical":
        return blob(48, 5), blob(48, 4)
    return blob(30, 6), blob(18, 6)


def _shares(r):
    return (np.asarray(r.centroids.s0), np.asarray(r.centroids.s1),
            np.asarray(r.assignment.s0), np.asarray(r.assignment.s1))


def _counters(r):
    return (r.dealer.n_matmul, r.dealer.n_mul, r.dealer.n_bin)


# ---------------------------------------------------------------------------
# (1) fit-plan bank: provision once, fit bit-exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partition,sparse", COMBOS)
def test_fit_bank_full_batch_bit_exact(partition, sparse):
    xa, xb = _fit_data(partition, seed=1, sparse=sparse)
    kw = dict(k=3, iters=2, seed=3, partition=partition, sparse=sparse)
    r_od = SecureKMeans(KMeansConfig(offline="on_demand", **kw)).fit(xa, xb)
    r_pool = SecureKMeans(KMeansConfig(offline="pooled", **kw)).fit(xa, xb)

    km = SecureKMeans(KMeansConfig(offline="pooled", **kw))
    key, plan, comm = km.plan_fit(xa.shape, xb.shape)
    bank = TripleBank(seed=3)
    bank.provision(key, plan, workers=2)
    r_bank = km.fit(xa, xb, dealer=bank.dealer(key))

    for ref in (r_od, r_pool):
        for a, b in zip(_shares(ref), _shares(r_bank)):
            np.testing.assert_array_equal(a, b)
    assert _counters(r_bank) == _counters(r_pool)
    assert r_bank.log.total_bytes("online") == r_pool.log.total_bytes("online")
    assert r_bank.log.total_rounds("online") \
        == r_pool.log.total_rounds("online")
    # the whole fit plan was consumed — zero leftover generation work
    assert bank.served_requests == len(plan)


@pytest.mark.parametrize("sparse", [False, True])
def test_fit_bank_minibatch_and_disk_roundtrip(sparse):
    """Minibatch fit from a provisioned bank == SlotDealer fit, the bank
    survives save/load at the SAME stream position (bit-exact fit), and a
    second fit from copy 2 agrees live vs reloaded and reconstructs the
    same centroids (different shares by design — later stream words)."""
    xa, xb = _fit_data("vertical", seed=2, sparse=sparse)
    kw = dict(k=3, iters=2, seed=3, sparse=sparse, batch_size=20,
              offline="pooled", pipeline=True)
    r_slot = SecureKMeans(KMeansConfig(**kw)).fit(xa, xb)
    s_slot = _shares(r_slot)

    km = SecureKMeans(KMeansConfig(**kw))
    key, plan, _ = km.plan_fit(xa.shape, xb.shape)
    bank = TripleBank(seed=3)
    bank.provision(key, plan, copies=2, workers=3)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "bank.npz")
        bank.save(path)          # snapshot BEFORE any serving
        r_bank = km.fit(xa, xb, dealer=bank.dealer(key))
        bank2 = TripleBank.load(path)
        r_re = SecureKMeans(KMeansConfig(**kw)).fit(
            xa, xb, dealer=bank2.dealer(key))
        # copy 2: live bank and reloaded bank have both served one fit and
        # must agree on the next one (stream-continuity through the disk)
        r2_live = SecureKMeans(KMeansConfig(**kw)).fit(
            xa, xb, dealer=bank.dealer(key))
        r2_re = SecureKMeans(KMeansConfig(**kw)).fit(
            xa, xb, dealer=bank2.dealer(key))
    for a, b in zip(s_slot, _shares(r_bank)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(s_slot, _shares(r_re)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(_shares(r2_live), _shares(r2_re)):
        np.testing.assert_array_equal(a, b)
    # copy-2 shares differ (later words) but reconstruct the same centroids
    # up to truncation-LSB noise
    c1 = s_slot[0] + s_slot[1]
    c2 = _shares(r2_live)[0] + _shares(r2_live)[1]
    assert np.abs((c1 - c2).astype(np.int64)).max() <= 2
    assert _counters(r_bank) == _counters(r_slot)
    assert r_bank.log.total_bytes("online") == r_slot.log.total_bytes("online")


def test_fit_bank_rejects_non_bank_dealer_for_minibatch():
    xa, xb = _fit_data("vertical", seed=4)
    km = SecureKMeans(KMeansConfig(k=3, iters=1, seed=0, batch_size=20,
                                   offline="pooled"))
    with pytest.raises(ValueError, match="TripleBank dealer"):
        km.fit(xa, xb, dealer=PlanningDealer())


# ---------------------------------------------------------------------------
# (2) parallel provisioning == serial provisioning
# ---------------------------------------------------------------------------

def _provision_plan(km, xa, xb):
    key, plan, _ = km.plan_fit(xa.shape, xb.shape)
    return key, plan


def _queue_words(bank):
    return {k: [tuple(np.asarray(a) for a in e) for e in q]
            for k, q in bank._queues.items()}


def _rng_states(bank):
    return {k: repr(r.bit_generator.state) for k, r in bank._rngs.items()}


@pytest.mark.parametrize("workers", [2, 3, 8])
def test_parallel_provisioning_bit_exact(workers):
    xa, xb = _fit_data("vertical", seed=5, sparse=True)
    km = SecureKMeans(KMeansConfig(k=3, iters=2, seed=7, sparse=True,
                                   offline="pooled"))
    key, plan = _provision_plan(km, xa, xb)
    serial = TripleBank(seed=11)
    serial.provision(key, plan, copies=2)
    par = TripleBank(seed=11)
    par.provision(key, plan, copies=2, workers=workers)
    qs, qp = _queue_words(serial), _queue_words(par)
    assert qs.keys() == qp.keys()
    for ck in qs:
        assert len(qs[ck]) == len(qp[ck])
        for es, ep in zip(qs[ck], qp[ck]):
            for a, b in zip(es, ep):
                np.testing.assert_array_equal(a, b)
    # master streams end at the same position -> future replenishment and
    # incremental provisioning stay identical too
    assert _rng_states(serial) == _rng_states(par)


def test_parallel_provisioning_completion_order_oblivious():
    """Chunks generated in REVERSE order assemble to the same words —
    each chunk derives its stream position from (class origin, offset)
    alone, so scheduling cannot matter."""
    xa, xb = _fit_data("vertical", seed=6)
    km = SecureKMeans(KMeansConfig(k=3, iters=1, seed=13, offline="pooled"))
    key, plan = _provision_plan(km, xa, xb)
    counts = plan.class_counts()
    states = {ck: _class_rng(13, ck).bit_generator.state for ck in counts}
    items = _provision_items(counts, workers=4)
    fwd = [_gen_provision_item(states, it) for it in items]
    rev = [_gen_provision_item(states, it) for it in reversed(items)][::-1]
    for (ef, _), (er, _) in zip(fwd, rev):
        for tf, tr in zip(ef, er):
            for a, b in zip(tf, tr):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the split covers every request exactly once, in order, per class
    covered = {}
    for ck, start, cnt in items:
        assert start == covered.get(ck, 0)
        covered[ck] = start + cnt
    assert covered == {ck: int(c) for ck, c in counts.items() if c > 0}


@pytest.mark.parametrize("key", [
    ("matmul", (7, 3), (3, 2)), ("mul", (5, 4)), ("bin", (2, 6)),
    ("rand", (8,)), ("seed", ())])
def test_class_words_matches_draw_width(key):
    """`advance(count * _class_words)` must land exactly where `count`
    generated requests leave the stream — the whole basis of chunked
    parallel generation."""
    a = _class_rng(3, key)
    b = _class_rng(3, key)
    kind = key[0]
    shape = key[1:] if kind == "matmul" else key[1]
    _gen_class(a, kind, shape, 5)
    b.bit_generator.advance(5 * _class_words(key))
    assert a.bit_generator.state["state"] == b.bit_generator.state["state"]


# ---------------------------------------------------------------------------
# (3) batched HE exchange == legacy loop (real Paillier) + op accounting
# ---------------------------------------------------------------------------

def _matmul_inputs(seed, n=5, d=7, k=3, density=0.5):
    rng = np.random.default_rng(seed)
    xr = rng.uniform(-2, 2, (n, d)) * (rng.random((n, d)) > 1 - density)
    x = CSRMatrix.from_dense_real(xr)
    yb = rng.integers(0, 1 << 63, (d, k)).astype(np.uint64)
    return x, yb


def test_batched_he_exchange_matches_legacy_paillier():
    """Same dealer seed => same masks => the column-batched path must be
    share-for-share identical to the per-ciphertext loop, not just equal
    after reconstruction."""
    x, yb = _matmul_inputs(21)
    he = Paillier(512)
    zb = secure_sparse_matmul(P.make_ctx(5), x, yb, he, batched=True)
    zl = secure_sparse_matmul(P.make_ctx(5), x, yb, he, batched=False)
    np.testing.assert_array_equal(np.asarray(zb.s0), np.asarray(zl.s0))
    np.testing.assert_array_equal(np.asarray(zb.s1), np.asarray(zl.s1))
    want = np.asarray(x.to_dense(), np.uint64) @ yb
    np.testing.assert_array_equal(
        np.asarray(zb.s0) + np.asarray(zb.s1), want)


def test_batched_he_exchange_empty_rows_and_empty_matrix():
    he = Paillier(512)
    # rows with no nonzeros still get correct (zero-product) shares
    xr = np.zeros((4, 3))
    xr[1, 2] = 1.5
    x = CSRMatrix.from_dense_real(xr)
    yb = np.arange(1, 13, dtype=np.uint64).reshape(3, 4)
    z = secure_sparse_matmul(P.make_ctx(1), x, yb, he)
    want = np.asarray(x.to_dense(), np.uint64) @ yb
    np.testing.assert_array_equal(np.asarray(z.s0) + np.asarray(z.s1), want)
    # fully-empty matrix: no ciphertexts at all, still well-formed shares
    empty = CSRMatrix.from_dense_real(np.zeros((3, 2)))
    z0 = secure_sparse_matmul(P.make_ctx(2), empty, yb[:2], he)
    np.testing.assert_array_equal(
        np.asarray(z0.s0) + np.asarray(z0.s1), np.zeros((3, 4), np.uint64))


def test_measured_op_counts_match_closed_form():
    """The counters the real path measures are exactly the closed form the
    simulated backend prices — so `he_s` comparisons across backends mean
    the same thing."""
    x, yb = _matmul_inputs(22, n=6, d=5, k=4, density=0.4)
    he = Paillier(512)
    secure_sparse_matmul(P.make_ctx(9), x, yb, he)
    got = dict(secure_sparse_matmul.last_op_counts)
    n, d = x.shape
    lay = he2ss_layout(yb.shape[1], he.plain_bits, default_value_bits(d))
    nrows_ne = sum(1 for i in range(n) if x.indptr[i + 1] > x.indptr[i])
    want = he2ss_op_counts(n, d, x.nnz, nrows_ne, lay)
    assert got == want


def test_batched_op_counts_beat_legacy():
    """>= 3x fewer modelled HE seconds than the per-ciphertext loop on the
    paper's sparse geometry (the offline cold-start claim)."""
    n, d, k, density = 256, 64, 8, 0.05
    rng = np.random.default_rng(23)
    nnz = int(n * d * density)
    nrows_ne = n
    he = SimulatedPHE()
    lay = he2ss_layout(k, he.plain_bits, default_value_bits(d))
    ops = he2ss_op_counts(n, d, nnz, nrows_ne, lay)
    batched_s = sum(ops[o] * OU_COST_S[o] for o in OU_COST_S)
    # legacy loop: d*k encrypts forward, nnz*k pmuls, (nnz-rows)*k adds,
    # n*k mask encrypts (the `ct + int` re-randomization) + n*k adds and
    # decrypts on the return leg
    legacy_s = ((d * k + n * k) * OU_COST_S["enc"]
                + nnz * k * OU_COST_S["pmul"]
                + ((nnz - nrows_ne) * k + n * k) * OU_COST_S["add"]
                + n * k * OU_COST_S["dec"])
    assert legacy_s / batched_s >= 3.0


def test_sim_fast_path_prices_packed_ops_and_accumulates_he_seconds():
    x, yb = _matmul_inputs(24)
    he = SimulatedPHE()
    ctx = P.make_ctx(3)
    assert ctx.he_seconds == 0.0
    secure_sparse_matmul(P.make_ctx(3), x, yb, he)
    packed = dict(secure_sparse_matmul.last_op_counts)
    ctx2 = P.make_ctx(3)
    secure_sparse_matmul(ctx2, x, yb, he, time_model=OU_COST_S)
    want_s = sum(packed[o] * OU_COST_S[o] for o in OU_COST_S)
    assert ctx2.he_seconds == pytest.approx(want_s)
    # Ctx aggregation helper
    ctx2.add_he_seconds(1.0)
    assert ctx2.he_seconds == pytest.approx(want_s + 1.0)


def test_he2ss_layout_slot_capacity():
    """Packing must stay sound: per-slot payloads fit slot_bits with the
    statistical mask, and a full wire ciphertext stays inside plain_bits."""
    for d in (2, 64, 4096):
        for k in (2, 8, 100):
            lay = he2ss_layout(k, SimulatedPHE().plain_bits,
                               default_value_bits(d))
            assert lay.slot_bits >= lay.value_bits + KAPPA_STAT + 2
            assert lay.g * lay.rpc * lay.slot_bits <= SimulatedPHE().plain_bits
            assert lay.g >= 1 and lay.rpc >= 1
            assert lay.ngrp == -(-k // lay.g)


# ---------------------------------------------------------------------------
# bank file integrity: refuse damaged or foreign archives
# ---------------------------------------------------------------------------

def _tiny_saved_bank(td):
    km = SecureKMeans(KMeansConfig(k=2, iters=1, seed=3))
    key, plan, _ = km.plan_fit((12, 2), (12, 2))
    bank = TripleBank(seed=3)
    bank.provision(key, plan)
    path = os.path.join(td, "bank.npz")
    bank.save(path)
    return path


def test_bank_load_rejects_bit_flip():
    with tempfile.TemporaryDirectory() as td:
        path = _tiny_saved_bank(td)
        raw = bytearray(open(path, "rb").read())
        # flip one bit inside the zip's data region (past local headers);
        # either an array CRC32 or the zip's own CRC must catch it
        raw[len(raw) // 2] ^= 0x10
        open(path, "wb").write(bytes(raw))
        with pytest.raises(ValueError, match="TripleBank"):
            TripleBank.load(path)


def test_bank_load_rejects_truncation():
    with tempfile.TemporaryDirectory() as td:
        path = _tiny_saved_bank(td)
        raw = open(path, "rb").read()
        open(path, "wb").write(raw[:len(raw) // 2])
        with pytest.raises(ValueError, match="TripleBank"):
            TripleBank.load(path)


def test_bank_load_rejects_foreign_npz():
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "foreign.npz")
        np.savez(path, x=np.arange(8))
        with pytest.raises(ValueError, match="manifest"):
            TripleBank.load(path)


def test_bank_load_rejects_wrong_version():
    import json
    import zlib as _zlib
    with tempfile.TemporaryDirectory() as td:
        path = _tiny_saved_bank(td)
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
        manifest = json.loads(bytes(arrays.pop("manifest")).decode())
        manifest["version"] = 99
        with open(path, "wb") as f:
            np.savez(f, manifest=np.frombuffer(
                json.dumps(manifest).encode(), np.uint8), **arrays)
        with pytest.raises(ValueError, match="version"):
            TripleBank.load(path)


def test_bank_load_rejects_garbage_file():
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "junk.npz")
        open(path, "wb").write(b"this is not an npz archive at all")
        with pytest.raises(ValueError, match="TripleBank"):
            TripleBank.load(path)
