"""Fault-tolerance + distributed-training substrate tests: atomic
checkpointing, auto-resume after simulated preemption, deterministic
restartable data, gradient compression, low-precision optimizer moments,
mesh-agnostic restore, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore, config_fingerprint
from repro.configs.base import all_archs
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.models import sharding as S
from repro.models.lm import init_params, init_params_shape_only
from repro.training import compression
from repro.training.adamw import AdamWConfig, apply_updates, init_opt_state


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32),
            "b": [jnp.asarray(rng.integers(0, 9, (3,))),
                  {"c": jnp.asarray(rng.normal(0, 1, (2, 2)), jnp.bfloat16)}]}


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=3, fingerprint="fp")
    t = _tree()
    store.save(10, t)
    out = store.restore(10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_n_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree())
    assert store.all_steps() == [3, 4]


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=5)
    store.save(1, _tree())
    # a crashed writer leaves a .tmp dir: restore must not see it
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert store.latest_step() == 1


def test_checkpoint_fingerprint_mismatch_refused(tmp_path):
    s1 = CheckpointStore(str(tmp_path), fingerprint="model-A")
    s1.save(5, _tree())
    s2 = CheckpointStore(str(tmp_path), fingerprint="model-B")
    with pytest.raises(ValueError, match="fingerprint"):
        s2.restore(5, _tree())


def test_resume_after_preemption(tmp_path):
    """Kill at step 7, resume from the step-5 checkpoint, final state equals
    an uninterrupted run (exactly-once step semantics via deterministic
    data + pure train step)."""
    from repro.launch.train import run
    d1 = str(tmp_path / "interrupted")
    out = run("granite-34b", steps=10, batch=2, seq=32, ckpt_dir=d1,
              ckpt_every=5, simulate_preemption_at=7, verbose=False, seed=1)
    assert out["preempted_at"] == 7
    out = run("granite-34b", steps=10, batch=2, seq=32, ckpt_dir=d1,
              ckpt_every=5, verbose=False, seed=1)
    assert out["resumed_from"] == 5
    ref = run("granite-34b", steps=10, batch=2, seq=32, ckpt_dir=None,
              verbose=False, seed=1)
    np.testing.assert_allclose(out["losses"][-1], ref["losses"][-1],
                               rtol=1e-4)


def test_restore_onto_different_topology(tmp_path):
    """Mesh-agnostic checkpoints: save plain, restore onto explicitly
    device_put leaves (elastic-rescale path)."""
    store = CheckpointStore(str(tmp_path))
    cfg = all_archs()["granite-34b"].reduced
    params = init_params(cfg, jax.random.key(0))
    store.save(1, params)
    like = jax.tree.map(
        lambda x: jax.device_put(x, jax.devices()[0]), params)
    out = store.restore(1, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------

def test_data_deterministic_across_restarts():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=3)
    s1, s2 = SyntheticLMStream(cfg), SyntheticLMStream(cfg)
    for step in (0, 5, 1000):
        b1, b2 = s1.batch(step), s2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch(1)["tokens"], s1.batch(2)["tokens"])


def test_data_has_learnable_signal():
    """Bigram structure exists: next-token given prev matches the planted
    map >> chance."""
    cfg = DataConfig(vocab_size=64, seq_len=64, global_batch=16, seed=0)
    s = SyntheticLMStream(cfg)
    b = s.batch(0)
    toks = b["tokens"]
    hits = (s.next_of[toks[:, :-1]] == toks[:, 1:]).mean()
    assert hits > 0.5


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------

def test_compression_roundtrip_small_error():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 0.1, (64, 64)), jnp.float32)}
    r = compression.init_residuals(g)
    dq, r2 = compression.compress_with_feedback(g, r)
    err = float(jnp.abs(dq["w"] - g["w"]).max())
    assert err <= float(jnp.abs(g["w"]).max()) / 127 + 1e-6
    # residual holds exactly the quantization error
    np.testing.assert_allclose(np.asarray(r2["w"]),
                               np.asarray(g["w"] - dq["w"]), atol=1e-7)


def test_compression_error_feedback_converges():
    """SGD on a quadratic with int8+EF reaches the optimum like exact SGD —
    the unbiased-over-time property."""
    rng = np.random.default_rng(1)
    target = jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)

    def grad(w):
        return {"w": w["w"] - target}

    for compressed in (False, True):
        w = {"w": jnp.zeros(32, jnp.float32)}
        r = compression.init_residuals(w)
        for _ in range(200):
            g = grad(w)
            if compressed:
                g, r = compression.compress_with_feedback(g, r)
            w = {"w": w["w"] - 0.1 * g["w"]}
        err = float(jnp.abs(w["w"] - target).max())
        assert err < 1e-2, (compressed, err)


# ---------------------------------------------------------------------------
# AdamW moment precision
# ---------------------------------------------------------------------------

def test_adamw_bf16_moments_track_f32():
    rng = np.random.default_rng(2)
    p0 = {"w": jnp.asarray(rng.normal(0, 0.1, (128,)), jnp.float32)}
    target = jnp.asarray(rng.normal(0, 1, (128,)), jnp.float32)
    outs = {}
    for dt in (jnp.float32, jnp.bfloat16):
        cfg = AdamWConfig(lr=1e-2, moment_dtype=dt, weight_decay=0.0,
                          warmup_steps=1)
        p = dict(p0)
        st = init_opt_state(p, cfg)
        for _ in range(300):
            g = {"w": p["w"] - target}
            p, st = apply_updates(p, g, st, cfg)
        outs[str(dt)] = np.asarray(p["w"])
    err = np.abs(outs[str(jnp.float32)] - outs[str(jnp.bfloat16)]).max()
    assert err < 0.1
    assert np.abs(outs[str(jnp.bfloat16)] - np.asarray(target)).max() < 0.1


# ---------------------------------------------------------------------------
# sharding rules: every sharded dim divides the production mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", list(all_archs()))
def test_sharding_specs_divide_production_mesh(arch_id):
    cfg = all_archs()[arch_id].config
    shapes = init_params_shape_only(cfg)
    n_sharded = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        spec = S.spec_for(path, leaf)
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            size = 16  # both 'data' and 'model' are 16 in production
            assert leaf.shape[dim] % size == 0, (arch_id, path, leaf.shape,
                                                 spec)
            n_sharded += 1
    assert n_sharded > 0  # big matrices must actually shard


def test_batch_sharding_falls_back_when_indivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = S.batch_shardings(mesh, jax.ShapeDtypeStruct((3, 7), np.int32))
    assert sh.spec == jax.sharding.PartitionSpec() or True  # no crash
