"""Serving-plane robustness tests (DESIGN.md §14).

Load-bearing properties: (1) admission control sheds past the high-water
mark with a typed `QueueFull` response and deadlines answer
`DeadlineExceeded` at dequeue instead of occupying a rung; (2) the
`BankReplenisher` daemon keeps responses bit-exact with the synchronous
replenish path (per-class stream-prefix invariance) while actually
topping shelves up off the hot path; (3) a daemon top-up racing a
stock-out draw can never fork a per-class stream (the PR-8 lock bugfix);
(4) a killed-and-restarted service answers every request exactly once,
bit-exact — journaled responses replay verbatim, in-flight requests
re-draw the SAME bank words after consumed-count realignment; (5) the
wire frontend survives drop/dup/corrupt/kill with authenticated frames,
rid-pinned retries riding the journal dedup."""
import os
import re
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.checkpoint.serve import ServeCheckpointer
from repro.core.channel import (FaultyTransport, FrameDecoder,
                                LoopbackTransport, SocketTransport, T_SCORE,
                                encode_frame, session_key)
from repro.core.fraud import FraudDataset
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.triples import TripleBank
from repro.serve import (ERR_DEADLINE, ERR_QUEUE_FULL, BatchLadder,
                         ScoringClient, ScoringResponse, ScoringServer,
                         ScoringService, ServiceStats)

D_A = D_B = 4
K = 3


@pytest.fixture(scope="module")
def fitted():
    ds = FraudDataset.synthesize(n=200, d_a=D_A, d_b=D_B, n_clusters=K,
                                 seed=0)
    km = SecureKMeans(KMeansConfig(k=K, iters=2, seed=0, offline="pooled"))
    res = km.fit(ds.x_a, ds.x_b)
    return km, res


def _batches(n, rows=8, seed=3):
    arr = FraudDataset.synthesize(n=rows * n, d_a=D_A, d_b=D_B,
                                  n_clusters=K, seed=seed)
    return [(arr.x_a[i * rows:(i + 1) * rows],
             arr.x_b[i * rows:(i + 1) * rows]) for i in range(n)]


def _service(km, res, **kw):
    kw.setdefault("rungs", (16,))
    kw.setdefault("provision_copies", 4)
    return ScoringService(km, res, d_a=D_A, d_b=D_B, with_scores=True, **kw)


def _one_at_a_time(svc, batches):
    """Submit/drain each batch alone — the wire server's effective
    schedule (one outstanding request per sequential channel)."""
    out = {}
    for xa, xb in batches:
        svc.submit(xa, xb)
        out.update({r.request_id: r for r in svc.drain()})
    return out


def _assert_same_responses(got: dict, ref: dict):
    assert set(got) == set(ref)
    for rid in ref:
        assert got[rid].error is None and ref[rid].error is None
        np.testing.assert_array_equal(got[rid].labels, ref[rid].labels)
        np.testing.assert_array_equal(got[rid].scores, ref[rid].scores)


# ---------------------------------------------------------------------------
# stats schema + latency percentiles + ladder boundaries
# ---------------------------------------------------------------------------

def test_stats_as_dict_schema_pin():
    """The stats dict is a wire/bench artifact — its key set is pinned."""
    assert set(ServiceStats().as_dict()) == {
        "requests", "rows", "padded_rows", "launches", "online_seconds",
        "rows_per_s", "triples_per_request", "bytes_per_request",
        "pad_overhead", "replenish_events", "failed_requests",
        "retried_groups", "shed_requests", "expired_requests",
        "queue_depth", "max_queue_depth", "p50_ms", "p99_ms",
        "queue_wait_p50_ms", "queue_wait_p99_ms",
        "inflight_p50_ms", "inflight_p99_ms"}


def test_latency_percentiles_match_numpy():
    st = ServiceStats()
    assert st.latency_quantile(0.5) == 0.0          # empty window
    rng = np.random.default_rng(7)
    trace = rng.gamma(2.0, 0.01, size=501)
    for s in trace:
        st.record_latency(s)
    for q in (0.5, 0.9, 0.99):
        assert st.latency_quantile(q) == pytest.approx(
            float(np.quantile(trace, q)))
    d = st.as_dict()
    assert d["p50_ms"] == pytest.approx(
        float(np.quantile(trace, 0.5)) * 1e3, abs=1e-3)
    assert d["p99_ms"] >= d["p50_ms"]


def test_rung_for_boundaries():
    lad = BatchLadder((32, 128, 512))
    assert lad.rung_for(1) == 32
    assert lad.rung_for(32) == 32       # exact rung: no promotion
    assert lad.rung_for(33) == 128
    assert lad.rung_for(128) == 128
    assert lad.rung_for(512) == 512
    assert lad.rung_for(513) == 512     # oversize: top rung (chunked)


def test_chunks_exact_multiple_no_empty_chunk(fitted):
    km, res = fitted
    svc = _service(km, res)             # top rung 16
    xa = np.zeros((32, D_A))
    xb = np.zeros((32, D_B))
    chunks = svc._chunks(xa, xb)
    assert len(chunks) == 2             # remainder 0: exactly 2, none empty
    assert all(c[0].shape[0] == 16 for c in chunks)
    assert len(svc._chunks(np.zeros((33, D_A)), np.zeros((33, D_B)))) == 3


# ---------------------------------------------------------------------------
# admission control + deadlines
# ---------------------------------------------------------------------------

def test_admission_sheds_past_high_water(fitted):
    km, res = fitted
    svc = _service(km, res, max_queue=2)
    b = _batches(3)
    r0 = svc.submit(*b[0])
    r1 = svc.submit(*b[1])
    shed = svc.submit(*b[2])
    assert isinstance(shed, ScoringResponse)
    assert shed.error.startswith(ERR_QUEUE_FULL)
    assert svc.stats.shed_requests == 1
    resp = svc.drain()
    assert [r.request_id for r in resp] == [r0, r1]
    assert all(r.error is None for r in resp)
    # shed is transient: the queue drained, the same submit is admitted now
    assert isinstance(svc.submit(*b[2]), int)


def test_submit_rid_dedup(fitted):
    km, res = fitted
    svc = _service(km, res)
    b = _batches(1)[0]
    assert svc.submit(*b, rid=5) == 5
    assert svc.submit(*b, rid=5) == 5   # duplicate delivery: not re-queued
    assert svc.pending() == 1
    resp = svc.drain()
    assert len(resp) == 1 and resp[0].request_id == 5
    assert svc.submit(*b, rid=5) == 5   # answered: dedup against the cache
    assert svc.pending() == 0
    assert svc.submit(*b) == 6          # auto ids continue past pinned ones


def test_deadline_expired_at_dequeue(fitted):
    km, res = fitted
    svc = _service(km, res)
    b = _batches(2)
    dead = svc.submit(*b[0], deadline_s=-1.0)   # already expired
    live = svc.submit(*b[1])
    served0 = svc.bank.served_requests
    svc.warm()
    served_warm = svc.bank.served_requests
    resp = {r.request_id: r for r in svc.drain()}
    assert resp[dead].error.startswith(ERR_DEADLINE)
    assert resp[dead].rows == 0
    assert resp[live].error is None
    assert svc.stats.expired_requests == 1
    # the expired request drew no correlated randomness: exactly one
    # launch worth of draws happened
    one = _service(km, res)
    one.submit(*b[1])
    one.warm()
    base = one.bank.served_requests
    one.drain()
    assert svc.bank.served_requests - served_warm \
        == one.bank.served_requests - base
    assert served0 == 0


# ---------------------------------------------------------------------------
# replenisher daemon: off-hot-path top-ups, bit-exact streams
# ---------------------------------------------------------------------------

def test_replenisher_stream_continuity(fitted):
    km, res = fitted
    b = _batches(10)
    ref = _one_at_a_time(_service(km, res, provision_copies=2), b)
    svc = _service(km, res, provision_copies=2,
                   replenisher={"low_water": 1, "high_water": 3,
                                "poll_s": 0.001})
    try:
        got = {}
        for xa, xb in b:
            svc.submit(xa, xb)
            got.update({r.request_id: r for r in svc.drain()})
            time.sleep(0.005)           # let the daemon race the drains
    finally:
        svc.close()
    _assert_same_responses(got, ref)
    assert svc.replenisher.topups > 0
    assert svc.replenisher.errors == 0, svc.replenisher.last_error
    # daemon kept the hot path from ever hitting a synchronous stock-out
    assert svc.stats.replenish_events < svc.bank.replenish_events \
        + len(b)


def test_concurrent_draws_never_fork_a_stream(fitted):
    """Regression (PR-8 bugfix): two threads hammering one class on an
    auto-replenish bank must serve the serial stream prefix — every word
    exactly once, no duplicates, no forks."""
    km, res = fitted
    key, plan, _ = km.plan_predict((16, D_A), (16, D_B), True)
    n_each = 12

    def words(e):
        return tuple(np.asarray(a).tobytes() for a in e)

    bank = TripleBank(seed=9)
    bank.provision(key, plan, copies=1)
    class_key = sorted(bank._queues)[0]
    popped, errs = [], []

    def hammer():
        try:
            for _ in range(n_each):
                popped.append(words(bank._pop(class_key, key)))
        except Exception as e:          # pragma: no cover - failure path
            errs.append(e)

    ts = [threading.Thread(target=hammer) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=120)
    assert not errs
    assert len(popped) == 2 * n_each
    assert len(set(popped)) == 2 * n_each     # a fork would duplicate

    serial = TripleBank(seed=9)
    serial.provision(key, plan, copies=1)
    expect = [words(serial._pop(class_key, key)) for _ in range(2 * n_each)]
    assert sorted(popped) == sorted(expect)   # exactly the serial prefix


# ---------------------------------------------------------------------------
# exactly-once restart (in-process)
# ---------------------------------------------------------------------------

def test_restart_replays_and_realigns_bit_exact(fitted, tmp_path):
    km, res = fitted
    b = _batches(6)
    ref = _one_at_a_time(_service(km, res), b)

    ck = ServeCheckpointer(str(tmp_path / "ck"))
    svc = _service(km, res, checkpointer=ck)
    got = _one_at_a_time(svc, b[:3])
    del svc                                   # "crash" after 3 journals

    ck2 = ServeCheckpointer(str(tmp_path / "ck"))
    svc2 = _service(km, res, checkpointer=ck2)
    # journaled rids replay verbatim without re-scoring
    for rid in got:
        r = svc2.lookup(rid)
        np.testing.assert_array_equal(r.labels, got[rid].labels)
        np.testing.assert_array_equal(r.scores, got[rid].scores)
    # the realigned bank re-draws the NEXT words: remaining requests are
    # bit-exact with the uninterrupted reference
    got.update(_one_at_a_time(svc2, b[3:]))
    _assert_same_responses(got, ref)


def test_restart_never_double_draws(fitted, tmp_path):
    km, res = fitted
    b = _batches(2)
    ck = ServeCheckpointer(str(tmp_path / "ck"))
    svc = _service(km, res, checkpointer=ck)
    _one_at_a_time(svc, b[:1])
    consumed_before = svc.bank.consumed_counts()
    svc2 = _service(km, res,
                    checkpointer=ServeCheckpointer(str(tmp_path / "ck")))
    # the reloaded bank starts exactly where the dead one stopped
    assert svc2.bank.consumed_counts() == consumed_before
    _one_at_a_time(svc2, b[1:])
    after = svc2.bank.consumed_counts()
    assert all(after[k] >= v for k, v in consumed_before.items())


# ---------------------------------------------------------------------------
# background loop + wire frontend under faults
# ---------------------------------------------------------------------------

def test_background_loop_serves_and_records_latency(fitted):
    km, res = fitted
    b = _batches(4)
    ref = _one_at_a_time(_service(km, res), b)
    svc = _service(km, res, provision_copies=8)
    svc.start()
    try:
        rids = []
        for xa, xb in b:                # one at a time: match the ref's
            rid = svc.submit(xa, xb)    # grouping
            assert svc.response(rid, timeout=60) is not None
            rids.append(rid)
        for i, rid in enumerate(rids):
            r = svc.lookup(rid)
            np.testing.assert_array_equal(r.labels, ref[i].labels)
            np.testing.assert_array_equal(r.scores, ref[i].scores)
    finally:
        svc.close()
    assert svc.loop_errors == 0
    assert len(svc.stats.latencies) == len(b)
    assert svc.stats.latency_quantile(0.5) > 0.0


def test_wire_chaos_authenticated_bit_exact(fitted):
    """Drop/dup/corrupt on the client's send side with keyed frames: the
    MAC rejects tampered frames like corruption, retries ride the rid
    dedup, and every response is bit-exact with the direct run."""
    km, res = fitted
    b = _batches(4)
    ref = _one_at_a_time(_service(km, res), b)
    key = session_key("serving-plane-test")
    ta, tb = LoopbackTransport.pair()
    ft = FaultyTransport(ta, seed=9, drop=0.15, dup=0.15, corrupt=0.2)
    svc = _service(km, res, provision_copies=8)
    server = ScoringServer(svc, tb, idle_timeout_s=30.0, auth_key=key)
    th = threading.Thread(target=server.serve_forever, daemon=True)
    th.start()
    client = ScoringClient(ft, auth_key=key, deadline_s=20.0)
    got = {}
    for xa, xb in b:
        r = client.score(xa, xb)
        got[r.request_id] = r
    client.bye()
    th.join(timeout=30)
    _assert_same_responses(got, ref)
    f = ft.faults
    assert f.dropped + f.duplicated + f.corrupted > 0
    assert server.responder.crc_drops > 0 or f.corrupted == 0


def test_unkeyed_frames_rejected_by_keyed_decoder():
    key = session_key("k1")
    dec = FrameDecoder(key=key)
    assert dec.feed(encode_frame(T_SCORE, 0, b"payload")) == []  # unkeyed
    assert dec.auth_errors == 1
    keyed = encode_frame(T_SCORE, 1, b"payload", key=key)
    tampered = bytearray(keyed)
    tampered[-1] ^= 1
    assert dec.feed(bytes(tampered)) == []                       # forged
    assert dec.auth_errors == 2
    frames = dec.feed(keyed)                                     # genuine
    assert frames == [(T_SCORE, 1, b"payload")]


# ---------------------------------------------------------------------------
# two-process chaos: kill the server mid-run, restart, exactly once
# ---------------------------------------------------------------------------

def _spawn_server(args, env):
    p = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_kmeans"] + args,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    for line in p.stdout:
        m = re.match(r"SERVING (\d+)", line)
        if m:
            return p, int(m.group(1))
    raise RuntimeError(f"server died before SERVING: rc={p.wait()}")


def test_wire_server_kill_restart_exactly_once(tmp_path):
    """The acceptance chaos run: seeded drop/dup/delay on the wire, the
    server os._exits right after its 3rd journaled response, a fresh
    server on the SAME port resumes from the checkpoint, and the client's
    rid-pinned retries get every one of 6 requests answered exactly once
    — bit-exact vs a fault-free in-process run."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    ck = str(tmp_path / "ck")
    base = ["--n-train", "200", "--d-a", str(D_A), "--d-b", str(D_B),
            "--k", str(K), "--iters", "2", "--rungs", "16",
            "--serve-checkpoint-dir", ck, "--auth-key", "hunter2",
            "--provision-copies", "16", "--idle-timeout", "120",
            "--seed", "0"]
    p, port = _spawn_server(base + ["--serve-port", "0",
                                    "--die-after-responses", "3"], env)
    b = _batches(6)
    t = SocketTransport("connect", port=port, io_timeout_s=5.0)
    ft = FaultyTransport(t, seed=11, drop=0.05, dup=0.05, delay_s=0.002)
    client = ScoringClient(ft, auth_key=session_key("hunter2"),
                           deadline_s=10.0, waves=2, retry_wait_s=0.2)
    got = {}
    restarted = False
    try:
        for i, (xa, xb) in enumerate(b):
            while True:
                try:
                    got[i] = client.score(xa, xb, rid=i)
                    break
                except Exception:
                    # server died mid-request: restart it on the SAME
                    # port with the SAME checkpoint dir (no die flag)
                    assert not restarted, "server unreachable after restart"
                    assert p.wait(timeout=60) == 17
                    p.stdout.read()
                    p, port2 = _spawn_server(
                        base + ["--serve-port", str(port)], env)
                    assert port2 == port
                    restarted = True
        client.bye()
    finally:
        t.close()
        try:
            p.stdout.read()
            p.wait(timeout=60)
        except Exception:
            p.kill()
    assert restarted, "die-after-responses never fired"
    assert sorted(got) == list(range(6))

    # fault-free direct reference (same deterministic fit as the server)
    ds = FraudDataset.synthesize(n=200, d_a=D_A, d_b=D_B, n_clusters=K,
                                 seed=0)
    km = SecureKMeans(KMeansConfig(k=K, iters=2, seed=0, offline="pooled"))
    res = km.fit(ds.x_a, ds.x_b)
    ref = _one_at_a_time(_service(km, res, provision_copies=16), b)
    _assert_same_responses(got, ref)


# ---------------------------------------------------------------------------
# health-state machine (DESIGN.md §16)
# ---------------------------------------------------------------------------

def test_health_starting_until_warm_then_ready(fitted):
    km, res = fitted
    svc = _service(km, res, provision_copies=2)
    assert svc.health == "STARTING" and svc.health_code() == 0
    svc.warm()
    assert svc.health == "READY" and svc.health_code() == 1


def test_health_degraded_on_loop_errors_and_draining_on_close(fitted):
    km, res = fitted
    svc = _service(km, res, provision_copies=2)
    svc.warm()
    svc.loop_errors = 1
    assert svc.health == "DEGRADED" and svc.health_code() == 2
    svc.loop_errors = 0
    svc.close()
    assert svc.health == "DRAINING" and svc.health_code() == 3


def test_health_degraded_when_replenisher_errors_or_dies(fitted):
    km, res = fitted
    svc = _service(km, res, provision_copies=2,
                   replenisher={"low_water": 0, "high_water": 1,
                                "poll_s": 0.01})
    svc.warm()
    assert svc.replenisher.running and svc.health == "READY"
    svc.replenisher.errors = 1               # a swallowed top-up failure
    assert svc.health == "DEGRADED"
    svc.replenisher.errors = 0
    svc.replenisher.stop()                   # daemon died under us
    assert svc.health == "DEGRADED"
    svc.close()
    assert svc.health == "DRAINING"


def test_health_gauge_registered_on_warm(fitted):
    from repro.obs import metrics as _metrics
    km, res = fitted
    svc = _service(km, res, provision_copies=2)
    svc.warm()
    assert _metrics.get_registry().snapshot()["repro_serve_health"] == 1


def test_stats_as_dict_keys_unchanged_by_health_machine(fitted):
    """Pin: the health machine must not leak new keys into the 22-key
    ServiceStats schema (dashboards + BENCH parsers rely on it)."""
    km, res = fitted
    svc = _service(km, res, provision_copies=2)
    svc.warm()
    assert len(svc.stats.as_dict()) == 22


# ---------------------------------------------------------------------------
# supervised wire server: crash-looping server, exactly-once answers
# ---------------------------------------------------------------------------

def test_supervised_server_restarts_and_answers_exactly_once(tmp_path):
    """`serve_kmeans --supervised`: the supervisor pins the port, the
    incarnation-0 server dies after its 3rd journaled response, the
    respawned server (crash switch stripped) replays the journal — and
    the client's rid-pinned waves get all 6 requests answered exactly
    once, bit-exact vs the in-process reference."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    ck = str(tmp_path / "ck")
    args = ["--supervised", "--serve-port", "0",
            "--n-train", "200", "--d-a", str(D_A), "--d-b", str(D_B),
            "--k", str(K), "--iters", "2", "--rungs", "16",
            "--serve-checkpoint-dir", ck, "--provision-copies", "16",
            "--die-after-responses", "3", "--idle-timeout", "120",
            "--seed", "0"]
    sup = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_kmeans"] + args,
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    port = None
    try:
        for line in sup.stdout:
            m = re.search(r"SERVING (\d+)", line)
            if m:
                port = int(m.group(1))
                break
        assert port is not None, "supervised server never reached SERVING"
        b = _batches(6)
        t = SocketTransport("connect", port=port, io_timeout_s=5.0)
        client = ScoringClient(t, deadline_s=15.0, try_timeout_s=0.5,
                               waves=20, retry_wait_s=2.0)
        got = {}
        for i, (xa, xb) in enumerate(b):
            got[i] = client.score(xa, xb, rid=i)
        client.bye()
        t.close()
        out_rest = sup.communicate(timeout=120)[0]
    finally:
        if sup.poll() is None:
            sup.kill()
            sup.communicate()
    # the supervisor observed exactly one crash (rc=17) and one restart,
    # then a clean terminal exit
    assert sup.returncode == 0, out_rest
    assert "restart 1 after rc=17" in out_rest
    assert "SUPERVISOR terminal: clean exit (rc=0, restarts=1)" in out_rest
    # exactly-once, bit-exact
    assert sorted(got) == list(range(6))
    ds = FraudDataset.synthesize(n=200, d_a=D_A, d_b=D_B, n_clusters=K,
                                 seed=0)
    km = SecureKMeans(KMeansConfig(k=K, iters=2, seed=0, offline="pooled"))
    res = km.fit(ds.x_a, ds.x_b)
    ref = _one_at_a_time(_service(km, res, provision_copies=16), b)
    _assert_same_responses(got, ref)
