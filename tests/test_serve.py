"""Secure scoring & serving subsystem tests.

Load-bearing properties: (1) `SecureKMeans.predict`/`score` assigns new
batches exactly like nearest-centroid under the (never actually revealed)
model, for all four partition x sparsity combos; (2) the compiled
`predict_program` launch is bit-exact with the eager reference, and a
provisioned `TripleBank` is bit-exact with the on-demand dealer; (3) the
bank round-trips through np.savez persistence — including the per-class
RNG stream positions, so post-reload replenishment stays deterministic —
and auto-replenishes on stock-out instead of crashing; (4) the
`ScoringService` coalesce/pad/launch loop returns per-request outputs
identical to direct scoring."""
import os

import numpy as np
import pytest

from repro.core.fraud import (FraudDataset, detect_outliers, fraud_scores,
                              jaccard)
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.triples import (PoolExhaustedError, TripleBank,
                                TrustedDealer, serve_seed)
from repro.serve import BatchLadder, ScoringService


def _blobs(n, d, k, seed, sparse_frac=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-4, 4, (k, d))
    lab = rng.integers(0, k, n)
    x = centers[lab] + rng.normal(0, 0.3, (n, d))
    if sparse_frac:
        x = x * (rng.random((n, d)) >= sparse_frac)
    return x


def _split(x, partition):
    n, d = x.shape
    if partition == "vertical":
        return x[:, :d // 2], x[:, d // 2:]
    return x[:n // 2], x[n // 2:]


def _fitted(partition, sparse, *, n=96, d=4, k=3, seed=5):
    x = _blobs(n, d, k, 1, 0.5 if sparse else 0.0)
    a, b = _split(x, partition)
    km = SecureKMeans(KMeansConfig(k=k, iters=3, partition=partition,
                                   sparse=sparse, seed=seed, backend="xla"))
    res = km.fit(a, b)
    return km, res


def _batch(partition, sparse, m=20, d=4, k=3, seed=9):
    xq = _blobs(m, d, k, seed, 0.5 if sparse else 0.0)
    return (xq, *_split(xq, partition))


# ---------------------------------------------------------------------------
# predict parity vs the plaintext nearest-centroid oracle (4 combos)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
@pytest.mark.parametrize("sparse", [False, True])
def test_predict_matches_nearest_centroid(partition, sparse):
    km, res = _fitted(partition, sparse)
    xq, qa, qb = _batch(partition, sparse)
    pr = km.predict(qa, qb)
    mu = res.centroids_plain()     # oracle only — predict never reveals mu
    full = xq if partition == "vertical" else np.concatenate(
        [qa, qb], 0)               # horizontal outputs: [A rows; B rows]
    ref = ((mu ** 2).sum(1)[None] - 2 * full @ mu.T).argmin(1)
    assert (pr.labels_plain() == ref).mean() == 1.0


@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
def test_score_matches_squared_distance(partition):
    km, res = _fitted(partition, False)
    xq, qa, qb = _batch(partition, False)
    pr = km.score(qa, qb)
    mu = res.centroids_plain()
    full = xq if partition == "vertical" else np.concatenate([qa, qb], 0)
    lab = pr.labels_plain()
    want = ((full - mu[lab]) ** 2).sum(1)
    np.testing.assert_allclose(pr.scores_plain(), want, atol=1e-2)


def test_predict_needs_a_fitted_model():
    km = SecureKMeans(KMeansConfig(k=3, iters=2, backend="xla"))
    with pytest.raises(ValueError, match="fitted"):
        km.predict(np.zeros((4, 2)), np.zeros((4, 2)))


def test_predict_default_randomness_is_domain_separated():
    """The default predict dealer must NOT replay the fit's per-class
    streams: mask reuse across protocol runs on overlapping shape-classes
    would leak differences of secrets. serve_seed(s) != s, and the default
    path serves different words than a fit-seeded dealer would."""
    assert serve_seed(5) != 5
    km, _ = _fitted("vertical", False, seed=5)
    _, qa, qb = _batch("vertical", False)
    default = km.score(qa, qb)                       # serve_seed(cfg.seed)
    fit_seeded = km.score(qa, qb, dealer=TrustedDealer(seed=5))
    assert not np.array_equal(
        np.asarray(default.scores.s0, np.uint64),
        np.asarray(fit_seeded.scores.s0, np.uint64))
    # ...while the OUTPUT is dealer-independent (masks cancel)
    np.testing.assert_array_equal(default.labels_plain(),
                                  fit_seeded.labels_plain())


def test_predict_compiled_true_rejects_unsupported_configs():
    """An explicit compiled=True must error loudly rather than truncate at
    the wrong fixed-point scale or die inside the tracer."""
    x = _blobs(48, 4, 2, 3)
    km = SecureKMeans(KMeansConfig(k=2, iters=2, seed=5, f=16,
                                   backend="xla"))
    km.fit(x[:, :2], x[:, 2:])
    with pytest.raises(ValueError, match="hardcodes"):
        km.predict(x[:, :2], x[:, 2:], compiled=True)
    km2 = SecureKMeans(KMeansConfig(k=2, iters=2, seed=5, backend="numpy"))
    km2.fit(x[:, :2], x[:, 2:])
    with pytest.raises(ValueError, match="numpy backend"):
        km2.predict(x[:, :2], x[:, 2:], compiled=True)
    km2.predict(x[:, :2], x[:, 2:])                  # auto path: eager, fine


# ---------------------------------------------------------------------------
# bit-exactness: eager == compiled, bank == on-demand
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
@pytest.mark.parametrize("sparse", [False, True])
def test_predict_eager_compiled_bit_exact(partition, sparse):
    """Same per-class dealer streams -> identical share words whether the
    scoring launch is the AOT-compiled predict_program or the eager
    reference protocol, for every combo (the sparse ones run Protocol 2
    host-side before the launch either way)."""
    km, _ = _fitted(partition, sparse)
    _, qa, qb = _batch(partition, sparse)
    fast = km.score(qa, qb, dealer=TrustedDealer(seed=7))
    ref = km.score(qa, qb, dealer=TrustedDealer(seed=7), compiled=False)
    for field in ("assignment", "scores"):
        np.testing.assert_array_equal(
            np.asarray(getattr(fast, field).s0, np.uint64),
            np.asarray(getattr(ref, field).s0, np.uint64))
        np.testing.assert_array_equal(
            np.asarray(getattr(fast, field).s1, np.uint64),
            np.asarray(getattr(ref, field).s1, np.uint64))
    # shape-determined traffic: the compiled replay equals the eager tally
    assert fast.log.by_tag("online") == ref.log.by_tag("online")


def test_predict_banked_bit_exact_vs_on_demand():
    """A freshly provisioned TripleBank serves the same words as a
    same-seeded TrustedDealer: pooled serving changes nothing downstream."""
    km, _ = _fitted("vertical", False)
    _, qa, qb = _batch("vertical", False)
    key, plan, _ = km.plan_predict(qa.shape, qb.shape, True)
    bank = TripleBank(seed=7)
    bank.provision(key, plan, copies=1)
    banked = km.score(qa, qb, dealer=bank.dealer(key))
    ondemand = km.score(qa, qb, dealer=TrustedDealer(seed=7))
    np.testing.assert_array_equal(
        np.asarray(banked.scores.s0, np.uint64),
        np.asarray(ondemand.scores.s0, np.uint64))
    np.testing.assert_array_equal(
        np.asarray(banked.assignment.s1, np.uint64),
        np.asarray(ondemand.assignment.s1, np.uint64))


# ---------------------------------------------------------------------------
# TripleBank: superpool across geometries/fits, persistence, replenish
# ---------------------------------------------------------------------------

def test_bank_serves_two_geometries_across_two_fits_after_reload(tmp_path):
    """ONE provisioning pass covers two predict geometries and two fitted
    models, and survives a save/reload in between (the acceptance
    criterion). The reloaded bank serves words identical to the original's."""
    km1, res1 = _fitted("vertical", False, seed=5)
    km2, res2 = _fitted("vertical", False, seed=6)
    geos = [_batch("vertical", False, m=8, seed=21),
            _batch("vertical", False, m=16, seed=22)]
    bank = TripleBank(seed=13)
    for _, qa, qb in geos:
        key, plan, _ = km1.plan_predict(qa.shape, qb.shape, True)
        bank.provision(key, plan, copies=4)     # 4 serves per geometry
    path = os.path.join(tmp_path, "bank.npz")
    bank.save(path)
    loaded = TripleBank.load(path)
    assert sorted(loaded.stock().items()) == sorted(bank.stock().items())
    for km, res in ((km1, res1), (km2, res2)):
        for _, qa, qb in geos:
            key, _, _ = km.plan_predict(qa.shape, qb.shape, True)
            a = km.score(qa, qb, res, dealer=bank.dealer(key))
            b = km.score(qa, qb, res, dealer=loaded.dealer(key))
            np.testing.assert_array_equal(
                np.asarray(a.scores.s1, np.uint64),
                np.asarray(b.scores.s1, np.uint64))
    assert loaded.replenish_events == 0         # all from provisioned stock


def test_bank_save_path_used_verbatim(tmp_path):
    """save(p) -> load(p) must pair up even when p lacks the '.npz' suffix
    (np.savez's silent suffixing is bypassed)."""
    km, _ = _fitted("vertical", False)
    _, qa, qb = _batch("vertical", False, m=8)
    key, plan, _ = km.plan_predict(qa.shape, qb.shape, False)
    bank = TripleBank(seed=1)
    bank.provision(key, plan, copies=1)
    path = os.path.join(tmp_path, "bank_no_suffix")
    bank.save(path)
    assert os.path.exists(path)
    loaded = TripleBank.load(path)
    assert loaded.stock() == bank.stock()


def test_bank_reload_preserves_replenish_streams(tmp_path):
    """Post-reload replenishment continues the SAME per-class streams the
    original bank would have used: drain past the provisioned stock on
    both copies and compare."""
    km, _ = _fitted("vertical", False)
    _, qa, qb = _batch("vertical", False, m=8)
    key, plan, _ = km.plan_predict(qa.shape, qb.shape, False)
    bank = TripleBank(seed=3)
    bank.provision(key, plan, copies=1)
    path = os.path.join(tmp_path, "bank.npz")
    bank.save(path)
    loaded = TripleBank.load(path)
    for _ in range(3):                          # serve 1 copies, force 2 repl
        a = km.predict(qa, qb, dealer=bank.dealer(key))
        b = km.predict(qa, qb, dealer=loaded.dealer(key))
        np.testing.assert_array_equal(
            np.asarray(a.assignment.s0, np.uint64),
            np.asarray(b.assignment.s0, np.uint64))
    assert bank.replenish_events == loaded.replenish_events == 2


def test_bank_auto_replenish_and_strict_mode():
    km, _ = _fitted("vertical", False)
    _, qa, qb = _batch("vertical", False, m=8)
    key, plan, _ = km.plan_predict(qa.shape, qb.shape, True)
    bank = TripleBank(seed=2)
    bank.provision(key, plan, copies=1)
    km.score(qa, qb, dealer=bank.dealer(key))
    assert bank.replenish_events == 0
    km.score(qa, qb, dealer=bank.dealer(key))   # stock-out -> replenish
    assert bank.replenish_events >= 1
    strict = TripleBank(seed=2, auto_replenish=False)
    strict.provision(key, plan, copies=1)
    km.score(qa, qb, dealer=strict.dealer(key))
    with pytest.raises(PoolExhaustedError, match="stock-out"):
        km.score(qa, qb, dealer=strict.dealer(key))


def test_bank_unknown_key_raises():
    bank = TripleBank(seed=0)
    with pytest.raises(KeyError, match="no plan registered"):
        bank.dealer(("predict", "nope"))


# ---------------------------------------------------------------------------
# ScoringService: coalesce + pad-to-ladder + per-request splitting
# ---------------------------------------------------------------------------

def test_batch_ladder():
    lad = BatchLadder((32, 128))
    assert lad.rungs == (32, 128)
    assert lad.rung_for(1) == 32
    assert lad.rung_for(32) == 32
    assert lad.rung_for(33) == 128
    assert lad.rung_for(1000) == 128            # caller chunks
    with pytest.raises(ValueError):
        BatchLadder(())
    # rungs are validated, not silently fixed up: unsorted/duplicate/
    # non-positive ladders are config typos
    with pytest.raises(ValueError, match="strictly increasing"):
        BatchLadder((128, 32))
    with pytest.raises(ValueError, match="strictly increasing"):
        BatchLadder((32, 32, 128))
    with pytest.raises(ValueError, match=">= 1"):
        BatchLadder((0, 32))


def test_service_rungs_param():
    """ScoringService(..., rungs=...) configures the ladder (alias of
    ladder=; passing both is ambiguous and rejected)."""
    km, res = _fitted("vertical", False)
    svc = ScoringService(km, res, rungs=(8, 16), d_a=2, d_b=2)
    assert svc.ladder.rungs == (8, 16)
    with pytest.raises(ValueError, match="not both"):
        ScoringService(km, res, rungs=(8,), ladder=(8,), d_a=2, d_b=2)


def test_service_pipeline_matches_sequential():
    """pipeline=True (request t+1's exchange/bank draw overlapping request
    t's launch) returns responses identical to the sequential drain — same
    bank words, same labels and scores."""
    from repro.core.triples import TripleBank, serve_seed
    km, res = _fitted("vertical", False)
    outs = {}
    for pipe in (True, False):
        svc = ScoringService(km, res,
                             bank=TripleBank(seed=serve_seed(km.cfg.seed)),
                             rungs=(8, 16), with_scores=True, d_a=2, d_b=2,
                             provision_copies=2, pipeline=pipe)
        for i, m in enumerate([3, 5, 9, 2, 40]):
            _, qa, qb = _batch("vertical", False, m=m, seed=100 + i)
            svc.submit(qa, qb)
        outs[pipe] = svc.drain()
    for a, b in zip(outs[True], outs[False]):
        assert a.request_id == b.request_id
        np.testing.assert_array_equal(a.labels, b.labels)
        np.testing.assert_array_equal(a.scores, b.scores)


@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
def test_service_matches_direct_scoring(partition):
    """Ragged submits -> coalesced padded launches -> per-request outputs
    identical to scoring each request alone (padding reveals nothing and
    perturbs nothing)."""
    km, res = _fitted(partition, False)
    svc = ScoringService(km, res, ladder=(8, 16), with_scores=True,
                         d_a=2, d_b=2, provision_copies=2)
    reqs = []
    for i, m in enumerate([3, 5, 9, 2, 40]):    # 40 > top rung: chunked
        xq, qa, qb = _batch(partition, False, m=m, seed=100 + i)
        reqs.append((qa, qb))
        svc.submit(qa, qb)
    out = svc.drain()
    assert [r.request_id for r in out] == list(range(len(reqs)))
    assert svc.pending() == 0
    for r, (qa, qb) in zip(out, reqs):
        direct = km.score(qa, qb, res, dealer=TrustedDealer(seed=1))
        np.testing.assert_array_equal(r.labels, direct.labels_plain())
        # padding changes the launch geometry, so the truncation share-
        # randomness differs: scores agree to the fixed-point LSB (~2^-f),
        # not bit-exactly
        np.testing.assert_allclose(r.scores, direct.scores_plain(),
                                   atol=1e-4)
    st = svc.stats.as_dict()
    assert st["requests"] == len(reqs)
    assert st["rows"] == sum(qa.shape[0] + (qb.shape[0] if partition ==
                             "horizontal" else 0) for qa, qb in reqs)
    assert st["padded_rows"] >= st["rows"]
    assert st["launches"] < len(reqs) + 3       # coalescing actually merges


def test_service_drains_bank_and_reports_traffic():
    km, _ = _fitted("vertical", False)
    bank = TripleBank(seed=4)
    svc = ScoringService(km, bank=bank, ladder=(8,), with_scores=True,
                         d_a=2, d_b=2, provision_copies=3)
    svc.warm()
    stock0 = sum(bank.stock().values())
    assert stock0 > 0                           # provisioned offline
    for i in range(3):
        _, qa, qb = _batch("vertical", False, m=6, seed=50 + i)
        svc.submit(qa, qb)
    svc.drain()
    assert sum(bank.stock().values()) < stock0  # the service drained it
    st = svc.stats.as_dict()
    assert st["triples_per_request"] > 0
    assert st["bytes_per_request"] > 0
    assert st["replenish_events"] == 0          # provisioning covered it


def test_service_requires_feature_split_for_vertical():
    km, _ = _fitted("vertical", False)
    with pytest.raises(ValueError, match="feature split"):
        ScoringService(km, ladder=(8,))


# ---------------------------------------------------------------------------
# fraud: secure scoring replaces the revealed-model path
# ---------------------------------------------------------------------------

def test_fraud_secure_scoring_matches_revealed_model_quality():
    """The leak-free score path flags (almost) the same outliers as the
    reveal_model=True escape hatch — secure scoring costs nothing in
    detection quality. (Scores may differ at cluster boundaries: predict
    assigns against the FINAL centroids, the revealed path re-uses the
    last iteration's labels.)"""
    ds = FraudDataset.synthesize(n=600, d_a=4, d_b=6, seed=1)
    km = SecureKMeans(KMeansConfig(k=5, iters=5, seed=2))
    res = km.fit(ds.x_a, ds.x_b)
    sec = fraud_scores(km, res, ds)
    rev = fraud_scores(km, res, ds, reveal_model=True)
    f_sec = detect_outliers(sec, 0.02)          # = the planted fraction
    f_rev = detect_outliers(rev, 0.02)
    assert jaccard(f_sec, f_rev) > 0.8
    assert jaccard(f_sec, ds.y_outlier) > 0.4


# ---------------------------------------------------------------------------
# drain failure policy: bounded retries, error responses, no livelock
# ---------------------------------------------------------------------------

def test_poisoned_request_cannot_livelock_drain():
    """A request whose geometry breaks its launch resolves as an ERROR
    response after bounded retries — it must neither spin the drain
    forever nor ride the queue into every later drain."""
    km, res = _fitted("vertical", False)
    svc = ScoringService(km, res, rungs=(8,), with_scores=True,
                         d_a=2, d_b=2, max_attempts=3)
    _, qa, qb = _batch("vertical", False, m=4, seed=100)
    good1 = svc.submit(qa, qb)
    # poison: wrong feature width (submit only validates row counts);
    # 5 + 4 rows > the 8-rung, so it cannot coalesce with a good request
    bad = svc.submit(np.zeros((5, 3)), np.zeros((5, 2)))
    _, qa2, qb2 = _batch("vertical", False, m=5, seed=101)
    good2 = svc.submit(qa2, qb2)

    responses = svc.drain()
    assert [r.request_id for r in responses] == [good1, bad, good2]
    by_id = {r.request_id: r for r in responses}
    assert by_id[bad].error is not None and by_id[bad].rows == 0
    assert by_id[good1].error is None and by_id[good1].labels.shape == (4,)
    assert by_id[good2].error is None and by_id[good2].labels.shape == (5,)
    # the poisoned request is DONE: nothing left to livelock on
    assert svc.pending() == 0
    assert svc.stats.failed_requests == 1
    assert svc.stats.retried_groups == 2          # attempts 2 and 3
    assert svc.drain() == []


def test_error_responses_match_direct_scoring_for_survivors():
    """Requests coalesced AWAY from the poisoned group score normally."""
    km, res = _fitted("vertical", False)
    svc = ScoringService(km, res, rungs=(8,), with_scores=True,
                         d_a=2, d_b=2, max_attempts=2)
    _, qa, qb = _batch("vertical", False, m=6, seed=200)
    good = svc.submit(qa, qb)
    svc.submit(np.zeros((7, 3)), np.zeros((7, 2)))   # own group (8-rung)
    responses = svc.drain()
    ok = [r for r in responses if r.error is None]
    assert [r.request_id for r in ok] == [good]
    direct = km.score(qa, qb, res)
    np.testing.assert_array_equal(ok[0].labels, direct.labels_plain())
    assert svc.stats.failed_requests == 1
