import jax

# The MPC core needs uint64 lanes; model code is dtype-explicit so this is
# safe to set globally for the test session. (dryrun.py manages its own
# device-count env and is NOT imported here — smoke tests must see 1 device.)
jax.config.update("jax_enable_x64", True)
