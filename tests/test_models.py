"""Per-arch smoke tests (reduced configs, 1 fwd/train step, shape+NaN
asserts) + numerical consistency: flash==naive attention, decode==forward,
chunked CE == direct CE, MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, all_archs
from repro.models import layers as L
from repro.models.lm import ce_loss, forward, init_params, lm_loss
from repro.serving.decode import init_cache, serve_step
from repro.training.adamw import AdamWConfig
from repro.training.train_step import init_state, make_train_step

ARCHS = list(all_archs().items())
KEY = jax.random.key(0)


def _batch(cfg, b=2, t=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)))}
    if cfg.enc_dec:
        batch["enc_inputs"] = jnp.asarray(
            rng.normal(0, 1, (b, t, cfg.d_model)), jnp.bfloat16)
    if cfg.frontend == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.n_patches, cfg.d_model)), jnp.bfloat16)
    return batch


# ---------------------------------------------------------------------------
# per-arch smoke: reduced config, one forward + one train step on CPU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id,spec", ARCHS, ids=[a for a, _ in ARCHS])
def test_arch_smoke_forward_and_train(arch_id, spec):
    cfg = spec.reduced
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    hidden = forward(params, cfg, tokens=batch["tokens"],
                     enc_inputs=batch.get("enc_inputs"),
                     patch_embeds=batch.get("patch_embeds"))
    assert hidden.shape == (2, 64, cfg.d_model)
    assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())
    step = make_train_step(cfg, AdamWConfig())
    state = init_state(params, AdamWConfig())
    new_params, _, metrics = jax.jit(step)(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch_id,spec", ARCHS, ids=[a for a, _ in ARCHS])
def test_arch_smoke_decode(arch_id, spec):
    cfg = spec.reduced
    params = init_params(cfg, KEY)
    cache = init_cache(cfg, 2, 32, enc_len=16 if cfg.enc_dec else 0)
    tok = jnp.zeros((2, 1), jnp.int32)
    for pos in range(3):
        logits, cache = serve_step(params, cfg, cache, tok, jnp.int32(pos))
    assert logits.shape == (2, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())


# ---------------------------------------------------------------------------
# decode == teacher-forced forward (the KV cache/state paths are exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ["granite-34b", "gemma2-27b",
                                     "deepseek-v2-236b", "rwkv6-1.6b",
                                     "recurrentgemma-2b",
                                     "granite-moe-3b-a800m"])
def test_decode_matches_forward(arch_id):
    import dataclasses
    # generous MoE capacity: the forward path drops overflow tokens by design
    # (cap_factor 1.25); exact decode==forward needs no drops
    cfg = dataclasses.replace(all_archs()[arch_id].reduced,
                              capacity_factor=8.0)
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(1)
    t = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, t)))
    hidden = forward(params, cfg, tokens=tokens, remat=False)
    h_last = hidden[:, -1].astype(jnp.bfloat16)
    logits_fwd = (h_last @ params["head"]).astype(jnp.float32)

    cache = init_cache(cfg, 1, t)
    for pos in range(t):
        logits_dec, cache = serve_step(params, cfg, cache,
                                       tokens[:, pos:pos + 1], jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_fwd),
                               rtol=0.15, atol=0.15)
    assert int(logits_dec.argmax(-1)[0]) == int(logits_fwd.argmax(-1)[0])


# ---------------------------------------------------------------------------
# attention: flash-chunked == naive; window masking
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal, window, scale, cap):
    b, tq, h, dk = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, tq, hkv, g, dk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    s = L.softcap(s, cap)
    qp, kp = jnp.arange(tq), jnp.arange(k.shape[1])
    mask = jnp.ones((tq, k.shape[1]), bool)
    if causal:
        mask &= kp[None] <= qp[:, None]
    if window:
        mask &= kp[None] > qp[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, tq, h, v.shape[-1])


@pytest.mark.parametrize("causal,window,cap", [(True, None, None),
                                               (True, 16, None),
                                               (True, None, 50.0),
                                               (False, None, None)])
def test_flash_matches_naive(causal, window, cap):
    rng = np.random.default_rng(0)
    b, t, h, hkv, d = 2, 100, 4, 2, 16
    q = jnp.asarray(rng.normal(0, 1, (b, t, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, t, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, t, hkv, d)), jnp.float32)
    got = L.flash_attention(q, k, v, causal=causal, window=window,
                            scale=0.25, cap=cap, kv_chunk=32)
    want = _naive_attention(q, k, v, causal, window, 0.25, cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# chunked CE == direct CE
# ---------------------------------------------------------------------------

def test_chunked_ce_matches_direct():
    cfg = all_archs()["granite-34b"].reduced
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(2)
    h = jnp.asarray(rng.normal(0, 1, (2, 64, cfg.d_model)), jnp.bfloat16)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)))
    got = ce_loss(params, cfg, h, labels, chunk=16)
    logits = (h @ params["head"]).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    true = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = (lse - true).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

def test_moe_capacity_and_padding():
    cfg = all_archs()["granite-moe-3b-a800m"].reduced
    params = init_params(cfg, KEY)
    moe_p = jax.tree.map(lambda x: x[0], params["groups"][0]["b0"]["moe"])
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (2, 32, cfg.d_model)), jnp.bfloat16)
    out = L.moe_mlp(moe_p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    # padded experts exist in weights but receive nothing: zeroing their
    # weights must not change the output
    ep = moe_p["w_gate"].shape[0]
    assert ep % 16 == 0 and ep >= cfg.n_experts
    moe_p2 = dict(moe_p)
    for nm in ("w_gate", "w_up", "w_down"):
        moe_p2[nm] = moe_p[nm].at[cfg.n_experts:].set(0)
    out2 = L.moe_mlp(moe_p2, x, cfg)
    np.testing.assert_allclose(np.asarray(out, jnp.float32),
                               np.asarray(out2, jnp.float32))


def test_moe_per_example_matches_global():
    """The per-example (local-sort) dispatch == global dispatch when no
    tokens are dropped (generous capacity)."""
    import dataclasses
    base = dataclasses.replace(all_archs()["granite-moe-3b-a800m"].reduced,
                               capacity_factor=8.0)
    params = init_params(base, KEY)
    moe_p = jax.tree.map(lambda x: x[0], params["groups"][0]["b0"]["moe"])
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 1, (3, 16, base.d_model)), jnp.bfloat16)
    got_g = np.asarray(L.moe_mlp(moe_p, x, base), jnp.float32)
    cfg_pe = dataclasses.replace(base, moe_dispatch="per_example")
    got_pe = np.asarray(L.moe_mlp(moe_p, x, cfg_pe), jnp.float32)
    np.testing.assert_allclose(got_g, got_pe, rtol=0.02, atol=0.02)


def test_moe_matches_dense_reference():
    """Sort-based dispatch == brute-force per-token expert evaluation
    (with generous capacity so nothing is dropped)."""
    import dataclasses
    cfg = dataclasses.replace(all_archs()["granite-moe-3b-a800m"].reduced,
                              capacity_factor=8.0)
    params = init_params(cfg, KEY)
    moe_p = jax.tree.map(lambda x: x[0], params["groups"][0]["b0"]["moe"])
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(0, 1, (1, 16, cfg.d_model)), jnp.bfloat16)
    got = np.asarray(L.moe_mlp(moe_p, x, cfg), jnp.float32)

    xf = x.reshape(-1, cfg.d_model)
    logits = (xf.astype(jnp.float32) @ moe_p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    want = np.zeros_like(got).reshape(-1, cfg.d_model)
    for tkn in range(xf.shape[0]):
        for j in range(cfg.top_k):
            e = int(ids[tkn, j])
            h = xf[tkn: tkn + 1]
            ge = jax.nn.silu(h @ moe_p["w_gate"][e]) * (h @ moe_p["w_up"][e])
            want[tkn] += float(gate[tkn, j]) * np.asarray(
                (ge @ moe_p["w_down"][e]).astype(jnp.float32))[0]
    np.testing.assert_allclose(got.reshape(-1, cfg.d_model), want,
                               rtol=0.05, atol=0.05)
