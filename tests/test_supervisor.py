"""Restart supervisor unit tests (DESIGN.md §16).

Load-bearing properties:
* a crashing child is respawned with backoff and succeeds once its
  transient failure clears — and the recovery is visible as restart
  latencies (the MTTR inputs);
* terminal exit codes (0 = clean, 4 = ResumeMismatch) are NEVER retried;
* a crash loop (N consecutive fast deaths) goes terminal with a
  diagnostic carrying the child's last output instead of respawning
  forever, and the restart budget bounds slow-death loops the same way;
* `argv_for(incarnation)` lets the caller arm crash switches on
  incarnation 0 only;
* backoff jitter is seeded — two identically-configured supervisors
  pause identically (deterministic chaos runs).
"""
import sys

import pytest

from repro.launch.supervisor import (ChildEvent, RestartPolicy,
                                     SupervisedChild, Supervisor, child_env,
                                     free_port, python_argv)

FAST = RestartPolicy(max_restarts=5, backoff_s=0.01, backoff_max_s=0.02,
                     crash_loop_window_s=0.0, crash_loop_threshold=3)


def _script_child(tmp_path, body, name="c", **kw):
    """A SupervisedChild running `python -c body` with a tmp marker dir
    available as MARK (scripts use it to behave differently per run)."""
    code = f"import os, sys; MARK = {str(tmp_path)!r}\n" + body
    return SupervisedChild(name, [sys.executable, "-c", code],
                           env=child_env(), **kw)


def test_crash_then_recover_counts_restart_and_latency(tmp_path):
    body = (
        "m = os.path.join(MARK, 'once')\n"
        "if not os.path.exists(m):\n"
        "    open(m, 'w').close(); print('boom', flush=True); sys.exit(9)\n"
        "print('READY now', flush=True)\n")
    c = _script_child(tmp_path, body,
                      policy=RestartPolicy(max_restarts=3, backoff_s=0.01,
                                           backoff_max_s=0.02,
                                           crash_loop_window_s=0.0),
                      ready_pattern=r"^READY ")
    c.start()
    assert c.wait(timeout=30.0)
    assert c.success and c.restarts == 1 and c.incarnation == 1
    assert c.terminal_reason == "clean exit"
    lats = c.restart_latencies()
    assert len(lats) == 1 and lats[0] > 0.0
    kinds = [e.kind for e in c.events]
    assert kinds == ["spawn", "exit", "spawn", "ready", "exit", "terminal"]


@pytest.mark.parametrize("rc", [0, 4])
def test_terminal_codes_never_respawn(tmp_path, rc):
    c = _script_child(tmp_path, f"sys.exit({rc})", policy=FAST,
                      terminal_codes=(0, 4))
    c.start()
    assert c.wait(timeout=30.0)
    assert c.returncode == rc and c.restarts == 0 and c.incarnation == 0
    if rc == 0:
        assert c.terminal_reason == "clean exit"
    else:
        assert "terminal exit code 4" in c.terminal_reason


def test_crash_loop_goes_terminal_with_diagnostic(tmp_path):
    # dies instantly every time; window 3s >> child lifetime
    c = _script_child(tmp_path, "print('dying fast', flush=True)\n"
                                "sys.exit(9)",
                      policy=RestartPolicy(max_restarts=50, backoff_s=0.01,
                                           backoff_max_s=0.02,
                                           crash_loop_window_s=30.0,
                                           crash_loop_threshold=3))
    c.start()
    assert c.wait(timeout=60.0)
    assert not c.success
    assert "crash loop" in c.terminal_reason
    assert "dying fast" in c.terminal_reason     # last output attached
    assert c.incarnation == 2                    # 3 deaths total, no 4th


def test_restart_budget_exhausted(tmp_path):
    c = _script_child(tmp_path, "sys.exit(9)",
                      policy=RestartPolicy(max_restarts=2, backoff_s=0.01,
                                           backoff_max_s=0.02,
                                           crash_loop_window_s=0.0))
    c.start()
    assert c.wait(timeout=30.0)
    assert "restart budget exhausted" in c.terminal_reason
    assert c.restarts == 2 and c.incarnation == 2


def test_argv_for_incarnation_strips_crash_switch(tmp_path):
    # the script crashes iff its argv carries --die; argv_for only passes
    # --die on incarnation 0 — exactly how the chaos bench arms kills
    body = ("print('run', sys.argv[1:], flush=True)\n"
            "sys.exit(9 if '--die' in sys.argv else 0)\n")
    code = f"import os, sys; MARK = {str(tmp_path)!r}\n" + body
    seen = []

    def argv_for(incarnation):
        seen.append(incarnation)
        extra = ["--die"] if incarnation == 0 else []
        return [sys.executable, "-c", code] + extra

    c = SupervisedChild("armed", argv_for, policy=FAST, env=child_env())
    c.start()
    assert c.wait(timeout=30.0)
    assert c.success and c.restarts == 1
    assert seen == [0, 1]


def test_stop_tears_down_running_child(tmp_path):
    c = _script_child(tmp_path,
                      "import time\nprint('READY', flush=True)\n"
                      "time.sleep(600)", policy=FAST,
                      ready_pattern=r"^READY")
    c.start()
    for _ in range(200):
        if any(e.kind == "ready" for e in c.events):
            break
        import time
        time.sleep(0.05)
    c.stop()
    assert c.wait(timeout=10.0)
    assert c.terminal_reason == "stopped"


def test_supervisor_groups_children_and_summarizes(tmp_path):
    sup = Supervisor()
    sup.spawn("ok", [sys.executable, "-c", "print('fine')"], policy=FAST)
    sup.spawn("bad", [sys.executable, "-c", "import sys; sys.exit(4)"],
              policy=FAST)
    sup.start()
    assert sup.wait(timeout=30.0)
    s = sup.summary()
    assert s["ok"]["returncode"] == 0 and s["bad"]["returncode"] == 4
    assert s["bad"]["restarts"] == 0
    sup.stop()


def test_backoff_jitter_is_seeded_deterministic():
    a = SupervisedChild("a", ["true"], policy=RestartPolicy(jitter_seed=23))
    b = SupervisedChild("b", ["true"], policy=RestartPolicy(jitter_seed=23))
    ja = [float(a._jitter.random()) for _ in range(8)]
    jb = [float(b._jitter.random()) for _ in range(8)]
    assert ja == jb


def test_free_port_is_bindable_and_helpers():
    import socket
    p = free_port()
    s = socket.socket()
    s.bind(("127.0.0.1", p))
    s.close()
    argv = python_argv("repro.launch.two_party", "--role", "A")
    assert argv[0] == sys.executable and argv[1:3] == ["-m",
                                                      "repro.launch.two_party"]
    env = child_env({"X_MARK": "1"})
    assert env["X_MARK"] == "1"


def test_restart_latencies_without_readiness_use_spawn():
    evs = [ChildEvent("spawn", 1.0, 0), ChildEvent("exit", 2.0, 0),
           ChildEvent("spawn", 2.5, 1), ChildEvent("exit", 4.0, 1)]
    c = SupervisedChild("x", ["true"])
    c.events = evs
    assert c.restart_latencies() == [0.5]
