"""Observability tests (DESIGN.md §15).

Load-bearing properties: (1) a disabled tracer's span() is the shared
no-op object — nothing recorded, nothing allocated per call; (2) enabled
spans carry epoch timestamps, durations, thread lanes, and the ambient
trace id, and export as loadable Chrome-trace JSON; (3) the wire frame
trace-id extension is backward compatible — traceless frames are
byte-identical to the pre-trace format and keyed/unkeyed rejection is
unchanged; (4) the metrics registry's CommLog gauges ARE the CommLog —
snapshot equality is exact, not approximate; (5) a rid-pinned retry wave
across a seeded faulty wire yields EXACTLY ONE server-side request span,
and the client + server span files merge into one consistent timeline
joined by the trace id; (6) FrameDecoder error paths tally into the
registry.
"""
import json
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core.channel import (CommLog, FaultyTransport, FrameCorrupt,
                                FrameDecoder, LoopbackTransport, T_SCORE,
                                decode_frame, encode_frame, session_key)
from repro.core.fraud import FraudDataset
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.serve import ScoringClient, ScoringServer, ScoringService

D_A = D_B = 4
K = 3


@pytest.fixture(scope="module")
def fitted():
    ds = FraudDataset.synthesize(n=200, d_a=D_A, d_b=D_B, n_clusters=K,
                                 seed=0)
    km = SecureKMeans(KMeansConfig(k=K, iters=2, seed=0, offline="pooled"))
    res = km.fit(ds.x_a, ds.x_b)
    return km, res


@pytest.fixture()
def global_tracer():
    """The process-global tracer, returned enabled and restored after."""
    t = _trace.get_tracer()
    was = (t.enabled, t.process)
    t.reset()
    _trace.configure(enabled=True, process="server")
    yield t
    _trace.configure(enabled=was[0], process=was[1])
    t.reset()


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    t = _trace.Tracer(enabled=False)
    s1 = t.span("a", iter=1)
    s2 = t.span("b")
    assert s1 is s2                      # one module-level no-op object
    with s1:
        pass
    t.instant("c")
    t.complete_span("d", 0, 10)
    assert t.events() == []


def test_enabled_span_records_ts_dur_thread_args():
    t = _trace.Tracer(enabled=True)
    before = time.time_ns() // 1_000
    with t.span("fit.s1_launch", iter=3):
        time.sleep(0.002)
    (e,) = t.events()
    assert e["name"] == "fit.s1_launch" and e["ph"] == "X"
    assert e["args"]["iter"] == 3
    assert e["ts"] >= before
    assert e["dur"] >= 1_000             # slept 2ms, recorded in us
    assert e["tid"] == threading.get_ident()
    assert t.span_counts() == {"fit.s1_launch": 1}


def test_ambient_trace_id_tags_spans():
    t = _trace.Tracer(enabled=True)
    tid = _trace.new_trace_id()
    assert _trace.trace_id_from_bytes(_trace.trace_id_to_bytes(tid)) == tid
    _trace.set_current_trace(tid)
    try:
        with t.span("serve.resolve", rid=1):
            pass
        t.instant("serve.admit", rid=1)
    finally:
        _trace.set_current_trace(None)
    with t.span("untraced"):
        pass
    tagged = t.spans_for_trace(tid)
    assert {e["name"] for e in tagged} == {"serve.resolve", "serve.admit"}


def test_max_events_drops_newest_and_counts():
    t = _trace.Tracer(enabled=True, max_events=2)
    for i in range(5):
        with t.span(f"s{i}"):
            pass
    assert len(t.events()) == 2 and t.dropped == 3
    assert "dropped" in t.flame_summary()


def test_export_chrome_loadable_with_lanes(tmp_path):
    t = _trace.Tracer(enabled=True, process="party_a")
    with t.span("pipeline.launch", iter=0):
        pass

    def other():
        with t.span("pipeline.pre", iter=1):
            pass

    th = threading.Thread(target=other, name="pipeline-worker")
    th.start()
    th.join()
    path = tmp_path / "trace.json"
    t.export_chrome(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs if e["ph"] == "M"}
    assert "process_name" in names and "thread_name" in names
    pmeta = [e for e in evs if e["ph"] == "M"
             and e["name"] == "process_name"]
    assert pmeta[0]["args"]["name"] == "party_a"
    lanes = {e["tid"] for e in evs if e["ph"] == "X"}
    assert len(lanes) == 2               # two thread lanes visible
    cats = {e.get("cat") for e in evs if e["ph"] == "X"}
    assert cats == {"pipeline"}


def test_merge_traces_two_files_distinct_pids(tmp_path):
    ta = _trace.Tracer(enabled=True, process="client")
    tb = _trace.Tracer(enabled=True, process="server")
    with ta.span("client.score", rid=0):
        with tb.span("serve.resolve", rid=0):
            pass
    fa, fb = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    ta.export_chrome(fa)
    tb.export_chrome(fb)
    doc = _trace.merge_traces([fa, fb], str(tmp_path / "m.json"))
    evs = doc["traceEvents"]
    assert {e["pid"] for e in evs} == {1, 2}
    pnames = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert pnames == {"client", "server"}
    reread = json.loads((tmp_path / "m.json").read_text())
    assert len(reread["traceEvents"]) == len(evs)


# ---------------------------------------------------------------------------
# wire frame trace-id extension
# ---------------------------------------------------------------------------

def test_traceless_frames_byte_identical_to_pre_trace_format():
    # no trace id -> emitted bytes must be EXACTLY the PR-8 format, keyed
    # and unkeyed alike: old and new endpoints interoperate frame-for-frame
    key = session_key("compat")
    for k in (None, key):
        f = encode_frame(T_SCORE, 7, b"hello", key=k)
        assert decode_frame(f, key=k) == (T_SCORE, 7, b"hello")
        ft, seq, payload, tid = decode_frame(f, key=k, with_trace=True)
        assert (ft, seq, payload, tid) == (T_SCORE, 7, b"hello", None)


def test_traced_frame_roundtrip_and_mac_coverage():
    key = session_key("traced")
    raw = _trace.trace_id_to_bytes(_trace.new_trace_id())
    f = encode_frame(T_SCORE, 3, b"pay", key=key, trace_id=raw)
    ft, seq, payload, tid = decode_frame(f, key=key, with_trace=True)
    assert (ft, seq, payload, tid) == (T_SCORE, 3, b"pay", raw)
    # the id sits under the MAC: flipping one of its bits is tampering
    bad = bytearray(f)
    bad[21] ^= 1                          # first trace-id byte
    with pytest.raises(FrameCorrupt):
        decode_frame(bytes(bad), key=key, with_trace=True)
    with pytest.raises(ValueError):
        encode_frame(T_SCORE, 0, b"", trace_id=b"short")


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram_snapshot():
    reg = _metrics.MetricsRegistry()
    c = reg.counter("repro_frame_crc_errors_total")
    assert c is reg.counter("repro_frame_crc_errors_total")  # get-or-create
    c.inc()
    c.inc(2)
    reg.gauge("repro_bank_stock_copies", labels={"key": "r16"}).set(4)
    h = reg.histogram("repro_latency_ms", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["repro_frame_crc_errors_total"] == 3
    assert snap['repro_bank_stock_copies{key="r16"}'] == 4
    hist = snap["repro_latency_ms"]
    assert hist["count"] == 4 and hist["sum"] == pytest.approx(555.5)
    text = reg.render_prometheus()
    assert "# TYPE repro_frame_crc_errors_total counter" in text
    assert 'repro_bank_stock_copies{key="r16"} 4' in text
    assert 'repro_latency_ms_bucket{le="10.0"} 2' in text
    assert "repro_latency_ms_count 4" in text


def test_callback_gauge_reads_live_and_survives_errors():
    reg = _metrics.MetricsRegistry()
    box = {"v": 1}
    reg.gauge("g", fn=lambda: box["v"])
    assert reg.snapshot()["g"] == 1
    box["v"] = 7
    assert reg.snapshot()["g"] == 7       # read at query time, no cache

    def boom():
        raise RuntimeError("down")

    reg.gauge("bad", fn=boom)
    assert np.isnan(reg.snapshot()["bad"])


def test_registry_commlog_equality_is_exact(fitted):
    """Acceptance pin: the registry's online-bytes answer EQUALS
    CommLog.total_bytes('online') — same object, zero drift."""
    _, res = fitted
    reg = _metrics.MetricsRegistry()
    _metrics.register_commlog(res.log, registry=reg)
    snap = reg.snapshot()
    assert snap['repro_comm_bytes_total{phase="online"}'] == \
        res.log.total_bytes("online")
    assert snap['repro_comm_rounds_total{phase="online"}'] == \
        res.log.total_rounds("online")
    assert res.log.total_bytes("online") > 0


def test_metrics_http_endpoint_serves_prometheus_text():
    reg = _metrics.MetricsRegistry()
    reg.counter("repro_requests_total").inc(5)
    srv = _metrics.MetricsServer(port=0, registry=reg)
    srv.start()
    try:
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{srv.port}/metrics", timeout=5).read()
    finally:
        srv.stop()
    assert b"repro_requests_total 5" in body


def test_frame_decoder_errors_route_to_registry():
    reg = _metrics.get_registry()

    def val(name):
        return reg.snapshot().get(name, 0)

    crc0 = val("repro_frame_crc_errors_total")
    auth0 = val("repro_frame_auth_errors_total")
    rs0 = val("repro_frame_resync_events_total")
    dec = FrameDecoder()
    good = encode_frame(T_SCORE, 0, b"x")
    bad = bytearray(good)
    bad[-1] ^= 1
    assert dec.feed(bytes(bad)) == []
    assert val("repro_frame_crc_errors_total") == crc0 + 1
    kdec = FrameDecoder(key=session_key("k"))
    assert kdec.feed(encode_frame(T_SCORE, 1, b"y")) == []    # unkeyed
    assert val("repro_frame_auth_errors_total") == auth0 + 1
    assert val("repro_frame_resync_events_total") == rs0


# ---------------------------------------------------------------------------
# distributed request trace across a faulty wire (satellite 3)
# ---------------------------------------------------------------------------

def test_retry_wave_single_server_span_and_merged_timeline(
        fitted, global_tracer, tmp_path):
    """Drop/dup chaos on the client's send side: the rid-pinned request
    crosses the wire several times, yet the server records EXACTLY ONE
    serve.request span for the trace id, and the client + server span
    files merge into one timeline where the server work nests inside the
    client span."""
    km, res = fitted
    arr = FraudDataset.synthesize(n=8, d_a=D_A, d_b=D_B, n_clusters=K,
                                  seed=3)
    key = session_key("obs-trace")
    ta, tb = LoopbackTransport.pair()
    ft = FaultyTransport(ta, seed=9, drop=0.25, dup=0.25)
    svc = ScoringService(km, res, d_a=D_A, d_b=D_B, with_scores=True,
                         rungs=(16,), provision_copies=4)
    server = ScoringServer(svc, tb, idle_timeout_s=30.0, auth_key=key)
    th = threading.Thread(target=server.serve_forever, daemon=True)
    th.start()
    client_tracer = _trace.Tracer(enabled=True, process="client")
    client = ScoringClient(ft, auth_key=key, deadline_s=20.0,
                           tracer=client_tracer)
    r = client.score(arr.x_a, arr.x_b)
    client.bye()
    th.join(timeout=30)
    assert r.error is None
    assert ft.faults.dropped + ft.faults.duplicated > 0   # chaos happened

    cl = [e for e in client_tracer.events()
          if e["name"] == "client.score"]
    assert len(cl) == 1
    tid = cl[0]["args"]["trace"]
    sv = global_tracer.spans_for_trace(tid)
    reqs = [e for e in sv if e["name"] == "serve.request"]
    assert len(reqs) == 1                 # exactly once, chaos or not
    assert reqs[0]["args"]["rid"] == r.request_id
    # admission + resolve happened under the SAME propagated id
    assert {"serve.resolve", "serve.admit"} <= {e["name"] for e in sv}
    # server-side work nests inside the client span on the shared clock
    c0 = cl[0]["ts"]
    c1 = c0 + cl[0]["dur"]
    assert c0 <= reqs[0]["ts"] <= c1
    assert reqs[0]["ts"] + reqs[0]["dur"] <= c1 + 1_000   # 1ms slack

    fa, fb = str(tmp_path / "client.json"), str(tmp_path / "server.json")
    client_tracer.export_chrome(fa)
    global_tracer.export_chrome(fb)
    doc = _trace.merge_traces([fa, fb], str(tmp_path / "merged.json"))
    evs = doc["traceEvents"]
    joined = [e for e in evs if e.get("args", {}).get("trace") == tid]
    assert {e["pid"] for e in joined} == {1, 2}  # both endpoints, one id
    pnames = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"client", "server"} <= pnames


# ---------------------------------------------------------------------------
# ServiceStats: latency split under one lock
# ---------------------------------------------------------------------------

def test_stats_latency_split_and_quantiles():
    from repro.serve import ServiceStats
    st = ServiceStats()
    for i in range(1, 101):
        st.record_latency(i / 1000, queue_wait=i / 4000, inflight=i / 2000)
    d = st.as_dict()
    assert d["p50_ms"] == pytest.approx(
        float(np.quantile(np.arange(1, 101) / 1000, 0.5)) * 1e3)
    assert d["queue_wait_p50_ms"] == pytest.approx(d["p50_ms"] / 4)
    assert d["inflight_p50_ms"] == pytest.approx(d["p50_ms"] / 2)
    assert d["queue_wait_p99_ms"] <= d["p99_ms"]
    assert len(st.latencies) == len(st.queue_waits) == len(st.inflights)


def test_stats_concurrent_recording_consistent():
    from repro.serve import ServiceStats
    st = ServiceStats()

    def pump():
        for _ in range(500):
            st.record_latency(0.001, queue_wait=0.0005, inflight=0.0005)

    ts = [threading.Thread(target=pump) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # windows are bounded deques, all fed under ONE lock: same length
    assert len(st.latencies) == len(st.queue_waits) == len(st.inflights)
    assert st.latency_quantile(0.5) == pytest.approx(0.001)


# ---------------------------------------------------------------------------
# bank gauges + stats line
# ---------------------------------------------------------------------------

def test_register_bank_and_stats_line(fitted):
    km, res = fitted
    svc = ScoringService(km, res, d_a=D_A, d_b=D_B, with_scores=True,
                         rungs=(16,), provision_copies=3)
    svc.warm()                            # registers service + bank gauges
    reg = _metrics.get_registry()
    snap = reg.snapshot()
    stocks = {k: v for k, v in snap.items()
              if k.startswith("repro_bank_stock_copies")}
    assert stocks and all(v >= 0 for v in stocks.values())
    line = _metrics.StatsLineLogger(svc, bank=svc.bank).render()
    assert "bank_stock" in line and "p99" in line
    arr = FraudDataset.synthesize(n=8, d_a=D_A, d_b=D_B, n_clusters=K,
                                  seed=5)
    svc.submit(arr.x_a, arr.x_b)
    svc.drain()
    snap2 = reg.snapshot()
    assert snap2["repro_serve_requests"] >= 1
    assert snap2["repro_bank_consumed_requests_total"] >= 1


# ---------------------------------------------------------------------------
# bounded-memory tracing: rotation + sampling (DESIGN.md §16 satellite)
# ---------------------------------------------------------------------------

def test_rotate_spans_keeps_newest_n_per_category():
    t = _trace.Tracer(enabled=True, rotate_spans=2)
    for i in range(5):
        with t.span("fit.iter", i=i):
            pass
    for i in range(3):
        with t.span("serve.request", i=i):
            pass
    evs = t.events()
    fit = [e["args"]["i"] for e in evs if e["name"] == "fit.iter"]
    srv = [e["args"]["i"] for e in evs if e["name"] == "serve.request"]
    assert fit == [3, 4]                 # newest 2, old fit spans evicted
    assert srv == [1, 2]                 # per-category: serve has its own 2
    assert t.rotated_out == 3 + 1
    # rotation shows up in every aggregate view
    assert t.span_counts() == {"fit.iter": 2, "serve.request": 2}
    assert "rotated out" in t.flame_summary()


def test_sample_rate_is_deterministic_counter_not_rng():
    a = _trace.Tracer(enabled=True, sample_rate=0.25)
    b = _trace.Tracer(enabled=True, sample_rate=0.25)
    for t in (a, b):
        for i in range(16):
            with t.span("wire.request", i=i):
                pass
    ia = [e["args"]["i"] for e in a.events()]
    ib = [e["args"]["i"] for e in b.events()]
    assert ia == ib == [0, 4, 8, 12]     # every 4th, from the first
    assert a.sampled_out == 12


def test_sampling_counters_are_per_category():
    t = _trace.Tracer(enabled=True, sample_rate=0.5)
    with t.span("fit.a"):
        pass
    with t.span("serve.b"):
        pass                             # different category: own counter
    assert t.span_counts() == {"fit.a": 1, "serve.b": 1}


def test_bounds_validation():
    with pytest.raises(ValueError):
        _trace.Tracer(rotate_spans=0)
    with pytest.raises(ValueError):
        _trace.Tracer(sample_rate=0.0)
    with pytest.raises(ValueError):
        _trace.Tracer(sample_rate=1.5)


def test_disabled_noop_path_unchanged_by_bounds():
    """Pin: rotation/sampling must not touch the disabled fast path —
    span() still returns the ONE shared no-op object, and nothing is
    recorded or counted."""
    t = _trace.Tracer(enabled=False, rotate_spans=4, sample_rate=0.1)
    s1 = t.span("a")
    s2 = t.span("b", x=1)
    assert s1 is s2 is _trace._NOOP
    with s1:
        pass
    t.instant("c")
    assert t.events() == [] and t.sampled_out == 0 and t.rotated_out == 0


def test_configure_global_bounds_roundtrip(global_tracer):
    _trace.configure(rotate_spans=3, sample_rate=1.0)
    try:
        for i in range(7):
            with _trace.span("fit.x", i=i):
                pass
        assert [e["args"]["i"] for e in global_tracer.events()
                if e["name"] == "fit.x"] == [4, 5, 6]
    finally:
        global_tracer.configure_bounds()  # restore unbounded defaults


# ---------------------------------------------------------------------------
# latency histograms: fixed log-spaced buckets (DESIGN.md §16 satellite)
# ---------------------------------------------------------------------------

def test_log_buckets_fixed_edges():
    edges = _metrics.log_buckets(1e-3, 10.0, per_decade=3)
    assert edges[0] == pytest.approx(1e-3)
    assert 10.0 in edges
    ratios = [edges[i + 1] / edges[i] for i in range(len(edges) - 1)]
    assert all(r == pytest.approx(10 ** (1 / 3), rel=1e-6) for r in ratios)
    # identical every call — dashboards can rely on stable bucket labels
    assert _metrics.log_buckets(1e-3, 10.0, per_decade=3) == edges
    with pytest.raises(ValueError):
        _metrics.log_buckets(0.0, 1.0)
    with pytest.raises(ValueError):
        _metrics.log_buckets(2.0, 1.0)


def _hist_count(snap, name):
    h = snap.get(name)
    return 0 if h is None else h["count"]


def test_wire_rtt_and_backoff_histograms_record():
    from repro.core.channel import ReliableChannel, serve_peer
    reg = _metrics.get_registry()
    before = reg.snapshot()
    ta, tb = LoopbackTransport.pair()
    th = threading.Thread(target=serve_peer, args=(tb,),
                          kwargs={"idle_timeout_s": 30.0}, daemon=True)
    th.start()
    from repro.core.channel import WireSession
    ws = WireSession(ReliableChannel(ta, deadline_s=10.0))
    ws.exchange(64, 2)
    ws.bye()
    th.join(timeout=10)
    after = reg.snapshot()
    d_rtt = _hist_count(after, "repro_wire_request_seconds") - \
        _hist_count(before, "repro_wire_request_seconds")
    assert d_rtt >= 3                    # 2 exchange rounds + bye
    # fixed log-spaced edges are what render in the exposition
    text = reg.render_prometheus()
    assert 'repro_wire_request_seconds_bucket{le="1e-05"}' in text or \
        'repro_wire_request_seconds_bucket{le="1.0"}' in text


def test_fit_iteration_histogram_records_per_iteration():
    reg = _metrics.get_registry()
    before = _hist_count(reg.snapshot(), "repro_fit_iteration_seconds")
    ds = FraudDataset.synthesize(n=96, d_a=D_A, d_b=D_B, n_clusters=K,
                                 seed=2)
    km = SecureKMeans(KMeansConfig(k=K, iters=3, seed=2, offline="pooled"))
    km.fit(ds.x_a, ds.x_b)
    after = _hist_count(reg.snapshot(), "repro_fit_iteration_seconds")
    assert after - before == 3


# ---------------------------------------------------------------------------
# /health endpoint (DESIGN.md §16)
# ---------------------------------------------------------------------------

def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=5) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


def test_health_route_reflects_callback_state():
    state = {"v": "STARTING"}
    srv = _metrics.MetricsServer(port=0, registry=_metrics.MetricsRegistry(),
                                 health_cb=lambda: state["v"])
    srv.start()
    base = f"http://127.0.0.1:{srv.port}"
    try:
        code, body = _get(base + "/health")
        assert code == 503 and "STARTING" in body
        state["v"] = "READY"
        code, body = _get(base + "/health")
        assert code == 200 and body.strip() == "READY"
        for s in ("DEGRADED", "DRAINING"):
            state["v"] = s
            code, body = _get(base + "/health")
            assert code == 503 and s in body
    finally:
        srv.stop()


def test_health_route_404_without_callback_and_cb_error_is_503():
    srv = _metrics.MetricsServer(port=0, registry=_metrics.MetricsRegistry())
    srv.start()
    try:
        code, _ = _get(f"http://127.0.0.1:{srv.port}/health")
        assert code == 404
    finally:
        srv.stop()

    def boom():
        raise RuntimeError("probe exploded")

    srv = _metrics.MetricsServer(port=0, registry=_metrics.MetricsRegistry(),
                                 health_cb=boom)
    srv.start()
    try:
        code, body = _get(f"http://127.0.0.1:{srv.port}/health")
        assert code == 503 and "DEGRADED" in body
    finally:
        srv.stop()
