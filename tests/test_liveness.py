"""Liveness-budget tests for the reliable wire (DESIGN.md §16).

Property-based (hypothesis when installed, the deterministic fallback
otherwise): across arbitrary interleavings of connection tears, receive
timeouts, and eventual delivery, a `ReliableChannel` request

* NEVER livelocks — total peer silence is bounded by
  ``deadline + park budget`` (plus scheduling slack), even when every
  redial succeeds and every window tears again (the pathological
  reconnect loop the park budget must not unbound);
* NEVER dies prematurely — the failure is raised no earlier than the
  deadline, and a response that arrives within the budget is returned,
  not discarded;
* and the responder's idle budget bounds B's total peer silence the
  same way (a dead engine cannot spin `serve_forever` forever).
"""
import time

import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core.channel import (RESP_BIT, ReliableChannel, Responder,
                                T_EXCHANGE, WireError, WireTimeout,
                                decode_frame, encode_frame)

# scripted fates, one per send attempt
OK, DROP, SEVER = "ok", "drop", "sever"


class ScriptedTransport:
    """A Transport whose per-send fate is a script: `ok` delivers and the
    response is receivable, `drop` loses the frame (recv times out),
    `sever` raises ConnectionError from send. Past the script's end the
    `tail` fate repeats forever. No real I/O, no sleeps — the channel's
    own clocks (try windows, backoff, park) drive all elapsed time."""

    def __init__(self, script, tail=SEVER):
        self.script = list(script)
        self.tail = tail
        self.sends = 0
        self.reconnects = 0
        self._inbox = []

    def _fate(self):
        i = self.sends
        self.sends += 1
        return self.script[i] if i < len(self.script) else self.tail

    def send_frame(self, frame):
        fate = self._fate()
        if fate == SEVER:
            raise ConnectionError("scripted sever")
        if fate == DROP:
            return
        ftype, seq, _payload, _tid = decode_frame(frame, with_trace=True)
        self._inbox.append(encode_frame(ftype | RESP_BIT, seq, b"pong"))

    def recv_frame(self, timeout=None):
        if self._inbox:
            return self._inbox.pop(0)
        raise TimeoutError("scripted silence")

    def reconnect(self):
        self.reconnects += 1

    def close(self):
        pass


def _channel(t, deadline, park):
    # huge retry budget so the TIME budgets are what terminate the loop
    return ReliableChannel(t, deadline_s=deadline, try_timeout_s=0.01,
                           max_retries=10_000, backoff_s=0.001,
                           backoff_max_s=0.01, reconnect_wait_s=park)


@given(st.lists(st.sampled_from([DROP, SEVER]), min_size=0, max_size=12),
       st.sampled_from([DROP, SEVER]),
       st.floats(min_value=0.0, max_value=0.25))
@settings(max_examples=25, deadline=None)
def test_total_silence_is_bounded_no_livelock_no_early_death(
        prefix, tail, park):
    """All-failure schedules: the request must fail, no earlier than the
    deadline and no later than deadline + park + slack — for EVERY
    interleaving of drops and severs, parked or not."""
    deadline = 0.25
    t = ScriptedTransport(prefix, tail=tail)
    chan = _channel(t, deadline, park)
    t0 = time.monotonic()
    with pytest.raises((WireTimeout, WireError)):
        chan.request(T_EXCHANGE, b"x")
    elapsed = time.monotonic() - t0
    assert elapsed >= deadline - 0.02, \
        f"died prematurely after {elapsed:.3f}s (deadline {deadline}s)"
    assert elapsed <= deadline + park + 1.0, \
        f"livelock: {elapsed:.3f}s > deadline+park ({deadline}+{park}s)"


@given(st.lists(st.sampled_from([DROP, SEVER]), min_size=0, max_size=8))
@settings(max_examples=25, deadline=None)
def test_delivery_within_budget_always_succeeds(prefix):
    """Any failure prefix short enough to leave budget must NOT kill the
    request: the eventual delivery is returned."""
    t = ScriptedTransport(list(prefix) + [OK], tail=OK)
    chan = _channel(t, deadline=10.0, park=10.0)
    assert chan.request(T_EXCHANGE, b"x") == b"pong"


def test_park_budget_not_consumed_by_clean_requests():
    """Parking is per-request and only on tears: a clean request after a
    parked one starts with the full budget again."""
    t = ScriptedTransport([SEVER, SEVER, OK, OK], tail=OK)
    chan = _channel(t, deadline=5.0, park=5.0)
    assert chan.request(T_EXCHANGE, b"a") == b"pong"
    parked_first = chan.parked_s
    assert parked_first > 0.0
    assert chan.request(T_EXCHANGE, b"b") == b"pong"
    assert chan.parked_s == parked_first     # no parking without a tear


def test_zero_park_budget_keeps_legacy_fail_fast():
    """reconnect_wait_s=0 (the default): tears charge the retry budget
    immediately — the unsupervised deployments' fail-fast behaviour."""
    t = ScriptedTransport([], tail=SEVER)
    chan = ReliableChannel(t, deadline_s=30.0, try_timeout_s=0.01,
                           max_retries=3, backoff_s=0.001,
                           backoff_max_s=0.002)
    t0 = time.monotonic()
    with pytest.raises(WireError, match="retries exhausted"):
        chan.request(T_EXCHANGE, b"x")
    assert time.monotonic() - t0 < 1.0
    assert chan.parked_s == 0.0


class DeadEngineTransport:
    """Responder-side fake: the engine is gone — every recv tears."""

    def __init__(self):
        self.reconnects = 0

    def recv_frame(self, timeout=None):
        raise ConnectionError("peer gone")

    def send_frame(self, frame):
        raise ConnectionError("peer gone")

    def reconnect(self):
        self.reconnects += 1

    def close(self):
        pass


def test_responder_idle_budget_bounds_dead_engine_spin():
    """B's serve loop must not livelock redialing a dead engine: total
    silence is capped by idle_timeout_s even though every recv raises
    ConnectionError (never TimeoutError)."""
    t = DeadEngineTransport()
    r = Responder(t, handler=lambda ftype, payload: b"", idle_timeout_s=0.3)
    t0 = time.monotonic()
    with pytest.raises(WireTimeout, match="silent"):
        r.serve_forever()
    elapsed = time.monotonic() - t0
    assert 0.25 <= elapsed <= 5.0
    assert t.reconnects > 0
