"""System tests: secure K-means vs plaintext oracle; Protocol 2; HE; fraud."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import protocol as P
from repro.core import ring
from repro.core.he import Paillier, SimulatedPHE
from repro.core.kmeans import (KMeansConfig, SecureKMeans, plaintext_kmeans)
from repro.core.fraud import (FraudDataset, jaccard, run_plaintext_fraud,
                              run_secure_fraud)
from repro.core.sharing import AShare, rec, share
from repro.core.sparse import (CSRMatrix, dense_ss_matmul_comm_bytes,
                               secure_sparse_matmul, sparse_matmul_comm_bytes)


def make_blobs(n, d, k, seed=0, sparse_frac=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-4, 4, (k, d))
    lab = rng.integers(0, k, n)
    x = centers[lab] + rng.normal(0, 0.4, (n, d))
    if sparse_frac:
        x = x * (rng.random((n, d)) >= sparse_frac)
    return x


def _match_labels(sec, ref, k):
    """Accuracy up to cluster permutation (greedy matching)."""
    best = 0.0
    from itertools import permutations
    for perm in permutations(range(k)):
        best = max(best, (np.asarray(perm)[sec] == ref).mean())
    return best


# ---------------------------------------------------------------------------
# secure == plaintext (both partitions, dense + sparse)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
def test_secure_matches_plaintext(partition):
    n, d, k = 240, 6, 3
    x = make_blobs(n, d, k, seed=1)
    if partition == "vertical":
        a, b = x[:, :3], x[:, 3:]
    else:
        a, b = x[:120], x[120:]
    res = SecureKMeans(KMeansConfig(k=k, iters=8, partition=partition,
                                    seed=3)).fit(a, b)
    _, lab_ref = plaintext_kmeans(x, k, 8, seed=3)
    assert (res.labels_plain() == lab_ref).mean() > 0.99


def test_sparse_path_matches_dense_path():
    x = make_blobs(150, 8, 3, seed=2, sparse_frac=0.6)
    a, b = x[:, :4], x[:, 4:]
    dense = SecureKMeans(KMeansConfig(k=3, iters=6, seed=5)).fit(a, b)
    sparse = SecureKMeans(KMeansConfig(k=3, iters=6, seed=5,
                                       sparse=True)).fit(a, b)
    assert (dense.labels_plain() == sparse.labels_plain()).mean() > 0.99
    np.testing.assert_allclose(dense.centroids_plain(),
                               sparse.centroids_plain(), atol=1e-3)


def test_sparse_real_paillier_end_to_end():
    x = make_blobs(30, 6, 2, seed=3, sparse_frac=0.5)
    res = SecureKMeans(KMeansConfig(k=2, iters=3, seed=7, sparse=True,
                                    he_backend=Paillier(512))
                       ).fit(x[:, :3], x[:, 3:])
    _, lab_ref = plaintext_kmeans(x, 2, 3, seed=7)
    assert (res.labels_plain() == lab_ref).mean() > 0.95


def test_convergence_early_stop():
    x = make_blobs(200, 4, 3, seed=4)
    res = SecureKMeans(KMeansConfig(k=3, iters=50, seed=5, tol=1e-6)
                       ).fit(x[:, :2], x[:, 2:])
    assert res.iters_run < 50


def test_empty_cluster_guard():
    """k > distinct points forces empty clusters; centroids must stay finite
    (secure CMP+MUX keeps the previous centroid)."""
    rng = np.random.default_rng(0)
    x = np.repeat(rng.uniform(-1, 1, (2, 4)), 20, axis=0)  # only 2 points
    res = SecureKMeans(KMeansConfig(k=5, iters=4, seed=1)).fit(x[:, :2], x[:, 2:])
    mu = res.centroids_plain()
    assert np.isfinite(mu).all()
    assert np.abs(mu).max() < 100.0


# ---------------------------------------------------------------------------
# communication properties (the paper's actual claims)
# ---------------------------------------------------------------------------

def test_online_offline_split_dominated_by_offline():
    """Fig 2: offline (triple generation) must dominate total traffic."""
    x = make_blobs(400, 4, 4, seed=6)
    res = SecureKMeans(KMeansConfig(k=4, iters=5, seed=2)).fit(x[:, :2], x[:, 2:])
    assert res.log.total_bytes("offline") > 5 * res.log.total_bytes("online")


def test_vectorized_rounds_much_smaller():
    """Fig 3: vectorization cuts rounds by orders of magnitude (same bytes)."""
    x = make_blobs(100, 6, 4, seed=7)
    vec = SecureKMeans(KMeansConfig(k=4, iters=2, seed=2)).fit(x[:, :3], x[:, 3:])
    nai = SecureKMeans(KMeansConfig(k=4, iters=2, seed=2,
                                    vectorized=False)).fit(x[:, :3], x[:, 3:])
    assert nai.log.total_rounds("online") > 20 * vec.log.total_rounds("online")
    assert nai.log.total_bytes("online") == vec.log.total_bytes("online")


def test_sparse_comm_beats_dense_at_high_dim():
    """Sec 4.3: Protocol 2 traffic independent of n*d; dense SS is not."""
    n, k = 4096, 4
    for d in (1 << 12, 1 << 14):
        p2 = sparse_matmul_comm_bytes(n, d, k)
        ss = dense_ss_matmul_comm_bytes(n, d, k)
        assert p2 < ss, (d, p2, ss)
    # and the crossover exists: tiny d favours dense SS
    assert sparse_matmul_comm_bytes(64, 2, 2) > dense_ss_matmul_comm_bytes(64, 2, 2)


# ---------------------------------------------------------------------------
# Protocol 2 property tests
# ---------------------------------------------------------------------------

@given(st.integers(2, 10), st.integers(2, 10), st.integers(1, 4),
       st.floats(0.0, 0.9))
@settings(deadline=None, max_examples=15)
def test_protocol2_random_shapes(n, d, k, sparsity):
    rng = np.random.default_rng(int(n * 1000 + d * 100 + k))
    xr = rng.uniform(-3, 3, (n, d)) * (rng.random((n, d)) >= sparsity)
    x = CSRMatrix.from_dense_real(xr)
    y_plain = rng.uniform(-3, 3, (d, k))
    ys = share(np.round(y_plain * (1 << ring.F)).astype(np.int64)
               .astype(np.uint64), rng)
    ctx = P.make_ctx(0)
    z = secure_sparse_matmul(ctx, x, np.asarray(ys.s1), SimulatedPHE())
    local = np.asarray(x.to_dense(), np.uint64) @ np.asarray(ys.s0)
    tot = AShare(z.s0 + local, z.s1)
    got = np.asarray(ring.decode(rec(P.trunc(tot, ring.F))))
    np.testing.assert_allclose(got, xr @ y_plain, atol=1e-3)


def test_protocol2_paillier_matches_simulated():
    rng = np.random.default_rng(9)
    xr = rng.uniform(-2, 2, (5, 7)) * (rng.random((5, 7)) > 0.5)
    x = CSRMatrix.from_dense_real(xr)
    yb = rng.integers(0, 1 << 63, (7, 3)).astype(np.uint64)
    for he in (SimulatedPHE(), Paillier(512)):
        z = secure_sparse_matmul(P.make_ctx(1), x, yb, he)
        want = np.asarray(x.to_dense(), np.uint64) @ yb
        got = np.asarray(rec(z), np.uint64)
        np.testing.assert_array_equal(got, want)


def test_paillier_homomorphism():
    he = Paillier(512)
    a, b, s = 123456789, 987654321, 42
    ct = he.encrypt(a) + he.encrypt(b)
    assert he.decrypt(ct) == a + b
    assert he.decrypt(s * he.encrypt(a)) == s * a
    # fresh randomness: same plaintext, different ciphertext
    assert he.encrypt(a).c != he.encrypt(a).c


def test_csr_roundtrip():
    rng = np.random.default_rng(10)
    x = (rng.random((13, 9)) > 0.6) * rng.integers(1, 100, (13, 9))
    m = CSRMatrix.from_dense(x.astype(np.uint64))
    np.testing.assert_array_equal(m.to_dense(), x.astype(np.uint64))
    assert m.nnz == (x != 0).sum()


def test_csr_transpose_matches_from_dense():
    """nnz-proportional transpose is layout-identical to densify+rebuild
    (the fast path pre-transposes X for the C^T X host exchange with it)."""
    rng = np.random.default_rng(11)
    for shape in [(13, 9), (1, 7), (8, 1), (6, 6)]:
        x = ((rng.random(shape) > 0.5)
             * rng.integers(1, 2**62, shape)).astype(np.uint64)
        a = CSRMatrix.from_dense(x).transpose()
        b = CSRMatrix.from_dense(x.T)
        assert a.shape == b.shape
        np.testing.assert_array_equal(a.indptr, b.indptr)
        np.testing.assert_array_equal(a.indices, b.indices)
        np.testing.assert_array_equal(a.data, b.data)


# ---------------------------------------------------------------------------
# fraud detection (Q5)
# ---------------------------------------------------------------------------

def test_fraud_jaccard_joint_beats_single_party():
    ds = FraudDataset.synthesize(n=800, d_a=6, d_b=8, seed=1)
    j_secure, _ = run_secure_fraud(ds, k=5, iters=6, seed=2)
    j_single = run_plaintext_fraud(ds, k=5, iters=6, seed=2, party_a_only=True)
    j_joint = run_plaintext_fraud(ds, k=5, iters=6, seed=2)
    assert j_secure > j_single          # paper: joint modelling wins
    assert abs(j_secure - j_joint) < 0.15  # secure ~ plaintext joint


def test_jaccard_bounds():
    r = np.zeros(10, bool); r[:3] = True
    assert jaccard(r, r) == 1.0
    assert jaccard(r, ~r) == 0.0
