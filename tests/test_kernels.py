"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.spmm import dense_to_ell

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# modmatmul: ring matmul sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,bits", [(np.uint32, 32), (np.uint64, 64)])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128),
                                   (100, 50, 30), (1, 200, 7), (129, 129, 129)])
def test_ring_matmul_sweep(dtype, bits, shape):
    n, d, k = shape
    a = RNG.integers(0, 1 << bits, (n, d), dtype=dtype)
    b = RNG.integers(0, 1 << bits, (d, k), dtype=dtype)
    got = np.asarray(ops.ring_matmul(jnp.asarray(a), jnp.asarray(b)))
    fn = ref.modmatmul_u32 if bits == 32 else ref.modmatmul_u64
    want = np.asarray(fn(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, want)


@given(st.integers(1, 300), st.integers(1, 300), st.integers(1, 100))
@settings(deadline=None, max_examples=8)
def test_ring_matmul_property(n, d, k):
    a = RNG.integers(0, 1 << 64, (n, d), dtype=np.uint64)
    b = RNG.integers(0, 1 << 64, (d, k), dtype=np.uint64)
    got = np.asarray(ops.ring_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.modmatmul_u64(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, want)


def test_ring_matmul_matches_beaver_semantics():
    """The kernel must be a drop-in for the protocol's jnp ring matmul."""
    from repro.core import ring
    a = RNG.integers(0, 1 << 64, (64, 32), dtype=np.uint64)
    b = RNG.integers(0, 1 << 64, (32, 16), dtype=np.uint64)
    got = np.asarray(ops.ring_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(jnp.matmul(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# fused ESD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 128, 128), (300, 40, 5),
                                   (1000, 2, 2), (57, 129, 17)])
def test_esd_sweep(shape):
    n, d, k = shape
    x = RNG.normal(0, 3, (n, d)).astype(np.float32)
    mu = RNG.normal(0, 3, (k, d)).astype(np.float32)
    got = np.asarray(ops.esd(jnp.asarray(x), jnp.asarray(mu)))
    want = np.asarray(ref.esd(jnp.asarray(x), jnp.asarray(mu)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_esd_argmin_matches_full_euclidean():
    """Dropping ||x||^2 must not change the argmin (paper Eq. 2)."""
    x = RNG.normal(0, 2, (200, 8)).astype(np.float32)
    mu = RNG.normal(0, 2, (5, 8)).astype(np.float32)
    dprime = np.asarray(ops.esd(jnp.asarray(x), jnp.asarray(mu)))
    full = ((x[:, None, :] - mu[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(dprime.argmin(1), full.argmin(1))


# ---------------------------------------------------------------------------
# argmin one-hot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 4), (1000, 7), (33, 2), (128, 256)])
def test_argmin_onehot_sweep(shape):
    d = RNG.normal(0, 10, shape).astype(np.float32)
    got = np.asarray(ops.argmin_onehot(jnp.asarray(d)))
    want = np.asarray(ref.argmin_onehot(jnp.asarray(d)))
    np.testing.assert_array_equal(got, want)


def test_argmin_onehot_ties_first_wins():
    d = np.zeros((8, 5), np.float32)  # all ties -> column 0
    got = np.asarray(ops.argmin_onehot(jnp.asarray(d)))
    assert (got[:, 0] == 1).all() and (got[:, 1:] == 0).all()


# ---------------------------------------------------------------------------
# blocked-ELL spmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9, 0.99])
@pytest.mark.parametrize("dtype", [np.float32, np.uint32])
def test_spmm_sweep(sparsity, dtype):
    n, d, k = 64, 512, 8
    mask = RNG.random((n, d)) >= sparsity
    if dtype == np.float32:
        x = (RNG.normal(0, 2, (n, d)) * mask).astype(np.float32)
        y = RNG.normal(0, 2, (d, k)).astype(np.float32)
    else:
        x = (RNG.integers(0, 1 << 32, (n, d), dtype=np.uint32) * mask)
        y = RNG.integers(0, 1 << 32, (d, k), dtype=np.uint32)
    got = np.asarray(ops.spmm_from_dense(x, jnp.asarray(y)))
    if dtype == np.float32:
        want = x @ y
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)
    else:
        want = np.einsum("ij,jk->ik", x.astype(np.uint32), y,
                         dtype=np.uint32, casting="unsafe")
        np.testing.assert_array_equal(got, want)


def test_spmm_ell_oracle_agrees():
    n, d, k = 40, 384, 4
    x = (RNG.normal(0, 1, (n, d)) * (RNG.random((n, d)) > 0.8)).astype(np.float32)
    y = RNG.normal(0, 1, (d, k)).astype(np.float32)
    blocks, idx, counts = dense_to_ell(x)
    want = np.asarray(ref.spmm_ell(jnp.asarray(blocks), jnp.asarray(idx),
                                   jnp.asarray(counts), jnp.asarray(y), n))
    got = np.asarray(ops.spmm(jnp.asarray(blocks), jnp.asarray(idx),
                              jnp.asarray(counts), jnp.asarray(y)))[:n]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_ell_packing_is_nnz_proportional():
    """The storage/compute win: blocks scale with density, not with n*d."""
    n, d = 256, 2048
    dense_blocks = dense_to_ell(np.ones((n, d), np.float32))[0]
    x = np.zeros((n, d), np.float32)
    x[:, :128] = 1.0  # one non-empty block column
    sparse_blocks = dense_to_ell(x)[0]
    assert sparse_blocks.shape[1] * sparse_blocks.shape[0] \
        < dense_blocks.shape[1] * dense_blocks.shape[0] / 8
