"""Unit + property tests for the MPC protocol layer (sharing, Beaver ops,
comparison, argmin, reciprocal, truncation)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import protocol as P
from repro.core import ring
from repro.core.sharing import (AShare, rec, rec_b, rec_real, share, share_b,
                                share_real)

RNG = np.random.default_rng(123)


def _ctx():
    return P.make_ctx(RNG.integers(1 << 30))


# ---------------------------------------------------------------------------
# sharing / fixed point
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1, max_size=32))
@settings(deadline=None, max_examples=50)
def test_share_reconstruct_roundtrip(xs):
    x = np.asarray(xs)
    a = share_real(x, np.random.default_rng(0))
    np.testing.assert_allclose(np.asarray(rec_real(a)), x, atol=2.0 ** -ring.F)


@given(st.integers(0, 2 ** 64 - 1))
@settings(deadline=None, max_examples=50)
def test_ring_share_exact(v):
    a = share(np.array([v], np.uint64), np.random.default_rng(1))
    assert int(np.asarray(rec(a))[0]) == v


def test_share_uniformity():
    """Shares of a constant must look uniform (the security property the
    whole protocol rests on): mean of share bytes ~ uniform."""
    rng = np.random.default_rng(7)
    a = share(np.zeros(20000, np.uint64), rng)
    s0 = np.asarray(a.s0)
    # each of the 8 bytes of the share should be ~uniform over [0,256)
    bytes_view = s0.view(np.uint8)
    hist = np.bincount(bytes_view, minlength=256)
    assert hist.min() > 0.8 * hist.mean()
    assert hist.max() < 1.2 * hist.mean()


def test_trunc_error_envelope():
    """SecureML local truncation: trunc(share(x * 2^2f), f) ~ x * 2^f with at
    most one LSB of error per lane."""
    rng = np.random.default_rng(3)
    x = rng.uniform(-1000, 1000, 5000)
    enc2 = np.round(x * (1 << (2 * ring.F))).astype(np.int64).astype(np.uint64)
    sh = share(enc2, rng)
    back = np.asarray(rec_real(P.trunc(sh, ring.F)))
    np.testing.assert_allclose(back, x, atol=2.0 ** -ring.F * 2)


# ---------------------------------------------------------------------------
# SMUL / matmul
# ---------------------------------------------------------------------------

@given(st.integers(1, 12), st.integers(1, 12))
@settings(deadline=None, max_examples=10)
def test_smul_elementwise(n, m):
    rng = np.random.default_rng(n * 100 + m)
    x = rng.uniform(-50, 50, (n, m))
    y = rng.uniform(-50, 50, (n, m))
    z = P.smul(_ctx(), share_real(x, rng), share_real(y, rng), trunc_f=ring.F)
    np.testing.assert_allclose(np.asarray(rec_real(z)), x * y,
                               atol=2.0 ** -ring.F * (np.abs(x).max() + 2))


def test_smul_broadcast():
    rng = np.random.default_rng(0)
    x = rng.uniform(-2, 2, (5, 1))
    y = rng.uniform(-2, 2, (1, 7))
    z = P.smul(_ctx(), share_real(x, rng), share_real(y, rng), trunc_f=ring.F)
    np.testing.assert_allclose(np.asarray(rec_real(z)), x * y, atol=1e-4)


@given(st.integers(1, 10), st.integers(1, 10), st.integers(1, 10))
@settings(deadline=None, max_examples=10)
def test_smatmul(n, d, k):
    rng = np.random.default_rng(n + 10 * d + 100 * k)
    a = rng.uniform(-5, 5, (n, d))
    b = rng.uniform(-5, 5, (d, k))
    z = P.smatmul(_ctx(), share_real(a, rng), share_real(b, rng), trunc_f=ring.F)
    np.testing.assert_allclose(np.asarray(rec_real(z)), a @ b,
                               atol=2.0 ** -ring.F * (d + 2) * 8)


def test_smatmul_comm_accounting():
    ctx = _ctx()
    rng = np.random.default_rng(5)
    a, b = rng.uniform(-1, 1, (64, 32)), rng.uniform(-1, 1, (32, 8))
    P.smatmul(ctx, share_real(a, rng), share_real(b, rng))
    # online: both parties exchange E (64x32) and F (32x8): 2*(nd+dk)*8 bytes
    assert ctx.log.total_bytes("online") == 2 * (64 * 32 + 32 * 8) * 8
    assert ctx.log.total_rounds("online") == 1
    assert ctx.log.total_bytes("offline") > 0  # modelled OT triple traffic


# ---------------------------------------------------------------------------
# boolean layer: MSB / CMP / MUX / B2A
# ---------------------------------------------------------------------------

@given(st.lists(st.integers(-(2 ** 40), 2 ** 40), min_size=1, max_size=64))
@settings(deadline=None, max_examples=30)
def test_msb_matches_sign(vals):
    x = np.asarray(vals, np.int64).astype(np.uint64)
    rng = np.random.default_rng(11)
    b = P.msb_carry(_ctx(), share(x, rng))
    got = np.asarray(rec_b(b)).astype(np.int64)
    want = (np.asarray(vals) < 0).astype(np.int64)
    np.testing.assert_array_equal(got, want)


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=2, max_size=40))
@settings(deadline=None, max_examples=30)
def test_cmp_lt(vals):
    half = len(vals) // 2
    x, y = np.asarray(vals[:half]), np.asarray(vals[half:2 * half])
    if half == 0:
        return
    rng = np.random.default_rng(13)
    c = P.cmp_lt(_ctx(), share_real(x, rng), share_real(y, rng))
    got = np.asarray(rec(c), np.uint64).astype(np.int64)
    enc = lambda v: np.round(v * (1 << ring.F)).astype(np.int64)
    np.testing.assert_array_equal(got, (enc(x) < enc(y)).astype(np.int64))


def test_mux_selects():
    rng = np.random.default_rng(17)
    x, y = rng.uniform(-9, 9, 100), rng.uniform(-9, 9, 100)
    ctx = _ctx()
    z = P.cmp_lt(ctx, share_real(x, rng), share_real(y, rng))
    m = P.mux(ctx, z, share_real(x, rng), share_real(y, rng))
    np.testing.assert_allclose(np.asarray(rec_real(m)), np.minimum(x, y),
                               atol=1e-4)


def test_b2a_bit():
    rng = np.random.default_rng(19)
    bits = rng.integers(0, 2, 200).astype(np.uint64)
    b = share_b(bits, rng)
    a = P.b2a_bit(_ctx(), b)
    np.testing.assert_array_equal(np.asarray(rec(a), np.uint64), bits)


# ---------------------------------------------------------------------------
# argmin / reciprocal
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [2, 3, 5, 6, 8, 17])
def test_argmin_onehot(k):
    rng = np.random.default_rng(k)
    d = rng.uniform(0, 100, (64, k))
    oh = P.argmin_onehot(_ctx(), share_real(d, rng))
    got = np.asarray(rec(oh), np.uint64).astype(np.int64)
    assert (got.sum(1) == 1).all()
    np.testing.assert_array_equal(got.argmax(1), d.argmin(1))


@given(st.integers(1, 100000))
@settings(deadline=None, max_examples=30)
def test_reciprocal(den):
    rng = np.random.default_rng(29)
    d = share(np.array([den], np.uint64), rng)
    # plain scale-f output: absolute error ~ ulp => relative error ~ den*2^-f
    r = P.reciprocal(_ctx(), d, max_den=100000)
    rel = abs(float(np.asarray(rec_real(r))[0]) * den - 1.0)
    assert rel < max(1e-3, 3 * den * 2.0 ** -ring.F), (den, rel)


@given(st.integers(1, 100000))
@settings(deadline=None, max_examples=30)
def test_reciprocal_extended_precision(den):
    """extra_bits recovers full relative precision for large denominators
    (the centroid-update configuration)."""
    rng = np.random.default_rng(31)
    d = share(np.array([den], np.uint64), rng)
    extra = 17
    r = P.reciprocal(_ctx(), d, max_den=100000, extra_bits=extra)
    val = float(np.asarray(rec(r), np.uint64).astype(np.int64)[0]) \
        / (1 << (ring.F + extra))
    rel = abs(val * den - 1.0)
    assert rel < 1e-4, (den, rel)


def test_rounds_scale_logarithmically_with_k():
    """Vectorization invariant: argmin rounds ~ O(log k), not O(nk)."""
    rounds = {}
    for k in (4, 16, 64):
        ctx = _ctx()
        rng = np.random.default_rng(0)
        P.argmin_onehot(ctx, share_real(rng.uniform(0, 1, (8, k)), rng))
        rounds[k] = ctx.log.total_rounds("online")
    assert rounds[16] <= rounds[4] * 2.1
    assert rounds[64] <= rounds[4] * 3.1
