"""Crash-durability tests: fsync'd publishes and torn-file recovery
(DESIGN.md §16 satellite).

The atomic-rename publish protocol is only crash-safe if the payload is
durable BEFORE the rename and the rename itself is durable after — both
now enforced with fsync in `checkpoint/store.py`, `checkpoint/fit.py`
and `checkpoint/serve.py`. A machine crash can still tear a file that
was *published by an older, pre-fsync writer*; recovery must skip the
torn step with a warning and fall back to the previous one, while
fingerprint mismatches keep failing loudly (config error, not damage).
"""
import json
import os
import shutil

import numpy as np
import pytest

from repro.checkpoint.fit import FitCheckpointer, FitState
from repro.checkpoint.serve import ServeCheckpointer
from repro.checkpoint.store import CheckpointStore, fsync_path, fsync_tree
from repro.core.kmeans import KMeansConfig, SecureKMeans

from test_wire import _assert_same_fit, _blobs, _split


def _fit_with_checkpoints(tmp_path, iters=3):
    x = _blobs(48, 4, 2, seed=5)
    a, b = _split(x, "vertical")
    cfg = KMeansConfig(k=2, iters=iters, seed=5, backend="xla")
    d = str(tmp_path / "ck")
    ck = FitCheckpointer(d, every=1, keep=0)
    res = SecureKMeans(cfg).fit(a, b, checkpoint=ck)
    return cfg, a, b, d, ck, res


# ---------------------------------------------------------------------------
# fsync helpers
# ---------------------------------------------------------------------------


def test_fsync_path_file_dir_and_missing(tmp_path):
    f = tmp_path / "x.bin"
    f.write_bytes(b"abc")
    fsync_path(str(f))                       # file
    fsync_path(str(tmp_path))                # directory
    fsync_path(str(tmp_path / "missing"))    # best-effort no-raise


def test_fsync_tree_walks_nested(tmp_path):
    (tmp_path / "a" / "b").mkdir(parents=True)
    (tmp_path / "a" / "b" / "f.txt").write_text("hi")
    (tmp_path / "a" / "g.txt").write_text("ho")
    fsync_tree(str(tmp_path))


# ---------------------------------------------------------------------------
# FitCheckpointer: torn-step fallback + step_at_or_before
# ---------------------------------------------------------------------------


def test_torn_newest_step_recovers_previous(tmp_path):
    cfg, a, b, d, ck, _ = _fit_with_checkpoints(tmp_path)
    steps = ck.all_steps()
    assert len(steps) >= 2
    # tear the newest step's arrays, as a pre-fsync writer + power loss
    # would: published name, garbage payload
    torn = os.path.join(d, f"step_{steps[-1]:010d}", "state.npz")
    with open(torn, "wb") as f:
        f.write(b"\x00" * 16)
    with pytest.warns(UserWarning, match="unreadable"):
        st = ck.latest()
    assert st is not None and st.step == steps[-2]


def test_every_step_torn_means_fresh_start(tmp_path):
    cfg, a, b, d, ck, _ = _fit_with_checkpoints(tmp_path)
    for s in ck.all_steps():
        with open(os.path.join(d, f"step_{s:010d}", "state.npz"),
                  "wb") as f:
            f.write(b"junk")
    with pytest.warns(UserWarning):
        assert ck.latest() is None


def test_torn_manifest_also_skipped(tmp_path):
    cfg, a, b, d, ck, _ = _fit_with_checkpoints(tmp_path)
    steps = ck.all_steps()
    with open(os.path.join(d, f"step_{steps[-1]:010d}", "manifest.json"),
              "w") as f:
        f.write("{half")
    with pytest.warns(UserWarning, match="unreadable"):
        st = ck.latest()
    assert st.step == steps[-2]


def test_fingerprint_mismatch_still_fails_loudly(tmp_path):
    cfg, a, b, d, ck, _ = _fit_with_checkpoints(tmp_path)
    ck2 = FitCheckpointer(d, fingerprint="some-other-config")
    with pytest.raises(ValueError, match="fingerprint"):
        ck2.latest()


def test_resume_after_torn_step_is_bit_exact(tmp_path):
    """The whole point: tearing the newest step only costs recompute —
    the fit resumed from the fallback step equals the clean fit."""
    cfg, a, b, d, ck, ref = _fit_with_checkpoints(tmp_path)
    steps = ck.all_steps()
    with open(os.path.join(d, f"step_{steps[-1]:010d}", "state.npz"),
              "wb") as f:
        f.write(b"\x00")
    with pytest.warns(UserWarning):
        res = SecureKMeans(cfg).fit(a, b, checkpoint=FitCheckpointer(d),
                                    resume=True)
    _assert_same_fit(ref, res)


def test_step_at_or_before(tmp_path):
    cfg, a, b, d, ck, _ = _fit_with_checkpoints(tmp_path)
    steps = ck.all_steps()                   # [1_000_000, 2_000_000]
    assert ck.step_at_or_before(steps[-1]) == steps[-1]
    assert ck.step_at_or_before(steps[-1] + 5) == steps[-1]
    assert ck.step_at_or_before(steps[0] + 1) == steps[0]
    assert ck.step_at_or_before(steps[0] - 1) is None
    assert ck.step_at_or_before(-1) is None


def test_torn_tmp_dir_is_ignored_and_recycled(tmp_path):
    """A writer killed mid-save leaves step_X.tmp; it must never count
    as published, and the next save of the same step must clobber it."""
    d = str(tmp_path / "ck")
    ck = FitCheckpointer(d, every=1)
    tmp = os.path.join(d, "step_0001000000.tmp")
    os.makedirs(tmp)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        f.write("{half-written")
    assert ck.all_steps() == []
    assert ck.latest() is None
    st = FitState(iteration=1, batch=0,
                  mu0=np.zeros((2, 2), np.uint64),
                  mu1=np.zeros((2, 2), np.uint64),
                  counters={"n_matmul": 0, "n_mul": 0, "n_bin": 0},
                  comm={}, advance={})
    ck.save(st)
    assert ck.all_steps() == [1_000_000]
    assert not os.path.exists(tmp)
    assert ck.load(1_000_000).iteration == 1


# ---------------------------------------------------------------------------
# CheckpointStore + ServeCheckpointer publish durability
# ---------------------------------------------------------------------------


def test_store_save_still_atomic_with_fsync(tmp_path):
    store = CheckpointStore(str(tmp_path / "st"), keep=2)
    tree = {"w": np.arange(6.0).reshape(2, 3)}
    p = store.save(3, tree)
    assert os.path.isdir(p) and not p.endswith(".tmp")
    got = store.restore(3, {"w": np.zeros((2, 3))})
    np.testing.assert_array_equal(got["w"], tree["w"])


def test_serve_journal_tmp_straggler_ignored(tmp_path):
    ck = ServeCheckpointer(str(tmp_path / "sck"))
    straggler = os.path.join(ck.journal_dir, "batch_00000007.npz.tmp")
    with open(straggler, "wb") as f:
        f.write(b"half a journal batch")
    responses, consumed = ck.load_journal()
    assert responses == {} and consumed == {}
    # and the straggler's batch number is not skipped into
    assert ck._next_batch() == 0
