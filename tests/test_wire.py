"""Transport, reliability, and fault-injection tests (DESIGN.md §13).

Load-bearing properties:
* the frame codec round-trips arbitrary payloads, survives arbitrarily
  split reads, and CRC-rejects bit flips without desyncing;
* `ReliableChannel` + `Responder` give exactly-once EFFECT over an
  at-least-once wire: drops, duplicates, corruption, and a severed
  connection all collapse to "resend until the response lands", with the
  responder's seq dedup preventing double handling;
* a fit run over a fault-injected wire produces IDENTICAL shares, dealer
  counters, and CommLog tallies to the clean in-process fit — the chaos
  only costs wall-clock;
* a real two-process fit over TCP (launch/two_party.py) is bit-exact
  against the in-process reference on every partition × sparsity combo;
* `NetModel.time_estimate` predicts the measured wall of a latency-
  injected exchange within tolerance;
* `CommLog` tallies stay exact under concurrent writers.
"""
import json
import os
import struct
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import given, settings, strategies as st
from repro.core.channel import (CommLog, FaultyTransport, FrameCorrupt,
                                FrameDecoder, FrameError, LoopbackTransport,
                                NetModel, ReliableChannel, Responder,
                                SocketTransport, T_BLOB, T_EXCHANGE,
                                WireSession, WireTimeout, decode_frame,
                                encode_frame, serve_peer)
from repro.core.kmeans import KMeansConfig, SecureKMeans


def _blobs(n, d, k, seed, sparse_frac=0.0):
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-4, 4, (k, d))
    lab = rng.integers(0, k, n)
    x = centers[lab] + rng.normal(0, 0.3, (n, d))
    if sparse_frac:
        x = x * (rng.random((n, d)) >= sparse_frac)
    return x


def _split(x, partition):
    n, d = x.shape
    if partition == "vertical":
        return x[:, :d // 2], x[:, d // 2:]
    return x[:n // 2], x[n // 2:]


def _assert_same_fit(r0, r1):
    for field in ("centroids", "assignment"):
        for s in ("s0", "s1"):
            np.testing.assert_array_equal(
                np.asarray(getattr(getattr(r0, field), s), np.uint64),
                np.asarray(getattr(getattr(r1, field), s), np.uint64))
    assert (r0.dealer.n_matmul, r0.dealer.n_mul, r0.dealer.n_bin) == \
           (r1.dealer.n_matmul, r1.dealer.n_mul, r1.dealer.n_bin)
    assert r0.log.by_tag("online") == r1.log.by_tag("online")


def _wired_pair(**chan_kw):
    """Loopback engine channel + responder thread; returns
    (WireSession, engine transport, responder transport, thread)."""
    ta, tb = LoopbackTransport.pair()
    th = threading.Thread(target=serve_peer, args=(tb,),
                          kwargs={"idle_timeout_s": 60.0}, daemon=True)
    th.start()
    return WireSession(ReliableChannel(ta, **chan_kw)), ta, tb, th


# ---------------------------------------------------------------------------
# frame codec
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=50)
@given(st.integers(0, 127), st.integers(0, 2**63), st.integers(0, 4096))
def test_frame_roundtrip(ftype, seq, size):
    payload = bytes((i * 131 + 7) % 256 for i in range(size))
    ft, sq, pl = decode_frame(encode_frame(ftype, seq, payload))
    assert (ft, sq, pl) == (ftype, seq, payload)


@settings(deadline=None, max_examples=25)
@given(st.integers(1, 8), st.integers(1, 997), st.integers(0, 2**31))
def test_frame_decoder_split_reads(n_frames, chunk, seed):
    """Any chunking of the byte stream yields the same frame sequence."""
    rng = np.random.default_rng(seed)
    frames = [(i % 5 + 1, i, rng.bytes(int(rng.integers(0, 600))))
              for i in range(n_frames)]
    stream = b"".join(encode_frame(*f) for f in frames)
    dec = FrameDecoder()
    got = []
    for lo in range(0, len(stream), chunk):
        got.extend(dec.feed(stream[lo:lo + chunk]))
    assert got == frames
    assert dec.pending() == 0 and dec.crc_errors == 0


def test_frame_decoder_drops_corrupt_keeps_stream():
    a = encode_frame(T_EXCHANGE, 1, b"hello world")
    b = encode_frame(T_EXCHANGE, 2, b"intact")
    bad = bytearray(a)
    bad[-3] ^= 0x40                     # flip a payload bit: CRC catches it
    dec = FrameDecoder()
    got = dec.feed(bytes(bad) + b)
    assert got == [(T_EXCHANGE, 2, b"intact")]
    assert dec.crc_errors == 1


def test_frame_decoder_bad_magic_raises():
    dec = FrameDecoder()
    with pytest.raises(FrameError):
        dec.feed(b"\x00" * 64)


def test_decode_frame_rejects_truncation():
    f = encode_frame(T_BLOB, 9, b"payload!")
    with pytest.raises(FrameError):
        decode_frame(f[:10])
    with pytest.raises(FrameCorrupt):
        decode_frame(f[:-2])


# ---------------------------------------------------------------------------
# reliability: retries, dedup, heartbeat
# ---------------------------------------------------------------------------

def test_exactly_once_effect_under_drop_dup_corrupt():
    """Chaos on BOTH directions; every request's handler still runs exactly
    once and every exchange completes with the exact byte count."""
    ta, tb = LoopbackTransport.pair()
    fa = FaultyTransport(ta, seed=3, drop=0.15, dup=0.15, corrupt=0.1)
    fb = FaultyTransport(tb, seed=4, drop=0.1, dup=0.1, corrupt=0.1)
    calls = []

    def handler(ftype, payload):
        if ftype == T_EXCHANGE:
            (b_len,) = struct.unpack_from(">I", payload)
            calls.append(b_len)
            return bytes(b_len)
        return b""

    resp = Responder(fb, handler, idle_timeout_s=30.0)
    th = threading.Thread(target=resp.serve_forever, daemon=True)
    th.start()
    ws = WireSession(ReliableChannel(fa, try_timeout_s=0.05,
                                     backoff_s=0.002, max_retries=200,
                                     deadline_s=30.0))
    for i in range(30):
        assert ws.exchange(101 + i, rounds=1) == 101 + i
    ws.bye()
    th.join(timeout=10)
    assert not th.is_alive()
    # exactly-once effect: one handler call per exchange, in seq order
    assert calls == [(101 + i) - (101 + i + 1) // 2 for i in range(30)]
    # and the chaos actually happened
    f = fa.faults
    assert f.dropped + f.duplicated + f.corrupted > 0
    assert resp.dedup_replays + resp.crc_drops + resp.stale_drops > 0


def test_sever_reconnect_mid_session():
    ta, tb = LoopbackTransport.pair()
    fa = FaultyTransport(ta, sever_at=(4,))
    resp_holder = {}

    def run():
        resp_holder["r"] = serve_peer(tb, idle_timeout_s=30.0)

    th = threading.Thread(target=run, daemon=True)
    th.start()
    ws = WireSession(ReliableChannel(fa, try_timeout_s=0.05,
                                     backoff_s=0.002, deadline_s=30.0,
                                     max_retries=100))
    for _ in range(8):
        ws.exchange(64, rounds=1)
    ws.bye()
    th.join(timeout=10)
    assert not th.is_alive()
    assert fa.faults.severed == 1
    assert ws.chan.reconnects >= 1
    assert ws.payload_bytes == 8 * 64


def test_responder_dead_engine_times_out_not_livelocks():
    """Engine gone for good: the responder's failed redials must count
    against the idle budget and surface as WireTimeout — NOT loop forever
    in reconnect (recv raises ConnectionError, the lazy redial inside the
    next recv fails with ConnectionError too)."""
    srv = SocketTransport("listen", port=0, io_timeout_s=2.0)
    port = srv.port
    cli = SocketTransport("connect", port=port, io_timeout_s=2.0,
                          connect_retries=1, backoff_s=0.01,
                          backoff_max_s=0.05)
    out = {}

    def run():
        try:
            serve_peer(cli, idle_timeout_s=1.5)
        except WireTimeout as e:
            out["err"] = e

    th = threading.Thread(target=run, daemon=True)
    th.start()
    # accept, then kill the engine end entirely (socket AND listener)
    srv._ensure()
    srv.close()
    th.join(timeout=30)
    assert not th.is_alive(), "responder livelocked on a dead engine"
    assert "err" in out, "responder exited without WireTimeout"


def test_heartbeat_keeps_idle_responder_alive():
    ws, _ta, _tb, th = _wired_pair()
    for _ in range(3):
        ws.heartbeat()
        time.sleep(0.01)
    ws.exchange(32, rounds=1)
    ws.bye()
    th.join(timeout=5)
    assert not th.is_alive()


def test_blob_roundtrip_ships_arrays():
    ta, tb = LoopbackTransport.pair()

    def on_blob(meta, arrays):
        assert meta["op"] == "double"
        return {"ok": True}, {"y": arrays["x"] * 2}

    th = threading.Thread(target=serve_peer, args=(tb,),
                          kwargs={"on_blob": on_blob,
                                  "idle_timeout_s": 30.0}, daemon=True)
    th.start()
    ws = WireSession(ReliableChannel(ta))
    x = np.arange(12, dtype=np.uint64).reshape(3, 4)
    meta, arrays = ws.send_arrays({"op": "double"}, {"x": x})
    assert meta == {"ok": True}
    np.testing.assert_array_equal(arrays["y"], x * 2)
    ws.bye()
    th.join(timeout=5)


# ---------------------------------------------------------------------------
# CommLog thread safety (the wire made it load-bearing)
# ---------------------------------------------------------------------------

def test_commlog_concurrent_tallies_exact():
    log = CommLog()
    n_threads, n_sends = 8, 500

    def worker(i):
        for _ in range(n_sends):
            log.send(3, tag=f"t{i % 2}", phase="online", rounds=1)

    ts = [threading.Thread(target=worker, args=(i,))
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert log.total_bytes("online") == 3 * n_threads * n_sends
    assert log.total_rounds("online") == n_threads * n_sends


def test_commlog_concurrent_merges_exact():
    src = CommLog()
    src.send(7, tag="x", phase="online", rounds=2)
    dst = CommLog()
    n_threads, n_merges = 8, 200

    def worker():
        for _ in range(n_merges):
            dst.merge(src, phase="online")

    ts = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert dst.total_bytes("online") == 7 * n_threads * n_merges
    assert dst.total_rounds("online") == 2 * n_threads * n_merges


# ---------------------------------------------------------------------------
# the chaos acceptance test: a faulted fit is bit-exact with the clean one
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partition,sparse",
                         [("vertical", False), ("horizontal", True)])
def test_chaos_fit_bit_exact(partition, sparse):
    """Seeded drops + delays + duplicates + corruption + one severed
    connection: the wired fit terminates with shares, counters, and
    tallies identical to the clean in-process run."""
    n, d, k = 48, 4, 2
    x = _blobs(n, d, k, seed=11, sparse_frac=0.5 if sparse else 0.0)
    a, b = _split(x, partition)
    cfg = KMeansConfig(k=k, iters=2, partition=partition, sparse=sparse,
                       seed=5, backend="xla")
    r_clean = SecureKMeans(cfg).fit(a, b)

    ta, tb = LoopbackTransport.pair()
    fa = FaultyTransport(ta, seed=13, drop=0.05, dup=0.05, corrupt=0.05,
                         delay_s=0.0005, sever_at=(25,))
    fb = FaultyTransport(tb, seed=14, drop=0.03, dup=0.03, corrupt=0.03)
    th = threading.Thread(target=serve_peer, args=(fb,),
                          kwargs={"idle_timeout_s": 60.0}, daemon=True)
    th.start()
    ws = WireSession(ReliableChannel(fa, try_timeout_s=0.05,
                                     backoff_s=0.002, max_retries=500,
                                     deadline_s=120.0))
    r_chaos = SecureKMeans(cfg).fit(a, b, wire=ws)
    ws.bye()
    th.join(timeout=30)
    assert not th.is_alive()
    _assert_same_fit(r_clean, r_chaos)
    f = fa.faults
    assert f.severed == 1 and f.dropped + f.duplicated + f.corrupted > 0


def test_wired_fit_pays_the_modelled_traffic():
    """The wire's shipped payload bytes equal the CommLog's online tally —
    the accounting IS the traffic, not an estimate of it."""
    x = _blobs(48, 4, 2, seed=11)
    a, b = _split(x, "vertical")
    cfg = KMeansConfig(k=2, iters=2, partition="vertical", seed=5,
                       backend="xla")
    ws, _ta, _tb, th = _wired_pair()
    r = SecureKMeans(cfg).fit(a, b, wire=ws)
    ws.bye()
    th.join(timeout=10)
    assert ws.payload_bytes == r.log.total_bytes("online")
    assert ws.rounds == r.log.total_rounds("online")


# ---------------------------------------------------------------------------
# NetModel pin: prediction vs measured wall under injected latency
# ---------------------------------------------------------------------------

def test_netmodel_time_estimate_matches_measured_wall():
    net = NetModel("emul", 1e12, 0.02)     # latency-dominated on purpose
    ta, tb = LoopbackTransport.pair()
    fa = FaultyTransport.emulate(ta, net)
    fb = FaultyTransport.emulate(tb, net)
    th = threading.Thread(target=serve_peer, args=(fb,),
                          kwargs={"idle_timeout_s": 30.0}, daemon=True)
    th.start()
    ws = WireSession(ReliableChannel(fa, try_timeout_s=5.0))
    log = CommLog()
    log.wire = ws
    nbytes, rounds = 4096, 8
    t0 = time.perf_counter()
    log.send(nbytes, tag="pin", phase="online", rounds=rounds)
    wall = time.perf_counter() - t0
    ws.bye()
    th.join(timeout=10)
    predicted = log.time_estimate(net, "online")
    assert predicted == net.time_s(nbytes, rounds)
    # sleep-based emulation only ever overshoots; allow generous headroom
    # above (scheduler) and a small floor below (nothing to undershoot by)
    assert 0.8 * predicted <= wall <= 3.0 * predicted + 0.25, \
        (predicted, wall)


# ---------------------------------------------------------------------------
# two real processes over TCP — the deployment acceptance test
# ---------------------------------------------------------------------------

def _run_two_party(extra_a, extra_b=(), timeout=600):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    a = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.two_party", "--role", "A",
         "--port", "0"] + list(extra_a),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    line = a.stdout.readline()
    assert line.startswith("LISTENING "), line
    port = int(line.split()[1])
    b = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.two_party", "--role", "B",
         "--port", str(port)] + list(extra_b),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env)
    a_out = a.communicate(timeout=timeout)[0]
    try:
        b_out = b.communicate(timeout=60)[0]
    except subprocess.TimeoutExpired:
        b.kill()
        b_out = b.communicate()[0]
    return a.returncode, a_out, b.returncode, b_out


@pytest.mark.parametrize("partition", ["vertical", "horizontal"])
@pytest.mark.parametrize("sparse_frac", [0.0, 0.5])
def test_two_process_socket_fit_bit_exact(tmp_path, partition, sparse_frac):
    """Party A and party B as REAL processes over TCP: shares, dealer
    counters, and online tallies equal the in-process reference."""
    out = str(tmp_path / "a.npz")
    rc_a, a_out, rc_b, b_out = _run_two_party(
        ["--out", out, "--partition", partition,
         "--sparse-frac", str(sparse_frac)],
        ["--partition", partition, "--sparse-frac", str(sparse_frac)])
    assert rc_a == 0, a_out
    assert rc_b == 0, b_out

    from repro.launch.two_party import make_data, split_data
    x = make_data(48, 4, 2, 5, sparse_frac)
    xa, xb = split_data(x, partition)
    cfg = KMeansConfig(k=2, iters=2, seed=5, partition=partition,
                       sparse=sparse_frac > 0, backend="xla")
    km = SecureKMeans(cfg)
    res = km.fit(xa, xb)
    arr = make_data(16, 4, 2, 6, sparse_frac)
    pa, pb = split_data(arr, partition)
    pred = km.predict(pa, pb)

    z = np.load(out)
    meta = json.loads(bytes(z["meta"]))
    np.testing.assert_array_equal(
        z["mu0"], np.asarray(res.centroids.s0, np.uint64))
    np.testing.assert_array_equal(
        z["mu1"], np.asarray(res.centroids.s1, np.uint64))
    np.testing.assert_array_equal(
        z["c0"], np.asarray(res.assignment.s0, np.uint64))
    np.testing.assert_array_equal(
        z["c1"], np.asarray(res.assignment.s1, np.uint64))
    np.testing.assert_array_equal(
        z["p0"], np.asarray(pred.assignment.s0, np.uint64))
    np.testing.assert_array_equal(
        z["p1"], np.asarray(pred.assignment.s1, np.uint64))
    assert meta["counters"] == {attr: int(getattr(res.dealer, attr))
                                for attr in ("n_matmul", "n_mul", "n_bin")}
    ref_online = {t: [int(v[0]), int(v[1])]
                  for t, v in res.log.by_tag("online").items()}
    assert meta["fit_online"] == ref_online
    # the wire carried exactly the modelled fit+predict traffic
    pred_online = res.log.total_bytes("online") \
        + pred.log.total_bytes("online")
    assert meta["wire_payload_bytes"] == pred_online


def test_socket_transport_port_zero_and_reconnect():
    """Socket specifics the loopback can't exercise: ephemeral port pickup
    and a reconnect after the server drops the connection."""
    srv = SocketTransport("listen", port=0, io_timeout_s=10.0)
    assert srv.port > 0
    cli = SocketTransport("connect", port=srv.port, io_timeout_s=10.0)
    done = {}

    def server():
        f = srv.recv_frame(10.0)
        srv.send_frame(f)               # echo 1
        srv.reconnect()                 # drop the conn; re-accept lazily
        f = srv.recv_frame(10.0)
        srv.send_frame(f)               # echo 2 on the new conn
        done["ok"] = True

    th = threading.Thread(target=server, daemon=True)
    th.start()
    f1 = encode_frame(T_EXCHANGE, 0, b"one")
    cli.send_frame(f1)
    assert cli.recv_frame(10.0) == f1
    # server tore the connection down; client sees it and reconnects
    f2 = encode_frame(T_EXCHANGE, 1, b"two")
    for _ in range(20):
        try:
            cli.send_frame(f2)
            got = cli.recv_frame(10.0)
            break
        except (ConnectionError, TimeoutError):
            cli.reconnect()
            time.sleep(0.05)
    else:
        pytest.fail("client never re-established the connection")
    assert got == f2
    th.join(timeout=10)
    assert done.get("ok")
    cli.close()
    srv.close()
