"""serve_step: single-token decode with KV caches / recurrent states.

Cache layouts (per scan group, stacked over repeats — decode scans layers
with the cache as scan xs/ys so the HLO again holds one unit body):

  gqa global : k/v (R, B, S_max, Hkv, Dh) bf16, positions implicit (<= pos)
  gqa local  : k/v (R, B, W, Hkv, Dh) ring buffer + kpos (R, B, W) int32
  MLA        : c_kv (R, B, S, kv_lora) + k_pe (R, B, S, dr)   <- the paper-
               relevant win: 576 f.p. per token instead of 2*Hkv*Dh
               (absorbed-matmul decode, DeepSeek-V2 Sec 2.1)
  rwkv       : prev_tm/prev_ch (R, B, 1, D) + S (R, B, H, dk, dv)
  rglru      : conv tail (R, B, cw-1, W) + h (R, B, W)
  xattn      : self cache + precomputed cross k/v
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import lm as M
from repro.models import recurrent as R

BF16 = jnp.bfloat16
F32 = jnp.float32


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------


def _dus(x, u, idx):
    """dynamic_update_slice with uniformly-int32 indices (x64-safe)."""
    return jax.lax.dynamic_update_slice(
        x, u, tuple(jnp.asarray(i, jnp.int32) for i in idx))

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_len: int = 0) -> list:
    caches = []
    for grp in cfg.groups:
        unit_cache = {}
        for bi, kind in enumerate(grp.unit):
            unit_cache[f"b{bi}"] = _init_block_cache(
                kind, cfg, grp.repeats, batch, max_seq, enc_len)
        caches.append(unit_cache)
    return caches


def _init_block_cache(kind, cfg, r, b, s, enc_len):
    hkv, dh, d = cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    if kind in ("attn", "moe_attn", "xattn"):
        c = {"k": jnp.zeros((r, b, s, hkv, dh), BF16),
             "v": jnp.zeros((r, b, s, hkv, dh), BF16)}
        if kind == "xattn":
            c["xk"] = jnp.zeros((r, b, enc_len, hkv, dh), BF16)
            c["xv"] = jnp.zeros((r, b, enc_len, hkv, dh), BF16)
        return c
    if kind in ("attn_local", "rglru_attn"):
        w = min(cfg.window, s)
        return {"k": jnp.zeros((r, b, w, hkv, dh), BF16),
                "v": jnp.zeros((r, b, w, hkv, dh), BF16),
                "kpos": jnp.full((r, b, w), -1, jnp.int32)}
    if kind in ("mla", "mla_dense"):
        return {"ckv": jnp.zeros((r, b, s, cfg.kv_lora), BF16),
                "kpe": jnp.zeros((r, b, s, cfg.rope_head_dim), BF16)}
    if kind == "rwkv":
        h = d // cfg.rwkv_head_dim
        return {"prev_tm": jnp.zeros((r, b, 1, d), BF16),
                "prev_ch": jnp.zeros((r, b, 1, d), BF16),
                "s": jnp.zeros((r, b, h, cfg.rwkv_head_dim,
                                cfg.rwkv_head_dim), F32)}
    if kind == "rglru":
        w = cfg.lru_width or d
        return {"tail": jnp.zeros((r, b, cfg.conv_width - 1, w), BF16),
                "h": jnp.zeros((r, b, w), F32)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# single-token attention over a cache
# ---------------------------------------------------------------------------

def _attend_cache(q, k, v, mask, scale, cap):
    """q (B,1,H,Dh); k/v (B,S,Hkv,Dh); mask (B,S) -> (B,1,H*Dh)."""
    b, _, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    s = jnp.einsum("bqhgd,bkhd->bhgqk",
                   q.astype(F32).reshape(b, 1, hkv, g, dh),
                   k.astype(F32)) * F32(scale)
    s = L.softcap(s, cap)
    s = jnp.where(mask[:, None, None, None, :], s, F32(-1e30))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(F32))
    return o.reshape(b, 1, h * dh).astype(BF16)


def _decode_gqa(p, cache, x, cfg, pos, *, window=None):
    b = x.shape[0]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, 1, h, dh)
    k = (x @ p["wk"]).reshape(b, 1, hkv, dh)
    v = (x @ p["wv"]).reshape(b, 1, hkv, dh)
    cos, sin = L.rope_freqs(pos[None], dh, cfg.rope_theta)
    q = L.apply_rope(q, cos[None], sin[None])
    k = L.apply_rope(k, cos[None], sin[None])
    if window is None:
        s_max = cache["k"].shape[1]        # (B, S, Hkv, Dh) inside the scan
        kc = _dus(cache["k"], k, (0, pos, 0, 0))
        vc = _dus(cache["v"], v, (0, pos, 0, 0))
        mask = (jnp.arange(s_max)[None] <= pos)
        mask = jnp.broadcast_to(mask, (b, s_max))
        new_cache = {"k": kc, "v": vc}
    else:
        w = cache["k"].shape[1]
        slot = pos % w
        kc = _dus(cache["k"], k, (0, slot, 0, 0))
        vc = _dus(cache["v"], v, (0, slot, 0, 0))
        kpos = _dus(cache["kpos"],
                    jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None],
                                     (b, 1)), (0, slot))
        mask = (kpos <= pos) & (kpos > pos - window) & (kpos >= 0)
        new_cache = {"k": kc, "v": vc, "kpos": kpos}
    o = _attend_cache(q, kc, vc, mask, 1.0 / np.sqrt(dh), cfg.attn_softcap)
    return o @ p["wo"], new_cache


def _decode_mla(p, cache, x, cfg, pos):
    """Absorbed-matmul MLA decode over the compressed cache."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    cq = L.rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, 1, h, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    cos, sin = L.rope_freqs(pos[None], dr, cfg.rope_theta)
    q_pe = L.apply_rope(q_pe, cos[None], sin[None])
    ckv_full = x @ p["wkv_a"]
    ckv = L.rms_norm(ckv_full[..., :cfg.kv_lora], p["kv_norm"], cfg.norm_eps)
    kpe = ckv_full[..., cfg.kv_lora:].reshape(b, 1, dr)
    kpe = L.apply_rope(kpe[:, :, None], cos[None], sin[None])[:, :, 0]
    s_max = cache["ckv"].shape[1]
    ckv_c = _dus(cache["ckv"], ckv.reshape(b, 1, -1), (0, pos, 0))
    kpe_c = _dus(cache["kpe"], kpe.reshape(b, 1, -1), (0, pos, 0))
    wkv_b = p["wkv_b"].reshape(cfg.kv_lora, h, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    q_eff = jnp.einsum("bhd,khd->bhk", q_nope[:, 0].astype(F32),
                       w_uk.astype(F32))                       # (B,H,kv_lora)
    scores = jnp.einsum("bhk,bsk->bhs", q_eff, ckv_c.astype(F32)) \
        + jnp.einsum("bhr,bsr->bhs", q_pe[:, 0].astype(F32),
                     kpe_c.astype(F32))
    scores = scores / F32(np.sqrt(dn + dr))
    mask = (jnp.arange(s_max)[None, None] <= pos)
    probs = jax.nn.softmax(jnp.where(mask, scores, F32(-1e30)), -1)
    ctx_c = jnp.einsum("bhs,bsk->bhk", probs, ckv_c.astype(F32))
    o = jnp.einsum("bhk,khd->bhd", ctx_c, w_uv.astype(F32))    # (B,H,dv)
    out = o.reshape(b, 1, h * dv).astype(BF16) @ p["wo"]
    return out, {"ckv": ckv_c, "kpe": kpe_c}


def _decode_rwkv_tm(p, cache_s, prev, x, cfg):
    """T=1 exact recurrence."""
    b, _, d = x.shape
    dh = cfg.rwkv_head_dim
    h = d // dh
    xm = x + (prev - x) * p["mix_rkvw"].astype(x.dtype)
    r = (xm @ p["wr"]).reshape(b, h, dh).astype(F32)
    k = (xm @ p["wk"]).reshape(b, h, dh).astype(F32)
    v = (xm @ p["wv"]).reshape(b, h, dh).astype(F32)
    g = jax.nn.silu(xm @ p["wg"])
    raw = jnp.clip(p["w_base"].astype(F32)
                   + (xm.astype(F32) @ p["w_lora_a"]) @ p["w_lora_b"],
                   -8.0, 0.6931)
    w = jnp.exp(-jnp.exp(raw)).reshape(b, h, dh)
    u = p["u_bonus"].reshape(h, dh).astype(F32)
    y = jnp.einsum("bhk,bhkv->bhv", r, cache_s) \
        + jnp.einsum("bhk,bhk->bh", r, u[None] * k)[..., None] * v
    s_new = w[..., None] * cache_s + k[..., None] * v[:, :, None]
    y = R._group_norm(y[:, None], p["ln_x_scale"], cfg.norm_eps)[:, 0]
    out = (y.reshape(b, 1, d).astype(x.dtype) * g) @ p["wo"]
    return out, s_new, x


def decode_block(kind, p, cache, x, cfg, pos, enc=None):
    if kind in ("attn", "moe_attn", "attn_local", "rglru_attn", "xattn"):
        window = cfg.window if kind in ("attn_local", "rglru_attn") else None
        a, nc = _decode_gqa(p, cache, M._norm(p, "ln1", x, cfg), cfg, pos,
                            window=window)
        if cfg.post_norms:
            a = M._norm(p, "ln1_post", a, cfg)
        x = x + a
        if kind == "xattn":
            h = M._norm(p, "ln3", x, cfg)
            b = x.shape[0]
            hh, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            q = (h @ p["xq"]).reshape(b, 1, hh, dh)
            mask = jnp.ones((b, cache["xk"].shape[1]), bool)
            o = _attend_cache(q, cache["xk"], cache["xv"], mask,
                              1.0 / np.sqrt(dh), None)
            x = x + o @ p["xo"]
            nc = {**nc, "xk": cache["xk"], "xv": cache["xv"]}
        h = M._norm(p, "ln2", x, cfg)
        m = L.moe_mlp(p["moe"], h, cfg) if kind == "moe_attn" \
            else L.glu_mlp(p, h, cfg.act)
        if cfg.post_norms:
            m = M._norm(p, "ln2_post", m, cfg)
        return x + m, nc
    if kind in ("mla", "mla_dense"):
        a, nc = _decode_mla(p, cache, M._norm(p, "ln1", x, cfg), cfg, pos)
        x = x + a
        h = M._norm(p, "ln2", x, cfg)
        m = L.moe_mlp(p["moe"], h, cfg) if kind == "mla" \
            else L.glu_mlp(p, h, cfg.act)
        return x + m, nc
    if kind == "rwkv":
        h = M._norm(p, "ln1", x, cfg)
        tm, s_new, prev_tm = _decode_rwkv_tm(p, cache["s"], cache["prev_tm"],
                                             h, cfg)
        x = x + tm
        h2 = M._norm(p, "ln2", x, cfg)
        cm, prev_ch = R.rwkv_channel_mix(p, h2, cfg, prev=cache["prev_ch"])
        return x + cm, {"s": s_new, "prev_tm": prev_tm, "prev_ch": prev_ch}
    if kind == "rglru":
        h = M._norm(p, "ln1", x, cfg)
        rec, (tail, hstate) = R.rg_lru(p, h, cfg,
                                       state=(cache["tail"], cache["h"]))
        x = x + rec
        return x + L.glu_mlp(p, M._norm(p, "ln2", x, cfg), cfg.act), \
            {"tail": tail, "h": hstate}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# serve_step: one new token for the whole stack
# ---------------------------------------------------------------------------

def serve_step(params, cfg: ModelConfig, cache: list, token: jnp.ndarray,
               pos: jnp.ndarray):
    """token (B,1) int32, pos () int32 -> (logits (B, Vp), new_cache)."""
    x = M.embed_tokens(params, token, cfg)

    new_cache = []
    for grp, gp, gc in zip(cfg.groups, params["groups"], cache):
        def unit(h, xs, _grp=grp):
            up, uc = xs
            ncs = {}
            for bi, kind in enumerate(_grp.unit):
                h, ncs[f"b{bi}"] = decode_block(kind, up[f"b{bi}"],
                                                uc[f"b{bi}"], h, cfg, pos)
            return M._pin_batch(h, cfg), ncs
        x, nc = jax.lax.scan(unit, x, (gp, gc),
                             unroll=grp.repeats if cfg.scan_unroll else 1)
        new_cache.append(nc)
    h = M._norm(params, "final_norm", x, cfg)
    logits = (h[:, 0].astype(BF16) @ params["head"]).astype(F32)
    logits = L.softcap(logits, cfg.final_softcap)
    return logits, new_cache
