"""The paper's own workload: sparsity-aware secure K-means for fraud
detection, sized like the production deployment (Sec 5.5-5.6 scaled up).

Used by launch/dryrun.py to lower the *online Lloyd iteration* (distance +
argmin + update on secret shares, trusted-dealer triples as inputs) onto the
production mesh: samples sharded over ('pod','data'), centroid shares
replicated, C^T X reduced with a psum — the MPC protocol expressed as a
pjit program.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class KMeansArch:
    name: str = "kmeans-fraud"
    n: int = 1_048_576          # samples (paper Fig 4 scale)
    d: int = 1024               # one-hot heavy feature dim
    k: int = 16                 # clusters (fraud patterns; keeps the secret
                                # one-hot tournament state n*m*k tractable)
    d_a: int = 512              # party A's feature slice (vertical)
    sparsity: float = 0.9


FULL = KMeansArch()
REDUCED = KMeansArch(name="kmeans-fraud-reduced", n=512, d=16, k=4, d_a=8)
