"""Model/arch configuration schema + registry for the assigned pool.

Every architecture is described as a sequence of *scan groups*: a unit
pattern of block kinds repeated R times. lax.scan runs over the repeats, so
the lowered HLO is one unit body per group regardless of depth — essential
for 512-device dry-run compiles and for remat policy.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

BlockKind = Literal[
    "attn",        # GQA self-attention + dense MLP
    "attn_local",  # windowed GQA + dense MLP
    "mla",         # DeepSeek multi-head latent attention + (shared+routed) MoE
    "mla_dense",   # MLA attention + dense MLP (DeepSeek first layer)
    "moe_attn",    # GQA attention + routed MoE MLP
    "rwkv",        # RWKV6 time-mix + channel-mix (attention-free)
    "rglru",       # RG-LRU recurrent block + dense MLP
    "rglru_attn",  # local attention block inside the Griffin pattern
]


@dataclasses.dataclass(frozen=True)
class ScanGroup:
    unit: tuple[BlockKind, ...]
    repeats: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    groups: tuple[ScanGroup, ...]
    d_head: int | None = None            # default d_model // n_heads

    # attention options
    rope_theta: float = 10000.0
    window: int = 4096                   # for *_local blocks
    attn_softcap: float | None = None    # gemma2
    final_softcap: float | None = None   # gemma2
    attn_bias: bool = False
    post_norms: bool = False             # gemma2 post-block norms

    # MLA (deepseek)
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    d_ff_dense_first: int = 0            # deepseek layer-0 dense MLP width
    capacity_factor: float = 1.25
    router_scale: float = 1.0
    expert_pad_multiple: int = 16        # pad E for EP; 1 = no pad (then
                                         # experts shard d_model instead)
    moe_dispatch: str = "global"         # 'global': one sort over all
                                         # tokens (distributed sort under
                                         # pjit!); 'per_example': vmapped
                                         # per-sequence dispatch — sorts
                                         # stay shard-local (§Perf)

    # recurrent
    lru_width: int = 0                   # rg-lru
    conv_width: int = 4
    rwkv_head_dim: int = 64

    # embeddings / misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: Literal["silu", "gelu", "relu_sq"] = "silu"
    enc_dec: bool = False                # seamless
    n_enc_layers: int = 0
    frontend: Literal[None, "audio", "vlm"] = None
    n_patches: int = 576                 # vlm stub prefix length
    scale_embed: bool = False            # gemma-style sqrt(d) embed scaling
    sub_quadratic: bool = False          # may run the long_500k cell
    scan_unroll: bool = False            # unroll layer scans (roofline probes
                                         # only: XLA cost analysis counts
                                         # while bodies once)
    act_axes: tuple = ()                 # mesh axes pinning the activation
                                         # batch dim inside layer scans (set
                                         # by the launcher; empty = none)
    remat_policy: str = "none"           # 'none' = save only block outputs;
                                         # 'dots' = save matmul outputs
                                         # (less recompute, more HBM)

    # ---- derived ------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return sum(len(g.unit) * g.repeats for g in self.groups)

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab_size // 256) * 256  # multiple of 256 (16-way TP)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model FLOPs)."""
        from repro.models.lm import init_params_shape_only
        import jax
        shapes = init_params_shape_only(self)
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed experts counted top_k/E)."""
        from repro.models.lm import init_params_shape_only
        import jax
        shapes = init_params_shape_only(self)
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            keys = "/".join(str(getattr(p, "key", p)) for p in path)
            n = int(np.prod(leaf.shape))
            if "experts" in keys and self.n_experts:
                n = n * self.top_k // self.n_experts
            total += n
        return total


import numpy as np  # noqa: E402

_REGISTRY: dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    reduced: ModelConfig                # smoke-test sized sibling
    skip_shapes: tuple[str, ...] = ()   # e.g. long_500k for quadratic attn
    skip_reason: str = ""


def register(arch_id: str, spec: ArchSpec) -> ArchSpec:
    _REGISTRY[arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[arch_id]


def all_archs() -> dict[str, ArchSpec]:
    if not _REGISTRY:
        _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    import importlib
    for mod in ("granite_34b", "command_r_35b", "llama3_405b", "gemma2_27b",
                "seamless_m4t_medium", "llava_next_34b", "rwkv6_1b6",
                "recurrentgemma_2b", "deepseek_v2_236b",
                "granite_moe_3b_a800m"):
        importlib.import_module(f"repro.configs.{mod}")
