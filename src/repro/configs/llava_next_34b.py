"""llava-next-34b [vlm] 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
— anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone only (per assignment): the anyres vision tower is a STUB —
input_specs provides precomputed patch embeddings (B, n_patches, d_model)
which replace the sequence prefix.
"""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="llava-next-34b", d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    groups=(ScanGroup(("attn",), 60),),
    rope_theta=5000000.0, frontend="vlm", n_patches=576, act="silu",
)

REDUCED = ModelConfig(
    name="llava-next-34b-reduced", d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    groups=(ScanGroup(("attn",), 2),),
    frontend="vlm", n_patches=16,
)

register("llava-next-34b", ArchSpec(
    config=FULL, reduced=REDUCED,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention arch (DESIGN.md §5)"))
