"""rwkv6-1.6b [ssm] 24L d=2048 (attn-free) d_ff=7168 vocab=65536
— Finch: data-dependent decay [arXiv:2404.05892; unverified].

Sub-quadratic (O(1) state): runs the long_500k cell. The paper's MPC
technique level (distance/argmin protocols) does not interact with the
recurrence — runtime-level integration only (DESIGN.md §5 arch-applicability).
"""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="rwkv6-1.6b", d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    groups=(ScanGroup(("rwkv",), 24),),
    rwkv_head_dim=64, act="relu_sq", sub_quadratic=True,
)

REDUCED = ModelConfig(
    name="rwkv6-1.6b-reduced", d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    groups=(ScanGroup(("rwkv",), 2),),
    rwkv_head_dim=64, act="relu_sq", sub_quadratic=True,
)

register("rwkv6-1.6b", ArchSpec(config=FULL, reduced=REDUCED))
