"""llama3-405b [dense] 126L d=16384 128H (GQA kv=8) d_ff=53248 vocab=128256
— GQA 128k vocab [arXiv:2407.21783; unverified]."""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="llama3-405b", d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256,
    groups=(ScanGroup(("attn",), 126),),
    rope_theta=500000.0, act="silu",
)

REDUCED = ModelConfig(
    name="llama3-405b-reduced", d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=384, vocab_size=512,
    groups=(ScanGroup(("attn",), 2),),
)

register("llama3-405b", ArchSpec(
    config=FULL, reduced=REDUCED,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention arch (DESIGN.md §5)"))
