"""gemma2-27b [dense] 46L d=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
— local+global alternating, logit softcap [arXiv:2408.00118; hf]."""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="gemma2-27b", d_model=4608, n_heads=32, n_kv_heads=16,
    d_head=128, d_ff=36864, vocab_size=256000,
    groups=(ScanGroup(("attn_local", "attn"), 23),),  # 46 layers
    window=4096, attn_softcap=50.0, final_softcap=30.0,
    post_norms=True, act="gelu", scale_embed=True,
)

REDUCED = ModelConfig(
    name="gemma2-27b-reduced", d_model=128, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, vocab_size=512,
    groups=(ScanGroup(("attn_local", "attn"), 1),),
    window=32, attn_softcap=50.0, final_softcap=30.0,
    post_norms=True, act="gelu", scale_embed=True,
)

register("gemma2-27b", ArchSpec(
    config=FULL, reduced=REDUCED,
    skip_shapes=("long_500k",),
    skip_reason="alternating local/global: the GLOBAL layers are still "
                "quadratic-history at 500k, so not purely sub-quadratic; "
                "skipped and noted (DESIGN.md §5)"))
