"""recurrentgemma-2b [hybrid] 26L d=2560 10H (GQA kv=1) d_ff=7680
— RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf].

Griffin pattern: (recurrent, recurrent, local-attention) x 8 + 2 trailing
recurrent blocks = 26 layers; local window 2048 => bounded state, runs the
long_500k cell.
"""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="recurrentgemma-2b", d_model=2560, n_heads=10, n_kv_heads=1,
    d_head=256, d_ff=7680, vocab_size=256000,
    groups=(ScanGroup(("rglru", "rglru", "rglru_attn"), 8),
            ScanGroup(("rglru",), 2)),
    window=2048, lru_width=2560, conv_width=4, act="gelu",
    scale_embed=True, sub_quadratic=True,
)

REDUCED = ModelConfig(
    name="recurrentgemma-2b-reduced", d_model=128, n_heads=2, n_kv_heads=1,
    d_head=64, d_ff=256, vocab_size=512,
    groups=(ScanGroup(("rglru", "rglru", "rglru_attn"), 1),
            ScanGroup(("rglru",), 1)),
    window=32, lru_width=128, act="gelu", scale_embed=True,
    sub_quadratic=True,
)

register("recurrentgemma-2b", ArchSpec(config=FULL, reduced=REDUCED))
