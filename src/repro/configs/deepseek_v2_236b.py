"""deepseek-v2-236b [moe] 60L d=5120 128H d_ff=1536(expert) vocab=102400,
MoE 160e top-6 — MLA kv_lora=512, 2 shared + 160 routed [arXiv:2405.04434;hf].

The strongest technique-level match for the paper (DESIGN.md §5): MoE
dispatch/combine are sparse one-hot x dense products — the same shape as
F_SCU's C^T X and Protocol 2 — implemented sort-based (nnz-proportional).
Decode uses the absorbed-matmul compressed-KV path (576 values/token).
First layer uses a dense MLP (d_ff 12288), per the released model.
"""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="deepseek-v2-236b", d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    groups=(ScanGroup(("mla_dense",), 1), ScanGroup(("mla",), 59)),
    q_lora=1536, kv_lora=512, rope_head_dim=64, nope_head_dim=128,
    v_head_dim=128,
    n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
    d_ff_dense_first=12288, capacity_factor=1.25, act="silu",
    moe_dispatch="per_example",   # local routing sorts (see granite-moe)
)

REDUCED = ModelConfig(
    name="deepseek-v2-236b-reduced", d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    groups=(ScanGroup(("mla_dense",), 1), ScanGroup(("mla",), 1)),
    q_lora=64, kv_lora=32, rope_head_dim=16, nope_head_dim=32, v_head_dim=32,
    n_experts=8, top_k=2, n_shared_experts=1, d_ff_expert=64,
    d_ff_dense_first=256,
)

register("deepseek-v2-236b", ArchSpec(
    config=FULL, reduced=REDUCED,
    skip_shapes=("long_500k",),
    skip_reason="full-attention (MLA is still quadratic-history) "
                "(DESIGN.md §5)"))
