"""seamless-m4t-medium [audio] 12L d=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

Modality frontend is a STUB per the assignment: input_specs provides
precomputed speech-frame embeddings (B, S, d_model) feeding the encoder;
the decoder is a standard causal stack with cross-attention.
"""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="seamless-m4t-medium", d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206,
    groups=(ScanGroup(("xattn",), 12),),         # 12 decoder layers
    enc_dec=True, n_enc_layers=12, frontend="audio", act="gelu",
)

REDUCED = ModelConfig(
    name="seamless-m4t-medium-reduced", d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512,
    groups=(ScanGroup(("xattn",), 2),),
    enc_dec=True, n_enc_layers=2, frontend="audio", act="gelu",
)

register("seamless-m4t-medium", ArchSpec(
    config=FULL, reduced=REDUCED,
    skip_shapes=("long_500k",),
    skip_reason="full-attention enc-dec (DESIGN.md §5); decode shapes run "
                "(it has a decoder)"))
