"""granite-moe-3b-a800m [moe] 32L d=1536 24H (GQA kv=8) d_ff=512(expert)
vocab=49155, MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

(The assignment lists 'MoE 40e top-8' in the config field and '32 experts'
in the free text; we follow the config field: 40 experts, padded to 48 for
16-way expert parallelism — pad experts receive no tokens.)
"""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="granite-moe-3b-a800m", d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    groups=(ScanGroup(("moe_attn",), 32),),
    n_experts=40, top_k=8, d_ff_expert=512, capacity_factor=1.25,
    act="silu",
    # §Perf iter 3: per-example dispatch keeps the routing sort local to
    # each batch shard (a global sort over sharded tokens is a distributed
    # sort — it was this cell's bottleneck). 1.24x step on the pod.
    moe_dispatch="per_example",
)

REDUCED = ModelConfig(
    name="granite-moe-3b-a800m-reduced", d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    groups=(ScanGroup(("moe_attn",), 2),),
    n_experts=8, top_k=2, d_ff_expert=64,
)

register("granite-moe-3b-a800m", ArchSpec(
    config=FULL, reduced=REDUCED,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention arch (DESIGN.md §5)"))
