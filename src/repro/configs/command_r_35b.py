"""command-r-35b [dense] 40L d=8192 64H (GQA kv=8) d_ff=22528 vocab=256000
— GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="command-r-35b", d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    groups=(ScanGroup(("attn",), 40),),
    rope_theta=8000000.0, attn_bias=False, act="silu",
)

REDUCED = ModelConfig(
    name="command-r-35b-reduced", d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    groups=(ScanGroup(("attn",), 2),),
)

register("command-r-35b", ArchSpec(
    config=FULL, reduced=REDUCED,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention arch (DESIGN.md §5)"))
