"""granite-34b [dense] 88L d=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
— llama-arch, code [arXiv:2405.04324; hf]."""
from repro.configs.base import ArchSpec, ModelConfig, ScanGroup, register

FULL = ModelConfig(
    name="granite-34b", d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    groups=(ScanGroup(("attn",), 88),),
    rope_theta=10000.0, act="silu",
)

REDUCED = ModelConfig(
    name="granite-34b-reduced", d_model=128, n_heads=4, n_kv_heads=1,
    d_ff=256, vocab_size=512,
    groups=(ScanGroup(("attn",), 2),),
)

register("granite-34b", ArchSpec(
    config=FULL, reduced=REDUCED,
    skip_shapes=("long_500k",),
    skip_reason="pure full-attention arch: 500k dense decode is quadratic-"
                "history; skipped per assignment (DESIGN.md §5)"))
