"""Serving-plane checkpoint: exactly-once responses across a crash
(DESIGN.md §14).

A `ServeCheckpointer` owns two artifacts inside its directory:

* **`bank.npz`** — the provision-time `TripleBank` snapshot, written once
  right after `ScoringService.warm()` provisions the ladder (atomic tmp +
  rename). The bank file is never rewritten while serving: consumption is
  tracked in the journal instead, so a crash can't tear it.
* **`journal/batch_NNNNNNNN.npz`** — one atomically-published file per
  drain batch, holding every response the batch resolved (request id,
  labels, scores, rows, error) PLUS the bank's cumulative per-class
  consumed-request counts at publish time.

Restart contract (the exactly-once argument):

1. *Replay* — a journaled request id is answered verbatim from the
   journal; the handler never runs again, no triple is drawn.
2. *Realign* — the reloaded bank starts at the provision-time snapshot;
   `TripleBank.discard(latest consumed counts)` drains exactly the
   requests the dead process consumed, so no word is ever served twice.
3. *Re-score* — a request that died in flight (drawn but not journaled)
   re-draws the SAME words after realignment, because the journal's
   counts stop *before* its draw — so its eventual response is bit-exact
   with what the dead process would have answered.

Journal publish happens BEFORE the response is exposed to the caller, so
the only crash windows are (a) before publish — the request is re-scored
identically — and (b) after publish — the request is replayed. Either
way the client observes exactly one response, and it is the same one.

`after_record(total_responses, path)` is a test seam mirroring
`FitCheckpointer.after_save`: chaos tests use it to `os._exit` the
serving process deterministically right after a journal publish.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.checkpoint.store import fsync_path
from repro.core.triples import TripleBank, _key_from_str, _key_to_str
from repro.obs import trace as _trace

JOURNAL_FORMAT = "repro.servejournal"
JOURNAL_VERSION = 1


class ServeCheckpointer:
    """Atomic response journal + bank snapshot for a `ScoringService`."""

    def __init__(self, directory: str, *, after_record=None):
        self.dir = directory
        self.journal_dir = os.path.join(directory, "journal")
        os.makedirs(self.journal_dir, exist_ok=True)
        self.after_record = after_record
        self._batch = self._next_batch()
        self.recorded = 0           # responses journaled THIS incarnation

    # -- bank snapshot ---------------------------------------------------
    @property
    def bank_path(self) -> str:
        return os.path.join(self.dir, "bank.npz")

    def has_bank(self) -> bool:
        return os.path.exists(self.bank_path)

    def save_bank(self, bank: TripleBank) -> None:
        tmp = self.bank_path + ".tmp"
        bank.save(tmp)
        fsync_path(tmp)                          # payload durable first
        os.replace(tmp, self.bank_path)          # atomic publish
        fsync_path(self.dir)

    def load_bank(self, **kw) -> TripleBank:
        return TripleBank.load(self.bank_path, **kw)

    # -- journal ---------------------------------------------------------
    def _next_batch(self) -> int:
        mx = -1
        for name in os.listdir(self.journal_dir):
            if name.startswith("batch_") and name.endswith(".npz"):
                mx = max(mx, int(name[6:-4]))
        return mx + 1

    def record(self, responses, consumed: dict) -> str:
        """Atomically journal one drain batch's responses together with
        the bank's CUMULATIVE per-class consumed counts at publish time.
        Later batches carry larger counts, so the newest file alone
        realigns a reloaded bank."""
        with _trace.span("checkpoint.journal", batch=int(self._batch),
                         responses=len(responses)):
            return self._record(responses, consumed)

    def _record(self, responses, consumed: dict) -> str:
        arrays = {}
        metas = []
        for j, r in enumerate(responses):
            arrays[f"r{j}_labels"] = np.asarray(r.labels, np.int64)
            if r.scores is not None:
                arrays[f"r{j}_scores"] = np.asarray(r.scores, np.float64)
            metas.append({"rid": int(r.request_id), "rows": int(r.rows),
                          "error": r.error,
                          "has_scores": r.scores is not None})
        manifest = {"format": JOURNAL_FORMAT, "version": JOURNAL_VERSION,
                    "responses": metas,
                    "consumed": {_key_to_str(k): int(v)
                                 for k, v in consumed.items()}}
        final = os.path.join(self.journal_dir, f"batch_{self._batch:08d}.npz")
        self._batch += 1
        tmp = final + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, manifest=np.frombuffer(
                json.dumps(manifest).encode(), np.uint8), **arrays)
            f.flush()
            os.fsync(f.fileno())                 # payload durable first
        os.replace(tmp, final)                   # atomic publish
        fsync_path(self.journal_dir)
        self.recorded += len(metas)
        if self.after_record is not None:
            self.after_record(self.recorded, final)
        return final

    def load_journal(self) -> tuple[dict, dict]:
        """Read every published batch: `(rid -> ScoringResponse replayed
        verbatim, latest cumulative consumed counts)`. A `.tmp` straggler
        from a mid-write crash is ignored — it was never published."""
        from repro.serve.service import ScoringResponse
        out: dict[int, ScoringResponse] = {}
        consumed: dict = {}
        names = sorted(n for n in os.listdir(self.journal_dir)
                       if n.startswith("batch_") and n.endswith(".npz"))
        for name in names:
            with np.load(os.path.join(self.journal_dir, name)) as z:
                manifest = json.loads(bytes(z["manifest"]).decode())
                if manifest.get("format") != JOURNAL_FORMAT \
                        or manifest.get("version") != JOURNAL_VERSION:
                    raise ValueError(
                        f"unrecognized serve journal {name!r}: "
                        f"{manifest.get('format')!r} "
                        f"v{manifest.get('version')!r}")
                for j, m in enumerate(manifest["responses"]):
                    scores = z[f"r{j}_scores"] if m["has_scores"] else None
                    out[int(m["rid"])] = ScoringResponse(
                        int(m["rid"]), z[f"r{j}_labels"], scores,
                        int(m["rows"]), m["error"])
                consumed = {_key_from_str(k): int(v)
                            for k, v in manifest["consumed"].items()}
        return out, consumed
