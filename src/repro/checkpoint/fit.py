"""Mid-fit checkpoint/resume for the secure k-means loop (DESIGN.md §13).

A `FitState` is everything a killed fit needs to finish bit-exact:

* the secret-shared model — mu shares, and (mid-iteration, minibatch only)
  the four partial accumulator shares + completed batches' assignment
  shares;
* the cursor — completed iterations, completed batches inside the current
  iteration;
* the dealer stream positions — NOT raw `bit_generator` states but the
  per-class consumed-request counts. Every dealer derives its class streams
  from `(seed, class_key)` and draws a fixed word count per request, so
  `_advanced_rng(seed, key, consumed)` reconstructs the exact position with
  one PCG64 jump; the counts themselves are recomputable from the plan ×
  cursor (the resume path recomputes them and cross-checks the stored copy
  as an integrity test);
* the bookkeeping — CommLog tallies and dealer counters, restored so a
  resumed fit's final accounting equals the uninterrupted run's.

Atomicity follows `CheckpointStore`: arrays + manifest land in
`step_XXXXXXXXXX.tmp/`, then one `os.rename` publishes — a writer killed
mid-save can never leave a half-checkpoint that `latest()` picks up.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil

import numpy as np

from repro.checkpoint.store import fsync_path, fsync_tree
from repro.core import faultpoints as _fp
from repro.core.triples import _key_from_str, _key_to_str
from repro.obs import trace as _trace


class FingerprintMismatch(ValueError):
    """Checkpoint was written under a different (cfg, data-shape)
    fingerprint. A config error, not disk damage — recovery must refuse
    loudly instead of falling back to an older step."""


@dataclasses.dataclass
class FitState:
    """One resumable cut of a fit. `iteration` counts COMPLETED iterations;
    `batch` counts completed batches inside iteration `iteration + 1` (0 at
    an iteration boundary — the full-batch loop only ever writes 0)."""

    iteration: int
    batch: int
    mu0: np.ndarray
    mu1: np.ndarray
    counters: dict          # {"n_matmul": int, "n_mul": int, "n_bin": int}
    comm: dict              # CommLog.state()
    advance: dict           # {class_key tuple: consumed request count}
    fingerprint: str = ""
    acc: list | None = None         # 4 partial accumulator share arrays
    c0_parts: list = dataclasses.field(default_factory=list)
    c1_parts: list = dataclasses.field(default_factory=list)

    @property
    def step(self) -> int:
        return self.iteration * 1_000_000 + self.batch


class FitCheckpointer:
    """Atomic keep-N store of `FitState`s + the save policy.

    `every`: checkpoint at the end of every Nth iteration. `batch_every`:
    additionally checkpoint after every Nth completed minibatch — only
    legal on the sequential executor (`pipeline=False`); the pipelined
    executor runs batch t+1's host exchange before batch t's accumulate, so
    mid-iteration the live CommLog is not the canonical prefix a resume
    must restore (`core/kmeans.py` enforces this with a `ValueError`).
    `after_save(state, path)` is a test seam — chaos tests use it to kill
    the process deterministically right after a publish."""

    def __init__(self, directory: str, *, every: int = 1,
                 batch_every: int | None = None, keep: int = 3,
                 fingerprint: str = "", after_save=None):
        self.dir = directory
        self.every = max(1, int(every))
        self.batch_every = None if batch_every is None \
            else max(1, int(batch_every))
        self.keep = int(keep)
        self.fingerprint = fingerprint
        self.after_save = after_save
        os.makedirs(directory, exist_ok=True)

    # -- policy ----------------------------------------------------------
    def want_iter(self, it: int, iters: int) -> bool:
        """Checkpoint after completed iteration `it`? Never after the last:
        the fit is about to return its result anyway."""
        return it < iters and it % self.every == 0

    def want_batch(self, b: int, n_batches: int) -> bool:
        """Checkpoint after completed batch `b` (1-based)? Never after the
        last — that cut is the iteration boundary."""
        return (self.batch_every is not None and b < n_batches
                and b % self.batch_every == 0)

    # -- persistence -----------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def save(self, state: FitState) -> str:
        with _trace.span("checkpoint.fit_save", step=int(state.step),
                         iteration=int(state.iteration),
                         batch=int(state.batch)):
            return self._save(state)

    def _save(self, state: FitState) -> str:
        arrays = {"mu0": np.asarray(state.mu0, np.uint64),
                  "mu1": np.asarray(state.mu1, np.uint64)}
        if state.acc is not None:
            for i, a in enumerate(state.acc):
                arrays[f"acc{i}"] = np.asarray(a, np.uint64)
        for t, (a0, a1) in enumerate(zip(state.c0_parts, state.c1_parts)):
            arrays[f"cp{t}_s0"] = np.asarray(a0, np.uint64)
            arrays[f"cp{t}_s1"] = np.asarray(a1, np.uint64)
        manifest = {
            "iteration": int(state.iteration),
            "batch": int(state.batch),
            "fingerprint": state.fingerprint or self.fingerprint,
            "counters": {k: int(v) for k, v in state.counters.items()},
            "comm": state.comm,
            "advance": {_key_to_str(k): int(v)
                        for k, v in state.advance.items()},
            "has_acc": state.acc is not None,
            "n_parts": len(state.c0_parts),
        }
        final = self._path(state.step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "state.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        fsync_tree(tmp)                     # payload durable before publish
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)               # atomic publish
        fsync_path(self.dir)                # the rename itself durable
        self._gc()
        if self.after_save is not None:
            self.after_save(state, final)
        _fp.probe("fit.publish")            # chaos kill-point: post-publish
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.dir, name,
                                                    "manifest.json")):
                out.append(int(name[5:]))
        return sorted(out)

    def latest(self) -> FitState | None:
        """Newest LOADABLE step: a published step whose arrays turn out
        torn on disk (pre-fsync writer + machine crash) is skipped with a
        warning and the previous step is recovered instead. Fingerprint
        mismatches are NOT skipped — that's a config error, not damage."""
        for s in reversed(self.all_steps()):
            try:
                return self.load(s)
            except FingerprintMismatch:
                raise                   # config error: refuse loudly
            except Exception as e:      # torn npz/manifest: fall back
                import warnings
                warnings.warn(f"checkpoint step {s} unreadable ({e}); "
                              "falling back to the previous step")
        return None

    def step_at_or_before(self, step: int) -> int | None:
        """Largest published step ≤ `step` — what the resume negotiation
        loads after both parties agree on `min(step)` (a party may hold a
        NEWER published step than the agreement; it must rewind to one
        the peer also witnessed). None == no such step: start fresh."""
        ok = [s for s in self.all_steps() if s <= int(step)]
        return max(ok) if ok else None

    def load(self, step: int) -> FitState:
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        if self.fingerprint and manifest["fingerprint"] \
                and manifest["fingerprint"] != self.fingerprint:
            raise FingerprintMismatch(
                f"checkpoint fingerprint {manifest['fingerprint']} does not "
                f"match this fit's config fingerprint {self.fingerprint} — "
                "refusing to resume a different (cfg, data-shape) run")
        with np.load(os.path.join(path, "state.npz")) as z:
            mu0, mu1 = z["mu0"], z["mu1"]
            acc = [z[f"acc{i}"] for i in range(4)] \
                if manifest["has_acc"] else None
            c0 = [z[f"cp{t}_s0"] for t in range(manifest["n_parts"])]
            c1 = [z[f"cp{t}_s1"] for t in range(manifest["n_parts"])]
        return FitState(
            iteration=int(manifest["iteration"]),
            batch=int(manifest["batch"]),
            mu0=mu0, mu1=mu1,
            counters={k: int(v) for k, v in manifest["counters"].items()},
            comm=manifest["comm"],
            advance={_key_from_str(k): int(v)
                     for k, v in manifest["advance"].items()},
            fingerprint=manifest["fingerprint"],
            acc=acc, c0_parts=c0, c1_parts=c1)
