"""Fault-tolerant checkpointing: atomic, mesh-agnostic, keep-N.

Design for 1000+ nodes (DESIGN.md §6):
* atomicity — write to `step_XXXX.tmp/` then os.rename (POSIX-atomic dir
  swap): a preempted writer can never leave a half-checkpoint that restore
  would pick up;
* mesh-agnostic — leaves are saved as full (unsharded) arrays keyed by
  pytree path, so a checkpoint written on a (16,16) mesh restores onto
  (2,16,16) or a single CPU device (elastic scaling). At real 405B scale the
  same layout shards per-leaf across hosts — the manifest already records
  per-leaf shapes/dtypes to support that extension;
* keep-N garbage collection + monotonic step index in a manifest;
* restore validates a config fingerprint to refuse foreign checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def fsync_path(path: str) -> None:
    """fsync one file or directory (directory fsync persists the entry
    names themselves — rename atomicity is only durable once the parent
    directory is synced)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return                       # non-POSIX / disappeared: best effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_tree(directory: str) -> None:
    """fsync every regular file under `directory`, then the directory.
    Called on the `.tmp` staging dir BEFORE the atomic rename: without
    it, the rename can land in the journal while the payload pages are
    still dirty in the page cache — a crash then publishes a step whose
    arrays are torn on disk. After the tree sync, rename + parent-dir
    sync makes the publish itself durable."""
    for root, _dirs, files in os.walk(directory):
        for name in files:
            fsync_path(os.path.join(root, name))
        fsync_path(root)


def config_fingerprint(cfg) -> str:
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3,
                 fingerprint: str = ""):
        self.dir = directory
        self.keep = keep
        self.fingerprint = fingerprint
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree) -> str:
        flat, _ = _flatten(tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        # numpy has no native bfloat16: store as f32 (lossless upcast);
        # restore() downcasts to the model's dtype.
        def host(v):
            a = np.asarray(v)
            if a.dtype.name == "bfloat16":
                a = a.astype(np.float32)
            return a
        arrays = {k: host(v) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "leaves.npz"),
                 **{str(i): a for i, a in enumerate(arrays.values())})
        manifest = {
            "step": step,
            "fingerprint": self.fingerprint,
            "keys": list(arrays.keys()),
            "shapes": [list(a.shape) for a in arrays.values()],
            "dtypes": [str(a.dtype) for a in arrays.values()],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        fsync_tree(tmp)                     # payload durable before publish
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)               # atomic publish
        fsync_path(self.dir)                # the rename itself durable
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ #
    def restore(self, step: int, like_tree):
        """Restore into the structure (and shardings, if the leaves of
        `like_tree` are sharded arrays) of `like_tree`."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        if self.fingerprint and manifest["fingerprint"] != self.fingerprint:
            raise ValueError(
                f"checkpoint fingerprint {manifest['fingerprint']} does not "
                f"match config {self.fingerprint}")
        data = np.load(os.path.join(path, "leaves.npz"))
        arrays = {k: data[str(i)] for i, k in enumerate(manifest["keys"])}
        flat_like, treedef = _flatten(like_tree)
        if set(flat_like.keys()) != set(arrays.keys()):
            missing = set(flat_like) ^ set(arrays)
            raise ValueError(f"checkpoint/model structure mismatch: {missing}")
        leaves = []
        for k, like in flat_like.items():
            a = arrays[k].astype(like.dtype)
            if hasattr(like, "sharding"):
                a = jax.device_put(a, like.sharding)
            leaves.append(a)
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like_tree), leaves)
