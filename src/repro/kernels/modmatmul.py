"""Pallas TPU kernel: secret-share ring matmul mod 2^32 / 2^64.

This is the online-phase hot spot of every Beaver matmul (E@F, U_i@F, E@V_i
— paper Sec 4.1): an *integer* matmul whose accumulator must wrap mod 2^l.

TPU adaptation (DESIGN.md §3): the MXU has no 64-bit integer path, so the
u32 ring matmul is decomposed into 16-bit limbs —

    a*b mod 2^32 = ll + ((lh + hl) << 16)        (hh*2^32 vanishes)

where ll/lh/hl are int32 matmuls of 16-bit limb matrices: products fit and
int32 accumulation wraparound IS the ring reduction. The u64 variant uses the
same blocking with native uint64 lanes (valid in interpret mode / CPU; on a
real TPU it extends to a 4-limb cascade — same structure, 10 partial matmuls).

Blocking: (bm x bk) @ (bk x bn) MXU-aligned tiles (multiples of 128 on the
lane dim), f32-free, VMEM accumulator scratch carried over the k grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_u32(a_ref, b_ref, o_ref, acc_ref, *, n_kblocks: int):
    """Grid (m_blocks, n_blocks, k_blocks); acc carried across k_blocks."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                      # (bm, bk) uint32
    b = b_ref[...]                      # (bk, bn) uint32
    mask = jnp.uint32(0xFFFF)
    a_lo, a_hi = (a & mask).astype(jnp.int32), (a >> 16).astype(jnp.int32)
    b_lo, b_hi = (b & mask).astype(jnp.int32), (b >> 16).astype(jnp.int32)
    dot = functools.partial(jax.lax.dot_general,
                            dimension_numbers=(((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.int32)
    ll = dot(a_lo, b_lo)
    lh = dot(a_lo, b_hi)
    hl = dot(a_hi, b_lo)
    prod = ll.astype(jnp.uint32) + ((lh + hl).astype(jnp.uint32) << 16)
    acc_ref[...] += prod

    @pl.when(kb == n_kblocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _kernel_u64(a_ref, b_ref, o_ref, acc_ref, *, n_kblocks: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # native uint64 lanes (interpret/CPU); TPU: 4x16-bit limb cascade
    acc_ref[...] += jnp.matmul(a_ref[...], b_ref[...])

    @pl.when(kb == n_kblocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def modmatmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128, bk: int = 128,
              bn: int = 128, interpret: bool = True) -> jnp.ndarray:
    """Ring matmul; dtype of `a` selects the u32 or u64 ring.

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    n, d = a.shape
    d2, k = b.shape
    assert d == d2 and a.dtype == b.dtype
    assert n % bm == 0 and d % bk == 0 and k % bn == 0, (a.shape, b.shape)
    kern = _kernel_u32 if a.dtype == jnp.uint32 else _kernel_u64
    grid = (n // bm, k // bn, d // bk)
    return pl.pallas_call(
        functools.partial(kern, n_kblocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((bk, bn), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), a.dtype)],
        interpret=interpret,
    )(a, b)
