"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def modmatmul_u32(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Ring matmul mod 2^32 (uint32 wraparound is the reduction)."""
    return jnp.matmul(a.astype(jnp.uint32), b.astype(jnp.uint32))


def modmatmul_u64(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Ring matmul mod 2^64."""
    return jnp.matmul(a.astype(jnp.uint64), b.astype(jnp.uint64))


def esd(x: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Fused distance D' = ||mu_j||^2 - 2 x_i . mu_j   (paper Eq. 3).

    x: (n, d) f32, mu: (k, d) f32 -> (n, k) f32.
    """
    u = (mu.astype(jnp.float32) ** 2).sum(-1)
    return u[None, :] - 2.0 * x.astype(jnp.float32) @ mu.astype(jnp.float32).T


def argmin_onehot(d: jnp.ndarray) -> jnp.ndarray:
    """(n, k) distances -> (n, k) one-hot of the row argmin (first-min wins,
    matching the tournament's tie-break used in the plaintext path)."""
    idx = jnp.argmin(d, axis=-1)
    return (jnp.arange(d.shape[-1])[None, :] == idx[:, None]).astype(jnp.int32)


def spmm_ell(blocks: jnp.ndarray, idx: jnp.ndarray, counts: jnp.ndarray,
             y: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """Blocked-ELL sparse x dense oracle.

    blocks: (nrb, maxb, bm, bk)  non-empty tiles of X, row-block major
    idx:    (nrb, maxb) int32    column-block index of each tile
    counts: (nrb,) int32         how many tiles are real in each row block
    y:      (d, k)
    returns (nrb*bm, k)[:n_rows]
    """
    nrb, maxb, bm, bk = blocks.shape
    k = y.shape[1]
    out = jnp.zeros((nrb, bm, k), y.dtype)
    for i in range(nrb):
        acc = jnp.zeros((bm, k), y.dtype)
        for j in range(maxb):
            yb = jax.lax.dynamic_slice(
                y, (idx[i, j].astype(jnp.int32) * jnp.int32(bk), jnp.int32(0)),
                (bk, k))
            contrib = blocks[i, j].astype(y.dtype) @ yb
            acc = acc + jnp.where(j < counts[i], 1, 0).astype(y.dtype) * contrib
        out = out.at[i].set(acc)
    return out.reshape(nrb * bm, k)[:n_rows]
