"""Pallas TPU kernel: fused Euclidean-squared distance (paper Eq. 3, F_ESD).

Computes  D'[i, j] = ||mu_j||^2 - 2 * <x_i, mu_j>  in ONE VMEM pass: the
centroid-norm term U is accumulated from the same mu tiles that feed the
matmul, so mu is read from HBM exactly once and the (n, k) distance tile is
produced directly — no separate norm pass, no intermediate X@mu^T buffer.

Used by the plaintext oracle path, centroid init, and the dealer-assisted
deployment mode; the secret-shared online path runs the same shape through
kernels/modmatmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, mu_ref, o_ref, acc_ref, u_ref, *, n_kblocks: int):
    """Grid (n_blocks, k_blocks, d_blocks). acc: -2*X@mu^T; u: ||mu||^2."""
    db = pl.program_id(2)

    @pl.when(db == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    x = x_ref[...]                       # (bm, bd) f32
    mu = mu_ref[...]                     # (bn, bd) f32  (k-major tile)
    acc_ref[...] += jax.lax.dot_general(
        x, mu, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    u_ref[...] += (mu * mu).sum(axis=1, keepdims=True).T  # (1, bn)

    @pl.when(db == n_kblocks - 1)
    def _flush():
        o_ref[...] = u_ref[...] - 2.0 * acc_ref[...]


def esd(x: jnp.ndarray, mu: jnp.ndarray, *, bm: int = 128, bd: int = 128,
        bn: int = 128, interpret: bool = True) -> jnp.ndarray:
    """x: (n, d) f32, mu: (k, d) f32 -> (n, k) f32 distances (ops.py pads)."""
    n, d = x.shape
    k, d2 = mu.shape
    assert d == d2
    assert n % bm == 0 and d % bd == 0 and k % bn == 0, (x.shape, mu.shape)
    grid = (n // bm, k // bn, d // bd)
    return pl.pallas_call(
        functools.partial(_kernel, n_kblocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, db: (i, db)),
            pl.BlockSpec((bn, bd), lambda i, j, db: (j, db)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, db: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((1, bn), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), mu.astype(jnp.float32))
