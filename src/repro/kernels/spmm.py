"""Pallas TPU kernel: blocked-ELL sparse x dense matmul (paper Sec 4.3).

TPU adaptation of the paper's sparsity optimization (DESIGN.md §3): CSR
gathers are GPU-idiomatic; the TPU-native layout is *blocked-ELL* — the
sparse matrix is cut into (bm x bk) tiles, only non-empty tiles are stored
(row-block major, padded to max_blocks per row block), and each tile is a
dense MXU-aligned matmul. HBM->VMEM traffic and MXU work are proportional to
the number of NON-EMPTY blocks, which is the paper's nnz-proportional-cost
insight transplanted to the TPU memory hierarchy.

The dense operand Y (the small k x d centroid block in K-means; the paper's
"shape of Y is much smaller than X") is held fully in VMEM and indexed
dynamically with the tile's column-block id — valid while d*k*4B fits the
~16 MB VMEM budget, which `ops.spmm` asserts.

Supports f32 (plaintext path) and the u32 ring via the same 16-bit limb
trick as kernels/modmatmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _tile_matmul(xb, yb, dtype):
    if dtype == jnp.uint64:
        # native uint64 lanes (interpret/CPU); on a real TPU this tile
        # matmul extends to the 4-limb cascade of kernels/modmatmul
        return jnp.matmul(xb, yb)
    if dtype == jnp.uint32:
        mask16 = jnp.uint32(0xFFFF)
        x_lo = (xb & mask16).astype(jnp.int32)
        x_hi = (xb >> 16).astype(jnp.int32)
        y_lo = (yb & mask16).astype(jnp.int32)
        y_hi = (yb >> 16).astype(jnp.int32)
        dot = functools.partial(
            jax.lax.dot_general,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return dot(x_lo, y_lo).astype(jnp.uint32) \
            + ((dot(x_lo, y_hi) + dot(x_hi, y_lo)).astype(jnp.uint32) << 16)
    return jax.lax.dot_general(
        xb, yb, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _kernel(idx_ref, cnt_ref, blocks_ref, y_ref, o_ref, *, bk: int,
            max_blocks: int, group: int, dtype):
    """One grid cell handles `group` row blocks. group=1 is the TPU tiling
    (one MXU-aligned row block per cell); group=nrb collapses the grid to a
    single cell for interpret mode, where the emulation's fixed per-grid-step
    cost — not the tile math — dominated the old (nrb,)-grid runtime 60x."""
    bm = blocks_ref.shape[2]
    k = y_ref.shape[1]

    def row_block(g):
        def body(j, acc):
            start = idx_ref[g, j].astype(jnp.int32) * jnp.int32(bk)
            yb = pl.load(y_ref, (pl.ds(start, bk), slice(None)))
            xb = blocks_ref[g, j]
            contrib = _tile_matmul(xb, yb, dtype)
            keep = (j < cnt_ref[g]).astype(contrib.dtype)
            return acc + keep * contrib
        return jax.lax.fori_loop(0, max_blocks, body, jnp.zeros((bm, k), dtype))

    if group == 1:
        o_ref[0] = row_block(0)
    else:
        def row(g, carry):
            pl.store(o_ref, (g, slice(None), slice(None)), row_block(g))
            return carry
        jax.lax.fori_loop(0, group, row, 0)


@functools.partial(jax.jit, static_argnames=("interpret", "group"))
def spmm_ell(blocks: jnp.ndarray, idx: jnp.ndarray, counts: jnp.ndarray,
             y: jnp.ndarray, *, interpret: bool = True,
             group: int | None = None) -> jnp.ndarray:
    """blocks (nrb, maxb, bm, bk), idx (nrb, maxb) i32, counts (nrb,) i32,
    y (d, k) -> (nrb*bm, k). dtype of `blocks` selects f32 / u32 / u64.

    `group` row blocks are processed per grid cell (must divide nrb);
    default: all of them in interpret mode (single cell — the emulation's
    per-cell cost dwarfs the tile work), one per cell on a real TPU."""
    nrb, maxb, bm, bk = blocks.shape
    d, k = y.shape
    if group is None:
        group = nrb if interpret else 1
    assert nrb % group == 0, (nrb, group)
    if blocks.dtype in (jnp.uint32, jnp.uint64):
        out_dtype = blocks.dtype
    else:
        out_dtype = jnp.float32
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, max_blocks=maxb, group=group,
                          dtype=out_dtype),
        grid=(nrb // group,),
        in_specs=[
            pl.BlockSpec((group, maxb), lambda i: (i, 0)),       # idx
            pl.BlockSpec((group,), lambda i: (i,)),              # counts
            pl.BlockSpec((group, maxb, bm, bk), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((d, k), lambda i: (0, 0)),              # whole Y
        ],
        out_specs=pl.BlockSpec((group, bm, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nrb, bm, k), out_dtype),
        interpret=interpret,
    )(idx, counts, blocks, y.astype(out_dtype))
    return out.reshape(nrb * bm, k)


# ---------------------------------------------------------------------------
# layout conversion: dense / CSR -> blocked-ELL
# ---------------------------------------------------------------------------

def dense_to_ell(x: np.ndarray, bm: int = 8, bk: int = 128):
    """Pack a dense matrix into blocked-ELL (numpy, host-side, offline)."""
    n, d = x.shape
    n_pad = (-n) % bm
    d_pad = (-d) % bk
    xp = np.pad(x, ((0, n_pad), (0, d_pad)))
    nrb, ncb = xp.shape[0] // bm, xp.shape[1] // bk
    tiles = xp.reshape(nrb, bm, ncb, bk).transpose(0, 2, 1, 3)  # (nrb,ncb,bm,bk)
    nonempty = (tiles != 0).any(axis=(2, 3))                    # (nrb, ncb)
    counts = nonempty.sum(1).astype(np.int32)
    maxb = max(1, int(counts.max()))
    blocks = np.zeros((nrb, maxb, bm, bk), x.dtype)
    idx = np.zeros((nrb, maxb), np.int32)
    for i in range(nrb):
        cols = np.flatnonzero(nonempty[i])
        blocks[i, :len(cols)] = tiles[i, cols]
        idx[i, :len(cols)] = cols
    return blocks, idx, counts


def csr_to_ell(indptr, indices, data, shape, bm: int = 8, bk: int = 128):
    """CSR -> blocked-ELL without densifying: memory stays proportional to
    the number of non-empty (bm x bk) tiles, never to n*d. Fully vectorized
    (one sort over nnz), so the offline pack keeps up with large inputs."""
    n, d = shape
    indptr = np.asarray(indptr, np.int64)
    indices = np.asarray(indices, np.int64)
    data = np.asarray(data)
    nrb = -(-n // bm)
    ncb = -(-d // bk)
    nnz = len(data)
    if nnz == 0:
        return (np.zeros((nrb, 1, bm, bk), data.dtype),
                np.zeros((nrb, 1), np.int32), np.zeros((nrb,), np.int32))
    rows = np.repeat(np.arange(n), np.diff(indptr))
    rb, cb = rows // bm, indices // bk
    tile_id = rb * ncb + cb
    uniq, inv = np.unique(tile_id, return_inverse=True)
    counts = np.bincount(uniq // ncb, minlength=nrb).astype(np.int32)
    maxb = max(1, int(counts.max()))
    # slot of each unique tile within its row block (uniq is sorted, so
    # tiles of one row block are contiguous)
    starts = np.zeros(nrb, np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    slot_of_uniq = np.arange(len(uniq)) - starts[uniq // ncb]
    blocks = np.zeros((nrb, maxb, bm, bk), data.dtype)
    idx = np.zeros((nrb, maxb), np.int32)
    idx[uniq // ncb, slot_of_uniq] = (uniq % ncb).astype(np.int32)
    blocks[rb, slot_of_uniq[inv], rows % bm, indices % bk] = data
    return blocks, idx, counts
