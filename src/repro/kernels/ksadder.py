"""Pallas TPU kernel: fused Kogge-Stone carry network for the B-share MSB
(paper F^k_min's CMP — the S2 hot spot).

Each party's local work per AND level of the secure adder is a handful of
bitwise ops over bit-packed uint64 lanes (protocol.py msb_carry). Fusing all
6 levels' LOCAL pieces (the Beaver shares recombination given the already-
exchanged masked operands E_l, F_l per level) into one VMEM pass removes 12
HBM round-trips per CMP over the (n, m) comparison tensor.

Inputs are per-level public E/F masks + this party's triple shares
(u, v, z), i.e. exactly the online-phase state after the exchange rounds;
the kernel computes the party's share of the final carry-out word. Validated
in interpret mode against the pure-jnp oracle derived from protocol.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LEVELS = (1, 2, 4, 8, 16, 32)


def _and_share(e, f, u, v, z, party0: bool):
    """One party's Beaver AND recombination on packed uint64 words."""
    out = z ^ (u & f) ^ (e & v)
    if party0:
        out = out ^ (e & f)
    return out


def _kernel(x_ref, e0_ref, f0_ref, u0_ref, v0_ref, z0_ref,
            el_ref, fl_ref, ul_ref, vl_ref, zl_ref, o_ref, *, party0: bool):
    """x: this party's arithmetic-share word (the adder input bits).
    Level 0 = initial g = AND(x, y); levels 1..6 = the stacked (g,p) ANDs.
    All E/F are the publicly reconstructed masked operands."""
    g = _and_share(e0_ref[...], f0_ref[...], u0_ref[...], v0_ref[...],
                   z0_ref[...], party0)
    p = x_ref[...]                                # p-share: xor of inputs
    for li, s in enumerate(LEVELS):
        # batched AND pair: lhs = [p, p]; rhs = [g << s, p << s]
        eg, ep = el_ref[li, 0], el_ref[li, 1]
        fg, fp = fl_ref[li, 0], fl_ref[li, 1]
        new_g = g ^ _and_share(eg, fg, ul_ref[li, 0], vl_ref[li, 0],
                               zl_ref[li, 0], party0)
        new_p = _and_share(ep, fp, ul_ref[li, 1], vl_ref[li, 1],
                           zl_ref[li, 1], party0)
        g, p = new_g, new_p
    o_ref[...] = g


@functools.partial(jax.jit,
                   static_argnames=("party0", "bm", "bn", "interpret"))
def ks_carry_share(x, e0, f0, u0, v0, z0, el, fl, ul, vl, zl, *,
                   party0: bool, bm: int = 8, bn: int = 128,
                   interpret: bool = True):
    """All tensors (n, m) uint64 except the level-stacked ones
    (6, 2, n, m). Returns this party's share of the carry word (n, m).

    Jit'd: the interpret-mode emulation pays a large fixed dispatch cost per
    *traced* grid step, so eager per-call execution was ~100x off the fused
    op's real cost; under jit it compiles once per (shape, party) and runs at
    XLA speed. Callers pick bm: 8 for MXU-aligned VMEM tiles on a real TPU,
    n for a single grid cell in interpret mode (core/backend.py)."""
    n, m = x.shape
    assert n % bm == 0 and m % bn == 0, (n, m)
    grid = (n // bm, m // bn)
    lvl_spec = pl.BlockSpec((6, 2, bm, bn), lambda i, j: (0, 0, i, j))
    flat_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_kernel, party0=party0),
        grid=grid,
        in_specs=[flat_spec] * 6 + [lvl_spec] * 5,
        out_specs=flat_spec,
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.uint64),
        interpret=interpret,
    )(x, e0, f0, u0, v0, z0, el, fl, ul, vl, zl)
