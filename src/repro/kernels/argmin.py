"""Pallas TPU kernel: fused row-argmin -> one-hot (paper F^k_min, Fig. 1).

The secure path evaluates the tournament with CMP/MUX rounds (protocol.py);
this kernel is its plaintext-path / dealer-assisted counterpart: for a
(bm, k) distance tile it emits the (bm, k) one-hot assignment matrix C in a
single fused pass (min-reduce + broadcast-compare + first-hit mask), which is
exactly the C consumed by the centroid update C^T X. First minimum wins ties,
matching np.argmin and the tournament's left-preference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(d_ref, o_ref):
    d = d_ref[...]                                      # (bm, k) f32
    k = d.shape[1]
    mins = d.min(axis=1, keepdims=True)
    hit = (d == mins)
    # first-hit mask: one-hot even when duplicates exist
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    first = jnp.min(jnp.where(hit, col, k), axis=1, keepdims=True)
    o_ref[...] = (col == first).astype(jnp.int32)


def argmin_onehot(d: jnp.ndarray, *, bm: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """(n, k) f32 distances -> (n, k) int32 one-hot (n % bm == 0; ops pads)."""
    n, k = d.shape
    assert n % bm == 0, d.shape
    return pl.pallas_call(
        _kernel,
        grid=(n // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.int32),
        interpret=interpret,
    )(d.astype(jnp.float32))
