"""Jit'd public wrappers for the Pallas kernels: padding to block multiples,
dtype dispatch, VMEM-budget checks, and un-padding of results."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import argmin as _argmin
from repro.kernels import esd as _esd
from repro.kernels import modmatmul as _modmatmul
from repro.kernels import spmm as _spmm

VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # conservative v5e VMEM working budget


def _pad2(x, bm, bn):
    pm, pn = (-x.shape[0]) % bm, (-x.shape[1]) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def ring_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                bk: int = 128, bn: int = 128,
                interpret: bool = True) -> jnp.ndarray:
    """Ring matmul mod 2^32/2^64 with auto-padding (zero rows/cols are
    ring-neutral, so padding is exact).

    In interpret mode the whole product runs as ONE grid cell on the
    unpadded operands: MXU tile alignment only matters on a real TPU, and
    padding a (1024, 16) x (16, 8) Beaver recombination up to 128-multiples
    made the emulation do ~64x the necessary work (plus a per-grid-step
    dispatch cost) — the 'pallas loses in interpret mode' artefact was
    tiling, not the kernel."""
    n, k = a.shape[0], b.shape[1]
    if interpret:
        bm, bk, bn = a.shape[0], a.shape[1], b.shape[1]
    ap, bp = _pad2(a, bm, bk), _pad2(b, bk, bn)
    out = _modmatmul.modmatmul(ap, bp, bm=bm, bk=bk, bn=bn,
                               interpret=interpret)
    return out[:n, :k]


@functools.partial(jax.jit, static_argnames=("bm", "bd", "bn", "interpret"))
def esd(x: jnp.ndarray, mu: jnp.ndarray, *, bm: int = 128, bd: int = 128,
        bn: int = 128, interpret: bool = True) -> jnp.ndarray:
    """Fused distances. Padding mu rows with zeros adds fake centroids with
    U=0 at columns >= k which are sliced away; padding d is exact."""
    n, k = x.shape[0], mu.shape[0]
    xp = _pad2(x.astype(jnp.float32), bm, bd)
    mup = _pad2(mu.astype(jnp.float32), bn, bd)
    out = _esd.esd(xp, mup, bm=bm, bd=bd, bn=bn, interpret=interpret)
    return out[:n, :k]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def argmin_onehot(d: jnp.ndarray, *, bm: int = 128,
                  interpret: bool = True) -> jnp.ndarray:
    """Fused argmin->one-hot; pad rows with zeros (their one-hot is sliced
    away) — columns are NOT padded (k stays exact so the argmin is exact)."""
    n = d.shape[0]
    pm = (-n) % bm
    dp = jnp.pad(d.astype(jnp.float32), ((0, pm), (0, 0)),
                 constant_values=jnp.inf) if pm else d.astype(jnp.float32)
    return _argmin.argmin_onehot(dp, bm=bm, interpret=interpret)[:n]


def spmm(blocks, idx, counts, y, *, interpret: bool = True) -> jnp.ndarray:
    """Blocked-ELL sparse x dense (f32 / u32 / u64 ring — dtype of `blocks`
    dispatches). Asserts the dense operand fits VMEM (kernel keeps all of Y
    resident — DESIGN.md §4); pads Y's rows to the tile width bk (zero rows
    are ring-neutral) and its columns to the lane width — the lane pad is a
    real-TPU layout requirement only, and skipping it in interpret mode
    avoids doing 128/k times the necessary tile work in emulation."""
    bk = blocks.shape[3]
    d, k = y.shape
    dp, kp = (-d) % bk, 0 if interpret else (-k) % 128
    itemsize = jnp.dtype(y.dtype).itemsize
    assert (d + dp) * (k + kp) * itemsize <= VMEM_BUDGET_BYTES, \
        f"Y ({d}x{k}) exceeds the VMEM-resident budget; shard k or d first"
    yp = jnp.pad(y, ((0, dp), (0, kp))) if dp or kp else y
    out = _spmm.spmm_ell(blocks, idx, counts, yp, interpret=interpret)
    return out[:, :k]


def spmm_from_dense(x_dense: np.ndarray, y, *, bm: int = 8, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """Convenience: host-side ELL pack + kernel call; returns (n, k)."""
    blocks, idx, counts = _spmm.dense_to_ell(np.asarray(x_dense), bm=bm, bk=bk)
    out = spmm(jnp.asarray(blocks), jnp.asarray(idx), jnp.asarray(counts),
               jnp.asarray(y), interpret=interpret)
    return out[: x_dense.shape[0]]
