"""Deterministic, preemption-safe synthetic data pipeline.

The batch for global step s is a pure function of (seed, s, host) — there is
NO iterator state to checkpoint or lose: after a restart at step s the
pipeline replays exactly the same stream (the property large-fleet training
actually needs; file-backed corpora plug in by replacing `_tokens_for` with
an indexed shard read, keeping the same stateless contract).

Also provides the two-party fraud-detection table generator used by the
K-means examples/benchmarks.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLMStream:
    """Zipfian token stream with a planted bigram structure so the loss has
    learnable signal (used by the end-to-end train driver)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.freq = (1.0 / ranks) / (1.0 / ranks).sum()
        self.next_of = rng.permutation(v)      # deterministic bigram map

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, t = cfg.global_batch, cfg.seq_len
        first = rng.choice(cfg.vocab_size, size=(b, 1), p=self.freq)
        noise = rng.random((b, t - 1)) < 0.3
        toks = np.empty((b, t), np.int32)
        toks[:, 0:1] = first
        for i in range(1, t):
            follow = self.next_of[toks[:, i - 1]]
            rand = rng.integers(0, cfg.vocab_size, b)
            toks[:, i] = np.where(noise[:, i - 1], rand, follow)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        return {"tokens": toks, "labels": labels.astype(np.int32)}
