"""Unified metrics registry for the secure k-means runtime (DESIGN.md §15).

One process-wide `MetricsRegistry` absorbs the stats that previous PRs
scattered across objects — CommLog byte tallies by phase, TripleBank
stock/consumed counts, replenisher occupancy, `ServiceStats` latency
quantiles, frame CRC/auth/retry/dedup counters — behind three primitive
kinds:

* **Counter** — monotonically increasing float/int (`inc`).
* **Gauge** — settable point-in-time value, or a *callback* gauge that
  reads a live object at snapshot time (how CommLog/bank/service state is
  exposed without double-bookkeeping: the registry never caches a copy
  that could drift from the source of truth).
* **Histogram** — fixed-bucket counts + sum, Prometheus semantics.

Names follow Prometheus conventions: `repro_<subsystem>_<what>_<unit>`
with labels for the varying dimension (phase, key, ftype). `snapshot()`
returns plain dicts for tests/JSON; `render_prometheus()` emits the text
exposition format served by ``serve_kmeans --metrics-port`` (stdlib
`http.server`, daemon thread). `StatsLineLogger` prints a periodic
one-line digest (including the `bank_stock` line) for log-only
deployments.
"""
from __future__ import annotations

import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> tuple:
    """Fixed log-spaced histogram bucket edges from `lo` to at least `hi`
    with `per_decade` buckets per decade. Deterministic (no data-dependent
    sizing) so two processes' histograms are mergeable bucket-by-bucket —
    what the wire-latency / backoff / launch-wall histograms use."""
    import math
    if not (lo > 0 and hi > lo):
        raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
    step = 10.0 ** (1.0 / per_decade)
    edges, v = [], float(lo)
    while v < hi * (1.0 + 1e-12):
        edges.append(round(v, 12))
        v *= step
    edges.append(round(v, 12))
    return tuple(edges)


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class Counter:
    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Set-value gauge, or callback gauge when `fn` is given — the
    callback is invoked at read time so the exposed number is always the
    live one."""

    __slots__ = ("name", "labels", "_value", "_fn", "_lock")

    def __init__(self, name: str, labels: dict, fn=None):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._fn = fn
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value

    @property
    def value(self) -> float:
        if self._fn is not None:
            try:
                return float(self._fn())
            except Exception:
                return float("nan")
        with self._lock:
            return self._value


class Histogram:
    """Fixed-bucket histogram: cumulative bucket counts, total sum and
    count (Prometheus `_bucket`/`_sum`/`_count` semantics)."""

    __slots__ = ("name", "labels", "buckets", "_counts", "_sum", "_count",
                 "_lock")

    DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                       1.0, 2.5, 5.0, 10.0)

    def __init__(self, name: str, labels: dict, buckets=None):
        self.name = name
        self.labels = labels
        self.buckets = tuple(sorted(buckets or self.DEFAULT_BUCKETS))
        self._counts = [0] * (len(self.buckets) + 1)
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        with self._lock:
            i = 0
            for i, b in enumerate(self.buckets):
                if value <= b:
                    break
            else:
                i = len(self.buckets)
            self._counts[i] += 1
            self._sum += value
            self._count += 1

    def snapshot(self) -> dict:
        with self._lock:
            cum, out = 0, {}
            for b, c in zip(self.buckets, self._counts):
                cum += c
                out[b] = cum
            return {"buckets": out, "sum": self._sum,
                    "count": self._count}


class MetricsRegistry:
    """The process-wide metric namespace. `counter`/`gauge`/`histogram`
    get-or-create by (name, labels) — repeated registration returns the
    same instrument, so hot paths can call `registry.counter(...)` without
    caching handles (though caching is cheaper)."""

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._lock = threading.Lock()

    @staticmethod
    def _key(name: str, labels: dict | None) -> tuple:
        return (name, tuple(sorted((labels or {}).items())))

    def counter(self, name: str, labels: dict | None = None) -> Counter:
        key = self._key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = Counter(name, dict(labels or {}))
            return m

    def gauge(self, name: str, labels: dict | None = None,
              fn=None) -> Gauge:
        key = self._key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = Gauge(name, dict(labels or {}),
                                               fn=fn)
            elif fn is not None:
                m._fn = fn
            return m

    def histogram(self, name: str, labels: dict | None = None,
                  buckets=None) -> Histogram:
        key = self._key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = Histogram(name,
                                                   dict(labels or {}),
                                                   buckets=buckets)
            return m

    def snapshot(self) -> dict:
        """{name{labels}: value} for counters/gauges, nested dict for
        histograms — a plain-data view for tests and JSON dumps."""
        with self._lock:
            metrics = list(self._metrics.values())
        out = {}
        for m in metrics:
            key = m.name + _fmt_labels(m.labels)
            if isinstance(m, Histogram):
                out[key] = m.snapshot()
            else:
                out[key] = m.value
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        with self._lock:
            metrics = list(self._metrics.values())
        by_name: dict[str, list] = {}
        for m in metrics:
            by_name.setdefault(m.name, []).append(m)
        lines = []
        for name in sorted(by_name):
            group = by_name[name]
            kind = ("counter" if isinstance(group[0], Counter) else
                    "histogram" if isinstance(group[0], Histogram) else
                    "gauge")
            lines.append(f"# TYPE {name} {kind}")
            for m in group:
                if isinstance(m, Histogram):
                    snap = m.snapshot()
                    for b, c in snap["buckets"].items():
                        lab = dict(m.labels, le=repr(b))
                        lines.append(f"{name}_bucket{_fmt_labels(lab)} {c}")
                    lab = dict(m.labels, le="+Inf")
                    lines.append(
                        f"{name}_bucket{_fmt_labels(lab)} {snap['count']}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(m.labels)} {snap['sum']}")
                    lines.append(
                        f"{name}_count{_fmt_labels(m.labels)} "
                        f"{snap['count']}")
                else:
                    v = m.value
                    sv = repr(int(v)) if float(v).is_integer() else repr(v)
                    lines.append(f"{name}{_fmt_labels(m.labels)} {sv}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


# -- live-object adapters ----------------------------------------------------
#
# Callback gauges reading the owning object directly: the registry's
# answer for e.g. repro_comm_bytes_total{phase="online"} is by
# construction CommLog.total_bytes("online") — there is no second tally
# to drift.

def register_commlog(log, registry: MetricsRegistry | None = None,
                     phases=("offline", "online", "setup")) -> None:
    reg = registry or _REGISTRY
    for phase in phases:
        reg.gauge("repro_comm_bytes_total", {"phase": phase},
                  fn=lambda p=phase: log.total_bytes(p))
        reg.gauge("repro_comm_rounds_total", {"phase": phase},
                  fn=lambda p=phase: log.total_rounds(p))


def register_bank(bank, registry: MetricsRegistry | None = None) -> None:
    """Expose TripleBank stock (complete plan copies per registered key),
    cumulative consumed-request totals, and replenish events. Per-key
    gauges cover the keys present at registration — call again after
    provisioning new plans if the key set grew."""
    reg = registry or _REGISTRY

    def _stock(k):
        return lambda: bank.stock_copies(k)

    for k in bank.keys():
        reg.gauge("repro_bank_stock_copies", {"key": str(k)},
                  fn=_stock(k))
    reg.gauge("repro_bank_consumed_requests_total",
              fn=lambda: sum(bank.consumed_counts().values()))
    reg.gauge("repro_bank_served_requests_total",
              fn=lambda: bank.served_requests)
    reg.gauge("repro_bank_replenish_events_total",
              fn=lambda: bank.replenish_events)


def register_replenisher(rep,
                         registry: MetricsRegistry | None = None) -> None:
    reg = registry or _REGISTRY
    reg.gauge("repro_bank_topups_total", fn=lambda: rep.topups)
    reg.gauge("repro_bank_topup_copies_total", fn=lambda: rep.topup_copies)
    reg.gauge("repro_bank_topup_seconds_total",
              fn=lambda: rep.topup_seconds)
    reg.gauge("repro_bank_replenisher_errors_total",
              fn=lambda: rep.errors)


def register_service(svc, registry: MetricsRegistry | None = None) -> None:
    """Expose every ServiceStats.as_dict key as a callback gauge
    (repro_serve_<key>), each reading the live stats object."""
    reg = registry or _REGISTRY
    keys = svc.stats.as_dict().keys()

    def _read(k):
        return lambda: svc.stats.as_dict()[k]

    for k in keys:
        reg.gauge(f"repro_serve_{k}", fn=_read(k))
    if hasattr(svc, "health_code"):
        reg.gauge("repro_serve_health", fn=svc.health_code)


# -- exposition server -------------------------------------------------------

class _MetricsHandler(BaseHTTPRequestHandler):
    registry: MetricsRegistry = _REGISTRY
    health_cb = None        # () -> state string, e.g. "READY"

    def _serve(self, status: int, body: bytes) -> None:
        self.send_response(status)
        self.send_header("Content-Type",
                         "text/plain; version=0.0.4; charset=utf-8")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):  # noqa: N802 (stdlib interface)
        if self.path == "/health":
            # readiness probe: 200 only when the service reports READY,
            # 503 otherwise (STARTING/DEGRADED/DRAINING) — what the
            # supervisor and load balancers gate on. Body is the state.
            if self.health_cb is None:
                self._serve(404, b"no health callback registered\n")
                return
            try:
                state = str(self.health_cb())
            except Exception as e:  # health must never take the server down
                self._serve(503, f"DEGRADED ({e})\n".encode())
                return
            self._serve(200 if state == "READY" else 503,
                        (state + "\n").encode())
            return
        if self.path not in ("/", "/metrics"):
            self.send_response(404)
            self.end_headers()
            return
        self._serve(200, self.registry.render_prometheus().encode())

    def log_message(self, *a):  # silence per-request stderr lines
        pass


class MetricsServer:
    """`GET /metrics` → Prometheus text, `GET /health` → readiness state
    (200 iff READY), on a daemon thread. Port 0 picks a free port (read
    `.port` after start)."""

    def __init__(self, port: int = 0,
                 registry: MetricsRegistry | None = None,
                 health_cb=None):
        handler = type("Handler", (_MetricsHandler,),
                       {"registry": registry or _REGISTRY,
                        "health_cb": staticmethod(health_cb)
                        if health_cb is not None else None})
        self._httpd = ThreadingHTTPServer(("127.0.0.1", port), handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-http", daemon=True)

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)


# -- periodic stats line -----------------------------------------------------

class StatsLineLogger:
    """Emit a one-line digest every `interval_s` via `emit` (default
    print): serve counters, p50/p99, queue depth, and — when a bank is
    attached — the `bank_stock` line making stock-out visible BEFORE the
    first synchronous-replenish stall."""

    def __init__(self, svc=None, bank=None, interval_s: float = 10.0,
                 emit=print):
        self.svc = svc
        self.bank = bank
        self.interval_s = float(interval_s)
        self.emit = emit
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run,
                                        name="stats-line", daemon=True)

    def render(self) -> str:
        parts = [f"stats t={time.strftime('%H:%M:%S')}"]
        if self.svc is not None:
            d = self.svc.stats.as_dict()
            parts.append(
                f"req={d['requests']} rows={d['rows']} "
                f"q={d['queue_depth']} shed={d['shed_requests']} "
                f"expired={d['expired_requests']} "
                f"p50={d['p50_ms']:.1f}ms p99={d['p99_ms']:.1f}ms")
        if self.bank is not None:
            stock = {str(k): self.bank.stock_copies(k)
                     for k in self.bank.keys()}
            inner = " ".join(f"{k}:{v}" for k, v in sorted(stock.items()))
            parts.append(f"bank_stock [{inner or 'empty'}]")
        return " | ".join(parts)

    def _run(self):
        while not self._stop.wait(self.interval_s):
            try:
                self.emit(self.render())
            except Exception:
                pass

    def start(self) -> "StatsLineLogger":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)
