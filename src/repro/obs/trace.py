"""Span tracing for the secure k-means runtime (DESIGN.md §15).

One process-wide `Tracer` instruments the hot seams — fit iterations,
pipeline stages, HE exchanges, bank provisioning, serving drains, wire
retries — with `with tracer.span("s1_launch", iter=i):` context managers.
Disabled (the default) a span call is a single attribute check returning a
shared no-op context manager: no allocation, no clock read, no lock — the
online path pays nothing it could measure. Enabled, each span records
wall-clock epoch start (`time.time_ns`, so spans from DIFFERENT processes
land on one absolute timeline), a monotonic duration, and its thread lane,
and exports as Chrome-trace / Perfetto JSON (``chrome://tracing``,
https://ui.perfetto.dev) — thread-lane aware, so the pipelined executor's
pre(t+1)-under-launch(t) overlap is *visible* — plus an aggregated text
flame summary for terminals.

Distributed request traces ride a **trace id**: an 8-byte token minted by
the client (`new_trace_id`), carried inside wire frames (the
`channel.TRACE_BIT` header extension), and installed thread-locally on the
serving side (`set_current_trace`) so every span opened while handling the
request tags itself with it. `merge_traces` joins the per-process span
files into one timeline keyed by those ids.

The module-level `span`/`instant` helpers delegate to the GLOBAL tracer
(`get_tracer`); components that need per-endpoint span files (e.g. a
client and a server in one test process) accept an explicit `tracer=`.
"""
from __future__ import annotations

import json
import secrets
import threading
import time
from collections import defaultdict, deque

TRACE_ID_BYTES = 8


def new_trace_id() -> str:
    """Mint a fresh request trace id: 16 hex chars (8 random bytes)."""
    return secrets.token_hex(TRACE_ID_BYTES)


def trace_id_to_bytes(tid: str) -> bytes:
    return bytes.fromhex(tid)


def trace_id_from_bytes(raw: bytes) -> str:
    return raw.hex()


# -- thread-local trace propagation -----------------------------------------

_TLS = threading.local()


def set_current_trace(tid: str | None) -> None:
    """Install `tid` as this thread's ambient trace id (None clears it).
    Spans opened while it is set tag themselves with ``trace=tid``."""
    _TLS.trace = tid


def current_trace() -> str | None:
    return getattr(_TLS, "trace", None)


class _NoopSpan:
    """Shared do-nothing context manager — what a disabled tracer returns.
    One module-level instance, so the disabled fast path allocates
    nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    """One live span: records on `__exit__`. Cheap on purpose — two clock
    reads plus one locked list append per span."""

    __slots__ = ("tracer", "name", "args", "t_epoch_us", "t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        self.t_epoch_us = time.time_ns() // 1_000
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur_us = max(0, (time.perf_counter_ns() - self.t0) // 1_000)
        self.tracer._record(self.name, self.t_epoch_us, dur_us, self.args)
        return False


class Tracer:
    """Lock-protected span recorder with a no-op fast path.

    `enabled=False` (the default): `span()` returns the shared no-op
    context manager after a single attribute check — instrumentation left
    in the hot seams costs one branch. `enabled=True`: complete spans
    accumulate as Chrome-trace events (bounded by `max_events`,
    drop-newest beyond it, counted in `dropped`).

    `process` labels this tracer's pid lane in the exported JSON — set it
    to "client" / "server" / "party_a" so merged multi-process timelines
    stay readable. Spans inherit the thread's ambient trace id
    (`set_current_trace`) unless the call passes its own ``trace=``.

    Long-lived servers bound the tracer two ways (both leave the DISABLED
    fast path untouched — still one attribute check, no clock read):

    * `rotate_spans=N` keeps only the newest N events **per category**
      (the span name's first dot-component: ``serve.request`` and
      ``serve.drain`` share the "serve" ring) instead of the flat
      `max_events` drop-newest list — a week-old fit span can't starve
      today's serve spans out of the buffer. Evictions count in
      `rotated_out`.
    * `sample_rate=r` records ~every ``round(1/r)``-th event per category
      (deterministic counter sampling, not RNG — reruns trace the same
      spans). Skips count in `sampled_out`."""

    def __init__(self, enabled: bool = False, process: str = "repro",
                 max_events: int = 1_000_000,
                 rotate_spans: int | None = None,
                 sample_rate: float = 1.0):
        self.enabled = bool(enabled)
        self.process = str(process)
        self.max_events = int(max_events)
        self.dropped = 0
        self._events: list[dict] = []
        self._threads: dict[int, str] = {}
        self._lock = threading.Lock()
        self.configure_bounds(rotate_spans=rotate_spans,
                              sample_rate=sample_rate)

    def configure_bounds(self, rotate_spans: int | None = None,
                         sample_rate: float | None = None) -> None:
        """(Re)apply the bounded-memory knobs. Resets the rotation rings
        and sampling counters — call before tracing, not mid-flight."""
        if rotate_spans is not None and int(rotate_spans) < 1:
            raise ValueError("rotate_spans must be >= 1 (or None)")
        self.rotate_spans = None if rotate_spans is None \
            else int(rotate_spans)
        rate = 1.0 if sample_rate is None else float(sample_rate)
        if not 0.0 < rate <= 1.0:
            raise ValueError(f"sample_rate must be in (0, 1], got {rate}")
        self.sample_rate = rate
        self._sample_every = max(1, round(1.0 / rate))
        self._sample_n: dict[str, int] = {}
        self.sampled_out = 0
        self.rotated_out = 0
        self._rings: dict[str, deque] = {}

    @staticmethod
    def _category(name: str) -> str:
        return name.split(".", 1)[0]

    # -- recording --------------------------------------------------------
    def span(self, name: str, **args):
        """Context manager timing one named region. Keyword args land in
        the event's ``args`` (Chrome trace) — keep them small scalars."""
        if not self.enabled:
            return _NOOP
        tid = current_trace()
        if tid is not None and "trace" not in args:
            args["trace"] = tid
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker (Chrome-trace instant event)."""
        if not self.enabled:
            return
        tid = current_trace()
        if tid is not None and "trace" not in args:
            args["trace"] = tid
        self._record(name, time.time_ns() // 1_000, None, args)

    def complete_span(self, name: str, start_epoch_us: int, dur_us: int,
                      **args) -> None:
        """Record a span retroactively from explicit epoch-µs timestamps —
        for request lifetimes that cross threads (admitted on a responder
        thread, published from the drain thread), where no single
        with-block can cover the extent."""
        if not self.enabled:
            return
        tid = current_trace()
        if tid is not None and "trace" not in args:
            args["trace"] = tid
        self._record(name, int(start_epoch_us), max(0, int(dur_us)), args)

    def _record(self, name: str, ts_us: int, dur_us: int | None,
                args: dict) -> None:
        th = threading.current_thread()
        ev = {"name": name, "ts": ts_us, "tid": th.ident,
              "args": args}
        if dur_us is None:
            ev["ph"] = "i"
            ev["s"] = "t"
        else:
            ev["ph"] = "X"
            ev["dur"] = dur_us
        cat = self._category(name)
        with self._lock:
            if self._sample_every > 1:
                n = self._sample_n.get(cat, 0)
                self._sample_n[cat] = n + 1
                if n % self._sample_every:
                    self.sampled_out += 1
                    return
            if self.rotate_spans is not None:
                ring = self._rings.get(cat)
                if ring is None:
                    ring = self._rings[cat] = deque(maxlen=self.rotate_spans)
                if len(ring) == self.rotate_spans:
                    self.rotated_out += 1
                ring.append(ev)
            else:
                if len(self._events) >= self.max_events:
                    self.dropped += 1
                    return
                self._events.append(ev)
            self._threads.setdefault(th.ident, th.name)

    def _all_events(self) -> list[dict]:
        """Every retained event (flat list + rotation rings), ts-ordered.
        Caller must hold `_lock`."""
        evs = list(self._events)
        for ring in self._rings.values():
            evs.extend(ring)
        evs.sort(key=lambda e: e["ts"])
        return evs

    # -- queries ----------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return [dict(e) for e in self._all_events()]

    def span_counts(self) -> dict:
        """{span name: count} over everything retained so far."""
        out: dict[str, int] = defaultdict(int)
        with self._lock:
            for e in self._all_events():
                out[e["name"]] += 1
        return dict(out)

    def spans_for_trace(self, trace_id: str) -> list[dict]:
        with self._lock:
            return [dict(e) for e in self._all_events()
                    if e["args"].get("trace") == trace_id]

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
            self._threads.clear()
            self._rings.clear()
            self._sample_n.clear()
            self.dropped = 0
            self.rotated_out = 0
            self.sampled_out = 0

    # -- export -----------------------------------------------------------
    def chrome_events(self, pid: int = 1) -> list[dict]:
        """The Chrome-trace event list: metadata rows naming the process
        and thread lanes, then every recorded span."""
        with self._lock:
            events = [dict(e) for e in self._all_events()]
            threads = dict(self._threads)
        out = [{"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": self.process}}]
        for tid, tname in sorted(threads.items()):
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": tname}})
        for e in events:
            e["pid"] = pid
            e["cat"] = e["name"].split(".")[0].split("_")[0]
            out.append(e)
        return out

    def export_chrome(self, path: str, pid: int = 1) -> str:
        """Write ``{"traceEvents": [...]}`` JSON loadable by
        chrome://tracing and ui.perfetto.dev. Returns `path`."""
        doc = {"traceEvents": self.chrome_events(pid=pid),
               "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return path

    def flame_summary(self, top: int = 24) -> str:
        """Aggregated per-span-name text table: count, total wall,
        mean — the terminal's flame graph."""
        agg: dict[str, list] = defaultdict(lambda: [0, 0])
        with self._lock:
            for e in self._all_events():
                a = agg[e["name"]]
                a[0] += 1
                a[1] += e.get("dur", 0)
        rows = sorted(agg.items(), key=lambda kv: -kv[1][1])[:top]
        if not rows:
            return "(no spans recorded)"
        w = max(len(n) for n, _ in rows)
        lines = [f"{'span':<{w}}  {'count':>7}  {'total_ms':>10}  "
                 f"{'mean_us':>9}"]
        for name, (cnt, tot) in rows:
            lines.append(f"{name:<{w}}  {cnt:>7}  {tot / 1e3:>10.3f}  "
                         f"{tot / max(1, cnt):>9.1f}")
        if self.dropped:
            lines.append(f"(+{self.dropped} events dropped past "
                         f"max_events={self.max_events})")
        if self.rotated_out:
            lines.append(f"(+{self.rotated_out} events rotated out past "
                         f"rotate_spans={self.rotate_spans} per category)")
        if self.sampled_out:
            lines.append(f"(+{self.sampled_out} events skipped at "
                         f"sample_rate={self.sample_rate})")
        return "\n".join(lines)


# -- the global tracer -------------------------------------------------------

_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def configure(enabled: bool | None = None, process: str | None = None,
              max_events: int | None = None,
              rotate_spans: int | None = None,
              sample_rate: float | None = None) -> Tracer:
    """Adjust the global tracer in place (None = leave unchanged; passing
    either bounded-memory knob resets the rotation rings + sample
    counters)."""
    if enabled is not None:
        _TRACER.enabled = bool(enabled)
    if process is not None:
        _TRACER.process = str(process)
    if max_events is not None:
        _TRACER.max_events = int(max_events)
    if rotate_spans is not None or sample_rate is not None:
        _TRACER.configure_bounds(
            rotate_spans=rotate_spans if rotate_spans is not None
            else _TRACER.rotate_spans,
            sample_rate=sample_rate if sample_rate is not None
            else _TRACER.sample_rate)
    return _TRACER


def span(name: str, **args):
    """Module-level shortcut: a span on the GLOBAL tracer. The disabled
    fast path is one attribute check + the shared no-op context manager."""
    if not _TRACER.enabled:
        return _NOOP
    return _TRACER.span(name, **args)


def instant(name: str, **args) -> None:
    if _TRACER.enabled:
        _TRACER.instant(name, **args)


# -- multi-process timeline merge --------------------------------------------

def merge_traces(sources, out_path: str | None = None) -> dict:
    """Join several span files (or in-memory Tracers) into ONE Chrome
    trace: each source gets its own pid lane (its `process_name` metadata
    is preserved), span events keep their absolute epoch timestamps — the
    shared clock that lets a client request span line up under the server
    span carrying the same ``args.trace`` id. Returns the merged document
    (and writes it to `out_path` when given)."""
    events = []
    for pid, src in enumerate(sources, start=1):
        if isinstance(src, Tracer):
            evs = src.chrome_events(pid=pid)
        else:
            with open(src) as f:
                doc = json.load(f)
            evs = doc["traceEvents"] if isinstance(doc, dict) else doc
        for e in evs:
            e = dict(e)
            e["pid"] = pid
            events.append(e)
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(doc, f)
    return doc
