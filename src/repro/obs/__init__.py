"""Telemetry plane: span tracing, metrics registry, distributed request
traces. See DESIGN.md §15."""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      MetricsServer, StatsLineLogger, get_registry,
                      register_bank, register_commlog, register_replenisher,
                      register_service)
from .trace import (TRACE_ID_BYTES, Tracer, configure, current_trace,
                    get_tracer, instant, merge_traces, new_trace_id,
                    set_current_trace, span, trace_id_from_bytes,
                    trace_id_to_bytes)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "MetricsServer",
    "StatsLineLogger", "get_registry", "register_bank", "register_commlog",
    "register_replenisher", "register_service",
    "TRACE_ID_BYTES", "Tracer", "configure", "current_trace", "get_tracer",
    "instant", "merge_traces", "new_trace_id", "set_current_trace", "span",
    "trace_id_from_bytes", "trace_id_to_bytes",
]
