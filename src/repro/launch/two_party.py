"""Two-PROCESS secure k-means: the fit and predict protocols over a real
TCP socket (DESIGN.md §13).

    # party A (engine) — binds, prints "LISTENING <port>", runs the fit
    PYTHONPATH=src python -m repro.launch.two_party --role A --port 0 \
        --out /tmp/a.npz
    # party B (responder) — dials A and answers the wire until BYE
    PYTHONPATH=src python -m repro.launch.two_party --role B --port <port>

Deployment shape: the repo's engine simulates BOTH parties' protocol
state in one process (core/protocol.py), so party A hosts the joint
simulation while party B is a pure wire peer — it ships its data slice
on request (a real length-prefixed blob over TCP), then echoes the
online protocol's exchange frames (core/channel.serve_peer). Every byte
and round the CommLog tallies is carried by a real frame with sequence
number and CRC, so a socket fit's shares AND accounting are bit-exact
against the in-process fit — test-enforced on all partition × sparsity
combos (tests/test_wire.py).

`--die-at-iter N` kills party A with os._exit right after the iteration-N
checkpoint publishes (requires --checkpoint-dir) — the crash half of the
checkpoint/resume acceptance test; rerunning with --resume (fresh B)
finishes bit-exact against an uninterrupted run.

Self-healing mode (DESIGN.md §16): `--auto-resume` makes party A
negotiate the resume step with B on every start — it announces a fresh
incarnation nonce (resetting B's dedup window so the new sequence space
isn't mistaken for stale duplicates), exchanges latest published
checkpoint step + config fingerprint, and resumes from `min(step)` with
no operator action; `--state-dir` gives B a durable progress marker so
the negotiation survives B's own crashes; `--peer-wait S` parks either
side through a supervised peer restart instead of dying. `--die-at
point[:nth]` arms the chaos kill-points (core/faultpoints.py) and
`--fault-*` inject deterministic wire faults — together they are the
levers `benchmarks/chaos_bench.py` sweeps under `launch/supervisor.py`.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core import faultpoints
from repro.core.channel import (FaultyTransport, PeerProgress,
                                ReliableChannel, ResumeMismatch,
                                SocketTransport, WireSession, WireTimeout,
                                serve_peer, session_key)
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.obs import trace as _trace


def _trace_setup(args) -> None:
    if args.trace_out:
        _trace.configure(enabled=True,
                         process=f"party_{args.role.lower()}")


def _trace_finish(args) -> None:
    if args.trace_out:
        t = _trace.get_tracer()
        t.export_chrome(args.trace_out)
        print(f"{args.role}: trace {len(t.events())} spans -> "
              f"{args.trace_out}", flush=True)


def make_data(n: int, d: int, k: int, seed: int,
              sparse_frac: float = 0.0) -> np.ndarray:
    """Deterministic gaussian blobs (optionally sparsified) — the shared
    generator both parties AND the in-process reference fit use, so the
    only thing the wire changes is where the bytes travel."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 4.0, size=(k, d))
    x = centers[rng.integers(0, k, size=n)] + rng.normal(size=(n, d))
    if sparse_frac > 0:
        x = np.where(rng.random(x.shape) < sparse_frac, 0.0, x)
    return x


def split_data(x: np.ndarray, partition: str) -> tuple:
    n, d = x.shape
    if partition == "vertical":
        return x[:, :d // 2], x[:, d // 2:]
    return x[:n // 2], x[n // 2:]


def _auth(args) -> bytes | None:
    return session_key(args.auth_key) if args.auth_key else None


def _wrap_faults(t, args):
    """Apply the CLI's deterministic fault schedule to a transport.
    FaultyTransport delegates `.stats` to the inner transport, so the
    wire accounting below keeps reading the same counters."""
    sever = tuple(int(s) for s in
                  (args.fault_sever_at or "").split(",") if s.strip())
    if not (args.fault_drop or args.fault_dup or args.fault_corrupt
            or sever):
        return t
    return FaultyTransport(t, seed=args.fault_seed, drop=args.fault_drop,
                           dup=args.fault_dup, corrupt=args.fault_corrupt,
                           sever_at=sever)


def _wire_stats_line(role: str, t, extra: dict | None = None) -> None:
    """One machine-parsable line the chaos bench totals across
    incarnations (the DYING line carries the same dict for killed ones)."""
    d = {"role": role, "frames_sent": int(t.stats.frames_sent),
         "frames_recv": int(t.stats.frames_recv),
         "wire_bytes_sent": int(t.stats.wire_bytes_sent),
         "reconnects": int(t.stats.reconnects)}
    d.update(extra or {})
    print("WIRE_STATS " + json.dumps(d, sort_keys=True), flush=True)


def _party_b(args) -> None:
    _trace_setup(args)
    if args.die_at:
        faultpoints.arm(args.die_at)
    t = SocketTransport("connect", host=args.host, port=args.port,
                        io_timeout_s=args.io_timeout)
    ft = _wrap_faults(t, args)
    faultpoints.set_reporter(lambda: {
        "role": "B", "frames_sent": int(t.stats.frames_sent),
        "frames_recv": int(t.stats.frames_recv),
        "wire_bytes_sent": int(t.stats.wire_bytes_sent)})

    progress = None
    if args.state_dir:
        os.makedirs(args.state_dir, exist_ok=True)
        progress = PeerProgress(os.path.join(args.state_dir,
                                             "peer_progress.json"))
        if progress.step >= 0:
            print(f"B: resuming with recorded step {progress.step}",
                  flush=True)

    def on_blob(meta, arrays):
        if meta.get("op") != "get_slice":
            raise ValueError(f"unknown blob op {meta!r}")
        x = make_data(int(meta["n"]), int(meta["d"]), int(meta["k"]),
                      int(meta["seed"]), float(meta["sparse_frac"]))
        _, x_b = split_data(x, meta["partition"])
        return {"op": "slice"}, {"x_b": x_b}

    # the idle budget doubles as the bounded reconnect-wait: while the
    # supervisor restarts a crashed engine, B parks in its reconnect loop
    # and only gives up once TOTAL silence exceeds the budget
    park = args.peer_wait if args.peer_wait else args.io_timeout
    try:
        stats = serve_peer(ft, on_blob=on_blob,
                           idle_timeout_s=max(args.io_timeout, park),
                           auth_key=_auth(args), progress=progress)
    except WireTimeout as e:
        # engine crashed or unreachable past the idle budget: exit with a
        # clear diagnostic (its checkpoint-resume relaunches a fresh B)
        print(f"B: giving up — {e}", flush=True)
        ft.close()
        raise SystemExit(3)
    print(f"B: served {stats.served} requests, "
          f"{stats.dedup_replays} dedup replays", flush=True)
    _wire_stats_line("B", t, {"served": int(stats.served),
                              "incarnation_resets":
                              int(stats.incarnation_resets)})
    ft.close()
    _trace_finish(args)


def _party_a(args) -> None:
    _trace_setup(args)
    if args.die_at:
        faultpoints.arm(args.die_at)
    t = SocketTransport("listen", host=args.host, port=args.port,
                        io_timeout_s=args.io_timeout)
    print(f"LISTENING {t.port}", flush=True)
    ft = _wrap_faults(t, args)
    chan = ReliableChannel(ft, deadline_s=args.io_timeout,
                           auth_key=_auth(args),
                           reconnect_wait_s=args.peer_wait)
    # the incarnation nonce distinguishes THIS process from any earlier
    # one on the same port: B resets its dedup window when it changes
    inc = f"{os.getpid()}-{time.time_ns()}"
    ws = WireSession(chan, incarnation=inc)
    faultpoints.set_reporter(lambda: {
        "role": "A", "frames_sent": int(t.stats.frames_sent),
        "frames_recv": int(t.stats.frames_recv),
        "wire_bytes_sent": int(t.stats.wire_bytes_sent),
        "retries": int(chan.retries), "reconnects": int(chan.reconnects)})

    if args.auto_resume:
        # announce the incarnation FIRST: a restarted engine's sequence
        # space restarts at 0, which B would stale-drop until the reset
        ws.negotiate_resume(step=-1, fingerprint=None)

    x = make_data(args.n, args.d, args.k, args.seed, args.sparse_frac)
    x_a, x_b_local = split_data(x, args.partition)
    # B's slice arrives over the wire — the engine never recomputes it
    meta, arrays = ws.send_arrays(
        {"op": "get_slice", "n": args.n, "d": args.d, "k": args.k,
         "seed": args.seed, "sparse_frac": args.sparse_frac,
         "partition": args.partition}, {})
    x_b = arrays["x_b"]
    assert x_b.shape == x_b_local.shape, "peer slice geometry mismatch"

    cfg = KMeansConfig(k=args.k, iters=args.iters, seed=args.seed,
                       partition=args.partition,
                       sparse=args.sparse_frac > 0,
                       batch_size=args.batch_size,
                       offline=args.offline,
                       pipeline=not args.no_pipeline, backend="xla")
    km = SecureKMeans(cfg)
    ckpt = None
    fp = None
    if args.checkpoint_dir:
        from repro.checkpoint.fit import FitCheckpointer

        fp = km._fit_fingerprint(x_a.shape, x_b.shape)

        def after_save(state, _path):
            if args.auto_resume:
                # tell B the step is published BEFORE any scripted death:
                # notify-then-die and die-before-notify are both safe (B
                # lagging only makes the agreed step older), but notifying
                # eagerly keeps MTTR low — the restart resumes at min()
                ws.notify_publish(state.step, fp)
            if args.die_at_iter is not None \
                    and state.iteration >= args.die_at_iter \
                    and state.batch == 0:
                print(f"DYING at iteration {state.iteration} "
                      "(post-checkpoint)", flush=True)
                os._exit(17)    # simulated crash: no cleanup, no BYE

        ckpt = FitCheckpointer(args.checkpoint_dir,
                               every=args.checkpoint_every,
                               fingerprint=fp,
                               after_save=after_save)
    resume_step = None
    if args.auto_resume:
        if ckpt is None:
            raise SystemExit("--auto-resume requires --checkpoint-dir")
        my_step = max(ckpt.all_steps(), default=-1)
        resume_step = ws.negotiate_resume(step=my_step, fingerprint=fp)
        print(f"A: negotiated resume step {resume_step} "
              f"(ours {my_step})", flush=True)
    res = km.fit(x_a, x_b, wire=ws, checkpoint=ckpt, resume=args.resume,
                 resume_step=resume_step)

    # score a fresh arrival batch over the same session
    arr = make_data(args.predict_n, args.d, args.k, args.seed + 1,
                    args.sparse_frac)
    pa, pb = split_data(arr, args.partition)
    pred = km.predict(pa, pb, wire=ws)

    d = res.log.by_tag("online")
    meta = {
        "counters": {a: int(getattr(res.dealer, a))
                     for a in ("n_matmul", "n_mul", "n_bin")},
        "fit_online": {k_: [int(v[0]), int(v[1])] for k_, v in d.items()},
        "predict_online": {k_: [int(v[0]), int(v[1])]
                           for k_, v in pred.log.by_tag("online").items()},
        "wire_payload_bytes": int(ws.payload_bytes),
        "wire_rounds": int(ws.rounds),
        "frames_sent": int(t.stats.frames_sent),
        "wire_bytes_sent": int(t.stats.wire_bytes_sent),
    }
    if args.out:
        np.savez(args.out,
                 mu0=np.asarray(res.centroids.s0, np.uint64),
                 mu1=np.asarray(res.centroids.s1, np.uint64),
                 c0=np.asarray(res.assignment.s0, np.uint64),
                 c1=np.asarray(res.assignment.s1, np.uint64),
                 p0=np.asarray(pred.assignment.s0, np.uint64),
                 p1=np.asarray(pred.assignment.s1, np.uint64),
                 meta=np.frombuffer(json.dumps(meta).encode(), np.uint8))
    print(f"A: fit+predict done, wire {ws.payload_bytes} payload bytes / "
          f"{ws.rounds} rounds over {t.stats.frames_sent} frames",
          flush=True)
    _wire_stats_line("A", t, {"retries": int(chan.retries),
                              "reconnects": int(chan.reconnects)})
    ws.bye()
    ft.close()
    _trace_finish(args)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=("A", "B"), required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="A: listen port (0 = ephemeral, printed); "
                         "B: A's port")
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--predict-n", type=int, default=16)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--partition", choices=("vertical", "horizontal"),
                    default="vertical")
    ap.add_argument("--sparse-frac", type=float, default=0.0)
    ap.add_argument("--batch-size", type=int, default=None)
    ap.add_argument("--offline",
                    choices=("on_demand", "pooled", "streamed"),
                    default="on_demand")
    ap.add_argument("--no-pipeline", action="store_true")
    ap.add_argument("--io-timeout", type=float, default=60.0)
    ap.add_argument("--auth-key", default=None,
                    help="shared session passphrase: frames carry a keyed "
                         "BLAKE2b MAC instead of a CRC (both roles must "
                         "agree; tampered/unkeyed frames are dropped)")
    ap.add_argument("--out", default=None,
                    help="A: write result shares + accounting npz here")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--die-at-iter", type=int, default=None,
                    help="A: os._exit right after this iteration's "
                         "checkpoint publishes (crash simulation)")
    ap.add_argument("--die-at", default=None,
                    help="arm chaos kill-points: comma-separated "
                         "point[:nth] (e.g. fit.mid_s1:4, wire.serve:20); "
                         "the process hard-exits at the Nth hit")
    ap.add_argument("--auto-resume", action="store_true",
                    help="A: negotiate the resume step with B on start "
                         "(incarnation announce + min(step) agreement); "
                         "requires --checkpoint-dir")
    ap.add_argument("--state-dir", default=None,
                    help="B: durable progress-marker directory for the "
                         "resume negotiation")
    ap.add_argument("--peer-wait", type=float, default=0.0,
                    help="park budget (s): survive a peer crash+restart "
                         "this long instead of dying (A: per-request "
                         "reconnect wait; B: extends the idle budget)")
    ap.add_argument("--fault-drop", type=float, default=0.0)
    ap.add_argument("--fault-dup", type=float, default=0.0)
    ap.add_argument("--fault-corrupt", type=float, default=0.0)
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--fault-sever-at", default=None,
                    help="comma-separated send indices at which to tear "
                         "the connection down (deterministic)")
    ap.add_argument("--trace-out", default=None,
                    help="enable span tracing; export this role's "
                         "Chrome-trace JSON here on exit (merge A+B "
                         "files with repro.obs.merge_traces)")
    args = ap.parse_args(argv)
    try:
        if args.role == "B":
            if args.port == 0:
                ap.error("role B needs A's --port")
            _party_b(args)
        else:
            _party_a(args)
    except ResumeMismatch as e:
        # terminal: a config mismatch can't be fixed by restarting, so
        # the supervisor must NOT respawn on this exit code
        print(f"{args.role}: RESUME MISMATCH — {e}", flush=True)
        raise SystemExit(4)


if __name__ == "__main__":
    try:
        main()
    except BrokenPipeError:
        sys.exit(1)
