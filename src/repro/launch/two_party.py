"""Two-PROCESS secure k-means: the fit and predict protocols over a real
TCP socket (DESIGN.md §13).

    # party A (engine) — binds, prints "LISTENING <port>", runs the fit
    PYTHONPATH=src python -m repro.launch.two_party --role A --port 0 \
        --out /tmp/a.npz
    # party B (responder) — dials A and answers the wire until BYE
    PYTHONPATH=src python -m repro.launch.two_party --role B --port <port>

Deployment shape: the repo's engine simulates BOTH parties' protocol
state in one process (core/protocol.py), so party A hosts the joint
simulation while party B is a pure wire peer — it ships its data slice
on request (a real length-prefixed blob over TCP), then echoes the
online protocol's exchange frames (core/channel.serve_peer). Every byte
and round the CommLog tallies is carried by a real frame with sequence
number and CRC, so a socket fit's shares AND accounting are bit-exact
against the in-process fit — test-enforced on all partition × sparsity
combos (tests/test_wire.py).

`--die-at-iter N` kills party A with os._exit right after the iteration-N
checkpoint publishes (requires --checkpoint-dir) — the crash half of the
checkpoint/resume acceptance test; rerunning with --resume (fresh B)
finishes bit-exact against an uninterrupted run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.core.channel import (ReliableChannel, SocketTransport,
                                WireSession, WireTimeout, serve_peer,
                                session_key)
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.obs import trace as _trace


def _trace_setup(args) -> None:
    if args.trace_out:
        _trace.configure(enabled=True,
                         process=f"party_{args.role.lower()}")


def _trace_finish(args) -> None:
    if args.trace_out:
        t = _trace.get_tracer()
        t.export_chrome(args.trace_out)
        print(f"{args.role}: trace {len(t.events())} spans -> "
              f"{args.trace_out}", flush=True)


def make_data(n: int, d: int, k: int, seed: int,
              sparse_frac: float = 0.0) -> np.ndarray:
    """Deterministic gaussian blobs (optionally sparsified) — the shared
    generator both parties AND the in-process reference fit use, so the
    only thing the wire changes is where the bytes travel."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0.0, 4.0, size=(k, d))
    x = centers[rng.integers(0, k, size=n)] + rng.normal(size=(n, d))
    if sparse_frac > 0:
        x = np.where(rng.random(x.shape) < sparse_frac, 0.0, x)
    return x


def split_data(x: np.ndarray, partition: str) -> tuple:
    n, d = x.shape
    if partition == "vertical":
        return x[:, :d // 2], x[:, d // 2:]
    return x[:n // 2], x[n // 2:]


def _auth(args) -> bytes | None:
    return session_key(args.auth_key) if args.auth_key else None


def _party_b(args) -> None:
    _trace_setup(args)
    t = SocketTransport("connect", host=args.host, port=args.port,
                        io_timeout_s=args.io_timeout)

    def on_blob(meta, arrays):
        if meta.get("op") != "get_slice":
            raise ValueError(f"unknown blob op {meta!r}")
        x = make_data(int(meta["n"]), int(meta["d"]), int(meta["k"]),
                      int(meta["seed"]), float(meta["sparse_frac"]))
        _, x_b = split_data(x, meta["partition"])
        return {"op": "slice"}, {"x_b": x_b}

    try:
        stats = serve_peer(t, on_blob=on_blob,
                           idle_timeout_s=args.io_timeout,
                           auth_key=_auth(args))
    except WireTimeout as e:
        # engine crashed or unreachable past the idle budget: exit with a
        # clear diagnostic (its checkpoint-resume relaunches a fresh B)
        print(f"B: giving up — {e}", flush=True)
        t.close()
        raise SystemExit(3)
    print(f"B: served {stats.served} requests, "
          f"{stats.dedup_replays} dedup replays", flush=True)
    t.close()
    _trace_finish(args)


def _party_a(args) -> None:
    _trace_setup(args)
    t = SocketTransport("listen", host=args.host, port=args.port,
                        io_timeout_s=args.io_timeout)
    print(f"LISTENING {t.port}", flush=True)
    ws = WireSession(ReliableChannel(t, deadline_s=args.io_timeout,
                                     auth_key=_auth(args)))

    x = make_data(args.n, args.d, args.k, args.seed, args.sparse_frac)
    x_a, x_b_local = split_data(x, args.partition)
    # B's slice arrives over the wire — the engine never recomputes it
    meta, arrays = ws.send_arrays(
        {"op": "get_slice", "n": args.n, "d": args.d, "k": args.k,
         "seed": args.seed, "sparse_frac": args.sparse_frac,
         "partition": args.partition}, {})
    x_b = arrays["x_b"]
    assert x_b.shape == x_b_local.shape, "peer slice geometry mismatch"

    cfg = KMeansConfig(k=args.k, iters=args.iters, seed=args.seed,
                       partition=args.partition,
                       sparse=args.sparse_frac > 0,
                       batch_size=args.batch_size,
                       offline=args.offline,
                       pipeline=not args.no_pipeline, backend="xla")
    km = SecureKMeans(cfg)
    ckpt = None
    if args.checkpoint_dir:
        from repro.checkpoint.fit import FitCheckpointer

        def after_save(state, _path):
            if args.die_at_iter is not None \
                    and state.iteration >= args.die_at_iter \
                    and state.batch == 0:
                print(f"DYING at iteration {state.iteration} "
                      "(post-checkpoint)", flush=True)
                os._exit(17)    # simulated crash: no cleanup, no BYE

        ckpt = FitCheckpointer(args.checkpoint_dir,
                               every=args.checkpoint_every,
                               after_save=after_save)
    res = km.fit(x_a, x_b, wire=ws, checkpoint=ckpt, resume=args.resume)

    # score a fresh arrival batch over the same session
    arr = make_data(args.predict_n, args.d, args.k, args.seed + 1,
                    args.sparse_frac)
    pa, pb = split_data(arr, args.partition)
    pred = km.predict(pa, pb, wire=ws)

    d = res.log.by_tag("online")
    meta = {
        "counters": {a: int(getattr(res.dealer, a))
                     for a in ("n_matmul", "n_mul", "n_bin")},
        "fit_online": {k_: [int(v[0]), int(v[1])] for k_, v in d.items()},
        "predict_online": {k_: [int(v[0]), int(v[1])]
                           for k_, v in pred.log.by_tag("online").items()},
        "wire_payload_bytes": int(ws.payload_bytes),
        "wire_rounds": int(ws.rounds),
        "frames_sent": int(t.stats.frames_sent),
        "wire_bytes_sent": int(t.stats.wire_bytes_sent),
    }
    if args.out:
        np.savez(args.out,
                 mu0=np.asarray(res.centroids.s0, np.uint64),
                 mu1=np.asarray(res.centroids.s1, np.uint64),
                 c0=np.asarray(res.assignment.s0, np.uint64),
                 c1=np.asarray(res.assignment.s1, np.uint64),
                 p0=np.asarray(pred.assignment.s0, np.uint64),
                 p1=np.asarray(pred.assignment.s1, np.uint64),
                 meta=np.frombuffer(json.dumps(meta).encode(), np.uint8))
    print(f"A: fit+predict done, wire {ws.payload_bytes} payload bytes / "
          f"{ws.rounds} rounds over {t.stats.frames_sent} frames",
          flush=True)
    ws.bye()
    t.close()
    _trace_finish(args)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=("A", "B"), required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="A: listen port (0 = ephemeral, printed); "
                         "B: A's port")
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--predict-n", type=int, default=16)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--partition", choices=("vertical", "horizontal"),
                    default="vertical")
    ap.add_argument("--sparse-frac", type=float, default=0.0)
    ap.add_argument("--batch-size", type=int, default=None)
    ap.add_argument("--offline",
                    choices=("on_demand", "pooled", "streamed"),
                    default="on_demand")
    ap.add_argument("--no-pipeline", action="store_true")
    ap.add_argument("--io-timeout", type=float, default=60.0)
    ap.add_argument("--auth-key", default=None,
                    help="shared session passphrase: frames carry a keyed "
                         "BLAKE2b MAC instead of a CRC (both roles must "
                         "agree; tampered/unkeyed frames are dropped)")
    ap.add_argument("--out", default=None,
                    help="A: write result shares + accounting npz here")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--checkpoint-every", type=int, default=1)
    ap.add_argument("--die-at-iter", type=int, default=None,
                    help="A: os._exit right after this iteration's "
                         "checkpoint publishes (crash simulation)")
    ap.add_argument("--trace-out", default=None,
                    help="enable span tracing; export this role's "
                         "Chrome-trace JSON here on exit (merge A+B "
                         "files with repro.obs.merge_traces)")
    args = ap.parse_args(argv)
    if args.role == "B":
        if args.port == 0:
            ap.error("role B needs A's --port")
        _party_b(args)
    else:
        _party_a(args)


if __name__ == "__main__":
    try:
        main()
    except BrokenPipeError:
        sys.exit(1)
