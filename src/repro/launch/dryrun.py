import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

# Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
# production meshes and dump memory/cost/collective analyses.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
#       --shape train_4k --mesh multi
#
# Cells: 10 archs x 4 shapes (skips recorded with reasons, DESIGN.md §5)
# + the paper's own kmeans-fraud online iteration. Meshes: single pod
# (16 data x 16 model = 256 chips) and 2 pods (2 x 16 x 16 = 512).

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

jax.config.update("jax_enable_x64", True)  # uint64 ring for the kmeans cell

import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES, all_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

BF16 = jnp.bfloat16

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

# Per-cell training microbatch counts (activation-memory control; see
# EXPERIMENTS.md §Perf for the derivation).
MICROBATCHES = {("llama3-405b", "train_4k"): 8,
                ("deepseek-v2-236b", "train_4k"): 2,
                ("command-r-35b", "train_4k"): 2}
# >=100B params: bf16 Adam moments (DESIGN.md §6)
BF16_MOMENT_ARCHS = {"llama3-405b", "deepseek-v2-236b"}


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device result-buffer bytes of every collective in the
    post-partitioning HLO. '-start' async forms count once ('-done' skipped).
    Returns {op_kind: bytes} + derived per-device link traffic where
    all-reduce counts 2x (ring reduce-scatter + all-gather)."""
    kinds = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
    out = {k: 0 for k in kinds}
    shape_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(\(?[^=]*?)\s+([a-z\-]+)\(", line)
        if not m:
            continue
        op = m.group(2)
        base = op[:-6] if op.endswith("-start") else op
        if base not in kinds or op.endswith("-done"):
            continue
        sizes = []
        for dt, dims in shape_re.findall(m.group(1)):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for tok in dims.split(","):
                if tok:
                    n *= int(tok)
            sizes.append(n * _DTYPE_BYTES[dt])
        if not sizes:
            continue
        # async start ops return (operand_alias, result): count the result
        out[base] += max(sizes) if op.endswith("-start") else sum(sizes)
    out["link_bytes"] = sum(v * (2 if k == "all-reduce" else 1)
                            for k, v in out.items() if k in kinds)
    return out


# ---------------------------------------------------------------------------
# input specs per (arch, shape)
# ---------------------------------------------------------------------------

def input_specs(arch_id: str, shape_name: str, *, cfg=None,
                global_batch: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    spec = all_archs()[arch_id]
    cfg = cfg or spec.config
    sh = SHAPES[shape_name]
    b, s = global_batch or sh.global_batch, sh.seq_len
    ii = lambda *sp: jax.ShapeDtypeStruct(sp, np.int32)
    bb = lambda *sp: jax.ShapeDtypeStruct(sp, BF16)
    if sh.kind == "train":
        batch = {"tokens": ii(b, s), "labels": ii(b, s)}
        if cfg.enc_dec:
            batch["enc_inputs"] = bb(b, s, cfg.d_model)
        if cfg.frontend == "vlm":
            batch["patch_embeds"] = bb(b, cfg.n_patches, cfg.d_model)
        return batch
    if sh.kind == "prefill":
        batch = {"tokens": ii(b, s)}
        if cfg.enc_dec:
            batch["enc_inputs"] = bb(b, s, cfg.d_model)
        if cfg.frontend == "vlm":
            batch["patch_embeds"] = bb(b, cfg.n_patches, cfg.d_model)
        return batch
    return {"token": ii(b, 1), "pos": jax.ShapeDtypeStruct((), np.int32)}


def _opt_state_shardings(param_sh, mesh):
    rep = NamedSharding(mesh, P())
    return {"adam": {"m": param_sh, "v": param_sh, "step": rep}}


def _prefill_step(cfg):
    from repro.models.lm import forward

    def prefill(params, batch):
        hidden = forward(params, cfg, tokens=batch.get("tokens"),
                         enc_inputs=batch.get("enc_inputs"),
                         patch_embeds=batch.get("patch_embeds"))
        return (hidden[:, -1].astype(BF16) @ params["head"]).astype(
            jnp.float32)
    return prefill


def lower_cell(arch_id: str, shape_name: str, mesh, *, cfg=None,
               micro: int | None = None,
               global_batch: int | None = None,
               sharding_mode: str = "2d") -> dict:
    """Lower + compile one cell; return analysis record. cfg/micro/batch/
    sharding_mode overrides support the roofline probes and the §Perf
    hillclimb variants (launch/roofline.py, launch/perf.py)."""
    from repro.models import sharding as S
    from repro.models.lm import init_params
    from repro.serving.decode import init_cache
    from repro.training.adamw import AdamWConfig
    from repro.training.train_step import init_state, make_train_step

    spec = all_archs()[arch_id]
    cfg = cfg or spec.config
    sh = SHAPES[shape_name]
    t0 = time.perf_counter()

    # pin activation batch axes inside layer scans (DESIGN.md §6; without
    # this pure-FSDP lets GSPMD replicate the scan carry)
    act_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if sharding_mode == "fsdp":
        act_axes = act_axes + ("model",)
    if sharding_mode == "repl_act" or sh.kind == "decode":
        # decode §Perf: tiny token batches — replicated activations let the
        # contraction partial-sum instead of all-gathering FSDP weights
        # (2.02 s -> 1.31 s on llama3 decode_32k)
        act_axes = ()
    gb_eff = global_batch or sh.global_batch
    n_batch_shards = int(np.prod([mesh.shape[a] for a in act_axes]) or 1)
    if act_axes and gb_eff % n_batch_shards != 0:
        act_axes = ()                       # e.g. long_500k's global_batch=1
    cfg = dataclasses.replace(cfg, act_axes=act_axes)

    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    param_sh = S.param_shardings(mesh, params_shape, sharding_mode)
    batch = input_specs(arch_id, shape_name, cfg=cfg,
                        global_batch=global_batch)
    rep = NamedSharding(mesh, P())

    if sh.kind == "train":
        opt_cfg = AdamWConfig(
            moment_dtype=BF16 if arch_id in BF16_MOMENT_ARCHS
            else jnp.float32)
        micro = micro if micro is not None \
            else MICROBATCHES.get((arch_id, shape_name), 1)
        step = make_train_step(cfg, opt_cfg, microbatches=micro)
        state_shape = jax.eval_shape(
            lambda: init_state(params_shape_to_zeros(params_shape), opt_cfg))
        state_sh = _opt_state_shardings(param_sh, mesh)
        batch_sh = S.batch_shardings(mesh, batch, sharding_mode)
        jitted = jax.jit(step,
                         in_shardings=(param_sh, state_sh, batch_sh),
                         out_shardings=(param_sh, state_sh,
                                        {"loss": rep, "grad_norm": rep}),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_shape, state_shape, batch)
    elif sh.kind == "prefill":
        prefill = _prefill_step(cfg)
        batch_sh = S.batch_shardings(mesh, batch, sharding_mode)
        jitted = jax.jit(prefill, in_shardings=(param_sh, batch_sh),
                         out_shardings=S.batch_shardings(
                             mesh, jax.ShapeDtypeStruct(
                                 (global_batch or sh.global_batch,
                                  cfg.vocab_padded), np.float32)))
        lowered = jitted.lower(params_shape, batch)
    else:  # decode
        from repro.serving.decode import serve_step
        b = global_batch or sh.global_batch
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, b, sh.seq_len,
                               enc_len=sh.seq_len if cfg.enc_dec else 0))
        cache_sh = S.cache_shardings(mesh, cache_shape)
        tok_sh = S.batch_shardings(mesh, batch["token"])

        def decode(params, cache, token, pos):
            return serve_step(params, cfg, cache, token, pos)

        jitted = jax.jit(decode,
                         in_shardings=(param_sh, cache_sh, tok_sh, rep),
                         donate_argnums=(1,))
        lowered = jitted.lower(params_shape, cache_shape, batch["token"],
                               batch["pos"])

    compiled = lowered.compile()
    rec = analyze(compiled)
    rec.update(arch=arch_id, shape=shape_name,
               mesh="x".join(str(mesh.shape[a]) for a in mesh.axis_names),
               status="ok", compile_s=round(time.perf_counter() - t0, 1))
    return rec


def params_shape_to_zeros(params_shape):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_shape)


def analyze(compiled) -> dict:
    rec = {}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec["flops_per_device"] = float(ca.get("flops", -1))
        rec["bytes_per_device"] = float(ca.get("bytes accessed", -1))
    except Exception as e:  # pragma: no cover
        rec["cost_error"] = str(e)[:200]
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
        if hasattr(ma, "peak_memory_in_bytes"):
            rec["memory"]["peak_memory_in_bytes"] = int(ma.peak_memory_in_bytes)
    except Exception as e:  # pragma: no cover
        rec["memory_error"] = str(e)[:200]
    try:
        rec["collectives"] = parse_collectives(compiled.as_text())
    except Exception as e:  # pragma: no cover
        rec["collective_error"] = str(e)[:200]
    return rec


def lower_kmeans_cell(mesh) -> dict:
    """The paper's own config: one online Lloyd iteration on shares."""
    from repro.configs.kmeans_fraud import FULL as KCFG
    from repro.launch.kmeans_step import arg_shardings, online_iteration_fn
    t0 = time.perf_counter()
    fn, args = online_iteration_fn(KCFG.n, KCFG.d, KCFG.k, KCFG.d_a)
    shardings = arg_shardings(mesh, args, KCFG.n)
    jitted = jax.jit(fn, in_shardings=shardings,
                     out_shardings=NamedSharding(mesh, P()))
    compiled = jitted.lower(*args).compile()
    rec = analyze(compiled)
    rec.update(arch="kmeans-fraud", shape=f"n{KCFG.n}_d{KCFG.d}_k{KCFG.k}",
               mesh="x".join(str(mesh.shape[a]) for a in mesh.axis_names),
               status="ok", compile_s=round(time.perf_counter() - t0, 1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kmeans", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    cells = []
    if args.all or args.kmeans:
        cells.append(("kmeans-fraud", None))
    if args.all:
        for arch_id in all_archs():
            for shape_name in SHAPES:
                cells.append((arch_id, shape_name))
    elif args.arch and args.arch != "kmeans-fraud":
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells += [(args.arch, s) for s in shapes]
    elif args.arch == "kmeans-fraud" and not (args.all or args.kmeans):
        cells.append(("kmeans-fraud", None))

    results = []
    for arch_id, shape_name in cells:
        for mesh_name, mesh in meshes:
            label = f"{arch_id}/{shape_name}/{mesh_name}"
            if arch_id != "kmeans-fraud":
                spec = all_archs()[arch_id]
                if shape_name in spec.skip_shapes:
                    results.append({"arch": arch_id, "shape": shape_name,
                                    "mesh": mesh_name, "status": "skip",
                                    "reason": spec.skip_reason})
                    print(f"[skip] {label}: {spec.skip_reason[:60]}")
                    continue
            try:
                with mesh:
                    rec = (lower_kmeans_cell(mesh) if arch_id == "kmeans-fraud"
                           else lower_cell(arch_id, shape_name, mesh))
                rec["mesh_name"] = mesh_name
                results.append(rec)
                mem = rec.get("memory", {})
                print(f"[ok] {label}: compile {rec['compile_s']}s, "
                      f"flops/dev {rec.get('flops_per_device', -1):.3g}, "
                      f"argbytes/dev {mem.get('argument_size_in_bytes', -1):.3g}, "
                      f"link {rec.get('collectives', {}).get('link_bytes', -1):.3g}")
            except Exception as e:
                results.append({"arch": arch_id, "shape": shape_name,
                                "mesh": mesh_name, "status": "error",
                                "error": f"{type(e).__name__}: {e}"[:500]})
                print(f"[ERR] {label}: {type(e).__name__}: {str(e)[:160]}")
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error -> {args.out}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
