"""Production mesh builders (functions, not module constants: importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods =
    512 chips as (pod=2, data=16, model=16); 'pod' is the DCN-crossing pure-DP
    axis (gradient all-reduce only, optionally int8-compressed)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh():
    """1-device mesh with the production axis names (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
