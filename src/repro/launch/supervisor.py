"""Child-process supervisor for the self-healing two-party runtime
(DESIGN.md §16).

Each party's engine runs as a `SupervisedChild`: the supervisor spawns
it, captures its merged stdout/stderr (to a log file and an in-memory
ring for the chaos bench to parse), and on death applies a
`RestartPolicy` — bounded restarts with exponential backoff and seeded
jitter, crash-loop detection (N fast deaths in a row → terminal
diagnostic instead of a respawn storm), and a set of *terminal* exit
codes that must never be retried (0 = clean, and e.g. two_party's 4 =
`ResumeMismatch`, where restarting cannot help).

Recovery is the children's job, not the supervisor's: party A relaunches
with `--auto-resume` and renegotiates the resume step with B; a scoring
server relaunches into its `ServeCheckpointer` replay. The supervisor
only guarantees that *some* incarnation is running until one exits
terminally, and records the timeline (spawn / ready / exit events) from
which the chaos bench computes MTTR.

Readiness: `ready_pattern` (a regex matched against stdout lines, e.g.
``^LISTENING`` / ``^SERVING``) and/or `health_url` (polled until it
answers 200 — the `/health` endpoint on `--metrics-port`, which only
goes 200 once a `ScoringService` reports READY).
"""
from __future__ import annotations

import dataclasses
import os
import re
import subprocess
import sys
import threading
import time
import urllib.request

import numpy as np


def free_port(host: str = "127.0.0.1") -> int:
    """A currently-free TCP port. The supervisor picks ports up front so
    every incarnation of a child listens on the SAME address and the
    surviving peer's redial loop finds the restarted process."""
    import socket
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.bind((host, 0))
    port = s.getsockname()[1]
    s.close()
    return port


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """Bounded-restart policy. `max_restarts` counts respawns (not the
    first spawn); backoff grows `backoff_s * 2**n` capped at
    `backoff_max_s`, scaled by seeded jitter in [0.5, 1.5). A death
    within `crash_loop_window_s` of its spawn is a *fast* death;
    `crash_loop_threshold` consecutive fast deaths are declared a crash
    loop and the child goes terminal with a diagnostic."""

    max_restarts: int = 5
    backoff_s: float = 0.2
    backoff_max_s: float = 3.0
    jitter_seed: int = 23
    crash_loop_window_s: float = 3.0
    crash_loop_threshold: int = 3


@dataclasses.dataclass
class ChildEvent:
    kind: str           # spawn | ready | exit | terminal
    t: float            # monotonic timestamp
    incarnation: int
    detail: str = ""


class SupervisedChild:
    """One supervised OS process with restart policy.

    `argv_for` is either a plain argv list (same every incarnation) or a
    callable `incarnation -> argv` — the chaos bench uses the callable
    to arm kill-points on incarnation 0 only, so a restart doesn't
    re-kill itself at the same seam forever."""

    def __init__(self, name: str, argv_for, *,
                 policy: RestartPolicy | None = None,
                 terminal_codes: tuple = (0, 4),
                 env: dict | None = None, cwd: str | None = None,
                 log_path: str | None = None,
                 ready_pattern: str | None = None,
                 health_url: str | None = None,
                 on_line=None):
        self.name = name
        self._argv_for = argv_for if callable(argv_for) \
            else (lambda _i: list(argv_for))
        self.policy = policy or RestartPolicy()
        self.terminal_codes = set(terminal_codes)
        self.env = env
        self.cwd = cwd
        self.log_path = log_path
        self.ready_re = re.compile(ready_pattern) if ready_pattern else None
        self.health_url = health_url
        self.on_line = on_line
        self._jitter = np.random.default_rng(self.policy.jitter_seed)
        self.events: list[ChildEvent] = []
        self.lines: list[str] = []
        self.incarnation = -1
        self.restarts = 0
        self.returncode: int | None = None
        self.terminal_reason: str | None = None
        self._proc: subprocess.Popen | None = None
        self._stop = threading.Event()
        self._done = threading.Event()
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._run,
                                        name=f"supervise-{name}",
                                        daemon=True)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "SupervisedChild":
        self._thread.start()
        return self

    def wait(self, timeout: float | None = None) -> bool:
        """True once the child reached a terminal state."""
        return self._done.wait(timeout)

    def stop(self) -> None:
        """Tear the child down (terminate → kill) and end supervision."""
        self._stop.set()
        p = self._proc
        if p is not None and p.poll() is None:
            p.terminate()
            try:
                p.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                p.kill()
        self._thread.join(timeout=10.0)

    @property
    def success(self) -> bool:
        return self.returncode == 0

    # -- events / metrics ------------------------------------------------
    def _event(self, kind: str, detail: str = "") -> None:
        with self._lock:
            self.events.append(ChildEvent(kind, time.monotonic(),
                                          self.incarnation, detail))

    def _emit(self, line: str) -> None:
        with self._lock:
            self.lines.append(line)
        if self.log_path:
            try:
                with open(self.log_path, "a", encoding="utf-8") as f:
                    f.write(line + "\n")
            except OSError:
                pass
        if self.on_line is not None:
            try:
                self.on_line(line)
            except Exception:
                pass

    def restart_latencies(self) -> list[float]:
        """Seconds from each death to the NEXT incarnation's readiness
        (its ready event when readiness is tracked, else its spawn) —
        the per-restart recovery times MTTR averages."""
        with self._lock:
            evs = list(self.events)
        out, last_exit = [], None
        tracked = self.ready_re is not None or self.health_url is not None
        for e in evs:
            if e.kind == "exit":
                last_exit = e.t
            elif last_exit is not None and (
                    e.kind == "ready" if tracked else e.kind == "spawn"):
                out.append(e.t - last_exit)
                last_exit = None
        return out

    def tail(self, n: int = 20) -> str:
        with self._lock:
            return "\n".join(self.lines[-n:])

    # -- the supervision loop -------------------------------------------
    def _poll_health(self, incarnation: int) -> None:
        while not self._stop.is_set() and incarnation == self.incarnation:
            p = self._proc
            if p is None or p.poll() is not None:
                return
            try:
                with urllib.request.urlopen(self.health_url,
                                            timeout=1.0) as r:
                    if r.status == 200:
                        self._event("ready", "health=READY")
                        return
            except Exception:
                pass
            time.sleep(0.2)

    def _run(self) -> None:
        fast_deaths = 0
        while not self._stop.is_set():
            argv = self._argv_for(self.incarnation + 1)
            self.incarnation += 1
            spawned = time.monotonic()
            try:
                self._proc = subprocess.Popen(
                    argv, stdout=subprocess.PIPE,
                    stderr=subprocess.STDOUT, text=True,
                    env=self.env, cwd=self.cwd)
            except OSError as e:
                self.returncode = -1
                self.terminal_reason = f"spawn failed: {e}"
                self._event("terminal", self.terminal_reason)
                break
            self._event("spawn", " ".join(argv[:4]) + " ...")
            if self.health_url:
                threading.Thread(target=self._poll_health,
                                 args=(self.incarnation,),
                                 daemon=True).start()
            saw_ready = False
            for line in self._proc.stdout:
                line = line.rstrip("\n")
                self._emit(f"[{self.name}#{self.incarnation}] {line}")
                if not saw_ready and self.ready_re is not None \
                        and self.ready_re.search(line):
                    saw_ready = True
                    if not self.health_url:     # health poll owns 'ready'
                        self._event("ready", line[:80])
            rc = self._proc.wait()
            died = time.monotonic()
            self._event("exit", f"rc={rc}")
            if self._stop.is_set():
                self.returncode = rc
                self.terminal_reason = "stopped"
                break
            if rc in self.terminal_codes:
                self.returncode = rc
                self.terminal_reason = "clean exit" if rc == 0 \
                    else f"terminal exit code {rc}"
                self._event("terminal", self.terminal_reason)
                break
            if died - spawned < self.policy.crash_loop_window_s:
                fast_deaths += 1
            else:
                fast_deaths = 0
            if fast_deaths >= self.policy.crash_loop_threshold:
                self.returncode = rc
                self.terminal_reason = (
                    f"crash loop: {fast_deaths} consecutive deaths "
                    f"within {self.policy.crash_loop_window_s}s of spawn "
                    f"(last rc={rc}); last output:\n" + self.tail(10))
                self._event("terminal", "crash loop")
                break
            if self.restarts >= self.policy.max_restarts:
                self.returncode = rc
                self.terminal_reason = (
                    f"restart budget exhausted "
                    f"({self.policy.max_restarts}); last rc={rc}")
                self._event("terminal", self.terminal_reason)
                break
            self.restarts += 1
            base = min(self.policy.backoff_max_s,
                       self.policy.backoff_s * (2 ** (self.restarts - 1)))
            pause = base * (0.5 + float(self._jitter.random()))
            self._emit(f"[{self.name}] restart {self.restarts} after "
                       f"rc={rc}, backoff {pause:.2f}s")
            if self._stop.wait(pause):
                self.returncode = rc
                self.terminal_reason = "stopped"
                break
        self._done.set()


class Supervisor:
    """A set of supervised children sharing one lifetime: `start()` them
    all, `wait()` until every child is terminal (or a deadline), then
    read each child's outcome. `stop()` tears everything down."""

    def __init__(self):
        self.children: list[SupervisedChild] = []

    def add(self, child: SupervisedChild) -> SupervisedChild:
        self.children.append(child)
        return child

    def spawn(self, name: str, argv_for, **kw) -> SupervisedChild:
        return self.add(SupervisedChild(name, argv_for, **kw))

    def start(self) -> "Supervisor":
        for c in self.children:
            c.start()
        return self

    def wait(self, timeout: float | None = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        for c in self.children:
            left = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            if not c.wait(left):
                return False
        return True

    def stop(self) -> None:
        for c in self.children:
            c.stop()

    def summary(self) -> dict:
        return {c.name: {"returncode": c.returncode,
                         "restarts": c.restarts,
                         "incarnations": c.incarnation + 1,
                         "reason": c.terminal_reason,
                         "restart_latencies": c.restart_latencies()}
                for c in self.children}


def python_argv(module: str, *args: str) -> list[str]:
    """argv running `python -m module args...` with this interpreter."""
    return [sys.executable, "-m", module, *args]


def child_env(extra: dict | None = None) -> dict:
    """Current environment (incl. PYTHONPATH=src wiring) + overrides."""
    env = dict(os.environ)
    env.update(extra or {})
    return env
