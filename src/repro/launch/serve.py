"""Batched serving driver: prefill + decode loop over the serve_step path.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-34b \
        --reduced --batch 4 --prompt-len 32 --gen 64

Serves a (reduced by default) model with a static batch of requests:
prefill fills the KV cache token-by-token through the same serve_step used
by the dry-run (so the exercised code path is exactly the production one),
then greedy-decodes `gen` tokens. Reports per-phase latency and tokens/s.
Production differences (continuous batching, paged caches) are design-noted
in DESIGN.md §6 — the cache layouts here already support ring-buffer
windows and compressed MLA entries.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import all_archs
from repro.models.lm import init_params
from repro.serving.decode import init_cache, serve_step


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 64, seed: int = 0,
          verbose: bool = True) -> dict:
    spec = all_archs()[arch]
    cfg = spec.reduced if reduced else spec.config
    rng = np.random.default_rng(seed)
    params = init_params(cfg, jax.random.key(seed))
    max_seq = prompt_len + gen
    cache = init_cache(cfg, batch, max_seq,
                       enc_len=prompt_len if cfg.enc_dec else 0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (batch, prompt_len)), jnp.int32)

    step = jax.jit(lambda p, c, t, pos: serve_step(p, cfg, c, t, pos))

    t0 = time.perf_counter()
    logits = None
    for pos in range(prompt_len):                  # prefill via decode path
        logits, cache = step(params, cache, prompts[:, pos:pos + 1],
                             jnp.int32(pos))
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    t0 = time.perf_counter()
    for pos in range(prompt_len, prompt_len + gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = step(params, cache, tok[:, None], jnp.int32(pos))
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    toks = np.stack(out_tokens, 1)                 # (batch, gen)
    result = {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * gen / t_decode,
        "tokens": toks,
        "finite": bool(np.isfinite(np.asarray(logits)).all()),
    }
    if verbose:
        print(f"{arch}: batch={batch} prompt={prompt_len} gen={gen}")
        print(f"  prefill {t_prefill:.2f}s  decode {t_decode:.2f}s "
              f"({result['decode_tok_per_s']:.1f} tok/s)")
        print(f"  sample continuation: {toks[0, :16].tolist()}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    args = ap.parse_args()
    serve(args.arch, reduced=args.reduced, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
