"""Double-buffered host/device pipeline executor (DESIGN.md §11).

One online *step* — a minibatch of a Lloyd iteration, or one serving
launch — decomposes into four phases:

    pre     host work that depends on nothing in flight: the Protocol-2
            exchange computable from the centroid shares, plus pinning the
            step's offline tranche (SlotDealer.acquire / bank draw +
            materialize_offline)
    launch  the compiled program dispatch — ASYNC under jax, so the host
            gets control back while the device crunches
    mid     host work on the launch's outputs: the sparse S2 callback runs
            here and blocks on the assignment shares coming off the device
    post    the final dispatch / result assembly

`run_pipeline(pipeline=True)` slides step t+1's `pre` into the window
where step t's launch is on device — that is the ONLY reordering. Every
phase still runs exactly once per step, `pre` order stays monotonic in t,
and all correlated randomness is pinned per slot (the dealer fixes served
words at GENERATION time, in canonical slot order — never at acquisition
time), so pipeline=True and pipeline=False consume identical dealer words
and produce identical shares and CommLog tallies: the escape hatch is
stream-identical by construction, and any measured speedup cannot come
from computing something different.

Used by `SecureKMeans._fit_minibatch` (overlap batch t+1's Protocol-2
exchange + tranche pin with batch t's S1 launch) and by
`repro.serve.ScoringService.drain` (overlap request t+1's pre-launch
exchange + bank draw with request t's scoring launch).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple


class StageTask(NamedTuple):
    """One pipeline step. `mid`/`post` are optional; phase signatures:

        prep = pre()
        out  = launch(prep)
        m    = mid(prep, out)          # may block on device results
        res  = post(prep, out, m)      # appended to run_pipeline's result
    """

    pre: Callable[[], Any]
    launch: Callable[[Any], Any]
    mid: Callable[[Any, Any], Any] | None = None
    post: Callable[[Any, Any, Any], Any] | None = None


def run_pipeline(tasks, pipeline: bool = True) -> list:
    """Execute `tasks` in order, returning one result per task.

    pipeline=False: strict sequence pre -> launch -> mid -> post per task.
    pipeline=True: after dispatching task t's launch, task t+1's `pre` runs
    while the device is busy; then t's mid/post complete before t+1's
    launch. Single-threaded on the host — the overlap comes from jax's
    asynchronous dispatch, not from host threads."""
    tasks = list(tasks)
    results = []
    if not pipeline:
        for t in tasks:
            prep = t.pre()
            out = t.launch(prep)
            m = t.mid(prep, out) if t.mid is not None else None
            results.append(t.post(prep, out, m) if t.post is not None
                           else out)
        return results
    prep = tasks[0].pre() if tasks else None
    for i, t in enumerate(tasks):
        out = t.launch(prep)
        nxt = tasks[i + 1].pre() if i + 1 < len(tasks) else None
        m = t.mid(prep, out) if t.mid is not None else None
        results.append(t.post(prep, out, m) if t.post is not None else out)
        prep = nxt
    return results
