"""Double-buffered host/device pipeline executor (DESIGN.md §11).

One online *step* — a minibatch of a Lloyd iteration, or one serving
launch — decomposes into four phases:

    pre     host work that depends on nothing in flight: the Protocol-2
            exchange computable from the centroid shares, plus pinning the
            step's offline tranche (SlotDealer.acquire / bank draw +
            materialize_offline)
    launch  the compiled program dispatch — ASYNC under jax, so the host
            gets control back while the device crunches
    mid     host work on the launch's outputs: the sparse S2 callback runs
            here and blocks on the assignment shares coming off the device
    post    the final dispatch / result assembly

`run_pipeline(pipeline=True)` slides step t+1's `pre` into the window
where step t's launch is on device — that is the ONLY reordering. Every
phase still runs exactly once per step, `pre` order stays monotonic in t,
and all correlated randomness is pinned per slot (the dealer fixes served
words at GENERATION time, in canonical slot order — never at acquisition
time), so pipeline=True and pipeline=False consume identical dealer words
and produce identical shares and CommLog tallies: the escape hatch is
stream-identical by construction, and any measured speedup cannot come
from computing something different.

Used by `SecureKMeans._fit_minibatch` (overlap batch t+1's Protocol-2
exchange + tranche pin with batch t's S1 launch) and by
`repro.serve.ScoringService.drain` (overlap request t+1's pre-launch
exchange + bank draw with request t's scoring launch).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

from repro.obs import trace as _trace


@dataclasses.dataclass
class PipelineError:
    """Sentinel slotted into `run_pipeline`'s result list where task
    `index` raised (capture_errors=True): the failed task's remaining
    phases are skipped, every other task still runs. Only `Exception`s
    are captured — KeyboardInterrupt and friends always propagate."""

    index: int
    exc: Exception


class StageTask(NamedTuple):
    """One pipeline step. `mid`/`post` are optional; phase signatures:

        prep = pre()
        out  = launch(prep)
        m    = mid(prep, out)          # may block on device results
        res  = post(prep, out, m)      # appended to run_pipeline's result
    """

    pre: Callable[[], Any]
    launch: Callable[[Any], Any]
    mid: Callable[[Any, Any], Any] | None = None
    post: Callable[[Any, Any, Any], Any] | None = None


def run_pipeline(tasks, pipeline: bool = True,
                 capture_errors: bool = False) -> list:
    """Execute `tasks` in order, returning one result per task.

    pipeline=False: strict sequence pre -> launch -> mid -> post per task.
    pipeline=True: after dispatching task t's launch, task t+1's `pre` runs
    while the device is busy; then t's mid/post complete before t+1's
    launch. Single-threaded on the host — the overlap comes from jax's
    asynchronous dispatch, not from host threads.

    capture_errors=False (default): the first raising phase propagates,
    aborting the run — right for the fit loop, where batches are causally
    chained and a half-run iteration is useless. capture_errors=True: a
    task whose phase raises an `Exception` contributes a `PipelineError`
    result and its remaining phases are skipped; the other tasks still run
    — right for serving drains, where requests are independent and one
    poisoned request must not take down its whole group."""
    tasks = list(tasks)
    results = []
    span = _trace.span   # per-phase spans: one branch each when disabled

    def _launch(t, i, prep):
        with span("pipeline.launch", task=i):
            return t.launch(prep)

    def _mid_post(t, i, prep, out):
        if t.mid is not None:
            with span("pipeline.mid", task=i):
                m = t.mid(prep, out)
        else:
            m = None
        if t.post is None:
            return out
        with span("pipeline.post", task=i):
            return t.post(prep, out, m)

    def _phases(t, i):
        with span("pipeline.pre", task=i):
            prep = t.pre()
        out = _launch(t, i, prep)
        return _mid_post(t, i, prep, out)

    if not pipeline:
        for i, t in enumerate(tasks):
            if capture_errors:
                try:
                    results.append(_phases(t, i))
                except Exception as e:
                    results.append(PipelineError(i, e))
            else:
                results.append(_phases(t, i))
        return results

    def _pre(i):
        if i >= len(tasks):
            return None
        if not capture_errors:
            with span("pipeline.pre", task=i):
                return tasks[i].pre()
        try:
            with span("pipeline.pre", task=i):
                return tasks[i].pre()
        except Exception as e:
            return PipelineError(i, e)

    _unset = object()
    prep = _pre(0)
    for i, t in enumerate(tasks):
        if isinstance(prep, PipelineError):
            results.append(prep)
            prep = _pre(i + 1)
            continue
        nxt = _unset
        if capture_errors:
            try:
                out = _launch(t, i, prep)
                nxt = _pre(i + 1)
                res = _mid_post(t, i, prep, out)
            except Exception as e:
                res = PipelineError(i, e)
                if nxt is _unset:
                    # launch died before the overlap window opened; t+1's
                    # pre runs un-overlapped — never twice (pre() draws
                    # dealer words, so re-running it would corrupt streams)
                    nxt = _pre(i + 1)
        else:
            out = _launch(t, i, prep)
            nxt = _pre(i + 1)
            res = _mid_post(t, i, prep, out)
        results.append(res)
        prep = nxt
    return results
