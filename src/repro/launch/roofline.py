import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ same contract as dryrun.py: set before jax initializes.

# Roofline analysis (single-pod mesh) from the compiled dry-run artifacts.
#
# XLA's HLO cost analysis counts while-loop bodies ONCE regardless of trip
# count (verified empirically), and our models scan over layer groups (and
# microbatches). We therefore reconstruct exact totals with PROBE compiles:
#
#   probe0  = cell with every scan group at repeats=1 (+ microbatches=1,
#             batch = global_batch / microbatches): trip-1 loops are counted
#             exactly.
#   probe_g = same but group g at repeats=2  =>  unit_g = probe_g - probe0.
#   total   = M * (probe0 + sum_g (R_g - 1) * unit_g)       [per device]
#
# All inner loops (flash-attention chunks, CE chunks, tournament levels,
# NR iterations) are Python-unrolled in the model code precisely so this
# two-level correction is exact. Exception: the RWKV6 intra-chunk recurrence
# stays a lax.scan; its body is <2% of unit FLOPs (documented).
#
#   PYTHONPATH=src python -m repro.launch.roofline --out roofline.json

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.configs.base import SHAPES, ScanGroup, all_archs  # noqa: E402
from repro.launch import dryrun  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

# TPU v5e hardware model (assignment constants)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / chip (one ICI link; see DESIGN.md)
CHIPS = 256                  # single pod


def _with_repeats(cfg, group_repeats: list[int], enc_layers: int | None):
    groups = tuple(ScanGroup(g.unit, r)
                   for g, r in zip(cfg.groups, group_repeats))
    # scan_unroll: probe configs inline their (tiny) layer loops so XLA's
    # cost analysis counts every instruction — the production configs keep
    # rolled scans (compile time) and the correction formula extrapolates.
    kw = {"groups": groups, "scan_unroll": True}
    if cfg.enc_dec and enc_layers is not None:
        kw["n_enc_layers"] = enc_layers
    return dataclasses.replace(cfg, **kw)


def _measure(arch_id, shape_name, mesh, cfg, micro, global_batch):
    rec = dryrun.lower_cell(arch_id, shape_name, mesh, cfg=cfg, micro=micro,
                            global_batch=global_batch)
    return (rec.get("flops_per_device", 0.0),
            rec.get("bytes_per_device", 0.0),
            float(rec.get("collectives", {}).get("link_bytes", 0)))


def corrected_totals(arch_id: str, shape_name: str, mesh,
                     cfg_base=None) -> dict:
    """Per-device (flops, bytes, link_bytes) with scan-trip correction.

    Probes difference repeats=4 against repeats=2 (NOT 1): a length-1 scan
    inlines and lets GSPMD pick different (replicated!) shardings than the
    rolled loop, polluting the base term — observed as ~16x attention
    replication. R=2 and R=4 share in-loop-consistent shardings, verified
    by exact 2x scaling of the marginal layer.

        unit_g = (f[g=4] - f[all=2]) / 2
        base   = f[all=2] - sum_g 2*unit_g
        total  = microbatches * (base + sum_g R_g * unit_g)
    """
    spec = all_archs()[arch_id]
    cfg = cfg_base or spec.config
    sh = SHAPES[shape_name]
    micro = dryrun.MICROBATCHES.get((arch_id, shape_name), 1) \
        if sh.kind == "train" else 1
    gb = sh.global_batch // micro if sh.kind == "train" else None
    probe_micro = 1 if sh.kind == "train" else None

    scan_axes = [("group", i, g.repeats) for i, g in enumerate(cfg.groups)]
    if cfg.enc_dec:
        scan_axes.append(("encoder", None, cfg.n_enc_layers))

    twos = [2] * len(cfg.groups)
    cfg2 = _with_repeats(cfg, twos, 2 if cfg.enc_dec else None)
    f2, b2, l2 = _measure(arch_id, shape_name, mesh, cfg2, probe_micro, gb)

    units = []
    for kind, gi, repeats in scan_axes:
        reps = list(twos)
        enc = 2 if cfg.enc_dec else None
        if kind == "group":
            reps[gi] = 4
        else:
            enc = 4
        cfg4 = _with_repeats(cfg, reps, enc)
        f4, b4, l4 = _measure(arch_id, shape_name, mesh, cfg4, probe_micro,
                              gb)
        units.append((repeats, max(0.0, (f4 - f2) / 2),
                      max(0.0, (b4 - b2) / 2), max(0.0, (l4 - l2) / 2)))

    base_f = f2 - sum(2 * u[1] for u in units)
    base_b = b2 - sum(2 * u[2] for u in units)
    base_l = l2 - sum(2 * u[3] for u in units)
    tot_f = max(0.0, base_f) + sum(r * uf for r, uf, _, _ in units)
    tot_b = max(0.0, base_b) + sum(r * ub for r, _, ub, _ in units)
    tot_l = max(0.0, base_l) + sum(r * ul for r, _, _, ul in units)
    return {"flops_dev": micro * tot_f, "bytes_dev": micro * tot_b,
            "link_bytes_dev": micro * tot_l, "microbatches": micro}


def model_flops(arch_id: str, shape_name: str, cfg_base=None) -> float:
    """Analytic 'useful' FLOPs: 6*N*D train / 2*N*D prefill / 2*N*B decode,
    N = matmul params (embed-gather excluded, head included; MoE routed
    params scaled by top_k/E). Attention itself excluded by convention —
    the ratio reads low on long-sequence cells by design."""
    from repro.models.lm import init_params_shape_only
    spec = all_archs()[arch_id]
    cfg = cfg_base or spec.config
    sh = SHAPES[shape_name]
    shapes = init_params_shape_only(cfg)
    n = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if leaf.ndim < 2 or "embed" in keys:
            continue
        cnt = int(np.prod(leaf.shape))
        if "moe" in keys and any(w in keys for w in
                                 ("w_gate", "w_up", "w_down")) \
                and "shared" not in keys and cfg.n_experts:
            from repro.models.lm import padded_experts
            cnt = cnt * cfg.top_k / padded_experts(cfg)
        n += cnt
    if sh.kind == "train":
        return 6.0 * n * sh.global_batch * sh.seq_len
    if sh.kind == "prefill":
        return 2.0 * n * sh.global_batch * sh.seq_len
    return 2.0 * n * sh.global_batch          # decode: one token/seq


def analyze_cell(arch_id: str, shape_name: str, mesh) -> dict:
    t0 = time.perf_counter()
    tot = corrected_totals(arch_id, shape_name, mesh)
    compute_s = tot["flops_dev"] / PEAK_FLOPS
    memory_s = tot["bytes_dev"] / HBM_BW
    coll_s = tot["link_bytes_dev"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(arch_id, shape_name)
    mf_dev = mf / CHIPS
    return {
        "arch": arch_id, "shape": shape_name,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "roofline_step_s": step_s,
        "model_flops_global": mf,
        "hlo_flops_global": tot["flops_dev"] * CHIPS,
        "useful_ratio": mf_dev / max(tot["flops_dev"], 1.0),
        "roofline_fraction": (mf_dev / PEAK_FLOPS) / max(step_s, 1e-12),
        "microbatches": tot["microbatches"],
        "analysis_s": round(time.perf_counter() - t0, 1),
    }


def analyze_kmeans(mesh) -> dict:
    """The paper's own cell: protocol ops are fully unrolled (no lax.scan),
    so cost analysis is exact — no probes needed. MODEL_FLOPS = plaintext
    Lloyd iteration (distances + argmin + update)."""
    from repro.configs.kmeans_fraud import FULL as K
    rec = dryrun.lower_kmeans_cell(mesh)
    f = rec["flops_per_device"]
    b = rec["bytes_per_device"]
    l = float(rec.get("collectives", {}).get("link_bytes", 0))
    compute_s, memory_s, coll_s = f / PEAK_FLOPS, b / HBM_BW, l / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    mf = 2.0 * K.n * K.d * K.k + 4.0 * K.n * K.k + 2.0 * K.n * K.d
    return {"arch": "kmeans-fraud", "shape": f"n{K.n}_d{K.d}_k{K.k}",
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": max(terms, key=terms.get),
            "roofline_step_s": max(terms.values()),
            "model_flops_global": mf, "hlo_flops_global": f * CHIPS,
            "useful_ratio": (mf / CHIPS) / max(f, 1.0),
            "roofline_fraction": (mf / CHIPS / PEAK_FLOPS)
            / max(max(terms.values()), 1e-12),
            "microbatches": 1, "status": "ok"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="roofline_results.json")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)
    rows = []
    if args.arch in (None, "kmeans-fraud"):
        try:
            with mesh:
                rec = analyze_kmeans(mesh)
            rows.append(rec)
            print(f"[ok] kmeans-fraud: dominant={rec['dominant']} "
                  f"step={rec['roofline_step_s']:.4f}s "
                  f"useful={rec['useful_ratio']:.3f}")
        except Exception as e:
            rows.append({"arch": "kmeans-fraud", "status": "error",
                         "error": str(e)[:300]})
            print(f"[ERR] kmeans-fraud: {str(e)[:160]}")
    for arch_id, spec in all_archs().items():
        if args.arch and arch_id != args.arch:
            continue
        for shape_name in SHAPES:
            if args.shape and shape_name != args.shape:
                continue
            if shape_name in spec.skip_shapes:
                rows.append({"arch": arch_id, "shape": shape_name,
                             "status": "skip"})
                continue
            try:
                with mesh:
                    rec = analyze_cell(arch_id, shape_name, mesh)
                rec["status"] = "ok"
                rows.append(rec)
                print(f"[ok] {arch_id}/{shape_name}: dominant="
                      f"{rec['dominant']} step={rec['roofline_step_s']:.4f}s "
                      f"useful={rec['useful_ratio']:.2f} "
                      f"roofline={rec['roofline_fraction']:.2%}")
            except Exception as e:
                rows.append({"arch": arch_id, "shape": shape_name,
                             "status": "error",
                             "error": f"{type(e).__name__}: {e}"[:300]})
                print(f"[ERR] {arch_id}/{shape_name}: {str(e)[:160]}")
            with open(args.out, "w") as f:
                json.dump(rows, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
