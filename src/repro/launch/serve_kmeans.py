"""Secure fraud-scoring service driver: fit jointly, then serve a stream.

    PYTHONPATH=src python -m repro.launch.serve_kmeans \
        --n-train 2000 --requests 24 --mean-batch 32 --ladder 32,128

Synthesizes the paper's two-party fraud deployment (payment company holds
transaction features, merchant holds behavioural features), fits
`SecureKMeans` with the pooled offline phase, provisions a `TripleBank`
for the serving ladder, then drives a stream of ragged arrival batches
through `repro.serve.ScoringService` — scoring every new transaction
against the SECRET-SHARED centroids and revealing only scores + outlier
flags. Reports per-phase latency, rows/s, triples and bytes per request.

`--bank-path` persists the provisioned bank to disk (np.savez) and reloads
it before serving — the cross-restart serving story. `--fit-from-bank`
provisions the FIT plan into the bank too (plan_fit) and fits from the
provisioned tranches, so the online fit does zero generation work;
`--provision-workers N` splits all provisioning across N threads
(bit-exact with serial — per-class streams).

`--serve-port` switches to WIRE-SERVER mode (DESIGN.md §14): fit
deterministically, warm, then listen for `ScoringClient` requests —
printing "SERVING <port>" once ready. With `--serve-checkpoint-dir` the
service journals every response (exactly-once across a kill/restart:
rerun the same command and it resumes from the journal);
`--die-after-responses N` crashes with os._exit right after the Nth
response journals (the chaos harness' kill switch). `--max-queue`,
`--deadline-s` and `--low-water`/`--high-water` configure admission,
deadlines and the background `BankReplenisher`.

`--supervised` (DESIGN.md §16) wraps wire-server mode in the restart
supervisor: the parent pins the serve/metrics ports, respawns the server
on crashes (bounded restarts, backoff, crash-loop detection), strips
crash-simulation flags after incarnation 0, and treats the `/health`
endpoint (or the "SERVING" line) as readiness. Combined with
`--serve-checkpoint-dir`, a crash-looping server still answers every
admitted request id exactly once.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from repro.core.fraud import FraudDataset, detect_outliers, jaccard
from repro.core.kmeans import KMeansConfig, SecureKMeans
from repro.core.triples import TripleBank, serve_seed
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.serve import ScoringService


def _finish_trace(trace_out: str | None, verbose: bool = True) -> None:
    """Export the global tracer's Chrome trace + text flame summary."""
    if not trace_out:
        return
    t = _trace.get_tracer()
    t.export_chrome(trace_out)
    if verbose:
        print(f"trace: {len(t.events())} spans -> {trace_out} "
              "(load in chrome://tracing or ui.perfetto.dev)")
        print(t.flame_summary())


def serve(*, n_train: int = 2000, d_a: int = 18, d_b: int = 24, k: int = 5,
          iters: int = 5, sparse: bool = False, rungs=(32, 128),
          requests: int = 24, mean_batch: int = 32, frac: float = 0.02,
          provision_copies: int | None = None, bank_path: str | None = None,
          pipeline: bool = True, fit_batch_size: int | None = None,
          fit_from_bank: bool = False, provision_workers: int = 1,
          checkpoint_dir: str | None = None, resume: bool = False,
          checkpoint_every: int = 1, seed: int = 0,
          trace_out: str | None = None,
          trace_rotate: int | None = None, trace_sample: float = 1.0,
          metrics_port: int | None = None,
          stats_interval: float = 0.0, verbose: bool = True) -> dict:
    if trace_out:
        _trace.configure(enabled=True, process="serve_kmeans",
                         rotate_spans=trace_rotate,
                         sample_rate=trace_sample)
    ds = FraudDataset.synthesize(n=n_train, d_a=d_a, d_b=d_b,
                                 n_clusters=k, seed=seed)
    km = SecureKMeans(KMeansConfig(k=k, iters=iters, seed=seed,
                                   sparse=sparse, offline="pooled",
                                   batch_size=fit_batch_size,
                                   pipeline=pipeline))
    t_provision_fit = 0.0
    fit_dealer = None
    if fit_from_bank:
        # offline: bulk-generate the whole fit's correlated randomness into
        # a bank keyed by the fit plan; the fit itself then does zero
        # generation work (bit-exact with the on-the-fly dealers)
        fit_bank = TripleBank(seed=seed)
        fkey, fplan, _ = km.plan_fit(ds.x_a.shape, ds.x_b.shape)
        t0 = time.perf_counter()
        fit_bank.provision(fkey, fplan, workers=provision_workers)
        t_provision_fit = time.perf_counter() - t0
        fit_dealer = fit_bank.dealer(fkey)
    ckpt = None
    if checkpoint_dir:
        from repro.checkpoint.fit import FitCheckpointer
        ckpt = FitCheckpointer(checkpoint_dir, every=checkpoint_every)
    t0 = time.perf_counter()
    res = km.fit(ds.x_a, ds.x_b, dealer=fit_dealer, checkpoint=ckpt,
                 resume=resume)
    t_fit = time.perf_counter() - t0
    # callback gauges READ the live CommLog: the registry's answer for
    # online bytes is total_bytes("online") itself, not a second tally
    _metrics.register_commlog(res.log)
    mserver = None
    if metrics_port is not None:
        mserver = _metrics.MetricsServer(port=metrics_port)
        mserver.start()
        if verbose:
            print(f"METRICS {mserver.port}", flush=True)

    bank = TripleBank(seed=serve_seed(seed))
    svc = ScoringService(km, res, bank=bank, rungs=rungs,
                         with_scores=True, d_a=d_a, d_b=d_b,
                         pipeline=pipeline,
                         provision_copies=provision_copies or requests,
                         provision_workers=provision_workers)
    t0 = time.perf_counter()
    svc.warm()
    if bank_path:
        # persist the provisioned bank and serve from the reloaded copy —
        # stream positions survive, so replenishment stays deterministic
        bank.save(bank_path)
        svc.bank = TripleBank.load(bank_path)
    t_warm = time.perf_counter() - t0

    rng = np.random.default_rng(seed + 1)
    sizes = np.maximum(1, rng.poisson(mean_batch, requests))
    arrivals = FraudDataset.synthesize(n=int(sizes.sum()), d_a=d_a, d_b=d_b,
                                       n_clusters=k, seed=seed + 2)
    slog = None
    if stats_interval > 0:
        slog = _metrics.StatsLineLogger(svc, bank=svc.bank,
                                        interval_s=stats_interval)
        slog.start()
    off = 0
    for m in sizes:
        svc.submit(arrivals.x_a[off:off + m], arrivals.x_b[off:off + m])
        off += m
    t0 = time.perf_counter()
    responses = svc.drain()
    t_drain = time.perf_counter() - t0
    if slog is not None:
        slog.stop()
        if verbose:
            print(slog.render())
    if mserver is not None:
        mserver.stop()

    scores = np.concatenate([r.scores for r in responses])
    flags = detect_outliers(scores, frac)
    j = jaccard(flags, arrivals.y_outlier)

    out = {"fit_s": round(t_fit, 3), "warm_s": round(t_warm, 3),
           "drain_s": round(t_drain, 3), "jaccard_stream": round(j, 3),
           "bank_loaded_from_disk": bool(bank_path),
           "fit_from_bank": bool(fit_from_bank),
           "provision_fit_s": round(t_provision_fit, 3),
           "provision_workers": int(provision_workers)}
    out.update(svc.stats.as_dict())
    if verbose:
        if fit_from_bank:
            print(f"fit bank provisioned in {t_provision_fit:.2f}s "
                  f"({provision_workers} worker"
                  f"{'s' if provision_workers != 1 else ''}) — offline")
        print(f"fit {t_fit:.2f}s ({iters} iters, n={n_train})  "
              f"warm {t_warm:.2f}s (compile + provision "
              f"{'-> ' + bank_path if bank_path else ''})")
        print(f"served {out['requests']} requests / {out['rows']} rows "
              f"in {t_drain:.2f}s  ->  {out['rows_per_s']} rows/s")
        print(f"  {out['triples_per_request']} triples/request, "
              f"{out['bytes_per_request']} B/request, "
              f"pad x{out['pad_overhead']}, "
              f"{out['replenish_events']} replenish events")
        print(f"stream outlier Jaccard vs planted fraud: {j:.3f} "
              "(only scores/flags revealed — the model stays shared)")
    _finish_trace(trace_out, verbose)
    return out


def serve_wire(*, port: int = 0, auth_key: str | None = None,
               checkpoint_dir: str | None = None,
               die_after_responses: int | None = None,
               max_queue: int | None = None,
               deadline_s: float | None = None,
               low_water: int | None = None, high_water: int | None = None,
               idle_timeout_s: float = 120.0,
               n_train: int = 400, d_a: int = 6, d_b: int = 6, k: int = 3,
               iters: int = 2, rungs=(16, 64), provision_copies: int = 8,
               provision_workers: int = 1, seed: int = 0,
               trace_out: str | None = None,
               trace_rotate: int | None = None, trace_sample: float = 1.0,
               metrics_port: int | None = None,
               stats_interval: float = 0.0) -> None:
    """Wire-server mode: fit (deterministic — a restart refits the same
    model from the same seed), warm, listen, serve until BYE. The serving
    randomness is NOT refit-dependent: with a checkpoint dir the bank is
    snapshotted at first warm and every restart reloads + realigns it, so
    responses are bit-exact across kills."""
    from repro.core.channel import SocketTransport, WireTimeout, session_key
    from repro.serve import ScoringServer

    if trace_out:
        _trace.configure(enabled=True, process="server",
                         rotate_spans=trace_rotate,
                         sample_rate=trace_sample)
    ds = FraudDataset.synthesize(n=n_train, d_a=d_a, d_b=d_b,
                                 n_clusters=k, seed=seed)
    km = SecureKMeans(KMeansConfig(k=k, iters=iters, seed=seed,
                                   offline="pooled"))
    res = km.fit(ds.x_a, ds.x_b)
    _metrics.register_commlog(res.log)

    ckpt = None
    if checkpoint_dir:
        from repro.checkpoint.serve import ServeCheckpointer
        after = None
        if die_after_responses is not None:

            def after(total, _path):
                if total >= die_after_responses:
                    print(f"DYING after {total} journaled responses",
                          flush=True)
                    os._exit(17)   # simulated crash: no cleanup, no BYE
        ckpt = ServeCheckpointer(checkpoint_dir, after_record=after)
    repl = None
    if low_water is not None:
        repl = {"low_water": low_water, "workers": provision_workers}
        if high_water is not None:
            repl["high_water"] = high_water
    svc = ScoringService(km, res, rungs=rungs, with_scores=True,
                         d_a=d_a, d_b=d_b,
                         provision_copies=provision_copies,
                         provision_workers=provision_workers,
                         max_queue=max_queue, default_deadline_s=deadline_s,
                         checkpointer=ckpt, replenisher=repl)
    # start the exposition BEFORE warm() so a supervisor probing /health
    # sees STARTING during bank load + journal replay, READY only after
    mserver = None
    if metrics_port is not None:
        mserver = _metrics.MetricsServer(port=metrics_port,
                                         health_cb=lambda: svc.health)
        mserver.start()
        print(f"METRICS {mserver.port}", flush=True)
    svc.warm()
    slog = None
    if stats_interval > 0:
        slog = _metrics.StatsLineLogger(svc, bank=svc.bank,
                                        interval_s=stats_interval)
        slog.start()
    t = SocketTransport("listen", port=port, io_timeout_s=idle_timeout_s)
    print(f"SERVING {t.port}", flush=True)
    server = ScoringServer(
        svc, t, idle_timeout_s=idle_timeout_s,
        auth_key=session_key(auth_key) if auth_key else None)
    try:
        responder = server.serve_forever()
        print(f"served {responder.served} wire requests "
              f"({responder.dedup_replays} dedup replays); "
              f"stats: {svc.stats.as_dict()}", flush=True)
    except WireTimeout as e:
        print(f"server idle timeout: {e}", flush=True)
    finally:
        if slog is not None:
            slog.stop()
            print(slog.render(), flush=True)
        if mserver is not None:
            mserver.stop()
        t.close()
        _finish_trace(trace_out)


_RUNBOOK = """\
ops runbook (self-healing serving, DESIGN.md §16)
-------------------------------------------------
health states on http://HOST:METRICS_PORT/health —
  STARTING  warm() in progress: bank loading, journal replaying,
            programs compiling. /health answers 503; wait.
  READY     serving. /health answers 200; the supervisor marks the
            incarnation ready and the MTTR clock stops.
  DEGRADED  still serving, but the drain loop or the BankReplenisher
            has swallowed errors (or the daemon died). Check
            repro_serve_* + repro_replenisher_* gauges, then restart
            at a quiet moment — the journal makes restarts safe.
  DRAINING  stop() is flushing the queue. New work should go elsewhere.

restart decision table —
  exit 0    clean (client sent BYE / idle timeout): do not restart.
  exit 4    ResumeMismatch: config/data fingerprint drifted between the
            parties. Restarting CANNOT help — fix the config, or move
            --serve-checkpoint-dir / --checkpoint-dir aside to accept a
            fresh run.
  exit 17   injected/simulated crash (chaos harness): restart; with
            --serve-checkpoint-dir the journal replays and every
            admitted request id is answered exactly once.
  other     crash: restart with the SAME command line. --supervised
            does this for you (bounded restarts, exponential backoff,
            crash-loop detection after 3 fast deaths).

what survives a crash —
  bank.npz              provision-time snapshot, never rewritten.
  journal/batch_*.npz   published responses + cumulative consumed
                        counts; replayed verbatim on restart.
  Anything not journaled is re-scored BIT-EXACT after bank realignment.
"""


def run_supervised(argv: list[str]) -> int:
    """Wrap wire-server mode in the restart supervisor: pin the ports so
    every incarnation listens at the same address, strip crash-simulation
    flags after incarnation 0, respawn per the RestartPolicy, and exit
    with the child's terminal returncode."""
    from repro.launch.supervisor import (RestartPolicy, SupervisedChild,
                                         child_env, free_port, python_argv)

    def _flag(name, default=None):
        return argv[argv.index(name) + 1] if name in argv else default

    def _pin(name, value):
        if name in argv:
            argv[argv.index(name) + 1] = str(value)
        else:
            argv.extend([name, str(value)])

    if _flag("--serve-port") in (None, "0"):
        _pin("--serve-port", free_port())
    if _flag("--metrics-port") == "0":
        _pin("--metrics-port", free_port())
    metrics_port = _flag("--metrics-port")
    health_url = (f"http://127.0.0.1:{metrics_port}/health"
                  if metrics_port else None)

    def argv_for(incarnation: int) -> list[str]:
        child = list(argv)
        if incarnation > 0 and "--die-after-responses" in child:
            i = child.index("--die-after-responses")
            del child[i:i + 2]      # the crash switch fires once, not forever
        return python_argv("repro.launch.serve_kmeans", *child)

    child = SupervisedChild(
        "serve", argv_for, policy=RestartPolicy(),
        terminal_codes=(0, 4), env=child_env(),
        ready_pattern=r"^SERVING ", health_url=health_url,
        on_line=lambda line: print(line, flush=True))
    child.start()
    child.wait()
    print(f"SUPERVISOR terminal: {child.terminal_reason} "
          f"(rc={child.returncode}, restarts={child.restarts})", flush=True)
    return child.returncode if child.returncode is not None else 1


def main() -> None:
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=_RUNBOOK)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--d-a", type=int, default=18)
    ap.add_argument("--d-b", type=int, default=24)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--sparse", action="store_true")
    ap.add_argument("--rungs", "--ladder", dest="rungs", default="32,128",
                    help="comma-separated padded batch rungs (strictly "
                         "increasing positive ints)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--mean-batch", type=int, default=32)
    ap.add_argument("--frac", type=float, default=0.02)
    ap.add_argument("--provision-copies", type=int, default=None,
                    help="launches of correlated randomness provisioned "
                         "per rung (default: --requests; wire mode: 8)")
    ap.add_argument("--bank-path", default=None,
                    help="save + reload the provisioned TripleBank here")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="sequential escape hatch: disable the overlap of "
                         "request t+1's exchange/bank draw with request "
                         "t's launch (stream-identical outputs)")
    ap.add_argument("--fit-batch-size", type=int, default=None,
                    help="minibatch Lloyd batch rows for the fit "
                         "(default: full batch)")
    ap.add_argument("--fit-from-bank", action="store_true",
                    help="pre-provision the fit plan into a TripleBank "
                         "(offline) and fit from it — zero online "
                         "generation work")
    ap.add_argument("--provision-workers", type=int, default=1,
                    help="thread-pool width for bulk provisioning "
                         "(bit-exact with serial)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="save a resumable FitCheckpoint here at iteration "
                         "boundaries (atomic keep-N store)")
    ap.add_argument("--resume", action="store_true",
                    help="resume the fit from the latest checkpoint in "
                         "--checkpoint-dir (bit-exact with an "
                         "uninterrupted run)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="checkpoint every Nth iteration")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--serve-port", type=int, default=None,
                    help="wire-server mode: listen here (0 = ephemeral, "
                         "printed as 'SERVING <port>') and answer "
                         "ScoringClient requests until BYE")
    ap.add_argument("--auth-key", default=None,
                    help="wire mode: shared session passphrase — frames "
                         "carry a keyed BLAKE2b MAC instead of a CRC")
    ap.add_argument("--serve-checkpoint-dir", default=None,
                    help="wire mode: journal responses + bank consumed "
                         "counts here (exactly-once restart)")
    ap.add_argument("--die-after-responses", type=int, default=None,
                    help="wire mode: os._exit right after this many "
                         "responses journal (crash simulation)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission high-water mark: shed past this depth")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default per-request deadline")
    ap.add_argument("--low-water", type=int, default=None,
                    help="start a BankReplenisher daemon topping up rungs "
                         "at this stock level")
    ap.add_argument("--high-water", type=int, default=None,
                    help="replenisher top-up target (default 2x low)")
    ap.add_argument("--idle-timeout", type=float, default=120.0,
                    help="wire mode: give up after this much client "
                         "silence")
    ap.add_argument("--supervised", action="store_true",
                    help="wire mode: run the server under the restart "
                         "supervisor (pin ports, respawn on crash, strip "
                         "--die-after-responses after incarnation 0)")
    ap.add_argument("--trace-out", default=None,
                    help="enable span tracing and export a Chrome-trace / "
                         "Perfetto JSON timeline here on exit")
    ap.add_argument("--trace-rotate", type=int, default=None,
                    help="keep only the newest N spans per category "
                         "(bounded-memory tracing for long-lived servers)")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="record ~this fraction of spans (deterministic "
                         "counter sampling; 1.0 = everything)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve the Prometheus text exposition on this "
                         "port (0 = ephemeral, printed as "
                         "'METRICS <port>')")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="log a one-line stats summary (latency quantiles "
                         "+ bank_stock) every this many seconds")
    args = ap.parse_args()
    if args.supervised:
        import sys
        if args.serve_port is None:
            ap.error("--supervised requires wire mode (--serve-port)")
        argv = [a for a in sys.argv[1:] if a != "--supervised"]
        raise SystemExit(run_supervised(argv))
    if args.serve_port is not None:
        serve_wire(port=args.serve_port, auth_key=args.auth_key,
                   checkpoint_dir=args.serve_checkpoint_dir,
                   die_after_responses=args.die_after_responses,
                   max_queue=args.max_queue, deadline_s=args.deadline_s,
                   low_water=args.low_water, high_water=args.high_water,
                   idle_timeout_s=args.idle_timeout,
                   n_train=args.n_train, d_a=args.d_a, d_b=args.d_b,
                   k=args.k, iters=args.iters,
                   rungs=tuple(int(r) for r in args.rungs.split(",")),
                   provision_copies=args.provision_copies or 8,
                   provision_workers=args.provision_workers,
                   seed=args.seed, trace_out=args.trace_out,
                   trace_rotate=args.trace_rotate,
                   trace_sample=args.trace_sample,
                   metrics_port=args.metrics_port,
                   stats_interval=args.stats_interval)
        return
    serve(n_train=args.n_train, d_a=args.d_a, d_b=args.d_b, k=args.k,
          iters=args.iters, sparse=args.sparse,
          rungs=tuple(int(r) for r in args.rungs.split(",")),
          requests=args.requests, mean_batch=args.mean_batch,
          frac=args.frac, provision_copies=args.provision_copies,
          bank_path=args.bank_path,
          pipeline=not args.no_pipeline,
          fit_batch_size=args.fit_batch_size,
          fit_from_bank=args.fit_from_bank,
          provision_workers=args.provision_workers,
          checkpoint_dir=args.checkpoint_dir, resume=args.resume,
          checkpoint_every=args.checkpoint_every, seed=args.seed,
          trace_out=args.trace_out, trace_rotate=args.trace_rotate,
          trace_sample=args.trace_sample, metrics_port=args.metrics_port,
          stats_interval=args.stats_interval)


if __name__ == "__main__":
    main()
