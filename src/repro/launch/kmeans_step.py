"""The paper's online Lloyd iteration as a pjit-able pure function.

The offline phase (Beaver triples, B2A randomness) is materialized as
*function inputs*: a RecordingDealer first traces the protocol to enumerate
every correlated-randomness tensor the iteration consumes (their shapes are
data-independent — that's WHY the offline phase exists), then the real
lowering consumes them from the argument list via a ListDealer.

Sharding: sample-major tensors (n, ...) are sharded over ('pod','data') —
each MPC *party* owns a slice of the pod in production, and its sample rows
are data-parallel within it. Centroid-sized tensors replicate. C^T X lowers
to a psum over the sample axis: the paper's vectorized F_SCU is literally a
data-parallel reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol as P
from repro.core import ring
from repro.core.channel import CommLog
from repro.core.sharing import AShare, BShare
from repro.core.triples import BinTriple, MatmulTriple, MulTriple


class RecordingDealer:
    """Enumerates the offline-phase tensors (kind, shape) in consumption
    order; hands back zeros so tracing proceeds."""

    def __init__(self):
        self.requests: list[tuple[str, tuple]] = []

    def _z(self, shape):
        return jnp.zeros(shape, ring.DTYPE)

    def matmul_triple(self, sa, sb, *, tag="x"):
        self.requests.append(("matmul", (tuple(sa), tuple(sb))))
        n, d = sa
        _, k = sb
        return MatmulTriple(AShare(self._z((n, d)), self._z((n, d))),
                            AShare(self._z((d, k)), self._z((d, k))),
                            AShare(self._z((n, k)), self._z((n, k))))

    def mul_triple(self, shape, *, tag="x"):
        self.requests.append(("mul", tuple(shape)))
        z = self._z(shape)
        return MulTriple(AShare(z, z), AShare(z, z), AShare(z, z))

    def bin_triple(self, shape, *, tag="x"):
        self.requests.append(("bin", tuple(shape)))
        z = self._z(shape)
        return BinTriple(BShare(z, z), BShare(z, z), BShare(z, z))

    def rand(self, shape):
        self.requests.append(("rand", tuple(shape)))
        return self._z(shape)


class ListDealer:
    """Consumes pre-materialized offline tensors (jnp arrays) in order."""

    def __init__(self, flat: list):
        self.flat = list(flat)
        self.i = 0

    def _pop(self):
        v = self.flat[self.i]
        self.i += 1
        return v

    def matmul_triple(self, sa, sb, *, tag="x"):
        return MatmulTriple(AShare(self._pop(), self._pop()),
                            AShare(self._pop(), self._pop()),
                            AShare(self._pop(), self._pop()))

    def mul_triple(self, shape, *, tag="x"):
        return MulTriple(AShare(self._pop(), self._pop()),
                         AShare(self._pop(), self._pop()),
                         AShare(self._pop(), self._pop()))

    def bin_triple(self, shape, *, tag="x"):
        return BinTriple(BShare(self._pop(), self._pop()),
                         BShare(self._pop(), self._pop()),
                         BShare(self._pop(), self._pop()))

    def rand(self, shape):
        return self._pop()


def _iteration(xa_enc, xb_enc, mu: AShare, dealer, n: int, k: int,
               d_a: int, he_results: tuple | None = None,
               backend=None, return_assignment: bool = False):
    """One vertical-partition online Lloyd iteration on shares (Alg. 3).

    he_results=None  -> dense-SS path: joint products via Beaver matmuls.
    he_results=(...) -> sparsity-aware path (paper Sec 4.3): the four joint
    products are computed host-side by Protocol 2 (HE over the plaintext
    sparse X) and enter the mesh program as fresh share INPUTS — the
    nnz-independent n*d Beaver traffic and its triple matmuls vanish from
    the TPU roofline, which is exactly the paper's claim mapped onto the
    accelerator.

    `backend` selects the ring-compute implementation (core/backend.py);
    every local ring product below, including the ones inside P.smatmul and
    P.cmp_lt, dispatches through it, so the pjit'd production path runs the
    same kernels as the simulated SecureKMeans path."""
    ctx = P.Ctx(dealer=dealer, log=CommLog(), backend=backend)
    mm = ctx.backend.ring_mm
    f = ring.F
    # ---- S1: distances ---------------------------------------------------
    mu_sq = P.smul(ctx, mu, mu)
    u = AShare(mu_sq.s0.sum(1), mu_sq.s1.sum(1))
    mut = AShare(mu.s0.T, mu.s1.T)
    loc_a = mm(xa_enc, mut.s0[:d_a])
    loc_b = mm(xb_enc, mut.s1[d_a:])
    if he_results is None:
        j1 = P.smatmul(ctx, AShare(xa_enc, jnp.zeros_like(xa_enc)),
                       AShare(jnp.zeros_like(mut.s1[:d_a]), mut.s1[:d_a]))
        j2 = P.smatmul(ctx, AShare(jnp.zeros_like(xb_enc), xb_enc),
                       AShare(mut.s0[d_a:], jnp.zeros_like(mut.s0[d_a:])))
    else:
        j1, j2 = he_results[0], he_results[1]
    xmu = AShare(loc_a + j1.s0 + j2.s0, loc_b + j1.s1 + j2.s1)
    d2 = P.sub(AShare(u.s0[None, :], u.s1[None, :]), P.lshift(xmu, 1))
    dist = P.trunc(d2, f)
    # ---- S2: assignment --------------------------------------------------
    c = P.argmin_onehot(ctx, dist)
    # ---- S3: update ------------------------------------------------------
    ct = AShare(c.s0.T, c.s1.T)
    za = AShare(mm(ct.s0, xa_enc), jnp.zeros((k, d_a), ring.DTYPE))
    zb = AShare(jnp.zeros((k, xb_enc.shape[1]), ring.DTYPE),
                mm(ct.s1, xb_enc))
    if he_results is None:
        ja = P.smatmul(ctx, AShare(jnp.zeros_like(ct.s1), ct.s1),
                       AShare(xa_enc, jnp.zeros_like(xa_enc)))
        jb = P.smatmul(ctx, AShare(ct.s0, jnp.zeros_like(ct.s0)),
                       AShare(jnp.zeros_like(xb_enc), xb_enc))
    else:
        ja, jb = he_results[2], he_results[3]
    num = AShare(jnp.concatenate([za.s0 + ja.s0, zb.s0 + jb.s0], 1),
                 jnp.concatenate([za.s1 + ja.s1, zb.s1 + jb.s1], 1))
    den = AShare(c.s0.sum(0), c.s1.sum(0))
    one = AShare(jnp.full((k,), 1, ring.DTYPE), jnp.zeros((k,), ring.DTYPE))
    is_empty = P.cmp_lt(ctx, den, one)
    den_safe = P.mux(ctx, is_empty, one, den)
    # balanced-split division (see core/kmeans.py for the derivation)
    m = int(np.ceil(np.log2(max(2, n))))
    s = m // 2
    num_s = P.trunc(num, s)
    r = P.reciprocal(ctx, den_safe, max_den=n, f=f, extra_bits=s)
    mu_new = P.smul(ctx, num_s, AShare(r.s0[:, None], r.s1[:, None]),
                    trunc_f=f)
    guard = AShare(is_empty.s0[:, None], is_empty.s1[:, None])
    out = P.mux(ctx, guard, mu, mu_new)
    return (out, c) if return_assignment else out


def materialize_offline(requests, dealer) -> list:
    """Flat jnp tensor list the ListDealer consumes, in recorded order.
    `dealer` is any triple provider (TrustedDealer on demand, PooledDealer
    for the planned offline phase)."""
    flat = []
    for kind, shape in requests:
        if kind == "matmul":
            t = dealer.matmul_triple(*shape)
        elif kind == "mul":
            t = dealer.mul_triple(shape)
        elif kind == "bin":
            t = dealer.bin_triple(shape)
            flat += [t.u.b0, t.u.b1, t.v.b0, t.v.b1, t.z.b0, t.z.b1]
            continue
        else:  # rand
            flat.append(dealer.rand(shape))
            continue
        flat += [t.u.s0, t.u.s1, t.v.s0, t.v.s1, t.z.s0, t.z.s1]
    return flat


def pooled_offline_arrays(requests, seed: int, iters: int = 1,
                          tag: str = "launch"):
    """True offline phase for the pjit path: bulk-generate `iters`
    iterations' worth of the recorded schedule with ONE stacked draw and one
    batched ring op per shape-class, and return ([flat_per_iteration...],
    dealer). Each flat list feeds one jit'd `_iteration` via its ListDealer;
    the arrays are preallocated device slices, so consuming them adds no
    host work to the online step. Bit-exact with `materialize_offline`
    against a same-seeded TrustedDealer (tests/test_triples_pool.py)."""
    from repro.core.triples import PlanRequest, PooledDealer, TriplePlan
    plan = TriplePlan([PlanRequest(kind, tuple(shape) if kind != "matmul"
                                   else shape, tag)
                       for kind, shape in requests]).repeat(iters)
    dealer = PooledDealer(plan, seed=seed)
    return [materialize_offline(requests, dealer) for _ in range(iters)], dealer


def record_offline_shapes(n: int, d: int, k: int, d_a: int,
                          sparse: bool = False):
    """Trace the iteration once to enumerate the offline tensor list.
    sparse=True enumerates the Protocol-2 variant (the four joint-product
    Beaver matmul triples are replaced by HE-result share inputs)."""
    dealer = RecordingDealer()

    def run():
        z = jnp.zeros((n, d_a), ring.DTYPE)
        zb = jnp.zeros((n, d - d_a), ring.DTYPE)
        mu = AShare(jnp.zeros((k, d), ring.DTYPE),
                    jnp.zeros((k, d), ring.DTYPE))
        he = None
        if sparse:
            he = tuple(AShare(jnp.zeros(s, ring.DTYPE),
                              jnp.zeros(s, ring.DTYPE))
                       for s in [(n, k), (n, k), (k, d_a), (k, d - d_a)])
        return _iteration(z, zb, mu, dealer, n, k, d_a, he_results=he)

    jax.eval_shape(run)
    return dealer.requests


def offline_tensor_specs(requests, n: int):
    """Flat list of ShapeDtypeStructs mirroring ListDealer consumption."""
    flat = []
    for kind, shape in requests:
        if kind == "matmul":
            (nn, d), (d2, k) = shape
            flat += [jax.ShapeDtypeStruct(s, ring.NP_DTYPE)
                     for s in [(nn, d), (nn, d), (d, k), (d, k),
                               (nn, k), (nn, k)]]
        elif kind in ("mul", "bin"):
            flat += [jax.ShapeDtypeStruct(shape, ring.NP_DTYPE)] * 6
        else:  # rand
            flat.append(jax.ShapeDtypeStruct(shape, ring.NP_DTYPE))
    return flat


def online_iteration_fn(n: int, d: int, k: int, d_a: int,
                        sparse: bool = False, backend: str = "auto"):
    """(fn, arg ShapeDtypeStructs) with fn(xa, xb, mu0, mu1, *he, *flat).
    sparse=True adds the 8 Protocol-2 result shares as inputs and drops the
    joint Beaver matmuls (paper Sec 4.3 on-mesh). `backend` picks the
    ring-compute implementation (core/backend.py) baked into the lowering."""
    from repro.core.backend import get_backend
    ring_backend = get_backend(backend)
    n_he = 0
    he_shapes = []
    if sparse:
        he_shapes = [(n, k), (n, k), (k, d_a), (k, d - d_a)]
        n_he = 8  # 4 AShares = 8 tensors

    def _he_args(flat):
        if not sparse:
            return None, flat
        he = [AShare(flat[2 * i], flat[2 * i + 1]) for i in range(4)]
        return tuple(he), flat[n_he:]

    class _Rec(RecordingDealer):
        pass

    dealer = _Rec()

    def run():
        z = jnp.zeros((n, d_a), ring.DTYPE)
        zb = jnp.zeros((n, d - d_a), ring.DTYPE)
        mu = AShare(jnp.zeros((k, d), ring.DTYPE),
                    jnp.zeros((k, d), ring.DTYPE))
        he = tuple(AShare(jnp.zeros(s, ring.DTYPE), jnp.zeros(s, ring.DTYPE))
                   for s in he_shapes) if sparse else None
        return _iteration(z, zb, mu, dealer, n, k, d_a, he_results=he,
                          backend=ring_backend)

    jax.eval_shape(run)
    flat_specs = offline_tensor_specs(dealer.requests, n)

    def fn(xa_enc, xb_enc, mu_s0, mu_s1, *flat):
        he, rest = _he_args(list(flat))
        out = _iteration(xa_enc, xb_enc, AShare(mu_s0, mu_s1),
                         ListDealer(rest), n, k, d_a, he_results=he,
                         backend=ring_backend)
        return out.s0, out.s1

    he_specs = []
    for s in he_shapes:
        he_specs += [jax.ShapeDtypeStruct(s, ring.NP_DTYPE)] * 2
    args = (jax.ShapeDtypeStruct((n, d_a), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((n, d - d_a), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((k, d), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((k, d), ring.NP_DTYPE)) \
        + tuple(he_specs) + tuple(flat_specs)
    return fn, args


def fit_iteration_fn(n: int, d: int, k: int, d_a: int,
                     backend: str = "auto"):
    """`online_iteration_fn` variant backing SecureKMeans' pooled fast path
    (dense vertical): returns (fn, arg ShapeDtypeStructs, requests) where
    fn(xa, xb, mu0, mu1, *flat) -> (mu0', mu1', c0, c1) also exposes the
    assignment shares, and `requests` is the offline schedule one call
    consumes — feed it to `materialize_offline` against the PooledDealer."""
    from repro.core.backend import get_backend
    ring_backend = get_backend(backend)
    dealer = RecordingDealer()

    def run():
        z = jnp.zeros((n, d_a), ring.DTYPE)
        zb = jnp.zeros((n, d - d_a), ring.DTYPE)
        mu = AShare(jnp.zeros((k, d), ring.DTYPE),
                    jnp.zeros((k, d), ring.DTYPE))
        return _iteration(z, zb, mu, dealer, n, k, d_a,
                          backend=ring_backend, return_assignment=True)

    jax.eval_shape(run)
    requests = list(dealer.requests)
    flat_specs = offline_tensor_specs(requests, n)

    def fn(xa_enc, xb_enc, mu_s0, mu_s1, *flat):
        mu, c = _iteration(xa_enc, xb_enc, AShare(mu_s0, mu_s1),
                           ListDealer(list(flat)), n, k, d_a,
                           backend=ring_backend, return_assignment=True)
        return mu.s0, mu.s1, c.s0, c.s1

    args = (jax.ShapeDtypeStruct((n, d_a), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((n, d - d_a), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((k, d), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((k, d), ring.NP_DTYPE)) + tuple(flat_specs)
    return fn, args, requests


def arg_shardings(mesh, args, n: int):
    """Shard the sample axis over ('pod','data') WHEREVER it appears —
    including dim-1 of the transposed (k, n) Beaver triples. (§Perf
    iteration 1: leaving those replicated made GSPMD reconstruct E
    replicated and ALL-GATHER the 4 GB F operands of C^T X instead of
    partial-summing — 8.6 GB/device/step of pure waste.)"""
    from jax.sharding import NamedSharding, PartitionSpec as Pspec
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    out = []
    for a in args:
        spec = [None] * len(a.shape)
        for dim, sz in enumerate(a.shape):
            if sz == n:
                spec[dim] = axes
                break
        out.append(NamedSharding(mesh, Pspec(*spec)))
    return tuple(out)
