"""The paper's online Lloyd iteration as pjit-able pure programs.

The offline phase (Beaver triples, B2A randomness) is materialized as
*function inputs*: a RecordingDealer first traces the protocol to enumerate
every correlated-randomness tensor the iteration consumes (their shapes are
data-independent — that's WHY the offline phase exists), then the real
lowering consumes them from the argument list via a ListDealer.

Program split (DESIGN.md §9): one online iteration is TWO compiled programs
with an optional host-side exchange between them —

  S1  distances + tournament argmin, ending at the assignment shares. The
      joint public-x-share products are Beaver matmuls inside the program
      (dense) or Protocol-2 HE results entering as share INPUTS (sparse);
      the distance-phase HE results depend only on the centroid shares, so
      the host computes them before launching S1.
  S2  (sparse only, not a program) the mid-iteration Protocol-2 exchange:
      the update-phase joint products need the assignment shares S1 just
      produced, so the host runs `core/sparse.secure_sparse_matmul` on them
      between the launches — a first-class callback, not a re-trace.
  S3  centroid update: C^T X assembly, empty-cluster guard, Newton-Raphson
      division, MUX — consuming the S2 results as inputs (sparse) or Beaver
      matmuls (dense).

Every partition x sparsity combo lowers through the same two bodies,
parameterized by a `FitGeometry`; `fit_programs` AOT-compiles and caches the
pair per (geometry, backend).

Sharding: sample-major tensors (n, ...) are sharded over ('pod','data') —
each MPC *party* owns a slice of the pod in production, and its sample rows
are data-parallel within it. Centroid-sized tensors replicate. C^T X lowers
to a psum over the sample axis: the paper's vectorized F_SCU is literally a
data-parallel reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol as P
from repro.core import ring
from repro.core.channel import CommLog
from repro.core.sharing import AShare, BShare
from repro.core.triples import BinTriple, MatmulTriple, MulTriple


class RecordingDealer:
    """Enumerates the offline-phase tensors (kind, shape) in consumption
    order; hands back zeros so tracing proceeds."""

    def __init__(self):
        self.requests: list[tuple[str, tuple]] = []

    def _z(self, shape):
        return jnp.zeros(shape, ring.DTYPE)

    def matmul_triple(self, sa, sb, *, tag="x"):
        self.requests.append(("matmul", (tuple(sa), tuple(sb))))
        n, d = sa
        _, k = sb
        return MatmulTriple(AShare(self._z((n, d)), self._z((n, d))),
                            AShare(self._z((d, k)), self._z((d, k))),
                            AShare(self._z((n, k)), self._z((n, k))))

    def mul_triple(self, shape, *, tag="x"):
        self.requests.append(("mul", tuple(shape)))
        z = self._z(shape)
        return MulTriple(AShare(z, z), AShare(z, z), AShare(z, z))

    def bin_triple(self, shape, *, tag="x"):
        self.requests.append(("bin", tuple(shape)))
        z = self._z(shape)
        return BinTriple(BShare(z, z), BShare(z, z), BShare(z, z))

    def rand(self, shape):
        self.requests.append(("rand", tuple(shape)))
        return self._z(shape)


class ListDealer:
    """Consumes pre-materialized offline tensors (jnp arrays) in order."""

    def __init__(self, flat: list):
        self.flat = list(flat)
        self.i = 0

    def _pop(self):
        v = self.flat[self.i]
        self.i += 1
        return v

    def matmul_triple(self, sa, sb, *, tag="x"):
        return MatmulTriple(AShare(self._pop(), self._pop()),
                            AShare(self._pop(), self._pop()),
                            AShare(self._pop(), self._pop()))

    def mul_triple(self, shape, *, tag="x"):
        return MulTriple(AShare(self._pop(), self._pop()),
                         AShare(self._pop(), self._pop()),
                         AShare(self._pop(), self._pop()))

    def bin_triple(self, shape, *, tag="x"):
        return BinTriple(BShare(self._pop(), self._pop()),
                         BShare(self._pop(), self._pop()),
                         BShare(self._pop(), self._pop()))

    def rand(self, shape):
        return self._pop()


# ---------------------------------------------------------------------------
# FitGeometry — static shape info of one partition x sparsity combo
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FitGeometry:
    """Shapes of one secure-fit combo. Vertical: X = [X_A | X_B]; horizontal:
    X = [X_A ; X_B]. Hashable — it keys the compiled-program cache."""

    partition: str     # "vertical" | "horizontal"
    sparse: bool
    shape_a: tuple     # party A's encoded-data shape
    shape_b: tuple
    k: int

    def __post_init__(self):
        if self.partition not in ("vertical", "horizontal"):
            raise ValueError(f"unknown partition {self.partition!r}")
        if self.partition == "vertical" and self.shape_a[0] != self.shape_b[0]:
            raise ValueError("vertical partition requires equal sample counts")
        if self.partition == "horizontal" and self.shape_a[1] != self.shape_b[1]:
            raise ValueError("horizontal partition requires equal feature counts")

    @property
    def n(self) -> int:
        return self.shape_a[0] if self.partition == "vertical" \
            else self.shape_a[0] + self.shape_b[0]

    @property
    def d(self) -> int:
        return self.shape_a[1] + self.shape_b[1] \
            if self.partition == "vertical" else self.shape_a[1]

    @property
    def d_a(self) -> int:
        return self.shape_a[1]

    def he_shapes_s1(self) -> list:
        """Protocol-2 result shapes entering S1 (the X mu^T joint blocks)."""
        if not self.sparse:
            return []
        if self.partition == "vertical":
            return [(self.n, self.k), (self.n, self.k)]
        return [(self.shape_a[0], self.k), (self.shape_b[0], self.k)]

    def he_shapes_s3(self) -> list:
        """Protocol-2 result shapes entering S3 (the C^T X joint blocks)."""
        if not self.sparse:
            return []
        if self.partition == "vertical":
            return [(self.k, self.shape_a[1]), (self.k, self.shape_b[1])]
        return [(self.k, self.d), (self.k, self.d)]


def _zero_he(shapes):
    if not shapes:
        return None
    return tuple(AShare(jnp.zeros(s, ring.DTYPE), jnp.zeros(s, ring.DTYPE))
                 for s in shapes)


def _split_he(flat, shapes):
    """(he tuple | None, remaining flat) from a program's trailing args."""
    flat = list(flat)
    if not shapes:
        return None, flat
    n_he = 2 * len(shapes)
    he = tuple(AShare(flat[2 * i], flat[2 * i + 1])
               for i in range(len(shapes)))
    return he, flat[n_he:]


# ---------------------------------------------------------------------------
# Program bodies — ONE implementation per online stage, all combos
# ---------------------------------------------------------------------------

def _s1_body(ctx, geo: FitGeometry, xa, xb, mu: AShare, he,
             return_min: bool = False):
    """S1: vectorized distances D' = U - 2 X mu^T + tournament argmin,
    up to the Protocol-2 boundary. Returns the (n, k) assignment shares
    (plus, with return_min, the (n,) share of the winning D' value — the
    scoring path's distance-to-assigned-centroid, free from the tournament).

    he=None  -> dense: the joint public-x-share blocks are Beaver matmuls
    consuming pool triples inside the program.
    he=(j1, j2) -> sparse: the blocks were computed host-side by Protocol 2
    from the centroid shares (they depend on nothing else) and enter as
    fresh share inputs — the nnz-independent n*d Beaver traffic and its
    triple matmuls vanish from the accelerator roofline."""
    mm = ctx.backend.ring_mm
    mu_sq = P.smul(ctx, mu, mu)
    u = AShare(mu_sq.s0.sum(1), mu_sq.s1.sum(1))
    mut = AShare(mu.s0.T, mu.s1.T)
    if geo.partition == "vertical":
        da = geo.d_a
        loc_a = mm(xa, mut.s0[:da])
        loc_b = mm(xb, mut.s1[da:])
        if he is None:
            j1 = P.smatmul(ctx, AShare(xa, jnp.zeros_like(xa)),
                           AShare(jnp.zeros_like(mut.s1[:da]), mut.s1[:da]))
            j2 = P.smatmul(ctx, AShare(jnp.zeros_like(xb), xb),
                           AShare(mut.s0[da:], jnp.zeros_like(mut.s0[da:])))
        else:
            j1, j2 = he
        xmu = AShare(loc_a + j1.s0 + j2.s0, loc_b + j1.s1 + j2.s1)
    else:
        # horizontal: rows split; each party's rows hit BOTH mu shares
        loc_a = mm(xa, mut.s0)
        loc_b = mm(xb, mut.s1)
        if he is None:
            j_a = P.smatmul(ctx, AShare(xa, jnp.zeros_like(xa)),
                            AShare(jnp.zeros_like(mut.s1), mut.s1))
            j_b = P.smatmul(ctx, AShare(jnp.zeros_like(xb), xb),
                            AShare(mut.s0, jnp.zeros_like(mut.s0)))
        else:
            j_a, j_b = he
        xmu = AShare(jnp.concatenate([loc_a + j_a.s0, j_b.s0], 0),
                     jnp.concatenate([j_a.s1, loc_b + j_b.s1], 0))
    d2 = P.sub(AShare(u.s0[None, :], u.s1[None, :]), P.lshift(xmu, 1))
    dist = P.trunc(d2, ring.F)
    if return_min:
        return P.argmin_onehot(ctx, dist, return_min=True)
    return P.argmin_onehot(ctx, dist)


def _s3_partial_body(ctx, geo: FitGeometry, xa, xb, c: AShare, he):
    """S3 head: the (k, d) numerator C^T X and (k,) denominator 1^T C sums
    of one batch — pure local/Beaver products, no division. These are the
    secret-shared running-sum accumulators of the minibatch mode: partial
    sums from several batch launches ADD (share addition is free), and one
    `_s3_final_body` launch per iteration closes the update. The full-batch
    `_s3_body` is partial + final composed, so the minibatch path at
    batch_size >= n is the same trace.

    he=None -> dense Beaver joint blocks; he=(ja, jb) -> the Protocol-2
    results of the MID-ITERATION host exchange on the assignment shares S1
    produced (the S2 callback)."""
    mm = ctx.backend.ring_mm
    k = geo.k
    ct = AShare(c.s0.T, c.s1.T)
    if geo.partition == "vertical":
        da, db = geo.shape_a[1], geo.shape_b[1]
        za = AShare(mm(ct.s0, xa), jnp.zeros((k, da), ring.DTYPE))
        zb = AShare(jnp.zeros((k, db), ring.DTYPE), mm(ct.s1, xb))
        if he is None:
            ja = P.smatmul(ctx, AShare(jnp.zeros_like(ct.s1), ct.s1),
                           AShare(xa, jnp.zeros_like(xa)))
            jb = P.smatmul(ctx, AShare(ct.s0, jnp.zeros_like(ct.s0)),
                           AShare(jnp.zeros_like(xb), xb))
        else:
            ja, jb = he
        num = AShare(jnp.concatenate([za.s0 + ja.s0, zb.s0 + jb.s0], 1),
                     jnp.concatenate([za.s1 + ja.s1, zb.s1 + jb.s1], 1))
    else:
        na = geo.shape_a[0]
        ct_a = AShare(ct.s0[:, :na], ct.s1[:, :na])
        ct_b = AShare(ct.s0[:, na:], ct.s1[:, na:])
        loc_a = mm(ct_a.s0, xa)
        if he is None:
            ja = P.smatmul(ctx, AShare(jnp.zeros_like(ct_a.s1), ct_a.s1),
                           AShare(xa, jnp.zeros_like(xa)))
        else:
            ja = he[0]
        za = AShare(loc_a + ja.s0, ja.s1)
        loc_b = mm(ct_b.s1, xb)
        if he is None:
            jb = P.smatmul(ctx, AShare(ct_b.s0, jnp.zeros_like(ct_b.s0)),
                           AShare(jnp.zeros_like(xb), xb))
        else:
            jb = he[1]
        zb = AShare(jb.s0, loc_b + jb.s1)
        num = P.add(za, zb)
    den = AShare(c.s0.sum(0), c.s1.sum(0))
    return num, den


def _s3_final_body(ctx, k: int, n: int, mu: AShare, num: AShare,
                   den: AShare):
    """S3 tail: mu' = num / den with the empty-cluster MUX guard and
    balanced-split division (see core/kmeans.py for the numerics) on the
    (possibly cross-batch accumulated) sums. `n` is the TOTAL sample count
    — it sizes the division constants, which is what keeps the minibatch
    update bit-exact with the full-batch S3 at batch_size >= n."""
    one = AShare(jnp.full((k,), 1, ring.DTYPE), jnp.zeros((k,), ring.DTYPE))
    is_empty = P.cmp_lt(ctx, den, one)
    den_safe = P.mux(ctx, is_empty, one, den)
    # balanced-split division (see core/kmeans.py for the derivation)
    m = int(np.ceil(np.log2(max(2, n))))
    s = m // 2
    num_s = P.trunc(num, s)
    r = P.reciprocal(ctx, den_safe, max_den=n, f=ring.F, extra_bits=s)
    mu_new = P.smul(ctx, num_s, AShare(r.s0[:, None], r.s1[:, None]),
                    trunc_f=ring.F)
    guard = AShare(is_empty.s0[:, None], is_empty.s1[:, None])
    return P.mux(ctx, guard, mu, mu_new)


def _s3_body(ctx, geo: FitGeometry, xa, xb, mu: AShare, c: AShare, he):
    """S3: centroid update mu' = C^T X / 1^T C — the partial-sum head and
    the finalize tail composed back to back (the full-batch form)."""
    num, den = _s3_partial_body(ctx, geo, xa, xb, c, he)
    return _s3_final_body(ctx, geo.k, geo.n, mu, num, den)


def _iteration(xa_enc, xb_enc, mu: AShare, dealer, n: int, k: int,
               d_a: int, he_results: tuple | None = None,
               backend=None, return_assignment: bool = False):
    """One vertical-partition online Lloyd iteration on shares (Alg. 3) —
    S1 and S3 bodies composed back to back over ONE dealer. Kept as the
    single-launch legacy form behind `online_iteration_fn`; the production
    fast path uses the split `fit_programs` pair."""
    d_b = xb_enc.shape[1]
    geo = FitGeometry("vertical", he_results is not None,
                      (n, d_a), (n, d_b), k)
    ctx = P.Ctx(dealer=dealer, log=CommLog(), backend=backend)
    he1 = he3 = None
    if he_results is not None:
        he1, he3 = tuple(he_results[:2]), tuple(he_results[2:])
    c = _s1_body(ctx, geo, xa_enc, xb_enc, mu, he1)
    out = _s3_body(ctx, geo, xa_enc, xb_enc, mu, c, he3)
    return (out, c) if return_assignment else out


def materialize_offline(requests, dealer) -> list:
    """Flat jnp tensor list the ListDealer consumes, in recorded order.
    `dealer` is any triple provider (TrustedDealer on demand, PooledDealer
    or StreamingPooledDealer for the planned offline phase)."""
    flat = []
    for kind, shape in requests:
        if kind == "matmul":
            t = dealer.matmul_triple(*shape)
        elif kind == "mul":
            t = dealer.mul_triple(shape)
        elif kind == "bin":
            t = dealer.bin_triple(shape)
            flat += [t.u.b0, t.u.b1, t.v.b0, t.v.b1, t.z.b0, t.z.b1]
            continue
        else:  # rand
            flat.append(dealer.rand(shape))
            continue
        flat += [t.u.s0, t.u.s1, t.v.s0, t.v.s1, t.z.s0, t.z.s1]
    return flat


def pooled_offline_arrays(requests, seed: int, iters: int = 1,
                          tag: str = "launch"):
    """True offline phase for the pjit path: bulk-generate `iters`
    iterations' worth of the recorded schedule with ONE stacked draw and one
    batched ring op per shape-class, and return ([flat_per_iteration...],
    dealer). Each flat list feeds one jit'd iteration via its ListDealer;
    the arrays are preallocated device slices, so consuming them adds no
    host work to the online step. Bit-exact with `materialize_offline`
    against a same-seeded TrustedDealer (tests/test_triples_pool.py)."""
    from repro.core.triples import PlanRequest, PooledDealer, TriplePlan
    plan = TriplePlan([PlanRequest(kind, tuple(shape) if kind != "matmul"
                                   else shape, tag)
                       for kind, shape in requests]).repeat(iters)
    dealer = PooledDealer(plan, seed=seed)
    return [materialize_offline(requests, dealer) for _ in range(iters)], dealer


def record_offline_shapes(n: int, d: int, k: int, d_a: int,
                          sparse: bool = False):
    """Trace the iteration once to enumerate the offline tensor list.
    sparse=True enumerates the Protocol-2 variant (the four joint-product
    Beaver matmul triples are replaced by HE-result share inputs)."""
    dealer = RecordingDealer()

    def run():
        z = jnp.zeros((n, d_a), ring.DTYPE)
        zb = jnp.zeros((n, d - d_a), ring.DTYPE)
        mu = AShare(jnp.zeros((k, d), ring.DTYPE),
                    jnp.zeros((k, d), ring.DTYPE))
        he = None
        if sparse:
            he = tuple(AShare(jnp.zeros(s, ring.DTYPE),
                              jnp.zeros(s, ring.DTYPE))
                       for s in [(n, k), (n, k), (k, d_a), (k, d - d_a)])
        return _iteration(z, zb, mu, dealer, n, k, d_a, he_results=he)

    jax.eval_shape(run)
    return dealer.requests


def offline_tensor_specs(requests, n: int):
    """Flat list of ShapeDtypeStructs mirroring ListDealer consumption."""
    flat = []
    for kind, shape in requests:
        if kind == "matmul":
            (nn, d), (d2, k) = shape
            flat += [jax.ShapeDtypeStruct(s, ring.NP_DTYPE)
                     for s in [(nn, d), (nn, d), (d, k), (d, k),
                               (nn, k), (nn, k)]]
        elif kind in ("mul", "bin"):
            flat += [jax.ShapeDtypeStruct(shape, ring.NP_DTYPE)] * 6
        else:  # rand
            flat.append(jax.ShapeDtypeStruct(shape, ring.NP_DTYPE))
    return flat


# ---------------------------------------------------------------------------
# fit_programs — the per-iteration S1/S3 compiled pair, ALL fit shapes
# ---------------------------------------------------------------------------

class FitPrograms(NamedTuple):
    """AOT-compiled S1/S3 pair plus the offline schedule each launch
    consumes. Per online iteration:

        he1 = host Protocol-2 on the centroid shares        (sparse only)
        c   = s1(xa, xb, mu0, mu1, *he1, *flat_s1)          launch 1
        he3 = host Protocol-2 on the assignment shares      (sparse only,
                                                             the S2 callback)
        mu' = s3(xa, xb, mu0, mu1, c0, c1, *he3, *flat_s3)  launch 2

    where flat_s1/flat_s3 = materialize_offline(s{1,3}_requests, pool)."""

    geo: FitGeometry
    s1: Any
    s3: Any
    s1_requests: list
    s3_requests: list


_PROGRAM_CACHE: dict[tuple, FitPrograms] = {}


def _sds(shape):
    return jax.ShapeDtypeStruct(tuple(shape), ring.NP_DTYPE)


def _he_specs(shapes):
    out = []
    for s in shapes:
        out += [_sds(s), _sds(s)]
    return out


def fit_programs(partition: str, sparse: bool, shape_a, shape_b, k: int,
                 backend: str = "auto") -> FitPrograms:
    """Build (or fetch from the cross-fit cache) the compiled S1/S3 pair for
    one fit combo. Hardcodes f = ring.F like the rest of the launch path;
    the request schedules consume the same per-class dealer streams as the
    eager loop, so pooled serving is bit-exact by construction."""
    from repro.core.backend import get_backend
    ring_backend = get_backend(backend)
    geo = FitGeometry(partition, bool(sparse),
                      tuple(int(s) for s in shape_a),
                      tuple(int(s) for s in shape_b), int(k))
    key = (geo, ring_backend.name)
    hit = _PROGRAM_CACHE.get(key)
    if hit is not None:
        return hit

    n, d = geo.n, geo.d
    base = (_sds(geo.shape_a), _sds(geo.shape_b), _sds((k, d)), _sds((k, d)))

    def zero_inputs():
        xa = jnp.zeros(geo.shape_a, ring.DTYPE)
        xb = jnp.zeros(geo.shape_b, ring.DTYPE)
        mu = AShare(jnp.zeros((k, d), ring.DTYPE),
                    jnp.zeros((k, d), ring.DTYPE))
        return xa, xb, mu

    # ---- S1: distances + argmin -> assignment shares ---------------------
    rec1 = RecordingDealer()

    def trace1():
        xa, xb, mu = zero_inputs()
        ctx = P.Ctx(dealer=rec1, log=CommLog(), backend=ring_backend)
        return _s1_body(ctx, geo, xa, xb, mu, _zero_he(geo.he_shapes_s1()))

    jax.eval_shape(trace1)
    s1_requests = list(rec1.requests)

    def s1_fn(xa, xb, mu0, mu1, *rest):
        he, flat = _split_he(rest, geo.he_shapes_s1())
        ctx = P.Ctx(dealer=ListDealer(flat), log=CommLog(),
                    backend=ring_backend)
        c = _s1_body(ctx, geo, xa, xb, AShare(mu0, mu1), he)
        return c.s0, c.s1

    s1_args = base + tuple(_he_specs(geo.he_shapes_s1())) \
        + tuple(offline_tensor_specs(s1_requests, n))
    s1 = jax.jit(s1_fn).lower(*s1_args).compile()

    # ---- S3: centroid update --------------------------------------------
    rec3 = RecordingDealer()

    def trace3():
        xa, xb, mu = zero_inputs()
        c = AShare(jnp.zeros((n, k), ring.DTYPE),
                   jnp.zeros((n, k), ring.DTYPE))
        ctx = P.Ctx(dealer=rec3, log=CommLog(), backend=ring_backend)
        return _s3_body(ctx, geo, xa, xb, mu, c, _zero_he(geo.he_shapes_s3()))

    jax.eval_shape(trace3)
    s3_requests = list(rec3.requests)

    def s3_fn(xa, xb, mu0, mu1, c0, c1, *rest):
        he, flat = _split_he(rest, geo.he_shapes_s3())
        ctx = P.Ctx(dealer=ListDealer(flat), log=CommLog(),
                    backend=ring_backend)
        out = _s3_body(ctx, geo, xa, xb, AShare(mu0, mu1), AShare(c0, c1), he)
        return out.s0, out.s1

    s3_args = base + (_sds((n, k)), _sds((n, k))) \
        + tuple(_he_specs(geo.he_shapes_s3())) \
        + tuple(offline_tensor_specs(s3_requests, n))
    s3 = jax.jit(s3_fn).lower(*s3_args).compile()

    progs = FitPrograms(geo, s1, s3, s1_requests, s3_requests)
    _PROGRAM_CACHE[key] = progs
    return progs


# ---------------------------------------------------------------------------
# Minibatch programs — S1 + S3-partial per batch geometry, one finalize
# ---------------------------------------------------------------------------

class BatchPrograms(NamedTuple):
    """Compiled (S1, S3-partial) pair for ONE minibatch geometry plus the
    offline schedule each launch consumes. Per batch t of an iteration:

        he1 = host Protocol-2 on the centroid shares            (sparse)
        c   = s1(xa_t, xb_t, mu0, mu1, *he1, *flat1)            launch 1
        he3 = host Protocol-2 on the assignment shares          (sparse,
                                                                 S2 callback)
        n0, n1, d0, d1 = s3p(xa_t, xb_t, c0, c1, *he3, *flat3)  launch 2

    The (k, d) numerator and (k,) denominator partials accumulate across
    batches by share addition; the iteration closes with one
    `finalize_program` launch. One cached pair serves every batch of its
    geometry — a fit needs at most a handful of entries (full batch shape
    + remainder)."""

    geo: FitGeometry
    s1: Any
    s3p: Any
    s1_requests: list
    s3p_requests: list


_BATCH_PROGRAM_CACHE: dict[tuple, BatchPrograms] = {}


def fit_batch_programs(partition: str, sparse: bool, shape_a, shape_b,
                       k: int, backend: str = "auto") -> BatchPrograms:
    """Build (or fetch from the cross-fit cache) the compiled S1/S3-partial
    pair for one BATCH geometry. The S1 body is the same one `fit_programs`
    compiles — a batch is just a fit geometry with the batch rows in place
    of the training rows; S3-partial stops at the running sums."""
    from repro.core.backend import get_backend
    ring_backend = get_backend(backend)
    geo = FitGeometry(partition, bool(sparse),
                      tuple(int(s) for s in shape_a),
                      tuple(int(s) for s in shape_b), int(k))
    key = (geo, ring_backend.name)
    hit = _BATCH_PROGRAM_CACHE.get(key)
    if hit is not None:
        return hit

    n, d = geo.n, geo.d
    base = (_sds(geo.shape_a), _sds(geo.shape_b), _sds((k, d)), _sds((k, d)))

    rec1 = RecordingDealer()

    def trace1():
        xa = jnp.zeros(geo.shape_a, ring.DTYPE)
        xb = jnp.zeros(geo.shape_b, ring.DTYPE)
        mu = AShare(jnp.zeros((k, d), ring.DTYPE),
                    jnp.zeros((k, d), ring.DTYPE))
        ctx = P.Ctx(dealer=rec1, log=CommLog(), backend=ring_backend)
        return _s1_body(ctx, geo, xa, xb, mu, _zero_he(geo.he_shapes_s1()))

    jax.eval_shape(trace1)
    s1_requests = list(rec1.requests)

    def s1_fn(xa, xb, mu0, mu1, *rest):
        he, flat = _split_he(rest, geo.he_shapes_s1())
        ctx = P.Ctx(dealer=ListDealer(flat), log=CommLog(),
                    backend=ring_backend)
        c = _s1_body(ctx, geo, xa, xb, AShare(mu0, mu1), he)
        return c.s0, c.s1

    s1_args = base + tuple(_he_specs(geo.he_shapes_s1())) \
        + tuple(offline_tensor_specs(s1_requests, n))
    s1 = jax.jit(s1_fn).lower(*s1_args).compile()

    rec3 = RecordingDealer()

    def trace3():
        xa = jnp.zeros(geo.shape_a, ring.DTYPE)
        xb = jnp.zeros(geo.shape_b, ring.DTYPE)
        c = AShare(jnp.zeros((n, k), ring.DTYPE),
                   jnp.zeros((n, k), ring.DTYPE))
        ctx = P.Ctx(dealer=rec3, log=CommLog(), backend=ring_backend)
        return _s3_partial_body(ctx, geo, xa, xb, c,
                                _zero_he(geo.he_shapes_s3()))

    jax.eval_shape(trace3)
    s3p_requests = list(rec3.requests)

    def s3p_fn(xa, xb, c0, c1, *rest):
        he, flat = _split_he(rest, geo.he_shapes_s3())
        ctx = P.Ctx(dealer=ListDealer(flat), log=CommLog(),
                    backend=ring_backend)
        num, den = _s3_partial_body(ctx, geo, xa, xb, AShare(c0, c1), he)
        return num.s0, num.s1, den.s0, den.s1

    s3p_args = (_sds(geo.shape_a), _sds(geo.shape_b),
                _sds((n, k)), _sds((n, k))) \
        + tuple(_he_specs(geo.he_shapes_s3())) \
        + tuple(offline_tensor_specs(s3p_requests, n))
    s3p = jax.jit(s3p_fn).lower(*s3p_args).compile()

    progs = BatchPrograms(geo, s1, s3p, s1_requests, s3p_requests)
    _BATCH_PROGRAM_CACHE[key] = progs
    return progs


class FinalizeProgram(NamedTuple):
    """Compiled per-iteration S3 tail: one launch on the accumulated sums.

        mu'0, mu'1 = fn(mu0, mu1, num0, num1, den0, den1, *flat)

    where flat = materialize_offline(requests, dealer)."""

    fn: Any
    requests: list


_FINALIZE_CACHE: dict[tuple, FinalizeProgram] = {}


def finalize_program(k: int, d: int, n: int,
                     backend: str = "auto") -> FinalizeProgram:
    """Build (or fetch) the compiled minibatch finalize launch. `n` is the
    TOTAL sample count (division constants), so every batch layout of one
    fit shares a single entry — and the batch_size >= n fit runs the exact
    algebra of the full-batch S3 program's tail."""
    from repro.core.backend import get_backend
    ring_backend = get_backend(backend)
    key = (int(k), int(d), int(n), ring_backend.name)
    hit = _FINALIZE_CACHE.get(key)
    if hit is not None:
        return hit

    rec = RecordingDealer()

    def trace():
        z = lambda s: jnp.zeros(s, ring.DTYPE)  # noqa: E731
        ctx = P.Ctx(dealer=rec, log=CommLog(), backend=ring_backend)
        return _s3_final_body(ctx, k, n, AShare(z((k, d)), z((k, d))),
                              AShare(z((k, d)), z((k, d))),
                              AShare(z((k,)), z((k,))))

    jax.eval_shape(trace)
    requests = list(rec.requests)

    def fn(mu0, mu1, num0, num1, den0, den1, *flat):
        ctx = P.Ctx(dealer=ListDealer(list(flat)), log=CommLog(),
                    backend=ring_backend)
        out = _s3_final_body(ctx, k, n, AShare(mu0, mu1),
                             AShare(num0, num1), AShare(den0, den1))
        return out.s0, out.s1

    args = (_sds((k, d)), _sds((k, d)), _sds((k, d)), _sds((k, d)),
            _sds((k,)), _sds((k,))) \
        + tuple(offline_tensor_specs(requests, n))
    prog = FinalizeProgram(jax.jit(fn).lower(*args).compile(), requests)
    _FINALIZE_CACHE[key] = prog
    return prog


# ---------------------------------------------------------------------------
# predict_program — the S1 body alone, serving new batches against a model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PredictGeometry:
    """Shapes of one secure-scoring batch against a fitted (k, d) model.
    Vertical: both parties hold the same batch rows' column slices,
    shape_a = (m, d_a), shape_b = (m, d_b). Horizontal: each party owns
    whole arrival rows, shape_a = (m_a, d), shape_b = (m_b, d); outputs are
    ordered [A rows; B rows]. Hashable — it keys the compiled-program
    cache and (through the predict-plan key) the TripleBank lookup."""

    partition: str
    sparse: bool
    shape_a: tuple
    shape_b: tuple
    k: int
    with_scores: bool = False

    def fit_geometry(self) -> FitGeometry:
        """The S1 body is geometry-parameterized by FitGeometry; a predict
        batch is the same geometry with the batch rows in place of the
        training rows (validation included)."""
        return FitGeometry(self.partition, self.sparse,
                           self.shape_a, self.shape_b, self.k)


class PredictProgram(NamedTuple):
    """AOT-compiled batched scoring launch plus the offline schedule one
    call consumes. Per request:

        he1  = host Protocol-2 on the centroid shares          (sparse only)
        outs = fn(xa, xb, mu0, mu1, *he1, *flat)               ONE launch
        (c0, c1) = outs[:2]; (v0, v1) = outs[2:]               (with_scores)

    where flat = materialize_offline(requests, dealer). The min-distance
    shares v are D'(x, mu_c) = ||mu_c||^2 - 2 x.mu_c at scale f; the caller
    adds the locally-computable ||x||^2 share to get the true squared
    distance (core/kmeans.SecureKMeans.score)."""

    geo: PredictGeometry
    fn: Any
    requests: list


_PREDICT_PROGRAM_CACHE: dict[tuple, PredictProgram] = {}


def predict_program(partition: str, sparse: bool, shape_a, shape_b, k: int,
                    with_scores: bool = False,
                    backend: str = "auto") -> PredictProgram:
    """Build (or fetch from the cross-request cache) the compiled scoring
    launch for one batch geometry — the S1 body of `fit_programs` extracted
    and parameterized by `PredictGeometry`. Dense combos consume pool
    triples inside the program; sparse combos take the Protocol-2 joint
    products (computable from the centroid shares alone, so the host runs
    the exchange BEFORE the launch) as share inputs. Hardcodes f = ring.F
    like the rest of the launch path."""
    from repro.core.backend import get_backend
    ring_backend = get_backend(backend)
    geo = PredictGeometry(partition, bool(sparse),
                          tuple(int(s) for s in shape_a),
                          tuple(int(s) for s in shape_b), int(k),
                          bool(with_scores))
    key = (geo, ring_backend.name)
    hit = _PREDICT_PROGRAM_CACHE.get(key)
    if hit is not None:
        return hit

    fgeo = geo.fit_geometry()
    n, d = fgeo.n, fgeo.d
    rec1 = RecordingDealer()

    def trace():
        xa = jnp.zeros(geo.shape_a, ring.DTYPE)
        xb = jnp.zeros(geo.shape_b, ring.DTYPE)
        mu = AShare(jnp.zeros((k, d), ring.DTYPE),
                    jnp.zeros((k, d), ring.DTYPE))
        ctx = P.Ctx(dealer=rec1, log=CommLog(), backend=ring_backend)
        return _s1_body(ctx, fgeo, xa, xb, mu, _zero_he(fgeo.he_shapes_s1()),
                        return_min=with_scores)

    jax.eval_shape(trace)
    requests = list(rec1.requests)

    def fn(xa, xb, mu0, mu1, *rest):
        he, flat = _split_he(rest, fgeo.he_shapes_s1())
        ctx = P.Ctx(dealer=ListDealer(flat), log=CommLog(),
                    backend=ring_backend)
        out = _s1_body(ctx, fgeo, xa, xb, AShare(mu0, mu1), he,
                       return_min=with_scores)
        if with_scores:
            c, v = out
            return c.s0, c.s1, v.s0, v.s1
        return out.s0, out.s1

    args = (_sds(geo.shape_a), _sds(geo.shape_b),
            _sds((k, d)), _sds((k, d))) \
        + tuple(_he_specs(fgeo.he_shapes_s1())) \
        + tuple(offline_tensor_specs(requests, n))
    prog = PredictProgram(geo, jax.jit(fn).lower(*args).compile(), requests)
    _PREDICT_PROGRAM_CACHE[key] = prog
    return prog


def clear_program_cache() -> None:
    _PROGRAM_CACHE.clear()
    _PREDICT_PROGRAM_CACHE.clear()
    _BATCH_PROGRAM_CACHE.clear()
    _FINALIZE_CACHE.clear()


def online_iteration_fn(n: int, d: int, k: int, d_a: int,
                        sparse: bool = False, backend: str = "auto"):
    """(fn, arg ShapeDtypeStructs) with fn(xa, xb, mu0, mu1, *he, *flat).
    sparse=True adds the 8 Protocol-2 result shares as inputs and drops the
    joint Beaver matmuls (paper Sec 4.3 on-mesh). `backend` picks the
    ring-compute implementation (core/backend.py) baked into the lowering.

    Legacy single-launch form (S1+S3 fused, no mid-iteration callback) kept
    for the mesh/perf harnesses; `fit_programs` is the production split."""
    from repro.core.backend import get_backend
    ring_backend = get_backend(backend)
    n_he = 0
    he_shapes = []
    if sparse:
        he_shapes = [(n, k), (n, k), (k, d_a), (k, d - d_a)]
        n_he = 8  # 4 AShares = 8 tensors

    def _he_args(flat):
        if not sparse:
            return None, flat
        he = [AShare(flat[2 * i], flat[2 * i + 1]) for i in range(4)]
        return tuple(he), flat[n_he:]

    dealer = RecordingDealer()

    def run():
        z = jnp.zeros((n, d_a), ring.DTYPE)
        zb = jnp.zeros((n, d - d_a), ring.DTYPE)
        mu = AShare(jnp.zeros((k, d), ring.DTYPE),
                    jnp.zeros((k, d), ring.DTYPE))
        he = tuple(AShare(jnp.zeros(s, ring.DTYPE), jnp.zeros(s, ring.DTYPE))
                   for s in he_shapes) if sparse else None
        return _iteration(z, zb, mu, dealer, n, k, d_a, he_results=he,
                          backend=ring_backend)

    jax.eval_shape(run)
    flat_specs = offline_tensor_specs(dealer.requests, n)

    def fn(xa_enc, xb_enc, mu_s0, mu_s1, *flat):
        he, rest = _he_args(list(flat))
        out = _iteration(xa_enc, xb_enc, AShare(mu_s0, mu_s1),
                         ListDealer(rest), n, k, d_a, he_results=he,
                         backend=ring_backend)
        return out.s0, out.s1

    he_specs = []
    for s in he_shapes:
        he_specs += [jax.ShapeDtypeStruct(s, ring.NP_DTYPE)] * 2
    args = (jax.ShapeDtypeStruct((n, d_a), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((n, d - d_a), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((k, d), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((k, d), ring.NP_DTYPE)) \
        + tuple(he_specs) + tuple(flat_specs)
    return fn, args


def fit_iteration_fn(n: int, d: int, k: int, d_a: int,
                     backend: str = "auto"):
    """`online_iteration_fn` variant that also exposes the assignment
    shares: fn(xa, xb, mu0, mu1, *flat) -> (mu0', mu1', c0, c1), plus the
    offline schedule one call consumes. Superseded by `fit_programs` (the
    S1/S3 split) on SecureKMeans' pooled fast path; kept for callers that
    want the fused single-launch dense-vertical iteration."""
    from repro.core.backend import get_backend
    ring_backend = get_backend(backend)
    dealer = RecordingDealer()

    def run():
        z = jnp.zeros((n, d_a), ring.DTYPE)
        zb = jnp.zeros((n, d - d_a), ring.DTYPE)
        mu = AShare(jnp.zeros((k, d), ring.DTYPE),
                    jnp.zeros((k, d), ring.DTYPE))
        return _iteration(z, zb, mu, dealer, n, k, d_a,
                          backend=ring_backend, return_assignment=True)

    jax.eval_shape(run)
    requests = list(dealer.requests)
    flat_specs = offline_tensor_specs(requests, n)

    def fn(xa_enc, xb_enc, mu_s0, mu_s1, *flat):
        mu, c = _iteration(xa_enc, xb_enc, AShare(mu_s0, mu_s1),
                           ListDealer(list(flat)), n, k, d_a,
                           backend=ring_backend, return_assignment=True)
        return mu.s0, mu.s1, c.s0, c.s1

    args = (jax.ShapeDtypeStruct((n, d_a), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((n, d - d_a), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((k, d), ring.NP_DTYPE),
            jax.ShapeDtypeStruct((k, d), ring.NP_DTYPE)) + tuple(flat_specs)
    return fn, args, requests


def arg_shardings(mesh, args, n: int):
    """Shard the sample axis over ('pod','data') WHEREVER it appears —
    including dim-1 of the transposed (k, n) Beaver triples. (§Perf
    iteration 1: leaving those replicated made GSPMD reconstruct E
    replicated and ALL-GATHER the 4 GB F operands of C^T X instead of
    partial-summing — 8.6 GB/device/step of pure waste.)"""
    from jax.sharding import NamedSharding, PartitionSpec as Pspec
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    out = []
    for a in args:
        spec = [None] * len(a.shape)
        for dim, sz in enumerate(a.shape):
            if sz == n:
                spec[dim] = axes
                break
        out.append(NamedSharding(mesh, Pspec(*spec)))
    return tuple(out)
