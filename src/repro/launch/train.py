"""End-to-end fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-34b \
        --reduced --steps 300 --batch 16 --seq 128 --ckpt-dir /tmp/run1

Features exercised (at laptop scale here; the same code paths drive the
production mesh): auto-resume from the latest atomic checkpoint, keep-N GC,
deterministic restartable data, straggler-tolerant synchronous steps
(deadline metric), optional int8 error-feedback gradient compression, and a
--simulate-preemption flag used by the fault-tolerance tests.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore, config_fingerprint
from repro.configs.base import all_archs
from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.models.lm import init_params
from repro.training.adamw import AdamWConfig
from repro.training.train_step import init_state, make_train_step


def run(arch: str, *, reduced: bool = True, steps: int = 100,
        batch: int = 8, seq: int = 128, ckpt_dir: str | None = None,
        ckpt_every: int = 50, keep: int = 3, lr: float = 3e-4,
        compress: bool = False, simulate_preemption_at: int | None = None,
        log_every: int = 10, seed: int = 0, verbose: bool = True) -> dict:
    spec = all_archs()[arch]
    cfg = spec.reduced if reduced else spec.config
    opt_cfg = AdamWConfig(lr=lr)
    params = init_params(cfg, jax.random.key(seed))
    state = init_state(params, opt_cfg, compress_pod_grads=compress)
    step0 = 0

    store = None
    if ckpt_dir:
        store = CheckpointStore(ckpt_dir, keep=keep,
                                fingerprint=config_fingerprint(cfg))
        latest = store.latest_step()
        if latest is not None:
            restored = store.restore(latest, {"params": params,
                                              "state": state})
            params, state = restored["params"], restored["state"]
            step0 = latest
            if verbose:
                print(f"[resume] restored step {latest} from {ckpt_dir}")

    stream = SyntheticLMStream(DataConfig(cfg.vocab_size, seq, batch,
                                          seed=seed))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      compress_pod_grads=compress))

    losses, step_times = [], []
    for s in range(step0, steps):
        if simulate_preemption_at is not None and s == simulate_preemption_at:
            if verbose:
                print(f"[preempt] simulated kill at step {s}")
            return {"preempted_at": s, "losses": losses}
        t0 = time.perf_counter()
        host = stream.batch(s)
        b = {k: jnp.asarray(v) for k, v in host.items()}
        params, state, metrics = step_fn(params, state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        step_times.append(time.perf_counter() - t0)
        if store and (s + 1) % ckpt_every == 0:
            store.save(s + 1, {"params": params, "state": state})
        if verbose and (s % log_every == 0 or s == steps - 1):
            print(f"step {s:5d} loss {loss:.4f} "
                  f"({step_times[-1]*1e3:.0f} ms)")
    # straggler telemetry: p50/p95 step time (sync training's health metric)
    result = {"losses": losses, "final_loss": losses[-1] if losses else None,
              "p50_ms": float(np.percentile(step_times, 50) * 1e3)
              if step_times else None,
              "p95_ms": float(np.percentile(step_times, 95) * 1e3)
              if step_times else None,
              "steps_run": len(losses), "resumed_from": step0}
    if store:
        store.save(steps, {"params": params, "state": state})
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run(args.arch, reduced=args.reduced, steps=args.steps,
              batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
              ckpt_every=args.ckpt_every, lr=args.lr,
              compress=args.compress, seed=args.seed)
    print(f"final loss: {out['final_loss']:.4f}  "
          f"p50 {out['p50_ms']:.0f} ms  p95 {out['p95_ms']:.0f} ms")


if __name__ == "__main__":
    main()
