import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# §Perf hillclimb driver: baseline -> variant -> measure, per EXPERIMENTS.md.
# Three cells (chosen from the roofline table): the paper's own
# kmeans-fraud iteration, the most collective-bound train cell, and the
# flagship decode cell. Each variant is an explicit hypothesis; the output
# JSON is the iteration log.
#
#   PYTHONPATH=src python -m repro.launch.perf --cell kmeans --out perf.json

import argparse  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402
jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.launch import dryrun  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (CHIPS, HBM_BW, LINK_BW, PEAK_FLOPS,  # noqa: E402
                                   corrected_totals, model_flops)

MESH = None


def _terms(f, b, l):
    t = {"compute_s": f / PEAK_FLOPS, "memory_s": b / HBM_BW,
         "collective_s": l / LINK_BW}
    t["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                        key=lambda k: t[k])
    t["step_s"] = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return t


def measure_kmeans(sparse: bool, fuse: bool) -> dict:
    from repro.configs.kmeans_fraud import FULL as K
    from repro.core import protocol
    from repro.launch.kmeans_step import arg_shardings, online_iteration_fn
    old = protocol.FUSE_BEAVER
    protocol.FUSE_BEAVER = fuse
    try:
        fn, args = online_iteration_fn(K.n, K.d, K.k, K.d_a, sparse=sparse)
        shardings = arg_shardings(MESH, args, K.n)
        with MESH:
            compiled = jax.jit(fn, in_shardings=shardings,
                               out_shardings=NamedSharding(MESH, P())
                               ).lower(*args).compile()
        rec = dryrun.analyze(compiled)
    finally:
        protocol.FUSE_BEAVER = old
    f = rec["flops_per_device"]
    b = rec["bytes_per_device"]
    l = float(rec["collectives"]["link_bytes"])
    out = _terms(f, b, l)
    mf = (2.0 * K.n * K.d * K.k + 4.0 * K.n * K.k + 2.0 * K.n * K.d) / CHIPS
    out.update(flops_dev=f, bytes_dev=b, link_dev=l,
               useful_ratio=mf / max(f, 1.0),
               roofline_fraction=(mf / PEAK_FLOPS) / max(out["step_s"], 1e-12),
               variant=f"sparse={sparse},fuse={fuse}")
    return out


def measure_lm(arch: str, shape: str, *, sharding_mode="2d",
               micro=None, cfg_patch: dict | None = None) -> dict:
    import dataclasses

    from repro.configs.base import all_archs
    cfg_base = None
    if cfg_patch:
        cfg_base = dataclasses.replace(all_archs()[arch].config, **cfg_patch)
    old_micro = dict(dryrun.MICROBATCHES)
    if micro is not None:
        dryrun.MICROBATCHES[(arch, shape)] = micro
    try:
        old_lower = dryrun.lower_cell
        if sharding_mode != "2d":
            def lower_patched(*a, **kw):
                kw["sharding_mode"] = sharding_mode
                return old_lower(*a, **kw)
            dryrun.lower_cell = lower_patched
        try:
            with MESH:
                tot = corrected_totals(arch, shape, MESH, cfg_base=cfg_base)
        finally:
            dryrun.lower_cell = old_lower
    finally:
        dryrun.MICROBATCHES.clear()
        dryrun.MICROBATCHES.update(old_micro)
    out = _terms(tot["flops_dev"], tot["bytes_dev"], tot["link_bytes_dev"])
    mf = model_flops(arch, shape, cfg_base=cfg_base) / CHIPS
    out.update(flops_dev=tot["flops_dev"], bytes_dev=tot["bytes_dev"],
               link_dev=tot["link_bytes_dev"],
               useful_ratio=mf / max(tot["flops_dev"], 1.0),
               roofline_fraction=(mf / PEAK_FLOPS) / max(out["step_s"], 1e-12),
               variant=f"mode={sharding_mode},micro={micro},"
                       f"patch={cfg_patch}")
    return out


def main():
    global MESH
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    choices=["kmeans", "train", "moe", "decode"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    MESH = make_production_mesh(multi_pod=False)

    results = []
    if args.cell == "kmeans":
        variants = [("baseline (paper-faithful dense SS)",
                     dict(sparse=False, fuse=False)),
                    ("fused Beaver recombination", dict(sparse=False,
                                                        fuse=True)),
                    ("sparsity-aware: joint matmuls -> host HE (Protocol 2)",
                     dict(sparse=True, fuse=True))]
        if args.variant:
            variants = [v for v in variants if args.variant in v[0]]
        for name, kw in variants:
            rec = measure_kmeans(**kw)
            rec["name"] = name
            results.append(rec)
            print(f"[{name}] dom={rec['dominant']} step={rec['step_s']:.4f}s "
                  f"flops/dev={rec['flops_dev']:.3e} "
                  f"link/dev={rec['link_dev']:.3e}")
    else:
        defaults = {"train": ("granite-34b", "train_4k"),
                    "moe": ("granite-moe-3b-a800m", "train_4k"),
                    "decode": ("llama3-405b", "decode_32k")}
        arch = args.arch or defaults[args.cell][0]
        shape = args.shape or defaults[args.cell][1]
        variants = [("baseline 2D (FSDP x TP)", dict(sharding_mode="2d")),
                    ("pure FSDP (no TP)", dict(sharding_mode="fsdp"))]
        if args.cell == "decode":
            variants = [
                ("baseline 2D, batch-sharded activations",
                 dict(sharding_mode="2d")),
                ("replicated activations (partial-sum MLPs)",
                 dict(sharding_mode="repl_act")),
            ]
        if args.cell == "moe":
            variants += [
                ("FSDP + unpadded experts (40, d-sharded)",
                 dict(sharding_mode="fsdp",
                      cfg_patch={"expert_pad_multiple": 1})),
                ("FSDP + unpadded + capacity 1.0",
                 dict(sharding_mode="fsdp",
                      cfg_patch={"expert_pad_multiple": 1,
                                 "capacity_factor": 1.0})),
                ("2D + per-example dispatch (local sorts)",
                 dict(cfg_patch={"moe_dispatch": "per_example"})),
            ]
        if args.cell == "train":
            variants.append(("FSDP + save-dots remat",
                             dict(sharding_mode="fsdp",
                                  cfg_patch={"remat_policy": "dots"})))
        if args.cell == "train" and arch == "llama3-405b":
            variants.append(("2D + microbatch=4", dict(micro=4)))
        if args.variant:
            variants = [v for v in variants if args.variant in v[0]]
        for name, kw in variants:
            try:
                rec = measure_lm(arch, shape, **kw)
                rec["name"] = f"{arch}/{shape}: {name}"
                results.append(rec)
                print(f"[{name}] dom={rec['dominant']} "
                      f"step={rec['step_s']:.4f}s "
                      f"roofline={rec['roofline_fraction']:.2%}")
            except Exception as e:
                results.append({"name": name, "error": str(e)[:300]})
                print(f"[{name}] ERROR {str(e)[:160]}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
