"""Wire frontend for the scoring service (DESIGN.md §14).

`ScoringServer` fronts a `ScoringService` with the PR-7 `Responder`:
scoring requests arrive as `T_SCORE` blobs ({rid, deadline_s} meta +
x_a/x_b arrays) and are answered with the response blob ({rid, rows,
error} meta + labels/scores arrays). `ScoringClient` drives the matching
`ReliableChannel`.

Exactly-once across an unreliable wire AND a server crash:

* The transport layer already collapses drops/duplicates/corruption into
  "resend until the response lands" (sequence-number dedup in the
  `Responder`, CRC/MAC rejection, reconnect on sever).
* Above that, the CLIENT pins the request id: a retry *wave* (a fresh
  `ReliableChannel` request after the previous one exhausted its
  retries — e.g. the server died mid-request) re-sends the SAME rid.
  The server answers a rid it has already published from its response
  cache (`ScoringService.lookup`) without re-scoring — and with a
  `ServeCheckpointer` that cache survives the crash via the journal. A
  rid still in flight is deduped at admission and simply awaited again.

So client delivery is at-least-once, scoring effect is exactly-once, and
the response bytes are identical no matter how many times the request
crossed the wire.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.channel import (ReliableChannel, Responder, T_BYE, T_SCORE,
                                Transport, WireError, _pack_blob,
                                _unpack_blob)
from repro.obs import trace as _trace
from repro.serve.service import ERR_DEADLINE, ScoringResponse, ScoringService


def _response_blob(r: ScoringResponse) -> bytes:
    arrays = {"labels": np.asarray(r.labels, np.int64)}
    if r.scores is not None:
        arrays["scores"] = np.asarray(r.scores, np.float64)
    return _pack_blob({"rid": int(r.request_id), "rows": int(r.rows),
                       "error": r.error}, arrays)


def _response_from_blob(payload: bytes) -> ScoringResponse:
    meta, arrays = _unpack_blob(payload)
    return ScoringResponse(
        int(meta["rid"]), arrays.get("labels", np.zeros(0, np.int64)),
        arrays.get("scores"), int(meta["rows"]), meta.get("error"))


class ScoringServer:
    """Responder loop fronting a `ScoringService`.

    `serve_forever()` starts the service's background drain loop, then
    answers `T_SCORE` requests until the client says BYE (or the idle
    timeout trips). Each request is resolved in order: published response
    (replay — journal or cache), else admission (`submit(rid=rid)`, which
    dedups an in-flight rid) + `result()` wait. A shed admission returns
    the typed `QueueFull` response directly — transient by design, so a
    later retry of the same rid can be admitted. Handler errors answer as
    error responses instead of killing the loop."""

    def __init__(self, service: ScoringService, transport: Transport, *,
                 idle_timeout_s: float = 120.0,
                 auth_key: bytes | None = None,
                 result_timeout_s: float = 120.0):
        self.service = service
        self.result_timeout_s = float(result_timeout_s)
        self.responder = Responder(transport, self._handle,
                                   idle_timeout_s=idle_timeout_s,
                                   auth_key=auth_key)

    def _resolve(self, meta: dict, arrays: dict) -> ScoringResponse:
        # runs on the responder thread with the frame's trace id installed
        # as the ambient trace (core/channel.Responder), so this span —
        # and everything submit() stamps — carries the client's id
        rid = int(meta["rid"])
        with _trace.span("serve.resolve", rid=rid):
            r = self.service.lookup(rid)
            if r is not None:
                _trace.instant("serve.replay", rid=rid)
                return r                           # exactly-once replay
            sub = self.service.submit(arrays["x_a"], arrays["x_b"], rid=rid,
                                      deadline_s=meta.get("deadline_s"))
            if isinstance(sub, ScoringResponse):
                return sub                         # shed at admission
            r = self.service.response(rid, timeout=self.result_timeout_s)
            if r is None:
                return ScoringResponse(
                    rid, np.zeros(0, np.int64), None, 0,
                    error=f"{ERR_DEADLINE}: server result wait exceeded "
                    f"{self.result_timeout_s}s")
            return r

    def _handle(self, ftype: int, payload: bytes) -> bytes:
        if ftype != T_SCORE:
            return b""                             # heartbeat / bye
        try:
            meta, arrays = _unpack_blob(payload)
            return _response_blob(self._resolve(meta, arrays))
        except Exception as e:                     # noqa: BLE001 — the loop
            # must survive a malformed request; the client gets the reason
            try:
                rid = int(meta.get("rid", -1))
            except Exception:
                rid = -1
            return _response_blob(ScoringResponse(
                rid, np.zeros(0, np.int64), None, 0,
                error=f"{type(e).__name__}: {e}"))

    def serve_forever(self) -> Responder:
        self.service.start()
        try:
            self.responder.serve_forever()
        finally:
            self.service.close()
        return self.responder


class ScoringClient:
    """Client side: `score()` ships one arrival batch and blocks for its
    response. Wire failures inside one request are retried by the
    `ReliableChannel`; if a whole request *wave* fails (retries exhausted
    — typically the server dying mid-request), `score` starts a new wave
    with the SAME rid after `retry_wait_s`, up to `waves` times — riding
    the server's rid dedup, so redelivery never re-scores."""

    def __init__(self, transport: Transport, *,
                 auth_key: bytes | None = None, deadline_s: float = 30.0,
                 try_timeout_s: float = 0.5, max_retries: int = 10,
                 waves: int = 4, retry_wait_s: float = 0.5,
                 jitter_seed: int = 11,
                 tracer: _trace.Tracer | None = None):
        self.chan = ReliableChannel(transport, deadline_s=deadline_s,
                                    try_timeout_s=try_timeout_s,
                                    max_retries=max_retries,
                                    jitter_seed=jitter_seed,
                                    auth_key=auth_key)
        self.waves = max(1, int(waves))
        self.retry_wait_s = float(retry_wait_s)
        self.wave_retries = 0
        self._next_rid = 0
        # client-side spans go here; defaults to the process-global tracer,
        # injectable so a client and a server sharing one test process can
        # still export separate span files
        self.tracer = tracer if tracer is not None else _trace.get_tracer()

    def score(self, x_a, x_b, *, rid: int | None = None,
              deadline_s: float | None = None) -> ScoringResponse:
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, int(rid) + 1)
        meta: dict = {"rid": int(rid)}
        if deadline_s is not None:
            meta["deadline_s"] = float(deadline_s)
        payload = _pack_blob(meta, {"x_a": np.asarray(x_a, np.float64),
                                    "x_b": np.asarray(x_b, np.float64)})
        # one trace id per request — pinned like the rid, so every retry
        # wave carries the SAME id and the server's spans join up
        tid = _trace.new_trace_id()
        tid_raw = _trace.trace_id_to_bytes(tid)
        last: WireError | None = None
        with self.tracer.span("client.score", rid=int(rid), trace=tid):
            for wave in range(self.waves):
                if wave:
                    self.wave_retries += 1
                    self.tracer.instant("client.wave_retry", rid=int(rid),
                                        wave=wave, trace=tid)
                    time.sleep(self.retry_wait_s)
                    self.chan.t.reconnect()
                try:
                    return _response_from_blob(
                        self.chan.request(T_SCORE, payload,
                                          trace_id=tid_raw))
                except WireError as e:
                    last = e
        raise WireError(f"score rid={rid} failed after {self.waves} "
                        f"waves: {last}") from last

    def bye(self) -> None:
        self.chan.request(T_BYE, b"")
