"""Secure fraud-scoring service loop (paper Sec 5.6 deployed).

A fitted model must score a continuous stream of NEW transactions. Three
pieces make that a service rather than a per-request protocol run:

* **Batch ladder** — arrival batches are ragged; compiling a
  `predict_program` per exact batch size would trace/compile on the hot
  path. The service pads each coalesced group up to a small ladder of fixed
  geometries (`BatchLadder`), so steady state runs entirely from the
  compiled-program and predict-plan caches. Pad rows are zeros; their
  outputs are sliced off before anything is revealed.
* **Request coalescing** — queued requests are merged FIFO until the next
  one would overflow the top rung, then scored in ONE launch; a single
  oversized request is chunked across launches. Per-request outputs are
  split back out of the group results.
* **TripleBank** — the correlated randomness for every ladder geometry is
  provisioned ONCE (offline) under the predict-plan key and drained across
  requests and fits; a stock-out auto-replenishes (counted — size
  `provision_copies` so replenishment stays off the online path, and a
  `BankReplenisher` daemon can top shelves up before the stock-out ever
  happens).

Long-lived serving (DESIGN.md §14) adds the control plane:

* **Admission control** — `submit` against a bounded queue
  (`max_queue`): past the high-water mark the request is SHED with a
  typed `QueueFull` response instead of growing the queue without bound.
* **Deadlines** — per-request (or service-default) deadlines are checked
  at dequeue AND after collect; an expired request answers
  `DeadlineExceeded` instead of occupying a rung.
* **Exactly-once restart** — with a `ServeCheckpointer`, every drain
  journals its responses plus the bank's consumed counts BEFORE exposing
  them; a restarted service replays journaled responses verbatim and
  realigns the bank so no triple is ever double-drawn
  (checkpoint/serve.py has the full argument).
* **Background loop** — `start()` runs drains on a supervised daemon
  thread; `result(rid)` blocks until a response is published.

The service reveals ONLY the per-transaction outputs (cluster label and/or
outlier score) — centroids and per-cluster structure stay secret-shared.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time

import numpy as np

from repro.core import ring
from repro.core.kmeans import KMeansResult, SecureKMeans
from repro.core.triples import BankReplenisher, TripleBank, serve_seed
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

# Stable error-string prefixes (the `ScoringResponse.error` type tags —
# wire clients and tests dispatch on `error.startswith(...)`).
ERR_QUEUE_FULL = "QueueFull"
ERR_DEADLINE = "DeadlineExceeded"

# Latency samples kept for the p50/p99 window (drop-oldest beyond this).
LATENCY_WINDOW = 10_000


class BatchLadder:
    """Sorted rung sizes; `rung_for(m)` is the smallest rung >= m (the pad
    target), falling back to the top rung for oversized groups (the caller
    chunks those). Rungs must come in strictly increasing positive order —
    an unsorted or duplicated ladder is almost always a typo in a config
    or CLI flag, so it is rejected rather than silently reordered."""

    def __init__(self, rungs=(32, 128, 512)):
        if not rungs:
            raise ValueError("BatchLadder needs at least one rung")
        self.rungs = tuple(int(r) for r in rungs)
        if self.rungs[0] < 1:
            raise ValueError(f"ladder rungs must be >= 1, got {self.rungs}")
        if any(a >= b for a, b in zip(self.rungs, self.rungs[1:])):
            raise ValueError("ladder rungs must be sorted strictly "
                             f"increasing, got {self.rungs}")

    @property
    def max_rung(self) -> int:
        return self.rungs[-1]

    def rung_for(self, m: int) -> int:
        for r in self.rungs:
            if m <= r:
                return r
        return self.rungs[-1]


@dataclasses.dataclass
class ScoringResponse:
    request_id: int
    labels: np.ndarray                # horizontal: [A rows; B rows] order
    scores: np.ndarray | None         # squared distance to assigned centroid
    rows: int
    error: str | None = None          # None iff scored; else a typed tag:
                                      # "QueueFull: ..." (shed at admission),
                                      # "DeadlineExceeded: ..." (expired),
                                      # "<ExcType>: ..." (group kept failing
                                      # through max_attempts)


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    rows: int = 0                     # real transaction rows scored
    padded_rows: int = 0              # launch rows incl. ladder padding
    launches: int = 0
    online_seconds: float = 0.0       # drain wall-clock
    online_bytes: int = 0             # per-launch protocol traffic
    triples_served: int = 0           # correlated-randomness requests drawn
    replenish_events: int = 0         # bank stock-outs hit on the hot path
    failed_requests: int = 0          # resolved with an error response
    retried_groups: int = 0           # group retry attempts after a failure
    shed_requests: int = 0            # rejected at admission (queue full)
    expired_requests: int = 0         # answered DeadlineExceeded
    queue_depth: int = 0              # gauge: pending right now
    max_queue_depth: int = 0          # high-water mark ever observed
    latencies: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW),
        repr=False)                   # submit->publish seconds, per request
    queue_waits: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW),
        repr=False)                   # submit->dequeue seconds, per request
    inflights: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW),
        repr=False)                   # dequeue->publish seconds, per request
    lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False)
    # the lock guards the sample windows: records land from whatever
    # thread publishes (drain thread, wire responder), quantile reads come
    # from stats scrapes — an unlocked deque + numpy read can see a
    # half-rotated window

    def record_latency(self, seconds: float, *,
                       queue_wait: float | None = None,
                       inflight: float | None = None) -> None:
        with self.lock:
            self.latencies.append(float(seconds))
            if queue_wait is not None:
                self.queue_waits.append(float(queue_wait))
            if inflight is not None:
                self.inflights.append(float(inflight))

    def _quantile(self, window, q: float) -> float:
        with self.lock:
            if not window:
                return 0.0
            arr = np.asarray(window, np.float64)
        return float(np.quantile(arr, q))

    def latency_quantile(self, q: float) -> float:
        """Submit-to-publish latency quantile (seconds) over the sample
        window; 0.0 before any response has been published."""
        return self._quantile(self.latencies, q)

    def queue_wait_quantile(self, q: float) -> float:
        """Submit-to-dequeue (admission queue wait) quantile, seconds."""
        return self._quantile(self.queue_waits, q)

    def inflight_quantile(self, q: float) -> float:
        """Dequeue-to-publish (launch + collect) quantile, seconds."""
        return self._quantile(self.inflights, q)

    def as_dict(self) -> dict:
        s = max(self.online_seconds, 1e-9)
        return {
            "requests": self.requests, "rows": self.rows,
            "padded_rows": self.padded_rows, "launches": self.launches,
            "online_seconds": round(self.online_seconds, 4),
            "rows_per_s": round(self.rows / s, 1),
            "triples_per_request": round(
                self.triples_served / max(1, self.requests), 1),
            "bytes_per_request": int(
                self.online_bytes / max(1, self.requests)),
            "pad_overhead": round(
                self.padded_rows / max(1, self.rows), 3),
            "replenish_events": self.replenish_events,
            "failed_requests": self.failed_requests,
            "retried_groups": self.retried_groups,
            "shed_requests": self.shed_requests,
            "expired_requests": self.expired_requests,
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "p50_ms": round(self.latency_quantile(0.50) * 1e3, 3),
            "p99_ms": round(self.latency_quantile(0.99) * 1e3, 3),
            # telemetry split of the end-to-end latency: time spent waiting
            # for a drain vs. time inside one (launch + collect)
            "queue_wait_p50_ms": round(
                self.queue_wait_quantile(0.50) * 1e3, 3),
            "queue_wait_p99_ms": round(
                self.queue_wait_quantile(0.99) * 1e3, 3),
            "inflight_p50_ms": round(
                self.inflight_quantile(0.50) * 1e3, 3),
            "inflight_p99_ms": round(
                self.inflight_quantile(0.99) * 1e3, 3),
        }


@dataclasses.dataclass(eq=False)     # identity equality: ndarray payloads
class _Pending:
    """One queued request: payload plus its admission bookkeeping."""
    rid: int
    x_a: np.ndarray
    x_b: np.ndarray
    deadline: float | None            # time.monotonic() cutoff, or None
    t_submit: float                   # time.monotonic() at admission
    t_submit_us: int = 0              # epoch µs at admission (span clock)
    t_dequeue: float | None = None    # time.monotonic() when a drain took it
    trace: str | None = None          # ambient trace id at admission


class ScoringService:
    """Queue -> coalesce -> pad-to-ladder -> compiled secure scoring.

    `model` is a `SecureKMeans` whose config describes the deployment
    (partition, sparsity, backend); `result` the fitted model to serve
    (defaults to `model.result_`). Vertical partitions need the feature
    split (`d_a`, `d_b`) to pre-provision; horizontal infers `d` from the
    centroids. `warm()` — called lazily on first drain — compiles every
    rung's `predict_program` and provisions `provision_copies` launches of
    correlated randomness per rung into the bank; both are pure offline
    work. `provision_workers > 1` splits each provisioning across a thread
    pool by shape-class — bit-exact with serial provisioning because every
    class draws from its own seeded stream (core/triples.py).

    `rungs` configures the pad ladder (alias: `ladder`, which also accepts
    a built `BatchLadder`); rungs must be strictly increasing positive
    ints. `pipeline=True` overlaps request t+1's pre-launch host work (the
    Protocol-2 exchange and the bank draw) with request t's in-flight
    compiled launch — stream-identical to `pipeline=False` because the
    per-request prepare order is the same either way.

    Serving-plane knobs (all optional — defaults preserve the drain-a-list
    behaviour):

    * `max_queue` — admission high-water mark; `submit` past it returns a
      shed `ScoringResponse` (error prefix `QueueFull`) instead of an id.
    * `default_deadline_s` — deadline applied to requests that don't carry
      their own; expired requests answer `DeadlineExceeded`.
    * `checkpointer` — a `ServeCheckpointer`; a fresh service snapshots
      its bank after `warm()`, every drain journals responses + consumed
      counts before exposing them, and a restart replays the journal and
      realigns the bank (exactly-once responses across a crash).
    * `replenisher` — a `BankReplenisher` bound to this service's bank,
      or a kwargs dict to build one (e.g. `{"low_water": 1}`); started by
      `warm()`, stopped by `close()`.
    """

    def __init__(self, model: SecureKMeans,
                 result: KMeansResult | None = None, *,
                 bank: TripleBank | None = None, ladder=None, rungs=None,
                 with_scores: bool = True, provision_copies: int = 4,
                 provision_workers: int = 1,
                 d_a: int | None = None, d_b: int | None = None,
                 pipeline: bool = True, max_attempts: int = 3,
                 max_queue: int | None = None,
                 default_deadline_s: float | None = None,
                 checkpointer=None, replenisher=None):
        self.model = model
        self.result = result if result is not None \
            else getattr(model, "result_", None)
        if self.result is None:
            raise ValueError("ScoringService needs a fitted model")
        self.bank = bank if bank is not None \
            else TripleBank(seed=serve_seed(model.cfg.seed))
        if rungs is not None and ladder is not None:
            raise ValueError("pass rungs= or ladder=, not both")
        ladder = rungs if rungs is not None \
            else (ladder if ladder is not None else (32, 128, 512))
        self.ladder = ladder if isinstance(ladder, BatchLadder) \
            else BatchLadder(ladder)
        self.with_scores = with_scores
        self.pipeline = bool(pipeline)
        self.max_attempts = max(1, int(max_attempts))
        self.provision_copies = int(provision_copies)
        self.provision_workers = int(provision_workers)
        d = int(self.result.centroids.shape[1])
        if model.cfg.partition == "vertical":
            if d_a is None or d_b is None:
                raise ValueError("vertical service needs the feature split "
                                 "(d_a, d_b) to size its geometries")
            if d_a + d_b != d:
                raise ValueError(f"d_a + d_b = {d_a + d_b} != model d = {d}")
            self.d_a, self.d_b = int(d_a), int(d_b)
        else:
            self.d_a = self.d_b = d
        self.max_queue = None if max_queue is None else int(max_queue)
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        self.default_deadline_s = default_deadline_s
        self._queue: list[_Pending] = []
        self._next_id = 0
        self._warmed = False
        self.offline_seconds = 0.0    # warm(): compiles + provisioning
        self.stats = ServiceStats()
        self._cond = threading.Condition()
        self._done: dict[int, ScoringResponse] = {}
        self._thread: threading.Thread | None = None
        self._running = False
        self._draining = False
        self._closed = False
        self.loop_errors = 0
        self.last_loop_error: BaseException | None = None
        self.checkpointer = checkpointer
        if checkpointer is not None and checkpointer.has_bank():
            # Restart: reload the provision-time bank snapshot, replay the
            # response journal, and discard exactly the requests the dead
            # incarnation consumed so every stream resumes at the right
            # word (exactly-once argument in checkpoint/serve.py).
            self.bank = checkpointer.load_bank()
            journal, consumed = checkpointer.load_journal()
            if consumed:
                self.bank.discard(consumed)
            self._done.update(journal)
            if journal:
                self._next_id = max(journal) + 1
        if replenisher is None:
            self.replenisher = None
        elif isinstance(replenisher, BankReplenisher):
            if replenisher.bank is not self.bank:
                raise ValueError("replenisher must be bound to this "
                                 "service's bank (after a checkpoint "
                                 "restart the bank is the reloaded one — "
                                 "pass a kwargs dict instead)")
            self.replenisher = replenisher
        else:
            self.replenisher = BankReplenisher(self.bank,
                                               **dict(replenisher))

    # -- geometry helpers -------------------------------------------------
    def _rung_shapes(self, r: int) -> tuple:
        # vertical: column split; horizontal: d_a == d_b == d, both parties'
        # row blocks padded to the same rung
        return (r, self.d_a), (r, self.d_b)

    def warm(self) -> None:
        """Offline: compile every rung's program and provision its triples
        (idempotent; re-warming only tops up unprovisioned rungs). With a
        checkpointer, the FIRST warm also snapshots the provisioned bank —
        the restart baseline; a restarted service loads that snapshot
        instead of re-provisioning, so the snapshot is never rewritten.
        Starts the replenisher daemon if one is configured."""
        from repro.launch import kmeans_step as K
        t0 = time.perf_counter()
        cfg = self.model.cfg
        for r in self.ladder.rungs:
            sa, sb = self._rung_shapes(r)
            key, plan, _ = self.model.plan_predict(sa, sb, self.with_scores)
            if key not in self.bank.keys():
                self.bank.provision(key, plan, copies=self.provision_copies,
                                    workers=self.provision_workers)
            if cfg.vectorized and cfg.f == ring.F \
                    and self.model._traceable_backend():
                K.predict_program(cfg.partition, cfg.sparse, sa, sb, cfg.k,
                                  with_scores=self.with_scores,
                                  backend=cfg.backend)
        if self.checkpointer is not None and not self.checkpointer.has_bank():
            self.checkpointer.save_bank(self.bank)
        if self.replenisher is not None and not self.replenisher.running:
            self.replenisher.start()
        # expose this service's live stats/bank through the process-wide
        # registry (callback gauges — no second tally to drift)
        _metrics.register_service(self)
        _metrics.register_bank(self.bank)
        if self.replenisher is not None:
            _metrics.register_replenisher(self.replenisher)
        self._warmed = True
        self.offline_seconds += time.perf_counter() - t0

    # -- request queue ----------------------------------------------------
    def submit(self, x_a: np.ndarray, x_b: np.ndarray, *,
               deadline_s: float | None = None, rid: int | None = None):
        """Enqueue one arrival batch; returns its request id. Vertical:
        equal row counts (the parties' column slices of the same
        transactions); horizontal: each party's own arrival rows.

        `deadline_s` (else `default_deadline_s`) bounds how long the
        request may wait + run before answering `DeadlineExceeded`.
        `rid` lets a wire frontend pin the request id for retry dedup: a
        rid already answered or already queued is NOT re-enqueued — the
        same id comes back and `result(rid)` returns the original
        response (at-least-once delivery, exactly-once effect).

        If admission would push the queue past `max_queue`, the request
        is SHED: a `ScoringResponse` with error prefix `QueueFull` is
        returned instead of an id. Shed responses are transient — not
        journaled, not cached — so a later retry of the same rid can be
        admitted normally."""
        x_a = np.asarray(x_a, np.float64)
        x_b = np.asarray(x_b, np.float64)
        if self.model.cfg.partition == "vertical" \
                and x_a.shape[0] != x_b.shape[0]:
            raise ValueError("vertical request needs equal batch rows")
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        now = time.monotonic()
        with self._cond:
            if rid is not None:
                rid = int(rid)
                if rid in self._done \
                        or any(p.rid == rid for p in self._queue):
                    return rid            # duplicate delivery: dedup
                self._next_id = max(self._next_id, rid + 1)
            if self.max_queue is not None \
                    and len(self._queue) >= self.max_queue:
                self.stats.shed_requests += 1
                _trace.instant("serve.shed", rid=-1 if rid is None else rid)
                shed_rid = rid if rid is not None else -1
                return ScoringResponse(
                    shed_rid, labels=np.zeros(0, np.int64), scores=None,
                    rows=0, error=f"{ERR_QUEUE_FULL}: queue depth "
                    f"{len(self._queue)} at high-water mark "
                    f"{self.max_queue}")
            if rid is None:
                rid = self._next_id
                self._next_id += 1
            deadline = None if deadline_s is None else now + float(deadline_s)
            self._queue.append(_Pending(
                rid, x_a, x_b, deadline, now,
                t_submit_us=time.time_ns() // 1_000,
                trace=_trace.current_trace()))
            self.stats.queue_depth = len(self._queue)
            self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                             len(self._queue))
            self._cond.notify_all()
        _trace.instant("serve.admit", rid=rid)
        return rid

    def pending(self) -> int:
        with self._cond:
            return len(self._queue)

    # -- the serving loop -------------------------------------------------
    def drain(self) -> list[ScoringResponse]:
        """Score everything queued: coalesce FIFO up to the top rung, pad,
        launch, split per-request. Returns responses in submit order.

        With `pipeline`, the drain runs as a launch pipeline: while chunk
        t's compiled launch is on device, chunk t+1's pre-launch host work
        (padding, Protocol-2 exchange, bank draw) runs on the main thread
        (launch/pipeline.run_pipeline). Prepare order is monotonic either
        way, so the bank serves identical words and pipeline=False returns
        identical responses.

        Deadline policy: a request already expired at dequeue answers
        `DeadlineExceeded` WITHOUT drawing triples or occupying a rung; a
        request that expires while its group is in flight answers
        `DeadlineExceeded` after collect (the work is sunk, the caller
        still gets a prompt typed answer).

        Failure policy: a group whose launch raises is retried up to
        `max_attempts` times WITHIN this drain; exhausted, its requests
        resolve as error `ScoringResponse`s (counted in
        `stats.failed_requests`) instead of being requeued — a poisoned
        request can therefore never livelock the drain by riding the queue
        forever. Non-`Exception` escapes (KeyboardInterrupt and friends)
        still requeue everything and propagate: nothing was returned and
        nothing was journaled, so nothing is lost.

        With a checkpointer, the full response batch (including expired
        and error responses — they are final answers) is journaled BEFORE
        being exposed; see checkpoint/serve.py for why that ordering gives
        exactly-once responses across a crash."""
        if not self._warmed:
            self.warm()
        with _trace.span("serve.drain"):
            return self._drain_batch()

    def _drain_batch(self) -> list[ScoringResponse]:
        from repro.launch.pipeline import (PipelineError, StageTask,
                                           run_pipeline)
        t0 = time.perf_counter()
        served0 = self.bank.served_requests
        repl0 = self.bank.replenish_events
        with self._cond:
            pending = list(self._queue)
            self._queue.clear()
            self.stats.queue_depth = 0
        if not pending:
            self.stats.online_seconds += time.perf_counter() - t0
            return []
        now_deq = time.monotonic()
        for p in pending:
            p.t_dequeue = now_deq
        order = {p.rid: i for i, p in enumerate(pending)}
        now = time.monotonic()
        expired = [p for p in pending
                   if p.deadline is not None and now >= p.deadline]
        live = [p for p in pending if p not in expired]
        groups = []
        queue = list(live)
        while queue:
            group = [queue.pop(0)]
            while queue and self._fits(group, queue[0]):
                group.append(queue.pop(0))
            groups.append(group)
        results: dict[int, tuple] = {}    # gi -> (labels, scores)
        errors: dict[int, Exception] = {}  # gi -> last failure
        todo = list(range(len(groups)))
        try:
            for attempt in range(self.max_attempts):
                if not todo:
                    break
                if attempt:
                    self.stats.retried_groups += len(todo)
                units = []        # one entry per launch: (group idx, chunk)
                failed: set[int] = set()
                for gi in todo:
                    group = groups[gi]
                    try:
                        xa = np.concatenate([p.x_a for p in group], 0)
                        xb = np.concatenate([p.x_b for p in group], 0)
                        units.extend((gi, ca, cb)
                                     for ca, cb in self._chunks(xa, xb))
                    except Exception as e:
                        # malformed geometry dies before it ever reaches a
                        # launch — same bounded-retry fate as a launch error
                        failed.add(gi)
                        errors[gi] = e
                tasks = [StageTask(
                    pre=lambda ca=ca, cb=cb: self._prepare_one(ca, cb),
                    launch=self._launch_prepared,
                    post=lambda prep, outs, _m, ca=ca, cb=cb:
                        self._collect_one(prep, outs, ca, cb))
                    for _gi, ca, cb in units]
                chunk_outs = run_pipeline(tasks, pipeline=self.pipeline,
                                          capture_errors=True)
                per_group: dict[int, list] = {}
                for (gi, _ca, _cb), out in zip(units, chunk_outs):
                    if isinstance(out, PipelineError):
                        failed.add(gi)
                        errors[gi] = out.exc
                    else:
                        per_group.setdefault(gi, []).append(out)
                for gi in todo:
                    if gi not in failed:
                        results[gi] = self._stitch(per_group[gi])
                todo = [gi for gi in todo if gi in failed]
        except BaseException:
            # an escape the retry loop does not own (KeyboardInterrupt,
            # SystemExit, a bug in the drain scaffolding itself): no
            # responses were returned, so requeue EVERY request (submit
            # order preserved) for a later drain and re-raise
            with self._cond:
                self._queue[:0] = pending
                self.stats.queue_depth = len(self._queue)
            raise
        responses = [self._deadline_response(p, "at dequeue")
                     for p in expired]
        for gi, group in enumerate(groups):
            if gi in results:
                responses.extend(self._split_group(group, *results[gi]))
            else:
                responses.extend(self._error_responses(group, errors[gi]))
        responses.sort(key=lambda r: order[r.request_id])
        self.stats.online_seconds += time.perf_counter() - t0
        self.stats.triples_served += self.bank.served_requests - served0
        self.stats.replenish_events += self.bank.replenish_events - repl0
        self._publish(responses, pending)
        return responses

    def _publish(self, responses: list[ScoringResponse],
                 pending: list[_Pending]) -> None:
        """Journal (if checkpointing) then expose one drain's responses —
        in that order, so a crash between the two replays rather than
        re-scores (checkpoint/serve.py)."""
        if not responses:
            return
        if self.checkpointer is not None:
            self.checkpointer.record(responses, self.bank.consumed_counts())
        now = time.monotonic()
        by_rid = {p.rid: p for p in pending}
        with self._cond:
            for r in responses:
                self._done[r.request_id] = r
                p = by_rid.get(r.request_id)
                if p is not None:
                    wait = None if p.t_dequeue is None \
                        else p.t_dequeue - p.t_submit
                    fly = None if p.t_dequeue is None \
                        else now - p.t_dequeue
                    self.stats.record_latency(now - p.t_submit,
                                              queue_wait=wait, inflight=fly)
            self._cond.notify_all()
        tracer = _trace.get_tracer()
        if tracer.enabled:
            # exactly ONE request span per rid: submit-level dedup means a
            # rid is queued (and published) once; retry waves replay the
            # cached response without re-entering a drain
            for r in responses:
                p = by_rid.get(r.request_id)
                if p is None:
                    continue
                args = {"rid": p.rid,
                        "rows": r.rows,
                        "queue_wait_ms": round(
                            (p.t_dequeue - p.t_submit) * 1e3, 3)
                        if p.t_dequeue is not None else None,
                        "error": r.error}
                if p.trace is not None:
                    args["trace"] = p.trace
                tracer.complete_span(
                    "serve.request", p.t_submit_us,
                    round((now - p.t_submit) * 1e6), **args)

    # -- background serving loop ------------------------------------------
    def start(self) -> None:
        """Warm (provision + compile + snapshot) then serve drains on a
        daemon thread until `stop()`. Exceptions escaping a drain are
        counted (`loop_errors`, `last_loop_error`) and the loop keeps
        serving — a poisoned batch must not kill the service."""
        if self._thread is not None and self._thread.is_alive():
            return
        if not self._warmed:
            self.warm()
        self._running = True
        self._thread = threading.Thread(target=self._serve_loop,
                                        name="scoring-service", daemon=True)
        self._thread.start()

    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                while self._running and not self._queue:
                    self._cond.wait(0.05)
                if not self._running and not self._queue:
                    return
            try:
                self.drain()
            except Exception as e:             # noqa: BLE001 — supervised
                self.loop_errors += 1
                self.last_loop_error = e
                time.sleep(0.01)               # don't spin on a hot failure

    def stop(self) -> None:
        """Graceful: the loop finishes draining whatever is queued, then
        exits. No-op if the loop isn't running."""
        if self._thread is None:
            return
        self._draining = True
        try:
            with self._cond:
                self._running = False
                self._cond.notify_all()
            self._thread.join(timeout=60.0)
            self._thread = None
        finally:
            self._draining = False

    # -- health ------------------------------------------------------------
    HEALTH_CODES = {"STARTING": 0, "READY": 1, "DEGRADED": 2, "DRAINING": 3}

    @property
    def health(self) -> str:
        """Health state for the supervisor / `/health` endpoint
        (DESIGN.md §16): STARTING until `warm()` finished (bank loaded,
        journal replayed, programs compiled), DRAINING while `stop()` is
        flushing the queue, DEGRADED when the serving loop or the
        replenisher daemon has swallowed errors or the replenisher died
        under us, READY otherwise. Only READY answers HTTP 200."""
        if self._draining or self._closed:
            return "DRAINING"
        if not self._warmed:
            return "STARTING"
        if self.loop_errors > 0:
            return "DEGRADED"
        r = self.replenisher
        if r is not None and (r.errors > 0 or (self._warmed
                                               and not r.running)):
            return "DEGRADED"
        return "READY"

    def health_code(self) -> int:
        """Numeric encoding of `health` for the metrics gauge."""
        return self.HEALTH_CODES[self.health]

    def close(self) -> None:
        """Stop the serving loop and the replenisher daemon."""
        self.stop()
        if self.replenisher is not None:
            self.replenisher.stop()
        self._closed = True

    def response(self, rid: int,
                 timeout: float | None = None) -> ScoringResponse | None:
        """Block until `rid`'s response is published (drain / background
        loop / journal replay); None on timeout. (`self.result` is the
        fitted model — hence not `result()`.)"""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while rid not in self._done:
                if deadline is None:
                    self._cond.wait(0.5)
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    self._cond.wait(remaining)
            return self._done[rid]

    def lookup(self, rid: int) -> ScoringResponse | None:
        """Non-blocking: the published response for `rid`, else None."""
        with self._cond:
            return self._done.get(rid)

    # -- response assembly ------------------------------------------------
    def _deadline_response(self, p: _Pending, phase: str) -> ScoringResponse:
        self.stats.expired_requests += 1
        return ScoringResponse(
            p.rid, labels=np.zeros(0, np.int64), scores=None, rows=0,
            error=f"{ERR_DEADLINE}: request expired {phase}")

    def _error_responses(self, group, exc: Exception) -> list:
        out = []
        for p in group:
            out.append(ScoringResponse(
                p.rid, labels=np.zeros(0, np.int64), scores=None, rows=0,
                error=f"{type(exc).__name__}: {exc}"))
            self.stats.failed_requests += 1
        return out

    def _fits(self, group, nxt: _Pending) -> bool:
        top = self.ladder.max_rung
        if self.model.cfg.partition == "vertical":
            return sum(p.x_a.shape[0] for p in group) \
                + nxt.x_a.shape[0] <= top
        return (sum(p.x_a.shape[0] for p in group)
                + nxt.x_a.shape[0] <= top
                and sum(p.x_b.shape[0] for p in group)
                + nxt.x_b.shape[0] <= top)

    def _chunks(self, xa, xb) -> list:
        """Top-rung row windows of one coalesced group (an oversized group
        runs as several launches)."""
        top = self.ladder.max_rung
        if self.model.cfg.partition == "vertical":
            return [(xa[lo:lo + top], xb[lo:lo + top])
                    for lo in range(0, max(1, xa.shape[0]), top)]
        n_chunks = max(1, -(-max(xa.shape[0], xb.shape[0]) // top))
        return [(xa[i * top:(i + 1) * top], xb[i * top:(i + 1) * top])
                for i in range(n_chunks)]

    def _compiled(self) -> bool:
        cfg = self.model.cfg
        return cfg.vectorized and cfg.f == ring.F \
            and self.model._traceable_backend()

    def _prepare_one(self, ca, cb):
        """Pre-launch host phase of one chunk: pad to its rung, plan/bank
        lookup, bank draw, Protocol-2 exchange (model.predict_prepare).
        For configs the compiled path can't serve, returns an eager marker
        — the whole protocol then runs in the launch phase (nothing to
        overlap, but the drain stays correct)."""
        cfg = self.model.cfg
        if cfg.partition == "vertical":
            r = self.ladder.rung_for(ca.shape[0])
        else:
            r = self.ladder.rung_for(max(ca.shape[0], cb.shape[0]))
        pa = _pad_rows(ca, r)
        pb = _pad_rows(cb, r)
        key, plan, _ = self.model.plan_predict(pa.shape, pb.shape,
                                               self.with_scores)
        if key not in self.bank.keys():
            # a rung the warmup never saw (e.g. ladder edited live)
            self.bank.provision(key, plan, copies=self.provision_copies,
                                workers=self.provision_workers)
        dealer = self.bank.dealer(key)
        if self._compiled():
            prep = self.model.predict_prepare(pa, pb, self.result,
                                              dealer=dealer,
                                              with_scores=self.with_scores)
            return prep, r, None
        return None, r, (pa, pb, dealer)

    def _launch_prepared(self, prep_state):
        prep, _r, eager = prep_state
        if prep is not None:
            return self.model.predict_launch(prep)
        pa, pb, dealer = eager
        run = self.model.score if self.with_scores else self.model.predict
        return run(pa, pb, self.result, dealer=dealer)

    def _collect_one(self, prep_state, outs, ca, cb):
        """Finish one chunk (blocks on the device): PredictResult assembly,
        stats, pad-row slicing. Returns (labels, scores, a_rows) with
        horizontal labels ordered [real A rows; real B rows]."""
        prep, r, _eager = prep_state
        cfg = self.model.cfg
        pr = self.model.predict_collect(prep, outs) if prep is not None \
            else outs
        self.stats.launches += 1
        self.stats.padded_rows += 2 * r if cfg.partition == "horizontal" \
            else r
        self.stats.online_bytes += pr.log.total_bytes("online")
        labels = pr.labels_plain()
        scores = pr.scores_plain() if self.with_scores else None
        if cfg.partition == "vertical":
            m = ca.shape[0]
            return labels[:m], None if scores is None else scores[:m], m
        idx = np.r_[0:ca.shape[0], r:r + cb.shape[0]]
        return (labels[idx], None if scores is None else scores[idx],
                ca.shape[0])

    def _stitch(self, chunk_outs) -> tuple:
        """Recombine one group's chunk outputs: vertical concatenates rows;
        horizontal restores the [all A rows; all B rows] group order from
        each chunk's [A block; B block]."""
        if self.model.cfg.partition == "vertical":
            labels = np.concatenate([o[0] for o in chunk_outs])
            scores = None if chunk_outs[0][1] is None \
                else np.concatenate([o[1] for o in chunk_outs])
            return labels, scores
        labels = np.concatenate([o[0][:o[2]] for o in chunk_outs]
                                + [o[0][o[2]:] for o in chunk_outs])
        if chunk_outs[0][1] is None:
            return labels, None
        scores = np.concatenate([o[1][:o[2]] for o in chunk_outs]
                                + [o[1][o[2]:] for o in chunk_outs])
        return labels, scores

    def _split_group(self, group, labels, scores) -> list[ScoringResponse]:
        """Split one coalesced group's stacked outputs back per request.
        A request whose deadline lapsed while the group was in flight
        answers `DeadlineExceeded` — its rows were scored (the work is
        sunk) but the caller asked not to wait this long."""
        cfg = self.model.cfg
        now = time.monotonic()
        out = []
        a_off = b_off = 0
        na_tot = sum(p.x_a.shape[0] for p in group)
        for p in group:
            na, nb = p.x_a.shape[0], p.x_b.shape[0]
            if cfg.partition == "vertical":
                sel = slice(a_off, a_off + na)
                lab = labels[sel]
                sc = scores[sel] if scores is not None else None
            else:
                idx = np.r_[a_off:a_off + na,
                            na_tot + b_off:na_tot + b_off + nb]
                lab = labels[idx]
                sc = scores[idx] if scores is not None else None
                b_off += nb
            a_off += na
            if p.deadline is not None and now >= p.deadline:
                out.append(self._deadline_response(p, "in flight"))
                continue
            out.append(ScoringResponse(p.rid, lab, sc,
                                       rows=na + (0 if cfg.partition ==
                                                  "vertical" else nb)))
            self.stats.requests += 1
            self.stats.rows += out[-1].rows
        return out


def _pad_rows(x: np.ndarray, rows: int) -> np.ndarray:
    if x.shape[0] == rows:
        return x
    pad = np.zeros((rows - x.shape[0], x.shape[1]), x.dtype)
    return np.concatenate([x, pad], 0)
