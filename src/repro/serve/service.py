"""Secure fraud-scoring service loop (paper Sec 5.6 deployed).

A fitted model must score a continuous stream of NEW transactions. Three
pieces make that a service rather than a per-request protocol run:

* **Batch ladder** — arrival batches are ragged; compiling a
  `predict_program` per exact batch size would trace/compile on the hot
  path. The service pads each coalesced group up to a small ladder of fixed
  geometries (`BatchLadder`), so steady state runs entirely from the
  compiled-program and predict-plan caches. Pad rows are zeros; their
  outputs are sliced off before anything is revealed.
* **Request coalescing** — queued requests are merged FIFO until the next
  one would overflow the top rung, then scored in ONE launch; a single
  oversized request is chunked across launches. Per-request outputs are
  split back out of the group results.
* **TripleBank** — the correlated randomness for every ladder geometry is
  provisioned ONCE (offline) under the predict-plan key and drained across
  requests and fits; a stock-out auto-replenishes (counted — size
  `provision_copies` so replenishment stays off the online path).

The service reveals ONLY the per-transaction outputs (cluster label and/or
outlier score) — centroids and per-cluster structure stay secret-shared.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import ring
from repro.core.kmeans import KMeansResult, SecureKMeans
from repro.core.triples import TripleBank, serve_seed


class BatchLadder:
    """Sorted rung sizes; `rung_for(m)` is the smallest rung >= m (the pad
    target), falling back to the top rung for oversized groups (the caller
    chunks those)."""

    def __init__(self, rungs=(32, 128, 512)):
        if not rungs:
            raise ValueError("BatchLadder needs at least one rung")
        self.rungs = tuple(sorted(int(r) for r in rungs))
        if self.rungs[0] < 1:
            raise ValueError(f"ladder rungs must be >= 1, got {self.rungs}")

    @property
    def max_rung(self) -> int:
        return self.rungs[-1]

    def rung_for(self, m: int) -> int:
        for r in self.rungs:
            if m <= r:
                return r
        return self.rungs[-1]


@dataclasses.dataclass
class ScoringResponse:
    request_id: int
    labels: np.ndarray                # horizontal: [A rows; B rows] order
    scores: np.ndarray | None         # squared distance to assigned centroid
    rows: int


@dataclasses.dataclass
class ServiceStats:
    requests: int = 0
    rows: int = 0                     # real transaction rows scored
    padded_rows: int = 0              # launch rows incl. ladder padding
    launches: int = 0
    online_seconds: float = 0.0       # drain wall-clock
    online_bytes: int = 0             # per-launch protocol traffic
    triples_served: int = 0           # correlated-randomness requests drawn
    replenish_events: int = 0         # bank stock-outs hit on the hot path

    def as_dict(self) -> dict:
        s = max(self.online_seconds, 1e-9)
        return {
            "requests": self.requests, "rows": self.rows,
            "padded_rows": self.padded_rows, "launches": self.launches,
            "online_seconds": round(self.online_seconds, 4),
            "rows_per_s": round(self.rows / s, 1),
            "triples_per_request": round(
                self.triples_served / max(1, self.requests), 1),
            "bytes_per_request": int(
                self.online_bytes / max(1, self.requests)),
            "pad_overhead": round(
                self.padded_rows / max(1, self.rows), 3),
            "replenish_events": self.replenish_events,
        }


class ScoringService:
    """Queue -> coalesce -> pad-to-ladder -> compiled secure scoring.

    `model` is a `SecureKMeans` whose config describes the deployment
    (partition, sparsity, backend); `result` the fitted model to serve
    (defaults to `model.result_`). Vertical partitions need the feature
    split (`d_a`, `d_b`) to pre-provision; horizontal infers `d` from the
    centroids. `warm()` — called lazily on first drain — compiles every
    rung's `predict_program` and provisions `provision_copies` launches of
    correlated randomness per rung into the bank; both are pure offline
    work."""

    def __init__(self, model: SecureKMeans,
                 result: KMeansResult | None = None, *,
                 bank: TripleBank | None = None, ladder=(32, 128, 512),
                 with_scores: bool = True, provision_copies: int = 4,
                 d_a: int | None = None, d_b: int | None = None):
        self.model = model
        self.result = result if result is not None \
            else getattr(model, "result_", None)
        if self.result is None:
            raise ValueError("ScoringService needs a fitted model")
        self.bank = bank if bank is not None \
            else TripleBank(seed=serve_seed(model.cfg.seed))
        self.ladder = ladder if isinstance(ladder, BatchLadder) \
            else BatchLadder(ladder)
        self.with_scores = with_scores
        self.provision_copies = int(provision_copies)
        d = int(self.result.centroids.shape[1])
        if model.cfg.partition == "vertical":
            if d_a is None or d_b is None:
                raise ValueError("vertical service needs the feature split "
                                 "(d_a, d_b) to size its geometries")
            if d_a + d_b != d:
                raise ValueError(f"d_a + d_b = {d_a + d_b} != model d = {d}")
            self.d_a, self.d_b = int(d_a), int(d_b)
        else:
            self.d_a = self.d_b = d
        self._queue: list = []
        self._next_id = 0
        self._warmed = False
        self.offline_seconds = 0.0    # warm(): compiles + provisioning
        self.stats = ServiceStats()

    # -- geometry helpers -------------------------------------------------
    def _rung_shapes(self, r: int) -> tuple:
        # vertical: column split; horizontal: d_a == d_b == d, both parties'
        # row blocks padded to the same rung
        return (r, self.d_a), (r, self.d_b)

    def warm(self) -> None:
        """Offline: compile every rung's program and provision its triples
        (idempotent; re-warming only tops up unprovisioned rungs)."""
        from repro.launch import kmeans_step as K
        t0 = time.perf_counter()
        cfg = self.model.cfg
        for r in self.ladder.rungs:
            sa, sb = self._rung_shapes(r)
            key, plan, _ = self.model.plan_predict(sa, sb, self.with_scores)
            if key not in self.bank.keys():
                self.bank.provision(key, plan, copies=self.provision_copies)
            if cfg.vectorized and cfg.f == ring.F \
                    and self.model._traceable_backend():
                K.predict_program(cfg.partition, cfg.sparse, sa, sb, cfg.k,
                                  with_scores=self.with_scores,
                                  backend=cfg.backend)
        self._warmed = True
        self.offline_seconds += time.perf_counter() - t0

    # -- request queue ----------------------------------------------------
    def submit(self, x_a: np.ndarray, x_b: np.ndarray) -> int:
        """Enqueue one arrival batch; returns its request id. Vertical:
        equal row counts (the parties' column slices of the same
        transactions); horizontal: each party's own arrival rows."""
        x_a = np.asarray(x_a, np.float64)
        x_b = np.asarray(x_b, np.float64)
        if self.model.cfg.partition == "vertical" \
                and x_a.shape[0] != x_b.shape[0]:
            raise ValueError("vertical request needs equal batch rows")
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, x_a, x_b))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    # -- the serving loop -------------------------------------------------
    def drain(self) -> list[ScoringResponse]:
        """Score everything queued: coalesce FIFO up to the top rung, pad,
        launch, split per-request. Returns responses in submit order."""
        if not self._warmed:
            self.warm()
        responses = []
        t0 = time.perf_counter()
        served0 = self.bank.served_requests
        repl0 = self.bank.replenish_events
        while self._queue:
            group = [self._queue.pop(0)]
            while self._queue and self._fits(group, self._queue[0]):
                group.append(self._queue.pop(0))
            responses.extend(self._run_group(group))
        self.stats.online_seconds += time.perf_counter() - t0
        self.stats.triples_served += self.bank.served_requests - served0
        self.stats.replenish_events += self.bank.replenish_events - repl0
        return responses

    def _fits(self, group, nxt) -> bool:
        top = self.ladder.max_rung
        if self.model.cfg.partition == "vertical":
            return sum(g[1].shape[0] for g in group) \
                + nxt[1].shape[0] <= top
        return (sum(g[1].shape[0] for g in group) + nxt[1].shape[0] <= top
                and sum(g[2].shape[0] for g in group)
                + nxt[2].shape[0] <= top)

    def _run_group(self, group) -> list[ScoringResponse]:
        """One coalesced group -> one or more padded launches; split the
        stacked outputs back per request."""
        cfg = self.model.cfg
        xa = np.concatenate([g[1] for g in group], 0)
        xb = np.concatenate([g[2] for g in group], 0)
        # horizontal outputs come back ordered [all A rows; all B rows]
        labels, scores = self._launch_chunked(xa, xb)
        out = []
        a_off = b_off = 0
        na_tot = xa.shape[0]
        for rid, ga, gb in group:
            na, nb = ga.shape[0], gb.shape[0]
            if cfg.partition == "vertical":
                sel = slice(a_off, a_off + na)
                lab = labels[sel]
                sc = scores[sel] if scores is not None else None
            else:
                idx = np.r_[a_off:a_off + na,
                            na_tot + b_off:na_tot + b_off + nb]
                lab = labels[idx]
                sc = scores[idx] if scores is not None else None
                b_off += nb
            a_off += na
            out.append(ScoringResponse(rid, lab, sc,
                                       rows=na + (0 if cfg.partition ==
                                                  "vertical" else nb)))
            self.stats.requests += 1
            self.stats.rows += out[-1].rows
        return out

    def _launch_chunked(self, xa, xb):
        """Pad to the ladder and launch; oversized inputs run as several
        top-rung chunks. Returns (labels, scores) for the REAL rows only —
        horizontal results ordered [all A rows; all B rows]."""
        top = self.ladder.max_rung
        if self.model.cfg.partition == "vertical":
            labs, scs = [], []
            for lo in range(0, max(1, xa.shape[0]), top):
                la, sc = self._launch_one(xa[lo:lo + top], xb[lo:lo + top])
                labs.append(la)
                scs.append(sc)
            labels = np.concatenate(labs)
            scores = None if scs[0] is None else np.concatenate(scs)
            return labels, scores
        la_parts, lb_parts, sa_parts, sb_parts = [], [], [], []
        chunks = max(1, -(-max(xa.shape[0], xb.shape[0]) // top))
        for i in range(chunks):
            ca = xa[i * top:(i + 1) * top]
            cb = xb[i * top:(i + 1) * top]
            la, sc = self._launch_one(ca, cb)
            la_parts.append(la[:ca.shape[0]])
            lb_parts.append(la[ca.shape[0]:])
            if sc is not None:
                sa_parts.append(sc[:ca.shape[0]])
                sb_parts.append(sc[ca.shape[0]:])
        labels = np.concatenate(la_parts + lb_parts)
        scores = np.concatenate(sa_parts + sb_parts) if sa_parts else None
        return labels, scores

    def _launch_one(self, xa, xb):
        """Pad one chunk up to its rung, score it with a bank dealer, and
        reveal — returning only the real rows (vertical) or the real
        [A block; B block] concatenation (horizontal)."""
        cfg = self.model.cfg
        if cfg.partition == "vertical":
            r = self.ladder.rung_for(xa.shape[0])
            pa = _pad_rows(xa, r)
            pb = _pad_rows(xb, r)
            m = xa.shape[0]
        else:
            r = self.ladder.rung_for(max(xa.shape[0], xb.shape[0]))
            pa = _pad_rows(xa, r)
            pb = _pad_rows(xb, r)
            m = None
        sa, sb = pa.shape, pb.shape
        key, plan, _ = self.model.plan_predict(sa, sb, self.with_scores)
        if key not in self.bank.keys():
            # a rung the warmup never saw (e.g. ladder edited live)
            self.bank.provision(key, plan, copies=self.provision_copies)
        dealer = self.bank.dealer(key)
        run = self.model.score if self.with_scores else self.model.predict
        pr = run(pa, pb, self.result, dealer=dealer)
        self.stats.launches += 1
        self.stats.padded_rows += 2 * r if cfg.partition == "horizontal" \
            else r
        self.stats.online_bytes += pr.log.total_bytes("online")
        labels = pr.labels_plain()
        scores = pr.scores_plain() if self.with_scores else None
        if cfg.partition == "vertical":
            return labels[:m], None if scores is None else scores[:m]
        idx = np.r_[0:xa.shape[0], r:r + xb.shape[0]]
        return labels[idx], None if scores is None else scores[idx]


def _pad_rows(x: np.ndarray, rows: int) -> np.ndarray:
    if x.shape[0] == rows:
        return x
    pad = np.zeros((rows - x.shape[0], x.shape[1]), x.dtype)
    return np.concatenate([x, pad], 0)
