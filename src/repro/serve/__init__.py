"""Secure scoring & serving subsystem.

Turns a fitted `SecureKMeans` model into a service: arrival batches of new
transactions are padded onto a small ladder of compiled `predict_program`
geometries, scored against the secret-shared centroids (assignments and/or
outlier scores are the ONLY reveals), and fed correlated randomness from a
persistent `TripleBank` provisioned offline.

The serving plane (DESIGN.md §14) is crash-safe and wire-facing: bounded
admission with load shedding, per-request deadlines, a background
`BankReplenisher` daemon, exactly-once restart via `ServeCheckpointer`,
and a `ScoringServer`/`ScoringClient` pair over the reliable wire.
"""
from repro.serve.service import (ERR_DEADLINE, ERR_QUEUE_FULL, BatchLadder,
                                 ScoringResponse, ScoringService,
                                 ServiceStats)
from repro.serve.wire import ScoringClient, ScoringServer

__all__ = ["BatchLadder", "ScoringResponse", "ScoringService",
           "ServiceStats", "ScoringClient", "ScoringServer",
           "ERR_DEADLINE", "ERR_QUEUE_FULL"]
