"""Secure scoring & serving subsystem.

Turns a fitted `SecureKMeans` model into a service: arrival batches of new
transactions are padded onto a small ladder of compiled `predict_program`
geometries, scored against the secret-shared centroids (assignments and/or
outlier scores are the ONLY reveals), and fed correlated randomness from a
persistent `TripleBank` provisioned offline.
"""
from repro.serve.service import (BatchLadder, ScoringResponse,
                                 ScoringService, ServiceStats)

__all__ = ["BatchLadder", "ScoringResponse", "ScoringService",
           "ServiceStats"]
