"""Fraud detection on top of secure K-means (paper Sec 5.6).

K-means-based outlier detection: cluster jointly, score each transaction by
the (squared) distance to its assigned centroid, flag the top fraction as
outliers, evaluate with the Jaccard coefficient J(R, R*) = |R n R*|/|R u R*|
against ground truth.

The secure pipeline reveals ONLY the per-transaction outlier scores (the
paper's "output"): scoring runs through `SecureKMeans.score`, the batched
secure-distance + argmin protocol against the secret-shared centroids, so
neither centroids nor cluster labels are ever reconstructed — exactly the
intermediate-information leakage Liu et al. argue against and Li & Luo
("On the Privacy of Federated Clustering", 2023) show is exploitable.
`reveal_model=True` is an explicit escape hatch restoring the old
reconstruct-and-score-in-plaintext behavior (cheaper, leaks the model).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.kmeans import (KMeansConfig, KMeansResult, SecureKMeans,
                               plaintext_kmeans)


def jaccard(r: np.ndarray, r_star: np.ndarray) -> float:
    a, b = set(np.flatnonzero(r)), set(np.flatnonzero(r_star))
    if not a and not b:
        return 1.0
    return len(a & b) / len(a | b)


def outlier_scores(x: np.ndarray, centroids: np.ndarray,
                   labels: np.ndarray) -> np.ndarray:
    return ((x - centroids[labels]) ** 2).sum(1)


def detect_outliers(scores: np.ndarray, frac: float) -> np.ndarray:
    q = np.quantile(scores, 1.0 - frac)
    return scores > q


@dataclasses.dataclass
class FraudDataset:
    """Synthetic two-party fraud data shaped like the paper's deployment:
    payment company holds transaction + partial user features, merchant holds
    behavioural features; ~frac_outlier planted frauds off-manifold."""

    x_a: np.ndarray
    x_b: np.ndarray
    y_outlier: np.ndarray

    @classmethod
    def synthesize(cls, n: int = 10_000, d_a: int = 18, d_b: int = 24,
                   n_clusters: int = 5, frac_outlier: float = 0.02,
                   seed: int = 0) -> "FraudDataset":
        rng = np.random.default_rng(seed)
        d = d_a + d_b
        centers = rng.uniform(-3, 3, (n_clusters, d))
        lab = rng.integers(0, n_clusters, n)
        x = centers[lab] + rng.normal(0, 0.35, (n, d))
        n_out = int(n * frac_outlier)
        out_idx = rng.choice(n, n_out, replace=False)
        # fraud displacement lives (almost) entirely in the MERCHANT's
        # behavioural features: the payment company alone cannot see it —
        # exactly the paper's motivation for joint modelling (Sec 5.6)
        x[out_idx, :d_a] += rng.normal(0, 0.2, (n_out, d_a))
        x[out_idx, d_a:] += rng.normal(0, 1.5, (n_out, d_b)) + 4.0 * rng.choice(
            [-1, 1], (n_out, 1))
        y = np.zeros(n, bool)
        y[out_idx] = True
        return cls(x[:, :d_a], x[:, d_a:], y)


def fraud_scores(km: SecureKMeans | None, res: KMeansResult,
                 ds: FraudDataset, reveal_model: bool = False) -> np.ndarray:
    """Per-transaction outlier scores from a fitted secure model.

    Default: the secure scoring path — `SecureKMeans.score` computes
    ||x - mu_c||^2 on shares and reveals only the scores. reveal_model=True
    reconstructs centroids AND labels in plaintext first (the pre-PR-4
    behavior, kept as an explicit escape hatch for debugging/benchmarks);
    that branch needs no protocol runner, so `km` may be None."""
    if reveal_model:
        x = np.concatenate([ds.x_a, ds.x_b], 1)
        return outlier_scores(x, res.centroids_plain(), res.labels_plain())
    if km is None:
        raise ValueError("secure scoring needs the SecureKMeans instance")
    return km.score(ds.x_a, ds.x_b, res).scores_plain()


def run_secure_fraud(ds: FraudDataset, k: int = 5, iters: int = 10,
                     frac: float = 0.02, seed: int = 0, sparse: bool = False,
                     reveal_model: bool = False):
    """Joint secure pipeline -> Jaccard vs ground truth. Only the outlier
    scores are revealed (see `fraud_scores`)."""
    cfg = KMeansConfig(k=k, iters=iters, partition="vertical", seed=seed,
                       sparse=sparse)
    km = SecureKMeans(cfg)
    res = km.fit(ds.x_a, ds.x_b)
    scores = fraud_scores(km, res, ds, reveal_model=reveal_model)
    pred = detect_outliers(scores, frac)
    return jaccard(pred, ds.y_outlier), res


def run_plaintext_fraud(ds: FraudDataset, k: int = 5, iters: int = 10,
                        frac: float = 0.02, seed: int = 0,
                        party_a_only: bool = False) -> float:
    """Plaintext baseline: joint features, or payment-company-only (the
    paper's single-party comparison, Sec 5.6)."""
    x = ds.x_a if party_a_only else np.concatenate([ds.x_a, ds.x_b], 1)
    mu, lab = plaintext_kmeans(x, k, iters, seed=seed)
    pred = detect_outliers(outlier_scores(x, mu, lab), frac)
    return jaccard(pred, ds.y_outlier)
