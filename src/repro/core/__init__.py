"""Privacy-preserving K-means core (the paper's contribution).

Importing this package enables jax x64 so the l=64 ring (paper's choice,
Z_{2^64} with f=20 fractional bits) runs on native uint64 lanes. All LM-side
model code in repro.models is dtype-explicit, so flipping x64 here is safe.
"""
import jax

jax.config.update("jax_enable_x64", True)
