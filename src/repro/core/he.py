"""Additively homomorphic encryption (paper Sec 3.2) + HE2SS (Sec 3.3).

Two interchangeable backends behind one interface:

* `Paillier` — a real cryptosystem (pure-python bigints, Miller-Rabin
  keygen). Used by tests at 512/768-bit keys to validate the *actual*
  protocol end to end. (The paper uses Okamoto-Uchiyama at 2048 bits purely
  because OU beats Paillier on speed; the homomorphic interface — and hence
  the protocol — is identical.)
* `SimulatedPHE` — same interface, plaintext-backed (exact big-int
  homomorphism), with byte-accurate OU-2048 ciphertext accounting and slot
  packing. Benchmarks use it so Table/Figure reproductions aren't dominated
  by python bigint exponentiation that the paper ran in C++.

Hardware-adaptation note (DESIGN.md §3): 2048-bit modular exponentiation has
no TPU analogue; HE runs host-side in production. What the framework needs is
the protocol structure + traffic, which both backends provide exactly.
"""
from __future__ import annotations

import dataclasses
import secrets

import numpy as np

KAPPA_STAT = 40  # statistical masking parameter for HE2SS (standard sigma)


# ---------------------------------------------------------------------------
# Miller-Rabin prime generation (keygen support)
# ---------------------------------------------------------------------------

def _is_probable_prime(n: int, rounds: int = 40) -> bool:
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for _ in range(rounds):
        a = secrets.randbelow(n - 3) + 2
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def _rand_prime(bits: int) -> int:
    while True:
        cand = secrets.randbits(bits) | (1 << (bits - 1)) | 1
        if _is_probable_prime(cand):
            return cand


# ---------------------------------------------------------------------------
# Real Paillier
# ---------------------------------------------------------------------------

class PaillierPublicKey:
    def __init__(self, n: int):
        self.n = n
        self.n2 = n * n
        self.ct_bytes = (n.bit_length() * 2 + 7) // 8  # ciphertext in Z_{n^2}
        self.plain_bits = n.bit_length() - 2           # usable plaintext space

    def encrypt(self, m: int):
        m %= self.n
        r = secrets.randbelow(self.n - 2) + 1
        # g = n+1 optimization: g^m = (1 + m*n) mod n^2
        c = (1 + m * self.n) % self.n2 * pow(r, self.n, self.n2) % self.n2
        return Ciphertext(self, c)


class PaillierPrivateKey:
    def __init__(self, pk: PaillierPublicKey, p: int, q: int):
        self.pk = pk
        self.lam = _lcm(p - 1, q - 1)
        self.mu = pow(_L(pow(pk.n + 1, self.lam, pk.n2), pk.n), -1, pk.n)

    def decrypt(self, ct: "Ciphertext") -> int:
        return _L(pow(ct.c, self.lam, self.pk.n2), self.pk.n) * self.mu % self.pk.n


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


def _L(u: int, n: int) -> int:
    return (u - 1) // n


class Ciphertext:
    """[[m]] — supports + (ct or plain int) and * (plain int), paper Sec 3.2."""

    __slots__ = ("pk", "c")

    def __init__(self, pk: PaillierPublicKey, c: int):
        self.pk, self.c = pk, c

    def __add__(self, other):
        if isinstance(other, Ciphertext):
            return Ciphertext(self.pk, self.c * other.c % self.pk.n2)
        return self + self.pk.encrypt(int(other))

    def add_plain(self, m: int) -> "Ciphertext":
        """[[x + m]] without fresh randomness: g^m = (1 + m*n) mod n^2.

        Deterministic (unlike `ct + int`, which re-randomizes via a full
        encrypt) — the cost of one modular mul instead of one encryption.
        Callers that transmit the result must re-randomize it themselves
        (e.g. add a fresh [[0]]) or the recipient who produced `self` could
        recover m from the known randomness."""
        m = int(m) % self.pk.n
        return Ciphertext(self.pk, self.c * (1 + m * self.pk.n) % self.pk.n2)

    def __rmul__(self, k: int):
        k = int(k) % self.pk.n
        return Ciphertext(self.pk, pow(self.c, k, self.pk.n2))

    __mul__ = __rmul__


@dataclasses.dataclass
class Paillier:
    """Backend object: keygen + (de/en)cryption + accounting hooks."""

    key_bits: int = 512
    name: str = "paillier"

    def __post_init__(self):
        p = _rand_prime(self.key_bits // 2)
        q = _rand_prime(self.key_bits // 2)
        while q == p:
            q = _rand_prime(self.key_bits // 2)
        self.pk = PaillierPublicKey(p * q)
        self.sk = PaillierPrivateKey(self.pk, p, q)

    @property
    def ct_bytes(self) -> int:
        return self.pk.ct_bytes

    @property
    def plain_bits(self) -> int:
        return self.pk.plain_bits

    def encrypt(self, m: int) -> Ciphertext:
        return self.pk.encrypt(m)

    def decrypt(self, ct: Ciphertext) -> int:
        return self.sk.decrypt(ct)


# ---------------------------------------------------------------------------
# Simulated PHE: exact integer homomorphism, OU-2048 byte accounting
# ---------------------------------------------------------------------------

class SimCiphertext:
    __slots__ = ("he", "m")

    def __init__(self, he: "SimulatedPHE", m: int):
        self.he, self.m = he, m % he.modulus

    def __add__(self, other):
        o = other.m if isinstance(other, SimCiphertext) else int(other)
        return SimCiphertext(self.he, self.m + o)

    def add_plain(self, m: int) -> "SimCiphertext":
        """Deterministic plaintext add — same interface as Paillier's."""
        return SimCiphertext(self.he, self.m + int(m))

    def __rmul__(self, k: int):
        return SimCiphertext(self.he, int(k) * self.m)

    __mul__ = __rmul__


@dataclasses.dataclass
class SimulatedPHE:
    """Okamoto-Uchiyama cost profile (paper Sec 5.1): 2048-bit key, plaintext
    space >= 1365 bits (2/3 key len), ciphertext = one Z_n element = 256 B."""

    key_bits: int = 2048
    name: str = "ou-sim"

    def __post_init__(self):
        self.plain_bits = self.key_bits * 2 // 3  # psi, paper Sec 5.1
        self.modulus = 1 << self.plain_bits
        self.ct_bytes = self.key_bits // 8        # OU ct lives in Z_n

    def encrypt(self, m: int) -> SimCiphertext:
        return SimCiphertext(self, m)

    def decrypt(self, ct: SimCiphertext) -> int:
        return ct.m % self.modulus


# Measured single-core costs (2.5 GHz Xeon, paper's class of machine) used to
# model HE wall-time in benchmarks when running the simulated backend:
#   OU-2048 encrypt ~ 250us, decrypt ~ 150us, ct+ct ~ 1.5us, int*ct ~ 15us.
OU_COST_S = {"enc": 250e-6, "dec": 150e-6, "add": 1.5e-6, "pmul": 15e-6}
