"""Privacy-preserving (sparse) K-means — paper Algorithm 3, both partitions.

Secure Lloyd iteration (Sec 4.2):
  S1 F_ESD  — vectorized distances  D' = U - 2 X mu^T  (Eq. 3-5); the
              ||X_i||^2 term is dropped (constant per row under argmin) and
              U is computed once per iteration with ONE batched SMUL.
  S2 F_min  — tournament argmin over k (Fig. 1), vectorized over all n.
  S3 F_SCU  — mu = C^T X / 1^T C with Newton-Raphson secure division and a
              secure empty-cluster guard (CMP + MUX keep the old centroid).
  F_CSC     — secure convergence check, only the stop bit is revealed.

Vertical:   X = [X_A | X_B]   (Eq. 4, Alg. 3)      n x (dA + dB)
Horizontal: X = [X_A ; X_B]   (Eq. 5)              (nA + nB) x d

`sparse=True` swaps every joint public-x-share product for Protocol 2
(HE + HE2SS, core/sparse.py) — X never leaves its owner, traffic is
independent of nnz and of the big n*d dimension.

`vectorized=False` keeps results identical but *accounts* communication the
way the pre-vectorization protocol would ship it (one interaction per scalar
product / per comparison — "the total number of interactions in each
iteration is nk", Sec 4.2). This is the Fig. 3 baseline and the M-Kmeans
cost proxy; wall-clock on a real WAN is dominated by rounds x RTT which the
NetModel turns into Fig. 3's curves.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Literal

import jax.numpy as jnp
import numpy as np

from repro.core import faultpoints as _fp
from repro.core import protocol as P
from repro.core import ring
from repro.core.channel import CommLog, NetModel
from repro.core.he import OU_COST_S, SimulatedPHE
from repro.core.sharing import AShare, rec, rec_real, share
from repro.core.sparse import CSRMatrix, secure_sparse_matmul
from repro.core.triples import (BankSlotDealer, PlanningDealer, PooledDealer,
                                SlotDealer, StreamingPooledDealer, TriplePlan,
                                TrustedDealer, serve_seed)
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


def _h_iter_seconds():
    return _metrics.get_registry().histogram(
        "repro_fit_iteration_seconds",
        buckets=_metrics.log_buckets(1e-3, 100.0))


@dataclasses.dataclass
class KMeansConfig:
    k: int
    iters: int = 10
    partition: Literal["vertical", "horizontal"] = "vertical"
    sparse: bool = False
    vectorized: bool = True
    f: int = ring.F
    seed: int = 0
    init: Literal["random_data", "random_uniform"] = "random_data"
    tol: float | None = None        # if set, F_CSC early-stops
    he_backend: object | None = None  # default: SimulatedPHE()
    backend: str = "auto"           # ring-compute backend (core/backend.py)
    # "pooled": derive the data-independent triple schedule up front and run
    # the online loop against a PooledDealer (the paper's true offline/online
    # split). "streamed": same split, but each iteration's pool tranche is
    # generated on a background worker while the previous iteration runs —
    # peak pool residency is O(1 iteration) instead of O(iters).
    # "on_demand": synthesize triples inside the loop (baseline).
    offline: Literal["on_demand", "pooled", "streamed"] = "on_demand"
    # Minibatch Lloyd: each iteration is still one full pass over the data,
    # but processed as ceil(n / batch_size)-row batches whose S3 partial
    # sums accumulate in secret-shared running-sum/count accumulators —
    # peak launch/pool memory becomes O(batch), and the per-batch host
    # exchanges can overlap device launches (`pipeline`). None = full batch
    # (the unchanged single-pass path). batch_size >= n is bit-exact with
    # the full-batch pooled fast path. Requires offline="pooled"/"streamed"
    # and a compilable config (vectorized, f=ring.F, traceable backend).
    batch_size: int | None = None
    # With batch_size set: run batch t+1's Protocol-2 exchange + tranche pin
    # on the host while batch t's S1 launch is on device (launch/pipeline).
    # pipeline=False is the stream-identical sequential escape hatch — same
    # shares, same CommLog, same dealer words.
    pipeline: bool = True

    def __post_init__(self):
        if self.iters < 1:
            raise ValueError(
                f"KMeansConfig.iters must be >= 1, got {self.iters}: the "
                "secure Lloyd loop must run at least once to produce an "
                "assignment")
        if self.offline not in ("on_demand", "pooled", "streamed"):
            raise ValueError(
                f"KMeansConfig.offline must be 'on_demand', 'pooled' or "
                f"'streamed', got {self.offline!r}")
        if self.batch_size is not None and self.batch_size < 1:
            raise ValueError(
                f"KMeansConfig.batch_size must be None (full batch) or "
                f">= 1, got {self.batch_size}")


@dataclasses.dataclass
class KMeansResult:
    centroids: AShare                 # (k, d) shares, scale f
    assignment: AShare                # (n, k) one-hot shares, scale 1
    iters_run: int
    log: CommLog
    dealer: "TrustedDealer | PooledDealer | StreamingPooledDealer | SlotDealer"
    online_seconds: float             # loop wall minus in-loop dealer work
    offline_dealer_seconds: float     # triple synthesis (+ plan, if pooled)
    offline_modelled_ot_seconds: float
    he_seconds: float
    loop_seconds: float = 0.0         # raw Lloyd-loop wall-clock: with an
    # on-demand dealer this INCLUDES triple synthesis (no preprocessing means
    # the dealer sits on the online critical path); with offline="pooled" it
    # equals online_seconds.
    offline_plan_seconds: float = 0.0  # dry-run trace + fast-path AOT
    # compile (pooled only; the compile usually dominates)

    # -- convenience reconstructions (the protocol's single final Rec) -----
    def centroids_plain(self, f: int = ring.F) -> np.ndarray:
        return np.asarray(rec_real(self.centroids, f))

    def labels_plain(self) -> np.ndarray:
        oh = np.asarray(rec(self.assignment), np.uint64).astype(np.int64)
        return oh.argmax(1)

    def wan_lan_estimate(self, net: NetModel) -> dict:
        online = self.log.time_estimate(net, "online") + self.online_seconds \
            + self.he_seconds
        offline = self.log.time_estimate(net, "offline") \
            + self.offline_modelled_ot_seconds
        return {"online_s": online, "offline_s": offline,
                "total_s": online + offline}


@dataclasses.dataclass
class PredictResult:
    """One secure-scoring batch against a fitted model. Only the shares are
    held; the final Rec happens in `labels_plain` / `scores_plain` — the
    protocol's single reveal point, matching the paper's "nothing but the
    output" contract (centroids are never reconstructed)."""

    assignment: AShare                # (m, k) one-hot shares, scale 1
    scores: AShare | None             # (m,) ||x - mu_c||^2 shares, scale f
    log: CommLog
    seconds: float
    f: int = ring.F

    def labels_plain(self) -> np.ndarray:
        oh = np.asarray(rec(self.assignment), np.uint64).astype(np.int64)
        return oh.argmax(1)

    def scores_plain(self) -> np.ndarray:
        if self.scores is None:
            raise ValueError("assignments-only predict holds no scores; "
                             "use SecureKMeans.score")
        return np.asarray(ring.decode(rec(self.scores), self.f))


@dataclasses.dataclass
class PreparedPredict:
    """Host-phase output of one compiled scoring launch
    (`SecureKMeans.predict_prepare`): everything `predict_launch` needs to
    dispatch and `predict_collect` needs to finish. Produced on the main
    thread; the pipelined serving loop prepares request t+1 while request
    t's launch is on device."""

    prog: object                      # launch.kmeans_step.PredictProgram
    args: tuple                       # staged program inputs (device-ready)
    log: CommLog                      # the request's live log
    comm: CommLog                     # traced per-launch traffic to replay
    with_scores: bool
    x_a: np.ndarray                   # plaintext slices (for ||x||^2)
    x_b: np.ndarray
    t0: float


# (shapes, cfg-key) -> (one-iteration TriplePlan, one-iteration CommLog).
# The schedule is data-independent, so identical-shape fits share it; see
# SecureKMeans._plan_offline_iter.
_PLAN_CACHE: dict[tuple, tuple] = {}

# predict-plan cache: (shapes, with_scores, cfg-key) -> (TriplePlan,
# CommLog) of ONE scoring launch. The key doubles as the TripleBank lookup
# key — a bank provisioned under it serves any number of same-geometry
# requests across fits.
_PREDICT_PLAN_CACHE: dict[tuple, tuple] = {}


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _PREDICT_PLAN_CACHE.clear()


class SecureKMeans:
    """Two-party secure K-means. Party data stays plaintext at its owner;
    centroids/assignments are secret-shared end to end."""

    def __init__(self, cfg: KMeansConfig):
        self.cfg = cfg
        self.he = cfg.he_backend or SimulatedPHE()

    # ------------------------------------------------------------------ #
    def fit(self, x_a: np.ndarray, x_b: np.ndarray, *,
            dealer=None, wire=None, checkpoint=None,
            resume: bool = False,
            resume_step: int | None = None) -> KMeansResult:
        with _trace.span("fit", rows=int(np.asarray(x_a).shape[0]),
                         k=self.cfg.k, iters=self.cfg.iters,
                         sparse=self.cfg.sparse,
                         wired=wire is not None):
            return self._fit(x_a, x_b, dealer=dealer, wire=wire,
                             checkpoint=checkpoint, resume=resume,
                             resume_step=resume_step)

    def _fit(self, x_a: np.ndarray, x_b: np.ndarray, *,
             dealer=None, wire=None, checkpoint=None,
             resume: bool = False,
             resume_step: int | None = None) -> KMeansResult:
        """Jointly cluster the two parties' data. `dealer` (optional)
        supplies the fit's correlated randomness from an EXTERNAL provider —
        pass a `TripleBank.dealer(key)` view over a bank provisioned with
        `plan_fit`'s (key, plan) to fit with zero in-process generation
        work. The bank must share the fit's seed (`cfg.seed`): per-class
        streams then make the served words — and hence every share and
        CommLog tally — bit-identical to the built-in dealers
        (test-enforced on all partition x sparsity combos).

        `wire` (optional `channel.WireSession`): attach a real two-party
        transport — every online CommLog event then SHIPS its byte count as
        sequenced frames to the peer process and pays its round-trips
        before tallying (core/channel.py). The in-process joint simulation
        is unchanged, so a wired fit is bit-exact with an unwired one.

        `checkpoint` (optional `checkpoint.fit.FitCheckpointer`): save a
        resumable `FitState` at the configured iteration/batch cadence.
        `resume=True` restores the latest checkpoint (fingerprint-checked
        against this cfg + data shapes) and continues — finishing with
        shares, dealer counters, and CommLog tallies bit-identical to an
        uninterrupted run (test-enforced; DESIGN.md §13). `resume_step`
        (the resume negotiation's agreed `min(step)`, DESIGN.md §16)
        instead restores the largest PUBLISHED step ≤ that value — a
        party holding a newer step than its peer witnessed rewinds to the
        common one; no such step means a fresh start (also bit-exact)."""
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        ctx = P.make_ctx(cfg.seed, backend=cfg.backend, wire=wire)
        ctx.vectorized = cfg.vectorized
        x_a = np.asarray(x_a, np.float64)
        x_b = np.asarray(x_b, np.float64)
        if cfg.partition == "vertical":
            assert x_a.shape[0] == x_b.shape[0]
            n, d = x_a.shape[0], x_a.shape[1] + x_b.shape[1]
        else:
            assert x_a.shape[1] == x_b.shape[1]
            n, d = x_a.shape[0] + x_b.shape[0], x_a.shape[1]
        enc_a = _encode_np(x_a, cfg.f)
        enc_b = _encode_np(x_b, cfg.f)
        csr_a = CSRMatrix.from_dense(enc_a) if cfg.sparse else None
        csr_b = CSRMatrix.from_dense(enc_b) if cfg.sparse else None

        st = None
        if checkpoint is not None:
            # bind the store to this (cfg, shapes) run; a foreign
            # checkpoint then fails the fingerprint check at load
            fp = self._fit_fingerprint(x_a.shape, x_b.shape)
            checkpoint.fingerprint = checkpoint.fingerprint or fp
        if resume or resume_step is not None:
            if checkpoint is None:
                raise ValueError(
                    "fit(resume=True) needs checkpoint=FitCheckpointer(...)"
                    " to restore from")
            if resume_step is not None:
                s = checkpoint.step_at_or_before(int(resume_step))
                st = checkpoint.load(s) if s is not None else None
            else:
                st = checkpoint.latest()
        if st is not None:
            if st.iteration >= cfg.iters:
                raise ValueError(
                    f"checkpoint is at iteration {st.iteration} of a "
                    f"{cfg.iters}-iteration fit: nothing left to resume")
            # the checkpointed mu shares + restored tallies REPLACE init:
            # the init exchange already happened (and was tallied) in the
            # interrupted run
            mu = AShare(jnp.asarray(st.mu0), jnp.asarray(st.mu1))
            ctx.log.restore(st.comm)
        else:
            mu = self._init_centroids(ctx, rng, x_a, x_b)

        if cfg.batch_size is not None:
            # minibatch Lloyd: batched S1/S3-partial launches with secret-
            # shared running-sum accumulators and (optionally) pipelined
            # host exchanges — its own loop below
            return self._fit_minibatch(ctx, enc_a, enc_b, csr_a, csr_b,
                                       mu, n, d, ext_dealer=dealer,
                                       checkpoint=checkpoint, st=st)
        if st is not None and st.batch:
            raise ValueError(
                "a mid-iteration (batch > 0) checkpoint can only resume a "
                "minibatch fit; this config has batch_size=None")

        # pooled/streamed offline phase: trace the schedule (cached across
        # same-shape fits), bulk-generate the pools, upload once, and AOT-
        # compile the per-iteration S1/S3 program pair that consumes them —
        # for EVERY partition x sparsity combo. All of this is data-
        # independent work; the loop below then runs dealer-free, with the
        # sparse combos' Protocol-2 exchanges as host callbacks between the
        # two launches.
        it0 = st.iteration if st is not None else 0
        ckpt = checkpoint
        iter_counts = None
        if ckpt is not None or it0:
            # the advance map (per-class requests one iteration consumes) is
            # recomputed from the plan — the checkpoint stores a copy purely
            # as an integrity cross-check (DESIGN.md §13)
            iter_counts = self._plan_offline_iter(
                x_a.shape, x_b.shape)[0].class_counts()
        if it0:
            adv = {k: c * it0 for k, c in iter_counts.items()}
            if st.advance and st.advance != adv:
                raise ValueError(
                    "checkpoint dealer-stream positions disagree with the "
                    "plan-derived positions — the checkpoint belongs to a "
                    "different offline schedule")
        plan_s = 0.0
        fast = None
        if dealer is not None or cfg.offline in ("pooled", "streamed"):
            t0 = time.perf_counter()
            iter_plan, iter_comm = self._plan_offline_iter(
                x_a.shape, x_b.shape)
            # the compiled programs hardcode f = ring.F (launch/kmeans_step
            # has no per-config scale), so a custom precision falls back to
            # the eager pooled loop rather than silently truncating wrong;
            # the host-only numpy backend cannot be traced into a program
            use_fast = cfg.vectorized and cfg.f == ring.F \
                and self._traceable_backend()
            if use_fast:
                from repro.launch import kmeans_step as K
                progs = K.fit_programs(cfg.partition, cfg.sparse,
                                       enc_a.shape, enc_b.shape, cfg.k,
                                       backend=cfg.backend)
                # upload the constant plaintext operands once, offline; the
                # sparse host exchange #2 consumes the pre-transposed CSRs
                csr_at = csr_a.transpose() if cfg.sparse else None
                csr_bt = csr_b.transpose() if cfg.sparse else None
                fast = (progs, K.materialize_offline, iter_comm,
                        jnp.asarray(enc_a), jnp.asarray(enc_b),
                        csr_at, csr_bt)
            plan_s = time.perf_counter() - t0
            # resume: the restored comm snapshot already carries the FULL
            # fit's offline tallies (dealers account their whole plan at
            # construction), so a resumed dealer books offline to a scratch
            # log; its class streams start advanced past it0 iterations
            adv = {k: c * it0 for k, c in iter_counts.items()} if it0 else {}
            dlog = CommLog() if it0 else ctx.log
            if dealer is not None:
                # external provider (e.g. a provisioned TripleBank view):
                # its generation cost lives on the bank's offline books —
                # this fit pays only the (cached) plan + any stock-out stall
                if it0:
                    dealer.skip(iter_plan, it0)
                ctx.dealer = dealer
            elif cfg.offline == "pooled":
                ctx.dealer = PooledDealer(
                    iter_plan.repeat(cfg.iters - it0),
                    seed=cfg.seed, log=dlog, advance=adv)
            else:
                # group="auto": tiny k*d tranches share one background-
                # worker wakeup (bit-exact either way)
                ctx.dealer = StreamingPooledDealer(
                    iter_plan, cfg.iters - it0, seed=cfg.seed,
                    log=dlog, group="auto", advance=adv)
        elif it0:
            # on-demand resume: a fresh TrustedDealer on the live log (its
            # remaining offline tallies accrue ON TOP of the restored
            # snapshot, like the original loop's would have), streams
            # pre-advanced
            ctx.dealer = TrustedDealer(
                seed=cfg.seed, log=ctx.log,
                advance={k: c * it0 for k, c in iter_counts.items()})
        if st is not None:
            for attr in ("n_matmul", "n_mul", "n_bin"):
                setattr(ctx.dealer, attr, st.counters[attr])

        t_start = time.perf_counter()
        dealer_s_pre = ctx.dealer.dealer_seconds
        h_iter = _h_iter_seconds()
        it = it0
        try:
            for it in range(it0 + 1, cfg.iters + 1):
                t_iter = time.perf_counter()
                mu_old = mu
                if fast is not None:
                    # TWO launches per iteration (S1: distances+argmin, S3:
                    # update), the pool's device arrays entering as arguments
                    # (ListDealer discipline). The sparse combos run Protocol 2
                    # host-side around S1 — exchange #1 needs only the centroid
                    # shares, exchange #2 (the S2 callback) the assignment
                    # shares S1 just produced — and feed the results in as
                    # share inputs.
                    progs, materialize, iter_comm, dev_a, dev_b, \
                        csr_at, csr_bt = fast
                    he1 = he3 = []
                    hx = None
                    _fp.probe("fit.exchange1")
                    if cfg.sparse:
                        # scratch log (Ctx.fork): the launched programs' shape-
                        # determined traffic (incl. Protocol 2's) is replayed
                        # from iter_comm below; only he_seconds must flow back
                        hx = ctx.fork(tag="S1")
                        with _trace.span("fit.s1_exchange", iter=it):
                            he1 = self._s1_he_inputs(hx, enc_a, enc_b,
                                                     csr_a, csr_b, mu)
                    with _trace.span("fit.s1_launch", iter=it):
                        flat1 = materialize(progs.s1_requests, ctx.dealer)
                        c0, c1 = progs.s1(dev_a, dev_b, mu.s0, mu.s1,
                                          *he1, *flat1)
                    c = AShare(c0, c1)
                    _fp.probe("fit.mid_s1")
                    if cfg.sparse:
                        hx.tag = "S3"
                        with _trace.span("fit.s2_callback", iter=it):
                            he3 = self._s3_he_inputs(hx, csr_at, csr_bt, c)
                    _fp.probe("fit.s2_callback")
                    with _trace.span("fit.s3_launch", iter=it):
                        flat3 = materialize(progs.s3_requests, ctx.dealer)
                        mu0, mu1 = progs.s3(dev_a, dev_b, mu.s0, mu.s1,
                                            c0, c1, *he3, *flat3)
                    mu = AShare(mu0, mu1)
                    _fp.probe("fit.s3_partial")
                    if hx is not None:
                        ctx.add_he_seconds(hx.he_seconds)
                    # per-iteration traffic is shape-determined; replay the
                    # traced iteration's online tallies (protocol sends only
                    # fire at trace time inside a compiled step)
                    ctx.log.merge(iter_comm, phase="online")
                else:
                    ctx.tag = "S1"
                    with _trace.span("fit.s1_distances", iter=it):
                        dist = self._distances(ctx, enc_a, enc_b, csr_a,
                                               csr_b, mu)
                    ctx.tag = "S2"
                    r_before = ctx.log.total_rounds("online")
                    with _trace.span("fit.s2_argmin", iter=it):
                        c = P.argmin_onehot(ctx, dist)        # (n, k) scale 1
                    if not cfg.vectorized:
                        # pre-vectorization: each of the n samples runs its own
                        # tournament (n separate interaction chains per round)
                        dr = ctx.log.total_rounds("online") - r_before
                        _naive_extra_rounds(ctx, (n - 1) * dr + 1)
                    ctx.tag = "S3"
                    with _trace.span("fit.s3_update", iter=it):
                        mu = self._update(ctx, enc_a, enc_b, csr_a, csr_b,
                                          c, mu_old, n)
                if cfg.tol is not None:
                    ctx.tag = "CSC"
                    if self._converged(ctx, mu_old, mu, cfg.tol):
                        break
                if ckpt is not None and ckpt.want_iter(it, cfg.iters):
                    # iteration boundary: the live log is canonical (all of
                    # iterations 1..it merged, nothing ahead)
                    self._save_fit_ckpt(
                        ckpt, ctx, it, 0, mu,
                        {k: c * it for k, c in iter_counts.items()})
                h_iter.observe(time.perf_counter() - t_iter)
            jnp.asarray(mu.s0).block_until_ready()
            wall = time.perf_counter() - t_start
        finally:
            if isinstance(ctx.dealer, StreamingPooledDealer):
                # a tol early-stop — or an exception unwinding the loop —
                # leaves prefetched tranches and the worker thread alive;
                # release them AFTER the online clock stops (no-op when the
                # fit served every tranche)
                ctx.dealer.close()
        dealer = ctx.dealer
        in_loop_dealer_s = dealer.dealer_seconds - dealer_s_pre
        self.result_ = KMeansResult(
            centroids=mu, assignment=c, iters_run=it, log=ctx.log,
            dealer=dealer,
            online_seconds=max(0.0, wall - in_loop_dealer_s),
            offline_dealer_seconds=dealer.dealer_seconds + plan_s,
            offline_modelled_ot_seconds=dealer.modelled_ot_seconds,
            he_seconds=ctx.he_seconds,
            loop_seconds=wall,
            offline_plan_seconds=plan_s,
        )
        return self.result_

    # ------------------------------------------------------------------ #
    # Minibatch Lloyd — batched S1/S3-partial launches, pipelined exchanges
    # ------------------------------------------------------------------ #
    def _fit_minibatch(self, ctx, enc_a, enc_b, csr_a, csr_b, mu: AShare,
                       n: int, d: int, ext_dealer=None, checkpoint=None,
                       st=None) -> KMeansResult:
        """Each iteration is one full pass over the data in
        ceil(n / batch_size)-row batches: per batch an S1 launch (distances
        + argmin on the CURRENT centroids) and an S3-partial launch whose
        (k, d)/(k,) sums accumulate in secret-shared running accumulators
        (share addition — free), then ONE finalize launch divides. This is
        blocked full-batch Lloyd, not stochastic minibatching: bit-exact
        with the single-pass pooled path at batch_size >= n, and within
        truncation-LSB noise of it otherwise.

        With cfg.pipeline, batch t+1's Protocol-2 exchange and tranche pin
        run on the host while batch t's S1 launch is on device
        (launch/pipeline.run_pipeline); the SlotDealer pins each (iteration,
        batch, stage) slot's randomness at generation time — in canonical
        slot order — so pipeline=False is stream-identical."""
        cfg = self.cfg
        if cfg.offline not in ("pooled", "streamed"):
            raise ValueError(
                "batch_size (minibatch Lloyd) requires the planned offline "
                "phase: set offline='pooled' or 'streamed' "
                f"(got {cfg.offline!r})")
        if not (cfg.vectorized and cfg.f == ring.F
                and self._traceable_backend()):
            raise ValueError(
                "minibatch Lloyd runs on the compiled S1/S3 fast path only: "
                f"it needs vectorized=True, f={ring.F} and a device-"
                "traceable backend (numpy is host-only)")
        from repro.launch import kmeans_step as K
        from repro.launch.pipeline import run_pipeline

        t0 = time.perf_counter()
        bounds, stage_plans, (fin_plan, fin_comm), _ = \
            self._minibatch_slot_plans(enc_a.shape, enc_b.shape)
        batches = []
        for ((alo, ahi), (blo, bhi)), plans in zip(bounds, stage_plans):
            ea, eb = enc_a[alo:ahi], enc_b[blo:bhi]
            ca = CSRMatrix.from_dense(ea) if cfg.sparse else None
            cb = CSRMatrix.from_dense(eb) if cfg.sparse else None
            s1_plan, s1_comm, s3_plan, s3_comm = plans
            batches.append({
                "enc_a": ea, "enc_b": eb,
                "dev_a": jnp.asarray(ea), "dev_b": jnp.asarray(eb),
                "csr_a": ca, "csr_b": cb,
                "csr_at": ca.transpose() if cfg.sparse else None,
                "csr_bt": cb.transpose() if cfg.sparse else None,
                "progs": K.fit_batch_programs(cfg.partition, cfg.sparse,
                                              ea.shape, eb.shape, cfg.k,
                                              backend=cfg.backend),
                "s1_plan": s1_plan, "s1_comm": s1_comm,
                "s3_plan": s3_plan, "s3_comm": s3_comm,
                "a_rows": ahi - alo,
            })
        fin_prog = K.finalize_program(cfg.k, d, n, backend=cfg.backend)
        iter_slots = []
        for b in batches:
            iter_slots += [b["s1_plan"], b["s3_plan"]]
        iter_slots.append(fin_plan)
        spi = len(iter_slots)                    # slots per iteration
        ckpt = checkpoint
        if ckpt is not None and ckpt.batch_every is not None and cfg.pipeline:
            raise ValueError(
                "batch-granular checkpoints (batch_every) require "
                "pipeline=False: the pipelined executor merges batch t+1's "
                "traffic before batch t accumulates, so mid-iteration the "
                "live CommLog is not the canonical prefix a resume restores "
                "(iteration-boundary checkpoints work on both executors)")
        it0 = st.iteration if st is not None else 0
        b0 = st.batch if st is not None else 0
        start_slot = it0 * spi + 2 * b0
        slot_counts = [p.class_counts() for p in iter_slots]

        def slots_advance(n_slots: int) -> dict:
            adv: dict = {}
            for s in range(n_slots):
                for ck, c in slot_counts[s % spi].items():
                    adv[ck] = adv.get(ck, 0) + c
            return adv

        if st is not None and st.advance \
                and st.advance != slots_advance(start_slot):
            raise ValueError(
                "checkpoint dealer-stream positions disagree with the "
                "plan-derived slot positions — the checkpoint belongs to a "
                "different offline schedule")
        # resume: offline tallies for the WHOLE schedule were booked at the
        # original dealer's construction and live in the restored snapshot —
        # a resumed dealer books its (remaining-slot) accounting to scratch
        dlog = CommLog() if start_slot else ctx.log
        if ext_dealer is not None:
            bank = getattr(ext_dealer, "bank", None)
            if bank is None:
                raise ValueError(
                    "minibatch fit(dealer=...) takes a TripleBank dealer "
                    "view (bank.dealer(key) over a plan_fit provisioning); "
                    f"got {type(ext_dealer).__name__}")
            dealer = BankSlotDealer(bank, ext_dealer.key,
                                    iter_slots * cfg.iters, log=dlog,
                                    start_slot=start_slot)
        else:
            dealer = SlotDealer(iter_slots * cfg.iters, seed=cfg.seed,
                                log=dlog,
                                stream=(cfg.offline == "streamed"),
                                start_slot=start_slot)
        ctx.dealer = dealer
        if st is not None:
            for attr in ("n_matmul", "n_mul", "n_bin"):
                setattr(dealer, attr, st.counters[attr])
        plan_s = time.perf_counter() - t0

        t_start = time.perf_counter()
        h_iter = _h_iter_seconds()
        it = it0
        c_parts = [None] * len(batches)
        try:
            for it in range(it0 + 1, cfg.iters + 1):
                t_iter = time.perf_counter()
                mu_old = mu
                base = (it - 1) * spi
                start_b = b0 if it == it0 + 1 else 0
                if start_b:
                    # mid-iteration resume: restored partial accumulators +
                    # completed batches' assignment shares; remaining
                    # batches run from the checkpointed cursor
                    acc = [jnp.asarray(a) for a in st.acc]
                    for t in range(start_b):
                        c_parts[t] = AShare(jnp.asarray(st.c0_parts[t]),
                                            jnp.asarray(st.c1_parts[t]))
                else:
                    acc = [jnp.zeros((cfg.k, d), ring.DTYPE),
                           jnp.zeros((cfg.k, d), ring.DTYPE),
                           jnp.zeros((cfg.k,), ring.DTYPE),
                           jnp.zeros((cfg.k,), ring.DTYPE)]

                def on_done(t_done: int, _it=it, _acc=acc, _mu=mu):
                    b_done = t_done + 1
                    if ckpt is None \
                            or not ckpt.want_batch(b_done, len(batches)):
                        return
                    # sequential executor only (enforced above): after batch
                    # t's post, the live log holds exactly batches 0..t —
                    # the canonical prefix
                    self._save_fit_ckpt(
                        ckpt, ctx, _it - 1, b_done, _mu,
                        slots_advance((_it - 1) * spi + 2 * b_done),
                        acc=_acc, c_parts=c_parts[:b_done])

                tasks = [self._batch_task(ctx, dealer, b, mu,
                                          base + 2 * t, acc, c_parts, t,
                                          on_done=on_done)
                         for t, b in enumerate(batches) if t >= start_b]
                run_pipeline(tasks, pipeline=cfg.pipeline)
                _fp.probe("fit.finalize")
                fin_view = dealer.acquire(base + 2 * len(batches))
                flat_f = K.materialize_offline(fin_prog.requests, fin_view)
                mu0, mu1 = fin_prog.fn(mu.s0, mu.s1, acc[0], acc[1],
                                       acc[2], acc[3], *flat_f)
                mu = AShare(mu0, mu1)
                ctx.log.merge(fin_comm, phase="online")
                if cfg.tol is not None:
                    # CSC triples live at the tail of the finalize slot
                    cctx = P.Ctx(dealer=fin_view, log=ctx.log, tag="CSC",
                                 backend=ctx.backend)
                    if self._converged(cctx, mu_old, mu, cfg.tol):
                        break
                if ckpt is not None and ckpt.want_iter(it, cfg.iters):
                    # iteration boundary: the pipeline fully drained at
                    # finalize, so this cut is canonical on BOTH executors
                    self._save_fit_ckpt(ckpt, ctx, it, 0, mu,
                                        slots_advance(it * spi))
                h_iter.observe(time.perf_counter() - t_iter)
            jnp.asarray(mu.s0).block_until_ready()
            wall = time.perf_counter() - t_start
        finally:
            dealer.close()

        c = _assemble_assignment(cfg.partition, c_parts, batches)
        self.result_ = KMeansResult(
            centroids=mu, assignment=c, iters_run=it, log=ctx.log,
            dealer=dealer,
            # SlotDealer stalls (wait_seconds) stay in the online clock on
            # purpose — they are real online stalls, like the streaming
            # dealer's
            # same convention as the streamed full-batch path: overlapped
            # worker generation (gen_seconds) stays OFF the offline column
            # — it already overlaps the online wall
            online_seconds=wall,
            offline_dealer_seconds=dealer.dealer_seconds + plan_s,
            offline_modelled_ot_seconds=dealer.modelled_ot_seconds,
            he_seconds=ctx.he_seconds,
            loop_seconds=wall,
            offline_plan_seconds=plan_s,
        )
        return self.result_

    def _batch_task(self, ctx, dealer, b: dict, mu: AShare, slot0: int,
                    acc: list, c_parts: list, t: int, on_done=None):
        """One minibatch as a 4-phase pipeline step (launch/pipeline.py):
        pre = exchange #1 (centroid shares only) + S1 tranche pin; launch =
        S1 dispatch; mid = exchange #2 on the assignment shares (the S2
        callback — blocks on the device) + S3 tranche pin; post = S3-partial
        dispatch + accumulator adds."""
        cfg = self.cfg
        from repro.launch import kmeans_step as K
        from repro.launch.pipeline import StageTask
        progs = b["progs"]

        def hx_ctx(view, tag):
            return P.Ctx(dealer=view, log=CommLog(), tag=tag,
                         backend=ctx.backend)

        def flow_he(hx):
            ctx.add_he_seconds(hx.he_seconds)

        def pre():
            _fp.probe("fit.exchange1")
            view = dealer.acquire(slot0)
            he1 = []
            if cfg.sparse:
                hx = hx_ctx(view, "S1")
                he1 = self._s1_he_inputs(hx, b["enc_a"], b["enc_b"],
                                         b["csr_a"], b["csr_b"], mu)
                flow_he(hx)
            flat1 = K.materialize_offline(progs.s1_requests, view)
            ctx.log.merge(b["s1_comm"], phase="online")
            return he1, flat1

        def launch(prep):
            he1, flat1 = prep
            c0, c1 = progs.s1(b["dev_a"], b["dev_b"], mu.s0, mu.s1,
                              *he1, *flat1)
            _fp.probe("fit.mid_s1")
            return AShare(c0, c1)

        def mid(prep, c):
            _fp.probe("fit.s2_callback")
            view = dealer.acquire(slot0 + 1)
            he3 = []
            if cfg.sparse:
                hx = hx_ctx(view, "S3")
                he3 = self._s3_he_inputs(hx, b["csr_at"], b["csr_bt"], c)
                flow_he(hx)
            flat3 = K.materialize_offline(progs.s3p_requests, view)
            ctx.log.merge(b["s3_comm"], phase="online")
            return he3, flat3

        def post(prep, c, m):
            _fp.probe("fit.s3_partial")
            he3, flat3 = m
            n0, n1, d0, d1 = progs.s3p(b["dev_a"], b["dev_b"], c.s0, c.s1,
                                       *he3, *flat3)
            acc[0] = acc[0] + n0
            acc[1] = acc[1] + n1
            acc[2] = acc[2] + d0
            acc[3] = acc[3] + d1
            c_parts[t] = c
            if on_done is not None:
                on_done(t)
            return None

        return StageTask(pre, launch, mid, post)

    def _plan_batch_stage(self, shape_a, shape_b, stage: str):
        """(plan, comm) of ONE minibatch stage — 's1' (distances + argmin)
        or 's3p' (C^T X partial sums) — cached like the full-iteration
        plans. Concatenated per iteration (batch stages + finalize) the
        slot plans equal the full-batch iteration plan when
        batch_size >= n: the bit-exactness anchor."""
        key = ("mb", stage) + self._plan_cache_key(shape_a, shape_b)
        hit = _PLAN_CACHE.get(key)
        if hit is None:
            hit = _PLAN_CACHE[key] = self._trace_batch_stage(
                shape_a, shape_b, stage)
        plan, comm = hit
        return TriplePlan(list(plan.requests)), comm.copy()

    def _trace_batch_stage(self, shape_a, shape_b, stage: str):
        """Dry-run trace of one minibatch stage on zero-filled batch
        slices with a PlanningDealer (the per-stage analogue of
        `_trace_iteration`)."""
        cfg = self.cfg
        ctx = P.Ctx(dealer=PlanningDealer(), log=CommLog(),
                    backend=cfg.backend)
        ctx.vectorized = cfg.vectorized
        enc_a = np.zeros(tuple(shape_a), np.uint64)
        enc_b = np.zeros(tuple(shape_b), np.uint64)
        d = enc_a.shape[1] + enc_b.shape[1] if cfg.partition == "vertical" \
            else enc_a.shape[1]
        csr_a = CSRMatrix.from_dense(enc_a) if cfg.sparse else None
        csr_b = CSRMatrix.from_dense(enc_b) if cfg.sparse else None
        if stage == "s1":
            mu = AShare(jnp.zeros((cfg.k, d), ring.DTYPE),
                        jnp.zeros((cfg.k, d), ring.DTYPE))
            ctx.tag = "S1"
            dist = self._distances(ctx, enc_a, enc_b, csr_a, csr_b, mu)
            ctx.tag = "S2"
            P.argmin_onehot(ctx, dist)
        else:
            rows = enc_a.shape[0] if cfg.partition == "vertical" \
                else enc_a.shape[0] + enc_b.shape[0]
            c = AShare(jnp.zeros((rows, cfg.k), ring.DTYPE),
                       jnp.zeros((rows, cfg.k), ring.DTYPE))
            ctx.tag = "S3"
            self._ct_x(ctx, enc_a, enc_b, csr_a, csr_b, c)
        comm = CommLog()
        comm.merge(ctx.log, phase="online")
        return ctx.dealer.plan(), comm

    def _plan_finalize(self, d: int, n: int):
        """(plan, comm) of the per-iteration finalize launch (+ CSC when
        tol is set); keyed by the division constants, not the batch
        layout."""
        cfg = self.cfg
        key = ("mb", "fin", cfg.k, int(d), int(n), cfg.f, cfg.vectorized,
               cfg.tol is not None)
        hit = _PLAN_CACHE.get(key)
        if hit is None:
            hit = _PLAN_CACHE[key] = self._trace_finalize(d, n)
        plan, comm = hit
        return TriplePlan(list(plan.requests)), comm.copy()

    def _trace_finalize(self, d: int, n: int):
        cfg = self.cfg
        ctx = P.Ctx(dealer=PlanningDealer(), log=CommLog(),
                    backend=cfg.backend)
        ctx.vectorized = cfg.vectorized
        z = lambda s: jnp.zeros(s, ring.DTYPE)  # noqa: E731
        mu = AShare(z((cfg.k, d)), z((cfg.k, d)))
        num = AShare(z((cfg.k, d)), z((cfg.k, d)))
        den = AShare(z((cfg.k,)), z((cfg.k,)))
        ctx.tag = "S3"
        mu_new = self._update_final(ctx, num, den, mu, n)
        comm = CommLog()
        comm.merge(ctx.log, phase="online")
        if cfg.tol is not None:
            ctx.tag = "CSC"
            self._converged(ctx, mu, mu_new, cfg.tol)
        return ctx.dealer.plan(), comm

    def _minibatch_slot_plans(self, shape_a, shape_b):
        """Canonical minibatch offline layout for party-input shapes — the
        single source of truth shared by `plan_fit` (bank provisioning) and
        `_fit_minibatch` (consumption), so a provisioned bank and a live fit
        can never disagree on slot order. Returns (bounds, per-batch
        [(s1_plan, s1_comm, s3_plan, s3_comm)], (fin_plan, fin_comm),
        iter_comm): per iteration the slots run [s1(b0), s3p(b0), s1(b1),
        ..., finalize]."""
        cfg = self.cfg
        na, nb = int(shape_a[0]), int(shape_b[0])
        if cfg.partition == "vertical":
            n, d = na, int(shape_a[1]) + int(shape_b[1])
        else:
            n, d = na + nb, int(shape_a[1])
        bounds = _minibatch_bounds(cfg.partition, na, nb, cfg.batch_size)
        stage_plans = []
        iter_comm = CommLog()
        for (alo, ahi), (blo, bhi) in bounds:
            sa = (ahi - alo, int(shape_a[1]))
            sb = (bhi - blo, int(shape_b[1]))
            s1_plan, s1_comm = self._plan_batch_stage(sa, sb, "s1")
            s3_plan, s3_comm = self._plan_batch_stage(sa, sb, "s3p")
            stage_plans.append((s1_plan, s1_comm, s3_plan, s3_comm))
            iter_comm.merge(s1_comm, phase="online")
            iter_comm.merge(s3_comm, phase="online")
        fin_plan, fin_comm = self._plan_finalize(d, n)
        iter_comm.merge(fin_comm, phase="online")
        return bounds, stage_plans, (fin_plan, fin_comm), iter_comm

    # ------------------------------------------------------------------ #
    # Secure scoring: batched predict/score against the secret-shared model
    # ------------------------------------------------------------------ #
    def predict(self, x_a: np.ndarray, x_b: np.ndarray,
                result: KMeansResult | None = None, *, dealer=None,
                compiled: bool | None = None, wire=None) -> PredictResult:
        """Assign a NEW batch to the fitted clusters without revealing the
        model: batched secure distances + tournament argmin against the
        secret-shared centroids; only the (m, k) assignment shares come
        back (Rec happens in `labels_plain`). Vertical: the parties hold
        the batch rows' column slices (equal row counts); horizontal: each
        party owns whole arrival rows, outputs ordered [A rows; B rows].

        `dealer` supplies the correlated randomness — default an on-demand
        `TrustedDealer(cfg.seed)`; pass a `TripleBank.dealer(...)` view to
        serve from a provisioned pool (`plan_predict` gives the bank key
        and plan). `compiled=None` auto-selects the AOT-compiled
        `predict_program` launch (vectorized, f = ring.F) and falls back to
        the eager reference otherwise; both paths are bit-exact for any
        same-seeded per-class dealer (tests/test_serve.py)."""
        return self._predict(x_a, x_b, result, dealer=dealer,
                             compiled=compiled, with_scores=False, wire=wire)

    def score(self, x_a: np.ndarray, x_b: np.ndarray,
              result: KMeansResult | None = None, *, dealer=None,
              compiled: bool | None = None, wire=None) -> PredictResult:
        """`predict` + the (m,) squared-distance-to-assigned-centroid
        shares: the tournament's winning D' value (carried for free) plus
        each party's locally-computable ||x||^2 contribution. This is the
        fraud-scoring primitive — outlier flags follow from revealing ONLY
        these scores, never centroids or per-cluster structure."""
        return self._predict(x_a, x_b, result, dealer=dealer,
                             compiled=compiled, with_scores=True, wire=wire)

    def _check_predict_args(self, x_a, x_b, result):
        cfg = self.cfg
        if result is None:
            result = getattr(self, "result_", None)
        if result is None:
            raise ValueError("predict/score needs a fitted model: call "
                             "fit() first or pass result=")
        x_a = np.asarray(x_a, np.float64)
        x_b = np.asarray(x_b, np.float64)
        d = result.centroids.shape[1]
        if cfg.partition == "vertical":
            if x_a.shape[0] != x_b.shape[0]:
                raise ValueError("vertical predict needs equal batch rows")
            if x_a.shape[1] + x_b.shape[1] != d:
                raise ValueError("predict feature split disagrees with the "
                                 f"fitted model: {x_a.shape[1]}+{x_b.shape[1]}"
                                 f" != {d}")
        else:
            if x_a.shape[1] != d or x_b.shape[1] != d:
                raise ValueError("horizontal predict rows must carry all "
                                 f"{d} model features")
        return x_a, x_b, result

    def _predict(self, x_a, x_b, result, *, dealer, compiled,
                 with_scores: bool, wire=None) -> PredictResult:
        cfg = self.cfg
        x_a, x_b, result = self._check_predict_args(x_a, x_b, result)
        if compiled:
            # an explicit request for the compiled path must not silently
            # truncate at the wrong scale or die in an obscure trace error
            if cfg.f != ring.F:
                raise ValueError(
                    f"compiled predict hardcodes f = {ring.F}; cfg.f = "
                    f"{cfg.f} must use the eager path (compiled=False)")
            if not self._traceable_backend():
                raise ValueError(
                    "the host-only numpy backend cannot lower into the "
                    "compiled predict program; use compiled=False")
        use_fast = compiled if compiled is not None \
            else (cfg.vectorized and cfg.f == ring.F
                  and self._traceable_backend())
        if use_fast:
            prep = self.predict_prepare(x_a, x_b, result, dealer=dealer,
                                        with_scores=with_scores, wire=wire)
            return self.predict_collect(prep, self.predict_launch(prep))
        with _trace.span("predict.eager", rows=int(x_a.shape[0]),
                         scores=with_scores):
            return self._predict_eager(x_a, x_b, result, dealer=dealer,
                                       with_scores=with_scores, wire=wire)

    def _predict_eager(self, x_a, x_b, result, *, dealer,
                       with_scores: bool, wire=None) -> PredictResult:
        cfg = self.cfg
        t0 = time.perf_counter()
        enc_a = _encode_np(x_a, cfg.f)
        enc_b = _encode_np(x_b, cfg.f)
        csr_a = CSRMatrix.from_dense(enc_a) if cfg.sparse else None
        csr_b = CSRMatrix.from_dense(enc_b) if cfg.sparse else None
        log = CommLog()
        log.wire = wire
        if dealer is None:
            # domain-separated from the fit's streams: reusing cfg.seed
            # verbatim would replay the fit's Beaver masks on overlapping
            # shape-classes (mask reuse on two secrets leaks their diff)
            dealer = TrustedDealer(seed=serve_seed(cfg.seed), log=log)
        ctx = P.Ctx(dealer=dealer, log=log, backend=cfg.backend)
        ctx.vectorized = cfg.vectorized
        ctx.tag = "predict"
        mu = result.centroids
        vmin = None
        dist = self._distances(ctx, enc_a, enc_b, csr_a, csr_b, mu)
        if with_scores:
            c, vmin = P.argmin_onehot(ctx, dist, return_min=True)
        else:
            c = P.argmin_onehot(ctx, dist)
        scores = None
        if with_scores:
            # ||x - mu_c||^2 = ||x||^2 + (||mu_c||^2 - 2 x.mu_c): the first
            # term is party-local plaintext (each owner encodes its slice's
            # contribution into its share — no triples, no traffic), the
            # parenthesis is the tournament's winning D' value.
            scores = P.add(vmin, self._norm_shares(x_a, x_b))
        jnp.asarray(c.s0).block_until_ready()
        return PredictResult(assignment=c, scores=scores, log=log,
                             seconds=time.perf_counter() - t0, f=cfg.f)

    # -- compiled scoring, split into pipelineable phases ---------------- #
    def predict_prepare(self, x_a, x_b, result: KMeansResult | None = None,
                        *, dealer=None, with_scores: bool = False,
                        wire=None) -> "PreparedPredict":
        """Host phase of ONE compiled scoring launch: validate, encode, run
        the Protocol-2 pre-launch exchange (computable from the centroid
        shares alone), draw the offline tranche, stage the program
        arguments. `predict_launch` dispatches (async under jax) and
        `predict_collect` assembles the PredictResult; prepare -> launch ->
        collect in sequence IS the compiled predict path, and the serving
        loop overlaps request t+1's prepare with request t's in-flight
        launch (launch/pipeline.py) — same calls, same order per request,
        so the pipelined and sequential drains are stream-identical."""
        cfg = self.cfg
        x_a, x_b, result = self._check_predict_args(x_a, x_b, result)
        if not (cfg.vectorized and cfg.f == ring.F
                and self._traceable_backend()):
            raise ValueError(
                "predict_prepare stages the compiled scoring program only; "
                "non-default f / unvectorized / numpy-backend configs must "
                "score through predict/score (eager path)")
        from repro.launch import kmeans_step as K
        with _trace.span("predict.prepare", rows=int(x_a.shape[0]),
                         scores=with_scores):
            t0 = time.perf_counter()
            enc_a = _encode_np(x_a, cfg.f)
            enc_b = _encode_np(x_b, cfg.f)
            csr_a = CSRMatrix.from_dense(enc_a) if cfg.sparse else None
            csr_b = CSRMatrix.from_dense(enc_b) if cfg.sparse else None
            log = CommLog()
            log.wire = wire
            if dealer is None:
                # domain-separated from the fit's streams (see _predict)
                dealer = TrustedDealer(seed=serve_seed(cfg.seed), log=log)
            ctx = P.Ctx(dealer=dealer, log=log, backend=cfg.backend)
            ctx.vectorized = cfg.vectorized
            ctx.tag = "predict"
            mu = result.centroids
            prog = K.predict_program(cfg.partition, cfg.sparse,
                                     enc_a.shape, enc_b.shape, cfg.k,
                                     with_scores=with_scores,
                                     backend=cfg.backend)
            _, comm = self._plan_predict_cached(x_a.shape, x_b.shape,
                                                with_scores)
            he1 = []
            if cfg.sparse:
                # scratch log (Ctx.fork): the launch's shape-determined
                # traffic — the exchange's included — replays from the
                # traced plan's CommLog at collect time
                hx = ctx.fork(tag="predict")
                he1 = self._s1_he_inputs(hx, enc_a, enc_b, csr_a, csr_b, mu)
            flat = K.materialize_offline(prog.requests, ctx.dealer)
            args = (jnp.asarray(enc_a), jnp.asarray(enc_b), mu.s0, mu.s1,
                    *he1, *flat)
            return PreparedPredict(prog=prog, args=args, log=log, comm=comm,
                                   with_scores=with_scores, x_a=x_a,
                                   x_b=x_b, t0=t0)

    def predict_launch(self, prep: "PreparedPredict"):
        """Dispatch the staged scoring program — asynchronous under jax:
        the raw output buffers come back immediately while the device
        computes."""
        with _trace.span("predict.launch"):
            return prep.prog.fn(*prep.args)

    def predict_collect(self, prep: "PreparedPredict",
                        outs) -> PredictResult:
        """Reveal-side assembly of one launch's outputs (blocks on the
        device): assignment shares, optional score shares (winning D' +
        locally-encoded ||x||^2), replayed traffic tallies."""
        with _trace.span("predict.collect"):
            c = AShare(outs[0], outs[1])
            scores = None
            if prep.with_scores:
                vmin = AShare(outs[2], outs[3])
                scores = P.add(vmin, self._norm_shares(prep.x_a, prep.x_b))
            prep.log.merge(prep.comm, phase="online")
            jnp.asarray(c.s0).block_until_ready()
            return PredictResult(assignment=c, scores=scores, log=prep.log,
                                 seconds=time.perf_counter() - prep.t0,
                                 f=self.cfg.f)

    def _traceable_backend(self) -> bool:
        """The numpy ring backend runs host-side and cannot lower into the
        compiled fast paths; eager loops serve it (bit-exact either way)."""
        from repro.core.backend import get_backend
        return get_backend(self.cfg.backend).name != "numpy"

    def _norm_shares(self, x_a, x_b) -> AShare:
        """(m,) shares of ||x||^2 at scale f from party-local plaintext.
        Vertical: A's columns land in s0, B's in s1. Horizontal: the owner
        of each row holds its whole norm (A rows -> s0, B rows -> s1)."""
        cfg = self.cfg
        na = _encode_np((x_a ** 2).sum(1), cfg.f)
        nb = _encode_np((x_b ** 2).sum(1), cfg.f)
        if cfg.partition == "vertical":
            return AShare(jnp.asarray(na), jnp.asarray(nb))
        za = np.zeros_like(na)
        zb = np.zeros_like(nb)
        return AShare(jnp.asarray(np.concatenate([na, zb])),
                      jnp.asarray(np.concatenate([za, nb])))

    # ------------------------------------------------------------------ #
    def plan_predict(self, shape_a, shape_b,
                     with_scores: bool = False) -> tuple:
        """(bank_key, TriplePlan, CommLog) of ONE scoring launch for
        party-input batch shapes — without seeing any data. The plan is the
        exact correlated-randomness schedule a `predict`/`score` call of
        these shapes consumes (Protocol-2 mask seeds included); the key is
        the predict-plan cache key, which `TripleBank.provision` uses as
        the pool lookup key. Cached: a service scoring thousands of batches
        traces each geometry once."""
        key = self._predict_plan_key(shape_a, shape_b, with_scores)
        plan, comm = self._plan_predict_cached(shape_a, shape_b, with_scores)
        return key, plan, comm

    def _predict_plan_key(self, shape_a, shape_b, with_scores) -> tuple:
        return ("predict", bool(with_scores)) \
            + self._plan_cache_key(shape_a, shape_b)

    def _plan_predict_cached(self, shape_a, shape_b, with_scores):
        key = self._predict_plan_key(shape_a, shape_b, with_scores)
        hit = _PREDICT_PLAN_CACHE.get(key)
        if hit is None:
            hit = _PREDICT_PLAN_CACHE[key] = self._trace_predict(
                shape_a, shape_b, with_scores)
        plan, comm = hit
        return TriplePlan(list(plan.requests)), comm.copy()

    def _trace_predict(self, shape_a, shape_b, with_scores):
        """Dry-run trace of one scoring launch (distances + argmin) with a
        PlanningDealer on zero-filled inputs — the predict counterpart of
        `_trace_iteration`."""
        cfg = self.cfg
        ctx = P.Ctx(dealer=PlanningDealer(), log=CommLog(),
                    backend=cfg.backend)
        ctx.vectorized = cfg.vectorized
        ctx.tag = "predict"
        enc_a = np.zeros(tuple(shape_a), np.uint64)
        enc_b = np.zeros(tuple(shape_b), np.uint64)
        d = enc_a.shape[1] + enc_b.shape[1] if cfg.partition == "vertical" \
            else enc_a.shape[1]
        csr_a = CSRMatrix.from_dense(enc_a) if cfg.sparse else None
        csr_b = CSRMatrix.from_dense(enc_b) if cfg.sparse else None
        mu = AShare(jnp.zeros((cfg.k, d), ring.DTYPE),
                    jnp.zeros((cfg.k, d), ring.DTYPE))
        dist = self._distances(ctx, enc_a, enc_b, csr_a, csr_b, mu)
        P.argmin_onehot(ctx, dist, return_min=with_scores)
        comm = CommLog()
        comm.merge(ctx.log, phase="online")
        return ctx.dealer.plan(), comm

    # ------------------------------------------------------------------ #
    def plan_offline(self, shape_a, shape_b) -> TriplePlan:
        """Derive the exact correlated-randomness schedule of `fit` for
        party-input shapes (shape_a, shape_b) — without seeing any data.

        One Lloyd iteration (+ the CSC check when `tol` is set) is traced
        eagerly on zero-filled inputs with a `PlanningDealer`; every triple
        shape is data-independent, so the full-fit schedule is that trace
        repeated `iters` times. A `tol` early-stop only leaves pool surplus.
        The trace runs the real protocol ops, so it also warms the backend's
        kernel caches with exactly the online shapes — offline work again.
        """
        return self._plan_offline_iter(shape_a, shape_b)[0] \
            .repeat(self.cfg.iters)

    def plan_fit(self, shape_a, shape_b) -> tuple:
        """(bank_key, TriplePlan, CommLog) of a WHOLE fit for party-input
        shapes — the fit-side counterpart of `plan_predict`. The plan is the
        exact correlated-randomness schedule `fit` consumes (full-batch:
        the iteration plan repeated `iters` times; minibatch: the canonical
        slot-plan sequence, concatenated), Protocol-2 mask seeds included;
        the key is the fit-plan cache key extended with the loop geometry
        (iters, batch_size), which `TripleBank.provision` uses as the pool
        lookup key. Provision a bank under the fit's `cfg.seed`, then call
        `fit(..., dealer=bank.dealer(key))`: the fit runs with zero
        generation work and bit-exact shares/counters/CommLog vs the
        built-in dealers. The returned CommLog carries ONE iteration's
        online traffic (informational — provisioning needs only the plan)."""
        cfg = self.cfg
        key = self._fit_plan_key(shape_a, shape_b)
        if cfg.batch_size is None:
            iter_plan, iter_comm = self._plan_offline_iter(shape_a, shape_b)
            return key, iter_plan.repeat(cfg.iters), iter_comm
        _bounds, stage_plans, (fin_plan, _fc), iter_comm = \
            self._minibatch_slot_plans(shape_a, shape_b)
        iter_reqs = [r for (s1, _c1, s3, _c3) in stage_plans
                     for r in list(s1.requests) + list(s3.requests)]
        iter_reqs += list(fin_plan.requests)
        return key, TriplePlan(iter_reqs * cfg.iters), iter_comm

    def _fit_plan_key(self, shape_a, shape_b) -> tuple:
        return ("fit", self.cfg.iters, self.cfg.batch_size) \
            + self._plan_cache_key(shape_a, shape_b)

    def _fit_fingerprint(self, shape_a, shape_b) -> str:
        """Checkpoint identity: everything that shapes the fit's schedule,
        streams, and init — a resumed run with ANY of these changed would
        not be the same fit. `pipeline` is deliberately excluded: the
        executors are stream-identical at checkpointable cuts."""
        from repro.checkpoint.store import config_fingerprint
        cfg = self.cfg
        key = self._fit_plan_key(shape_a, shape_b) + (
            "fit-ckpt", cfg.seed, cfg.offline, cfg.init, cfg.tol)
        return config_fingerprint(key)

    def _save_fit_ckpt(self, ckpt, ctx, it: int, batch: int, mu: AShare,
                       advance: dict, acc=None, c_parts=None) -> None:
        from repro.checkpoint.fit import FitState
        d = ctx.dealer
        ckpt.save(FitState(
            iteration=it, batch=batch,
            mu0=np.asarray(mu.s0, np.uint64),
            mu1=np.asarray(mu.s1, np.uint64),
            counters={"n_matmul": int(d.n_matmul), "n_mul": int(d.n_mul),
                      "n_bin": int(d.n_bin)},
            comm=ctx.log.state(), advance=advance,
            fingerprint=ckpt.fingerprint,
            acc=None if acc is None else [np.asarray(a, np.uint64)
                                          for a in acc],
            c0_parts=[np.asarray(p.s0, np.uint64) for p in (c_parts or [])],
            c1_parts=[np.asarray(p.s1, np.uint64) for p in (c_parts or [])]))

    def _plan_cache_key(self, shape_a, shape_b) -> tuple:
        cfg = self.cfg
        key = (tuple(shape_a), tuple(shape_b), cfg.k, cfg.partition,
               cfg.sparse, cfg.vectorized, cfg.f, cfg.tol is not None)
        if cfg.sparse:
            # the HE backend's sizes shape Protocol 2's logged traffic
            he = self.he
            key += (getattr(he, "name", type(he).__name__),
                    getattr(he, "ct_bytes", 0), getattr(he, "plain_bits", 0))
        return key

    def _plan_offline_iter(self, shape_a, shape_b):
        """(iter_plan, iter_comm): ONE iteration's TriplePlan plus a CommLog
        of its online traffic (S1/S2/S3, sans CSC) — the tallies the
        compiled fast path replays per launch. Cached across fits by
        (shapes, config key): the dry-run trace dominated the offline phase
        (6.8 of 7.6 s at the reference fit), so a second same-shape fit
        must not pay it again. Returns defensive copies; cached state is
        never handed out mutable."""
        key = self._plan_cache_key(shape_a, shape_b)
        hit = _PLAN_CACHE.get(key)
        if hit is None:
            hit = _PLAN_CACHE[key] = self._trace_iteration(shape_a, shape_b)
        plan, comm = hit
        return TriplePlan(list(plan.requests)), comm.copy()

    def _trace_iteration(self, shape_a, shape_b):
        """Dry-run trace of one Lloyd iteration (+CSC when tol is set) with
        a PlanningDealer on zero-filled inputs."""
        cfg = self.cfg
        ctx = P.Ctx(dealer=PlanningDealer(), log=CommLog(),
                    backend=cfg.backend)
        ctx.vectorized = cfg.vectorized
        enc_a = np.zeros(tuple(shape_a), np.uint64)
        enc_b = np.zeros(tuple(shape_b), np.uint64)
        n = enc_a.shape[0] if cfg.partition == "vertical" \
            else enc_a.shape[0] + enc_b.shape[0]
        d = enc_a.shape[1] + enc_b.shape[1] if cfg.partition == "vertical" \
            else enc_a.shape[1]
        csr_a = CSRMatrix.from_dense(enc_a) if cfg.sparse else None
        csr_b = CSRMatrix.from_dense(enc_b) if cfg.sparse else None
        mu = AShare(jnp.zeros((cfg.k, d), ring.DTYPE),
                    jnp.zeros((cfg.k, d), ring.DTYPE))
        ctx.tag = "S1"
        dist = self._distances(ctx, enc_a, enc_b, csr_a, csr_b, mu)
        ctx.tag = "S2"
        c = P.argmin_onehot(ctx, dist)
        ctx.tag = "S3"
        mu_new = self._update(ctx, enc_a, enc_b, csr_a, csr_b, c, mu, n)
        iter_comm = CommLog()
        iter_comm.merge(ctx.log, phase="online")
        if cfg.tol is not None:
            ctx.tag = "CSC"
            self._converged(ctx, mu, mu_new, cfg.tol)
        return ctx.dealer.plan(), iter_comm

    # ------------------------------------------------------------------ #
    def _init_centroids(self, ctx, rng, x_a, x_b) -> AShare:
        """Jointly negotiated random sample indexes (paper Sec 4.2); each
        party secret-shares its slice of the chosen rows."""
        cfg = self.cfg
        if cfg.partition == "vertical":
            n = x_a.shape[0]
            idx = rng.choice(n, cfg.k, replace=False)
            mu_a = _encode_np(x_a[idx], cfg.f)        # A shares its columns
            mu_b = _encode_np(x_b[idx], cfg.f)
            sh = _share_cat(ctx, rng, [mu_a, mu_b], axis=1)
        else:
            n = x_a.shape[0] + x_b.shape[0]
            idx = rng.choice(n, cfg.k, replace=False)
            mask = idx < x_a.shape[0]
            rows_a = _encode_np(x_a[idx[mask]], cfg.f)
            rows_b = _encode_np(x_b[idx[~mask] - x_a.shape[0]], cfg.f)
            sh = _share_cat(ctx, rng, [rows_a, rows_b], axis=0)
            # restore the jointly-negotiated index order (A rows then B rows
            # were concatenated; undo that permutation)
            perm = np.concatenate([np.where(mask)[0], np.where(~mask)[0]])
            inv = np.argsort(perm)
            sh = AShare(sh.s0[inv], sh.s1[inv])
        ctx.log.send(2 * ring.nbytes(sh.shape), tag="init", phase="online")
        return sh

    # ------------------------------------------------------------------ #
    def _distances(self, ctx, enc_a, enc_b, csr_a, csr_b, mu: AShare) -> AShare:
        """F_ESD: D' = U - 2 X mu^T at scale f (one final truncation)."""
        cfg = self.cfg
        k = cfg.k
        # U_j = ||mu_j||^2 : one batched SMUL + row-sum  (scale 2f)
        mu_sq = P.smul(ctx, mu, mu)
        u = AShare(mu_sq.s0.sum(1), mu_sq.s1.sum(1))          # (k,)
        if not cfg.vectorized:
            _naive_extra_rounds(ctx, k * mu.shape[1])
        xmu = self._x_mut(ctx, enc_a, enc_b, csr_a, csr_b, mu)  # (n,k) 2f
        d2 = P.sub(AShare(u.s0[None, :], u.s1[None, :]), P.lshift(xmu, 1))
        return P.trunc(d2, cfg.f)

    def _x_mut(self, ctx, enc_a, enc_b, csr_a, csr_b, mu: AShare) -> AShare:
        """X @ mu^T as shares, splitting local vs joint blocks (Eq. 4/5)."""
        cfg = self.cfg
        mm = ctx.backend.ring_mm
        mut = AShare(mu.s0.T, mu.s1.T)                        # (d, k)
        j1, j2 = self._joint_x_mut(ctx, enc_a, enc_b, csr_a, csr_b, mut)
        if cfg.partition == "vertical":
            da = enc_a.shape[1]
            # local: A's data x A's share slice; B's data x B's share slice
            loc_a = mm(jnp.asarray(enc_a), mut.s0[:da])
            loc_b = mm(jnp.asarray(enc_b), mut.s1[da:])
            return AShare(loc_a + j1.s0 + j2.s0, loc_b + j1.s1 + j2.s1)
        # horizontal: rows split; each party's rows hit BOTH mu shares
        loc_a = mm(jnp.asarray(enc_a), mut.s0)                # A x own share
        loc_b = mm(jnp.asarray(enc_b), mut.s1)
        top = AShare(loc_a + j1.s0, j1.s1)
        bot = AShare(j2.s0, loc_b + j2.s1)
        return AShare(jnp.concatenate([top.s0, bot.s0], 0),
                      jnp.concatenate([top.s1, bot.s1], 0))

    def _joint_x_mut(self, ctx, enc_a, enc_b, csr_a, csr_b,
                     mut: AShare) -> tuple:
        """The two JOINT blocks of X mu^T — A's data x B's share slice and
        vice versa (vertical: column slices of mu^T; horizontal: each
        party's rows x the other's full share). ONE implementation shared
        by the eager `_x_mut` and the fast path's pre-S1 host exchange, so
        both consume the dealer streams identically (the S1 counterpart of
        `_joint_share_times_pub`)."""
        cfg = self.cfg
        if cfg.partition == "vertical":
            da = enc_a.shape[1]
            j1 = self._pub_times_share(ctx, enc_a, csr_a,
                                       AShare(jnp.zeros_like(mut.s1[:da]),
                                              mut.s1[:da]), owner="A")
            j2 = self._pub_times_share(ctx, enc_b, csr_b,
                                       AShare(mut.s0[da:],
                                              jnp.zeros_like(mut.s0[da:])),
                                       owner="B")
            return j1, j2
        j1 = self._pub_times_share(ctx, enc_a, csr_a,
                                   AShare(jnp.zeros_like(mut.s1), mut.s1),
                                   owner="A")                  # A x B's share
        j2 = self._pub_times_share(ctx, enc_b, csr_b,
                                   AShare(mut.s0, jnp.zeros_like(mut.s0)),
                                   owner="B")                  # B x A's share
        return j1, j2

    def _pub_times_share(self, ctx, enc, csr, other_share: AShare,
                         owner: str) -> AShare:
        """One party's plaintext matrix x the OTHER party's share matrix.

        Dense path: Beaver matmul with the plaintext embedded as a degenerate
        share (this is what ships X-sized masked matrices).
        Sparse path: Protocol 2 — nnz-proportional HE compute, X never moves.
        """
        cfg = self.cfg
        if cfg.sparse:
            b_mat = np.asarray(other_share.s1 if owner == "A" else other_share.s0)
            z = secure_sparse_matmul(ctx, csr, b_mat, self.he,
                                     time_model=OU_COST_S)
            return z if owner == "A" else AShare(z.s1, z.s0)
        pub = AShare(jnp.asarray(enc), jnp.zeros_like(jnp.asarray(enc))) \
            if owner == "A" else \
            AShare(jnp.zeros_like(jnp.asarray(enc)), jnp.asarray(enc))
        out = P.smatmul(ctx, pub, other_share)
        if not cfg.vectorized:
            _naive_extra_rounds(ctx, enc.shape[0] * other_share.shape[1])
        return out

    # ------------------------------------------------------------------ #
    def _update(self, ctx, enc_a, enc_b, csr_a, csr_b, c: AShare,
                mu_old: AShare, n: int) -> AShare:
        """F_SCU: mu = C^T X / 1^T C with empty-cluster MUX guard."""
        num = self._ct_x(ctx, enc_a, enc_b, csr_a, csr_b, c)   # (k, d) scale f
        den = AShare(c.s0.sum(0), c.s1.sum(0))                 # (k,) scale 1
        return self._update_final(ctx, num, den, mu_old, n)

    def _update_final(self, ctx, num: AShare, den: AShare, mu_old: AShare,
                      n: int) -> AShare:
        """The S3 tail on (possibly cross-batch accumulated) sums: empty-
        cluster guard + balanced-split division + MUX. ONE implementation
        shared by the eager loop and the minibatch finalize trace, so both
        consume the dealer streams identically (kmeans_step._s3_final_body
        compiles the same algebra)."""
        cfg = self.cfg
        k = cfg.k
        one = AShare(jnp.full((k,), 1, ring.DTYPE), jnp.zeros((k,), ring.DTYPE))
        is_empty = P.cmp_lt(ctx, den, one)                     # [den < 1]
        den_safe = P.mux(ctx, is_empty, one, den)
        # Balanced-split division (see DESIGN.md numerics note): computing
        # num * (2^f/den) naively either loses den*2^-f relative precision
        # (plain reciprocal) or pushes the pre-truncation product to
        # ~2^(2f+m) bits, where SecureML local truncation fails with
        # probability 2^(bits+1-l) — at m=12 that is 2^-7 PER ELEMENT with
        # a +-2^(l-t) error (observed!). Split the 2^m rescale: shift num
        # down by s=m//2 and keep 2^s/den in the reciprocal; the product is
        # (num/2^s)*(2^s/den) = mean at ~2^(2f+4) bits -> failure 2^-19,
        # absolute error <= 2^(m-s)*|x|*2^-f ~ 1e-3.
        m = int(np.ceil(np.log2(max(2, n))))
        s = m // 2
        num_s = P.trunc(num, s)
        r = P.reciprocal(ctx, den_safe, max_den=n, f=cfg.f, extra_bits=s)
        mu_new = P.smul(ctx, num_s, AShare(r.s0[:, None], r.s1[:, None]),
                        trunc_f=cfg.f)
        guard = AShare(is_empty.s0[:, None], is_empty.s1[:, None])
        return P.mux(ctx, guard, mu_old, mu_new)

    def _ct_x(self, ctx, enc_a, enc_b, csr_a, csr_b, c: AShare) -> AShare:
        """C^T X -> (k, d) shares at scale f (C is scale-1 one-hot)."""
        cfg = self.cfg
        ct = AShare(c.s0.T, c.s1.T)                            # (k, n)
        if cfg.partition == "vertical":
            # [C^T X_A | C^T X_B]; each block: share x one party's plaintext
            za = self._share_times_pub(ctx, ct, enc_a, csr_a, owner="A")
            zb = self._share_times_pub(ctx, ct, enc_b, csr_b, owner="B")
            return AShare(jnp.concatenate([za.s0, zb.s0], 1),
                          jnp.concatenate([za.s1, zb.s1], 1))
        na = enc_a.shape[0]
        ct_a = AShare(ct.s0[:, :na], ct.s1[:, :na])
        ct_b = AShare(ct.s0[:, na:], ct.s1[:, na:])
        za = self._share_times_pub(ctx, ct_a, enc_a, csr_a, owner="A")
        zb = self._share_times_pub(ctx, ct_b, enc_b, csr_b, owner="B")
        return P.add(za, zb)

    def _share_times_pub(self, ctx, ct: AShare, enc, csr, owner: str) -> AShare:
        """<C>^T @ X_owner: the owner's share-product is local; the other
        party's requires a joint product (Beaver dense / Protocol 2 sparse,
        via the transpose identity <C>_other^T X = (X^T <C>_other)^T)."""
        cfg = self.cfg
        mm = ctx.backend.ring_mm
        x = jnp.asarray(enc)
        if owner == "A":
            local = mm(ct.s0, x)                               # A local
            if cfg.sparse:
                joint = self._joint_share_times_pub(ctx, ct, csr.transpose(),
                                                    owner="A")
            else:
                joint = P.smatmul(ctx, AShare(jnp.zeros_like(ct.s1), ct.s1),
                                  AShare(x, jnp.zeros_like(x)))
                if not cfg.vectorized:
                    _naive_extra_rounds(ctx, ct.shape[0] * x.shape[1])
            return AShare(local + joint.s0, joint.s1)
        local = mm(ct.s1, x)                                   # B local
        if cfg.sparse:
            joint = self._joint_share_times_pub(ctx, ct, csr.transpose(),
                                                owner="B")
        else:
            joint = P.smatmul(ctx, AShare(ct.s0, jnp.zeros_like(ct.s0)),
                              AShare(jnp.zeros_like(x), x))
            if not cfg.vectorized:
                _naive_extra_rounds(ctx, ct.shape[0] * x.shape[1])
        return AShare(joint.s0, local + joint.s1)

    def _joint_share_times_pub(self, ctx, ct: AShare, csr_t: CSRMatrix,
                               owner: str) -> AShare:
        """The sparse joint block of <C>^T X_owner: Protocol 2 on the pre-
        transposed CSR (transpose identity). ONE implementation shared by
        the eager loop and the fast path's mid-iteration host callback, so
        both consume the owner's dealer mask-seed stream identically —
        that's what makes the split-launch path bit-exact."""
        if owner == "A":
            z = secure_sparse_matmul(ctx, csr_t, np.asarray(ct.s1.T),
                                     self.he, time_model=OU_COST_S)
            return AShare(z.s0.T, z.s1.T)
        z = secure_sparse_matmul(ctx, csr_t, np.asarray(ct.s0.T), self.he,
                                 time_model=OU_COST_S)
        return AShare(z.s1.T, z.s0.T)

    # -- Protocol-2 host exchanges for the split-launch fast path -------- #
    def _s1_he_inputs(self, ctx, enc_a, enc_b, csr_a, csr_b,
                      mu: AShare) -> list:
        """Host exchange #1 (pre-S1): the distance-phase joint products of
        X mu^T, computable from the centroid shares alone. Returns the flat
        [s0, s1, ...] share list the S1 program takes as inputs, in the
        FitGeometry.he_shapes_s1 order."""
        mut = AShare(mu.s0.T, mu.s1.T)
        j1, j2 = self._joint_x_mut(ctx, enc_a, enc_b, csr_a, csr_b, mut)
        return [t for h in (j1, j2) for t in (h.s0, h.s1)]

    def _s3_he_inputs(self, ctx, csr_at, csr_bt, c: AShare) -> list:
        """Host exchange #2 (the S2 callback, post-S1): the update-phase
        joint products of C^T X on the assignment shares the S1 launch just
        produced. Flat share list in FitGeometry.he_shapes_s3 order."""
        cfg = self.cfg
        ct = AShare(c.s0.T, c.s1.T)
        if cfg.partition == "vertical":
            ja = self._joint_share_times_pub(ctx, ct, csr_at, owner="A")
            jb = self._joint_share_times_pub(ctx, ct, csr_bt, owner="B")
        else:
            na = csr_at.shape[1]                 # csr_at is X_A^T: (d, na)
            ct_a = AShare(ct.s0[:, :na], ct.s1[:, :na])
            ct_b = AShare(ct.s0[:, na:], ct.s1[:, na:])
            ja = self._joint_share_times_pub(ctx, ct_a, csr_at, owner="A")
            jb = self._joint_share_times_pub(ctx, ct_b, csr_bt, owner="B")
        return [t for h in (ja, jb) for t in (h.s0, h.s1)]

    # ------------------------------------------------------------------ #
    def _converged(self, ctx, mu_old: AShare, mu_new: AShare, tol: float) -> bool:
        """F_CSC: reveal only CMP(ESD(mu_t, mu_t+1), eps)."""
        diff = P.sub(mu_new, mu_old)
        sq = P.smul(ctx, diff, diff)                           # scale 2f
        tot = AShare(sq.s0.sum(), sq.s1.sum())
        eps = ring.encode(tol, 2 * self.cfg.f).reshape(())
        bit = P.cmp_lt(ctx, tot, AShare(eps, jnp.zeros((), ring.DTYPE)))
        ctx.log.send(8, tag="CSC", phase="online")             # reveal stop bit
        return bool(np.asarray(rec(bit)) == 1)


# ---------------------------------------------------------------------------
# Plaintext oracle (same init, same ESD criterion) + fraud-detection utils
# ---------------------------------------------------------------------------

def plaintext_kmeans(x: np.ndarray, k: int, iters: int, seed: int = 0,
                     tol: float | None = None):
    """Float Lloyd with the same joint-random-row init as SecureKMeans."""
    rng = np.random.default_rng(seed)
    idx = rng.choice(x.shape[0], k, replace=False)
    mu = x[idx].copy()
    labels = np.zeros(x.shape[0], np.int64)
    for _ in range(iters):
        d = (mu ** 2).sum(1)[None, :] - 2 * x @ mu.T           # same D'
        labels = d.argmin(1)
        mu_old = mu.copy()
        for j in range(k):
            m = labels == j
            if m.any():
                mu[j] = x[m].mean(0)
        if tol is not None and ((mu - mu_old) ** 2).sum() < tol:
            break
    return mu, labels


def _minibatch_bounds(partition: str, na: int, nb: int,
                      batch_size: int) -> list:
    """Per-batch row windows [((a_lo, a_hi), (b_lo, b_hi)), ...].

    Vertical: both parties hold column slices of the SAME rows, so batches
    are shared contiguous chunks of `batch_size` rows — at most two
    distinct shapes (full + remainder). Horizontal: each party's rows are
    split into the same NUMBER of contiguous near-equal chunks
    (B = ceil((na+nb)/batch_size), clamped so no chunk is empty); chunk
    sizes differ by at most one per party, so a fit compiles at most a
    handful of batch geometries regardless of batch count."""
    if partition == "vertical":
        bs = max(1, min(int(batch_size), na))
        return [((lo, min(lo + bs, na)),) * 2 for lo in range(0, na, bs)]
    n_batches = max(1, min(-(-(na + nb) // int(batch_size)), na, nb))
    return list(zip(_even_chunks(na, n_batches),
                    _even_chunks(nb, n_batches)))


def _even_chunks(n: int, parts: int) -> list:
    """Exactly `parts` contiguous windows over n rows, sizes q+1 x r then
    q x (parts - r) — never empty for parts <= n."""
    q, r = divmod(n, parts)
    out, lo = [], 0
    for i in range(parts):
        hi = lo + q + (1 if i < r else 0)
        out.append((lo, hi))
        lo = hi
    return out


def _assemble_assignment(partition: str, c_parts: list,
                         batches: list) -> AShare:
    """Stitch the last iteration's per-batch assignment shares back into
    the full-fit (n, k) layout: vertical concatenates rows in batch order;
    horizontal restores the [all A rows; all B rows] order the full-batch
    path produces (each batch's rows come back [A chunk; B chunk])."""
    if partition == "vertical":
        return AShare(jnp.concatenate([p.s0 for p in c_parts], 0),
                      jnp.concatenate([p.s1 for p in c_parts], 0))
    a0 = [p.s0[:b["a_rows"]] for p, b in zip(c_parts, batches)]
    a1 = [p.s1[:b["a_rows"]] for p, b in zip(c_parts, batches)]
    b0 = [p.s0[b["a_rows"]:] for p, b in zip(c_parts, batches)]
    b1 = [p.s1[b["a_rows"]:] for p, b in zip(c_parts, batches)]
    return AShare(jnp.concatenate(a0 + b0, 0), jnp.concatenate(a1 + b1, 0))


def _encode_np(x: np.ndarray, f: int) -> np.ndarray:
    return np.round(np.asarray(x, np.float64) * (1 << f)) \
        .astype(np.int64).astype(np.uint64)


def _share_cat(ctx, rng, mats, axis):
    parts = [share(m, rng) for m in mats]
    return AShare(jnp.concatenate([p.s0 for p in parts], axis),
                  jnp.concatenate([p.s1 for p in parts], axis))


def _naive_extra_rounds(ctx, n_interactions: int) -> None:
    """Pre-vectorization accounting: the same payload would be shipped in
    `n_interactions` round-trips instead of 1 (paper Sec 4.2). Bytes are
    already logged by the vectorized op; only rounds differ (+ per-message
    framing overhead which we ignore, making the naive baseline *favorable*)."""
    ctx.log.send(0, tag=ctx.tag, phase="online", rounds=int(n_interactions) - 1)
