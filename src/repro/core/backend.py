"""Pluggable ring-compute backend — the local hot ops of the online path.

Every *local* ring linear-algebra operation the 2PC protocols consume is
funnelled through one small dispatch interface (DESIGN.md §7):

  * ``ring_mm``       — uint64 matmul mod 2^64 (every Beaver recombination,
                        every public-x-share product, every C^T X block).
  * ``ring_spmm``     — blocked-ELL sparse x dense over the ring (the
                        nnz-proportional step-2 compute of Protocol 2).
  * ``ks_fused``      — one party's fused Kogge-Stone recombination: all
                        7 AND levels of the secure-adder MSB collapsed into a
                        single local pass given the exchanged masked operands.

Three implementations:

  * ``xla``    — pure jnp (the seed behaviour; fallback and bit-exact oracle).
  * ``pallas`` — the purpose-built kernels in ``repro.kernels`` (interpret
                 mode on CPU, real lowering on TPU).
  * ``numpy``  — host-side, for the offline dealer in ``core/triples.py``
                 and Protocol 2's host-resident sparse data.

Selection: ``get_backend("auto")`` picks ``pallas`` when a TPU is attached
and ``xla`` otherwise; ``KMeansConfig.backend`` / ``Ctx.backend`` carry the
choice through the protocol stack, so the pjit'd production path in
``launch/kmeans_step`` and the simulated path in ``core/kmeans`` execute the
same dispatch. All implementations are bit-exact in Z_{2^64}: the parity
tests assert equality, not closeness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ring
# The level schedule and Beaver AND recombination have ONE canonical source
# (the kernel module): the xla/numpy paths below must stay bit-identical to
# the pallas kernel for the backend parity guarantee to hold.
from repro.kernels.ksadder import LEVELS as KS_LEVELS
from repro.kernels.ksadder import _and_share


def _ks_fused_generic(x, e0, f0, u0, v0, z0, el, fl, ul, vl, zl,
                      party0: bool):
    """Fused local Kogge-Stone recombination (works on jnp and np arrays).

    Level 0 is the initial g = AND(x, y) triple; levels 1..6 are the
    stacked (g, p) AND pairs. All e/f are the publicly reconstructed masked
    operands. Returns this party's share of the carry word G.

    Only the g-chain is recombined: the per-level p *shares* feed nothing
    locally (the next level's public masks are part of the transcript), so
    the slot-1 operands — and `x`, the party's p0-share — are accepted for
    interface parity with the kernel but not computed on. The slot-1
    triples are still drawn and exchanged by msb_carry: they mask the
    public transcript itself.
    """
    g = _and_share(e0, f0, u0, v0, z0, party0)
    for li in range(len(KS_LEVELS)):
        g = g ^ _and_share(el[li, 0], fl[li, 0], ul[li, 0], vl[li, 0],
                           zl[li, 0], party0)
    return g


def _csr_spmm_chunked(csr, y):
    """Host-side CSR x dense mod 2^64: gather-multiply-scatter, chunked so
    the intermediate stays O(chunk * k) regardless of sparsity skew."""
    y = np.asarray(y, ring.NP_DTYPE)
    n = csr.shape[0]
    z = np.zeros((n, y.shape[1]), ring.NP_DTYPE)
    rows = np.repeat(np.arange(n), np.diff(csr.indptr))
    chunk = 1 << 22
    for lo in range(0, csr.nnz, chunk):
        hi = min(csr.nnz, lo + chunk)
        contrib = csr.data[lo:hi, None] * y[csr.indices[lo:hi]]
        np.add.at(z, rows[lo:hi], contrib)
    return z


class RingBackend:
    """Dispatch interface for the local ring ops on the online hot path."""

    name = "base"

    def ring_mm(self, a, b):
        """(n, d) @ (d, k) mod 2^64."""
        raise NotImplementedError

    def ring_spmm(self, blocks, idx, counts, y):
        """Blocked-ELL sparse x dense over the ring -> (nrb*bm, k)."""
        raise NotImplementedError

    def ring_spmm_csr(self, csr, y, *, bm: int = 8, bk: int = 128):
        """CSR sparse x dense mod 2^64 -> (n, k) via the blocked-ELL op.

        The ELL layout pads every row block to the max tile count, so a
        skewed matrix (one dense row block) costs O(nrb * maxb) — inherent
        to ELL and acceptable when the tiles feed an accelerator kernel.
        Host-only backends override this with the chunked CSR loop.
        """
        from repro.kernels.spmm import csr_to_ell
        blocks, idx, counts = csr_to_ell(csr.indptr, csr.indices, csr.data,
                                         csr.shape, bm=bm, bk=bk)
        y = np.asarray(y, ring.NP_DTYPE)
        pad = (-y.shape[0]) % bk
        if pad:
            y = np.pad(y, ((0, pad), (0, 0)))
        out = self.ring_spmm(blocks, idx, counts, y)
        return out[: csr.shape[0]]

    def ks_fused(self, x, e0, f0, u0, v0, z0, el, fl, ul, vl, zl, *,
                 party0: bool):
        """One party's fused 7-level Kogge-Stone local recombination."""
        raise NotImplementedError


class XlaBackend(RingBackend):
    """Pure-jnp implementation — the seed behaviour and the parity oracle."""

    name = "xla"

    def ring_mm(self, a, b):
        return jnp.matmul(jnp.asarray(a, ring.DTYPE),
                          jnp.asarray(b, ring.DTYPE))

    def ring_spmm(self, blocks, idx, counts, y):
        blocks = jnp.asarray(blocks, ring.DTYPE)
        y = jnp.asarray(y, ring.DTYPE)
        nrb, maxb, bm, bk = blocks.shape
        k = y.shape[1]
        y_tiles = y.reshape(-1, bk, k)[jnp.asarray(idx)]    # (nrb, maxb, bk, k)
        contrib = jnp.matmul(blocks, y_tiles)               # (nrb, maxb, bm, k)
        keep = (jnp.arange(maxb)[None, :] < jnp.asarray(counts)[:, None])
        contrib = jnp.where(keep[..., None, None], contrib, jnp.uint64(0))
        return contrib.sum(1).reshape(nrb * bm, k)

    def ring_spmm_csr(self, csr, y, *, bm: int = 8, bk: int = 128):
        # Protocol 2's sparse data is host-resident and the result returns
        # to the host immediately; with no accelerator to feed, the chunked
        # CSR loop beats an ELL densification (which blows up O(nrb*maxb)
        # on skewed matrices) — the ELL ring_spmm above stays as the
        # parity oracle for the pallas kernel.
        return jnp.asarray(_csr_spmm_chunked(csr, y))

    def ks_fused(self, x, e0, f0, u0, v0, z0, el, fl, ul, vl, zl, *,
                 party0: bool):
        return _ks_fused_generic(x, e0, f0, u0, v0, z0, el, fl, ul, vl, zl,
                                 party0)


class PallasBackend(RingBackend):
    """Routes through the Pallas kernels (interpret on CPU, lowered on TPU)."""

    name = "pallas"

    def __init__(self, interpret: bool | None = None,
                 bm: int = 128, bk: int = 128, bn: int = 128):
        if interpret is None:
            interpret = not _has_tpu()
        self.interpret = interpret
        self.bm, self.bk, self.bn = bm, bk, bn

    def ring_mm(self, a, b):
        from repro.kernels import ops
        return ops.ring_matmul(jnp.asarray(a, ring.DTYPE),
                               jnp.asarray(b, ring.DTYPE),
                               bm=self.bm, bk=self.bk, bn=self.bn,
                               interpret=self.interpret)

    def ring_spmm(self, blocks, idx, counts, y):
        from repro.kernels import ops
        return ops.spmm(jnp.asarray(blocks, ring.DTYPE), jnp.asarray(idx),
                        jnp.asarray(counts), jnp.asarray(y, ring.DTYPE),
                        interpret=self.interpret)

    def ks_fused(self, x, e0, f0, u0, v0, z0, el, fl, ul, vl, zl, *,
                 party0: bool):
        from repro.kernels.ksadder import ks_carry_share
        shape = jnp.shape(x)
        size = max(1, int(np.prod(shape, dtype=np.int64)))
        bn = 128
        rows = -(-size // bn)
        if self.interpret:
            # single grid cell: the interpret emulation pays per-grid-step,
            # so tiling rows 8 at a time made this op ~60x slower than XLA
            bm = rows
        else:
            bm = 8
            rows += (-rows) % bm
        padded = rows * bn

        def flat2d(t):
            t = jnp.asarray(t, ring.DTYPE).reshape(-1)
            return jnp.pad(t, (0, padded - t.size)).reshape(rows, bn)

        def lvl2d(t):
            t = jnp.asarray(t, ring.DTYPE).reshape(len(KS_LEVELS), 2, -1)
            t = jnp.pad(t, ((0, 0), (0, 0), (0, padded - t.shape[-1])))
            return t.reshape(len(KS_LEVELS), 2, rows, bn)

        out = ks_carry_share(flat2d(x), flat2d(e0), flat2d(f0), flat2d(u0),
                             flat2d(v0), flat2d(z0), lvl2d(el), lvl2d(fl),
                             lvl2d(ul), lvl2d(vl), lvl2d(zl), party0=party0,
                             bm=bm, bn=bn, interpret=self.interpret)
        return out.reshape(-1)[:size].reshape(shape)


class NumpyBackend(RingBackend):
    """Host-side implementation for the offline dealer and Protocol 2."""

    name = "numpy"

    def ring_mm(self, a, b):
        return np.einsum("ij,jk->ik", np.asarray(a, ring.NP_DTYPE),
                         np.asarray(b, ring.NP_DTYPE),
                         dtype=ring.NP_DTYPE, casting="unsafe")

    def ring_spmm(self, blocks, idx, counts, y):
        blocks = np.asarray(blocks, ring.NP_DTYPE)
        y = np.asarray(y, ring.NP_DTYPE)
        nrb, maxb, bm, bk = blocks.shape
        k = y.shape[1]
        y_tiles = y.reshape(-1, bk, k)[np.asarray(idx)]
        contrib = np.einsum("rbmi,rbik->rbmk", blocks, y_tiles,
                            dtype=ring.NP_DTYPE, casting="unsafe")
        keep = np.arange(maxb)[None, :] < np.asarray(counts)[:, None]
        contrib *= keep[..., None, None].astype(ring.NP_DTYPE)
        return contrib.sum(1, dtype=ring.NP_DTYPE).reshape(nrb * bm, k)

    def ring_spmm_csr(self, csr, y, *, bm: int = 8, bk: int = 128):
        return _csr_spmm_chunked(csr, y)

    def ks_fused(self, x, e0, f0, u0, v0, z0, el, fl, ul, vl, zl, *,
                 party0: bool):
        args = [np.asarray(t, ring.NP_DTYPE)
                for t in (x, e0, f0, u0, v0, z0, el, fl, ul, vl, zl)]
        return _ks_fused_generic(*args, party0)


# ---------------------------------------------------------------------------
# resolution
# ---------------------------------------------------------------------------

_INSTANCES: dict[str, RingBackend] = {}


def _has_tpu() -> bool:
    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except Exception:
        return False


def get_backend(name: "str | RingBackend | None" = "auto") -> RingBackend:
    """Resolve a backend name ('auto'|'xla'|'pallas'|'numpy') or pass an
    instance through. 'auto' = pallas when a TPU is attached, xla otherwise
    (interpret-mode pallas is always *available* but only wins on TPU)."""
    if isinstance(name, RingBackend):
        return name
    if name is None:
        name = "auto"
    if name == "auto":
        name = "pallas" if _has_tpu() else "xla"
    if name not in _INSTANCES:
        try:
            cls = {"xla": XlaBackend, "pallas": PallasBackend,
                   "numpy": NumpyBackend}[name]
        except KeyError:
            raise ValueError(
                f"unknown ring backend {name!r}; "
                "expected 'auto', 'xla', 'pallas' or 'numpy'") from None
        _INSTANCES[name] = cls()
    return _INSTANCES[name]
