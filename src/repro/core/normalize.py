"""Secure joint normalization (paper Sec 4.2: "before performing clustering,
a joint normalization operation is required").

Vertical partitioning: each party owns whole columns, so min-max
normalization is LOCAL (no protocol needed) — provided as `normalize_local`.

Horizontal partitioning: the column-wise min/max spans both parties' rows.
`secure_minmax` computes secret-shared global min/max with ONE CMP + MUX
round per reduction level: each party first reduces its own rows locally
(plaintext), shares the d-vector of local extrema, and the two candidates
are combined with the comparison protocol — the normalization constants are
then reconstructed (they are part of the agreed preprocessing output, like
the paper's public initialization) or kept shared for a fully-oblivious
variant.
"""
from __future__ import annotations

import numpy as np

from repro.core import protocol as P
from repro.core import ring
from repro.core.sharing import AShare, rec_real, share_real


def normalize_local(x: np.ndarray) -> np.ndarray:
    """Per-column min-max to [0, 1] (vertical partitioning: local & exact)."""
    lo, hi = x.min(0, keepdims=True), x.max(0, keepdims=True)
    return (x - lo) / np.maximum(hi - lo, 1e-9)


def secure_minmax(ctx: P.Ctx, x_a: np.ndarray, x_b: np.ndarray,
                  rng: np.random.Generator):
    """Horizontal partitioning: -> (min AShare (d,), max AShare (d,)).

    One CMP+MUX pair per extremum over the parties' local extrema (the
    local reductions are plaintext — each party's rows are its own data)."""
    lo_a, hi_a = x_a.min(0), x_a.max(0)
    lo_b, hi_b = x_b.min(0), x_b.max(0)
    sh = {k: share_real(v, rng) for k, v in
          {"la": lo_a, "ha": hi_a, "lb": lo_b, "hb": hi_b}.items()}
    b_lo = P.cmp_lt(ctx, sh["la"], sh["lb"])       # [lo_a < lo_b]
    g_min = P.mux(ctx, b_lo, sh["la"], sh["lb"])
    b_hi = P.cmp_lt(ctx, sh["hb"], sh["ha"])       # [hi_b < hi_a]
    g_max = P.mux(ctx, b_hi, sh["ha"], sh["hb"])
    return g_min, g_max


def normalize_horizontal(ctx: P.Ctx, x_a: np.ndarray, x_b: np.ndarray,
                         rng: np.random.Generator):
    """Jointly min-max normalize horizontally-partitioned data. The global
    (min, range) pair is reconstructed as agreed preprocessing output (same
    disclosure class as the paper's public initialization indexes); each
    party then rescales its own rows locally."""
    g_min, g_max = secure_minmax(ctx, x_a, x_b, rng)
    lo = np.asarray(rec_real(g_min))
    hi = np.asarray(rec_real(g_max))
    ctx.log.send(2 * ring.nbytes(lo.shape), tag="norm", phase="online")
    rng_span = np.maximum(hi - lo, 1e-9)
    return (x_a - lo) / rng_span, (x_b - lo) / rng_span
