"""Named crash-injection points for the chaos harness (DESIGN.md §16).

A *kill-point* is a named seam in the protocol — a fit-phase boundary or
a responder serve step — where the chaos matrix may terminate the
process mid-flight. Production code calls `probe("fit.mid_s1")` at the
seam; the call is a no-op (one dict truthiness check) unless the point
was armed via `arm("fit.mid_s1:3")`, in which case the 3rd hit prints a
terminal diagnostic line (plus whatever the registered reporter returns
— wire counters, so a dying incarnation still reports its traffic) and
hard-exits with `KILL_EXIT_CODE`, modelling a kill -9 that no `finally`
block softens.

Arming is per-process and explicit (CLI flag / env, wired by
`launch/two_party.py`); an un-armed process pays nothing on the hot
path. `os._exit` is deliberate: the whole point is that NO cleanup runs
— buffered writes are lost, sockets die with RST — so recovery must
come from published checkpoints alone.
"""
from __future__ import annotations

import json
import os

# same code the scripted `--die-at-iter` kills already use, so the
# supervisor treats every injected death uniformly as "restartable crash"
KILL_EXIT_CODE = 17

_armed: dict[str, int] = {}     # point -> remaining hits before death
_reporter = None                # () -> dict of diagnostics for the DYING line


def arm(spec: str) -> None:
    """Arm kill-points from a spec string: comma-separated
    ``point[:nth]`` entries — ``fit.mid_s1:3`` dies on the 3rd hit,
    ``fit.publish`` on the 1st."""
    for part in (spec or "").split(","):
        part = part.strip()
        if not part:
            continue
        if ":" in part:
            point, nth = part.rsplit(":", 1)
            _armed[point] = max(1, int(nth))
        else:
            _armed[part] = 1


def disarm_all() -> None:
    _armed.clear()


def armed() -> dict[str, int]:
    return dict(_armed)


def set_reporter(fn) -> None:
    """Register a callable returning a JSON-able dict (wire counters,
    retries, …) to be printed on the DYING line, so the chaos bench can
    total traffic across incarnations that never reach a clean exit."""
    global _reporter
    _reporter = fn


def probe(point: str) -> None:
    """Hot-path seam: dies iff `point` is armed and this is the Nth hit."""
    if not _armed:
        return
    n = _armed.get(point)
    if n is None:
        return
    if n > 1:
        _armed[point] = n - 1
        return
    del _armed[point]
    info = {}
    if _reporter is not None:
        try:
            info = dict(_reporter())
        except Exception:
            info = {}
    # single machine-parsable line; flush before the hard exit
    print(f"DYING point={point} stats={json.dumps(info, sort_keys=True)}",
          flush=True)
    os._exit(KILL_EXIT_CODE)
