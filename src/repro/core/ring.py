"""Z_{2^l} ring arithmetic with fixed-point encoding (paper: l=64, f=20).

Values live in uint64; two's-complement wraparound is the ring reduction.
XLA integer ops have defined mod-2^64 wraparound semantics, so `+ - *` on
uint64 arrays are exactly the ring ops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

L = 64                    # ring bit width (paper Sec 5.1: l = 64)
F = 20                    # fractional bits  (paper Sec 5.1: 20 of 64 bits)
DTYPE = jnp.uint64
NP_DTYPE = np.uint64
MASK = np.uint64(0xFFFFFFFFFFFFFFFF)


def encode(x, f: int = F):
    """Real -> fixed-point ring element (two's complement mod 2^64)."""
    x = jnp.asarray(x, jnp.float64)
    return jnp.round(x * np.float64(1 << f)).astype(jnp.int64).astype(DTYPE)


def decode(u, f: int = F):
    """Fixed-point ring element -> real (interpret high bit as sign)."""
    return jnp.asarray(u, DTYPE).astype(jnp.int64).astype(jnp.float64) / np.float64(1 << f)


def neg(u):
    return (jnp.uint64(0) - jnp.asarray(u, DTYPE)).astype(DTYPE)


def arith_rshift(u, f: int):
    """Arithmetic (sign-extending) right shift on the two's-complement view."""
    return (jnp.asarray(u, DTYPE).astype(jnp.int64) >> f).astype(DTYPE)


def from_int(x):
    """Integer -> ring element at scale 1 (no fractional bits)."""
    return jnp.asarray(x, jnp.int64).astype(DTYPE)


def rand_np(rng: np.random.Generator, shape) -> np.ndarray:
    """Uniform ring elements (numpy; used for share/triple generation)."""
    return rng.integers(0, 1 << 64, size=shape, dtype=np.uint64)


def nbytes(shape, l: int = L) -> int:
    """Bytes on the wire for a ring tensor of `shape`."""
    return int(np.prod(shape, dtype=np.int64)) * (l // 8)
