"""Additive secret sharing over Z_{2^64} (paper Sec 3.1).

A-shares are pairs (s0, s1) with x = s0 + s1 mod 2^64; B-shares are pairs of
*bit-packed* uint64 words with x = b0 XOR b1 — each tensor element carries its
64 bits in one lane, so bitwise protocol ops are lane-parallel across both the
tensor and the bit dimension.

Both parties' shares live in one process (simulated 2PC); protocol code only
ever combines them at explicit `rec` points which correspond 1:1 to real
communication, accounted in channel.CommLog.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import ring


class AShare(NamedTuple):
    """Arithmetic share: x = s0 + s1 (mod 2^64)."""

    s0: jnp.ndarray
    s1: jnp.ndarray

    @property
    def shape(self):
        return self.s0.shape


class BShare(NamedTuple):
    """Boolean share, bit-packed: x = b0 ^ b1 (64 bits per lane)."""

    b0: jnp.ndarray
    b1: jnp.ndarray

    @property
    def shape(self):
        return self.b0.shape


def share(x, rng: np.random.Generator) -> AShare:
    """Shr(x): split a ring tensor into two uniform shares."""
    x = np.asarray(x, np.uint64)
    s0 = ring.rand_np(rng, x.shape)
    s1 = x - s0  # uint64 wraparound == mod 2^64
    return AShare(jnp.asarray(s0), jnp.asarray(s1))


def share_real(x, rng: np.random.Generator, f: int = ring.F) -> AShare:
    """Encode reals to fixed point then share."""
    enc = np.round(np.asarray(x, np.float64) * (1 << f)).astype(np.int64).astype(np.uint64)
    return share(enc, rng)


def rec(a: AShare) -> jnp.ndarray:
    """Rec(x): reconstruct (the only point where plaintext reappears)."""
    return (a.s0 + a.s1).astype(ring.DTYPE)


def rec_real(a: AShare, f: int = ring.F) -> jnp.ndarray:
    return ring.decode(rec(a), f)


def share_b(x, rng: np.random.Generator) -> BShare:
    x = np.asarray(x, np.uint64)
    b0 = ring.rand_np(rng, x.shape)
    return BShare(jnp.asarray(b0), jnp.asarray(x ^ b0))


def rec_b(b: BShare) -> jnp.ndarray:
    return b.b0 ^ b.b1


def zeros_like(a: AShare) -> AShare:
    z = jnp.zeros(a.shape, ring.DTYPE)
    return AShare(z, z)


def public_to_ashare(x) -> AShare:
    """Embed a public ring tensor as a (degenerate) share pair (P0 holds it)."""
    x = jnp.asarray(x, ring.DTYPE)
    return AShare(x, jnp.zeros_like(x))
