"""Protocol 2: Secure Sparse Matrix Multiplication + HE2SS (paper Sec 4.3).

Setting: party A holds a *plaintext sparse* matrix X (its own raw data —
sparsity is only destroyed once a matrix is secret-shared, which is exactly
what this protocol avoids); party B holds a dense matrix Y (here: its share
of the centroids). Output: fresh A-shares of Z = X @ Y mod 2^64.

  1. B encrypts Y with its key and sends [[Y]] — slot-packed g columns per
     ciphertext, d*ceil(k/g) ciphertexts (DESIGN.md §12).
  2. A computes [[Z]] = X [[Y]] using ONLY nnz(X)*ceil(k/g) ciphertext ops:
     one plaintext-scalar pmul against a packed column-group ciphertext
     multiplies X_ij into g columns at once (the homomorphism is linear
     over Z mod N, so intermediate per-slot values may go negative — only
     the FINAL masked slots must be non-negative and slot-bounded).
  3. A masks: picks r uniform in [0, 2^{value_bits+kappa_stat}) per entry
     from a dealer-seeded numpy stream, adds (r + 2^{value_bits}) per slot
     with one deterministic `add_plain` per row-group, stacks `rpc`
     row-groups per wire ciphertext (shift-and-add), re-randomizes each
     wire ciphertext with one fresh [[0]], and sends. A's share is
     (-(r + offset) mod 2^l) = (-r mod 2^l) since value_bits >= l.
  4. B decrypts and reduces each slot mod 2^l -> its share. (HE2SS, Sec 3.3)

Step 3 is the paper's "A locally generates share from Z_2^l" line made
statistically sound: the mask must cover the value's full integer magnitude
plus kappa_stat bits, because decryption reveals Z + r over the integers.

Both legs pack (paper sizes psi=1365 bits for this): the B->A leg carries
g = min(k, slots) columns per ciphertext and the A->B leg carries
rpc = max(1, slots // g) masked row-groups (g slots each) per ciphertext —
the column-batched rewrite of the original per-(row, col, nnz) Python
ciphertext loops, which survive behind `batched=False` as the parity
reference.

Communication = d*ceil(k/g) ct (B->A) + ceil(n*ceil(k/g) / rpc) ct (A->B):
independent of nnz and, crucially, of the *large* dimension product n*d
that the dense-SS path must ship — the paper's headline sparsity win.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import ring
from repro.core.he import KAPPA_STAT, OU_COST_S
from repro.core.protocol import Ctx
from repro.core.sharing import AShare
from repro.obs import trace as _trace


class CSRMatrix:
    """Minimal CSR for party-local plaintext sparse data (int64 ring values)."""

    def __init__(self, indptr, indices, data, shape):
        self.indptr = np.asarray(indptr, np.int64)
        self.indices = np.asarray(indices, np.int64)
        self.data = np.asarray(data, np.uint64)
        self.shape = tuple(shape)

    @property
    def nnz(self) -> int:
        return len(self.data)

    @classmethod
    def from_dense(cls, x: np.ndarray) -> "CSRMatrix":
        x = np.asarray(x, np.uint64)
        mask = x != 0
        indptr = np.concatenate([[0], np.cumsum(mask.sum(1))])
        indices = np.nonzero(mask)[1]
        data = x[mask]
        return cls(indptr, indices, data, x.shape)

    @classmethod
    def from_dense_real(cls, x: np.ndarray, f: int = ring.F) -> "CSRMatrix":
        enc = np.round(np.asarray(x, np.float64) * (1 << f)).astype(np.int64)
        return cls.from_dense(enc.astype(np.uint64))

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.uint64)
        for i in range(self.shape[0]):
            lo, hi = self.indptr[i], self.indptr[i + 1]
            out[i, self.indices[lo:hi]] = self.data[lo:hi]
        return out

    def transpose(self) -> "CSRMatrix":
        """X^T in CSR, nnz-proportional (counting sort by column) — no
        densify round-trip. Identical layout to `from_dense(X.T)`: rows of
        the transpose in order, each row's entries ordered by original row
        index (stable sort). Used by the C^T X joint products, which apply
        Protocol 2 through the transpose identity <C>^T X = (X^T <C>)^T.
        Memoized: Lloyd consumes the same transpose every iteration."""
        t = getattr(self, "_transpose", None)
        if t is None:
            n, d = self.shape
            counts = np.bincount(self.indices, minlength=d)
            indptr = np.concatenate([[0], np.cumsum(counts)])
            order = np.argsort(self.indices, kind="stable")
            rows = np.repeat(np.arange(n, dtype=np.int64),
                             np.diff(self.indptr))
            t = CSRMatrix(indptr, rows[order], self.data[order], (d, n))
            self._transpose = t
        return t


@dataclasses.dataclass(frozen=True)
class HE2SSLayout:
    """Slot geometry for the both-leg packed Protocol-2 exchange (§12)."""

    value_bits: int  # |Z entry as integer| < 2^value_bits
    slot_bits: int   # value_bits + KAPPA_STAT + 2
    slots: int       # values per ciphertext at this slot width
    g: int           # columns packed per B->A ciphertext / per row-group
    ngrp: int        # ceil(k / g) column groups
    rpc: int         # row-groups stacked per A->B wire ciphertext

    def n_wire(self, n: int) -> int:
        """A->B wire ciphertexts for an n-row product."""
        return -(-(n * self.ngrp) // self.rpc)


def he2ss_layout(k: int, plain_bits: int, value_bits: int) -> HE2SSLayout:
    slot_bits = value_bits + KAPPA_STAT + 2
    slots = max(1, plain_bits // slot_bits)
    g = min(k, slots)
    return HE2SSLayout(value_bits=value_bits, slot_bits=slot_bits,
                       slots=slots, g=g, ngrp=-(-k // g),
                       rpc=max(1, slots // g))


def default_value_bits(d: int) -> int:
    """|Z| bound: full-range 2^l share x fixed-point data, summed over d."""
    return ring.L + (ring.F + 14) + max(1, int(np.ceil(np.log2(d))))


def he2ss_op_counts(n: int, d: int, nnz: int, nrows_ne: int,
                    lay: HE2SSLayout) -> dict:
    """HE operation counts of the batched exchange (mirrors the real path's
    measured counters exactly; test-enforced). `nrows_ne` = rows with any
    non-zero (their first product needs no accumulate-add)."""
    mct = n * lay.ngrp                 # masked row-group ciphertexts
    n_out = lay.n_wire(n)
    return {
        "enc": d * lay.ngrp + n_out,   # forward packing + wire re-randomize
        "pmul": nnz * lay.ngrp + (mct - n_out),   # step 2 + stacking shifts
        "add": (nnz - nrows_ne) * lay.ngrp + 2 * mct,
        "dec": n_out,
        "ct_fwd": d * lay.ngrp,
        "ct_ret": n_out,
    }


def _mask_words(seed: int, n: int, k: int, mask_bits: int) -> np.ndarray:
    """(n, k, w) uint64 little-endian words of r ~ U[0, 2^mask_bits), drawn
    from the dealer-seeded stream so a provisioned dealer replays bit-exact
    and the batched / legacy paths consume identical masks."""
    w = -(-mask_bits // 64)
    words = np.random.default_rng(seed) \
        .integers(0, 1 << 64, size=(n, k, w), dtype=np.uint64)
    top = mask_bits - 64 * (w - 1)
    if top < 64:
        words[..., -1] &= np.uint64((1 << top) - 1)
    return words


def _mask_int(words: np.ndarray, i: int, c: int) -> int:
    return sum(int(words[i, c, t]) << (64 * t)
               for t in range(words.shape[2]))


def secure_sparse_matmul(ctx: Ctx, x: CSRMatrix, y_share_b: np.ndarray, he,
                         *, value_bits: int | None = None,
                         trunc_f: int | None = None,
                         time_model: dict | None = None,
                         batched: bool = True) -> AShare:
    """Traced entry point for Protocol 2: the HE joint-product exchange is
    the dominant host-side hot seam of a sparse fit, so it gets its own
    span (`he.exchange`, tagged with the problem shape)."""
    with _trace.span("he.exchange", n=x.shape[0], d=x.shape[1],
                     k=int(y_share_b.shape[1]), nnz=int(x.nnz)):
        return _secure_sparse_matmul(ctx, x, y_share_b, he,
                                     value_bits=value_bits, trunc_f=trunc_f,
                                     time_model=time_model, batched=batched)


def _secure_sparse_matmul(ctx: Ctx, x: CSRMatrix, y_share_b: np.ndarray, he,
                          *, value_bits: int | None = None,
                          trunc_f: int | None = None,
                          time_model: dict | None = None,
                          batched: bool = True) -> AShare:
    """Protocol 2. `y_share_b` is party B's plaintext-held (d, k) ring matrix
    (e.g. its additive share of the centroids); A's share of Y is handled by
    the caller with a plain local sparse matmul (X is public to A).

    value_bits bounds |Z entries as integers| (NOT mod-reduced): B's share is
    full-range 2^64 and X is fixed point, so the default is
    l + (F + 14) + ceil(log2 d). The statistical mask r is uniform in
    [0, 2^{value_bits+KAPPA_STAT}) and an additive OFFSET = 2^{value_bits}
    keeps the revealed integer Z + r + OFFSET positive; both cancel mod 2^l.
    Returns A-shares of X @ Y. Also logs a modelled HE wall-time if
    `time_model` (dict like he.OU_COST_S) is given.

    `batched=False` selects the original per-(row, col, nnz) ciphertext
    loops — kept as the parity reference for the column-batched rewrite;
    both paths draw masks from the same dealer-seeded stream and produce
    bit-identical shares.
    """
    n, d = x.shape
    d2, k = y_share_b.shape
    assert d == d2
    if value_bits is None:
        value_bits = default_value_bits(d)
    assert value_bits >= ring.L, \
        "offset 2^value_bits must vanish mod 2^l for the share algebra"
    y = np.asarray(y_share_b, np.uint64)
    lay = he2ss_layout(k, he.plain_bits, value_bits)
    nrows_ne = int(np.count_nonzero(np.diff(x.indptr)))

    # Fast path for the simulated backend: the real protocol's shares reduced
    # mod 2^l are distributed exactly as (Z + r64, -r64) with r64 uniform in
    # Z_{2^64}; compute them directly with a vectorized nnz-proportional
    # numpy matmul. Traffic/HE-time accounting mirrors the batched path.
    if getattr(he, "name", "") == "ou-sim":
        ops = he2ss_op_counts(n, d, x.nnz, nrows_ne, lay)
        ctx.send(ops["ct_fwd"] * he.ct_bytes, rounds=1)         # B->A [[Y]]
        ctx.send(ops["ct_ret"] * he.ct_bytes, rounds=1)
        # step-2 local compute: nnz/block-proportional ring spmm, dispatched
        # through the ring backend (blocked-ELL kernel on pallas, gather-
        # scatter on numpy) — wraps mod 2^64 either way
        z = np.asarray(ctx.backend.ring_spmm_csr(x, y), np.uint64)
        # mask stream seeded through the dealer API so a PooledDealer can
        # pre-draw it in the offline phase (bit-exact replay)
        r = np.random.default_rng(ctx.dealer.mask_seed()) \
            .integers(0, 1 << 64, size=(n, k), dtype=np.uint64)
        if time_model is not None:
            ctx.add_he_seconds(sum(ops[op] * time_model[op]
                                   for op in ("enc", "pmul", "add", "dec")))
        secure_sparse_matmul.last_op_counts = ops
        out = AShare(jnp.asarray((np.uint64(0) - r)), jnp.asarray(z + r))
        from repro.core import protocol as P
        return P.trunc(out, trunc_f) if trunc_f else out

    sb, g, ngrp, rpc = lay.slot_bits, lay.g, lay.ngrp, lay.rpc
    offset = 1 << value_bits
    n_enc = n_pmul = n_add = n_dec = 0
    # one cached [[0]] per call (for all-empty row-groups; only ever summed
    # or masked before transmission, and every wire ciphertext is freshly
    # re-randomized, so reuse is semantically safe). Its single encryption
    # is O(1) and excluded from the modelled op counts.
    _zero = None

    def zero_ct():
        nonlocal _zero
        if _zero is None:
            _zero = he.encrypt(0)
        return _zero

    # masks for ALL n*k cells, dealer-seeded (shared by both real paths)
    words = _mask_words(ctx.dealer.mask_seed(), n, k,
                        value_bits + KAPPA_STAT)
    # -(r + offset) mod 2^l = -r mod 2^l: offset == 0 mod 2^l (value_bits>=l)
    share_a = np.uint64(0) - words[..., 0]

    if batched:
        # -- 1. B -> A: [[Y]] packed g columns per ciphertext ----------------
        cts_y = []
        for j in range(d):
            row = []
            for grp in range(ngrp):
                p = 0
                for pos, c in enumerate(range(grp * g, min(k, (grp + 1) * g))):
                    p |= int(y[j, c]) << (sb * pos)   # y < 2^64: slots disjoint
                row.append(he.encrypt(p))
                n_enc += 1
            cts_y.append(row)
        ctx.send(d * ngrp * he.ct_bytes, rounds=1)

        # -- 2. A: [[Z]] = X [[Y]] — one pmul covers g columns ---------------
        z_rows = []
        for i in range(n):
            lo, hi = int(x.indptr[i]), int(x.indptr[i + 1])
            row = []
            for grp in range(ngrp):
                acc = None
                for t in range(lo, hi):
                    j, v = int(x.indices[t]), int(np.int64(x.data[t]))
                    term = v * cts_y[j][grp]
                    n_pmul += 1
                    acc = term if acc is None else acc + term
                    n_add += acc is not term
                row.append(acc if acc is not None else zero_ct())
            z_rows.append(row)

        # -- 3. A: mask per slot, stack rpc row-groups, re-randomize ---------
        packed, cur, cur_n = [], None, 0
        for i in range(n):
            for grp in range(ngrp):
                m = 0
                for pos, c in enumerate(range(grp * g, min(k, (grp + 1) * g))):
                    # r + offset < 2^{slot_bits-1}: slots stay disjoint
                    m |= (_mask_int(words, i, c) + offset) << (sb * pos)
                mct = z_rows[i][grp].add_plain(m)
                n_add += 1
                if cur_n == 0:
                    cur = mct
                else:
                    cur = cur + (1 << (sb * g * cur_n)) * mct
                    n_pmul += 1
                    n_add += 1
                cur_n += 1
                if cur_n == rpc:
                    packed.append(cur)
                    cur, cur_n = None, 0
        if cur is not None:
            packed.append(cur)
        # every derived wire ciphertext gets FRESH randomness: B knows the
        # randomness of its own [[Y]], so an un-randomized derived ct would
        # leak A's coefficients through the deterministic add_plain chain
        out_cts = [ct + he.encrypt(0) for ct in packed]
        n_enc += len(packed)
        n_add += len(packed)
        ctx.send(len(out_cts) * he.ct_bytes, rounds=1)

        # -- 4. B: decrypt, unpack rpc x g slots, reduce mod 2^l -------------
        share_b = np.zeros((n, k), np.uint64)
        slot_mask = (1 << sb) - 1
        idx = 0                                   # flattened (i, grp) counter
        for ct in out_cts:
            w = he.decrypt(ct)
            n_dec += 1
            for b in range(rpc):
                if idx >= n * ngrp:
                    break
                i, grp = divmod(idx, ngrp)
                base = sb * g * b
                for pos, c in enumerate(range(grp * g, min(k, (grp + 1) * g))):
                    v = (w >> (base + sb * pos)) & slot_mask
                    share_b[i, c] = np.uint64(v & 0xFFFFFFFFFFFFFFFF)
                idx += 1
    else:
        # ---- legacy per-(row, col, nnz) loops: parity reference ------------
        # -- 1. B -> A: [[Y]] one ciphertext per matrix entry ----------------
        cts_y = [[he.encrypt(int(y[j, c])) for c in range(k)]
                 for j in range(d)]
        n_enc += d * k
        ctx.send(d * k * he.ct_bytes, rounds=1)

        # -- 2. A: [[Z]] = X [[Y]]  (nnz-proportional) -----------------------
        z_rows = []
        for i in range(n):
            lo, hi = int(x.indptr[i]), int(x.indptr[i + 1])
            row = []
            for c in range(k):
                acc = None
                for t in range(lo, hi):
                    j, v = int(x.indices[t]), int(np.int64(x.data[t]))
                    term = v * cts_y[j][c]
                    n_pmul += 1
                    acc = term if acc is None else acc + term
                    n_add += acc is not term
                row.append(acc if acc is not None else zero_ct())
            z_rows.append(row)

        # -- 3. A: mask + pack + send  (HE2SS, statistically sound) ----------
        slots = lay.slots
        packed, cur, cur_n = [], None, 0
        for i in range(n):
            for c in range(k):
                r = _mask_int(words, i, c)
                # `ct + int` performs a FULL fresh encryption of the mask —
                # the legacy path's hidden n*k encryptions (counted honestly)
                ct = z_rows[i][c] + (r + offset)  # [[Z + r + offset]]
                n_enc += 1
                n_add += 1
                # shift-and-add packing: ct * 2^{slot*pos} accumulated
                ct_shifted = (1 << (sb * cur_n)) * ct
                cur = ct_shifted if cur is None else cur + ct_shifted
                n_pmul += 1
                n_add += cur is not ct_shifted
                cur_n += 1
                if cur_n == slots:
                    packed.append(cur)
                    cur, cur_n = None, 0
        if cur is not None:
            packed.append(cur)
        out_cts = packed                      # already fresh via the mask encs
        ctx.send(len(packed) * he.ct_bytes, rounds=1)

        # -- 4. B: decrypt, unpack, reduce mod 2^l ---------------------------
        share_b = np.zeros((n, k), np.uint64)
        flat = []
        for ct in packed:
            w = he.decrypt(ct)
            n_dec += 1
            for s in range(slots):
                flat.append((w >> (sb * s)) & ((1 << sb) - 1))
                if len(flat) == n * k:
                    break
        for idx, w in enumerate(flat[: n * k]):
            share_b[idx // k, idx % k] = np.uint64(w & 0xFFFFFFFFFFFFFFFF)

    if time_model is not None:
        t = (n_enc * time_model["enc"] + n_pmul * time_model["pmul"]
             + n_add * time_model["add"] + n_dec * time_model["dec"])
        ctx.log.send(0, tag=ctx.tag + "/he_time", phase="online", rounds=0)
        ctx.add_he_seconds(t)
    # measured op counters, exposed for the accounting parity tests
    secure_sparse_matmul.last_op_counts = {
        "enc": n_enc, "pmul": n_pmul, "add": n_add, "dec": n_dec,
        "ct_fwd": len(cts_y) * len(cts_y[0]) if cts_y else 0,
        "ct_ret": len(out_cts),
    }

    out = AShare(jnp.asarray(share_a), jnp.asarray(share_b))
    from repro.core import protocol as P
    return P.trunc(out, trunc_f) if trunc_f else out


def sparse_matmul_comm_bytes(n: int, d: int, k: int, he_ct_bytes: int = 256,
                             plain_bits: int = 1365,
                             value_bits: int | None = None) -> int:
    """Closed-form Protocol-2 traffic (for the analytic sparsity benchmarks):
    both-leg packed layout — d*ceil(k/g) forward + ceil(n*ceil(k/g)/rpc)
    return ciphertexts."""
    if value_bits is None:
        value_bits = default_value_bits(d)
    lay = he2ss_layout(k, plain_bits, value_bits)
    return (d * lay.ngrp + lay.n_wire(n)) * he_ct_bytes


def dense_ss_matmul_comm_bytes(n: int, d: int, k: int, l: int = ring.L) -> int:
    """Dense Beaver-matmul online traffic for the same product (both dirs)."""
    return 2 * (n * d + d * k) * (l // 8)
