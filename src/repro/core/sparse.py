"""Protocol 2: Secure Sparse Matrix Multiplication + HE2SS (paper Sec 4.3).

Setting: party A holds a *plaintext sparse* matrix X (its own raw data —
sparsity is only destroyed once a matrix is secret-shared, which is exactly
what this protocol avoids); party B holds a dense matrix Y (here: its share
of the centroids). Output: fresh A-shares of Z = X @ Y mod 2^64.

  1. B encrypts Y with its key and sends [[Y]]  (d*k ciphertexts).
  2. A computes [[Z]] = X [[Y]] using ONLY nnz(X) ciphertext ops
     (row i: sum_j in nnz(i) X_ij * [[Y_j]]).
  3. A masks: picks r uniform in [0, 2^{l+kappa_stat+log-sum-bound}) per
     entry, sends [[Z + r]]; A's share is (-r mod 2^l).
  4. B decrypts and reduces mod 2^l -> its share.   (= HE2SS, Sec 3.3)

Step 3 is the paper's "A locally generates share from Z_2^l" line made
statistically sound: the mask must cover the value's full integer magnitude
plus kappa_stat bits, because decryption reveals Z + r over the integers.

Slot packing (paper sizes psi=1365 bits for this): step 3's n*k result
ciphertexts are packed `slots_per_ct` values per ciphertext via shift-and-add
homomorphism before transmission, cutting A->B traffic by ~8x.

Communication = d*k ct (B->A) + ceil(n*k / slots) ct (A->B): independent of
nnz and, crucially, of the *large* dimension product n*d that the dense-SS
path must ship — the paper's headline sparsity win.
"""
from __future__ import annotations

import secrets
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import ring
from repro.core.he import KAPPA_STAT, OU_COST_S
from repro.core.protocol import Ctx
from repro.core.sharing import AShare


class CSRMatrix:
    """Minimal CSR for party-local plaintext sparse data (int64 ring values)."""

    def __init__(self, indptr, indices, data, shape):
        self.indptr = np.asarray(indptr, np.int64)
        self.indices = np.asarray(indices, np.int64)
        self.data = np.asarray(data, np.uint64)
        self.shape = tuple(shape)

    @property
    def nnz(self) -> int:
        return len(self.data)

    @classmethod
    def from_dense(cls, x: np.ndarray) -> "CSRMatrix":
        x = np.asarray(x, np.uint64)
        mask = x != 0
        indptr = np.concatenate([[0], np.cumsum(mask.sum(1))])
        indices = np.nonzero(mask)[1]
        data = x[mask]
        return cls(indptr, indices, data, x.shape)

    @classmethod
    def from_dense_real(cls, x: np.ndarray, f: int = ring.F) -> "CSRMatrix":
        enc = np.round(np.asarray(x, np.float64) * (1 << f)).astype(np.int64)
        return cls.from_dense(enc.astype(np.uint64))

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, np.uint64)
        for i in range(self.shape[0]):
            lo, hi = self.indptr[i], self.indptr[i + 1]
            out[i, self.indices[lo:hi]] = self.data[lo:hi]
        return out

    def transpose(self) -> "CSRMatrix":
        """X^T in CSR, nnz-proportional (counting sort by column) — no
        densify round-trip. Identical layout to `from_dense(X.T)`: rows of
        the transpose in order, each row's entries ordered by original row
        index (stable sort). Used by the C^T X joint products, which apply
        Protocol 2 through the transpose identity <C>^T X = (X^T <C>)^T.
        Memoized: Lloyd consumes the same transpose every iteration."""
        t = getattr(self, "_transpose", None)
        if t is None:
            n, d = self.shape
            counts = np.bincount(self.indices, minlength=d)
            indptr = np.concatenate([[0], np.cumsum(counts)])
            order = np.argsort(self.indices, kind="stable")
            rows = np.repeat(np.arange(n, dtype=np.int64),
                             np.diff(self.indptr))
            t = CSRMatrix(indptr, rows[order], self.data[order], (d, n))
            self._transpose = t
        return t


def secure_sparse_matmul(ctx: Ctx, x: CSRMatrix, y_share_b: np.ndarray, he,
                         *, value_bits: int | None = None,
                         trunc_f: int | None = None,
                         time_model: dict | None = None) -> AShare:
    """Protocol 2. `y_share_b` is party B's plaintext-held (d, k) ring matrix
    (e.g. its additive share of the centroids); A's share of Y is handled by
    the caller with a plain local sparse matmul (X is public to A).

    value_bits bounds |Z entries as integers| (NOT mod-reduced): B's share is
    full-range 2^64 and X is fixed point, so the default is
    l + (F + 14) + ceil(log2 d). The statistical mask r is uniform in
    [0, 2^{value_bits+KAPPA_STAT}) and an additive OFFSET = 2^{value_bits}
    keeps the revealed integer Z + r + OFFSET positive; both cancel mod 2^l.
    Returns A-shares of X @ Y. Also logs a modelled HE wall-time if
    `time_model` (dict like he.OU_COST_S) is given.
    """
    n, d = x.shape
    d2, k = y_share_b.shape
    assert d == d2
    if value_bits is None:
        value_bits = ring.L + (ring.F + 14) + max(1, int(np.ceil(np.log2(d))))
    y = np.asarray(y_share_b, np.uint64)

    # Fast path for the simulated backend: the real protocol's shares reduced
    # mod 2^l are distributed exactly as (Z + r64, -r64) with r64 uniform in
    # Z_{2^64}; compute them directly with a vectorized nnz-proportional
    # numpy matmul. Traffic/HE-time accounting is identical to the slow path.
    if getattr(he, "name", "") == "ou-sim":
        slot_bits = value_bits + KAPPA_STAT + 2
        slots = max(1, he.plain_bits // slot_bits)
        ctx.send(d * k * he.ct_bytes, rounds=1)                 # B->A [[Y]]
        ctx.send(int(np.ceil(n * k / slots)) * he.ct_bytes, rounds=1)
        # step-2 local compute: nnz/block-proportional ring spmm, dispatched
        # through the ring backend (blocked-ELL kernel on pallas, gather-
        # scatter on numpy) — wraps mod 2^64 either way
        z = np.asarray(ctx.backend.ring_spmm_csr(x, y), np.uint64)
        # mask stream seeded through the dealer API so a PooledDealer can
        # pre-draw it in the offline phase (bit-exact replay)
        r = np.random.default_rng(ctx.dealer.mask_seed()) \
            .integers(0, 1 << 64, size=(n, k), dtype=np.uint64)
        if time_model is not None:
            t = (d * k * time_model["enc"] + (x.nnz * k + n * k) * time_model["pmul"]
                 + x.nnz * k * time_model["add"]
                 + int(np.ceil(n * k / slots)) * time_model["dec"])
            ctx.he_seconds = getattr(ctx, "he_seconds", 0.0) + t
        out = AShare(jnp.asarray((np.uint64(0) - r)), jnp.asarray(z + r))
        from repro.core import protocol as P
        return P.trunc(out, trunc_f) if trunc_f else out

    # -- 1. B -> A: [[Y]] -------------------------------------------------
    cts_y = [[he.encrypt(int(y[j, c])) for c in range(k)] for j in range(d)]
    ctx.send(d * k * he.ct_bytes, rounds=1)

    # -- 2. A: [[Z]] = X [[Y]]  (nnz-proportional) --------------------------
    n_pmul = n_add = 0
    z_rows = []
    for i in range(n):
        lo, hi = int(x.indptr[i]), int(x.indptr[i + 1])
        row = []
        for c in range(k):
            acc = None
            for t in range(lo, hi):
                j, v = int(x.indices[t]), int(np.int64(x.data[t]))
                term = v * cts_y[j][c]
                n_pmul += 1
                acc = term if acc is None else acc + term
                n_add += acc is not term
            row.append(acc if acc is not None else he.encrypt(0))
        z_rows.append(row)

    # -- 3. A: mask + pack + send  (HE2SS, statistically sound) ------------
    slot_bits = value_bits + KAPPA_STAT + 2
    slots = max(1, he.plain_bits // slot_bits)
    mask_hi = 1 << (value_bits + KAPPA_STAT)
    offset = 1 << value_bits                          # keeps Z + r + offset > 0
    share_a = np.zeros((n, k), np.uint64)
    packed, cur, cur_n = [], None, 0
    for i in range(n):
        for c in range(k):
            r = secrets.randbelow(mask_hi)
            share_a[i, c] = np.uint64((-(r + offset)) & 0xFFFFFFFFFFFFFFFF)
            ct = z_rows[i][c] + (r + offset)          # [[Z + r + offset]]
            # shift-and-add packing: ct * 2^{slot*pos} accumulated
            ct_shifted = (1 << (slot_bits * cur_n)) * ct
            cur = ct_shifted if cur is None else cur + ct_shifted
            n_pmul += 1
            cur_n += 1
            if cur_n == slots:
                packed.append(cur)
                cur, cur_n = None, 0
    if cur is not None:
        packed.append(cur)
    ctx.send(len(packed) * he.ct_bytes, rounds=1)

    # -- 4. B: decrypt, unpack, reduce mod 2^l ------------------------------
    share_b = np.zeros((n, k), np.uint64)
    flat = []
    for ct in packed:
        w = he.decrypt(ct)
        for s in range(slots):
            flat.append((w >> (slot_bits * s)) & ((1 << slot_bits) - 1))
            if len(flat) == n * k:
                break
    for idx, w in enumerate(flat[: n * k]):
        share_b[idx // k, idx % k] = np.uint64(w & 0xFFFFFFFFFFFFFFFF)

    if time_model is not None:
        t = (d * k * time_model["enc"] + n_pmul * time_model["pmul"]
             + n_add * time_model["add"] + len(packed) * time_model["dec"])
        ctx.log.send(0, tag=ctx.tag + "/he_time", phase="online", rounds=0)
        ctx.he_seconds = getattr(ctx, "he_seconds", 0.0) + t

    out = AShare(jnp.asarray(share_a), jnp.asarray(share_b))
    from repro.core import protocol as P
    return P.trunc(out, trunc_f) if trunc_f else out


def sparse_matmul_comm_bytes(n: int, d: int, k: int, he_ct_bytes: int = 256,
                             plain_bits: int = 1365,
                             value_bits: int | None = None) -> int:
    """Closed-form Protocol-2 traffic (for the analytic sparsity benchmarks)."""
    if value_bits is None:
        value_bits = ring.L + (ring.F + 14) + max(1, int(np.ceil(np.log2(d))))
    slot_bits = value_bits + KAPPA_STAT + 2
    slots = max(1, plain_bits // slot_bits)
    return d * k * he_ct_bytes + int(np.ceil(n * k / slots)) * he_ct_bytes


def dense_ss_matmul_comm_bytes(n: int, d: int, k: int, l: int = ring.L) -> int:
    """Dense Beaver-matmul online traffic for the same product (both dirs)."""
    return 2 * (n * d + d * k) * (l // 8)
