"""Beaver triple generation — the data-independent OFFLINE phase (paper Sec 4.1).

Provider flavours:

* `TrustedDealer` — generates correct triples on demand (numpy). This matches
  the paper's remark that "if there is a trusted third party that does the
  offline phase, the overall efficiency will improve further". On-demand
  generation puts the dealer's host work on the ONLINE critical path, which is
  exactly what the paper's offline/online split avoids — it remains as the
  oracle and as the no-preprocessing baseline.
* `PlanningDealer` + `TriplePlan` — a dry-run trace (the `ListDealer`-style
  replay discipline of launch/kmeans_step) that records the exact
  correlated-randomness schedule a protocol run will consume. The schedule is
  data-independent — that is WHY an offline phase exists at all.
* `PooledDealer` — executes a `TriplePlan` ahead of time with ONE stacked RNG
  draw and ONE batched ring op per shape-class (instead of thousands of tiny
  numpy calls), uploads the pools as device arrays, and serves the online
  phase with zero host work. Bit-exact against `TrustedDealer` under the same
  seed: both draw from identical per-class PCG64 streams, and a stacked
  full-range uint64 draw equals the concatenation of the per-request draws.
* OT-based generation is *cost-modelled* (we cannot run a real network OT
  extension here): per 64-bit scalar product the Gilboa/ABY protocol transfers
  l correlated OTs of (kappa + l)-bit strings per direction. Offline bytes and
  a CPU-rate-based time estimate are logged so Table 1/2's offline column can
  be reproduced analytically alongside the measured dealer wall-time.
* `HE-based` generation for matrix triples (paper ref [34] style) is available
  through repro.core.he for small shapes (real Paillier), mainly for tests.

Every request is tagged so the offline cost decomposes per Lloyd step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import ring
from repro.core.channel import CommLog
from repro.core.sharing import AShare, BShare

KAPPA = 128  # computational security parameter (paper Sec 5.1)


class MatmulTriple(NamedTuple):
    u: AShare  # (n, d)
    v: AShare  # (d, k)
    z: AShare  # (n, k) with Z = U @ V mod 2^64


class MulTriple(NamedTuple):
    u: AShare
    v: AShare
    z: AShare  # elementwise, broadcastable


class BinTriple(NamedTuple):
    u: BShare
    v: BShare
    z: BShare  # bit-packed, z = u & v


class PoolExhaustedError(RuntimeError):
    """The online phase asked for correlated randomness the plan did not
    include (wrong shape-class, or more requests than planned)."""


# ---------------------------------------------------------------------------
# Offline communication cost model (documented formulas, paper-calibrated)
# ---------------------------------------------------------------------------

def ot_mul_triple_bytes(n_scalar_products: int, l: int = ring.L,
                        kappa: int = KAPPA) -> int:
    """Gilboa-style OT multiplication: l COTs of (kappa+l) bits, both dirs."""
    return int(n_scalar_products) * 2 * l * (kappa + l) // 8


def ot_bin_triple_bytes(n_bits: int, kappa: int = KAPPA) -> int:
    """Binary triples via R-OT: ~2(kappa+1) bits per AND gate."""
    return int(n_bits) * 2 * (kappa + 1) // 8


# Calibration: a 2.5 GHz Xeon does ~2e6 OT-extension 64-bit triple ops/s/core
# (ABY paper, Table 2 ballpark). Used only for the modelled offline *time*.
OT_TRIPLES_PER_SEC = 2.0e6
OT_BIN_TRIPLES_PER_SEC = 2.0e7


# ---------------------------------------------------------------------------
# Shape-class generation core — shared by the on-demand and bulk dealers
# ---------------------------------------------------------------------------
#
# A *shape-class* is (kind, shape); every request of a class draws the same
# flat block of full-range uint64 words from the class's own PCG64 stream.
# Because a stacked draw of `count` blocks equals `count` sequential
# single-block draws (verified by tests/test_triples_pool.py), the bulk
# dealer below is bit-identical to the on-demand dealer per construction.

_KIND_ID = {"matmul": 0, "mul": 1, "bin": 2, "rand": 3, "seed": 4}


def _class_key(kind: str, shape) -> tuple:
    if kind == "matmul":
        sa, sb = shape
        return (kind, tuple(sa), tuple(sb))
    return (kind, tuple(shape))


def _class_rng(seed: int, key: tuple) -> np.random.Generator:
    """Deterministic per-class stream: entropy = (seed, kind, dims...)."""
    kind = key[0]
    dims = [d for s in key[1:] for d in (len(s), *s)]
    ent = (int(seed), _KIND_ID[kind], *[int(d) for d in dims])
    return np.random.default_rng(np.random.SeedSequence(ent))


def _nelem(shape) -> int:
    return int(np.prod(shape, dtype=np.int64))


def _check_matmul_dims(shape_a, shape_b) -> None:
    """Planner bugs must surface under `python -O` too — never a bare
    assert."""
    if tuple(shape_a)[1] != tuple(shape_b)[0]:
        raise ValueError(
            f"matmul triple inner dims disagree: A is {tuple(shape_a)}, "
            f"B is {tuple(shape_b)}")


def _gen_matmul(rng, sa, sb, count: int):
    """`count` matmul triples in one stacked draw + one batched ring matmul.

    Per-request word layout (the TrustedDealer draw order):
    u, v, mask_u, mask_v, mask_z. Returns six (count, ...) uint64 arrays
    (u0, u1, v0, v1, z0, z1)."""
    _check_matmul_dims(sa, sb)
    (n, d), (_, k) = tuple(sa), tuple(sb)
    nd, dk, nk = n * d, d * k, n * k
    per = 2 * nd + 2 * dk + nk
    flat = ring.rand_np(rng, (count, per))
    u = flat[:, :nd].reshape(count, n, d)
    v = flat[:, nd:nd + dk].reshape(count, d, k)
    mu = flat[:, nd + dk:2 * nd + dk].reshape(count, n, d)
    mv = flat[:, 2 * nd + dk:2 * (nd + dk)].reshape(count, d, k)
    mz = flat[:, 2 * (nd + dk):].reshape(count, n, k)
    z = np.einsum("bij,bjk->bik", u, v, dtype=ring.NP_DTYPE, casting="unsafe")
    return mu, u - mu, mv, v - mv, mz, z - mz


def _gen_mul(rng, shape, count: int):
    sz = _nelem(shape)
    flat = ring.rand_np(rng, (count, 5 * sz))
    u, v, mu, mv, mz = (flat[:, i * sz:(i + 1) * sz].reshape((count,) + tuple(shape))
                        for i in range(5))
    z = u * v  # uint64 wraps mod 2^64
    return mu, u - mu, mv, v - mv, mz, z - mz


def _gen_bin(rng, shape, count: int):
    sz = _nelem(shape)
    flat = ring.rand_np(rng, (count, 5 * sz))
    u, v, mu, mv, mz = (flat[:, i * sz:(i + 1) * sz].reshape((count,) + tuple(shape))
                        for i in range(5))
    z = u & v
    return mu, u ^ mu, mv, v ^ mv, mz, z ^ mz


def _gen_rand(rng, shape, count: int):
    return (ring.rand_np(rng, (count,) + tuple(shape)),)


def _gen_seed(rng, shape, count: int):
    # full-range uint64 seeds for host-side mask streams (Protocol 2 HE2SS)
    return (ring.rand_np(rng, (count,)),)


_GEN = {"mul": _gen_mul, "bin": _gen_bin, "rand": _gen_rand,
        "seed": _gen_seed}


def _gen_class(rng, kind: str, shape, count: int):
    if kind == "matmul":
        return _gen_matmul(rng, *shape, count)
    return _GEN[kind](rng, shape, count)


# ---------------------------------------------------------------------------
# TrustedDealer — on-demand generation (oracle / no-preprocessing baseline)
# ---------------------------------------------------------------------------

class TrustedDealer:
    """On-demand offline-phase provider. Each request synthesizes one triple
    from its shape-class stream; logs modelled OT cost + measured dealer
    time. The host work lands on the online critical path — `PooledDealer`
    moves it into a true offline phase."""

    def __init__(self, seed: int = 0, log: CommLog | None = None,
                 backend=None):
        # `backend` is accepted for interface compatibility; generation is
        # host-side numpy (bit-exact with every ring backend by the parity
        # guarantee in core/backend.py).
        del backend
        self.seed = seed
        self.log = log if log is not None else CommLog()
        self._rngs: dict[tuple, np.random.Generator] = {}
        self.dealer_seconds = 0.0
        self.modelled_ot_seconds = 0.0
        self.n_matmul = 0
        self.n_mul = 0
        self.n_bin = 0

    # -- helpers ---------------------------------------------------------
    def _rng_for(self, key: tuple) -> np.random.Generator:
        rng = self._rngs.get(key)
        if rng is None:
            rng = self._rngs[key] = _class_rng(self.seed, key)
        return rng

    def _one(self, kind: str, shape):
        key = _class_key(kind, shape)
        out = _gen_class(self._rng_for(key), kind, shape, 1)
        return [jnp.asarray(a[0]) for a in out]

    def _account(self, scalar_products: int, tag: str) -> None:
        """Model OT generation traffic + dealer->party distribution."""
        self.log.send(ot_mul_triple_bytes(scalar_products), tag=tag,
                      phase="offline", rounds=2)
        self.modelled_ot_seconds += scalar_products / OT_TRIPLES_PER_SEC

    def matmul_triple(self, shape_a, shape_b, *, tag: str = "misc") -> MatmulTriple:
        t0 = time.perf_counter()
        (n, d), (_, k) = tuple(shape_a), tuple(shape_b)
        u0, u1, v0, v1, z0, z1 = self._one("matmul", (shape_a, shape_b))
        tr = MatmulTriple(AShare(u0, u1), AShare(v0, v1), AShare(z0, z1))
        self.dealer_seconds += time.perf_counter() - t0
        # A matrix triple is worth n*d*k scalar products under OT generation.
        self._account(n * d * k, tag)
        self.n_matmul += 1
        return tr

    def mul_triple(self, shape, *, tag: str = "misc") -> MulTriple:
        t0 = time.perf_counter()
        u0, u1, v0, v1, z0, z1 = self._one("mul", shape)
        tr = MulTriple(AShare(u0, u1), AShare(v0, v1), AShare(z0, z1))
        self.dealer_seconds += time.perf_counter() - t0
        self._account(_nelem(shape), tag)
        self.n_mul += 1
        return tr

    def bin_triple(self, shape, *, tag: str = "misc") -> BinTriple:
        """Bit-packed binary AND triples: each uint64 lane = 64 AND gates."""
        t0 = time.perf_counter()
        u0, u1, v0, v1, z0, z1 = self._one("bin", shape)
        tr = BinTriple(BShare(u0, u1), BShare(v0, v1), BShare(z0, z1))
        self.dealer_seconds += time.perf_counter() - t0
        n_bits = _nelem(shape) * 64
        self.log.send(ot_bin_triple_bytes(n_bits), tag=tag, phase="offline",
                      rounds=2)
        self.modelled_ot_seconds += n_bits / OT_BIN_TRIPLES_PER_SEC
        self.n_bin += 1
        return tr

    def rand(self, shape) -> jnp.ndarray:
        """Correlated-randomness source for share-resharing steps (B2A)."""
        return self._one("rand", shape)[0]

    def mask_seed(self) -> int:
        """Seed for a host-side statistical-mask stream (Protocol 2 HE2SS)."""
        return int(self._one("seed", ())[0])


# ---------------------------------------------------------------------------
# Planner — derive the exact offline schedule by dry-run trace
# ---------------------------------------------------------------------------

class PlanRequest(NamedTuple):
    kind: str    # matmul | mul | bin | rand | seed
    shape: tuple  # (sa, sb) for matmul, the tensor shape otherwise
    tag: str


@dataclasses.dataclass
class TriplePlan:
    """The correlated-randomness schedule of a protocol run, in consumption
    order. Data-independent: derived once per (n, k, d, iters, partition,
    sparsity) config and valid for every input of those shapes."""

    requests: list

    def repeat(self, reps: int) -> "TriplePlan":
        """Schedule of `reps` identical passes (e.g. Lloyd iterations)."""
        return TriplePlan(list(self.requests) * int(reps))

    def __add__(self, other: "TriplePlan") -> "TriplePlan":
        return TriplePlan(list(self.requests) + list(other.requests))

    def __len__(self) -> int:
        return len(self.requests)

    def class_counts(self) -> dict:
        """{class_key: count} — the shape-class histogram the bulk dealer
        generates, one stacked draw each."""
        out: dict[tuple, int] = {}
        for r in self.requests:
            key = _class_key(r.kind, r.shape)
            out[key] = out.get(key, 0) + 1
        return out


class PlanningDealer:
    """Records the (kind, shape, tag) schedule while the traced code runs on
    zeros — the `ListDealer` replay discipline turned into a planner. The
    trace executes the real protocol (eagerly, on zero data), so control flow
    that depends on tensor *shapes* is followed exactly."""

    def __init__(self):
        self.requests: list[PlanRequest] = []

    def _z(self, shape):
        return jnp.zeros(shape, ring.DTYPE)

    def plan(self) -> TriplePlan:
        return TriplePlan(list(self.requests))

    def matmul_triple(self, shape_a, shape_b, *, tag: str = "misc"):
        _check_matmul_dims(shape_a, shape_b)
        (n, d), (_, k) = tuple(shape_a), tuple(shape_b)
        self.requests.append(
            PlanRequest("matmul", (tuple(shape_a), tuple(shape_b)), tag))
        return MatmulTriple(AShare(self._z((n, d)), self._z((n, d))),
                            AShare(self._z((d, k)), self._z((d, k))),
                            AShare(self._z((n, k)), self._z((n, k))))

    def mul_triple(self, shape, *, tag: str = "misc"):
        self.requests.append(PlanRequest("mul", tuple(shape), tag))
        z = self._z(shape)
        return MulTriple(AShare(z, z), AShare(z, z), AShare(z, z))

    def bin_triple(self, shape, *, tag: str = "misc"):
        self.requests.append(PlanRequest("bin", tuple(shape), tag))
        z = self._z(shape)
        return BinTriple(BShare(z, z), BShare(z, z), BShare(z, z))

    def rand(self, shape):
        self.requests.append(PlanRequest("rand", tuple(shape), "misc"))
        return self._z(shape)

    def mask_seed(self) -> int:
        self.requests.append(PlanRequest("seed", (), "misc"))
        return 0


# ---------------------------------------------------------------------------
# PooledDealer — planned bulk generation, zero-host-work serving
# ---------------------------------------------------------------------------

class PooledDealer:
    """Executes a `TriplePlan` up front and serves it back with device-array
    slicing only.

    Generation batches every shape-class into ONE stacked RNG draw and one
    batched ring op (`np.einsum` over the stacked operands for matmul
    triples, elementwise `*`/`&` otherwise), then uploads each class pool to
    the device once. Bit-exact with `TrustedDealer(seed)` serving the same
    request sequence: per-class streams + the uint64 draw-concatenation
    property make the stacked draw identical to the per-request draws.

    Serving past the planned count — or requesting a shape-class the plan
    never mentioned — raises `PoolExhaustedError`: the trace and the online
    run disagreed, which is a planner bug, not a condition to paper over.
    """

    def __init__(self, plan: TriplePlan, seed: int = 0,
                 log: CommLog | None = None):
        t0 = time.perf_counter()
        self.plan = plan
        self.seed = seed
        self.log = log if log is not None else CommLog()
        self.modelled_ot_seconds = 0.0
        self.n_matmul = 0
        self.n_mul = 0
        self.n_bin = 0
        self._pools: dict[tuple, tuple] = {}    # class key -> stacked arrays
        self._served: dict[tuple, int] = {}     # class key -> cursor
        counts = plan.class_counts()
        self.pool_bytes = 0
        for key, count in counts.items():
            kind = key[0]
            shape = key[1:] if kind == "matmul" else key[1]
            arrays = _gen_class(_class_rng(seed, key), kind, shape, count)
            # one host->device upload per class, then split into per-request
            # views HERE (still offline) so online serving is a plain list
            # index — no gather launches on the critical path
            stacked = tuple(jnp.asarray(a) for a in arrays)
            self._pools[key] = [tuple(a[i] for a in stacked)
                                for i in range(count)]
            self._served[key] = 0
            self.pool_bytes += sum(int(a.size) * 8 for a in stacked)
        self._account_offline(plan)
        self.dealer_seconds = time.perf_counter() - t0

    # -- offline accounting (identical totals to the on-demand dealer) ----
    def _account_offline(self, plan: TriplePlan) -> None:
        groups: dict[tuple, int] = {}
        for r in plan.requests:
            k = (r.kind, _class_key(r.kind, r.shape), r.tag)
            groups[k] = groups.get(k, 0) + 1
        for (kind, key, tag), count in groups.items():
            if kind == "matmul":
                (n, d), (_, k) = key[1], key[2]
                sp = n * d * k
                self.log.send(count * ot_mul_triple_bytes(sp), tag=tag,
                              phase="offline", rounds=2 * count)
                self.modelled_ot_seconds += count * sp / OT_TRIPLES_PER_SEC
            elif kind == "mul":
                sp = _nelem(key[1])
                self.log.send(count * ot_mul_triple_bytes(sp), tag=tag,
                              phase="offline", rounds=2 * count)
                self.modelled_ot_seconds += count * sp / OT_TRIPLES_PER_SEC
            elif kind == "bin":
                n_bits = _nelem(key[1]) * 64
                self.log.send(count * ot_bin_triple_bytes(n_bits), tag=tag,
                              phase="offline", rounds=2 * count)
                self.modelled_ot_seconds += \
                    count * n_bits / OT_BIN_TRIPLES_PER_SEC

    # -- serving ---------------------------------------------------------
    def _next(self, kind: str, shape) -> tuple:
        key = _class_key(kind, shape)
        pool = self._pools.get(key)
        if pool is None:
            raise PoolExhaustedError(
                f"no pool for {kind} {shape}: the offline plan never "
                "scheduled this shape-class (planner/online mismatch)")
        i = self._served[key]
        if i >= len(pool):
            raise PoolExhaustedError(
                f"pool exhausted for {kind} {shape}: planned "
                f"{len(pool)} requests, online asked for more")
        self._served[key] = i + 1
        return pool[i]

    def matmul_triple(self, shape_a, shape_b, *, tag: str = "misc") -> MatmulTriple:
        _check_matmul_dims(shape_a, shape_b)
        u0, u1, v0, v1, z0, z1 = self._next(
            "matmul", (tuple(shape_a), tuple(shape_b)))
        self.n_matmul += 1
        return MatmulTriple(AShare(u0, u1), AShare(v0, v1), AShare(z0, z1))

    def mul_triple(self, shape, *, tag: str = "misc") -> MulTriple:
        u0, u1, v0, v1, z0, z1 = self._next("mul", shape)
        self.n_mul += 1
        return MulTriple(AShare(u0, u1), AShare(v0, v1), AShare(z0, z1))

    def bin_triple(self, shape, *, tag: str = "misc") -> BinTriple:
        u0, u1, v0, v1, z0, z1 = self._next("bin", shape)
        self.n_bin += 1
        return BinTriple(BShare(u0, u1), BShare(v0, v1), BShare(z0, z1))

    def rand(self, shape) -> jnp.ndarray:
        return self._next("rand", shape)[0]

    def mask_seed(self) -> int:
        return int(self._next("seed", ())[0])

    def remaining(self) -> dict:
        """{class_key: unserved} — surplus after e.g. tol early-stop."""
        return {k: len(p) - self._served[k] for k, p in self._pools.items()}
