"""Beaver triple generation — the data-independent OFFLINE phase (paper Sec 4.1).

Three provider flavours:

* `TrustedDealer` — generates correct triples locally (numpy). This matches the
  paper's remark that "if there is a trusted third party that does the offline
  phase, the overall efficiency will improve further", and is what the online
  benchmarks consume.
* OT-based generation is *cost-modelled* (we cannot run a real network OT
  extension here): per 64-bit scalar product the Gilboa/ABY protocol transfers
  l correlated OTs of (kappa + l)-bit strings per direction. Offline bytes and
  a CPU-rate-based time estimate are logged so Table 1/2's offline column can
  be reproduced analytically alongside the measured dealer wall-time.
* `HE-based` generation for matrix triples (paper ref [34] style) is available
  through repro.core.he for small shapes (real Paillier), mainly for tests.

Every request is tagged so the offline cost decomposes per Lloyd step.
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import ring
from repro.core.backend import NumpyBackend, RingBackend
from repro.core.channel import CommLog
from repro.core.sharing import AShare, BShare, share, share_b

KAPPA = 128  # computational security parameter (paper Sec 5.1)


class MatmulTriple(NamedTuple):
    u: AShare  # (n, d)
    v: AShare  # (d, k)
    z: AShare  # (n, k) with Z = U @ V mod 2^64


class MulTriple(NamedTuple):
    u: AShare
    v: AShare
    z: AShare  # elementwise, broadcastable


class BinTriple(NamedTuple):
    u: BShare
    v: BShare
    z: BShare  # bit-packed, z = u & v


# ---------------------------------------------------------------------------
# Offline communication cost model (documented formulas, paper-calibrated)
# ---------------------------------------------------------------------------

def ot_mul_triple_bytes(n_scalar_products: int, l: int = ring.L,
                        kappa: int = KAPPA) -> int:
    """Gilboa-style OT multiplication: l COTs of (kappa+l) bits, both dirs."""
    return int(n_scalar_products) * 2 * l * (kappa + l) // 8


def ot_bin_triple_bytes(n_bits: int, kappa: int = KAPPA) -> int:
    """Binary triples via R-OT: ~2(kappa+1) bits per AND gate."""
    return int(n_bits) * 2 * (kappa + 1) // 8


# Calibration: a 2.5 GHz Xeon does ~2e6 OT-extension 64-bit triple ops/s/core
# (ABY paper, Table 2 ballpark). Used only for the modelled offline *time*.
OT_TRIPLES_PER_SEC = 2.0e6
OT_BIN_TRIPLES_PER_SEC = 2.0e7


class TrustedDealer:
    """Offline-phase provider. Logs modelled OT cost + measured dealer time."""

    def __init__(self, seed: int = 0, log: CommLog | None = None,
                 backend: RingBackend | None = None):
        self.rng = np.random.default_rng(seed)
        self.log = log if log is not None else CommLog()
        # dealer work is host-side and data-independent: numpy ring algebra
        self.backend = backend if backend is not None else NumpyBackend()
        self.dealer_seconds = 0.0
        self.modelled_ot_seconds = 0.0
        self.n_matmul = 0
        self.n_mul = 0
        self.n_bin = 0

    # -- helpers ---------------------------------------------------------
    def _account(self, scalar_products: int, share_bytes: int, tag: str) -> None:
        """Model OT generation traffic + dealer->party distribution."""
        ot_bytes = ot_mul_triple_bytes(scalar_products)
        self.log.send(ot_bytes, tag=tag, phase="offline", rounds=2)
        self.modelled_ot_seconds += scalar_products / OT_TRIPLES_PER_SEC

    def matmul_triple(self, shape_a, shape_b, *, tag: str = "misc") -> MatmulTriple:
        t0 = time.perf_counter()
        (n, d), (d2, k) = tuple(shape_a), tuple(shape_b)
        assert d == d2, (shape_a, shape_b)
        u = ring.rand_np(self.rng, (n, d))
        v = ring.rand_np(self.rng, (d, k))
        z = self.backend.ring_mm(u, v)
        tr = MatmulTriple(share(u, self.rng), share(v, self.rng), share(z, self.rng))
        self.dealer_seconds += time.perf_counter() - t0
        # A matrix triple is worth n*d*k scalar products under OT generation.
        self._account(n * d * k, (n * d + d * k + n * k) * 8, tag)
        self.n_matmul += 1
        return tr

    def mul_triple(self, shape, *, tag: str = "misc") -> MulTriple:
        t0 = time.perf_counter()
        u = ring.rand_np(self.rng, shape)
        v = ring.rand_np(self.rng, shape)
        z = u * v  # uint64 wraps mod 2^64
        tr = MulTriple(share(u, self.rng), share(v, self.rng), share(z, self.rng))
        self.dealer_seconds += time.perf_counter() - t0
        self._account(int(np.prod(shape, dtype=np.int64)), 3 * ring.nbytes(shape), tag)
        self.n_mul += 1
        return tr

    def rand(self, shape) -> jnp.ndarray:
        """Correlated-randomness source for share-resharing steps (B2A)."""
        return jnp.asarray(ring.rand_np(self.rng, shape))

    def bin_triple(self, shape, *, tag: str = "misc") -> BinTriple:
        """Bit-packed binary AND triples: each uint64 lane = 64 AND gates."""
        t0 = time.perf_counter()
        u = ring.rand_np(self.rng, shape)
        v = ring.rand_np(self.rng, shape)
        z = u & v
        tr = BinTriple(share_b(u, self.rng), share_b(v, self.rng), share_b(z, self.rng))
        self.dealer_seconds += time.perf_counter() - t0
        n_bits = int(np.prod(shape, dtype=np.int64)) * 64
        self.log.send(ot_bin_triple_bytes(n_bits), tag=tag, phase="offline", rounds=2)
        self.modelled_ot_seconds += n_bits / OT_BIN_TRIPLES_PER_SEC
        self.n_bin += 1
        return tr
