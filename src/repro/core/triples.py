"""Beaver triple generation — the data-independent OFFLINE phase (paper Sec 4.1).

Provider flavours:

* `TrustedDealer` — generates correct triples on demand (numpy). This matches
  the paper's remark that "if there is a trusted third party that does the
  offline phase, the overall efficiency will improve further". On-demand
  generation puts the dealer's host work on the ONLINE critical path, which is
  exactly what the paper's offline/online split avoids — it remains as the
  oracle and as the no-preprocessing baseline.
* `PlanningDealer` + `TriplePlan` — a dry-run trace (the `ListDealer`-style
  replay discipline of launch/kmeans_step) that records the exact
  correlated-randomness schedule a protocol run will consume. The schedule is
  data-independent — that is WHY an offline phase exists at all.
* `PooledDealer` — executes a `TriplePlan` ahead of time with ONE stacked RNG
  draw and ONE batched ring op per shape-class (instead of thousands of tiny
  numpy calls), uploads the pools as device arrays, and serves the online
  phase with zero host work. Bit-exact against `TrustedDealer` under the same
  seed: both draw from identical per-class PCG64 streams, and a stacked
  full-range uint64 draw equals the concatenation of the per-request draws.
* `StreamingPooledDealer` — the pooled dealer's generation chunked into
  per-iteration *tranches*, double-buffered on a background worker: tranche
  t+1 is generated while iteration t's launches consume tranche t, so peak
  pool residency is O(1 iteration) — independent of `iters` — and fits whose
  total pool exceeds device memory become possible. Bit-exact with both other
  dealers (persistent per-class streams + draw concatenation). `group`
  merges several small iterations into one generation wakeup.
* `SlotDealer` — the minibatch/pipeline generalization: the schedule is a
  SEQUENCE of per-(iteration, batch, stage) slot plans, tranches generated
  in canonical slot order (streamed on a worker, or all up front), and
  `acquire(i)` hands slot i out as a dealer view in ANY order within the
  window — the pipelined executor's double-buffer contract (DESIGN.md §11).
* OT-based generation is *cost-modelled* (we cannot run a real network OT
  extension here): per 64-bit scalar product the Gilboa/ABY protocol transfers
  l correlated OTs of (kappa + l)-bit strings per direction. Offline bytes and
  a CPU-rate-based time estimate are logged so Table 1/2's offline column can
  be reproduced analytically alongside the measured dealer wall-time.
* `HE-based` generation for matrix triples (paper ref [34] style) is available
  through repro.core.he for small shapes (real Paillier), mainly for tests.

Every request is tagged so the offline cost decomposes per Lloyd step.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import ring
from repro.core.channel import CommLog
from repro.core.sharing import AShare, BShare
from repro.obs import trace as _trace

KAPPA = 128  # computational security parameter (paper Sec 5.1)


class MatmulTriple(NamedTuple):
    u: AShare  # (n, d)
    v: AShare  # (d, k)
    z: AShare  # (n, k) with Z = U @ V mod 2^64


class MulTriple(NamedTuple):
    u: AShare
    v: AShare
    z: AShare  # elementwise, broadcastable


class BinTriple(NamedTuple):
    u: BShare
    v: BShare
    z: BShare  # bit-packed, z = u & v


class PoolExhaustedError(RuntimeError):
    """The online phase asked for correlated randomness the plan did not
    include (wrong shape-class, or more requests than planned)."""


# ---------------------------------------------------------------------------
# Offline communication cost model (documented formulas, paper-calibrated)
# ---------------------------------------------------------------------------

def ot_mul_triple_bytes(n_scalar_products: int, l: int = ring.L,
                        kappa: int = KAPPA) -> int:
    """Gilboa-style OT multiplication: l COTs of (kappa+l) bits, both dirs."""
    return int(n_scalar_products) * 2 * l * (kappa + l) // 8


def ot_bin_triple_bytes(n_bits: int, kappa: int = KAPPA) -> int:
    """Binary triples via R-OT: ~2(kappa+1) bits per AND gate."""
    return int(n_bits) * 2 * (kappa + 1) // 8


# Calibration: a 2.5 GHz Xeon does ~2e6 OT-extension 64-bit triple ops/s/core
# (ABY paper, Table 2 ballpark). Used only for the modelled offline *time*.
OT_TRIPLES_PER_SEC = 2.0e6
OT_BIN_TRIPLES_PER_SEC = 2.0e7


# ---------------------------------------------------------------------------
# Shape-class generation core — shared by the on-demand and bulk dealers
# ---------------------------------------------------------------------------
#
# A *shape-class* is (kind, shape); every request of a class draws the same
# flat block of full-range uint64 words from the class's own PCG64 stream.
# Because a stacked draw of `count` blocks equals `count` sequential
# single-block draws (verified by tests/test_triples_pool.py), the bulk
# dealer below is bit-identical to the on-demand dealer per construction.

_KIND_ID = {"matmul": 0, "mul": 1, "bin": 2, "rand": 3, "seed": 4}


def _class_key(kind: str, shape) -> tuple:
    if kind == "matmul":
        sa, sb = shape
        return (kind, tuple(sa), tuple(sb))
    return (kind, tuple(shape))


def _class_rng(seed: int, key: tuple) -> np.random.Generator:
    """Deterministic per-class stream: entropy = (seed, kind, dims...)."""
    kind = key[0]
    dims = [d for s in key[1:] for d in (len(s), *s)]
    ent = (int(seed), _KIND_ID[kind], *[int(d) for d in dims])
    return np.random.default_rng(np.random.SeedSequence(ent))


def _advanced_rng(seed: int, key: tuple, skip: int) -> np.random.Generator:
    """The class stream positioned AFTER `skip` requests — the checkpoint/
    resume primitive. `ring.rand_np` draws exactly `_class_words(key)` PCG64
    words per request, so one `bit_generator.advance` jump reconstructs the
    stream position of any request offset without replaying the draws."""
    rng = _class_rng(seed, key)
    if skip:
        rng.bit_generator.advance(int(skip) * _class_words(key))
    return rng


_SERVE_DOMAIN = 0x53657276  # "Serv"


def serve_seed(fit_seed: int) -> int:
    """Domain-separated dealer seed for the SERVING side of a model fitted
    under `fit_seed`. Per-class streams are keyed by (seed, class) only, so
    reusing the fit's seed for predict-time randomness would replay the
    exact Beaver masks the fit already consumed on overlapping shape-
    classes — and a mask reused on two secrets reveals their difference.
    Every serve-side default (predict/score's on-demand dealer, the
    ScoringService bank, the launch driver) derives its seed through this
    helper; only an explicitly passed equal seed can collide."""
    return int(np.random.SeedSequence(
        (int(fit_seed), _SERVE_DOMAIN)).generate_state(1, np.uint64)[0])


def _nelem(shape) -> int:
    return int(np.prod(shape, dtype=np.int64))


def _check_matmul_dims(shape_a, shape_b) -> None:
    """Planner bugs must surface under `python -O` too — never a bare
    assert."""
    if tuple(shape_a)[1] != tuple(shape_b)[0]:
        raise ValueError(
            f"matmul triple inner dims disagree: A is {tuple(shape_a)}, "
            f"B is {tuple(shape_b)}")


def _check_elemwise_shape(kind: str, shape) -> None:
    """Elementwise (mul/bin) triples take ONE tensor shape; a nested or
    non-integer 'shape' is a planner bug (e.g. a matmul-style ((n,d),(d,k))
    pair leaking into mul_triple) and must raise, matching the matmul inner-
    dim check above."""
    try:
        dims = tuple(shape)
    except TypeError:
        raise ValueError(
            f"{kind} triple shape must be an iterable of ints, "
            f"got {shape!r}") from None
    for s in dims:
        if isinstance(s, bool) or not isinstance(s, (int, np.integer)):
            raise ValueError(
                f"{kind} triple shape must be a flat tuple of ints, got "
                f"{dims!r} (offending entry {s!r})")
        if int(s) < 0:
            raise ValueError(
                f"{kind} triple shape has a negative dimension: {dims!r}")


def _gen_matmul(rng, sa, sb, count: int):
    """`count` matmul triples in one stacked draw + one batched ring matmul.

    Per-request word layout (the TrustedDealer draw order):
    u, v, mask_u, mask_v, mask_z. Returns six (count, ...) uint64 arrays
    (u0, u1, v0, v1, z0, z1)."""
    _check_matmul_dims(sa, sb)
    (n, d), (_, k) = tuple(sa), tuple(sb)
    nd, dk, nk = n * d, d * k, n * k
    per = 2 * nd + 2 * dk + nk
    flat = ring.rand_np(rng, (count, per))
    u = flat[:, :nd].reshape(count, n, d)
    v = flat[:, nd:nd + dk].reshape(count, d, k)
    mu = flat[:, nd + dk:2 * nd + dk].reshape(count, n, d)
    mv = flat[:, 2 * nd + dk:2 * (nd + dk)].reshape(count, d, k)
    mz = flat[:, 2 * (nd + dk):].reshape(count, n, k)
    z = np.einsum("bij,bjk->bik", u, v, dtype=ring.NP_DTYPE, casting="unsafe")
    return mu, u - mu, mv, v - mv, mz, z - mz


def _gen_mul(rng, shape, count: int):
    sz = _nelem(shape)
    flat = ring.rand_np(rng, (count, 5 * sz))
    u, v, mu, mv, mz = (flat[:, i * sz:(i + 1) * sz].reshape((count,) + tuple(shape))
                        for i in range(5))
    z = u * v  # uint64 wraps mod 2^64
    return mu, u - mu, mv, v - mv, mz, z - mz


def _gen_bin(rng, shape, count: int):
    sz = _nelem(shape)
    flat = ring.rand_np(rng, (count, 5 * sz))
    u, v, mu, mv, mz = (flat[:, i * sz:(i + 1) * sz].reshape((count,) + tuple(shape))
                        for i in range(5))
    z = u & v
    return mu, u ^ mu, mv, v ^ mv, mz, z ^ mz


def _gen_rand(rng, shape, count: int):
    return (ring.rand_np(rng, (count,) + tuple(shape)),)


def _gen_seed(rng, shape, count: int):
    # full-range uint64 seeds for host-side mask streams (Protocol 2 HE2SS)
    return (ring.rand_np(rng, (count,)),)


_GEN = {"mul": _gen_mul, "bin": _gen_bin, "rand": _gen_rand,
        "seed": _gen_seed}


def _gen_class(rng, kind: str, shape, count: int):
    if kind == "matmul":
        return _gen_matmul(rng, *shape, count)
    return _GEN[kind](rng, shape, count)


def _class_words(key: tuple) -> int:
    """PCG64 words ONE request of this shape-class draws — the stream
    advance per request. Must mirror the `_gen_*` draw widths above exactly:
    it is what lets a worker jump its class stream to an arbitrary request
    offset with `bit_generator.advance` and land on the same words a single
    stacked draw would produce there."""
    kind = key[0]
    if kind == "matmul":
        (n, d), (_, k) = key[1], key[2]
        return 2 * (n * d) + 2 * (d * k) + n * k
    if kind in ("mul", "bin"):
        return 5 * _nelem(key[1])
    if kind == "rand":
        return _nelem(key[1])
    return 1  # seed


# ---------------------------------------------------------------------------
# TrustedDealer — on-demand generation (oracle / no-preprocessing baseline)
# ---------------------------------------------------------------------------

class TrustedDealer:
    """On-demand offline-phase provider. Each request synthesizes one triple
    from its shape-class stream; logs modelled OT cost + measured dealer
    time. The host work lands on the online critical path — `PooledDealer`
    moves it into a true offline phase."""

    def __init__(self, seed: int = 0, log: CommLog | None = None,
                 backend=None, advance: dict | None = None):
        # `backend` is accepted for interface compatibility; generation is
        # host-side numpy (bit-exact with every ring backend by the parity
        # guarantee in core/backend.py).
        del backend
        self.seed = seed
        self.log = log if log is not None else CommLog()
        self._rngs: dict[tuple, np.random.Generator] = {}
        # checkpoint resume: {class_key: requests already consumed} — each
        # class stream starts pre-advanced past them (applied lazily in
        # _rng_for, matching the lazy stream creation)
        self._advance = {tuple(k): int(v)
                         for k, v in (advance or {}).items()}
        self.dealer_seconds = 0.0
        self.modelled_ot_seconds = 0.0
        self.n_matmul = 0
        self.n_mul = 0
        self.n_bin = 0

    # -- helpers ---------------------------------------------------------
    def _rng_for(self, key: tuple) -> np.random.Generator:
        rng = self._rngs.get(key)
        if rng is None:
            rng = self._rngs[key] = _advanced_rng(
                self.seed, key, self._advance.get(key, 0))
        return rng

    def _one(self, kind: str, shape):
        key = _class_key(kind, shape)
        out = _gen_class(self._rng_for(key), kind, shape, 1)
        return [jnp.asarray(a[0]) for a in out]

    def _account(self, scalar_products: int, tag: str) -> None:
        """Model OT generation traffic + dealer->party distribution."""
        self.log.send(ot_mul_triple_bytes(scalar_products), tag=tag,
                      phase="offline", rounds=2)
        self.modelled_ot_seconds += scalar_products / OT_TRIPLES_PER_SEC

    def matmul_triple(self, shape_a, shape_b, *, tag: str = "misc") -> MatmulTriple:
        t0 = time.perf_counter()
        (n, d), (_, k) = tuple(shape_a), tuple(shape_b)
        u0, u1, v0, v1, z0, z1 = self._one("matmul", (shape_a, shape_b))
        tr = MatmulTriple(AShare(u0, u1), AShare(v0, v1), AShare(z0, z1))
        self.dealer_seconds += time.perf_counter() - t0
        # A matrix triple is worth n*d*k scalar products under OT generation.
        self._account(n * d * k, tag)
        self.n_matmul += 1
        return tr

    def mul_triple(self, shape, *, tag: str = "misc") -> MulTriple:
        _check_elemwise_shape("mul", shape)
        t0 = time.perf_counter()
        u0, u1, v0, v1, z0, z1 = self._one("mul", shape)
        tr = MulTriple(AShare(u0, u1), AShare(v0, v1), AShare(z0, z1))
        self.dealer_seconds += time.perf_counter() - t0
        self._account(_nelem(shape), tag)
        self.n_mul += 1
        return tr

    def bin_triple(self, shape, *, tag: str = "misc") -> BinTriple:
        """Bit-packed binary AND triples: each uint64 lane = 64 AND gates."""
        _check_elemwise_shape("bin", shape)
        t0 = time.perf_counter()
        u0, u1, v0, v1, z0, z1 = self._one("bin", shape)
        tr = BinTriple(BShare(u0, u1), BShare(v0, v1), BShare(z0, z1))
        self.dealer_seconds += time.perf_counter() - t0
        n_bits = _nelem(shape) * 64
        self.log.send(ot_bin_triple_bytes(n_bits), tag=tag, phase="offline",
                      rounds=2)
        self.modelled_ot_seconds += n_bits / OT_BIN_TRIPLES_PER_SEC
        self.n_bin += 1
        return tr

    def rand(self, shape) -> jnp.ndarray:
        """Correlated-randomness source for share-resharing steps (B2A)."""
        return self._one("rand", shape)[0]

    def mask_seed(self) -> int:
        """Seed for a host-side statistical-mask stream (Protocol 2 HE2SS)."""
        return int(self._one("seed", ())[0])


# ---------------------------------------------------------------------------
# Planner — derive the exact offline schedule by dry-run trace
# ---------------------------------------------------------------------------

class PlanRequest(NamedTuple):
    kind: str    # matmul | mul | bin | rand | seed
    shape: tuple  # (sa, sb) for matmul, the tensor shape otherwise
    tag: str


@dataclasses.dataclass
class TriplePlan:
    """The correlated-randomness schedule of a protocol run, in consumption
    order. Data-independent: derived once per (n, k, d, iters, partition,
    sparsity) config and valid for every input of those shapes."""

    requests: list

    def repeat(self, reps: int) -> "TriplePlan":
        """Schedule of `reps` identical passes (e.g. Lloyd iterations)."""
        return TriplePlan(list(self.requests) * int(reps))

    def __add__(self, other: "TriplePlan") -> "TriplePlan":
        return TriplePlan(list(self.requests) + list(other.requests))

    def __len__(self) -> int:
        return len(self.requests)

    def class_counts(self) -> dict:
        """{class_key: count} — the shape-class histogram the bulk dealer
        generates, one stacked draw each."""
        out: dict[tuple, int] = {}
        for r in self.requests:
            key = _class_key(r.kind, r.shape)
            out[key] = out.get(key, 0) + 1
        return out

    def pool_words(self) -> int:
        """uint64 words a generated pool/tranche of this plan holds (six
        share tensors per triple, one tensor per rand, one word per seed) —
        the device-residency estimate the tranche-grouping heuristics size
        against."""
        words = 0
        for r in self.requests:
            if r.kind == "matmul":
                (n, d), (_, k) = r.shape
                words += 2 * (n * d + d * k + n * k)
            elif r.kind in ("mul", "bin"):
                words += 6 * _nelem(r.shape)
            elif r.kind == "rand":
                words += _nelem(r.shape)
            else:  # seed
                words += 1
        return words


class _TripleServing:
    """Shared dealer-interface surface for pool-backed providers: validate
    the request, draw its word tuple from ``self._next(kind, shape)``, wrap
    into the triple type, bump the counters. PooledDealer,
    StreamingPooledDealer, BankDealer and SlotDealer views all serve
    through this one implementation — only their `_next` differs."""

    def matmul_triple(self, shape_a, shape_b, *,
                      tag: str = "misc") -> MatmulTriple:
        _check_matmul_dims(shape_a, shape_b)
        u0, u1, v0, v1, z0, z1 = self._next(
            "matmul", (tuple(shape_a), tuple(shape_b)))
        self.n_matmul += 1
        return MatmulTriple(AShare(u0, u1), AShare(v0, v1), AShare(z0, z1))

    def mul_triple(self, shape, *, tag: str = "misc") -> MulTriple:
        _check_elemwise_shape("mul", shape)
        u0, u1, v0, v1, z0, z1 = self._next("mul", shape)
        self.n_mul += 1
        return MulTriple(AShare(u0, u1), AShare(v0, v1), AShare(z0, z1))

    def bin_triple(self, shape, *, tag: str = "misc") -> BinTriple:
        _check_elemwise_shape("bin", shape)
        u0, u1, v0, v1, z0, z1 = self._next("bin", shape)
        self.n_bin += 1
        return BinTriple(BShare(u0, u1), BShare(v0, v1), BShare(z0, z1))

    def rand(self, shape) -> jnp.ndarray:
        return self._next("rand", shape)[0]

    def mask_seed(self) -> int:
        return int(self._next("seed", ())[0])


class PlanningDealer:
    """Records the (kind, shape, tag) schedule while the traced code runs on
    zeros — the `ListDealer` replay discipline turned into a planner. The
    trace executes the real protocol (eagerly, on zero data), so control flow
    that depends on tensor *shapes* is followed exactly."""

    def __init__(self):
        self.requests: list[PlanRequest] = []

    def _z(self, shape):
        return jnp.zeros(shape, ring.DTYPE)

    def plan(self) -> TriplePlan:
        return TriplePlan(list(self.requests))

    def matmul_triple(self, shape_a, shape_b, *, tag: str = "misc"):
        _check_matmul_dims(shape_a, shape_b)
        (n, d), (_, k) = tuple(shape_a), tuple(shape_b)
        self.requests.append(
            PlanRequest("matmul", (tuple(shape_a), tuple(shape_b)), tag))
        return MatmulTriple(AShare(self._z((n, d)), self._z((n, d))),
                            AShare(self._z((d, k)), self._z((d, k))),
                            AShare(self._z((n, k)), self._z((n, k))))

    def mul_triple(self, shape, *, tag: str = "misc"):
        _check_elemwise_shape("mul", shape)
        self.requests.append(PlanRequest("mul", tuple(shape), tag))
        z = self._z(shape)
        return MulTriple(AShare(z, z), AShare(z, z), AShare(z, z))

    def bin_triple(self, shape, *, tag: str = "misc"):
        _check_elemwise_shape("bin", shape)
        self.requests.append(PlanRequest("bin", tuple(shape), tag))
        z = self._z(shape)
        return BinTriple(BShare(z, z), BShare(z, z), BShare(z, z))

    def rand(self, shape):
        self.requests.append(PlanRequest("rand", tuple(shape), "misc"))
        return self._z(shape)

    def mask_seed(self) -> int:
        self.requests.append(PlanRequest("seed", (), "misc"))
        return 0


# ---------------------------------------------------------------------------
# PooledDealer — planned bulk generation, zero-host-work serving
# ---------------------------------------------------------------------------

def _account_offline_plan(plan: TriplePlan, log: CommLog) -> float:
    """Log a plan's modelled OT generation traffic (identical totals to the
    on-demand dealer serving the same schedule); returns the modelled OT
    wall-time. Shared by the pooled and streaming dealers."""
    modelled_s = 0.0
    groups: dict[tuple, int] = {}
    for r in plan.requests:
        key = (r.kind, _class_key(r.kind, r.shape), r.tag)
        groups[key] = groups.get(key, 0) + 1
    for (kind, key, tag), count in groups.items():
        if kind == "matmul":
            (n, d), (_, k) = key[1], key[2]
            sp = n * d * k
            log.send(count * ot_mul_triple_bytes(sp), tag=tag,
                     phase="offline", rounds=2 * count)
            modelled_s += count * sp / OT_TRIPLES_PER_SEC
        elif kind == "mul":
            sp = _nelem(key[1])
            log.send(count * ot_mul_triple_bytes(sp), tag=tag,
                     phase="offline", rounds=2 * count)
            modelled_s += count * sp / OT_TRIPLES_PER_SEC
        elif kind == "bin":
            n_bits = _nelem(key[1]) * 64
            log.send(count * ot_bin_triple_bytes(n_bits), tag=tag,
                     phase="offline", rounds=2 * count)
            modelled_s += count * n_bits / OT_BIN_TRIPLES_PER_SEC
    return modelled_s


def _gen_tranche(rngs: dict, counts: dict):
    """Generate one {class key: [per-request device-array tuples]} tranche
    from persistent per-class RNG streams. Because a class's stream is
    advanced by exactly count*words_per_request words per call, consecutive
    tranches concatenate to the single stacked draw PooledDealer performs —
    the bit-exactness property, chunked."""
    pools: dict[tuple, list] = {}
    nbytes = 0
    for key, count in counts.items():
        kind = key[0]
        shape = key[1:] if kind == "matmul" else key[1]
        arrays = _gen_class(rngs[key], kind, shape, count)
        stacked = tuple(jnp.asarray(a) for a in arrays)
        pools[key] = [tuple(a[i] for a in stacked) for i in range(count)]
        nbytes += sum(int(a.size) * 8 for a in stacked)
    return pools, nbytes


class PooledDealer(_TripleServing):
    """Executes a `TriplePlan` up front and serves it back with device-array
    slicing only.

    Generation batches every shape-class into ONE stacked RNG draw and one
    batched ring op (`np.einsum` over the stacked operands for matmul
    triples, elementwise `*`/`&` otherwise), then uploads each class pool to
    the device once. Bit-exact with `TrustedDealer(seed)` serving the same
    request sequence: per-class streams + the uint64 draw-concatenation
    property make the stacked draw identical to the per-request draws.

    Serving past the planned count — or requesting a shape-class the plan
    never mentioned — raises `PoolExhaustedError`: the trace and the online
    run disagreed, which is a planner bug, not a condition to paper over.
    """

    def __init__(self, plan: TriplePlan, seed: int = 0,
                 log: CommLog | None = None, advance: dict | None = None):
        t0 = time.perf_counter()
        self.plan = plan
        self.seed = seed
        self.log = log if log is not None else CommLog()
        self.modelled_ot_seconds = 0.0
        self.n_matmul = 0
        self.n_mul = 0
        self.n_bin = 0
        self._served: dict[tuple, int] = {}     # class key -> cursor
        counts = plan.class_counts()
        # one host->device upload per class, then split into per-request
        # views HERE (still offline) so online serving is a plain list
        # index — no gather launches on the critical path.
        # `advance`: checkpoint resume — pass the REMAINING plan and the
        # per-class request counts the interrupted run already consumed;
        # each class stream jumps past them before generating.
        advance = advance or {}
        rngs = {key: _advanced_rng(seed, key, advance.get(key, 0))
                for key in counts}
        self._pools, self.pool_bytes = _gen_tranche(rngs, counts)
        self._served = {key: 0 for key in counts}
        self.modelled_ot_seconds = _account_offline_plan(plan, self.log)
        self.dealer_seconds = time.perf_counter() - t0

    # -- serving ---------------------------------------------------------
    def _next(self, kind: str, shape) -> tuple:
        key = _class_key(kind, shape)
        pool = self._pools.get(key)
        if pool is None:
            raise PoolExhaustedError(
                f"no pool for {kind} {shape}: the offline plan never "
                "scheduled this shape-class (planner/online mismatch)")
        i = self._served[key]
        if i >= len(pool):
            raise PoolExhaustedError(
                f"pool exhausted for {kind} {shape}: planned "
                f"{len(pool)} requests, online asked for more")
        self._served[key] = i + 1
        return pool[i]

    def remaining(self) -> dict:
        """{class_key: unserved} — surplus after e.g. tol early-stop."""
        return {k: len(p) - self._served[k] for k, p in self._pools.items()}


# ---------------------------------------------------------------------------
# StreamingPooledDealer — double-buffered per-iteration pool generation
# ---------------------------------------------------------------------------

GROUP_TRANCHE_BYTES = 4 << 20
# auto-grouping target: when one iteration's tranche is tiny (small k*d),
# generating it alone makes the background worker wake up per iteration for
# microseconds of work — group consecutive iterations until a tranche
# reaches ~this many device bytes (bit-exact either way: the per-class
# streams just advance in bigger stacked draws).


class StreamingPooledDealer(_TripleServing):
    """`PooledDealer` semantics with O(1-iteration) device residency.

    Instead of materializing `iters` iterations' worth of every shape-class
    up front (pool residency O(iters), capping fit size at device memory),
    the plan of ONE iteration is generated as a *tranche* — one stacked draw
    + one batched ring op + one upload per shape-class, exactly like the bulk
    dealer but with per-iteration counts — and tranche t+1 is generated on a
    background worker WHILE iteration t's launches consume tranche t. At any
    moment at most `prefetch` tranches are alive (double-buffered by
    default), so peak residency is independent of `iters`.

    Bit-exact with ``PooledDealer(iter_plan.repeat(iters), seed)``: each
    shape-class keeps ONE persistent PCG64 stream across tranches, and the
    uint64 draw-concatenation property makes `iters` sequential per-iteration
    draws identical to the single stacked draw (property-tested in
    tests/test_triples_pool.py).

    Tranche advance is request-counted: the online phase consumes exactly
    ``len(iter_plan)`` requests per iteration (the plan IS the per-iteration
    schedule), so when that many have been served the current tranche's
    device buffers are dropped, the prefetched tranche becomes current, and
    generation of the next one is dispatched. Serving past the per-iteration
    class count — or an unplanned class — raises `PoolExhaustedError` just
    like the bulk dealer.

    Timing accounting: ``dealer_seconds`` is construction (first-tranche)
    time only; generation overlapped with the online loop accumulates in
    ``gen_seconds`` (worker wall-time) and ``wait_seconds`` (time the online
    loop blocked on a tranche that was not ready — real online stalls, left
    IN the caller's online wall-clock on purpose).
    """

    def __init__(self, iter_plan: TriplePlan, iters: int, seed: int = 0,
                 log: CommLog | None = None, prefetch: int = 2,
                 async_gen: bool = True, group: int | str = 1,
                 advance: dict | None = None):
        t0 = time.perf_counter()
        self.iter_plan = TriplePlan(list(iter_plan.requests))
        self.iters = int(iters)
        self.seed = seed
        self.log = log if log is not None else CommLog()
        self.n_matmul = 0
        self.n_mul = 0
        self.n_bin = 0
        self._iter_counts = self.iter_plan.class_counts()
        self._per_iter = len(self.iter_plan)
        # tranche grouping: `group` iterations share one generation wakeup
        # (one stacked draw per class covers them all — the concatenation
        # property keeps every served word identical to group=1); "auto"
        # sizes tranches to ~GROUP_TRANCHE_BYTES so tiny k*d fits don't pay
        # a worker wakeup per iteration
        if group == "auto":
            words = max(1, self.iter_plan.pool_words())
            group = max(1, GROUP_TRANCHE_BYTES // (8 * words))
        self.group = max(1, min(int(group), max(1, self.iters)))
        self._tranche_iters = 1      # iterations covered by _current
        # checkpoint resume: `iters` = REMAINING iterations; `advance` =
        # per-class requests the interrupted run already consumed
        advance = advance or {}
        self._rngs = {key: _advanced_rng(seed, key, advance.get(key, 0))
                      for key in self._iter_counts}
        self.modelled_ot_seconds = _account_offline_plan(
            self.iter_plan.repeat(self.iters), self.log)
        self.gen_seconds = 0.0
        self.wait_seconds = 0.0
        self.pool_bytes = 0          # PEAK concurrent device residency
        self._live_bytes = 0
        import threading
        self._lock = threading.Lock()
        self._executor = None
        if async_gen:
            from concurrent.futures import ThreadPoolExecutor
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="triple-dealer")
        self._pending: list = []     # generated-or-in-flight tranches, FIFO
        self._next_gen = 0           # next tranche index to dispatch
        self._current: dict | None = None
        self._current_bytes = 0
        self._cursors: dict[tuple, int] = {}
        self._served_in_tranche = 0
        self.served_iters = 0
        for _ in range(max(1, prefetch)):
            self._dispatch()
        if self._per_iter and self.iters:
            self._advance()
        # the first-tranche wait is construction (offline) time, already in
        # dealer_seconds — wait_seconds reports ONLINE stalls only
        self.wait_seconds = 0.0
        self.dealer_seconds = time.perf_counter() - t0

    # -- tranche lifecycle ----------------------------------------------
    def _generate(self, counts):
        t0 = time.perf_counter()
        pools, nbytes = _gen_tranche(self._rngs, counts)
        with self._lock:
            self.gen_seconds += time.perf_counter() - t0
            self._live_bytes += nbytes
            self.pool_bytes = max(self.pool_bytes, self._live_bytes)
        return pools, nbytes

    def _dispatch(self) -> None:
        """Queue generation of the next tranche (async on the worker) —
        covering `group` iterations (fewer for the tail). The single worker
        serializes tranches, so the per-class streams advance in tranche
        order no matter when the futures are submitted."""
        if self._next_gen >= self.iters:
            return
        g = min(self.group, self.iters - self._next_gen)
        self._next_gen += g
        counts = self._iter_counts if g == 1 else \
            {k: c * g for k, c in self._iter_counts.items()}
        if self._executor is None:
            self._pending.append((g, "done", self._generate(counts)))
        else:
            self._pending.append(
                (g, "fut", self._executor.submit(self._generate, counts)))

    def _advance(self) -> None:
        g, kind, payload = self._pending.pop(0)
        t0 = time.perf_counter()
        pools, nbytes = payload.result() if kind == "fut" else payload
        self.wait_seconds += time.perf_counter() - t0
        self._current, self._current_bytes = pools, nbytes
        self._tranche_iters = g
        self._cursors = {}
        self._served_in_tranche = 0

    def _drop_current(self) -> None:
        self._current = None
        with self._lock:
            self._live_bytes -= self._current_bytes
        self._current_bytes = 0

    def _finish_tranche(self) -> None:
        """Drop the consumed tranche and queue the next generation. The
        ADVANCE to the prefetched tranche is deferred to the next serve
        call: blocking here would make the LAST iteration of a tol
        early-stopped fit stall on randomness it is about to throw away."""
        self.served_iters += self._tranche_iters
        self._drop_current()
        self._cursors = {}
        self._served_in_tranche = 0
        self._dispatch()
        if self.served_iters >= self.iters and self._executor is not None:
            self._executor.shutdown(wait=False)

    # -- serving ---------------------------------------------------------
    def _next(self, kind: str, shape) -> tuple:
        key = _class_key(kind, shape)
        per_iter = self._iter_counts.get(key)
        if per_iter is None:
            raise PoolExhaustedError(
                f"no pool for {kind} {shape}: the offline plan never "
                "scheduled this shape-class (planner/online mismatch)")
        if self._current is None and self.served_iters < self.iters:
            self._advance()                  # lazy: first request of an iter
        i = self._cursors.get(key, 0)
        if self._current is None or i >= per_iter * self._tranche_iters:
            raise PoolExhaustedError(
                f"pool exhausted for {kind} {shape}: planned {per_iter} "
                f"requests/iteration x {self.iters} iterations, online "
                "asked for more")
        self._cursors[key] = i + 1
        out = self._current[key][i]
        self._served_in_tranche += 1
        if self._served_in_tranche == self._per_iter * self._tranche_iters:
            self._finish_tranche()
        return out

    def remaining(self) -> dict:
        """{class_key: unserved across ALL remaining iterations} — surplus
        after e.g. a tol early-stop (undispatched tranches are never even
        generated)."""
        rem_tranches = self.iters - self.served_iters
        out = {}
        for key, c in self._iter_counts.items():
            out[key] = rem_tranches * c - self._cursors.get(key, 0)
        return out

    def close(self) -> None:
        """Drop buffers and stop the worker — called by an early-stopped
        fit so the prefetched tranches and the executor thread don't outlive
        the loop (idempotent; a fully-served fit has already shut the worker
        down via the last tranche). `remaining()` stays valid after close:
        it is pure counter arithmetic."""
        if self._executor is not None:
            self._executor.shutdown(wait=True)   # let in-flight gen finish
        for _g, kind, payload in self._pending:
            pools, nbytes = payload.result() if kind == "fut" else payload
            del pools
            with self._lock:
                self._live_bytes -= nbytes
        self._pending.clear()
        if self._current is not None:
            self._drop_current()


# ---------------------------------------------------------------------------
# SlotDealer — per-(iteration, batch) tranches for the pipelined executor
# ---------------------------------------------------------------------------

def _gen_tranche_split(rngs: dict, counts_list: list):
    """Generate several consecutive tranches in ONE merged stacked draw per
    shape-class, then split the per-request tuples back out per tranche.
    Stream-identical to generating each tranche separately (the uint64
    draw-concatenation property) — this is what lets one worker wakeup
    amortize over several small slots. Returns [(pools, nbytes), ...]."""
    merged: dict[tuple, int] = {}
    for counts in counts_list:
        for key, c in counts.items():
            merged[key] = merged.get(key, 0) + c
    pools, _ = _gen_tranche(rngs, merged)
    cursors = {key: 0 for key in merged}
    out = []
    for counts in counts_list:
        slot_pools: dict[tuple, list] = {}
        slot_bytes = 0
        for key, c in counts.items():
            i = cursors[key]
            entries = pools[key][i:i + c]
            cursors[key] = i + c
            slot_pools[key] = entries
            slot_bytes += sum(int(a.size) * 8 for t in entries for a in t)
        out.append((slot_pools, slot_bytes))
    return out


class _SlotView(_TripleServing):
    """Dealer view over ONE acquired slot tranche: serves exactly the
    slot's planned requests (per-class cursors, `PoolExhaustedError` past
    them). Counters aggregate on the owning SlotDealer; when the last
    request is served the tranche's device buffers are released and the
    dealer's generation window frees a slot."""

    def __init__(self, dealer: "SlotDealer", index: int, pools: dict,
                 counts: dict, total: int, nbytes: int):
        self.dealer = dealer
        self.index = index
        self.log = dealer.log
        self._pools = pools
        self._counts = counts
        self._total = total
        self._nbytes = nbytes
        self._cursors: dict[tuple, int] = {}
        self._served = 0

    # the fit-level dealer counters live on the SlotDealer so results can
    # compare them across offline/pipeline modes
    @property
    def n_matmul(self):
        return self.dealer.n_matmul

    @n_matmul.setter
    def n_matmul(self, v):
        self.dealer.n_matmul = v

    @property
    def n_mul(self):
        return self.dealer.n_mul

    @n_mul.setter
    def n_mul(self, v):
        self.dealer.n_mul = v

    @property
    def n_bin(self):
        return self.dealer.n_bin

    @n_bin.setter
    def n_bin(self, v):
        self.dealer.n_bin = v

    def _next(self, kind: str, shape) -> tuple:
        key = _class_key(kind, shape)
        limit = self._counts.get(key)
        if limit is None:
            raise PoolExhaustedError(
                f"no pool for {kind} {shape} in slot {self.index}: the slot "
                "plan never scheduled this shape-class (planner/online "
                "mismatch)")
        i = self._cursors.get(key, 0)
        if i >= limit:
            raise PoolExhaustedError(
                f"slot {self.index} pool exhausted for {kind} {shape}: "
                f"planned {limit} requests, online asked for more")
        self._cursors[key] = i + 1
        out = self._pools[key][i]
        self._served += 1
        if self._served == self._total:
            self._pools = {}
            self.dealer._release(self.index, self._nbytes)
        return out


class SlotDealer:
    """Per-slot tranche pools for the pipelined minibatch executor
    (DESIGN.md §11).

    The offline schedule is a SEQUENCE of slot plans — e.g. per Lloyd
    iteration ``[S1(batch 0), S3(batch 0), S1(batch 1), ..., finalize]`` —
    and each slot's correlated randomness is generated as its own tranche
    from the SAME persistent per-class PCG64 streams as every other dealer,
    always in canonical slot order. ``acquire(i)`` hands slot i's tranche
    out as a dealer view; acquisition may run AHEAD of lower slots (the
    pipelined executor pins batch t+1's S1 tranche while batch t's launch
    is still in flight) without perturbing a single served word, because
    GENERATION order — not acquisition order — fixes the streams. That is
    the double-buffer contract that makes ``pipeline=True`` stream-identical
    to ``pipeline=False``.

    stream=False (the pooled offline phase): every slot is generated up
    front in one merged stacked draw per shape-class — PooledDealer
    residency and bulk-generation speed, slot-indexed serving. stream=True:
    a background worker generates slots in order with at most ``window``
    generated-but-unconsumed slots alive (backpressure), so peak residency
    is O(window x slot bytes) — independent of n and iters. ``group_bytes``
    merges consecutive small slots into one generation wakeup (still split
    and served per slot; "auto" targets GROUP_TRANCHE_BYTES).

    Bit-exact with ``PooledDealer(concat(slot_plans), seed)`` for any
    acquisition order that consumes each slot's own plan exactly
    (property-tested in tests/test_pipeline.py)."""

    def __init__(self, slot_plans, seed: int = 0, log: CommLog | None = None,
                 stream: bool = True, window: int = 4, async_gen: bool = True,
                 group_bytes: int | str = "auto", start_slot: int = 0):
        import threading
        t0 = time.perf_counter()
        self.slot_plans = [TriplePlan(list(p.requests)) for p in slot_plans]
        self.seed = seed
        self.log = log if log is not None else CommLog()
        self.stream = bool(stream)
        # checkpoint resume: slots < start_slot were consumed by the
        # interrupted run — never generated here; each class stream starts
        # advanced past their requests (canonical slot order fixes the
        # offsets), so slot start_slot serves the EXACT words it would have
        self.start_slot = int(start_slot)
        if not 0 <= self.start_slot <= len(self.slot_plans):
            raise IndexError(f"start_slot {start_slot} out of range "
                             f"({len(self.slot_plans)} slots planned)")
        self.n_matmul = 0
        self.n_mul = 0
        self.n_bin = 0
        self.gen_seconds = 0.0
        self.wait_seconds = 0.0      # online acquire() stalls
        self.pool_bytes = 0          # PEAK concurrent device residency
        self._live_bytes = 0
        self._live_slots = 0
        self._counts = [p.class_counts() for p in self.slot_plans]
        self._totals = [len(p) for p in self.slot_plans]
        keys = sorted({k for c in self._counts for k in c})
        skip: dict[tuple, int] = {}
        for counts in self._counts[:self.start_slot]:
            for key, c in counts.items():
                skip[key] = skip.get(key, 0) + c
        self._rngs = {key: _advanced_rng(seed, key, skip.get(key, 0))
                      for key in keys}
        # only the slots this dealer will actually generate hit its offline
        # books (a resumed fit's checkpoint already carries the full tallies)
        self.modelled_ot_seconds = _account_offline_plan(
            TriplePlan([r for p in self.slot_plans[self.start_slot:]
                        for r in p.requests]),
            self.log)
        if group_bytes == "auto":
            group_bytes = GROUP_TRANCHE_BYTES
        # partition the REMAINING slots into generation groups of
        # >= group_bytes each
        self._groups: list[tuple[int, int]] = []
        i = self.start_slot
        while i < len(self.slot_plans):
            j = i + 1
            b = 8 * self.slot_plans[i].pool_words()
            while j < len(self.slot_plans) and b < int(group_bytes):
                b += 8 * self.slot_plans[j].pool_words()
                j += 1
            self._groups.append((i, j))
            i = j
        self._ready: dict[int, tuple] = {}   # slot -> (pools, nbytes)
        self._acquired: set[int] = set(range(self.start_slot))
        self._served_class: dict[tuple, int] = dict(skip)
        self._cond = threading.Condition()
        self._closed = False
        self._error: BaseException | None = None
        self._next_group = 0
        self._max_requested = -1     # highest slot a caller is waiting on
        self._worker = None
        if not self.stream:
            # pooled: ONE merged generation pass over the remaining schedule
            for i, tr in enumerate(_gen_tranche_split(
                    self._rngs, self._counts[self.start_slot:]),
                    start=self.start_slot):
                self._ready[i] = tr
                self._live_bytes += tr[1]
                self._live_slots += 1
            self._next_group = len(self._groups)
            self.pool_bytes = self._live_bytes
        elif async_gen and self._groups:
            max_group = max(hi - lo for lo, hi in self._groups)
            self._window = max(int(window), max_group + 1)
            self._worker = threading.Thread(target=self._worker_loop,
                                            name="slot-dealer", daemon=True)
            self._worker.start()
        else:
            self._window = max(2, int(window))
        self.dealer_seconds = time.perf_counter() - t0

    # -- generation ------------------------------------------------------
    def _gen_group(self, gi: int) -> None:
        """Generate group gi's slots (caller holds no lock); fill _ready."""
        lo, hi = self._groups[gi]
        t0 = time.perf_counter()
        tranches = _gen_tranche_split(self._rngs, self._counts[lo:hi])
        with self._cond:
            self.gen_seconds += time.perf_counter() - t0
            for i, tr in zip(range(lo, hi), tranches):
                self._ready[i] = tr
                self._live_slots += 1
                self._live_bytes += tr[1]
            self.pool_bytes = max(self.pool_bytes, self._live_bytes)
            self._cond.notify_all()

    def _worker_loop(self) -> None:
        try:
            for gi, (lo, hi) in enumerate(self._groups):
                with self._cond:
                    # backpressure: hold generation at `window` live slots —
                    # unless a caller is already WAITING on a slot this
                    # group must be generated for (acquire can run ahead of
                    # consumption; stalling it here would deadlock)
                    while (self._live_slots + (hi - lo) > self._window
                           and lo > self._max_requested
                           and not self._closed):
                        self._cond.wait()
                    if self._closed:
                        return
                self._gen_group(gi)
        except BaseException as e:             # surface on the next acquire
            with self._cond:
                self._error = e
                self._cond.notify_all()

    # -- acquisition -----------------------------------------------------
    def acquire(self, i: int) -> _SlotView:
        """Slot i's tranche as a dealer view (blocking until generated).
        Each slot can be acquired exactly once; out-of-order acquisition is
        fine within the generation window — the words a slot serves are
        fixed at generation time."""
        if not 0 <= i < len(self.slot_plans):
            raise IndexError(f"slot {i} out of range "
                             f"({len(self.slot_plans)} slots planned)")
        t0 = time.perf_counter()
        with self._cond:
            if i in self._acquired:
                raise PoolExhaustedError(
                    f"slot {i} was already acquired: each slot serves its "
                    "plan exactly once")
            if self._worker is None:
                # inline generation (pooled mode is pre-filled; streamed
                # sync mode generates groups on demand, in canonical order)
                while i not in self._ready \
                        and self._next_group < len(self._groups):
                    gi = self._next_group
                    self._next_group += 1
                    self._cond.release()
                    try:
                        self._gen_group(gi)
                    finally:
                        self._cond.acquire()
            else:
                self._max_requested = max(self._max_requested, i)
                self._cond.notify_all()
                while i not in self._ready and self._error is None \
                        and not self._closed:
                    self._cond.wait()
            if self._error is not None:
                raise RuntimeError("slot-dealer worker failed") \
                    from self._error
            if i not in self._ready:
                raise PoolExhaustedError(f"slot {i} unavailable "
                                         "(dealer closed or out of range)")
            pools, nbytes = self._ready.pop(i)
            self._acquired.add(i)
            self.wait_seconds += time.perf_counter() - t0
        view = _SlotView(self, i, pools, self._counts[i], self._totals[i],
                         nbytes)
        if self._totals[i] == 0:               # empty slot: nothing to serve
            self._release(i, nbytes)
        return view

    def _release(self, i: int, nbytes: int) -> None:
        with self._cond:
            for key, c in self._counts[i].items():
                self._served_class[key] = self._served_class.get(key, 0) + c
            self._live_slots -= 1
            self._live_bytes -= nbytes
            self._cond.notify_all()

    def remaining(self) -> dict:
        """{class_key: unserved across unacquired + unconsumed slots} —
        surplus after e.g. a tol early-stop."""
        total: dict[tuple, int] = {}
        for counts in self._counts:
            for key, c in counts.items():
                total[key] = total.get(key, 0) + c
        return {key: c - self._served_class.get(key, 0)
                for key, c in total.items()}

    def close(self) -> None:
        """Early-stop cleanup: stop the worker and drop generated-but-
        unacquired tranches (idempotent)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        with self._cond:
            for i, (_pools, nbytes) in self._ready.items():
                self._live_slots -= 1
                self._live_bytes -= nbytes
            self._ready.clear()


# ---------------------------------------------------------------------------
# TripleBank — persistent cross-fit pool: provision once, serve many
# ---------------------------------------------------------------------------

def _key_to_str(key: tuple) -> str:
    return repr(tuple(key))


def _key_from_str(s: str) -> tuple:
    import ast
    return tuple(ast.literal_eval(s))


_SLOTS = {"matmul": 6, "mul": 6, "bin": 6, "rand": 1, "seed": 1}


def _provision_items(counts: dict, workers: int) -> list:
    """Deterministic split of a bulk-generation request into `(class_key,
    start, count)` chunks: whole classes, the heavy ones subdivided by word
    volume so no worker idles behind one giant class. A pure function of
    `(counts, workers)` — scheduling and completion order cannot influence
    which words a chunk draws, because each chunk re-derives its stream
    position from (class stream start, request offset) alone."""
    total = sum(int(c) * _class_words(k) for k, c in counts.items())
    target = max(1, -(-total // max(1, int(workers))))
    items = []
    for key in sorted(counts):
        count = int(counts[key])
        if count <= 0:
            continue
        wpr = _class_words(key)
        nchunks = max(1, min(count, -(-(count * wpr) // target)))
        base, extra = divmod(count, nchunks)
        start = 0
        for i in range(nchunks):
            cnt = base + (1 if i < extra else 0)
            items.append((key, start, cnt))
            start += cnt
    return items


def _gen_provision_item(states: dict, item: tuple) -> tuple:
    """Generate one chunk from a PRIVATE clone of its class stream, advanced
    to the chunk's request offset. `ring.rand_np` draws exactly
    `_class_words(key)` PCG64 words per request, so advance(start*words)
    lands the clone on the words request `start` of the serial stacked draw
    would consume — chunked generation concatenates to the serial draw
    bit-for-bit."""
    key, start, count = item
    rng = np.random.default_rng(0)
    rng.bit_generator.state = states[key]
    if start:
        rng.bit_generator.advance(int(start) * _class_words(key))
    kind = key[0]
    shape = key[1:] if kind == "matmul" else key[1]
    arrays = _gen_class(rng, kind, shape, count)
    stacked = tuple(jnp.asarray(a) for a in arrays)
    entries = [tuple(a[i] for a in stacked) for i in range(count)]
    nbytes = sum(int(a.size) * 8 for a in stacked)
    return entries, nbytes


def _run_provision_items(items: list, states: dict, workers: int) -> list:
    """Run the chunks on a thread pool; results come back in ITEM order
    regardless of completion order (positional assembly), which together
    with per-chunk stream derivation makes the whole pass order-oblivious."""
    from concurrent.futures import ThreadPoolExecutor
    with ThreadPoolExecutor(max_workers=int(workers)) as ex:
        return list(ex.map(lambda it: _gen_provision_item(states, it), items))


class TripleBank:
    """A persistent correlated-randomness store serving MANY protocol runs
    (fits, predict batches, scoring services) from one provisioning pass.

    Structure: one FIFO queue of per-request tensor tuples per shape-class,
    fed by the same per-class PCG64 streams as every dealer — so a freshly
    provisioned bank serves bit-identical words to a same-seeded
    `TrustedDealer` for any request sequence with matching per-class order
    (the PooledDealer property, lifted across runs). Plans are registered
    under a lookup key (`SecureKMeans.plan_predict`'s key — the predict-plan
    cache key — by convention) via `provision`; `dealer(key)` hands out a
    `BankDealer` view that draws from the shared class queues.

    Exhaustion: where PooledDealer raises `PoolExhaustedError` (the fit
    trace/online mismatch is a bug), a serving bank treats an empty class as
    *stock-out*, not corruption — with `auto_replenish` it synchronously
    generates one more tranche of the requesting key's registered plan
    (stream-continuous: the class streams simply advance) and keeps serving;
    the stall is counted in `replenish_events`/`replenish_seconds` so a
    service can size `copies` to keep replenishment off the online path.

    Persistence: `save`/`load` round-trip the unserved tranches AND the
    per-class RNG states via one `np.savez` archive, so a reloaded bank
    serves the exact words the original would have — and replenishes from
    the same stream positions.

    Thread safety: a standing `BankReplenisher` daemon may top the bank up
    while serving threads draw from it. Two locks split the contention:
    `_gen_lock` serializes every per-class STREAM advance (two concurrent
    generations from the same snapshot would fork a class stream and serve
    the same mask words twice — a correctness *and* privacy bug), while
    the short-critical-section `_lock` guards the queues and counters so
    the hot-path pop never waits behind a long generation unless the shelf
    is actually empty. Lock order is `_gen_lock` → `_lock`; nothing
    acquires `_gen_lock` while holding `_lock`.
    """

    def __init__(self, seed: int = 0, auto_replenish: bool = True,
                 log: CommLog | None = None):
        self.seed = int(seed)
        self.auto_replenish = auto_replenish
        self.log = log if log is not None else CommLog()
        self._rngs: dict[tuple, np.random.Generator] = {}
        self._queues: dict[tuple, list] = {}
        self._plans: dict[tuple, TriplePlan] = {}
        self._lock = threading.RLock()       # queues + counters
        self._gen_lock = threading.RLock()   # per-class stream advance
        self.modelled_ot_seconds = 0.0
        self.gen_seconds = 0.0
        self.replenish_seconds = 0.0
        self.replenish_events = 0
        self.pool_bytes = 0              # live (unserved) device bytes
        self.served_requests = 0
        self.consumed_class: dict[tuple, int] = {}   # lifetime pops per class

    # -- provisioning ----------------------------------------------------
    def _gen(self, counts: dict, workers: int = 1) -> None:
        t0 = time.perf_counter()
        with self._gen_lock:
            for key in counts:
                self._rngs.setdefault(key, _class_rng(self.seed, key))
            if workers <= 1 or len(counts) == 0:
                pools, nbytes = _gen_tranche(self._rngs, counts)
                with self._lock:
                    for key, entries in pools.items():
                        self._queues.setdefault(key, []).extend(entries)
                    self.pool_bytes += nbytes
            else:
                items = _provision_items(counts, workers)
                # snapshot the CURRENT stream positions: chunks are offsets
                # relative to where the serial draw would start
                states = {key: self._rngs[key].bit_generator.state
                          for key in counts}
                results = _run_provision_items(items, states, workers)
                with self._lock:
                    for (key, _start, _cnt), (entries, nbytes) in zip(
                            items, results):
                        self._queues.setdefault(key, []).extend(entries)
                        self.pool_bytes += nbytes
                # master streams end exactly where one stacked draw would
                for key, count in counts.items():
                    self._rngs[key].bit_generator.advance(
                        int(count) * _class_words(key))
            self.gen_seconds += time.perf_counter() - t0

    def provision(self, key, plan: TriplePlan, copies: int = 1,
                  workers: int = 1) -> None:
        """Register `plan` under the lookup `key` and bulk-generate
        `copies` executions' worth of it into the class queues (one stacked
        draw + one batched ring op per class, like PooledDealer). Calling
        again with the same key re-registers (a changed plan replaces the
        old one) and tops the stock up.

        `workers > 1` fans the generation out over a thread pool — the bulk
        of the work is GIL-releasing numpy RNG + einsum — split by
        shape-class (the per-class PCG64 streams make classes order-
        independent) and, within a heavy class, by `advance`-offset chunks.
        The produced words are bit-identical to the serial draw for ANY
        worker count and completion order (property-tested)."""
        key = tuple(key)
        with self._lock:
            self._plans[key] = TriplePlan(list(plan.requests))
        if copies > 0:
            with _trace.span("bank.provision", key=str(key),
                             copies=int(copies), workers=int(workers)):
                counts = {ck: c * int(copies)
                          for ck, c in plan.class_counts().items()}
                self._gen(counts, workers=workers)
                self.modelled_ot_seconds += _account_offline_plan(
                    plan.repeat(copies), self.log)

    def keys(self) -> list:
        with self._lock:
            return list(self._plans)

    def stock(self) -> dict:
        """{class_key: unserved request count} across the whole bank."""
        with self._lock:
            return {k: len(q) for k, q in self._queues.items()}

    def stock_copies(self, key) -> int:
        """Complete executions of `key`'s registered plan in stock: the
        min over its classes of shelf depth // per-execution count."""
        key = tuple(key)
        with self._lock:
            plan = self._plans[key]
            counts = plan.class_counts()
            if not counts:
                return 0
            return min(len(self._queues.get(ck, ())) // c
                       for ck, c in counts.items())

    def dealer(self, key, log: CommLog | None = None) -> "BankDealer":
        key = tuple(key)
        if key not in self._plans:
            raise KeyError(f"TripleBank has no plan registered under "
                           f"{key!r}; call provision() first")
        return BankDealer(self, key, log=log)

    # -- serving ---------------------------------------------------------
    def _pop(self, class_key: tuple, plan_key: tuple) -> tuple:
        while True:
            with self._lock:
                q = self._queues.get(class_key)
                if q:
                    out = q.pop(0)
                    self.pool_bytes -= sum(int(np.asarray(a).size) * 8
                                           for a in out)
                    self.served_requests += 1
                    self.consumed_class[class_key] = \
                        self.consumed_class.get(class_key, 0) + 1
                    return out
            # shelf empty: regenerate OUTSIDE the queue lock (generation is
            # long), then retry — a racing daemon top-up may beat us to it
            self._replenish(class_key, plan_key)

    def _replenish(self, class_key: tuple, plan_key: tuple) -> None:
        """Stock-out handling: regenerate the requesting key's whole plan
        (keeping its classes aligned for the next request) — or, for a
        class the plan never mentions, a single emergency request. Raises
        `PoolExhaustedError` only when replenishment is disabled.

        Serialized on `_gen_lock` against daemon top-ups: by the time the
        lock is held, a concurrent generation may already have restocked
        the shelf — then the wait was the whole stall (counted, no event)
        and no words are drawn."""
        if not self.auto_replenish:
            raise PoolExhaustedError(
                f"TripleBank stock-out for {class_key}: provisioned pool "
                "consumed and auto_replenish=False")
        t0 = time.perf_counter()
        with _trace.span("bank.replenish", class_key=str(class_key)), \
                self._gen_lock:
            with self._lock:
                restocked = bool(self._queues.get(class_key))
                plan = self._plans.get(tuple(plan_key))
            if restocked:
                self.replenish_seconds += time.perf_counter() - t0
                return
            if plan is not None and class_key in plan.class_counts():
                self._gen(plan.class_counts())
                self.modelled_ot_seconds += _account_offline_plan(
                    plan, self.log)
            else:
                self._gen({class_key: 1})
            self.replenish_events += 1
            self.replenish_seconds += time.perf_counter() - t0

    def consumed_counts(self) -> dict:
        """Cumulative per-class consumed-request counts (a copy) — what a
        `ServeCheckpointer` journals so a restart can `discard` its way
        back to the exact stream positions."""
        with self._lock:
            return dict(self.consumed_class)

    def discard(self, counts: dict) -> None:
        """Pop and DROP `counts[class_key]` requests per class — restart
        realignment. A reloaded bank's FIFOs sit at the provision-time
        snapshot; the requests a previous incarnation already consumed
        (journaled as cumulative per-class counts) are drained here before
        serving resumes, so no word is ever served twice across a crash.
        Exact because a class's served words are always the same stream
        prefix regardless of when (or under which plan) generation ran —
        popping past the journaled counts lands every stream exactly where
        the dead process left it."""
        for class_key in sorted(counts):
            n = int(counts[class_key])
            if n <= 0:
                continue
            with self._lock:
                plan_key = next(
                    (pk for pk, plan in self._plans.items()
                     if class_key in plan.class_counts()), class_key)
            for _ in range(n):
                self._pop(class_key, plan_key)

    # -- persistence -----------------------------------------------------
    BANK_FORMAT = "repro.triplebank"
    BANK_VERSION = 2

    def save(self, path: str) -> None:
        """One `np.savez` archive: per class, the unserved requests stacked
        per tensor slot, plus a JSON manifest carrying the class keys, RNG
        states (stream positions), registered plans, a format marker +
        version, and a CRC32 per array — so `load` can refuse a truncated,
        bit-flipped, or foreign file instead of serving garbage correlated
        randomness. The path is used VERBATIM (np.savez's silent '.npz'
        suffixing is bypassed by writing through a file handle), so
        save(p) -> load(p) always pairs up."""
        import json
        import zlib
        classes = []
        arrays = {}
        with self._gen_lock, self._lock:
            # every class with an RNG is saved, queued stock or not: stream
            # position is state even when the shelf is empty; both locks
            # make the (queues, stream positions) snapshot consistent
            # against a concurrent daemon top-up
            all_keys = set(self._rngs) | set(self._queues)
            for i, key in enumerate(sorted(all_keys)):
                q = self._queues.get(key, [])
                rng = self._rngs.get(key) or _class_rng(self.seed, key)
                n_slots = _SLOTS[key[0]]
                for s in range(n_slots):
                    if q:
                        arrays[f"c{i}_s{s}"] = np.stack(
                            [np.asarray(t[s], np.uint64) for t in q])
                classes.append({"key": _key_to_str(key), "count": len(q),
                                "rng_state": rng.bit_generator.state})
            plans = {
                _key_to_str(k): [[r.kind, list(r.shape) if r.kind != "matmul"
                                  else [list(r.shape[0]), list(r.shape[1])],
                                  r.tag] for r in plan.requests]
                for k, plan in self._plans.items()}
        checksums = {name: zlib.crc32(np.ascontiguousarray(a).tobytes())
                     for name, a in arrays.items()}
        manifest = {"format": self.BANK_FORMAT, "version": self.BANK_VERSION,
                    "seed": self.seed, "classes": classes, "plans": plans,
                    "checksums": checksums}
        with open(path, "wb") as f:
            np.savez(f, manifest=np.frombuffer(
                json.dumps(manifest).encode(), np.uint8), **arrays)

    @classmethod
    def load(cls, path: str, auto_replenish: bool = True,
             log: CommLog | None = None) -> "TripleBank":
        """Load a `save`d bank, validating format, version, and per-array
        checksums. Any structural damage — truncation, bit flips, a foreign
        npz, an unreadable manifest — raises `ValueError` naming the
        problem; a corrupt bank must never silently serve wrong words."""
        import json
        import zipfile
        import zlib

        def bad(reason: str) -> ValueError:
            return ValueError(f"not a valid TripleBank file {path!r}: "
                              f"{reason}")
        try:
            z = np.load(path)
        except (zipfile.BadZipFile, OSError, EOFError, ValueError) as e:
            raise bad(f"unreadable archive ({e})") from e
        with z:
            if "manifest" not in getattr(z, "files", ()):
                raise bad("no manifest (foreign or pre-format npz)")
            try:
                manifest = json.loads(bytes(z["manifest"]).decode())
            except (zipfile.BadZipFile, OSError, EOFError, KeyError,
                    UnicodeDecodeError, json.JSONDecodeError,
                    ValueError) as e:
                raise bad(f"manifest unreadable ({e})") from e
            if not isinstance(manifest, dict) \
                    or manifest.get("format") != cls.BANK_FORMAT:
                raise bad("manifest format marker missing or foreign")
            if manifest.get("version") != cls.BANK_VERSION:
                raise bad(f"format version {manifest.get('version')!r}, "
                          f"expected {cls.BANK_VERSION}")
            try:
                checksums = manifest["checksums"]
                expected_names = set(checksums)
                stored = set(z.files) - {"manifest"}
                if stored != expected_names:
                    raise bad("archive arrays do not match the manifest "
                              f"(missing {sorted(expected_names - stored)}, "
                              f"unexpected {sorted(stored - expected_names)})")
                loaded = {}
                for name in sorted(expected_names):
                    try:
                        a = z[name]
                    except (zipfile.BadZipFile, OSError, EOFError,
                            ValueError) as e:
                        raise bad(f"array {name!r} unreadable — truncated "
                                  f"archive? ({e})") from e
                    crc = zlib.crc32(np.ascontiguousarray(a).tobytes())
                    if crc != int(checksums[name]):
                        raise bad(f"checksum mismatch on array {name!r} "
                                  "(bit rot or tampering)")
                    loaded[name] = a
                bank = cls(seed=manifest["seed"],
                           auto_replenish=auto_replenish, log=log)
                for i, entry in enumerate(manifest["classes"]):
                    key = _key_from_str(entry["key"])
                    rng = np.random.default_rng(0)
                    rng.bit_generator.state = entry["rng_state"]
                    bank._rngs[key] = rng
                    count = int(entry["count"])
                    if count:
                        slots = [jnp.asarray(loaded[f"c{i}_s{s}"])
                                 for s in range(_SLOTS[key[0]])]
                        if any(a.shape[0] != count for a in slots):
                            raise bad(f"class {entry['key']} declares "
                                      f"{count} requests but arrays "
                                      "disagree")
                        bank._queues[key] = [tuple(a[j] for a in slots)
                                             for j in range(count)]
                        bank.pool_bytes += sum(int(a.size) * 8
                                               for a in slots)
                plans_raw = manifest["plans"]
            except ValueError:
                raise
            except (KeyError, IndexError, TypeError, SyntaxError) as e:
                raise bad(f"malformed manifest structure ({e})") from e
        for kstr, reqs in plans_raw.items():
            reqs = [PlanRequest(kind,
                                (tuple(shape[0]), tuple(shape[1]))
                                if kind == "matmul" else tuple(shape), tag)
                    for kind, shape, tag in reqs]
            bank._plans[_key_from_str(kstr)] = TriplePlan(reqs)
        return bank


class BankReplenisher:
    """Standing top-up daemon for a `TripleBank`: a background thread that
    watches per-plan stock and regenerates BEFORE the hot path runs dry,
    so steady-state replenishment leaves the online path entirely.

    Policy: whenever a registered plan key's complete-execution stock
    (`bank.stock_copies`) falls to `low_water` or below, provision enough
    copies to restore `high_water`. Generation happens on this thread
    under the bank's `_gen_lock`, so a top-up can never fork a class
    stream against a hot-path synchronous replenish — and because every
    class FIFO is only ever extended with its own stream's next words,
    the words SERVED are bit-exact with a purely synchronous bank no
    matter how daemon and hot-path generation interleave (property-
    tested). If the daemon falls behind, `TripleBank._pop` still degrades
    gracefully to the PR-4 synchronous replenish with its stall
    accounting intact.

    A generation failure is recorded (`errors`, `last_error`) and the
    daemon keeps polling — the service must keep serving off the
    synchronous path rather than die with its supervisor."""

    def __init__(self, bank: TripleBank, *, low_water: int = 1,
                 high_water: int | None = None, poll_s: float = 0.002,
                 workers: int = 1, keys=None):
        self.bank = bank
        self.low_water = max(0, int(low_water))
        self.high_water = int(high_water) if high_water is not None \
            else max(self.low_water + 1, 2 * self.low_water)
        if self.high_water <= self.low_water:
            raise ValueError(
                f"high_water ({self.high_water}) must exceed low_water "
                f"({self.low_water}) or the daemon top-up never gains stock")
        self.poll_s = float(poll_s)
        self.workers = int(workers)
        self._keys = None if keys is None else [tuple(k) for k in keys]
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self.topups = 0                 # top-up passes that generated
        self.topup_copies = 0           # plan executions generated
        self.topup_seconds = 0.0        # daemon-side generation wall
        self.errors = 0
        self.last_error: BaseException | None = None

    # -- one scan over the registered plans ------------------------------
    def poll_once(self) -> int:
        """Scan every watched key; top up those at/below the low-water
        mark. Returns the number of plan copies generated."""
        made = 0
        keys = self._keys if self._keys is not None else self.bank.keys()
        for key in keys:
            if self._stop.is_set():
                break
            with self.bank._lock:
                plan = self.bank._plans.get(tuple(key))
            if plan is None:
                continue
            have = self.bank.stock_copies(key)
            if have > self.low_water:
                continue
            need = self.high_water - have
            t0 = time.perf_counter()
            with _trace.span("bank.topup", key=str(key), copies=need,
                             stock=have):
                self.bank.provision(key, plan, copies=need,
                                    workers=self.workers)
            self.topup_seconds += time.perf_counter() - t0
            self.topups += 1
            self.topup_copies += need
            made += need
        return made

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.poll_once()
            except Exception as e:        # noqa: BLE001 — daemon must live
                self.errors += 1
                self.last_error = e
            self._stop.wait(self.poll_s)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "BankReplenisher":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="bank-replenisher", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def __enter__(self) -> "BankReplenisher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


class BankDealer(_TripleServing):
    """Dealer-interface view over a `TripleBank` for one plan key —
    interface-compatible with `TrustedDealer` (same methods and counters),
    so it drops into `SecureKMeans.predict(..., dealer=...)` and
    `materialize_offline`. `dealer_seconds` counts only replenishment
    stalls incurred while THIS view was serving (online time); provisioned
    generation stays on the bank's offline clock."""

    def __init__(self, bank: TripleBank, key: tuple,
                 log: CommLog | None = None):
        self.bank = bank
        self.key = tuple(key)
        self.log = log if log is not None else CommLog()
        self.dealer_seconds = 0.0
        self.modelled_ot_seconds = 0.0
        self.n_matmul = 0
        self.n_mul = 0
        self.n_bin = 0

    def _next(self, kind: str, shape) -> tuple:
        r0 = self.bank.replenish_seconds
        out = self.bank._pop(_class_key(kind, shape), self.key)
        self.dealer_seconds += self.bank.replenish_seconds - r0
        return out

    def skip(self, plan, reps: int = 1) -> None:
        """Drain `reps` executions of `plan` without serving them — resume
        support: realigns the bank's FIFO queues past the requests an
        earlier (checkpointed) run already consumed."""
        for _ in range(int(reps)):
            for r in plan.requests:
                self.bank._pop(_class_key(r.kind, r.shape), self.key)


class BankSlotDealer:
    """SlotDealer-compatible view over a provisioned `TripleBank` for the
    minibatch fit path: slot tranches for the pipelined executor, PINNED
    from the bank's class queues eagerly in canonical slot order at
    construction. The pipelined executor may `acquire` slots ahead of
    consumption; because the bank's FIFO positions were fixed at PROVISION
    time and this view drains every slot's words before the loop starts,
    acquisition order can never perturb a served word — the same contract
    `SlotDealer` gets from generation order, inherited from the bank.

    Bit-exact with `SlotDealer(slot_plans, seed)` when the bank was
    provisioned under the same seed with the concatenated slot plans: the
    bank's one stacked draw per class IS the SlotDealer's canonical-order
    per-class concatenation (test-enforced)."""

    def __init__(self, bank: TripleBank, key: tuple, slot_plans,
                 log: CommLog | None = None, start_slot: int = 0):
        t0 = time.perf_counter()
        self.bank = bank
        self.key = tuple(key)
        self.slot_plans = [TriplePlan(list(p.requests)) for p in slot_plans]
        self.log = log if log is not None else CommLog()
        self.start_slot = int(start_slot)
        if not 0 <= self.start_slot <= len(self.slot_plans):
            raise IndexError(f"start_slot {start_slot} out of range "
                             f"({len(self.slot_plans)} slots planned)")
        self.n_matmul = 0
        self.n_mul = 0
        self.n_bin = 0
        self.gen_seconds = 0.0
        self.wait_seconds = 0.0
        # provisioning's modelled OT cost lives on the bank's offline books
        self.modelled_ot_seconds = 0.0
        self._counts = [p.class_counts() for p in self.slot_plans]
        self._totals = [len(p) for p in self.slot_plans]
        self._served_class: dict[tuple, int] = {}
        self._acquired: set[int] = set()
        self._slots: list[tuple] = []
        self.pool_bytes = 0
        for si, plan in enumerate(self.slot_plans):
            if si < self.start_slot:
                # checkpoint resume against a FRESHLY provisioned bank:
                # the interrupted run consumed these slots' words, so drain
                # (and discard) them to keep the FIFO positions aligned —
                # slot start_slot then pops the exact entries it would have
                for r in plan.requests:
                    bank._pop(_class_key(r.kind, r.shape), self.key)
                for ck, c in self._counts[si].items():
                    self._served_class[ck] = self._served_class.get(ck, 0) + c
                self._acquired.add(si)
                self._slots.append((None, 0))
                continue
            pools: dict[tuple, list] = {}
            nbytes = 0
            for r in plan.requests:
                ck = _class_key(r.kind, r.shape)
                out = bank._pop(ck, self.key)
                pools.setdefault(ck, []).append(out)
                nbytes += sum(int(np.asarray(a).size) * 8 for a in out)
            self._slots.append((pools, nbytes))
            self.pool_bytes += nbytes
        # the pin (pops + any replenish stall) is offline-side dealer time
        self.dealer_seconds = time.perf_counter() - t0

    def acquire(self, i: int) -> _SlotView:
        if not 0 <= i < len(self.slot_plans):
            raise IndexError(f"slot {i} out of range "
                             f"({len(self.slot_plans)} slots planned)")
        if i in self._acquired:
            raise PoolExhaustedError(
                f"slot {i} was already acquired: each slot serves its "
                "plan exactly once")
        self._acquired.add(i)
        pools, nbytes = self._slots[i]
        self._slots[i] = (None, nbytes)          # hand ownership to the view
        view = _SlotView(self, i, pools, self._counts[i], self._totals[i],
                         nbytes)
        if self._totals[i] == 0:                 # empty slot: nothing to serve
            self._release(i, nbytes)
        return view

    def _release(self, i: int, nbytes: int) -> None:
        for key, c in self._counts[i].items():
            self._served_class[key] = self._served_class.get(key, 0) + c

    def remaining(self) -> dict:
        total: dict[tuple, int] = {}
        for counts in self._counts:
            for key, c in counts.items():
                total[key] = total.get(key, 0) + c
        return {key: c - self._served_class.get(key, 0)
                for key, c in total.items()}

    def close(self) -> None:
        self._slots = [(None, nb) for _pools, nb in self._slots]
