"""Communication accounting + network cost models for the 2PC protocols.

The simulated two parties live in one process, so "sending" is a no-op; what
matters for reproducing the paper's Tables 1-2 / Figures 2-4 is an *exact*
count of bytes and rounds, which are fully determined by tensor shapes. Every
protocol op reports its traffic here, tagged by Lloyd step (S1 distance /
S2 assignment / S3 update) and phase (online / offline).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass(frozen=True)
class NetModel:
    """One-way latency is rtt/2; paper quotes round-trip latency."""

    name: str
    bandwidth_bps: float
    rtt_s: float

    def time_s(self, nbytes: int, rounds: int) -> float:
        return nbytes * 8.0 / self.bandwidth_bps + rounds * self.rtt_s


# Paper Sec 5.1: LAN 10 Gbps / 0.02 ms RTT; WAN 20 Mbps / 40 ms RTT.
LAN = NetModel("LAN", 10e9, 0.02e-3)
WAN = NetModel("WAN", 20e6, 40e-3)


class CommLog:
    """Byte/round tallies keyed by (phase, tag)."""

    def __init__(self) -> None:
        self.bytes = defaultdict(int)   # (phase, tag) -> bytes
        self.rounds = defaultdict(int)  # (phase, tag) -> rounds

    def send(self, nbytes: int, *, tag: str = "misc", phase: str = "online",
             rounds: int = 1) -> None:
        self.bytes[(phase, tag)] += int(nbytes)
        self.rounds[(phase, tag)] += int(rounds)

    # ---- queries -------------------------------------------------------
    def total_bytes(self, phase: str | None = None) -> int:
        return sum(v for (p, _), v in self.bytes.items()
                   if phase is None or p == phase)

    def total_rounds(self, phase: str | None = None) -> int:
        return sum(v for (p, _), v in self.rounds.items()
                   if phase is None or p == phase)

    def by_tag(self, phase: str) -> dict:
        out = defaultdict(lambda: [0, 0])
        for (p, t), v in self.bytes.items():
            if p == phase:
                out[t][0] += v
        for (p, t), v in self.rounds.items():
            if p == phase:
                out[t][1] += v
        return {t: tuple(v) for t, v in out.items()}

    def time_estimate(self, net: NetModel, phase: str | None = None) -> float:
        return net.time_s(self.total_bytes(phase), self.total_rounds(phase))

    def merge(self, other: "CommLog", phase: str | None = None) -> None:
        """Accumulate another log's tallies (optionally one phase only).
        Used to replay the shape-determined per-iteration traffic of a
        compiled online step, whose protocol-level sends only fire at trace
        time."""
        for (p, t), v in other.bytes.items():
            if phase is None or p == phase:
                self.bytes[(p, t)] += v
        for (p, t), v in other.rounds.items():
            if phase is None or p == phase:
                self.rounds[(p, t)] += v

    def copy(self) -> "CommLog":
        """Independent tally copy — what the plan cache hands out, so one
        fit's replay merges never mutate the cached per-iteration log."""
        out = CommLog()
        out.merge(self)
        return out

    def snapshot(self) -> dict:
        return {"bytes": dict(self.bytes), "rounds": dict(self.rounds)}

    def reset(self) -> None:
        self.bytes.clear()
        self.rounds.clear()
