"""Communication accounting, network cost models, AND the real wire.

Two layers live here (DESIGN.md §13):

* **Accounting** — `CommLog` tallies bytes/rounds keyed by (phase, tag);
  every protocol op reports its traffic, which is fully determined by
  tensor shapes. This reproduces the paper's Tables 1-2 / Figures 2-4.
* **Transport** — the seam that makes those bytes *paid* instead of
  modelled. A `Transport` moves length-prefixed frames (monotonic
  sequence number + CRC32) between two endpoints: `LoopbackTransport`
  (in-process, zero-copy — the frame bytes object itself crosses the
  queue), `SocketTransport` (TCP), and a seeded `FaultyTransport` wrapper
  that drops/delays/duplicates/corrupts frames and severs connections on
  a deterministic schedule. `ReliableChannel`/`Responder` layer
  request/response reliability on top (retries with exponential backoff +
  jitter, per-op deadlines, idempotent receive via sequence-number
  dedup, heartbeat liveness); with a session `auth_key` both replace the
  CRC with a keyed BLAKE2b MAC (constant-time verified) so tampered or
  unkeyed frames are rejected like corruption. `WireSession` plugs into
  `CommLog`:
  when a log has a wire attached, every online `send`/`merge` ships its
  byte count as real frames to the peer process and counts the tally
  from the payload bytes that actually crossed — so a two-process fit
  pays its network cost while staying bit-exact with the in-process one.
"""
from __future__ import annotations

import dataclasses
import hashlib
import hmac
import io
import json
import logging
import struct
import threading
import time
import zlib
from collections import defaultdict

import numpy as np

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

_WIRE_LOG = logging.getLogger("repro.wire")


@dataclasses.dataclass(frozen=True)
class NetModel:
    """One-way latency is rtt/2; paper quotes round-trip latency."""

    name: str
    bandwidth_bps: float
    rtt_s: float

    def time_s(self, nbytes: int, rounds: int) -> float:
        return nbytes * 8.0 / self.bandwidth_bps + rounds * self.rtt_s


# Paper Sec 5.1: LAN 10 Gbps / 0.02 ms RTT; WAN 20 Mbps / 40 ms RTT.
LAN = NetModel("LAN", 10e9, 0.02e-3)
WAN = NetModel("WAN", 20e6, 40e-3)


class CommLog:
    """Byte/round tallies keyed by (phase, tag).

    Thread-safe: the pipelined executor's background generation worker and
    the main thread may both land on one shared log, so every tally
    mutation/read holds `_lock` (defaultdict `+=` is a read-modify-write —
    not atomic even under the GIL).

    `wire`: when a `WireSession` is attached, online-phase `send`/`merge`
    traffic is SHIPPED over it as real frames before being tallied, and
    the tally comes from the session's reported payload bytes. The wire is
    deliberately NOT inherited by `copy()` (plan-cache copies and scratch
    logs must never touch the network) and `restore()` bypasses it
    (replaying a checkpoint's tallies is bookkeeping, not traffic).
    """

    def __init__(self) -> None:
        self.bytes = defaultdict(int)   # (phase, tag) -> bytes
        self.rounds = defaultdict(int)  # (phase, tag) -> rounds
        self.wire: "WireSession | None" = None
        self._lock = threading.Lock()

    def send(self, nbytes: int, *, tag: str = "misc", phase: str = "online",
             rounds: int = 1) -> None:
        nbytes, rounds = int(nbytes), int(rounds)
        if self.wire is not None and phase == "online" \
                and (nbytes or rounds):
            # pay the traffic: the tally is the payload byte count that
            # actually crossed the wire (== nbytes; WireSession asserts it)
            nbytes = self.wire.exchange(nbytes, rounds)
        with self._lock:
            self.bytes[(phase, tag)] += nbytes
            self.rounds[(phase, tag)] += rounds

    # ---- queries -------------------------------------------------------
    def total_bytes(self, phase: str | None = None) -> int:
        with self._lock:
            return sum(v for (p, _), v in self.bytes.items()
                       if phase is None or p == phase)

    def total_rounds(self, phase: str | None = None) -> int:
        with self._lock:
            return sum(v for (p, _), v in self.rounds.items()
                       if phase is None or p == phase)

    def by_tag(self, phase: str) -> dict:
        out = defaultdict(lambda: [0, 0])
        with self._lock:
            for (p, t), v in self.bytes.items():
                if p == phase:
                    out[t][0] += v
            for (p, t), v in self.rounds.items():
                if p == phase:
                    out[t][1] += v
        return {t: tuple(v) for t, v in out.items()}

    def time_estimate(self, net: NetModel, phase: str | None = None) -> float:
        return net.time_s(self.total_bytes(phase), self.total_rounds(phase))

    def merge(self, other: "CommLog", phase: str | None = None) -> None:
        """Accumulate another log's tallies (optionally one phase only).
        Used to replay the shape-determined per-iteration traffic of a
        compiled online step, whose protocol-level sends only fire at trace
        time. With a wire attached, the merged online traffic is shipped
        (one aggregate exchange of the other log's online bytes/rounds) —
        this is where a two-process fit on the compiled fast path pays its
        per-iteration network cost."""
        with other._lock:
            ob = dict(other.bytes)
            orn = dict(other.rounds)
        if self.wire is not None and phase in (None, "online"):
            nb = sum(v for (p, _), v in ob.items() if p == "online")
            nr = sum(v for (p, _), v in orn.items() if p == "online")
            if nb or nr:
                self.wire.exchange(nb, nr)
        with self._lock:
            for (p, t), v in ob.items():
                if phase is None or p == phase:
                    self.bytes[(p, t)] += v
            for (p, t), v in orn.items():
                if phase is None or p == phase:
                    self.rounds[(p, t)] += v

    def copy(self) -> "CommLog":
        """Independent tally copy — what the plan cache hands out, so one
        fit's replay merges never mutate the cached per-iteration log.
        The wire is NOT copied: a scratch/cached log never pays traffic."""
        out = CommLog()
        with self._lock:
            for k, v in self.bytes.items():
                out.bytes[k] += v
            for k, v in self.rounds.items():
                out.rounds[k] += v
        return out

    def snapshot(self) -> dict:
        with self._lock:
            return {"bytes": dict(self.bytes), "rounds": dict(self.rounds)}

    def state(self) -> dict:
        """JSON-serializable tally state (tuple keys flattened) — what a
        `FitCheckpoint` stores."""
        with self._lock:
            return {"bytes": [[p, t, v] for (p, t), v in self.bytes.items()],
                    "rounds": [[p, t, v]
                               for (p, t), v in self.rounds.items()]}

    def restore(self, state: dict) -> None:
        """Replace the tallies with a `state()` snapshot. Bypasses the
        wire: restoring a checkpoint replays bookkeeping, not traffic."""
        with self._lock:
            self.bytes.clear()
            self.rounds.clear()
            for p, t, v in state["bytes"]:
                self.bytes[(p, t)] = int(v)
            for p, t, v in state["rounds"]:
                self.rounds[(p, t)] = int(v)

    def reset(self) -> None:
        with self._lock:
            self.bytes.clear()
            self.rounds.clear()


# ===========================================================================
# Frame codec — length-prefixed, sequence-numbered, CRC32-guarded
# ===========================================================================

FRAME_MAGIC = 0x4B4D5732          # "KMW2"
_HEADER = struct.Struct(">IBQII")  # magic, ftype, seq, payload len, crc32
HEADER_BYTES = _HEADER.size        # 21
MAX_FRAME_PAYLOAD = 1 << 30

# request frame types; a response echoes the type with RESP_BIT set
T_EXCHANGE = 1     # payload: u32 reply_len + engine's half of the round
T_BLOB = 2         # payload: u32 json_len + json meta + npz raw
T_HEARTBEAT = 3    # liveness probe, empty payload both ways
T_BYE = 4          # orderly shutdown of the responder loop
T_SCORE = 5        # scoring request: blob of {rid, deadline_s} + x_a/x_b
T_RESUME = 6       # resume negotiation: JSON {op, inc, step, fp} both ways
RESP_BIT = 0x80

# optional trace-id header extension: a frame whose ftype carries
# TRACE_BIT prefixes its payload with an 8-byte request trace id, INSIDE
# the CRC/MAC coverage (the id rides the existing checksum; a flipped
# trace byte is corruption like any other). Frames without the bit are
# byte-identical to the PR-8 format — old and new endpoints interoperate
# on traceless traffic, and an old endpoint treats an unexpected
# TRACE_BIT ftype like any unknown type (responder answers empty) rather
# than mis-parsing, since the bit never collides with RESP_BIT (0x80) or
# the type space (1..5).
TRACE_BIT = 0x40
TRACE_ID_BYTES = 8

# keyed frames replace the CRC32 with a BLAKE2b MAC appended to the payload
AUTH_TAG_BYTES = 16


class FrameError(ValueError):
    """Structurally invalid frame (bad magic / impossible length)."""


class FrameCorrupt(FrameError):
    """Well-formed frame whose CRC32 does not match its payload."""


class WireError(RuntimeError):
    """Reliable-channel failure: retries exhausted or protocol violation."""


class WireTimeout(WireError):
    """A per-op deadline expired before the peer answered."""


class ResumeMismatch(WireError):
    """Resume negotiation rejected: the two parties' config fingerprints
    disagree, so no common checkpoint step can be bit-exact. Terminal —
    restarting won't help; the supervisor must NOT respawn on it."""


def _crc(ftype: int, seq: int, payload) -> int:
    return zlib.crc32(payload, zlib.crc32(struct.pack(">BQ", ftype, seq)))


def session_key(passphrase: str | bytes) -> bytes:
    """Derive a 32-byte wire session key from a shared passphrase (what
    `--auth-key` feeds). Key agreement itself is out of scope — the paper's
    deployment assumes the two parties share credentials out of band."""
    raw = passphrase.encode() if isinstance(passphrase, str) else passphrase
    return hashlib.blake2b(raw, digest_size=32).digest()


def _mac(key: bytes, ftype: int, seq: int, payload: bytes) -> bytes:
    """Keyed BLAKE2b MAC over (type, seq, payload) — same coverage as the
    CRC, but unforgeable without the session key. The sequence number is
    inside the MAC, so a tampered frame can't be replayed under a
    different seq either."""
    return hashlib.blake2b(struct.pack(">BQ", ftype, seq) + payload,
                           key=key, digest_size=AUTH_TAG_BYTES).digest()


def encode_frame(ftype: int, seq: int, payload: bytes = b"", *,
                 key: bytes | None = None,
                 trace_id: bytes | None = None) -> bytes:
    """Encode one frame. With a session `key`, the CRC32 is REPLACED by a
    keyed MAC: the tag is appended to the payload and the header checksum
    field is zeroed, so keyed and unkeyed endpoints reject each other's
    frames the same way they reject corruption. With a `trace_id`
    (exactly `TRACE_ID_BYTES`), the ftype carries `TRACE_BIT` and the id
    is prepended to the payload under the same CRC/MAC coverage; without
    one the emitted bytes are identical to the pre-trace format."""
    if trace_id is not None:
        if len(trace_id) != TRACE_ID_BYTES:
            raise ValueError(f"trace_id must be {TRACE_ID_BYTES} bytes, "
                             f"got {len(trace_id)}")
        ftype |= TRACE_BIT
        payload = trace_id + payload
    if key is None:
        return _HEADER.pack(FRAME_MAGIC, ftype, seq, len(payload),
                            _crc(ftype, seq, payload)) + payload
    body = payload + _mac(key, ftype, seq, payload)
    return _HEADER.pack(FRAME_MAGIC, ftype, seq, len(body), 0) + body


def _split_trace(ftype: int, payload: bytes, seq: int):
    """Strip the TRACE_BIT extension: (base ftype, payload, trace_id)."""
    if not ftype & TRACE_BIT:
        return ftype, payload, None
    if len(payload) < TRACE_ID_BYTES:
        raise FrameCorrupt(f"TRACE_BIT frame on seq {seq} shorter than "
                           "its trace id")
    return (ftype & ~TRACE_BIT, payload[TRACE_ID_BYTES:],
            payload[:TRACE_ID_BYTES])


def decode_frame(buf: bytes, *, key: bytes | None = None,
                 with_trace: bool = False):
    """Decode ONE complete frame; raises `FrameError`/`FrameCorrupt`.
    With a session `key`, the trailing MAC is verified (constant-time)
    instead of the CRC; unkeyed or tampered frames fail exactly like
    corrupt ones and are dropped/resent by the reliability layer.

    Returns `(ftype, seq, payload)`; with `with_trace=True` returns
    `(ftype, seq, payload, trace_id | None)` — TRACE_BIT stripped from
    the ftype and the 8-byte id split off the payload. The default
    3-tuple keeps every pre-trace call site working; a traced frame
    decoded without `with_trace` surfaces its raw extended form."""
    if len(buf) < HEADER_BYTES:
        raise FrameError(f"short frame: {len(buf)} < header {HEADER_BYTES}")
    magic, ftype, seq, length, crc = _HEADER.unpack_from(buf)
    if magic != FRAME_MAGIC:
        raise FrameError(f"bad magic {magic:#x}")
    if length > MAX_FRAME_PAYLOAD or len(buf) != HEADER_BYTES + length:
        raise FrameCorrupt(
            f"length field {length} vs actual {len(buf) - HEADER_BYTES}")
    body = buf[HEADER_BYTES:]
    if key is not None:
        if length < AUTH_TAG_BYTES:
            raise FrameCorrupt(f"unauthenticated frame on seq {seq} "
                               "(no MAC tag)")
        payload, tag = body[:-AUTH_TAG_BYTES], body[-AUTH_TAG_BYTES:]
        if not hmac.compare_digest(tag, _mac(key, ftype, seq, payload)):
            raise FrameCorrupt(f"MAC mismatch on seq {seq}")
    else:
        if _crc(ftype, seq, body) != crc:
            raise FrameCorrupt(f"crc mismatch on seq {seq}")
        payload = body
    if not with_trace:
        return ftype, seq, payload
    ftype, payload, trace_id = _split_trace(ftype, payload, seq)
    return ftype, seq, payload, trace_id


class _RateLimitedWarn:
    """At most one warning line per `interval_s` per event kind — chaos
    schedules inject hundreds of corrupt frames and the point is a
    diagnosable log, not a flooded one. Suppressed occurrences are
    summarized in the next emitted line."""

    def __init__(self, interval_s: float = 5.0):
        self.interval_s = float(interval_s)
        self._lock = threading.Lock()
        self._state: dict[str, list] = {}   # kind -> [last_emit, muted]

    def warn(self, kind: str, msg: str) -> None:
        now = time.monotonic()
        with self._lock:
            last, muted = self._state.get(kind, (None, 0))
            if last is not None and now - last < self.interval_s:
                self._state[kind] = [last, muted + 1]
                return
            self._state[kind] = [now, 0]
        if muted:
            msg += f" (+{muted} similar suppressed)"
        _WIRE_LOG.warning(msg)


_rate_warn = _RateLimitedWarn()


class FrameDecoder:
    """Incremental frame parser over an arbitrary byte stream: `feed`
    chunks of any size (split reads welcome) and collect complete frames.
    Integrity-failed frames are dropped and counted (`crc_errors`; keyed
    decoders additionally count MAC failures in `auth_errors`); a bad
    magic means the byte stream itself desynced — unrecoverable without a
    reconnect — so it raises `FrameError`. Every drop/desync is also
    routed to the metrics registry (`repro_frame_*_total`) and surfaces
    as a rate-limited `repro.wire` warning, so chaos-test noise is
    diagnosable from logs alone instead of sitting in a bare counter."""

    def __init__(self, key: bytes | None = None) -> None:
        self._buf = bytearray()
        self.key = key
        self.crc_errors = 0
        self.auth_errors = 0
        reg = _metrics.get_registry()
        self._m_crc = reg.counter("repro_frame_crc_errors_total")
        self._m_auth = reg.counter("repro_frame_auth_errors_total")
        self._m_desync = reg.counter("repro_frame_resync_events_total")

    def feed(self, data: bytes) -> list[tuple[int, int, bytes]]:
        self._buf += data
        out = []
        while len(self._buf) >= HEADER_BYTES:
            magic, _ftype, _seq, length, _crc_f = _HEADER.unpack_from(
                self._buf)
            if magic != FRAME_MAGIC:
                self._m_desync.inc()
                _rate_warn.warn("desync",
                                f"frame stream desync: bad magic "
                                f"{magic:#x} with {len(self._buf)} B "
                                "buffered; connection must reconnect")
                raise FrameError(f"bad magic {magic:#x}: stream desync")
            if length > MAX_FRAME_PAYLOAD:
                self._m_desync.inc()
                _rate_warn.warn("desync",
                                f"frame stream desync: oversized frame "
                                f"({length} B)")
                raise FrameError(f"oversized frame ({length} B)")
            end = HEADER_BYTES + length
            if len(self._buf) < end:
                break
            frame = bytes(self._buf[:end])
            del self._buf[:end]
            try:
                out.append(decode_frame(frame, key=self.key))
            except FrameCorrupt as e:
                self.crc_errors += 1
                if self.key is not None:
                    self.auth_errors += 1
                    self._m_auth.inc()
                    _rate_warn.warn("auth",
                                    f"dropped unauthenticated frame: {e}")
                else:
                    self._m_crc.inc()
                    _rate_warn.warn("crc",
                                    f"dropped corrupt frame: {e}")
        return out

    def pending(self) -> int:
        return len(self._buf)


# ===========================================================================
# Transport seam — frame movers
# ===========================================================================

@dataclasses.dataclass
class TransportStats:
    frames_sent: int = 0
    frames_recv: int = 0
    wire_bytes_sent: int = 0          # frame bytes incl. headers
    wire_bytes_recv: int = 0
    reconnects: int = 0


class Transport:
    """The seam every wire backend implements: move opaque encoded frames
    between two endpoints. Discrete-frame semantics (one `send_frame` ==
    one `recv_frame` on the peer); delivery may fail with
    `ConnectionError` (endpoint severed — `reconnect()` and retry) or
    `TimeoutError` (nothing arrived within the recv deadline). Reliability
    is NOT this layer's job — `ReliableChannel` adds it on top."""

    def __init__(self) -> None:
        self.stats = TransportStats()

    def send_frame(self, frame: bytes) -> None:
        raise NotImplementedError

    def recv_frame(self, timeout: float | None = None) -> bytes:
        raise NotImplementedError

    def reconnect(self) -> None:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError


class _LoopbackState:
    """Shared half of a loopback pair: two inboxes + liveness flag."""

    def __init__(self) -> None:
        self.cond = threading.Condition()
        self.queues = ([], [])
        self.alive = True
        self.closed = False


class LoopbackTransport(Transport):
    """In-process transport: the encoded frame bytes object itself is
    appended to the peer's inbox (zero-copy — no serialization, no
    syscalls), preserving the current single-process behavior while
    exercising the exact frame path the socket backend uses. `sever()`
    drops the connection for BOTH endpoints (fault injection);
    `reconnect()` revives it, losing any in-flight frames — like a TCP
    reset."""

    def __init__(self, state: _LoopbackState, side: int):
        super().__init__()
        self._st = state
        self._side = side

    @classmethod
    def pair(cls) -> tuple["LoopbackTransport", "LoopbackTransport"]:
        st = _LoopbackState()
        return cls(st, 0), cls(st, 1)

    def send_frame(self, frame: bytes) -> None:
        st = self._st
        with st.cond:
            if st.closed or not st.alive:
                raise ConnectionError("loopback severed")
            st.queues[1 - self._side].append(frame)
            self.stats.frames_sent += 1
            self.stats.wire_bytes_sent += len(frame)
            st.cond.notify_all()

    def recv_frame(self, timeout: float | None = None) -> bytes:
        st = self._st
        deadline = None if timeout is None else time.monotonic() + timeout
        with st.cond:
            q = st.queues[self._side]
            while not q:
                if st.closed or not st.alive:
                    raise ConnectionError("loopback severed")
                remaining = None if deadline is None \
                    else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("loopback recv timed out")
                st.cond.wait(remaining)
            frame = q.pop(0)
            self.stats.frames_recv += 1
            self.stats.wire_bytes_recv += len(frame)
            return frame

    def sever(self) -> None:
        with self._st.cond:
            self._st.alive = False
            self._st.cond.notify_all()

    def reconnect(self) -> None:
        st = self._st
        with st.cond:
            if st.closed:
                raise ConnectionError("loopback closed")
            st.alive = True
            st.queues[self._side].clear()   # in-flight frames died with the
            self.stats.reconnects += 1      # old connection
            st.cond.notify_all()

    def close(self) -> None:
        with self._st.cond:
            self._st.closed = True
            self._st.alive = False
            self._st.cond.notify_all()


class SocketTransport(Transport):
    """TCP transport: length-prefixed frames over one stream socket.

    `mode="listen"` binds (port 0 picks a free port — read `.port`) and
    accepts lazily; `mode="connect"` dials with bounded retries and
    exponential backoff + seeded jitter (a peer that hasn't bound yet is
    the normal case at two-process startup). A torn connection surfaces as
    `ConnectionError`; `reconnect()` re-accepts / re-dials. A bad magic in
    the byte stream means desync — the connection is dropped rather than
    resynchronized."""

    def __init__(self, mode: str, host: str = "127.0.0.1", port: int = 0, *,
                 io_timeout_s: float = 30.0, connect_retries: int = 12,
                 backoff_s: float = 0.05, backoff_max_s: float = 2.0,
                 jitter_seed: int = 1):
        super().__init__()
        import socket as socketlib
        if mode not in ("listen", "connect"):
            raise ValueError(f"mode must be 'listen' or 'connect', "
                             f"got {mode!r}")
        self.mode = mode
        self.host = host
        self.io_timeout_s = float(io_timeout_s)
        self.connect_retries = int(connect_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_max_s = float(backoff_max_s)
        self._jitter = np.random.default_rng(jitter_seed)
        self._socketlib = socketlib
        self._conn = None
        self._listener = None
        if mode == "listen":
            s = socketlib.socket(socketlib.AF_INET, socketlib.SOCK_STREAM)
            s.setsockopt(socketlib.SOL_SOCKET, socketlib.SO_REUSEADDR, 1)
            s.bind((host, port))
            s.listen(1)
            self._listener = s
            self.port = s.getsockname()[1]
        else:
            self.port = int(port)

    # -- connection lifecycle -------------------------------------------
    def _backoff(self, attempt: int) -> float:
        base = min(self.backoff_max_s, self.backoff_s * (2 ** attempt))
        return base * (0.5 + float(self._jitter.random()))

    def _ensure(self) -> None:
        if self._conn is not None:
            return
        sk = self._socketlib
        if self.mode == "listen":
            self._listener.settimeout(self.io_timeout_s)
            try:
                conn, _addr = self._listener.accept()
            except TimeoutError:
                raise TimeoutError("accept timed out waiting for peer")
        else:
            last = None
            for attempt in range(self.connect_retries + 1):
                try:
                    conn = sk.create_connection(
                        (self.host, self.port), timeout=self.io_timeout_s)
                    break
                except OSError as e:
                    last = e
                    time.sleep(self._backoff(attempt))
            else:
                raise ConnectionError(
                    f"connect to {self.host}:{self.port} failed after "
                    f"{self.connect_retries + 1} attempts: {last}")
        conn.setsockopt(sk.IPPROTO_TCP, sk.TCP_NODELAY, 1)
        conn.settimeout(self.io_timeout_s)
        self._conn = conn

    def _drop(self) -> None:
        if self._conn is not None:
            try:
                self._conn.close()
            except OSError:
                pass
            self._conn = None

    def reconnect(self) -> None:
        self._drop()
        self.stats.reconnects += 1
        # lazily re-accepted / re-dialed on the next send/recv

    def close(self) -> None:
        self._drop()
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None

    # -- frame IO --------------------------------------------------------
    def send_frame(self, frame: bytes) -> None:
        self._ensure()
        try:
            self._conn.sendall(frame)
        except (OSError, ValueError) as e:
            self._drop()
            raise ConnectionError(f"send failed: {e}") from e
        self.stats.frames_sent += 1
        self.stats.wire_bytes_sent += len(frame)

    def _read_exact(self, n: int, deadline: float | None) -> bytes:
        chunks = []
        got = 0
        while got < n:
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("recv timed out")
                self._conn.settimeout(remaining)
            try:
                chunk = self._conn.recv(min(1 << 20, n - got))
            except TimeoutError:
                raise
            except OSError as e:
                self._drop()
                raise ConnectionError(f"recv failed: {e}") from e
            if not chunk:
                self._drop()
                raise ConnectionError("peer closed the connection")
            chunks.append(chunk)
            got += len(chunk)
        return b"".join(chunks)

    def recv_frame(self, timeout: float | None = None) -> bytes:
        self._ensure()
        deadline = None if timeout is None else time.monotonic() + timeout
        try:
            header = self._read_exact(HEADER_BYTES, deadline)
            magic, _ftype, _seq, length, _crc = _HEADER.unpack_from(header)
            if magic != FRAME_MAGIC or length > MAX_FRAME_PAYLOAD:
                self._drop()
                raise ConnectionError("frame stream desync (bad magic)")
            payload = self._read_exact(length, deadline) if length else b""
        finally:
            if self._conn is not None:
                self._conn.settimeout(self.io_timeout_s)
        frame = header + payload
        self.stats.frames_recv += 1
        self.stats.wire_bytes_recv += len(frame)
        return frame


@dataclasses.dataclass
class FaultStats:
    dropped: int = 0
    duplicated: int = 0
    corrupted: int = 0
    delayed: int = 0
    severed: int = 0


class FaultyTransport(Transport):
    """Deterministic fault injector around any `Transport` (send side).

    Each outgoing frame draws its fate from a seeded PCG64 stream indexed
    by send order, so a given (seed, rates, schedule) replays the same
    fault sequence every run: `drop` (never delivered), `dup` (delivered
    twice — exercises the receiver's seq dedup), `corrupt` (one bit
    flipped — caught by CRC32), `delay_s` (+ seeded `delay_jitter_s`)
    sleeps before delivery (one-way latency; set to `rtt/2` on BOTH
    endpoints to emulate a `NetModel`), `bandwidth_bps` adds a
    size-proportional serialization sleep, and `sever_at` (an iterable of
    send indices) tears the connection down at exactly those frames.
    """

    def __init__(self, inner: Transport, *, seed: int = 0,
                 drop: float = 0.0, dup: float = 0.0, corrupt: float = 0.0,
                 delay_s: float = 0.0, delay_jitter_s: float = 0.0,
                 bandwidth_bps: float | None = None,
                 sever_at: tuple | set | list = ()):
        super().__init__()
        self.inner = inner
        self._rng = np.random.default_rng(seed)
        self.drop = float(drop)
        self.dup = float(dup)
        self.corrupt = float(corrupt)
        self.delay_s = float(delay_s)
        self.delay_jitter_s = float(delay_jitter_s)
        self.bandwidth_bps = bandwidth_bps
        self.sever_at = set(int(i) for i in sever_at)
        self.faults = FaultStats()
        self._n_sent = 0

    @classmethod
    def emulate(cls, inner: Transport, net: NetModel,
                **kw) -> "FaultyTransport":
        """Latency/bandwidth emulation of a `NetModel` with no faults:
        one-way delay rtt/2 + bytes/bandwidth per frame. Wrap BOTH
        endpoints so each direction pays its half of the RTT."""
        return cls(inner, delay_s=net.rtt_s / 2.0,
                   bandwidth_bps=net.bandwidth_bps, **kw)

    @property
    def stats(self) -> TransportStats:       # delegate wire accounting
        return self.inner.stats

    @stats.setter
    def stats(self, v) -> None:              # Transport.__init__ writes it
        pass

    def send_frame(self, frame: bytes) -> None:
        i = self._n_sent
        self._n_sent += 1
        if i in self.sever_at:
            self.faults.severed += 1
            if hasattr(self.inner, "sever"):
                self.inner.sever()
            else:
                self.inner.reconnect()
            raise ConnectionError("fault injection: connection severed")
        sleep = 0.0
        if self.delay_s or self.delay_jitter_s:
            sleep += self.delay_s \
                + self.delay_jitter_s * float(self._rng.random())
            self.faults.delayed += 1
        if self.bandwidth_bps:
            sleep += len(frame) * 8.0 / float(self.bandwidth_bps)
        if sleep > 0.0:
            time.sleep(sleep)
        if self.drop and float(self._rng.random()) < self.drop:
            self.faults.dropped += 1
            return
        out = frame
        if self.corrupt and float(self._rng.random()) < self.corrupt:
            ba = bytearray(frame)
            pos = int(self._rng.integers(len(ba)))
            ba[pos] ^= 1 << int(self._rng.integers(8))
            out = bytes(ba)
            self.faults.corrupted += 1
        self.inner.send_frame(out)
        if self.dup and float(self._rng.random()) < self.dup:
            self.inner.send_frame(out)
            self.faults.duplicated += 1

    def recv_frame(self, timeout: float | None = None) -> bytes:
        return self.inner.recv_frame(timeout)

    def sever(self) -> None:
        if hasattr(self.inner, "sever"):
            self.inner.sever()

    def reconnect(self) -> None:
        self.inner.reconnect()

    def close(self) -> None:
        self.inner.close()


# ===========================================================================
# Reliable request/response channel
# ===========================================================================

class ReliableChannel:
    """Engine side of the wire protocol: strictly sequential
    request/response with at-least-once delivery and exactly-once effect.

    Each request gets the next monotonic sequence number; the frame is
    (re)sent until the matching response arrives, with exponential backoff
    + seeded jitter between tries, a per-try `try_timeout_s`, a per-op
    `deadline_s`, and `max_retries` before `WireError`. A torn connection
    triggers `Transport.reconnect()` and a resend. Because the responder
    dedups by sequence number (answering a replayed request from its
    response cache), redelivery is safe: drops, duplicates, and corrupt
    frames all collapse to 'resend until the response lands'.

    `reconnect_wait_s` is the *park budget* for supervised deployments:
    when the connection tears (peer crashed and is being restarted), up
    to that much additional time per request is spent parked — redial
    attempts inside the park window consume neither `max_retries` nor
    the original deadline, so a peer that takes seconds to respawn and
    re-import its runtime does not kill the survivor. The park window is
    bounded: once spent, normal retry/deadline accounting resumes, so
    total peer silence is still capped at deadline + park budget."""

    def __init__(self, transport: Transport, *, deadline_s: float = 30.0,
                 try_timeout_s: float = 0.5, max_retries: int = 10,
                 backoff_s: float = 0.02, backoff_max_s: float = 0.5,
                 jitter_seed: int = 7, auth_key: bytes | None = None,
                 reconnect_wait_s: float = 0.0):
        self.t = transport
        self.auth_key = auth_key
        self.deadline_s = float(deadline_s)
        self.try_timeout_s = float(try_timeout_s)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_max_s = float(backoff_max_s)
        self.reconnect_wait_s = float(reconnect_wait_s)
        self._jitter = np.random.default_rng(jitter_seed)
        self._seq = 0
        self.retries = 0
        self.crc_drops = 0
        self.reconnects = 0
        self.parked_s = 0.0
        reg = _metrics.get_registry()
        self._m_retries = reg.counter("repro_wire_retries_total")
        self._m_crc_drops = reg.counter("repro_wire_resp_drops_total")
        self._m_reconnects = reg.counter("repro_wire_reconnects_total")
        self._h_rtt = reg.histogram(
            "repro_wire_request_seconds",
            buckets=_metrics.log_buckets(1e-5, 30.0))
        self._h_backoff = reg.histogram(
            "repro_wire_backoff_seconds",
            buckets=_metrics.log_buckets(1e-3, 10.0))

    def request(self, ftype: int, payload: bytes = b"", *,
                deadline_s: float | None = None,
                trace_id: bytes | None = None) -> bytes:
        with _trace.span("wire.request", ftype=ftype, seq=self._seq):
            return self._request(ftype, payload, deadline_s=deadline_s,
                                 trace_id=trace_id)

    def _request(self, ftype: int, payload: bytes, *,
                 deadline_s: float | None,
                 trace_id: bytes | None) -> bytes:
        seq = self._seq
        self._seq += 1
        frame = encode_frame(ftype, seq, payload, key=self.auth_key,
                             trace_id=trace_id)
        want = ftype | RESP_BIT
        t0 = time.monotonic()
        deadline = t0 + (self.deadline_s if deadline_s is None
                         else float(deadline_s))
        attempt = 0
        park_until = None    # set on first sever when a park budget exists
        while True:
            now = time.monotonic()
            if now >= deadline and (park_until is None
                                    or now >= park_until):
                raise WireTimeout(
                    f"request seq={seq} ftype={ftype} deadline expired "
                    f"after {attempt} tries"
                    + (f" (incl. {self.reconnect_wait_s}s park)"
                       if park_until is not None else ""))
            try:
                self.t.send_frame(frame)
                limit = min(max(deadline, park_until or 0.0),
                            time.monotonic() + self.try_timeout_s)
                while True:
                    remaining = limit - time.monotonic()
                    if remaining <= 0:
                        break                      # per-try timeout: resend
                    try:
                        raw = self.t.recv_frame(remaining)
                    except TimeoutError:
                        break
                    try:
                        ft, rseq, rpayload, _rtid = decode_frame(
                            raw, key=self.auth_key, with_trace=True)
                    except FrameError:
                        self.crc_drops += 1   # corrupt/forged: wait/resend
                        self._m_crc_drops.inc()
                        continue
                    if ft == want and rseq == seq:
                        self._h_rtt.observe(time.monotonic() - t0)
                        return rpayload
                    # stale duplicate response of an earlier seq: ignore
            except ConnectionError:
                self.reconnects += 1
                self._m_reconnects.inc()
                self.t.reconnect()
                if self.reconnect_wait_s > 0.0:
                    now = time.monotonic()
                    if park_until is None:
                        park_until = now + self.reconnect_wait_s
                        _WIRE_LOG.warning(
                            "peer connection lost on seq %d: parking up "
                            "to %.1fs for a restart", seq,
                            self.reconnect_wait_s)
                    if now < park_until:
                        # parked: wait out the peer restart without
                        # charging the retry budget; deadline extends to
                        # the park window (bounded), not forever
                        pause = min(self.backoff_max_s, 0.2) \
                            * (0.5 + float(self._jitter.random()))
                        self.parked_s += pause
                        _trace.instant("wire.park", seq=seq)
                        time.sleep(pause)
                        continue
            attempt += 1
            self.retries += 1
            self._m_retries.inc()
            _trace.instant("wire.retry", seq=seq, attempt=attempt)
            if attempt > self.max_retries:
                raise WireError(
                    f"request seq={seq} ftype={ftype} failed after "
                    f"{attempt} tries (retries exhausted)")
            base = min(self.backoff_max_s, self.backoff_s * (2 ** (attempt - 1)))
            pause = base * (0.5 + float(self._jitter.random()))
            self._h_backoff.observe(pause)
            time.sleep(pause)


class Responder:
    """Peer side: decode, dedup by sequence number, answer via `handler`.

    Idempotent receive: the last (seq, response) pair is cached, so a
    redelivered request — duplicate frame, or a resend after the response
    was lost — is answered from the cache WITHOUT re-invoking the handler.
    A request older than the cache is a late duplicate and is dropped.
    CRC-corrupt frames are discarded (the engine resends); with an
    `auth_key`, tampered or unkeyed frames are dropped the same way.
    Silence beyond `idle_timeout_s` raises `WireTimeout` — the engine's
    heartbeats are what keep a long offline phase alive.

    Incarnation reset: a restarted engine begins a fresh sequence space
    at 0, which the stale-duplicate rule would silently drop forever. Its
    first request is therefore a `T_RESUME` carrying an incarnation nonce;
    when the nonce differs from the last one seen, the dedup window is
    reset BEFORE the seq checks — old-incarnation responses can never be
    replayed to the new incarnation, and the new sequence space starts
    clean. Same-incarnation duplicates still replay from the cache."""

    def __init__(self, transport: Transport, handler, *,
                 idle_timeout_s: float = 120.0,
                 auth_key: bytes | None = None):
        self.t = transport
        self.handler = handler
        self.auth_key = auth_key
        self.idle_timeout_s = float(idle_timeout_s)
        self.crc_drops = 0
        self.stale_drops = 0
        self.dedup_replays = 0
        self.reconnects = 0
        self.served = 0
        self.incarnation_resets = 0
        self._last_seq = -1
        self._last_resp: bytes | None = None
        self._incarnation: str | None = None
        reg = _metrics.get_registry()
        self._m_crc_drops = reg.counter("repro_responder_crc_drops_total")
        self._m_dedup = reg.counter("repro_responder_dedup_replays_total")
        self._m_stale = reg.counter("repro_responder_stale_drops_total")

    def _reply(self, resp: bytes) -> None:
        try:
            self.t.send_frame(resp)
        except ConnectionError:
            # the engine will reconnect and resend; the dedup cache then
            # re-serves this response without re-running the handler
            self.reconnects += 1
            self.t.reconnect()

    def serve_forever(self) -> None:
        # the idle deadline bounds TOTAL peer silence — recv timeouts and
        # failed redials alike. Without the budget, a dead engine would
        # livelock this loop: recv raises ConnectionError, the lazy redial
        # inside the next recv fails with ConnectionError too, and the
        # except arm would reconnect forever, never reaching the timeout.
        last_frame = time.monotonic()
        while True:
            budget = self.idle_timeout_s - (time.monotonic() - last_frame)
            try:
                if budget <= 0:
                    raise TimeoutError
                raw = self.t.recv_frame(budget)
            except TimeoutError:
                raise WireTimeout(
                    f"peer silent for {self.idle_timeout_s}s "
                    "(no request or heartbeat)")
            except ConnectionError:
                self.reconnects += 1
                self.t.reconnect()
                continue
            last_frame = time.monotonic()
            try:
                ftype, seq, payload, trace_id = decode_frame(
                    raw, key=self.auth_key, with_trace=True)
            except FrameError:
                self.crc_drops += 1
                self._m_crc_drops.inc()
                continue
            if ftype & RESP_BIT:
                continue                           # echo of our own class
            if ftype == T_RESUME:
                inc = _resume_incarnation(payload)
                if inc is not None and inc != self._incarnation:
                    # a (re)started engine announced itself: reset the
                    # dedup window so its fresh seq space isn't mistaken
                    # for stale duplicates of the previous incarnation
                    if self._incarnation is not None:
                        self.incarnation_resets += 1
                        _WIRE_LOG.warning(
                            "peer incarnation changed (%s -> %s): "
                            "resetting dedup window at seq %d",
                            self._incarnation, inc, self._last_seq)
                    self._incarnation = inc
                    self._last_seq, self._last_resp = -1, None
            if seq == self._last_seq:
                self.dedup_replays += 1
                self._m_dedup.inc()
                self._reply(self._last_resp)
                continue
            if seq < self._last_seq:
                self.stale_drops += 1              # late duplicate
                self._m_stale.inc()
                continue
            # the frame's trace id becomes this thread's ambient trace for
            # the handler's whole downstream (spans tag themselves with it)
            # and is echoed on the response so the requester can match
            if trace_id is not None:
                _trace.set_current_trace(_trace.trace_id_from_bytes(
                    trace_id))
            try:
                with _trace.span("wire.handle", ftype=ftype, seq=seq):
                    resp_payload = self.handler(ftype, payload)
            finally:
                if trace_id is not None:
                    _trace.set_current_trace(None)
            resp = encode_frame(ftype | RESP_BIT, seq, resp_payload,
                                key=self.auth_key, trace_id=trace_id)
            self._last_seq, self._last_resp = seq, resp
            self.served += 1
            self._reply(resp)
            if ftype == T_BYE:
                return


# ===========================================================================
# Resume negotiation — T_RESUME payload helpers + peer progress marker
# ===========================================================================

def _resume_incarnation(payload: bytes) -> str | None:
    """Best-effort incarnation nonce from a T_RESUME payload (the dedup
    reset must work even when the handler later rejects the message)."""
    try:
        v = json.loads(payload.decode())
        inc = v.get("inc")
        return str(inc) if inc is not None else None
    except Exception:
        return None


class PeerProgress:
    """The data party's durable record of fit progress: the latest
    checkpoint step the engine *published* (announced via a T_RESUME
    `publish` message after each atomic checkpoint rename) plus the
    config fingerprint it was published under.

    This is party B's half of the resume negotiation: on an engine
    (re)start the `hello` answers with (step, fingerprint) so both sides
    can agree on `min(step)`. B lagging behind A (engine died between
    rename and notify) is safe — the agreed step is then merely older,
    and resuming from an older published step is still bit-exact.

    With a `path` the marker is persisted atomically (tmp + fsync +
    `os.replace`) so it survives B's own crashes; without one it lives
    in memory (single-process tests)."""

    def __init__(self, path: str | None = None):
        import os
        self._os = os
        self.path = path
        self.step = -1                      # -1 == nothing published yet
        self.fingerprint: str | None = None
        if path is not None and os.path.exists(path):
            try:
                with open(path, "r", encoding="utf-8") as f:
                    d = json.load(f)
                self.step = int(d.get("step", -1))
                self.fingerprint = d.get("fingerprint") or None
            except (OSError, ValueError):
                _WIRE_LOG.warning("unreadable progress marker %s; "
                                  "starting from scratch", path)

    def update(self, step: int, fingerprint: str | None) -> None:
        step = int(step)
        if step < self.step:
            return                          # never move backwards
        self.step = step
        if fingerprint:
            self.fingerprint = fingerprint
        if self.path is None:
            return
        os = self._os
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump({"step": self.step,
                       "fingerprint": self.fingerprint}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        _fsync_dir(os.path.dirname(os.path.abspath(self.path)))


def _fsync_dir(path: str) -> None:
    """fsync a directory so a just-renamed entry survives power loss."""
    import os
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return                              # e.g. non-POSIX; best effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def handle_resume(meta: dict, progress: PeerProgress) -> dict:
    """Responder-side T_RESUME logic, shared by `serve_peer` and tests.

    `hello` (engine (re)start): reject with a typed error when both
    sides hold fingerprints that disagree — no common step can be
    bit-exact, and restarting won't fix a config mismatch; otherwise
    answer our recorded (step, fingerprint). `publish`: record the
    engine's newly published checkpoint step."""
    op = meta.get("op")
    fp = meta.get("fp") or None
    if progress.fingerprint and fp and fp != progress.fingerprint:
        return {"error": "fingerprint-mismatch",
                "ours": progress.fingerprint, "theirs": fp}
    if op == "publish":
        progress.update(int(meta.get("step", -1)), fp)
        return {"ok": 1}
    # hello: bind our fingerprint on first contact so a future restart
    # of the engine under a different config is rejected
    if fp and progress.fingerprint is None:
        progress.update(progress.step, fp)
    return {"step": progress.step, "fp": progress.fingerprint}


# ===========================================================================
# WireSession — the CommLog plug + blob/heartbeat helpers
# ===========================================================================

def _pack_blob(meta: dict, arrays: dict | None = None) -> bytes:
    j = json.dumps(meta).encode()
    raw = b""
    if arrays:
        bio = io.BytesIO()
        np.savez(bio, **arrays)
        raw = bio.getvalue()
    return struct.pack(">I", len(j)) + j + raw


def _unpack_blob(payload: bytes) -> tuple[dict, dict]:
    (jlen,) = struct.unpack_from(">I", payload)
    meta = json.loads(payload[4:4 + jlen].decode())
    arrays = {}
    raw = payload[4 + jlen:]
    if raw:
        with np.load(io.BytesIO(raw)) as z:
            arrays = {k: z[k] for k in z.files}
    return meta, arrays


class WireSession:
    """Engine-side session over a `ReliableChannel`; what `CommLog.wire`
    points at. `exchange(nbytes, rounds)` performs `rounds` sequential
    request/response round-trips whose payloads total exactly `nbytes`
    (engine ships the ceil-half, the peer echoes the floor-half) — the
    modelled traffic, paid for real: rounds cost RTTs, bytes cost
    bandwidth. `send_arrays` moves real tensors (input upload, result
    download); `heartbeat` probes liveness."""

    def __init__(self, channel: ReliableChannel,
                 incarnation: str | None = None):
        self.chan = channel
        self.payload_bytes = 0        # protocol bytes shipped (both ways)
        self.rounds = 0
        self.blobs = 0
        self.incarnation = incarnation

    # -- resume negotiation ---------------------------------------------
    def _resume_request(self, body: dict,
                        deadline_s: float | None = None) -> dict:
        payload = json.dumps(body, sort_keys=True).encode()
        resp = self.chan.request(T_RESUME, payload, deadline_s=deadline_s)
        try:
            meta = json.loads(resp.decode()) if resp else {}
        except ValueError as e:
            raise WireError(f"malformed resume response: {e}") from e
        if meta.get("error") == "fingerprint-mismatch":
            raise ResumeMismatch(
                f"peer rejected resume: its fingerprint "
                f"{meta.get('ours')} != ours {body.get('fp')}")
        return meta

    def negotiate_resume(self, *, step: int, fingerprint: str | None,
                         deadline_s: float | None = None) -> int:
        """The (re)connect handshake (DESIGN.md §16): announce this
        incarnation + our latest published checkpoint step + config
        fingerprint; the peer answers with its recorded step. Returns
        the agreed resume step `min(ours, theirs)` (-1 == fresh start).
        Raises `ResumeMismatch` when the fingerprints disagree."""
        meta = self._resume_request(
            {"op": "hello", "inc": self.incarnation,
             "step": int(step), "fp": fingerprint},
            deadline_s=deadline_s)
        peer_step = int(meta.get("step", -1))
        return min(int(step), peer_step)

    def notify_publish(self, step: int, fingerprint: str | None) -> None:
        """Tell the peer a checkpoint step was atomically published, so
        its progress marker advances. Rides the reliable channel like any
        request; dying before OR after this notify is safe (the peer just
        lags, and min(step) resumes from the older published step)."""
        self._resume_request({"op": "publish", "inc": self.incarnation,
                              "step": int(step), "fp": fingerprint})

    def exchange(self, nbytes: int, rounds: int = 1) -> int:
        with _trace.span("wire.exchange", nbytes=int(nbytes),
                         rounds=int(rounds)):
            return self._exchange(int(nbytes), int(rounds))

    def _exchange(self, nbytes: int, rounds: int) -> int:
        rounds = max(1, int(rounds)) if nbytes else int(rounds)
        total = 0
        for r in range(rounds):
            this = nbytes // rounds + (1 if r < nbytes % rounds else 0)
            a_len = (this + 1) // 2
            b_len = this - a_len
            payload = struct.pack(">I", b_len) + bytes(a_len)
            resp = self.chan.request(T_EXCHANGE, payload)
            if len(resp) != b_len:
                raise WireError(
                    f"exchange round {r}: peer echoed {len(resp)} B, "
                    f"expected {b_len}")
            total += a_len + b_len
        if total != nbytes:
            raise WireError(f"exchange shipped {total} B != {nbytes} B")
        self.payload_bytes += total
        self.rounds += max(0, rounds)
        return total

    def send_arrays(self, meta: dict,
                    arrays: dict | None = None, *,
                    deadline_s: float | None = None) -> tuple[dict, dict]:
        resp = self.chan.request(T_BLOB, _pack_blob(meta, arrays),
                                 deadline_s=deadline_s)
        self.blobs += 1
        return _unpack_blob(resp)

    def heartbeat(self, deadline_s: float | None = None) -> None:
        self.chan.request(T_HEARTBEAT, b"", deadline_s=deadline_s)

    def bye(self) -> None:
        self.chan.request(T_BYE, b"")


def serve_peer(transport: Transport, *, on_blob=None,
               idle_timeout_s: float = 120.0,
               auth_key: bytes | None = None,
               progress: PeerProgress | None = None) -> Responder:
    """Run the data-party (responder) loop until the engine says BYE.

    EXCHANGE requests are answered with the requested echo half; BLOB
    requests go to `on_blob(meta, arrays) -> (meta, arrays) | None`;
    RESUME requests run the negotiation against `progress` (one is
    created in-memory when not given); heartbeats are acked empty.
    Returns the `Responder` (for its dedup / drop counters) once the
    engine closes the session."""
    from repro.core import faultpoints as _fp

    prog = progress if progress is not None else PeerProgress()

    def handler(ftype: int, payload: bytes) -> bytes:
        _fp.probe("wire.serve")
        if ftype == T_EXCHANGE:
            (b_len,) = struct.unpack_from(">I", payload)
            return bytes(b_len)
        if ftype == T_BLOB:
            meta, arrays = _unpack_blob(payload)
            out = on_blob(meta, arrays) if on_blob is not None else None
            out_meta, out_arrays = out if out is not None else ({}, None)
            return _pack_blob(out_meta, out_arrays)
        if ftype == T_RESUME:
            try:
                meta = json.loads(payload.decode())
            except ValueError:
                meta = {}
            return json.dumps(handle_resume(meta, prog),
                              sort_keys=True).encode()
        return b""                                 # heartbeat / bye

    r = Responder(transport, handler, idle_timeout_s=idle_timeout_s,
                  auth_key=auth_key)
    r.serve_forever()
    return r
