"""2PC protocol ops over additive shares (paper Sec 3.1 / 4.2).

Implemented: SADD (local), SMUL (elementwise + vectorized matmul via Beaver
triples), SecureML local truncation, A2B via a bit-packed Kogge-Stone adder
(log_2 l AND rounds instead of the naive l-round ripple carry), MSB, CMP,
B2A, MUX, the tournament argmin F^k_min (Fig. 1), and a Newton-Raphson
secure reciprocal used by the centroid-update division (paper: "secret
sharing division which is converted to SADD & SMUL operations").

Everything is vectorized: one CMP call compares whole (n, k/2) tensors, one
matmul call moves whole matrices — this IS the paper's vectorization claim.

All ops take a `Ctx` that carries the triple provider (offline phase) and the
communication log. Per-op traffic is shape-determined and exact.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import ring
from repro.core.backend import KS_LEVELS, RingBackend, get_backend
from repro.core.channel import CommLog
from repro.core.sharing import AShare, BShare
from repro.core.triples import TrustedDealer


FUSE_BEAVER = True
# P0's Beaver recombination z0 = z + u0@F + e@v0 + e@F folds into
# z + u0@F + e@(v0 + F): one fewer ring matmul on the online critical path
# (pure local algebra, no protocol/security change). Toggled off by the
# §Perf harness to measure the paper-faithful baseline.


@dataclasses.dataclass
class Ctx:
    dealer: TrustedDealer
    log: CommLog
    tag: str = "misc"  # current Lloyd step: S1 / S2 / S3
    backend: RingBackend | str | None = None  # local ring-compute dispatch
    he_seconds: float = 0.0  # modelled HE wall-time accumulated by Protocol 2

    def __post_init__(self):
        self.backend = get_backend(self.backend)

    def send(self, nbytes: int, rounds: int = 1) -> None:
        self.log.send(nbytes, tag=self.tag, phase="online", rounds=rounds)

    def add_he_seconds(self, t: float) -> None:
        self.he_seconds += t

    def fork(self, tag: str | None = None) -> "Ctx":
        """Child ctx sharing the dealer and backend but with a SCRATCH log.
        Used by the split-launch fast path's Protocol-2 host callbacks: the
        compiled programs' shape-determined traffic (the exchange's
        included) is replayed from the planning trace, so the live exchange
        must consume the dealer streams without double-logging bytes."""
        return Ctx(dealer=self.dealer, log=CommLog(),
                   tag=self.tag if tag is None else tag,
                   backend=self.backend)


def make_ctx(seed: int = 0, backend: RingBackend | str | None = None,
             wire=None) -> Ctx:
    log = CommLog()
    log.wire = wire  # online sends ship over the attached WireSession
    return Ctx(dealer=TrustedDealer(seed=seed, log=log), log=log,
               backend=backend)


# ---------------------------------------------------------------------------
# Linear ops — local, no communication (paper SADD)
# ---------------------------------------------------------------------------

def add(a: AShare, b: AShare) -> AShare:
    return AShare(a.s0 + b.s0, a.s1 + b.s1)


def sub(a: AShare, b: AShare) -> AShare:
    return AShare(a.s0 - b.s0, a.s1 - b.s1)


def add_pub(a: AShare, c) -> AShare:
    """a + c with public ring tensor c (added to one share only)."""
    c = jnp.asarray(c, ring.DTYPE)
    return AShare(a.s0 + c, a.s1)


def pub_sub(c, a: AShare) -> AShare:
    c = jnp.asarray(c, ring.DTYPE)
    return AShare(c - a.s0, ring.neg(a.s1))


def mul_pub(a: AShare, c) -> AShare:
    """a * c with public *integer* ring tensor c (scale-preserving)."""
    c = jnp.asarray(c, ring.DTYPE)
    return AShare(a.s0 * c, a.s1 * c)


def lshift(a: AShare, n: int) -> AShare:
    return AShare(a.s0 << n, a.s1 << n)


def neg(a: AShare) -> AShare:
    return AShare(ring.neg(a.s0), ring.neg(a.s1))


def matmul_pub_l(x_pub, a: AShare, backend: RingBackend | None = None) -> AShare:
    """Public X @ shared A — local at the party that owns X."""
    x_pub = jnp.asarray(x_pub, ring.DTYPE)
    return AShare(_ring_mm(x_pub, a.s0, backend),
                  _ring_mm(x_pub, a.s1, backend))


def matmul_pub_r(a: AShare, y_pub, backend: RingBackend | None = None) -> AShare:
    y_pub = jnp.asarray(y_pub, ring.DTYPE)
    return AShare(_ring_mm(a.s0, y_pub, backend),
                  _ring_mm(a.s1, y_pub, backend))


def _ring_mm(a, b, backend: RingBackend | None = None):
    """uint64 matmul mod 2^64, dispatched through the ring backend."""
    return get_backend(backend).ring_mm(a, b)


# ---------------------------------------------------------------------------
# Truncation (SecureML local truncation; error <= 2^-f w.h.p.)
# ---------------------------------------------------------------------------

def trunc(a: AShare, f: int = ring.F) -> AShare:
    """SecureML local truncation: P0 logically shifts its share; P1
    negates-shifts-negates. Off-by-2^-f LSB error w.h.p.; failure probability
    2^{f+1-l} per lane for |x| < 2^{l-f-1} (SecureML Thm. 1)."""
    if f == 0:
        return a
    s0 = a.s0 >> f                                   # logical shift (uint64)
    s1 = ring.neg(ring.neg(a.s1) >> f)
    return AShare(s0, s1)


# ---------------------------------------------------------------------------
# SMUL — Beaver multiplication (elementwise and matmul forms)
# ---------------------------------------------------------------------------

def smul(ctx: Ctx, a: AShare, b: AShare, *, trunc_f: int | None = None) -> AShare:
    """Elementwise product (broadcasting). One round: exchange E, F."""
    shape = jnp.broadcast_shapes(a.shape, b.shape)
    t = ctx.dealer.mul_triple(shape, tag=ctx.tag)
    a = AShare(jnp.broadcast_to(a.s0, shape), jnp.broadcast_to(a.s1, shape))
    b = AShare(jnp.broadcast_to(b.s0, shape), jnp.broadcast_to(b.s1, shape))
    e = (a.s0 - t.u.s0) + (a.s1 - t.u.s1)  # Rec(a - u)
    f = (b.s0 - t.v.s0) + (b.s1 - t.v.s1)  # Rec(b - v)
    # Both parties exchange their local (E,F) halves: 2 tensors each way.
    ctx.send(2 * 2 * ring.nbytes(shape), rounds=1)
    # ab = uv + u*f + e*v + e*f ;  z_i = z_t_i + u_i*f + e*v_i + [i==0]*e*f
    if FUSE_BEAVER:
        z0 = t.z.s0 + t.u.s0 * f + e * (t.v.s0 + f)
    else:
        z0 = t.z.s0 + t.u.s0 * f + e * t.v.s0 + e * f
    z1 = t.z.s1 + t.u.s1 * f + e * t.v.s1
    out = AShare(z0, z1)
    return trunc(out, trunc_f) if trunc_f else out


def smatmul(ctx: Ctx, a: AShare, b: AShare, *, trunc_f: int | None = None) -> AShare:
    """Secret-shared matrix product (paper's vectorized SMUL). One round."""
    (n, d), (d2, k) = a.shape, b.shape
    assert d == d2
    t = ctx.dealer.matmul_triple((n, d), (d, k), tag=ctx.tag)
    e = (a.s0 - t.u.s0) + (a.s1 - t.u.s1)
    f = (b.s0 - t.v.s0) + (b.s1 - t.v.s1)
    ctx.send(2 * (ring.nbytes((n, d)) + ring.nbytes((d, k))), rounds=1)
    mm = ctx.backend.ring_mm
    # AB = UV + U F + E V + E F
    if FUSE_BEAVER:  # P0: E@(V0 + F) fuses the public E@F term (see flag)
        z0 = t.z.s0 + mm(t.u.s0, f) + mm(e, t.v.s0 + f)
    else:
        z0 = t.z.s0 + mm(t.u.s0, f) + mm(e, t.v.s0) + mm(e, f)
    z1 = t.z.s1 + mm(t.u.s1, f) + mm(e, t.v.s1)
    out = AShare(z0, z1)
    return trunc(out, trunc_f) if trunc_f else out


def square(ctx: Ctx, a: AShare, *, trunc_f: int | None = None) -> AShare:
    return smul(ctx, a, a, trunc_f=trunc_f)


# ---------------------------------------------------------------------------
# Boolean layer: bit-packed AND / XOR, Kogge-Stone adder, MSB, CMP
# ---------------------------------------------------------------------------

def bxor(x: BShare, y: BShare) -> BShare:
    return BShare(x.b0 ^ y.b0, x.b1 ^ y.b1)


def bxor_pub(x: BShare, c) -> BShare:
    return BShare(x.b0 ^ jnp.asarray(c, ring.DTYPE), x.b1)


def band(ctx: Ctx, x: BShare, y: BShare) -> BShare:
    """Bit-packed AND via binary Beaver triple. One round, 64 gates/lane."""
    shape = jnp.broadcast_shapes(x.shape, y.shape)
    t = ctx.dealer.bin_triple(shape, tag=ctx.tag)
    x = BShare(jnp.broadcast_to(x.b0, shape), jnp.broadcast_to(x.b1, shape))
    y = BShare(jnp.broadcast_to(y.b0, shape), jnp.broadcast_to(y.b1, shape))
    e = (x.b0 ^ t.u.b0) ^ (x.b1 ^ t.u.b1)
    f = (y.b0 ^ t.v.b0) ^ (y.b1 ^ t.v.b1)
    ctx.send(2 * 2 * ring.nbytes(shape), rounds=1)
    # xy = (u^e)&(v^f) = uv ^ u&f ^ e&v ^ e&f
    z0 = t.z.b0 ^ (t.u.b0 & f) ^ (e & (t.v.b0 ^ f))
    z1 = t.z.b1 ^ (t.u.b1 & f) ^ (e & t.v.b1)
    return BShare(z0, z1)


def msb_carry(ctx: Ctx, a: AShare) -> BShare:
    """B-share of MSB(a.s0 + a.s1 mod 2^64) via Kogge-Stone carry network.

    Each party's arithmetic share is a *local plaintext* input to a boolean
    adder: X = (s0, 0), Y = (0, s1) as B-shares. log2(64)=6 AND rounds; the
    two ANDs per level (G and P updates) are batched into ONE round by
    stacking, so the whole MSB costs 7 rounds (1 initial + 6 levels).

    The per-level Beaver *recombination* is deferred: the exchange rounds
    only produce the public masked operands (E_l, F_l), and each party's
    share of the final carry word is ONE fused ``backend.ks_fused`` call over
    all 7 AND levels (kernels/ksadder on the pallas backend) instead of 12
    separate elementwise passes over the comparison tensor.
    """
    shape = tuple(a.shape)
    s0 = jnp.asarray(a.s0, ring.DTYPE)
    s1 = jnp.asarray(a.s1, ring.DTYPE)
    # Same triple shapes / draw order / traffic as the sequential band()
    # formulation, so offline accounting and ListDealer replay are unchanged.
    t0 = ctx.dealer.bin_triple(shape, tag=ctx.tag)
    ctx.send(2 * 2 * ring.nbytes(shape), rounds=1)        # exchange E0, F0
    lvl_shape = (2,) + shape
    lvl = []
    for _ in KS_LEVELS:
        lvl.append(ctx.dealer.bin_triple(lvl_shape, tag=ctx.tag))
        ctx.send(2 * 2 * ring.nbytes(lvl_shape), rounds=1)
    # Public masked operands per level. E_l/F_l reconstruct to
    # plaintext(lhs/rhs) ^ plaintext(triple) — exactly what band() computes
    # by combining both parties' messages — so the (g, p) evolution below is
    # the public transcript of the exchange rounds, not a security shortcut.
    e0 = s0 ^ (t0.u.b0 ^ t0.u.b1)
    f0 = s1 ^ (t0.v.b0 ^ t0.v.b1)
    g, p = s0 & s1, s0 ^ s1
    els, fls = [], []
    for li, s in enumerate(KS_LEVELS):
        t = lvl[li]
        els.append(jnp.stack([p, p]) ^ (t.u.b0 ^ t.u.b1))
        fls.append(jnp.stack([g << s, p << s]) ^ (t.v.b0 ^ t.v.b1))
        g = g ^ (p & (g << s))                 # g | (p & g<<s); disjoint => xor
        p = p & (p << s)
    el, fl = jnp.stack(els), jnp.stack(fls)    # (6, 2, *shape)
    ul = [jnp.stack([t.u.b0 for t in lvl]), jnp.stack([t.u.b1 for t in lvl])]
    vl = [jnp.stack([t.v.b0 for t in lvl]), jnp.stack([t.v.b1 for t in lvl])]
    zl = [jnp.stack([t.z.b0 for t in lvl]), jnp.stack([t.z.b1 for t in lvl])]
    g0 = ctx.backend.ks_fused(s0, e0, f0, t0.u.b0, t0.v.b0, t0.z.b0,
                              el, fl, ul[0], vl[0], zl[0], party0=True)
    g1 = ctx.backend.ks_fused(s1, e0, f0, t0.u.b1, t0.v.b1, t0.z.b1,
                              el, fl, ul[1], vl[1], zl[1], party0=False)
    # sum bit 63 = p_orig[63] ^ carry_in[63];  carry_in[63] = G[62]
    one = jnp.uint64(1)
    msb = bxor(BShare((s0 >> 63) & one, (s1 >> 63) & one),
               BShare((jnp.asarray(g0) >> 62) & one,
                      (jnp.asarray(g1) >> 62) & one))
    return msb  # single-bit B-share (values in {0,1})


def b2a_bit(ctx: Ctx, b: BShare) -> AShare:
    """Single-bit B-share -> A-share: b = b0 + b1 - 2*b0*b1.

    Each party arithmetically shares its own boolean share (one message each,
    half a round: batched into 1 round), then one Beaver product.
    """
    shape = b.shape
    one = jnp.uint64(1)
    b0, b1 = b.b0 & one, b.b1 & one     # LSB view of the packed share
    r0 = ctx.dealer.rand(shape)
    r1 = ctx.dealer.rand(shape)
    a0 = AShare(b0 - r0, r0)            # P0 shares its bit b0
    a1 = AShare(r1, b1 - r1)            # P1 shares its bit b1
    ctx.send(2 * ring.nbytes(shape), rounds=1)
    prod = smul(ctx, a0, a1)            # scale-1 bits: no truncation
    return sub(add(a0, a1), lshift(prod, 1))


def cmp_lt(ctx: Ctx, a: AShare, b: AShare) -> AShare:
    """CMP: A-share of the indicator [a < b] (signed fixed-point compare)."""
    shape = jnp.broadcast_shapes(a.shape, b.shape)
    a = AShare(jnp.broadcast_to(a.s0, shape), jnp.broadcast_to(a.s1, shape))
    b = AShare(jnp.broadcast_to(b.s0, shape), jnp.broadcast_to(b.s1, shape))
    diff = sub(a, b)
    return b2a_bit(ctx, msb_carry(ctx, diff))


def mux(ctx: Ctx, z: AShare, x: AShare, y: AShare) -> AShare:
    """MUX(z, x, y) = z*x + (1-z)*y = z*(x-y) + y (z is a 0/1 A-share)."""
    return add(smul(ctx, z, sub(x, y)), y)


# ---------------------------------------------------------------------------
# F^k_min — tournament argmin (paper Fig. 1), fully vectorized over n
# ---------------------------------------------------------------------------

def argmin_onehot(ctx: Ctx, d: AShare, *, return_min: bool = False):
    """Secret-shared one-hot argmin along the last axis of (n, k) distances.

    ceil(log2 k) rounds of [CMP + batched MUX], each round vectorized over
    all surviving pairs of all n samples at once — k-1 CMPMs total, exactly
    the binary-tree reduction of Fig. 1. Two launch-count optimizations on
    top of the paper's tree:

    * The candidate one-hots start out PUBLIC (the identity's columns), so
      they are carried as indexes — not as an (n, k, k) zero-padded share
      tensor — until the first MUX, which is a local public-constant product
      (mul_pub, no triple, no traffic). Peak tournament memory halves.
    * From the second level on, the value MUX and the one-hot MUX share the
      selector bit, so both Beaver recombinations are batched into ONE smul
      over the stacked (values | one-hots) tensor: one triple, one exchange
      round, one recombination pass per tournament round instead of two.

    return_min=True additionally returns the (n,) share of the winning
    value — the tournament already carries it, so this is free (no extra
    triples, traffic, or rounds; the dealer schedule is unchanged). The
    scoring path uses it for the distance-to-assigned-centroid output.
    """
    n, k = d.shape
    eye = jnp.eye(k, dtype=ring.DTYPE)
    vals = d
    ohs: AShare | None = None   # public eye carried implicitly until 1st MUX
    m = k
    while m > 1:
        half, odd = m // 2, m % 2
        l_v = AShare(vals.s0[:, 0:2 * half:2], vals.s1[:, 0:2 * half:2])
        r_v = AShare(vals.s0[:, 1:2 * half:2], vals.s1[:, 1:2 * half:2])
        b = cmp_lt(ctx, l_v, r_v)                       # [l < r]  (n, half)
        b_oh = AShare(b.s0[..., None], b.s1[..., None])  # broadcast over k
        if ohs is None:
            # level 1: one-hot operands are public eye columns — the MUX
            # b*(l_o - r_o) + r_o is a local scalar-by-public product.
            v_min = mux(ctx, b, l_v, r_v)
            l_o = eye[0:2 * half:2][None]                # (1, half, k) public
            r_o = eye[1:2 * half:2][None]
            o_min = add_pub(mul_pub(b_oh, l_o - r_o), r_o)
            if odd:
                tail_o = AShare(jnp.broadcast_to(eye[None, -1:], (n, 1, k)),
                                jnp.zeros((n, 1, k), ring.DTYPE))
        else:
            l_o = AShare(ohs.s0[:, 0:2 * half:2], ohs.s1[:, 0:2 * half:2])
            r_o = AShare(ohs.s0[:, 1:2 * half:2], ohs.s1[:, 1:2 * half:2])
            # batched MUX: stack (values | one-hots) differences along the
            # last axis and recombine with ONE Beaver product against the
            # shared selector — (n, half, 1+k) in a single round.
            diff = AShare(
                jnp.concatenate([(l_v.s0 - r_v.s0)[..., None],
                                 l_o.s0 - r_o.s0], -1),
                jnp.concatenate([(l_v.s1 - r_v.s1)[..., None],
                                 l_o.s1 - r_o.s1], -1))
            zz = smul(ctx, b_oh, diff)
            v_min = add(AShare(zz.s0[..., 0], zz.s1[..., 0]), r_v)
            o_min = add(AShare(zz.s0[..., 1:], zz.s1[..., 1:]), r_o)
            if odd:
                tail_o = AShare(ohs.s0[:, -1:], ohs.s1[:, -1:])
        if odd:
            v_min = AShare(jnp.concatenate([v_min.s0, vals.s0[:, -1:]], 1),
                           jnp.concatenate([v_min.s1, vals.s1[:, -1:]], 1))
            o_min = AShare(jnp.concatenate([o_min.s0, tail_o.s0], 1),
                           jnp.concatenate([o_min.s1, tail_o.s1], 1))
        vals, ohs, m = v_min, o_min, half + odd
    if ohs is None:    # k == 1: the argmin is trivially the only column
        oh = AShare(jnp.ones((n, 1), ring.DTYPE),
                    jnp.zeros((n, 1), ring.DTYPE))
        if return_min:
            return oh, AShare(d.s0[:, 0], d.s1[:, 0])
        return oh
    oh = AShare(ohs.s0[:, 0], ohs.s1[:, 0])    # (n, k)
    if return_min:
        return oh, AShare(vals.s0[:, 0], vals.s1[:, 0])
    return oh


# ---------------------------------------------------------------------------
# Secure reciprocal (division -> SADD/SMUL, paper Sec 4.2 F_SCU)
# ---------------------------------------------------------------------------

def reciprocal(ctx: Ctx, den: AShare, max_den: float, *, f: int = ring.F,
               iters: int | None = None, extra_bits: int = 0) -> AShare:
    """Newton-Raphson 1/den, den an *integer-valued* share (scale 1) in
    [1, max_den]; returns a share of 1/den at scale f + extra_bits.

    Normalize d' = den / 2^m in (0, 1] (m = ceil(log2 max_den); exact local
    shift when m <= f), iterate x <- x(2 - d'x) from x0 = 2 - d'
    (error e0 = (1-d')^2 < 1 converges for ALL d' in (0,1]), then unscale
    by >> (m - extra_bits). Error doubles bits per iter: ~m + log2(f) iters.

    extra_bits trades headroom for precision: the plain scale-f output has
    absolute error ~2^-f, i.e. *relative* error ~2^-f * den; keeping
    extra_bits <= m of the internal scale recovers 2^-(f+extra-m)-relative
    precision (the centroid update uses this — the subsequent num*recip
    product cancels den so the product still fits the ring).
    """
    m = max(0, int(np.ceil(np.log2(max_den))))
    extra_bits = min(extra_bits, m)
    if iters is None:
        iters = m + 6
    if m <= f:
        dp = lshift(den, f - m)                   # exact local rescale
    else:
        dp = trunc(mul_pub(den, jnp.uint64(1 << (2 * f - m))), f)
    two = ring.encode(2.0, f)
    x = pub_sub(two, dp)                          # x0 = 2 - d'
    for _ in range(iters):
        dx = smul(ctx, dp, x, trunc_f=f)
        x = smul(ctx, x, pub_sub(two, dx), trunc_f=f)
    # x ~ 2^(f+m)/den; drop (m - extra_bits) to land at scale f + extra_bits
    return trunc(x, m - extra_bits) if m > extra_bits else x
