"""2PC protocol ops over additive shares (paper Sec 3.1 / 4.2).

Implemented: SADD (local), SMUL (elementwise + vectorized matmul via Beaver
triples), SecureML local truncation, A2B via a bit-packed Kogge-Stone adder
(log_2 l AND rounds instead of the naive l-round ripple carry), MSB, CMP,
B2A, MUX, the tournament argmin F^k_min (Fig. 1), and a Newton-Raphson
secure reciprocal used by the centroid-update division (paper: "secret
sharing division which is converted to SADD & SMUL operations").

Everything is vectorized: one CMP call compares whole (n, k/2) tensors, one
matmul call moves whole matrices — this IS the paper's vectorization claim.

All ops take a `Ctx` that carries the triple provider (offline phase) and the
communication log. Per-op traffic is shape-determined and exact.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import ring
from repro.core.channel import CommLog
from repro.core.sharing import AShare, BShare
from repro.core.triples import TrustedDealer


FUSE_BEAVER = True
# P0's Beaver recombination z0 = z + u0@F + e@v0 + e@F folds into
# z + u0@F + e@(v0 + F): one fewer ring matmul on the online critical path
# (pure local algebra, no protocol/security change). Toggled off by the
# §Perf harness to measure the paper-faithful baseline.


@dataclasses.dataclass
class Ctx:
    dealer: TrustedDealer
    log: CommLog
    tag: str = "misc"  # current Lloyd step: S1 / S2 / S3

    def send(self, nbytes: int, rounds: int = 1) -> None:
        self.log.send(nbytes, tag=self.tag, phase="online", rounds=rounds)


def make_ctx(seed: int = 0) -> Ctx:
    log = CommLog()
    return Ctx(dealer=TrustedDealer(seed=seed, log=log), log=log)


# ---------------------------------------------------------------------------
# Linear ops — local, no communication (paper SADD)
# ---------------------------------------------------------------------------

def add(a: AShare, b: AShare) -> AShare:
    return AShare(a.s0 + b.s0, a.s1 + b.s1)


def sub(a: AShare, b: AShare) -> AShare:
    return AShare(a.s0 - b.s0, a.s1 - b.s1)


def add_pub(a: AShare, c) -> AShare:
    """a + c with public ring tensor c (added to one share only)."""
    c = jnp.asarray(c, ring.DTYPE)
    return AShare(a.s0 + c, a.s1)


def pub_sub(c, a: AShare) -> AShare:
    c = jnp.asarray(c, ring.DTYPE)
    return AShare(c - a.s0, ring.neg(a.s1))


def mul_pub(a: AShare, c) -> AShare:
    """a * c with public *integer* ring tensor c (scale-preserving)."""
    c = jnp.asarray(c, ring.DTYPE)
    return AShare(a.s0 * c, a.s1 * c)


def lshift(a: AShare, n: int) -> AShare:
    return AShare(a.s0 << n, a.s1 << n)


def neg(a: AShare) -> AShare:
    return AShare(ring.neg(a.s0), ring.neg(a.s1))


def matmul_pub_l(x_pub, a: AShare) -> AShare:
    """Public X @ shared A — local at the party that owns X."""
    x_pub = jnp.asarray(x_pub, ring.DTYPE)
    return AShare(_ring_mm(x_pub, a.s0), _ring_mm(x_pub, a.s1))


def matmul_pub_r(a: AShare, y_pub) -> AShare:
    y_pub = jnp.asarray(y_pub, ring.DTYPE)
    return AShare(_ring_mm(a.s0, y_pub), _ring_mm(a.s1, y_pub))


def _ring_mm(a, b):
    """uint64 matmul mod 2^64 (jnp dot on uint64 wraps)."""
    return jnp.matmul(a, b)


# ---------------------------------------------------------------------------
# Truncation (SecureML local truncation; error <= 2^-f w.h.p.)
# ---------------------------------------------------------------------------

def trunc(a: AShare, f: int = ring.F) -> AShare:
    """SecureML local truncation: P0 logically shifts its share; P1
    negates-shifts-negates. Off-by-2^-f LSB error w.h.p.; failure probability
    2^{f+1-l} per lane for |x| < 2^{l-f-1} (SecureML Thm. 1)."""
    if f == 0:
        return a
    s0 = a.s0 >> f                                   # logical shift (uint64)
    s1 = ring.neg(ring.neg(a.s1) >> f)
    return AShare(s0, s1)


# ---------------------------------------------------------------------------
# SMUL — Beaver multiplication (elementwise and matmul forms)
# ---------------------------------------------------------------------------

def smul(ctx: Ctx, a: AShare, b: AShare, *, trunc_f: int | None = None) -> AShare:
    """Elementwise product (broadcasting). One round: exchange E, F."""
    shape = jnp.broadcast_shapes(a.shape, b.shape)
    t = ctx.dealer.mul_triple(shape, tag=ctx.tag)
    a = AShare(jnp.broadcast_to(a.s0, shape), jnp.broadcast_to(a.s1, shape))
    b = AShare(jnp.broadcast_to(b.s0, shape), jnp.broadcast_to(b.s1, shape))
    e = (a.s0 - t.u.s0) + (a.s1 - t.u.s1)  # Rec(a - u)
    f = (b.s0 - t.v.s0) + (b.s1 - t.v.s1)  # Rec(b - v)
    # Both parties exchange their local (E,F) halves: 2 tensors each way.
    ctx.send(2 * 2 * ring.nbytes(shape), rounds=1)
    # ab = uv + u*f + e*v + e*f ;  z_i = z_t_i + u_i*f + e*v_i + [i==0]*e*f
    if FUSE_BEAVER:
        z0 = t.z.s0 + t.u.s0 * f + e * (t.v.s0 + f)
    else:
        z0 = t.z.s0 + t.u.s0 * f + e * t.v.s0 + e * f
    z1 = t.z.s1 + t.u.s1 * f + e * t.v.s1
    out = AShare(z0, z1)
    return trunc(out, trunc_f) if trunc_f else out


def smatmul(ctx: Ctx, a: AShare, b: AShare, *, trunc_f: int | None = None) -> AShare:
    """Secret-shared matrix product (paper's vectorized SMUL). One round."""
    (n, d), (d2, k) = a.shape, b.shape
    assert d == d2
    t = ctx.dealer.matmul_triple((n, d), (d, k), tag=ctx.tag)
    e = (a.s0 - t.u.s0) + (a.s1 - t.u.s1)
    f = (b.s0 - t.v.s0) + (b.s1 - t.v.s1)
    ctx.send(2 * (ring.nbytes((n, d)) + ring.nbytes((d, k))), rounds=1)
    # AB = UV + U F + E V + E F
    if FUSE_BEAVER:  # P0: E@(V0 + F) fuses the public E@F term (see flag)
        z0 = t.z.s0 + _ring_mm(t.u.s0, f) + _ring_mm(e, t.v.s0 + f)
    else:
        z0 = t.z.s0 + _ring_mm(t.u.s0, f) + _ring_mm(e, t.v.s0) \
            + _ring_mm(e, f)
    z1 = t.z.s1 + _ring_mm(t.u.s1, f) + _ring_mm(e, t.v.s1)
    out = AShare(z0, z1)
    return trunc(out, trunc_f) if trunc_f else out


def square(ctx: Ctx, a: AShare, *, trunc_f: int | None = None) -> AShare:
    return smul(ctx, a, a, trunc_f=trunc_f)


# ---------------------------------------------------------------------------
# Boolean layer: bit-packed AND / XOR, Kogge-Stone adder, MSB, CMP
# ---------------------------------------------------------------------------

def bxor(x: BShare, y: BShare) -> BShare:
    return BShare(x.b0 ^ y.b0, x.b1 ^ y.b1)


def bxor_pub(x: BShare, c) -> BShare:
    return BShare(x.b0 ^ jnp.asarray(c, ring.DTYPE), x.b1)


def band(ctx: Ctx, x: BShare, y: BShare) -> BShare:
    """Bit-packed AND via binary Beaver triple. One round, 64 gates/lane."""
    shape = jnp.broadcast_shapes(x.shape, y.shape)
    t = ctx.dealer.bin_triple(shape, tag=ctx.tag)
    x = BShare(jnp.broadcast_to(x.b0, shape), jnp.broadcast_to(x.b1, shape))
    y = BShare(jnp.broadcast_to(y.b0, shape), jnp.broadcast_to(y.b1, shape))
    e = (x.b0 ^ t.u.b0) ^ (x.b1 ^ t.u.b1)
    f = (y.b0 ^ t.v.b0) ^ (y.b1 ^ t.v.b1)
    ctx.send(2 * 2 * ring.nbytes(shape), rounds=1)
    # xy = (u^e)&(v^f) = uv ^ u&f ^ e&v ^ e&f
    z0 = t.z.b0 ^ (t.u.b0 & f) ^ (e & (t.v.b0 ^ f))
    z1 = t.z.b1 ^ (t.u.b1 & f) ^ (e & t.v.b1)
    return BShare(z0, z1)


def _bshift_l(x: BShare, s: int) -> BShare:
    return BShare(x.b0 << s, x.b1 << s)


def msb_carry(ctx: Ctx, a: AShare) -> BShare:
    """B-share of MSB(a.s0 + a.s1 mod 2^64) via Kogge-Stone carry network.

    Each party's arithmetic share is a *local plaintext* input to a boolean
    adder: X = (s0, 0), Y = (0, s1) as B-shares. log2(64)=6 AND rounds; the
    two ANDs per level (G and P updates) are batched into ONE round by
    stacking, so the whole MSB costs 7 rounds (1 initial + 6 levels).
    """
    x = BShare(a.s0, jnp.zeros_like(a.s0))
    y = BShare(jnp.zeros_like(a.s1), a.s1)
    g = band(ctx, x, y)                     # generate
    p = bxor(x, y)                          # propagate (free)
    p_orig = p
    for s in (1, 2, 4, 8, 16, 32):
        # one batched AND round: [p & (g<<s), p & (p<<s)]
        lhs = BShare(jnp.stack([p.b0, p.b0]), jnp.stack([p.b1, p.b1]))
        rhs_g, rhs_p = _bshift_l(g, s), _bshift_l(p, s)
        rhs = BShare(jnp.stack([rhs_g.b0, rhs_p.b0]), jnp.stack([rhs_g.b1, rhs_p.b1]))
        both = band(ctx, lhs, rhs)
        g = bxor(g, BShare(both.b0[0], both.b1[0]))  # g | (p & g<<s); disjoint => xor
        p = BShare(both.b0[1], both.b1[1])
    # sum bit 63 = p_orig[63] ^ carry_in[63];  carry_in[63] = G[62]
    msb = bxor(BShare((p_orig.b0 >> 63) & jnp.uint64(1),
                      (p_orig.b1 >> 63) & jnp.uint64(1)),
               BShare((g.b0 >> 62) & jnp.uint64(1),
                      (g.b1 >> 62) & jnp.uint64(1)))
    return msb  # single-bit B-share (values in {0,1})


def b2a_bit(ctx: Ctx, b: BShare) -> AShare:
    """Single-bit B-share -> A-share: b = b0 + b1 - 2*b0*b1.

    Each party arithmetically shares its own boolean share (one message each,
    half a round: batched into 1 round), then one Beaver product.
    """
    shape = b.shape
    one = jnp.uint64(1)
    b0, b1 = b.b0 & one, b.b1 & one     # LSB view of the packed share
    r0 = ctx.dealer.rand(shape)
    r1 = ctx.dealer.rand(shape)
    a0 = AShare(b0 - r0, r0)            # P0 shares its bit b0
    a1 = AShare(r1, b1 - r1)            # P1 shares its bit b1
    ctx.send(2 * ring.nbytes(shape), rounds=1)
    prod = smul(ctx, a0, a1)            # scale-1 bits: no truncation
    return sub(add(a0, a1), lshift(prod, 1))


def cmp_lt(ctx: Ctx, a: AShare, b: AShare) -> AShare:
    """CMP: A-share of the indicator [a < b] (signed fixed-point compare)."""
    shape = jnp.broadcast_shapes(a.shape, b.shape)
    a = AShare(jnp.broadcast_to(a.s0, shape), jnp.broadcast_to(a.s1, shape))
    b = AShare(jnp.broadcast_to(b.s0, shape), jnp.broadcast_to(b.s1, shape))
    diff = sub(a, b)
    return b2a_bit(ctx, msb_carry(ctx, diff))


def mux(ctx: Ctx, z: AShare, x: AShare, y: AShare) -> AShare:
    """MUX(z, x, y) = z*x + (1-z)*y = z*(x-y) + y (z is a 0/1 A-share)."""
    return add(smul(ctx, z, sub(x, y)), y)


# ---------------------------------------------------------------------------
# F^k_min — tournament argmin (paper Fig. 1), fully vectorized over n
# ---------------------------------------------------------------------------

def argmin_onehot(ctx: Ctx, d: AShare) -> AShare:
    """Secret-shared one-hot argmin along the last axis of (n, k) distances.

    ceil(log2 k) rounds of [CMP + 2 MUX], each round vectorized over all
    surviving pairs of all n samples at once — k-1 CMPMs total, exactly the
    binary-tree reduction of Fig. 1.
    """
    n, k = d.shape
    eye = jnp.eye(k, dtype=ring.DTYPE)
    vals = d
    ohs = AShare(jnp.broadcast_to(eye[None], (n, k, k)),
                 jnp.zeros((n, k, k), ring.DTYPE))  # public one-hots as shares
    m = k
    while m > 1:
        half, odd = m // 2, m % 2
        l_v = AShare(vals.s0[:, 0:2 * half:2], vals.s1[:, 0:2 * half:2])
        r_v = AShare(vals.s0[:, 1:2 * half:2], vals.s1[:, 1:2 * half:2])
        l_o = AShare(ohs.s0[:, 0:2 * half:2], ohs.s1[:, 0:2 * half:2])
        r_o = AShare(ohs.s0[:, 1:2 * half:2], ohs.s1[:, 1:2 * half:2])
        b = cmp_lt(ctx, l_v, r_v)                       # [l < r]  (n, half)
        v_min = mux(ctx, b, l_v, r_v)
        b_oh = AShare(b.s0[..., None], b.s1[..., None])  # broadcast over k
        o_min = mux(ctx, b_oh, l_o, r_o)
        if odd:
            v_min = AShare(jnp.concatenate([v_min.s0, vals.s0[:, -1:]], 1),
                           jnp.concatenate([v_min.s1, vals.s1[:, -1:]], 1))
            o_min = AShare(jnp.concatenate([o_min.s0, ohs.s0[:, -1:]], 1),
                           jnp.concatenate([o_min.s1, ohs.s1[:, -1:]], 1))
        vals, ohs, m = v_min, o_min, half + odd
    return AShare(ohs.s0[:, 0], ohs.s1[:, 0])  # (n, k)


# ---------------------------------------------------------------------------
# Secure reciprocal (division -> SADD/SMUL, paper Sec 4.2 F_SCU)
# ---------------------------------------------------------------------------

def reciprocal(ctx: Ctx, den: AShare, max_den: float, *, f: int = ring.F,
               iters: int | None = None, extra_bits: int = 0) -> AShare:
    """Newton-Raphson 1/den, den an *integer-valued* share (scale 1) in
    [1, max_den]; returns a share of 1/den at scale f + extra_bits.

    Normalize d' = den / 2^m in (0, 1] (m = ceil(log2 max_den); exact local
    shift when m <= f), iterate x <- x(2 - d'x) from x0 = 2 - d'
    (error e0 = (1-d')^2 < 1 converges for ALL d' in (0,1]), then unscale
    by >> (m - extra_bits). Error doubles bits per iter: ~m + log2(f) iters.

    extra_bits trades headroom for precision: the plain scale-f output has
    absolute error ~2^-f, i.e. *relative* error ~2^-f * den; keeping
    extra_bits <= m of the internal scale recovers 2^-(f+extra-m)-relative
    precision (the centroid update uses this — the subsequent num*recip
    product cancels den so the product still fits the ring).
    """
    m = max(0, int(np.ceil(np.log2(max_den))))
    extra_bits = min(extra_bits, m)
    if iters is None:
        iters = m + 6
    if m <= f:
        dp = lshift(den, f - m)                   # exact local rescale
    else:
        dp = trunc(mul_pub(den, jnp.uint64(1 << (2 * f - m))), f)
    two = ring.encode(2.0, f)
    x = pub_sub(two, dp)                          # x0 = 2 - d'
    for _ in range(iters):
        dx = smul(ctx, dp, x, trunc_f=f)
        x = smul(ctx, x, pub_sub(two, dx), trunc_f=f)
    # x ~ 2^(f+m)/den; drop (m - extra_bits) to land at scale f + extra_bits
    return trunc(x, m - extra_bits) if m > extra_bits else x
