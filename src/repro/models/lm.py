"""Model assembly: parameter init, scan-over-groups forward, chunked
vocab-parallel CE loss, prefill, and KV-cache / recurrent-state decode.

Layout invariants (see models/sharding.py):
* every per-layer parameter is STACKED with a leading `repeats` dim and the
  forward runs lax.scan over it -> the HLO holds ONE unit body per group
  (compile time independent of depth; remat applied at unit level);
* logits are never materialized (B, T, V): the loss scans over sequence
  chunks with the head kept vocab-sharded (chunked vocab-parallel CE);
* in-embedding is D-sharded (gather-friendly), out-head is V-sharded
  (reduction-friendly) — stored separately even for tied archs (noted in
  DESIGN.md; param counts use the analytic tied count).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ScanGroup
from repro.models import layers as L
from repro.models import recurrent as R

BF16 = jnp.bfloat16
F32 = jnp.float32


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _dense(key, fan_in, shape):
    return (jax.random.normal(key, shape, F32) / np.sqrt(fan_in)).astype(BF16)


def _zeros(shape):
    return jnp.zeros(shape, BF16)


def _init_mlp(key, d, f):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": _dense(k1, d, (d, f)), "w_up": _dense(k2, d, (d, f)),
            "w_down": _dense(k3, f, (f, d))}


def _init_attn(key, cfg: ModelConfig, window: bool = False):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    p = {"wq": _dense(ks[0], d, (d, h * dh)),
         "wk": _dense(ks[1], d, (d, hkv * dh)),
         "wv": _dense(ks[2], d, (d, hkv * dh)),
         "wo": _dense(ks[3], h * dh, (h * dh, d)),
         "ln1": _zeros((d,)), "ln2": _zeros((d,))}
    p.update(_init_mlp(ks[4], d, cfg.d_ff))
    if cfg.post_norms:
        p["ln1_post"] = _zeros((d,))
        p["ln2_post"] = _zeros((d,))
    return p


def _init_moe(key, cfg: ModelConfig):
    d, fe = cfg.d_model, cfg.d_ff_expert
    ep = padded_experts(cfg)
    ks = jax.random.split(key, 5)
    p = {"router": _dense(ks[0], d, (d, cfg.n_experts)),
         "w_gate": _dense(ks[1], d, (ep, d, fe)),
         "w_up": _dense(ks[2], d, (ep, d, fe)),
         "w_down": _dense(ks[3], fe, (ep, fe, d))}
    if cfg.n_shared_experts:
        p["shared"] = _init_mlp(ks[4], d, cfg.n_shared_experts * fe)
    return p


def _init_mla(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {"wq_a": _dense(ks[0], d, (d, cfg.q_lora)),
            "q_norm": _zeros((cfg.q_lora,)),
            "wq_b": _dense(ks[1], cfg.q_lora, (cfg.q_lora, h * (dn + dr))),
            "wkv_a": _dense(ks[2], d, (d, cfg.kv_lora + dr)),
            "kv_norm": _zeros((cfg.kv_lora,)),
            "wkv_b": _dense(ks[3], cfg.kv_lora, (cfg.kv_lora, h * (dn + dv))),
            "wo": _dense(ks[4], h * dv, (h * dv, d)),
            "ln1": _zeros((d,)), "ln2": _zeros((d,))}


def _init_rwkv(key, cfg: ModelConfig):
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    ks = jax.random.split(key, 10)
    return {"mix_rkvw": jnp.full((1, 1, d), 0.5, BF16),
            "wr": _dense(ks[0], d, (d, d)), "wk": _dense(ks[1], d, (d, d)),
            "wv": _dense(ks[2], d, (d, d)), "wg": _dense(ks[3], d, (d, d)),
            "wo": _dense(ks[4], d, (d, d)),
            "w_base": jnp.full((d,), -6.0, F32),
            "w_lora_a": _dense(ks[5], d, (d, 64)).astype(F32),
            "w_lora_b": _dense(ks[6], 64, (64, d)).astype(F32),
            "u_bonus": jnp.zeros((d,), F32),
            "ln_x_scale": jnp.ones((h, cfg.rwkv_head_dim), F32),
            "ln1": _zeros((d,)), "ln2": _zeros((d,)),
            "mix_ch": jnp.full((1, 1, d), 0.5, BF16),
            "wk_ch": _dense(ks[7], d, (d, cfg.d_ff)),
            "wv_ch": _dense(ks[8], cfg.d_ff, (cfg.d_ff, d)),
            "wr_ch": _dense(ks[9], d, (d, d))}


def _init_rglru(key, cfg: ModelConfig):
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 7)
    p = _init_mlp(ks[6], d, cfg.d_ff)
    p.update({"w_gate_branch": _dense(ks[0], d, (d, w)),
            "w_in": _dense(ks[1], d, (d, w)),
            "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w), F32)
                       * 0.1).astype(BF16),
            "conv_b": _zeros((w,)),
            "w_rg": _dense(ks[3], w, (w, w)).astype(F32),
            "b_rg": jnp.zeros((w,), F32),
            "w_ig": _dense(ks[4], w, (w, w)).astype(F32),
            "b_ig": jnp.zeros((w,), F32),
              "lambda": jnp.full((w,), 0.65, F32),
              "w_out": _dense(ks[5], w, (w, d)),
              "ln1": _zeros((d,)), "ln2": _zeros((d,))})
    return p


def _init_xattn(key, cfg: ModelConfig):
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    p = _init_attn(ks[0], cfg)
    p.update({"xq": _dense(ks[1], d, (d, h * dh)),
              "xk": _dense(ks[2], d, (d, hkv * dh)),
              "xv": _dense(ks[3], d, (d, hkv * dh)),
              "xo": _dense(ks[4], h * dh, (h * dh, d)),
              "ln3": _zeros((d,))})
    return p


_INIT = {"attn": _init_attn,
         "attn_local": _init_attn,
         "moe_attn": None,  # handled below
         "mla": None,
         "mla_dense": None,
         "rwkv": _init_rwkv,
         "rglru": _init_rglru,
         "rglru_attn": _init_attn,
         "xattn": _init_xattn}


def padded_experts(cfg: ModelConfig, tp: int | None = None) -> int:
    m = tp or cfg.expert_pad_multiple
    return -(-cfg.n_experts // m) * m if cfg.n_experts else 0


def _init_block(kind: str, key, cfg: ModelConfig):
    if kind == "moe_attn":
        k1, k2 = jax.random.split(key)
        p = _init_attn(k1, cfg)
        for name in ("w_gate", "w_up", "w_down"):
            p.pop(name)
        p["moe"] = _init_moe(k2, cfg)
        return p
    if kind in ("mla", "mla_dense"):
        k1, k2 = jax.random.split(key)
        p = _init_mla(k1, cfg)
        if kind == "mla":
            p["moe"] = _init_moe(k2, cfg)
        else:
            p.update(_init_mlp(k2, cfg.d_model, cfg.d_ff_dense_first
                               or cfg.d_ff))
        return p
    return _INIT[kind](key, cfg)


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    d, vp = cfg.d_model, cfg.vocab_padded
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (vp, d), F32) * 0.02).astype(BF16),
        "head": _dense(keys[1], d, (d, vp)),
        "final_norm": _zeros((d,)),
        "groups": [],
    }
    gk = jax.random.split(keys[2], len(cfg.groups))
    for gi, grp in enumerate(cfg.groups):
        unit_params = {}
        for bi, kind in enumerate(grp.unit):
            bkeys = jax.random.split(jax.random.fold_in(gk[gi], bi),
                                     grp.repeats)
            unit_params[f"b{bi}"] = jax.vmap(
                lambda k: _init_block(kind, k, cfg))(bkeys)
        params["groups"].append(unit_params)
    if cfg.enc_dec:
        ek = jax.random.split(keys[3], cfg.n_enc_layers)
        params["encoder"] = jax.vmap(
            lambda k: _init_block("attn", k, cfg))(ek)
        params["enc_norm"] = _zeros((d,))
    return params


def init_params_shape_only(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# block application (train/prefill mode)
# ---------------------------------------------------------------------------

def _norm(p, name, x, cfg):
    return L.rms_norm(x, p[name], cfg.norm_eps)


def _pin_batch(x, cfg: ModelConfig):
    """Pin the activation batch dim to the configured mesh axes. Without
    this, pure-FSDP sharding lets GSPMD replicate the scan carry (observed:
    19x flops). No-op when cfg.act_axes is empty (CPU tests/examples)."""
    if not cfg.act_axes:
        return x
    from jax.sharding import PartitionSpec
    spec = PartitionSpec(tuple(cfg.act_axes),
                         *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def apply_block(kind: str, p: dict, x: jnp.ndarray, cfg: ModelConfig,
                positions: jnp.ndarray, enc: jnp.ndarray | None = None):
    if kind in ("attn", "attn_local", "rglru_attn", "moe_attn", "xattn"):
        window = cfg.window if kind in ("attn_local", "rglru_attn") else None
        a = L.attention(p, _norm(p, "ln1", x, cfg), cfg, causal=True,
                        window=window, positions=positions)
        if cfg.post_norms:
            a = _norm(p, "ln1_post", a, cfg)
        x = x + a
        if kind == "xattn":
            x = x + L.cross_attention(
                {"wq": p["xq"], "wk": p["xk"], "wv": p["xv"], "wo": p["xo"]},
                _norm(p, "ln3", x, cfg), enc, cfg)
        h = _norm(p, "ln2", x, cfg)
        m = L.moe_mlp(p["moe"], h, cfg) if kind == "moe_attn" \
            else L.glu_mlp(p, h, cfg.act)
        if cfg.post_norms:
            m = _norm(p, "ln2_post", m, cfg)
        return x + m
    if kind in ("mla", "mla_dense"):
        x = x + L.mla_attention(p, _norm(p, "ln1", x, cfg), cfg, positions)
        h = _norm(p, "ln2", x, cfg)
        m = L.moe_mlp(p["moe"], h, cfg) if kind == "mla" \
            else L.glu_mlp(p, h, cfg.act)
        return x + m
    if kind == "rwkv":
        tm, _ = R.rwkv_time_mix(p, _norm(p, "ln1", x, cfg), cfg)
        x = x + tm
        cm, _ = R.rwkv_channel_mix(p, _norm(p, "ln2", x, cfg), cfg)
        return x + cm
    if kind == "rglru":
        rec, _ = R.rg_lru(p, _norm(p, "ln1", x, cfg), cfg)
        x = x + rec
        return x + L.glu_mlp(p, _norm(p, "ln2", x, cfg), cfg.act)
    raise ValueError(kind)


def _encoder_block(p, x, cfg):
    a = L.attention(p, _norm(p, "ln1", x, cfg), cfg, causal=False,
                    window=None, positions=jnp.arange(x.shape[1]))
    x = x + a
    return x + L.glu_mlp(p, _norm(p, "ln2", x, cfg), cfg.act)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig):
    x = params["embed"][tokens].astype(BF16)
    if cfg.scale_embed:
        x = x * BF16(np.sqrt(cfg.d_model))
    return x


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            enc_inputs=None, patch_embeds=None, remat: bool = True):
    """-> final hidden states (B, T, D). Inputs:
    tokens (B,T) int32, or embeds (audio stub); patch_embeds for vlm;
    enc_inputs (B,S_enc,D) for enc-dec."""
    x = embeds if embeds is not None else embed_tokens(params, tokens, cfg)
    if patch_embeds is not None:  # vlm stub: patches replace the prefix
        x = jax.lax.dynamic_update_slice(
            x, patch_embeds.astype(x.dtype), (0, 0, 0))
    positions = jnp.arange(x.shape[1])

    enc = None
    if cfg.enc_dec:
        e = enc_inputs.astype(BF16)

        def enc_step(h, p_layer):
            return _encoder_block(p_layer, h, cfg), None
        fn = jax.checkpoint(enc_step) if remat else enc_step
        e, _ = jax.lax.scan(fn, e, params["encoder"],
                            unroll=cfg.n_enc_layers if cfg.scan_unroll else 1)
        enc = _norm(params, "enc_norm", e, cfg)

    x = _pin_batch(x, cfg)
    ckpt_kw = {}
    if cfg.remat_policy == "dots":
        ckpt_kw["policy"] = jax.checkpoint_policies.checkpoint_dots
    for grp, gp in zip(cfg.groups, params["groups"]):
        def unit(h, unit_p, _grp=grp):
            for bi, kind in enumerate(_grp.unit):
                h = apply_block(kind, unit_p[f"b{bi}"], h, cfg, positions, enc)
            return _pin_batch(h, cfg), None
        fn = jax.checkpoint(unit, **ckpt_kw) if remat else unit
        x, _ = jax.lax.scan(fn, x, gp,
                            unroll=grp.repeats if cfg.scan_unroll else 1)
    return _norm(params, "final_norm", x, cfg)


# ---------------------------------------------------------------------------
# chunked vocab-parallel cross-entropy
# ---------------------------------------------------------------------------

def ce_loss(params, cfg: ModelConfig, hidden, labels, *, chunk: int = 512):
    """hidden (B,T,D), labels (B,T) -> mean CE. Scans T in chunks; the
    (B,chunk,V) logits stay vocab-sharded and are never stored (remat)."""
    b, t, d = hidden.shape
    chunk = min(chunk, t)
    n_chunks = t // chunk
    assert t % chunk == 0, (t, chunk)
    head = params["head"]
    hc = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(h, lab):
        logits = (h.astype(BF16) @ head).astype(F32)
        logits = L.softcap(logits, cfg.final_softcap)
        m = logits.max(-1, keepdims=True)
        lse = jnp.log(jnp.exp(logits - m).sum(-1)) + m[..., 0]
        onehot = (jnp.arange(logits.shape[-1])[None, None, :]
                  == lab[..., None])
        true_logit = jnp.where(onehot, logits, 0.0).sum(-1)
        return (lse - true_logit).sum()

    # Python-unrolled (<= T/512 chunks): keeps XLA cost analysis exact and
    # never materializes (B, T, V) — backward recomputes per-chunk logits.
    total = jnp.zeros((), F32)
    for i in range(n_chunks):
        total = total + chunk_loss(hc[i], lc[i])
    return total / (b * t)


def lm_loss(params, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    hidden = forward(params, cfg,
                     tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"),
                     enc_inputs=batch.get("enc_inputs"),
                     patch_embeds=batch.get("patch_embeds"))
    return ce_loss(params, cfg, hidden, batch["labels"])
