"""Attention-free recurrences: RWKV6 (Finch) and RG-LRU (Griffin /
RecurrentGemma). Both are O(T) in sequence length — the sub-quadratic archs
that run the long_500k cell.

RWKV6 time-mix: per-head state S in R^{dk x dv} with data-dependent
per-channel decay w_t:   S_t = diag(w_t) S_{t-1} + k_t^T v_t,
                         y_t = r_t (S_{t-1} + diag(u) k_t^T v_t).
Implemented chunk-parallel: within a chunk the contributions are dense
matmuls against cumulative decay products; the state is carried across
chunks with a scan (MXU-friendly; sequential length T/chunk).

RG-LRU:  h_t = a_t . h_{t-1} + sqrt(1 - a_t^2) . (i_t . x_t),
         a_t = exp(-c * softplus(L) * sigmoid(r_t))
computed with an associative scan (log-depth) over the gated pairs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

F32 = jnp.float32
RG_LRU_C = 8.0


# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

def _token_shift(x: jnp.ndarray, mix: jnp.ndarray,
                 prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """lerp(x, shift(x), mix); prev = last token of previous segment."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    xs = jnp.concatenate([prev, x[:, :-1]], axis=1)
    return x + (xs - x) * mix.astype(x.dtype)


def rwkv_time_mix(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                  state: tuple | None = None, chunk: int = 32):
    """x (B,T,D) -> (B,T,D), carrying (shift_prev, S) state for decode."""
    b, t, d = x.shape
    dh = cfg.rwkv_head_dim
    h = d // dh
    prev_tok = state[0] if state is not None else None
    xm = _token_shift(x, p["mix_rkvw"], prev_tok)
    r = (xm @ p["wr"]).reshape(b, t, h, dh)
    k = (xm @ p["wk"]).reshape(b, t, h, dh)
    v = (xm @ p["wv"]).reshape(b, t, h, dh)
    g = jax.nn.silu(xm @ p["wg"])
    # data-dependent decay (Finch): w from a small LoRA on the shifted input.
    # raw clipped so per-step log-decay >= -2: keeps the chunk-factored
    # exponents (<= chunk*2 = 64) inside f32 range (DESIGN.md numerics note).
    raw = jnp.clip(p["w_base"].astype(F32)
                   + (xm.astype(F32) @ p["w_lora_a"]) @ p["w_lora_b"],
                   -8.0, 0.6931)  # python floats stay weak-typed (no f64)
    w = jnp.exp(-jnp.exp(raw)).reshape(b, t, h, dh)        # (0.135, 1)
    u = p["u_bonus"].reshape(h, dh).astype(F32)

    s0 = state[1] if state is not None else jnp.zeros((b, h, dh, dh), F32)

    tc = min(chunk, t)
    n_chunks = -(-t // tc)
    pad = n_chunks * tc - t
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)

    def split(z):  # (B, Nc, Tc, H, Dh) -> scan over Nc
        return z.reshape(b, n_chunks, tc, h, dh).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = split(r.astype(F32)), split(k.astype(F32)), \
        split(v.astype(F32)), split(w)

    def body(s, inp):
        rr, kk, vv, ww = inp                     # (B,H,Tc,Dh/..)
        logw = jnp.log(jnp.maximum(ww, 1e-38))
        cum = jnp.cumsum(logw, axis=2)           # prod of decays up to t (incl)
        total = cum[:, :, -1:]
        # state contribution: decay from chunk start to t-1 (exclusive of t)
        dec_in = jnp.exp(cum - logw)             # (B,H,Tc,Dh)
        y_state = jnp.einsum("bhtk,bhkv->bhtv", rr * dec_in, s)
        # intra-chunk: sum_{j<t} r_t [prod_{s=j+1..t-1} w_s] k_j v_j
        # (factored exponents bounded by 2*chunk — see decay clip above)
        att = jnp.einsum("bhtk,bhjk->bhtj",
                         rr * jnp.exp(cum - logw),
                         kk * jnp.exp(-cum))
        mask = jnp.tril(jnp.ones((tc, tc), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        bonus = jnp.einsum("bhtk,bhtk->bht", rr * u[None, :, None, :], kk)
        y = y_state + jnp.einsum("bhtj,bhjv->bhtv", att, vv) \
            + bonus[..., None] * vv
        s_new = jnp.exp(total).transpose(0, 1, 3, 2) * s + jnp.einsum(
            "bhjk,bhjv->bhkv", kk * jnp.exp(total - cum), vv)
        return s_new, y

    s_fin, ys = jax.lax.scan(body, s0, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, n_chunks * tc, h, dh)[:, :t]
    y = _group_norm(y, p["ln_x_scale"], cfg.norm_eps).reshape(b, t, d)
    out = (y.astype(x.dtype) * g.astype(x.dtype)) @ p["wo"]
    new_state = (x[:, -1:], s_fin)
    return out, new_state


def _group_norm(y: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Per-head LayerNorm on (B,T,H,Dh)."""
    yf = y.astype(F32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    return (yf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(F32).reshape(
        1, 1, *scale.shape)


def rwkv_channel_mix(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                     prev: jnp.ndarray | None = None):
    xm = _token_shift(x, p["mix_ch"], prev)
    k = jnp.square(jax.nn.relu(xm @ p["wk_ch"]))
    out = jax.nn.sigmoid(xm @ p["wr_ch"]) * (k @ p["wv_ch"])
    return out, x[:, -1:]


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

def rg_lru(p: dict, x: jnp.ndarray, cfg: ModelConfig,
           state: tuple | None = None):
    """Recurrent block: in-proj -> conv1d(4) -> RG-LRU -> gated out-proj.
    x (B,T,D) -> (B,T,D); state = (conv_tail, h_last) for decode."""
    b, t, d = x.shape
    w = cfg.lru_width or d
    gate = jax.nn.gelu(x @ p["w_gate_branch"])
    y = x @ p["w_in"]                                   # (B,T,W)
    # depthwise causal conv, width cw
    cw = cfg.conv_width
    tail = state[0] if state is not None else jnp.zeros((b, cw - 1, w), x.dtype)
    ypad = jnp.concatenate([tail, y], axis=1)
    kernel = p["conv_w"].astype(F32)                    # (cw, W)
    yc = sum(ypad[:, i:i + t].astype(F32) * kernel[i][None, None]
             for i in range(cw)).astype(x.dtype) + p["conv_b"].astype(x.dtype)
    # RG-LRU gates
    rg = jax.nn.sigmoid(yc.astype(F32) @ p["w_rg"].astype(F32) + p["b_rg"])
    ig = jax.nn.sigmoid(yc.astype(F32) @ p["w_ig"].astype(F32) + p["b_ig"])
    log_a = -RG_LRU_C * jax.nn.softplus(p["lambda"].astype(F32)) * rg
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (ig * yc.astype(F32))
    h0 = state[1] if state is not None else jnp.zeros((b, w), F32)

    def combine(ca, cb):
        a1, b1 = ca
        a2, b2 = cb
        return a1 * a2, b1 * a2 + b2

    aa, bb = jax.lax.associative_scan(combine, (a, gated), axis=1)
    h = aa * h0[:, None] + bb                           # (B,T,W)
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    new_state = (ypad[:, t:], h[:, -1])
    return out, new_state
