"""Partition specs for the (pod, data, model) production mesh.

Scheme (DESIGN.md §6): 2D parameter sharding — FSDP over 'data' on one dim,
tensor parallelism over 'model' on the other; activations/batch over
('pod','data'); experts (EP) and vocab over 'model'. The 'pod' axis is pure
DP (gradient all-reduce crosses DCN once per step, optionally compressed).

Rules are name+shape driven so they apply to every arch in the pool; leaves
whose dims don't divide the mesh fall back to replication (asserted against
a whitelist of small params in tests).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# param-name -> (fsdp_dim, tp_dim) for 2D matrices (-1 = none)
_MATRIX_RULES = {
    # in-projections (D, out): FSDP on D, TP on out
    "wq": (0, 1), "wk": (0, 1), "wv": (0, 1), "wg": (0, 1),
    "xq": (0, 1), "xk": (0, 1), "xv": (0, 1),
    "w_gate": (0, 1), "w_up": (0, 1), "wk_ch": (0, 1), "wr_ch": (0, 1),
    "w_in": (0, 1), "w_gate_branch": (0, 1),
    "wq_a": (0, 1), "wq_b": (0, 1), "wkv_a": (0, 1), "wkv_b": (0, 1),
    "wr": (0, 1), "w_rg": (0, 1), "w_ig": (0, 1),
    # out-projections (in, D): TP on in (contraction), FSDP on D
    "wo": (1, 0), "xo": (1, 0), "w_down": (1, 0), "wv_ch": (1, 0),
    "w_out": (1, 0),
    # router (D, E): FSDP on D only
    "router": (0, -1),
}

_EXPERT_PARAMS = {"w_gate", "w_up", "w_down"}  # when rank-3: (E, ., .)


def spec_for(path: tuple, leaf, mode: str = "2d") -> P:
    """Leading stacked-layer dims (from vmap/scan) get None.

    mode='2d'   : FSDP over 'data' + TP over 'model' (default).
    mode='fsdp' : pure FSDP — parameters sharded over BOTH axes on one dim,
                  no tensor parallelism; batch shards over both axes too.
                  Collective profile: per-layer weight all-gather instead of
                  per-layer activation all-reduce (EXPERIMENTS.md §Perf).
    """
    name = None
    in_experts = False
    for part in path:
        key = getattr(part, "key", getattr(part, "name", None))
        if key == "moe":
            in_experts = True
        if key == "shared":
            in_experts = False  # shared experts are plain dense matrices
        if isinstance(key, str):
            name = key
    shape = leaf.shape
    nd = len(shape)

    if name in ("embed", "head"):
        if mode == "fsdp":
            return P(None, ("data", "model")) if leaf.shape[1] % 256 == 0 \
                else P(None, "model")
        return P(None, "model")
    if name is None or nd <= 1:
        return P(*([None] * nd))

    # stacked rank: matrices may carry 1 (scan) leading dim; experts carry
    # (scan, E) or (E,) leading dims
    if name in _MATRIX_RULES:
        fsdp, tp = _MATRIX_RULES[name]
        if in_experts and name in _EXPERT_PARAMS:
            # (..., E, d1, d2): EP over 'model' on E + FSDP over 'data' on
            # the d_model dim (DeepSeek's 223B of expert weights don't fit
            # EP-only: 472 GB / 16 = 29.5 GB/chip; 2D -> 1.8 GB/chip).
            # Unpadded expert counts (E % 16 != 0, §Perf granite-moe
            # variant) skip EP and shard d_model over the whole pod.
            lead = nd - 3
            d_dim = lead + 1 if name in ("w_gate", "w_up") else lead + 2
            spec = [None] * nd
            if shape[lead] % 16 == 0:
                spec[lead] = "model"
                if shape[d_dim] % 16 == 0:
                    spec[d_dim] = "data"
            elif shape[d_dim] % 256 == 0:
                spec[d_dim] = ("data", "model")
            elif shape[d_dim] % 16 == 0:
                spec[d_dim] = "data"
            return P(*spec)
        lead = nd - 2
        spec = [None] * nd
        if mode == "fsdp":
            # shard ONE dim over the whole 256-chip pod; no TP
            for dim in (fsdp, tp):
                if dim >= 0 and shape[lead + dim] % 256 == 0:
                    spec[lead + dim] = ("data", "model")
                    return P(*spec)
            for dim in (fsdp, tp):
                if dim >= 0 and shape[lead + dim] % 16 == 0:
                    spec[lead + dim] = "data"
                    return P(*spec)
            return P(*spec)
        if fsdp >= 0 and shape[lead + fsdp] % 16 == 0:
            spec[lead + fsdp] = "data"
        if tp >= 0 and shape[lead + tp] % 16 == 0:
            spec[lead + tp] = "model"
        return P(*spec)
    return P(*([None] * nd))


def param_shardings(mesh: Mesh, params_shape, mode: str = "2d") -> object:
    """pytree of NamedShardings matching `params_shape` (from eval_shape)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [NamedSharding(mesh, spec_for(path, leaf, mode))
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_spec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else axes[0])


def batch_shardings(mesh: Mesh, batch_shape, mode: str = "2d") -> object:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if mode == "fsdp":
        axes = axes + ("model",)     # pure-DP: batch over the whole pod
    n_data = int(np.prod([mesh.shape[a] for a in axes]))

    def leaf_spec(leaf):
        if leaf.shape and leaf.shape[0] % n_data == 0:
            return NamedSharding(mesh, P(axes,
                                         *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(leaf_spec, batch_shape)


def cache_shardings(mesh: Mesh, cache_shape) -> object:
    """KV caches (leaves are (R, B, S|W|H, ...) inside the layer scan):
    batch dim 1 over ('pod','data'), dim 2 (sequence / window / state-heads)
    over 'model' — the cache is the decode working set and must spread over
    the whole pod (a 32k llama3-405b cache is ~2.2 TB). Dims that don't
    divide the mesh fall back to replication per-dim."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = int(np.prod([mesh.shape[a] for a in axes]))
    n_model = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1

    def leaf_spec(leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 2 and leaf.shape[1] % n_data == 0:
            spec[1] = axes
        if len(leaf.shape) >= 3 and leaf.shape[2] % n_model == 0:
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(leaf_spec, cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
