"""Transformer layers: norms, RoPE, GQA/MLA attention (flash-chunked causal,
banded local, softcap), dense GLU MLP, and sort-based sparse MoE.

All compute is dtype-explicit: bf16 matmuls / f32 softmax-norm-router (safe
under the MPC core's global x64 flag). Attention never materializes the full
(S, S) score matrix — online-softmax over KV chunks (flash pattern), which is
what makes prefill_32k fit HBM.

The MoE dispatch is the paper's sparsity insight applied to the LM substrate
(DESIGN.md §5): assignment one-hots are never multiplied as dense matrices;
tokens are sorted by expert id and gathered into (E, C, D) — compute and
traffic proportional to routed tokens, exactly like Protocol 2 vs dense SS.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

BF16 = jnp.bfloat16
F32 = jnp.float32


# ---------------------------------------------------------------------------
# norms / rope / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(F32)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + F32(eps))
    return (y * (1.0 + scale.astype(F32))).astype(x.dtype)


def rope_freqs(positions: jnp.ndarray, dim: int, theta: float) -> tuple:
    """positions: (...,) int32 -> cos/sin (..., dim/2) f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=F32) / dim))
    ang = positions.astype(F32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., T, H, D); cos/sin: (..., T, D/2) broadcast over heads."""
    xf = x.astype(F32)
    x1, x2 = xf[..., ::2], xf[..., 1::2]
    c, s = cos[..., None, :], sin[..., None, :]
    out = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu_sq": lambda v: jnp.square(jax.nn.relu(v))}[name]


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / F32(cap)) * F32(cap)


# ---------------------------------------------------------------------------
# flash-chunked attention (causal / banded-local), GQA
# ---------------------------------------------------------------------------

def _attn_chunk(q, k, v, mask, scale, cap):
    """q (B,Tq,H,Dk) k (B,Tk,Hkv,Dk) v (B,Tk,Hkv,Dv) mask (Tq,Tk)."""
    b, tq, h, dk = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qf = q.astype(F32).reshape(b, tq, hkv, g, dk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(F32)) * F32(scale)
    s = softcap(s, cap)
    s = jnp.where(mask[None, None, None], s, F32(-1e30))
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(F32))
    return m, l, o  # o: (b, tq, hkv, g, dv)


def flash_attention(q, k, v, *, causal: bool, window: int | None,
                    scale: float, cap: float | None,
                    q_offset: int = 0, kv_chunk: int = 2048,
                    q_chunk: int = 2048) -> jnp.ndarray:
    """Online-softmax attention over KV chunks. q (B,Tq,H,D), k/v (B,Tk,Hkv,D).
    `q_offset` is the absolute position of q[0] relative to k[0] (decode /
    banded use). Full (Tq,Tk) scores never materialize.

    Long queries are processed in q_chunk slices so the static causal/window
    chunk-skip below turns causal attention into ~T^2/2 and windowed local
    attention into O(T*window) actual compute."""
    if q.shape[1] > q_chunk:
        outs = [flash_attention(q[:, i:i + q_chunk], k, v, causal=causal,
                                window=window, scale=scale, cap=cap,
                                q_offset=q_offset + i, kv_chunk=kv_chunk,
                                q_chunk=q_chunk)
                for i in range(0, q.shape[1], q_chunk)]
        return jnp.concatenate(outs, axis=1)
    b, tq, h, dk = q.shape
    tk = k.shape[1]
    hkv = k.shape[2]
    dv = v.shape[3]
    g = h // hkv
    kv_chunk = min(kv_chunk, tk)
    n_chunks = -(-tk // kv_chunk)
    pad = n_chunks * kv_chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, hkv, dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, hkv, dv).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(tq)

    # Python-unrolled over KV chunks (NOT lax.scan): chunks whose mask is
    # statically all-False (future-of-causal / outside-window) are SKIPPED
    # entirely — banded local attention costs O(T*window), and XLA's cost
    # analysis sees every surviving chunk (scan bodies are counted once,
    # which would corrupt the roofline — see launch/roofline.py).
    m_run = jnp.full((b, hkv, g, tq), -jnp.inf, F32)
    l_run = jnp.zeros((b, hkv, g, tq), F32)
    o_run = jnp.zeros((b, hkv, g, tq, dv), F32)
    q_lo, q_hi = q_offset, q_offset + tq - 1
    for ci in range(n_chunks):
        k_lo, k_hi = ci * kv_chunk, ci * kv_chunk + kv_chunk - 1
        if causal and k_lo > q_hi:
            continue                          # chunk entirely in the future
        if window is not None and k_hi <= q_lo - window:
            continue                          # chunk entirely out of window
        k_pos = k_lo + jnp.arange(kv_chunk)
        mask = jnp.ones((tq, kv_chunk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < tk)[None, :]
        m_c, l_c, o_c = _attn_chunk(q, kc[ci], vc[ci], mask, scale, cap)
        o_c = o_c.transpose(0, 2, 3, 1, 4)      # (b, hkv, g, tq, dv)
        m_new = jnp.maximum(m_run, m_c)
        a = jnp.exp(m_run - m_new)
        bb = jnp.exp(m_c - m_new)
        l_run = l_run * a + l_c * bb
        o_run = o_run * a[..., None] + o_c * bb[..., None]
        m_run = m_new
    o = o_run / jnp.maximum(l_run, 1e-30)[..., None]
    out = o.transpose(0, 3, 1, 2, 4).reshape(b, tq, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def attention(p: dict, x: jnp.ndarray, cfg: ModelConfig, *, causal: bool,
              window: int | None, positions: jnp.ndarray) -> jnp.ndarray:
    """x (B,T,D) -> (B,T,D). p: wq (D,H*Dh), wk/wv (D,Hkv*Dh), wo (H*Dh,D)."""
    b, t, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, t, h, dh)
    k = (x @ p["wk"]).reshape(b, t, hkv, dh)
    v = (x @ p["wv"]).reshape(b, t, hkv, dh)
    cos, sin = rope_freqs(positions, dh, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        scale=1.0 / np.sqrt(dh), cap=cfg.attn_softcap)
    return o.reshape(b, t, h * dh) @ p["wo"]


def mla_attention(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                  positions: jnp.ndarray) -> jnp.ndarray:
    """DeepSeek-V2 Multi-head Latent Attention (training/prefill form).

    KV compressed to kv_lora (+ shared rope key); decode uses the absorbed
    form over the compressed cache (serving/decode.py)."""
    b, t, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    # queries through the low-rank bottleneck
    cq = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(b, t, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    # compressed kv + shared rope key
    ckv_full = x @ p["wkv_a"]                       # (B,T,kv_lora+dr)
    ckv = rms_norm(ckv_full[..., :cfg.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora:].reshape(b, t, 1, dr)
    kv = (ckv @ p["wkv_b"]).reshape(b, t, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    cos, sin = rope_freqs(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = jnp.broadcast_to(apply_rope(k_rope, cos, sin), (b, t, h, dr))
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    k_full = jnp.concatenate([k_nope, k_rope], -1)
    o = flash_attention(q_full, k_full, v, causal=True, window=None,
                        scale=1.0 / np.sqrt(dn + dr), cap=None)
    return o.reshape(b, t, h * dv) @ p["wo"]


def cross_attention(p: dict, x: jnp.ndarray, enc: jnp.ndarray,
                    cfg: ModelConfig) -> jnp.ndarray:
    b, t, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, t, h, dh)
    k = (enc @ p["wk"]).reshape(b, enc.shape[1], hkv, dh)
    v = (enc @ p["wv"]).reshape(b, enc.shape[1], hkv, dh)
    o = flash_attention(q, k, v, causal=False, window=None,
                        scale=1.0 / np.sqrt(dh), cap=None)
    return o.reshape(b, t, h * dh) @ p["wo"]


# ---------------------------------------------------------------------------
# dense GLU MLP
# ---------------------------------------------------------------------------

def glu_mlp(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    g = act_fn(act)(x @ p["w_gate"])
    return ((g * (x @ p["w_up"])) @ p["w_down"])


# ---------------------------------------------------------------------------
# MoE with sort-based (sparsity-exploiting) dispatch
# ---------------------------------------------------------------------------

def moe_mlp(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x (B,T,D) -> (B,T,D). Router f32; tokens sorted by expert id and
    gathered to (E, C, D); capacity drops overflow (cap_factor).

    moe_dispatch='global' sorts all B*T tokens at once — under pjit with a
    sharded batch that is a DISTRIBUTED sort (collective-bound, §Perf);
    'per_example' vmaps the dispatch over the batch so every sort/scatter
    stays local to its shard, with capacity budgeted per sequence."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    if cfg.moe_dispatch == "per_example":
        cap = max(1, int(np.ceil(t * k / e * cfg.capacity_factor)))
        out = jax.vmap(lambda xe: _moe_tokens(p, xe, cfg, cap))(x)
        if cfg.n_shared_experts:
            out = out + glu_mlp(p["shared"], x, cfg.act)
        return out
    n = b * t
    cap = max(1, int(np.ceil(n * k / e * cfg.capacity_factor)))
    out = _moe_tokens(p, x.reshape(n, d), cfg, cap).reshape(b, t, d)
    if cfg.n_shared_experts:
        out = out + glu_mlp(p["shared"], x, cfg.act)
    return out


def _moe_tokens(p: dict, xf: jnp.ndarray, cfg: ModelConfig,
                cap: int) -> jnp.ndarray:
    """Sort-based dispatch for a flat (N, D) token block."""
    n, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    ep = p["w_gate"].shape[-3]           # padded expert count (EP-divisible)
    logits = (xf.astype(F32) @ p["router"].astype(F32))        # (N, E)
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, k)                        # (N, K)
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
            ) * F32(cfg.router_scale)
    ids_f = ids.reshape(-1)                                    # (N*K,)
    tok_f = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    gate_f = gate.reshape(-1)
    order = jnp.argsort(ids_f)                                 # stable
    ids_s, tok_s, gate_s = ids_f[order], tok_f[order], gate_f[order]
    # position of each routed token inside its expert's queue
    same = jnp.cumsum(jnp.ones_like(ids_s)) - 1
    seg_start = jnp.searchsorted(ids_s, jnp.arange(e))         # (E,)
    pos = same - seg_start[ids_s]
    keep = pos < cap
    dest = jnp.where(keep, ids_s * cap + pos, ep * cap)        # overflow slot
    gathered = jnp.zeros((ep * cap + 1, d), xf.dtype).at[dest].set(xf[tok_s])
    h = gathered[: ep * cap].reshape(ep, cap, d)
    # expert FFN: (E,C,D) x (E,D,F) — E is the sharded (EP) axis; pad
    # experts (>= e) receive no tokens, only the zero rows
    gh = act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]))
    uh = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    oh = jnp.einsum("ecf,efd->ecd", gh * uh, p["w_down"])
    flat = jnp.concatenate([oh.reshape(ep * cap, d),
                            jnp.zeros((1, d), xf.dtype)], 0)
    contrib = flat[dest] * gate_s[:, None].astype(xf.dtype)
    return jnp.zeros((n, d), xf.dtype).at[tok_s].add(
        jnp.where(keep[:, None], contrib, 0))
