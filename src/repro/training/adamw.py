"""AdamW with configurable moment precision.

Moments inherit the parameter sharding (ZeRO-style: optimizer state is as
sharded as the params). `moment_dtype=bf16` halves optimizer HBM — the knob
that lets llama3-405b train on a single 256-chip v5e pod (DESIGN.md §6):
bf16 keeps f32's exponent range, and Adam's rsqrt normalization makes the
mantissa loss benign (validated in tests against f32 moments).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32   # jnp.bfloat16 for >=100B params
    warmup_steps: int = 100


def init_opt_state(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    return cfg.lr * warm


def apply_updates(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = _schedule(step.astype(jnp.float32), cfg)
    b1, b2 = jnp.float32(cfg.b1), jnp.float32(cfg.b2)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mf.astype(cfg.moment_dtype), vf.astype(cfg.moment_dtype)

    flat_p, td = jax.tree.flatten(params)
    flat_g = td.flatten_up_to(grads)
    flat_m = td.flatten_up_to(state["m"])
    flat_v = td.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = td.unflatten([o[0] for o in out])
    new_m = td.unflatten([o[1] for o in out])
    new_v = td.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
