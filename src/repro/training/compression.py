"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

The 'pod' mesh axis crosses DCN (slow inter-pod links); compressing the
gradient all-reduce over that axis 4x (int8 + per-tensor scale) is a standard
large-fleet trick. Error feedback keeps the quantization residual locally and
folds it into the next step, making the scheme unbiased over time
(Karimireddy et al., 2019).

Used by train_step when `compress_pod_grads=True`; tested numerically in
tests/test_training.py (convergence parity on a quadratic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, residuals):
    """-> (quantized grads as f32 trees ready for the pod all-reduce,
    new residuals). Residual = g - dequant(quant(g))."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        q, s = quantize(g)
        dq = dequantize(q, s)
        return dq, g - dq
    flat_g, td = jax.tree.flatten(grads)
    flat_r = td.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return td.unflatten([o[0] for o in out]), td.unflatten([o[1] for o in out])


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
