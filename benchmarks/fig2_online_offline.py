"""Paper Fig. 2: per-step (S1 distance / S2 assignment / S3 update)
online-vs-offline runtime and communication, WAN, n=1000 d=2 k=4 t=20."""
from __future__ import annotations

from benchmarks.common import make_blobs
from repro.core.channel import WAN
from repro.core.kmeans import KMeansConfig, SecureKMeans


def run():
    x = make_blobs(1000, 2, 4, seed=2)
    res = SecureKMeans(KMeansConfig(k=4, iters=20, seed=3)
                       ).fit(x[:, :1], x[:, 1:])
    rows = []
    for step in ("S1", "S2", "S3"):
        on_b, on_r = res.log.by_tag("online").get(step, (0, 0))
        off_b, off_r = res.log.by_tag("offline").get(step, (0, 0))
        rows.append({
            "step": step,
            "online_MB": round(on_b / 2**20, 2),
            "online_rounds": on_r,
            "offline_MB": round(off_b / 2**20, 2),
            "online_wan_s": round(WAN.time_s(on_b, on_r), 2),
            "offline_wan_s": round(WAN.time_s(off_b, off_r), 2),
        })
    return rows


def derived(rows):
    on = sum(r["online_wan_s"] for r in rows)
    off = sum(r["offline_wan_s"] for r in rows)
    return off / max(on, 1e-9)   # paper: offline dominates heavily
