"""Paper Sec 5.6 (Q5): fraud detection deployment — Jaccard of secure joint
clustering vs plaintext joint vs payment-company-only. 10k x 42 features
(18 payment + 24 merchant), 5 clusters, 10 runs averaged."""
from __future__ import annotations

import numpy as np

from repro.core.fraud import (FraudDataset, run_plaintext_fraud,
                              run_secure_fraud)


def run(quick: bool = False):
    n_runs = 3 if quick else 10
    n = 2000 if quick else 10000
    js, jp, ja = [], [], []
    for seed in range(n_runs):
        ds = FraudDataset.synthesize(n=n, d_a=18, d_b=24, n_clusters=5,
                                     seed=seed)
        j_sec, _ = run_secure_fraud(ds, k=5, iters=10, seed=seed)
        js.append(j_sec)
        jp.append(run_plaintext_fraud(ds, k=5, iters=10, seed=seed))
        ja.append(run_plaintext_fraud(ds, k=5, iters=10, seed=seed,
                                      party_a_only=True))
    return [{
        "jaccard_secure_joint": round(float(np.mean(js)), 3),
        "jaccard_plaintext_joint": round(float(np.mean(jp)), 3),
        "jaccard_payment_only": round(float(np.mean(ja)), 3),
        "paper_ours": 0.86, "paper_mkmeans": 0.83, "paper_single": 0.62,
        "runs": n_runs, "n": n,
    }]


def derived(rows):
    r = rows[0]
    return r["jaccard_secure_joint"] - r["jaccard_payment_only"]
