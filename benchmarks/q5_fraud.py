"""Paper Sec 5.6 (Q5): fraud detection deployment — Jaccard of secure joint
clustering vs plaintext joint vs payment-company-only. 10k x 42 features
(18 payment + 24 merchant), 5 clusters, 10 runs averaged.

Each run fits ONCE and scores twice: `jaccard_secure_scored` is the
leak-free path (SecureKMeans.score on shares, only scores revealed);
`jaccard_model_revealed` is the reveal_model=True escape hatch (plaintext
centroids + labels). The two should agree up to fixed-point/boundary noise
— secure scoring costs nothing in detection quality."""
from __future__ import annotations

import numpy as np

from repro.core.fraud import (FraudDataset, detect_outliers, fraud_scores,
                              jaccard, run_plaintext_fraud)
from repro.core.kmeans import KMeansConfig, SecureKMeans


def run(quick: bool = False):
    n_runs = 3 if quick else 10
    n = 2000 if quick else 10000
    frac = 0.02
    js, jr, jp, ja = [], [], [], []
    for seed in range(n_runs):
        ds = FraudDataset.synthesize(n=n, d_a=18, d_b=24, n_clusters=5,
                                     seed=seed)
        km = SecureKMeans(KMeansConfig(k=5, iters=10, partition="vertical",
                                       seed=seed))
        res = km.fit(ds.x_a, ds.x_b)
        sec = fraud_scores(km, res, ds)                     # secure scoring
        rev = fraud_scores(km, res, ds, reveal_model=True)  # escape hatch
        js.append(jaccard(detect_outliers(sec, frac), ds.y_outlier))
        jr.append(jaccard(detect_outliers(rev, frac), ds.y_outlier))
        jp.append(run_plaintext_fraud(ds, k=5, iters=10, seed=seed))
        ja.append(run_plaintext_fraud(ds, k=5, iters=10, seed=seed,
                                      party_a_only=True))
    return [{
        "jaccard_secure_scored": round(float(np.mean(js)), 3),
        "jaccard_model_revealed": round(float(np.mean(jr)), 3),
        "jaccard_plaintext_joint": round(float(np.mean(jp)), 3),
        "jaccard_payment_only": round(float(np.mean(ja)), 3),
        "paper_ours": 0.86, "paper_mkmeans": 0.83, "paper_single": 0.62,
        "runs": n_runs, "n": n,
    }]


def derived(rows):
    r = rows[0]
    return r["jaccard_secure_scored"] - r["jaccard_payment_only"]
